"""Benchmark driver — one section per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows:
  compression     — §4.2 "about fifty times smaller" claim
  query_speed     — §4.2/§5 sequences-vs-raw query latency
  rollups         — §3.2 Oink five-schema aggregations
  ngram_table     — §5.4 temporal-signal table + collocations
  pipeline_tput   — substrate throughput (vectorized vs Pig-style oracle)
  serve_tput      — serving tokens/sec + p50/p99 request latency
                    (fixed single-batch vs continuous batching)

Roofline derivation lives in benchmarks/roofline.py (reads the dry-run
artifacts; see EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import json


def main() -> None:
    from . import compression, query_speed, rollups, ngram_table, \
        pipeline_tput, serve_tput
    sections = dict(compression=compression, query_speed=query_speed,
                    rollups=rollups, ngram_table=ngram_table,
                    pipeline_tput=pipeline_tput, serve_tput=serve_tput)
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(sections), nargs="+",
                    help="run only these sections (default: all)")
    ap.add_argument("--json", action="store_true",
                    help="also write each section's machine-readable "
                         "payload (BENCH_<section>.json next to the CSV) "
                         "so the perf trajectory is recorded")
    args = ap.parse_args()
    picked = args.only or list(sections)
    print("name,us_per_call,derived")
    for name in picked:
        mod = sections[name]
        for line in mod.run():
            print(line, flush=True)
        payload = getattr(mod, "LAST_JSON", None)
        if args.json and payload is not None:
            path = getattr(mod, "JSON_PATH", f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
                f.write("\n")


if __name__ == "__main__":
    main()
