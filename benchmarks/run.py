"""Benchmark driver — one section per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows:
  compression     — §4.2 "about fifty times smaller" claim
  query_speed     — §4.2/§5 sequences-vs-raw query latency
  rollups         — §3.2 Oink five-schema aggregations
  ngram_table     — §5.4 temporal-signal table + collocations
  pipeline_tput   — substrate throughput (vectorized vs Pig-style oracle)

Roofline derivation lives in benchmarks/roofline.py (reads the dry-run
artifacts; see EXPERIMENTS.md).
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import compression, query_speed, rollups, ngram_table, \
        pipeline_tput
    print("name,us_per_call,derived")
    for mod in (compression, query_speed, rollups, ngram_table,
                pipeline_tput):
        for line in mod.run():
            print(line, flush=True)


if __name__ == "__main__":
    main()
