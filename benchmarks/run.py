"""Benchmark driver — one section per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows:
  compression     — §4.2 "about fifty times smaller" claim
  query_speed     — §4.2/§5 sequences-vs-raw query latency
  rollups         — §3.2 Oink five-schema aggregations
  ngram_table     — §5.4 temporal-signal table + collocations
  pipeline_tput   — substrate throughput (vectorized vs Pig-style oracle)
  serve_tput      — serving tokens/sec + p50/p99 request latency
                    (fixed single-batch vs continuous batching)

Roofline derivation lives in benchmarks/roofline.py (reads the dry-run
artifacts; see EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import json
import os


def select_sections(picked, sections):
    """Resolve ``--only`` values against the section registry.

    Accepts space- and/or comma-separated names (``--only a,b c``),
    preserves first-mention order, drops repeats, and raises ``ValueError``
    naming any unknown section — an unknown ``--only`` must fail loudly,
    never silently produce no rows.
    """
    names = [n for arg in picked for n in arg.split(",") if n]
    unknown = [n for n in names if n not in sections]
    if unknown:
        raise ValueError(
            f"unknown benchmark section(s) {', '.join(sorted(set(unknown)))}"
            f"; available: {', '.join(sorted(sections))}")
    seen: dict[str, None] = {}
    for n in names:
        seen.setdefault(n)
    return list(seen)


def main() -> None:
    from . import compression, query_speed, rollups, ngram_table, \
        pipeline_tput, serve_tput
    sections = dict(compression=compression, query_speed=query_speed,
                    rollups=rollups, ngram_table=ngram_table,
                    pipeline_tput=pipeline_tput, serve_tput=serve_tput)
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="+", metavar="SECTION",
                    help="run only these sections, space- or comma-"
                         "separated (default: all); unknown names error")
    ap.add_argument("--json", action="store_true",
                    help="also write each section's machine-readable "
                         "payload (BENCH_<section>.json next to the CSV) "
                         "so the perf trajectory is recorded")
    args = ap.parse_args()
    try:
        picked = (select_sections(args.only, sections) if args.only
                  else list(sections))
    except ValueError as e:
        ap.error(str(e))
    print("name,us_per_call,derived")
    for name in picked:
        mod = sections[name]
        for line in mod.run():
            print(line, flush=True)
        payload = getattr(mod, "LAST_JSON", None)
        if args.json and payload is not None:
            path = getattr(mod, "JSON_PATH", f"BENCH_{name}.json")
            # Sections share files (compression/query_speed/pipeline_tput
            # all land in BENCH_pipeline.json): merge top-level keys so a
            # partial --only run never clobbers the other sections.
            merged = {}
            if os.path.exists(path):
                try:
                    with open(path) as f:
                        merged = json.load(f)
                except (json.JSONDecodeError, OSError):
                    merged = {}
            merged.update(payload)
            with open(path, "w") as f:
                json.dump(merged, f, indent=2, sort_keys=True)
                f.write("\n")


if __name__ == "__main__":
    main()
