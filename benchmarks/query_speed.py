"""Paper claim (§4.2/§5): queries over pre-materialized session sequences
are substantially faster than over raw client-event logs, because the raw
path re-does the scan + group-by every time.

raw path      = sessionize(raw events) -> count/funnel   (the old Pig job)
mat. path     = count/funnel over the stored sequences   (session sequences)
store path    = same answers through the segment store's pruning scan
                (repro.data.store): segment metadata skips non-matching
                segments before a single payload byte decodes
kernel path   = same, through the Pallas kernels (interpret on CPU; the
                TPU-native formulation, included for completeness)

Every store row asserts its answer equals the raw re-sessionize path —
pruning must never change a result, only skip work.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import sessionize, SessionSequences
from repro.analytics import (count_events, count_events_store, funnel_reach,
                             funnel_reach_store, build_stage_table)
from repro.data.store import scan_matches_sessions, _take_rows
from repro.kernels.funnel_match.ops import deepest_stage
from repro.kernels.event_count.ops import histogram as k_histogram
from .common import corpus, timeit, row
from .compression import build_store

# Merged into BENCH_pipeline.json by benchmarks/run.py --json; the CI and
# docs-freshness gates check "store_query" (pruned fraction + equal_raw).
LAST_JSON: dict | None = None
JSON_PATH = "BENCH_pipeline.json"

FUNNEL_PATTERNS = ["*:signup:landing:form:signup_button:click",
                   "*:signup:form:form:submit_button:submit",
                   "*:signup:follow_suggestions:list:user:follow",
                   "*:signup:complete:page::impression"]


def run() -> list[str]:
    c = corpus()
    b, d, codes, seqs = c["batch"], c["dictionary"], c["codes"], c["seqs"]
    A = d.alphabet_size
    targets = d.codes_matching("*:impression")
    stages = [d.codes_matching(p) for p in FUNNEL_PATTERNS]
    stage_table = build_stage_table(stages, A)
    n_events = len(b)

    def raw_count():
        s = sessionize(b.user_id, b.session_id, b.timestamp, codes,
                       b.ip.astype(np.int64), max_sessions=n_events,
                       max_len=2048)
        sq = SessionSequences.from_sessionized(s)
        return count_events(sq, targets, A)

    def mat_count():
        return count_events(seqs, targets, A)

    us_raw = timeit(raw_count, repeats=3)
    us_mat = timeit(mat_count)
    want = mat_count()
    assert raw_count() == want  # same answer either way

    def raw_funnel():
        s = sessionize(b.user_id, b.session_id, b.timestamp, codes,
                       b.ip.astype(np.int64), max_sessions=n_events,
                       max_len=2048)
        sq = SessionSequences.from_sessionized(s)
        return funnel_reach(sq, stages, A)

    def mat_funnel():
        return funnel_reach(seqs, stages, A)

    us_rawf = timeit(raw_funnel, repeats=3)
    us_matf = timeit(mat_funnel)
    assert raw_funnel() == mat_funnel()

    sym = jnp.asarray(seqs.symbols)
    mask = jnp.asarray(seqs.mask())
    tbl = jnp.asarray(stage_table)
    us_kf = timeit(lambda: np.asarray(deepest_stage(sym, mask, tbl,
                                                    impl="interpret")))
    us_kh = timeit(lambda: np.asarray(k_histogram(sym, mask, A,
                                                  impl="interpret")))

    # ---- the store-backed path: pruned scan vs full re-sessionize --------
    global LAST_JSON
    store = build_store(b, codes)
    # staged compaction at trailing watermarks (the log mover's hourly
    # folds) — several session segments, so time pruning has granularity
    for q in (25, 50, 75):
        store.compact(int(np.percentile(b.timestamp, q)))
    store.compact()

    def store_count():
        return count_events_store(store, targets, A)

    us_store = timeit(store_count)
    assert store_count() == want  # pruned scan == raw re-sessionize

    def store_funnel():
        return funnel_reach_store(store, stages, A)

    us_storef = timeit(store_funnel)
    funnel_equal = store_funnel() == raw_funnel()
    assert funnel_equal

    # time-windowed count: pruning skips segments outside the window; the
    # raw equivalent re-sessionizes everything then filters the sessions
    # with the scan's own exact predicate.
    lo = int(np.percentile(b.timestamp, 40))
    hi = int(np.percentile(b.timestamp, 60))

    def windowed_count():
        return count_events(
            store.sequences(time_range=(lo, hi), events=list(targets)),
            targets, A)

    us_window = timeit(windowed_count)
    scan = store.scan(time_range=(lo, hi), events=list(targets))
    full = store.scan()
    keep = scan_matches_sessions(full.sequences, (lo, hi), None,
                                 np.asarray(targets))
    window_equal = (windowed_count()
                    == count_events(_take_rows(full.sequences, keep),
                                    targets, A))
    assert window_equal
    assert scan.stats.segments_decoded < full.stats.segments_decoded
    pruned_frac = 1 - scan.stats.segments_decoded / scan.stats.segments_total
    LAST_JSON = {"store_query": {
        "segments_total": scan.stats.segments_total,
        "segments_decoded": scan.stats.segments_decoded,
        "pruned_frac": pruned_frac,
        "us_store_count": us_store, "us_raw_count": us_raw,
        "us_windowed_count": us_window,
        "equal_raw": bool(store_count() == want and funnel_equal
                          and window_equal),
    }}

    return [
        row("count_raw_logs", us_raw, f"events={n_events}"),
        row("count_session_sequences", us_mat,
            f"speedup={us_raw / us_mat:.1f}x sum={want[0]} sessions={want[1]}"),
        row("count_store_scan", us_store,
            f"speedup={us_raw / us_store:.1f}x vs raw (code-pruned scan); "
            f"equal_raw=True"),
        row("count_store_window", us_window,
            f"speedup={us_raw / us_window:.1f}x vs full re-sessionize; "
            f"decoded {scan.stats.segments_decoded}/"
            f"{scan.stats.segments_total} segments "
            f"(pruned {pruned_frac:.0%})"),
        row("funnel_raw_logs", us_rawf, f"stages={len(stages)}"),
        row("funnel_session_sequences", us_matf,
            f"speedup={us_rawf / us_matf:.1f}x reach="
            + "/".join(str(c2) for _, c2 in mat_funnel())),
        row("funnel_store_scan", us_storef,
            f"speedup={us_rawf / us_storef:.1f}x vs raw "
            "(stage-0 pruned scan); equal_raw=True"),
        row("funnel_pallas_interpret", us_kf, "TPU-kernel path (interpret)"),
        row("histogram_pallas_interpret", us_kh, "TPU-kernel path (interpret)"),
    ]
