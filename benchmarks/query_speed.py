"""Paper claim (§4.2/§5): queries over pre-materialized session sequences
are substantially faster than over raw client-event logs, because the raw
path re-does the scan + group-by every time.

raw path      = sessionize(raw events) -> count/funnel   (the old Pig job)
mat. path     = count/funnel over the stored sequences   (session sequences)
kernel path   = same, through the Pallas kernels (interpret on CPU; the
                TPU-native formulation, included for completeness)
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import sessionize, SessionSequences
from repro.analytics import count_events, funnel_reach, build_stage_table
from repro.kernels.funnel_match.ops import deepest_stage
from repro.kernels.event_count.ops import histogram as k_histogram
from .common import corpus, timeit, row

FUNNEL_PATTERNS = ["*:signup:landing:form:signup_button:click",
                   "*:signup:form:form:submit_button:submit",
                   "*:signup:follow_suggestions:list:user:follow",
                   "*:signup:complete:page::impression"]


def run() -> list[str]:
    c = corpus()
    b, d, codes, seqs = c["batch"], c["dictionary"], c["codes"], c["seqs"]
    A = d.alphabet_size
    targets = d.codes_matching("*:impression")
    stages = [d.codes_matching(p) for p in FUNNEL_PATTERNS]
    stage_table = build_stage_table(stages, A)
    n_events = len(b)

    def raw_count():
        s = sessionize(b.user_id, b.session_id, b.timestamp, codes,
                       b.ip.astype(np.int64), max_sessions=n_events,
                       max_len=2048)
        sq = SessionSequences.from_sessionized(s)
        return count_events(sq, targets, A)

    def mat_count():
        return count_events(seqs, targets, A)

    us_raw = timeit(raw_count, repeats=3)
    us_mat = timeit(mat_count)
    want = mat_count()
    assert raw_count() == want  # same answer either way

    def raw_funnel():
        s = sessionize(b.user_id, b.session_id, b.timestamp, codes,
                       b.ip.astype(np.int64), max_sessions=n_events,
                       max_len=2048)
        sq = SessionSequences.from_sessionized(s)
        return funnel_reach(sq, stages, A)

    def mat_funnel():
        return funnel_reach(seqs, stages, A)

    us_rawf = timeit(raw_funnel, repeats=3)
    us_matf = timeit(mat_funnel)
    assert raw_funnel() == mat_funnel()

    sym = jnp.asarray(seqs.symbols)
    mask = jnp.asarray(seqs.mask())
    tbl = jnp.asarray(stage_table)
    us_kf = timeit(lambda: np.asarray(deepest_stage(sym, mask, tbl,
                                                    impl="interpret")))
    us_kh = timeit(lambda: np.asarray(k_histogram(sym, mask, A,
                                                  impl="interpret")))

    return [
        row("count_raw_logs", us_raw, f"events={n_events}"),
        row("count_session_sequences", us_mat,
            f"speedup={us_raw / us_mat:.1f}x sum={want[0]} sessions={want[1]}"),
        row("funnel_raw_logs", us_rawf, f"stages={len(stages)}"),
        row("funnel_session_sequences", us_matf,
            f"speedup={us_rawf / us_matf:.1f}x reach="
            + "/".join(str(c2) for _, c2 in mat_funnel())),
        row("funnel_pallas_interpret", us_kf, "TPU-kernel path (interpret)"),
        row("histogram_pallas_interpret", us_kh, "TPU-kernel path (interpret)"),
    ]
