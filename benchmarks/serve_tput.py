"""Serving throughput and latency: fixed single-batch vs continuous vs
paged, plus per-family continuous-batching rows.

The same request stream (3x slot-count requests, variable prompt lengths,
all queued at t=0) served two ways over the same smoke behaviour LM:

* ``serve_single_batch`` — the pre-PR recipe: group requests into fixed
  batches padded to the bucket length, decode each group to its full
  budget before the next group starts. Every request in a group pays the
  group's full wall time; later groups queue behind earlier ones.
* ``serve_continuous``   — the slot-table scheduler: admit/evict/backfill,
  per-row positions, eviction on EOS/budget frees the slot immediately.

Then the paged-KV comparison at **equal slab bytes**: a short-dominated
stream served by the dense slot table (every row pins a ``max_cache_len``
stripe) vs the paged scheduler (the same bytes as fixed blocks shared by
many more rows). The paged ``(block_size, num_blocks)`` carving is not
hardcoded: an **autotune sweep** replays the stream through every
equal-slab candidate carving, scores admitted peak (then decode steps,
then smaller blocks), and the winner — recorded with the full candidate
table under ``BENCH_serve.json["autotune"]`` — is what ``serve_paged``
and ``serve_fleet`` run with. ``serve_dense`` / ``serve_paged`` rows
report tokens/sec, slab bytes, and the number of concurrently admitted
requests; the paged row must admit >= 2x the dense row (asserted).

``serve_prefix`` then replays a session-shaped stream (80% common prefix)
through the same pool with ``prefix_cache`` off vs on: sharing must admit
>= 2x the non-sharing paged path at equal slab bytes, cut mean TTFT for
hit requests (only the divergent tail prefills), and stay bit-equal to
the cold-cache outputs (all asserted).

The **DecodeState family rows**: ``serve_ssm`` (recurrent rows)
and ``serve_encdec`` (cross-attention stacks with per-request frame
extras) drive the same scheduler machinery end to end — zero retraces
asserted — proving continuous batching is family-agnostic, not a dense
special case.

Finally ``serve_slo`` retires the t=0 closed-loop drain for the question
that actually matters under "heavy traffic": **tail latency under bursty
open-loop arrivals**. A seeded Poisson-burst stream (mixed short/long
prompts, two priority classes) is replayed on a virtual clock (one unit
per scheduler step — fully deterministic, no wall time) through the same
pool twice: honest worst-case reservation (``overcommit=1.0``) vs
optimistic admission (``overcommit=2.0``) with priority preemption. The
row gates on over-commit admission gain >= 1.3x at equal slab bytes,
high-priority p99 latency no worse than the reservation baseline, at
least one actual preemption (the recovery path really ran), outputs
bit-equal to the never-preempted baseline, and zero retraces after
warmup.

``serve_fleet`` scales the same open-loop harness out horizontally: a
``ReplicaRouter`` over 4 independent replicas (each the autotuned
serve_paged slab — equal per-replica bytes vs the single-replica
oracle) absorbs a burst stream that saturates one replica. Gates:
fleet admitted peak >= 3x the single replica, fleet p99 no worse,
outputs bit-equal to the oracle, zero retraces; then an 80%-common-
prefix session stream replayed under JSQ vs prefix-affinity routing
must show affinity beating JSQ's prefix hit rate (the point of
affinity: N-way routing must not dilute PR 6's session cache). With
``run.py --json`` everything lands machine-readably in
``BENCH_serve.json`` (family rows under ``families``, the SLO row under
``slo``, the fleet row under ``fleet``, the carving sweep under
``autotune``).

Rows report tokens/sec plus the p50/p99 per-request latency derived from
the arrival model (t=0 queue for the closed-loop rows, seeded bursts for
``serve_slo``).
"""
from __future__ import annotations

import time

import numpy as np

from .common import row, bursty_arrivals, VirtualClock

# populated by run(); written to JSON_PATH by `benchmarks.run --json`
JSON_PATH = "BENCH_serve.json"
LAST_JSON: dict | None = None


def _requests(n: int, bucket: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, 64, int(rng.integers(4, bucket))).astype(np.int32)
            for _ in range(n)]


def _pct(xs, q):
    ys = sorted(xs)
    return ys[min(len(ys) - 1, int(round(q / 100 * (len(ys) - 1))))]


def run() -> list[str]:
    import jax
    from repro.configs import smoke_config
    from repro.models.registry import get_model
    from repro.serve import (Server, ServeConfig, ContinuousScheduler,
                             SchedulerConfig, ServeMetrics)
    from repro.data.pipeline import PAD_ID

    batch, bucket, max_new, n_req = 4, 32, 8, 12
    cfg = smoke_config("behavior-lm-100m").with_(vocab_size=64,
                                                 max_cache_len=64)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    reqs = _requests(n_req, bucket)

    # -- single fixed batch: groups of `batch`, padded to `bucket` ---------
    srv = Server(api, params, ServeConfig(max_new_tokens=max_new))
    groups = [reqs[i:i + batch] for i in range(0, n_req, batch)]

    def one_pass(record=None):
        t_start = time.perf_counter()
        tokens = 0
        for g in groups:
            prompts = np.full((len(g), bucket), PAD_ID, np.int32)
            for j, r in enumerate(g):
                prompts[j, :len(r)] = r
            out = srv.generate_batch(prompts)          # the fixed recipe
            tokens += out.size
            if record is not None:
                record += [time.perf_counter() - t_start] * len(g)
        return tokens, time.perf_counter() - t_start

    one_pass()                                  # warmup (jit compile)
    lat_single: list[float] = []
    tok_single, wall_single = one_pass(lat_single)

    # -- continuous scheduler ---------------------------------------------
    sched = ContinuousScheduler(api, params, SchedulerConfig(
        batch=batch, buckets=(bucket,), max_new_tokens=max_new))
    for r in reqs:                              # warmup stream
        sched.submit(r)
    sched.run()
    warm_traces = dict(sched.trace_counts)
    metrics = ServeMetrics()                    # measure only the 2nd stream
    sched.metrics = metrics
    for r in reqs:
        sched.submit(r)
    sched.run()
    assert dict(sched.trace_counts) == warm_traces, "recompiled after warmup"
    summ = metrics.summary()
    lat_cont = [t.finish - t.submit for t in metrics.requests.values()
                if t.finish is not None and t.submit is not None]

    rows = [
        row("serve_single_batch", wall_single * 1e6,
            f"{tok_single / wall_single:.1f} tok/s "
            f"p50={_pct(lat_single, 50) * 1e3:.0f}ms "
            f"p99={_pct(lat_single, 99) * 1e3:.0f}ms "
            f"{n_req} reqs batch={batch}"),
        row("serve_continuous", (summ['tokens'] / summ['tokens_per_sec'])
            * 1e6 if summ['tokens_per_sec'] else 0.0,
            f"{summ['tokens_per_sec']:.1f} tok/s "
            f"p50={_pct(lat_cont, 50) * 1e3:.0f}ms "
            f"p99={_pct(lat_cont, 99) * 1e3:.0f}ms "
            f"{summ['requests']} reqs slots={batch} 0 retraces"),
    ]

    # -- paged vs dense at equal slab bytes --------------------------------
    # Dense: 4 slots x 64-position stripes. Paged: the same device bytes
    # carved into fixed blocks shared by a 16-row slot table — the
    # (block_size, num_blocks) carving itself comes from the autotune
    # sweep below, not a hardcoded 8/31. The stream is short-dominated
    # (prompt 4..8, budget 6), the shape the dense stripe wastes.
    # serve_prefix/serve_slo keep the fixed 8/31 carving: their gates pin
    # prefix/preemption *machinery* at a known shape, not the carving.
    block_size = 8
    dense_slots = batch
    max_blocks = cfg.max_cache_len // block_size
    pool_blocks = dense_slots * max_blocks - 1      # -1: the trash block
    paged_slots, budget, n_short = 16, 6, 32
    rng = np.random.default_rng(7)
    short = [rng.integers(4, 64, int(rng.integers(4, 9))).astype(np.int32)
             for _ in range(n_short)]

    def drain(sched, prompts=None):
        """Submit the whole stream at t=0, drain, return the peak number of
        concurrently admitted requests."""
        rids = [sched.submit(p, max_new_tokens=budget)
                for p in (short if prompts is None else prompts)]
        peak = 0
        while sched.num_active or sched.num_pending:
            sched.step()
            peak = max(peak, sched.num_active)
        outs = sched.run()
        return peak, [outs[r] for r in rids]

    # -- autotune: sweep the (block_size, num_blocks) carving --------------
    # Down-payment on the roadmap's paged-attention autotune: every
    # candidate carves the SAME slab bytes (dense_slots x max_cache_len
    # positions) into a different block size, replays the serve_paged
    # stream, and is scored on deterministic stream metrics — admitted
    # peak first (the capacity the slab converts into), then fewer decode
    # steps to drain, then smaller blocks (less tail padding per request).
    # The winner is what serve_paged and serve_fleet actually run with,
    # and the whole table lands in BENCH_serve.json["autotune"].
    def autotune_block_config(block_sizes=(4, 8, 16)):
        cands = []
        for bs in block_sizes:
            if cfg.max_cache_len % bs:
                continue
            nb = dense_slots * (cfg.max_cache_len // bs) - 1
            sched = ContinuousScheduler(api, params, SchedulerConfig(
                batch=paged_slots, buckets=(bucket,), max_new_tokens=budget,
                paged=True, block_size=bs, num_blocks=nb))
            peak, _ = drain(sched)
            cands.append(dict(
                block_size=bs, num_blocks=nb,
                slab_bytes=int(sched.pool.slab_bytes),
                admitted_peak=int(peak),
                decode_steps=int(sched.decode_steps)))
        assert len({c["slab_bytes"] for c in cands}) == 1, \
            "autotune candidates must carve equal slab bytes"
        best = max(cands, key=lambda c: (c["admitted_peak"],
                                         -c["decode_steps"],
                                         -c["block_size"]))
        return dict(model="behavior-lm-100m-smoke",
                    stream=dict(requests=n_short, prompt_len="4..8",
                                budget=budget, slots=paged_slots),
                    candidates=cands,
                    block_size=best["block_size"],
                    num_blocks=best["num_blocks"])

    autotune = autotune_block_config()
    at_bs, at_nb = autotune["block_size"], autotune["num_blocks"]

    dense_sched = ContinuousScheduler(api, params, SchedulerConfig(
        batch=dense_slots, buckets=(bucket,), max_new_tokens=budget))
    drain(dense_sched)                              # warmup
    dense_metrics = ServeMetrics()
    dense_sched.metrics = dense_metrics
    dense_peak, dense_outs = drain(dense_sched)

    paged_sched = ContinuousScheduler(api, params, SchedulerConfig(
        batch=paged_slots, buckets=(bucket,), max_new_tokens=budget,
        paged=True, block_size=at_bs, num_blocks=at_nb))
    drain(paged_sched)                              # warmup
    warm_paged = dict(paged_sched.trace_counts)
    paged_metrics = ServeMetrics()
    paged_sched.metrics = paged_metrics
    paged_peak, paged_outs = drain(paged_sched)
    assert dict(paged_sched.trace_counts) == warm_paged, \
        "paged scheduler recompiled after warmup"

    for a, b in zip(dense_outs, paged_outs):        # same stream, same toks
        np.testing.assert_array_equal(a, b)

    kv_bytes = paged_sched.pool.block_bytes // at_bs        # per position
    dense_bytes = dense_slots * cfg.max_cache_len * kv_bytes
    paged_bytes = paged_sched.pool.slab_bytes
    assert paged_bytes == dense_bytes, (paged_bytes, dense_bytes)
    assert paged_peak >= 2 * dense_peak, \
        f"paged admitted {paged_peak} < 2x dense {dense_peak}"

    ds, ps = dense_metrics.summary(), paged_metrics.summary()
    rows += [
        row("serve_dense", (ds['tokens'] / ds['tokens_per_sec']) * 1e6
            if ds['tokens_per_sec'] else 0.0,
            f"{ds['tokens_per_sec']:.1f} tok/s slab={dense_bytes}B "
            f"admitted={dense_peak} slots={dense_slots} "
            f"util={ds['kv_util_peak']:.0%}"),
        row("serve_paged", (ps['tokens'] / ps['tokens_per_sec']) * 1e6
            if ps['tokens_per_sec'] else 0.0,
            f"{ps['tokens_per_sec']:.1f} tok/s slab={paged_bytes}B "
            f"admitted={paged_peak} blocks={at_nb}x{at_bs} "
            f"util={ps['kv_util_peak']:.0%} 0 retraces"),
    ]

    # -- session-prefix caching at equal slab bytes ------------------------
    # The session-shaped stream the paper's unit of analysis implies: every
    # request re-submits the same 24-token session prefix plus a 6-token
    # divergent tail (80% common). Same pool as serve_paged (31 x 8-token
    # blocks); each request worst-cases 5 blocks, so the non-sharing pool
    # admits 6 concurrently — sharing maps the 3 resident prefix blocks
    # copy-free and reserves only the 2 owned blocks per request.
    prefix_rng = np.random.default_rng(11)
    common24 = prefix_rng.integers(4, 64, 24).astype(np.int32)
    sess = [np.concatenate([common24,
                            prefix_rng.integers(4, 64, 6).astype(np.int32)])
            for _ in range(n_short)]

    def prefix_sched(share):
        return ContinuousScheduler(api, params, SchedulerConfig(
            batch=paged_slots, buckets=(8, 32), max_new_tokens=budget,
            paged=True, block_size=block_size, num_blocks=pool_blocks,
            prefix_cache=share))

    nosh_sched = prefix_sched(False)
    drain(nosh_sched, sess)                         # warmup
    nosh_metrics = ServeMetrics()
    nosh_sched.metrics = nosh_metrics
    nosh_peak, nosh_outs = drain(nosh_sched, sess)

    pref_sched = prefix_sched(True)
    drain(pref_sched, sess)                         # warmup: miss + hit paths
    warm_pref = dict(pref_sched.trace_counts)
    pref_metrics = ServeMetrics()
    pref_sched.metrics = pref_metrics
    pref_peak, pref_outs = drain(pref_sched, sess)
    assert dict(pref_sched.trace_counts) == warm_pref, \
        "prefix scheduler recompiled after warmup"
    pref_sched.pool.check_invariants()

    bit_equal = all(np.array_equal(a, b)
                    for a, b in zip(nosh_outs, pref_outs))
    assert bit_equal, "prefix-sharing outputs diverge from cold cache"
    assert pref_sched.pool.slab_bytes == nosh_sched.pool.slab_bytes
    assert pref_peak >= 2 * nosh_peak, \
        f"prefix sharing admitted {pref_peak} < 2x non-sharing {nosh_peak}"

    ns, xs = nosh_metrics.summary(), pref_metrics.summary()
    assert xs["prefix_hit_rate"] > 0.5 and xs["prefill_tokens_skipped"] > 0
    assert xs["mean_ttft_hit_s"] < xs["mean_ttft_miss_s"], \
        (xs["mean_ttft_hit_s"], xs["mean_ttft_miss_s"])
    rows.append(row(
        "serve_prefix", (xs['tokens'] / xs['tokens_per_sec']) * 1e6
        if xs['tokens_per_sec'] else 0.0,
        f"{xs['tokens_per_sec']:.1f} tok/s "
        f"admitted={pref_peak} vs {nosh_peak} cold "
        f"hit={xs['prefix_hit_rate']:.0%} "
        f"skipped={xs['prefill_tokens_skipped']}tok "
        f"ttft hit/miss={xs['mean_ttft_hit_s'] * 1e3:.1f}/"
        f"{xs['mean_ttft_miss_s'] * 1e3:.1f}ms 0 retraces"))

    # -- DecodeState family rows: the same scheduler over non-dense state -
    def family_stream(arch, seed):
        fcfg = smoke_config(arch).with_(vocab_size=64, max_cache_len=64)
        fapi = get_model(fcfg)
        fparams = fapi.init(jax.random.PRNGKey(0))
        frng = np.random.default_rng(seed)

        def extra():
            if fcfg.family == "encdec":
                return dict(frames=frng.standard_normal(
                    (fcfg.n_frames, fcfg.d_model)).astype(np.float32))
            if fcfg.family == "vlm":
                return dict(patches=frng.standard_normal(
                    (fcfg.n_patches, fcfg.vision_dim)).astype(np.float32))
            return None

        fsched = ContinuousScheduler(fapi, fparams, SchedulerConfig(
            batch=batch, buckets=(bucket,), max_new_tokens=max_new))
        freqs = _requests(n_req, bucket, seed=seed)
        for r in freqs:                              # warmup stream
            fsched.submit(r, extra=extra())
        fsched.run()
        warm = dict(fsched.trace_counts)
        fmetrics = ServeMetrics()
        fsched.metrics = fmetrics
        for r in freqs:
            fsched.submit(r, extra=extra())
        fsched.run()
        assert dict(fsched.trace_counts) == warm, \
            f"{arch} scheduler recompiled after warmup"
        fs = fmetrics.summary()
        flat = [t.finish - t.submit for t in fmetrics.requests.values()
                if t.finish is not None and t.submit is not None]
        return fs, flat

    families_json = {}
    for name, arch in (("serve_ssm", "mamba2-370m"),
                       ("serve_encdec", "whisper-tiny")):
        fs, flat = family_stream(arch, seed=3)
        rows.append(row(
            name, (fs['tokens'] / fs['tokens_per_sec']) * 1e6
            if fs['tokens_per_sec'] else 0.0,
            f"{fs['tokens_per_sec']:.1f} tok/s "
            f"p50={_pct(flat, 50) * 1e3:.0f}ms "
            f"p99={_pct(flat, 99) * 1e3:.0f}ms "
            f"{fs['requests']} reqs slots={batch} 0 retraces"))
        families_json[name] = dict(
            arch=arch, requests=fs["requests"], tokens=fs["tokens"],
            tokens_per_sec=fs["tokens_per_sec"],
            p50_latency_s=fs["p50_latency_s"],
            p99_latency_s=fs["p99_latency_s"],
            peak_resident_bytes=fs["kv_peak_resident_bytes"])

    # -- SLO under bursty open-loop load: over-commit vs honest reservation
    # Same slab as serve_paged (31 x 8-token blocks == 4 dense stripes),
    # bigger slot table so admission is gated by blocks, not rows. The
    # stream mixes short (2-block worst case) and long (3-4 block) requests
    # in Poisson bursts; ~25% ride the high-priority class.
    slo_slots, n_slo = 24, 40
    arrivals = bursty_arrivals(n_slo, mean_gap=6.0, burst_mean=8.0, seed=17)
    srng = np.random.default_rng(17)
    slo_stream = []
    for t in arrivals:
        # every prompt is one block; budgets split the worst case 2 vs 4
        # blocks — exactly the shape where the honest reservation wastes
        # the most (requests hold 1 block at admission, grow lazily, and
        # often hit EOS before their worst case)
        if srng.random() < 0.4:          # long budget: 4-block worst case
            p, b = srng.integers(4, 64, int(srng.integers(4, 9))), 20
        else:                            # short budget: 2-block worst case
            p, b = srng.integers(4, 64, int(srng.integers(4, 9))), 6
        prio = 1 if srng.random() < 0.25 else 0
        slo_stream.append((float(t), p.astype(np.int32), int(b), prio))

    def open_loop(sched, clock, stream):
        """Open-loop drive: requests appear at their seeded arrival times
        (submit stamped at the true arrival), the clock advances one unit
        per scheduler step, idle gaps fast-forward. Works identically for
        a single ``ContinuousScheduler`` and a ``ReplicaRouter`` — both
        speak submit/step/run and num_active/num_pending. Returns (peak
        concurrently admitted, {rid: outputs})."""
        i, peak = 0, 0
        while i < len(stream) or sched.num_active or sched.num_pending:
            if not (sched.num_active or sched.num_pending):
                clock.now = max(clock.now, stream[i][0])
            now = clock.now
            while i < len(stream) and stream[i][0] <= now:
                t, p, b, prio = stream[i]
                clock.now = t
                sched.submit(p, max_new_tokens=b, priority=prio)
                i += 1
            clock.now = now
            sched.step()
            peak = max(peak, sched.num_active)
            clock.advance(1.0)
        return peak, sched.run()

    def slo_run(factor):
        clock = VirtualClock()
        sched = ContinuousScheduler(api, params, SchedulerConfig(
            batch=slo_slots, buckets=(8, 16, 32), max_new_tokens=20,
            paged=True, block_size=block_size, num_blocks=pool_blocks,
            overcommit=factor, debug=True))
        open_loop(sched, clock, slo_stream)          # warmup (jit traces)
        warm = dict(sched.trace_counts)
        clock.now = 0.0
        sched.metrics = ServeMetrics(clock=clock)
        peak, outs = open_loop(sched, clock, slo_stream)
        assert dict(sched.trace_counts) == warm, \
            f"slo scheduler (overcommit={factor}) recompiled after warmup"
        sched.pool.check_invariants()
        return peak, outs, sched.metrics.summary(), sched

    base_peak, base_outs, bsum, base_sched = slo_run(1.0)
    oc_peak, oc_outs, osum, oc_sched = slo_run(2.0)

    assert oc_sched.pool.slab_bytes == base_sched.pool.slab_bytes
    assert bsum["preemptions"] == 0, "honest reservation preempted"
    slo_bit_equal = all(
        np.array_equal(base_outs[a], oc_outs[b])
        for a, b in zip(sorted(base_outs), sorted(oc_outs)))
    assert slo_bit_equal, \
        "preempted outputs diverge from the never-preempted baseline"
    slo_gain = oc_peak / max(base_peak, 1)
    assert slo_gain >= 1.3, \
        f"over-commit admitted {oc_peak} < 1.3x baseline {base_peak}"
    assert osum["preemptions"] >= 1, \
        "over-commit stream never exercised the preemption path"
    hi_base = bsum["per_priority"][1]["p99_latency_s"]
    hi_oc = osum["per_priority"][1]["p99_latency_s"]
    assert hi_oc <= hi_base, \
        f"hi-pri p99 regressed under over-commit: {hi_oc} > {hi_base}"
    rows.append(row(
        "serve_slo", osum["p99_latency_s"],
        f"admitted={oc_peak} vs {base_peak} honest "
        f"(gain {slo_gain:.2f}x) preempts={osum['preemptions']} "
        f"hi-p99={hi_oc:.0f} vs {hi_base:.0f} steps "
        f"qwait-p99={osum['p99_queue_wait_s']:.0f} steps "
        f"bit_equal={slo_bit_equal} 0 retraces"))

    def _slo_side(peak, s):
        return dict(
            admitted_peak=int(peak), preemptions=int(s["preemptions"]),
            p50_latency_steps=s["p50_latency_s"],
            p99_latency_steps=s["p99_latency_s"],
            p99_queue_wait_steps=s["p99_queue_wait_s"],
            p99_ttft_steps=s["p99_ttft_s"],
            per_priority={
                str(k): dict(requests=v["requests"],
                             preemptions=v["preemptions"],
                             p99_latency_steps=v["p99_latency_s"],
                             p99_queue_wait_steps=v["p99_queue_wait_s"])
                for k, v in s["per_priority"].items()})

    slo_json = dict(
        stream=dict(requests=n_slo, mean_gap=6.0, burst_mean=8.0, seed=17,
                    slots=slo_slots, num_blocks=pool_blocks,
                    block_size=block_size, overcommit=2.0),
        baseline=_slo_side(base_peak, bsum),
        overcommit=_slo_side(oc_peak, osum),
        admission_gain=slo_gain,
        hi_pri_p99_baseline_steps=hi_base,
        hi_pri_p99_overcommit_steps=hi_oc,
        preemptions=int(osum["preemptions"]),
        bit_equal=bool(slo_bit_equal),
    )

    # -- replica fleet: JSQ scaling + prefix-affinity routing --------------
    # One router over 4 independent replicas, each carved exactly like the
    # autotuned serve_paged slab — equal per-replica bytes vs the single-
    # replica oracle, so the scaling claim is about routing, not capacity.
    from repro.serve import ReplicaRouter, FleetConfig

    fleet_n, fleet_slots = 4, 16
    fleet_cfg = SchedulerConfig(
        batch=fleet_slots, buckets=(8, 32), max_new_tokens=budget,
        paged=True, block_size=at_bs, num_blocks=at_nb,
        prefix_cache=True, debug=True)

    # scaling stream: dense bursts of short prompts — arrivals outrun one
    # replica's admission capacity so the backlog is deep enough to fill
    # four replicas' worth of slots
    n_fleet = 96
    flrng = np.random.default_rng(23)
    scale_stream = [
        (float(t),
         flrng.integers(4, 64, int(flrng.integers(4, 9))).astype(np.int32),
         budget, 0)
        for t in bursty_arrivals(n_fleet, mean_gap=1.0, burst_mean=16.0,
                                 seed=23)]

    # session stream: 8 sessions x 24-token prefix + 6-token tails (80%
    # common), bursts close enough together that a session's blocks are
    # still refcount-resident when its next request lands
    n_aff = 40
    arng = np.random.default_rng(29)
    sess_prefix = [arng.integers(4, 64, 24).astype(np.int32)
                   for _ in range(8)]
    aff_stream = []
    for t in bursty_arrivals(n_aff, mean_gap=4.0, burst_mean=8.0, seed=29):
        s = int(arng.integers(0, len(sess_prefix)))
        tail = arng.integers(4, 64, 6).astype(np.int32)
        aff_stream.append(
            (float(t), np.concatenate([sess_prefix[s], tail]), budget, 0))

    def fleet_measure(target, stream):
        """Warmup pass (jit traces; metrics discarded) then a measured
        replay of the same open-loop stream on a fresh virtual clock.
        Returns (peak admitted, outputs in submit order, summary)."""
        open_loop(target, VirtualClock(), stream)            # warmup
        clock = VirtualClock()
        if isinstance(target, ReplicaRouter):
            warm = [dict(r.trace_counts) for r in target.replicas]
            target.reset_metrics(clock)
            peak, outs = open_loop(target, clock, stream)
            assert [dict(r.trace_counts) for r in target.replicas] == warm, \
                "fleet replica recompiled after warmup"
            summ = target.summary()
            for r in target.replicas:
                r.pool.check_invariants()
        else:
            warm = dict(target.trace_counts)
            target.metrics = ServeMetrics(clock=clock)
            peak, outs = open_loop(target, clock, stream)
            assert dict(target.trace_counts) == warm, \
                "single-replica oracle recompiled after warmup"
            summ = target.metrics.summary()
            target.pool.check_invariants()
        # rids are assigned monotonically in submit order on both the
        # single scheduler and the router's global namespace
        return peak, [outs[k] for k in sorted(outs)], summ

    single = ContinuousScheduler(api, params, fleet_cfg)
    jsq_fleet = ReplicaRouter(api, params, fleet_cfg,
                              FleetConfig(replicas=fleet_n, route="jsq"))
    aff_fleet = ReplicaRouter(
        api, params, fleet_cfg,
        FleetConfig(replicas=fleet_n, route="affinity"))
    assert jsq_fleet.replicas[0].pool.slab_bytes == single.pool.slab_bytes

    s_peak, s_outs, s_sum = fleet_measure(single, scale_stream)
    f_peak, f_outs, f_sum = fleet_measure(jsq_fleet, scale_stream)
    fleet_scaling = f_peak / max(s_peak, 1)
    fleet_bit_equal = (len(s_outs) == len(f_outs) and all(
        np.array_equal(a, b) for a, b in zip(s_outs, f_outs)))
    assert fleet_bit_equal, \
        "fleet outputs diverge from the single-replica oracle"
    assert fleet_scaling >= 3.0, \
        f"fleet admitted {f_peak} < 3x single-replica {s_peak}"
    assert f_sum["p99_latency_s"] <= s_sum["p99_latency_s"], \
        (f"fleet p99 {f_sum['p99_latency_s']} worse than single-replica "
         f"{s_sum['p99_latency_s']}")

    # affinity vs JSQ on the session stream (single run = output oracle)
    _, so_outs, _ = fleet_measure(single, aff_stream)
    jq_peak, jq_outs, jq_sum = fleet_measure(jsq_fleet, aff_stream)
    af_peak, af_outs, af_sum = fleet_measure(aff_fleet, aff_stream)
    aff_bit_equal = all(
        np.array_equal(a, b) for a, b in zip(so_outs, jq_outs)) and all(
        np.array_equal(a, b) for a, b in zip(so_outs, af_outs))
    assert aff_bit_equal, "routing policy changed decoded outputs"
    assert af_sum["prefix_hit_rate"] > jq_sum["prefix_hit_rate"], \
        (f"affinity hit rate {af_sum['prefix_hit_rate']:.2f} <= JSQ "
         f"{jq_sum['prefix_hit_rate']:.2f}")

    rows.append(row(
        "serve_fleet", f_sum["p99_latency_s"],
        f"replicas={fleet_n} admitted={f_peak} vs {s_peak} single "
        f"(x{fleet_scaling:.1f}) "
        f"p99={f_sum['p99_latency_s']:.0f} vs "
        f"{s_sum['p99_latency_s']:.0f} steps "
        f"imb={f_sum['fleet']['load_imbalance']:.2f} "
        f"aff-hit={af_sum['prefix_hit_rate']:.0%} vs "
        f"jsq={jq_sum['prefix_hit_rate']:.0%} "
        f"bit_equal={bool(fleet_bit_equal and aff_bit_equal)} 0 retraces"))

    fleet_json = dict(
        replicas=fleet_n, slots_per_replica=fleet_slots,
        block_size=at_bs, num_blocks=at_nb,
        slab_bytes_per_replica=int(single.pool.slab_bytes),
        scale_stream=dict(requests=n_fleet, mean_gap=1.0, burst_mean=16.0,
                          seed=23, prompt_len="4..8", budget=budget),
        single=dict(admitted_peak=int(s_peak),
                    p99_latency_steps=s_sum["p99_latency_s"],
                    p50_latency_steps=s_sum["p50_latency_s"],
                    tokens_per_sec=s_sum["tokens_per_sec"]),
        jsq=dict(admitted_peak=int(f_peak),
                 p99_latency_steps=f_sum["p99_latency_s"],
                 p50_latency_steps=f_sum["p50_latency_s"],
                 tokens_per_sec=f_sum["tokens_per_sec"],
                 load_imbalance=f_sum["fleet"]["load_imbalance"],
                 routed_per_replica=f_sum["fleet"]["routed_per_replica"],
                 gossip_ticks=f_sum["fleet"]["gossip_ticks"]),
        scaling=float(fleet_scaling),
        bit_equal=bool(fleet_bit_equal and aff_bit_equal),
        affinity_stream=dict(requests=n_aff, sessions=len(sess_prefix),
                             prefix_len=24, tail_len=6, mean_gap=4.0,
                             burst_mean=8.0, seed=29),
        jsq_prefix_hit_rate=jq_sum["prefix_hit_rate"],
        affinity_hit_rate=af_sum["prefix_hit_rate"],
        affinity=dict(
            admitted_peak=int(af_peak),
            load_imbalance=af_sum["fleet"]["load_imbalance"],
            routed_per_replica=af_sum["fleet"]["routed_per_replica"],
            prefix_blocks_reused=int(af_sum["prefix_blocks_reused"]),
            prefill_tokens_skipped=int(af_sum["prefill_tokens_skipped"])),
    )

    global LAST_JSON
    LAST_JSON = dict(
        autotune=autotune,
        fleet=fleet_json,
        slo=slo_json,
        families=families_json,
        stream=dict(requests=n_short, prompt_len="4..8", budget=budget,
                    model="behavior-lm-100m-smoke",
                    max_cache_len=cfg.max_cache_len),
        dense=dict(slab_bytes=int(dense_bytes), slots=dense_slots,
                   admitted_peak=int(dense_peak),
                   tokens_per_sec=ds["tokens_per_sec"],
                   p50_latency_s=ds["p50_latency_s"],
                   p99_latency_s=ds["p99_latency_s"],
                   kv_util_peak=ds["kv_util_peak"],
                   kv_peak_resident_bytes=ds["kv_peak_resident_bytes"]),
        paged=dict(slab_bytes=int(paged_bytes), slots=paged_slots,
                   num_blocks=at_nb, block_size=at_bs,
                   admitted_peak=int(paged_peak),
                   tokens_per_sec=ps["tokens_per_sec"],
                   p50_latency_s=ps["p50_latency_s"],
                   p99_latency_s=ps["p99_latency_s"],
                   kv_util_peak=ps["kv_util_peak"],
                   kv_peak_resident_bytes=ps["kv_peak_resident_bytes"]),
        admission_gain=paged_peak / max(dense_peak, 1),
        prefix=dict(
            stream=dict(requests=n_short, prompt_len=30, common_prefix=24,
                        budget=budget, num_blocks=pool_blocks,
                        block_size=block_size),
            off=dict(admitted_peak=int(nosh_peak),
                     tokens_per_sec=ns["tokens_per_sec"],
                     p50_ttft_s=ns["p50_ttft_s"],
                     kv_referenced_peak=ns["kv_referenced_peak"]),
            on=dict(admitted_peak=int(pref_peak),
                    tokens_per_sec=xs["tokens_per_sec"],
                    p50_ttft_s=xs["p50_ttft_s"],
                    kv_referenced_peak=xs["kv_referenced_peak"],
                    kv_live_blocks_peak=xs["kv_live_blocks_peak"]),
            admission_gain=pref_peak / max(nosh_peak, 1),
            prefix_hit_rate=xs["prefix_hit_rate"],
            prefill_tokens_skipped=int(xs["prefill_tokens_skipped"]),
            prefix_blocks_reused=int(xs["prefix_blocks_reused"]),
            mean_ttft_hit_s=xs["mean_ttft_hit_s"],
            mean_ttft_miss_s=xs["mean_ttft_miss_s"],
            bit_equal=bool(bit_equal),
        ),
    )
    return rows
