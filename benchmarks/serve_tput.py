"""Serving throughput and latency: fixed single-batch vs continuous batching.

The same request stream (3x slot-count requests, variable prompt lengths,
all queued at t=0) served two ways over the same smoke behaviour LM:

* ``serve_single_batch`` — the pre-PR recipe: group requests into fixed
  batches padded to the bucket length, decode each group to its full
  budget before the next group starts. Every request in a group pays the
  group's full wall time; later groups queue behind earlier ones.
* ``serve_continuous``   — the slot-table scheduler: admit/evict/backfill,
  per-row positions, eviction on EOS/budget frees the slot immediately.

Rows report tokens/sec plus the p50/p99 per-request latency derived from
the t=0 queue-arrival model.
"""
from __future__ import annotations

import time

import numpy as np

from .common import row


def _requests(n: int, bucket: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, 64, int(rng.integers(4, bucket))).astype(np.int32)
            for _ in range(n)]


def _pct(xs, q):
    ys = sorted(xs)
    return ys[min(len(ys) - 1, int(round(q / 100 * (len(ys) - 1))))]


def run() -> list[str]:
    import jax
    from repro.configs import smoke_config
    from repro.models.registry import get_model
    from repro.serve import (Server, ServeConfig, ContinuousScheduler,
                             SchedulerConfig, ServeMetrics)
    from repro.data.pipeline import PAD_ID

    batch, bucket, max_new, n_req = 4, 32, 8, 12
    cfg = smoke_config("behavior-lm-100m").with_(vocab_size=64,
                                                 max_cache_len=64)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    reqs = _requests(n_req, bucket)

    # -- single fixed batch: groups of `batch`, padded to `bucket` ---------
    srv = Server(api, params, ServeConfig(max_new_tokens=max_new))
    groups = [reqs[i:i + batch] for i in range(0, n_req, batch)]

    def one_pass(record=None):
        t_start = time.perf_counter()
        tokens = 0
        for g in groups:
            prompts = np.full((len(g), bucket), PAD_ID, np.int32)
            for j, r in enumerate(g):
                prompts[j, :len(r)] = r
            out = srv._generate_batch(prompts, None)   # the fixed recipe
            tokens += out.size
            if record is not None:
                record += [time.perf_counter() - t_start] * len(g)
        return tokens, time.perf_counter() - t_start

    one_pass()                                  # warmup (jit compile)
    lat_single: list[float] = []
    tok_single, wall_single = one_pass(lat_single)

    # -- continuous scheduler ---------------------------------------------
    sched = ContinuousScheduler(api, params, SchedulerConfig(
        batch=batch, buckets=(bucket,), max_new_tokens=max_new))
    for r in reqs:                              # warmup stream
        sched.submit(r)
    sched.run()
    warm_traces = dict(sched.trace_counts)
    metrics = ServeMetrics()                    # measure only the 2nd stream
    sched.metrics = metrics
    for r in reqs:
        sched.submit(r)
    sched.run()
    assert dict(sched.trace_counts) == warm_traces, "recompiled after warmup"
    summ = metrics.summary()
    lat_cont = [t.finish - t.submit for t in metrics.requests.values()
                if t.finish is not None and t.submit is not None]

    return [
        row("serve_single_batch", wall_single * 1e6,
            f"{tok_single / wall_single:.1f} tok/s "
            f"p50={_pct(lat_single, 50) * 1e3:.0f}ms "
            f"p99={_pct(lat_single, 99) * 1e3:.0f}ms "
            f"{n_req} reqs batch={batch}"),
        row("serve_continuous", (summ['tokens'] / summ['tokens_per_sec'])
            * 1e6 if summ['tokens_per_sec'] else 0.0,
            f"{summ['tokens_per_sec']:.1f} tok/s "
            f"p50={_pct(lat_cont, 50) * 1e3:.0f}ms "
            f"p99={_pct(lat_cont, 99) * 1e3:.0f}ms "
            f"{summ['requests']} reqs slots={batch} 0 retraces"),
    ]
