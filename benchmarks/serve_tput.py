"""Serving throughput and latency: fixed single-batch vs continuous vs
paged, plus per-family continuous-batching rows.

The same request stream (3x slot-count requests, variable prompt lengths,
all queued at t=0) served two ways over the same smoke behaviour LM:

* ``serve_single_batch`` — the pre-PR recipe: group requests into fixed
  batches padded to the bucket length, decode each group to its full
  budget before the next group starts. Every request in a group pays the
  group's full wall time; later groups queue behind earlier ones.
* ``serve_continuous``   — the slot-table scheduler: admit/evict/backfill,
  per-row positions, eviction on EOS/budget frees the slot immediately.

Then the paged-KV comparison at **equal slab bytes**: a short-dominated
stream served by the dense slot table (every row pins a ``max_cache_len``
stripe) vs the paged scheduler (the same bytes as fixed blocks shared by
many more rows). ``serve_dense`` / ``serve_paged`` rows report tokens/sec,
slab bytes, and the number of concurrently admitted requests; the paged
row must admit >= 2x the dense row (asserted).

``serve_prefix`` then replays a session-shaped stream (80% common prefix)
through the same pool with ``prefix_cache`` off vs on: sharing must admit
>= 2x the non-sharing paged path at equal slab bytes, cut mean TTFT for
hit requests (only the divergent tail prefills), and stay bit-equal to
the cold-cache outputs (all asserted).

The **DecodeState family rows**: ``serve_ssm`` (recurrent rows)
and ``serve_encdec`` (cross-attention stacks with per-request frame
extras) drive the same scheduler machinery end to end — zero retraces
asserted — proving continuous batching is family-agnostic, not a dense
special case.

Finally ``serve_slo`` retires the t=0 closed-loop drain for the question
that actually matters under "heavy traffic": **tail latency under bursty
open-loop arrivals**. A seeded Poisson-burst stream (mixed short/long
prompts, two priority classes) is replayed on a virtual clock (one unit
per scheduler step — fully deterministic, no wall time) through the same
pool twice: honest worst-case reservation (``overcommit=1.0``) vs
optimistic admission (``overcommit=2.0``) with priority preemption. The
row gates on over-commit admission gain >= 1.3x at equal slab bytes,
high-priority p99 latency no worse than the reservation baseline, at
least one actual preemption (the recovery path really ran), outputs
bit-equal to the never-preempted baseline, and zero retraces after
warmup. With ``run.py --json`` everything lands machine-readably in
``BENCH_serve.json`` (family rows under ``families``, the SLO row under
``slo``).

Rows report tokens/sec plus the p50/p99 per-request latency derived from
the arrival model (t=0 queue for the closed-loop rows, seeded bursts for
``serve_slo``).
"""
from __future__ import annotations

import time

import numpy as np

from .common import row, bursty_arrivals, VirtualClock

# populated by run(); written to JSON_PATH by `benchmarks.run --json`
JSON_PATH = "BENCH_serve.json"
LAST_JSON: dict | None = None


def _requests(n: int, bucket: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.integers(4, 64, int(rng.integers(4, bucket))).astype(np.int32)
            for _ in range(n)]


def _pct(xs, q):
    ys = sorted(xs)
    return ys[min(len(ys) - 1, int(round(q / 100 * (len(ys) - 1))))]


def run() -> list[str]:
    import jax
    from repro.configs import smoke_config
    from repro.models.registry import get_model
    from repro.serve import (Server, ServeConfig, ContinuousScheduler,
                             SchedulerConfig, ServeMetrics)
    from repro.data.pipeline import PAD_ID

    batch, bucket, max_new, n_req = 4, 32, 8, 12
    cfg = smoke_config("behavior-lm-100m").with_(vocab_size=64,
                                                 max_cache_len=64)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    reqs = _requests(n_req, bucket)

    # -- single fixed batch: groups of `batch`, padded to `bucket` ---------
    srv = Server(api, params, ServeConfig(max_new_tokens=max_new))
    groups = [reqs[i:i + batch] for i in range(0, n_req, batch)]

    def one_pass(record=None):
        t_start = time.perf_counter()
        tokens = 0
        for g in groups:
            prompts = np.full((len(g), bucket), PAD_ID, np.int32)
            for j, r in enumerate(g):
                prompts[j, :len(r)] = r
            out = srv.generate_batch(prompts)          # the fixed recipe
            tokens += out.size
            if record is not None:
                record += [time.perf_counter() - t_start] * len(g)
        return tokens, time.perf_counter() - t_start

    one_pass()                                  # warmup (jit compile)
    lat_single: list[float] = []
    tok_single, wall_single = one_pass(lat_single)

    # -- continuous scheduler ---------------------------------------------
    sched = ContinuousScheduler(api, params, SchedulerConfig(
        batch=batch, buckets=(bucket,), max_new_tokens=max_new))
    for r in reqs:                              # warmup stream
        sched.submit(r)
    sched.run()
    warm_traces = dict(sched.trace_counts)
    metrics = ServeMetrics()                    # measure only the 2nd stream
    sched.metrics = metrics
    for r in reqs:
        sched.submit(r)
    sched.run()
    assert dict(sched.trace_counts) == warm_traces, "recompiled after warmup"
    summ = metrics.summary()
    lat_cont = [t.finish - t.submit for t in metrics.requests.values()
                if t.finish is not None and t.submit is not None]

    rows = [
        row("serve_single_batch", wall_single * 1e6,
            f"{tok_single / wall_single:.1f} tok/s "
            f"p50={_pct(lat_single, 50) * 1e3:.0f}ms "
            f"p99={_pct(lat_single, 99) * 1e3:.0f}ms "
            f"{n_req} reqs batch={batch}"),
        row("serve_continuous", (summ['tokens'] / summ['tokens_per_sec'])
            * 1e6 if summ['tokens_per_sec'] else 0.0,
            f"{summ['tokens_per_sec']:.1f} tok/s "
            f"p50={_pct(lat_cont, 50) * 1e3:.0f}ms "
            f"p99={_pct(lat_cont, 99) * 1e3:.0f}ms "
            f"{summ['requests']} reqs slots={batch} 0 retraces"),
    ]

    # -- paged vs dense at equal slab bytes --------------------------------
    # Dense: 4 slots x 64-position stripes. Paged: the same device bytes as
    # 31 allocatable blocks of 8 tokens (+ the trash block) shared by a
    # 16-row slot table. The stream is short-dominated (prompt 4..8,
    # budget 6 -> 2 blocks/request), the shape the dense stripe wastes.
    block_size = 8
    dense_slots = batch
    max_blocks = cfg.max_cache_len // block_size
    pool_blocks = dense_slots * max_blocks - 1      # -1: the trash block
    paged_slots, budget, n_short = 16, 6, 32
    rng = np.random.default_rng(7)
    short = [rng.integers(4, 64, int(rng.integers(4, 9))).astype(np.int32)
             for _ in range(n_short)]

    def drain(sched, prompts=None):
        """Submit the whole stream at t=0, drain, return the peak number of
        concurrently admitted requests."""
        rids = [sched.submit(p, max_new_tokens=budget)
                for p in (short if prompts is None else prompts)]
        peak = 0
        while sched.num_active or sched.num_pending:
            sched.step()
            peak = max(peak, sched.num_active)
        outs = sched.run()
        return peak, [outs[r] for r in rids]

    dense_sched = ContinuousScheduler(api, params, SchedulerConfig(
        batch=dense_slots, buckets=(bucket,), max_new_tokens=budget))
    drain(dense_sched)                              # warmup
    dense_metrics = ServeMetrics()
    dense_sched.metrics = dense_metrics
    dense_peak, dense_outs = drain(dense_sched)

    paged_sched = ContinuousScheduler(api, params, SchedulerConfig(
        batch=paged_slots, buckets=(bucket,), max_new_tokens=budget,
        paged=True, block_size=block_size, num_blocks=pool_blocks))
    drain(paged_sched)                              # warmup
    warm_paged = dict(paged_sched.trace_counts)
    paged_metrics = ServeMetrics()
    paged_sched.metrics = paged_metrics
    paged_peak, paged_outs = drain(paged_sched)
    assert dict(paged_sched.trace_counts) == warm_paged, \
        "paged scheduler recompiled after warmup"

    for a, b in zip(dense_outs, paged_outs):        # same stream, same toks
        np.testing.assert_array_equal(a, b)

    kv_bytes = paged_sched.pool.block_bytes // block_size   # per position
    dense_bytes = dense_slots * cfg.max_cache_len * kv_bytes
    paged_bytes = paged_sched.pool.slab_bytes
    assert paged_bytes == dense_bytes, (paged_bytes, dense_bytes)
    assert paged_peak >= 2 * dense_peak, \
        f"paged admitted {paged_peak} < 2x dense {dense_peak}"

    ds, ps = dense_metrics.summary(), paged_metrics.summary()
    rows += [
        row("serve_dense", (ds['tokens'] / ds['tokens_per_sec']) * 1e6
            if ds['tokens_per_sec'] else 0.0,
            f"{ds['tokens_per_sec']:.1f} tok/s slab={dense_bytes}B "
            f"admitted={dense_peak} slots={dense_slots} "
            f"util={ds['kv_util_peak']:.0%}"),
        row("serve_paged", (ps['tokens'] / ps['tokens_per_sec']) * 1e6
            if ps['tokens_per_sec'] else 0.0,
            f"{ps['tokens_per_sec']:.1f} tok/s slab={paged_bytes}B "
            f"admitted={paged_peak} blocks={pool_blocks}x{block_size} "
            f"util={ps['kv_util_peak']:.0%} 0 retraces"),
    ]

    # -- session-prefix caching at equal slab bytes ------------------------
    # The session-shaped stream the paper's unit of analysis implies: every
    # request re-submits the same 24-token session prefix plus a 6-token
    # divergent tail (80% common). Same pool as serve_paged (31 x 8-token
    # blocks); each request worst-cases 5 blocks, so the non-sharing pool
    # admits 6 concurrently — sharing maps the 3 resident prefix blocks
    # copy-free and reserves only the 2 owned blocks per request.
    prefix_rng = np.random.default_rng(11)
    common24 = prefix_rng.integers(4, 64, 24).astype(np.int32)
    sess = [np.concatenate([common24,
                            prefix_rng.integers(4, 64, 6).astype(np.int32)])
            for _ in range(n_short)]

    def prefix_sched(share):
        return ContinuousScheduler(api, params, SchedulerConfig(
            batch=paged_slots, buckets=(8, 32), max_new_tokens=budget,
            paged=True, block_size=block_size, num_blocks=pool_blocks,
            prefix_cache=share))

    nosh_sched = prefix_sched(False)
    drain(nosh_sched, sess)                         # warmup
    nosh_metrics = ServeMetrics()
    nosh_sched.metrics = nosh_metrics
    nosh_peak, nosh_outs = drain(nosh_sched, sess)

    pref_sched = prefix_sched(True)
    drain(pref_sched, sess)                         # warmup: miss + hit paths
    warm_pref = dict(pref_sched.trace_counts)
    pref_metrics = ServeMetrics()
    pref_sched.metrics = pref_metrics
    pref_peak, pref_outs = drain(pref_sched, sess)
    assert dict(pref_sched.trace_counts) == warm_pref, \
        "prefix scheduler recompiled after warmup"
    pref_sched.pool.check_invariants()

    bit_equal = all(np.array_equal(a, b)
                    for a, b in zip(nosh_outs, pref_outs))
    assert bit_equal, "prefix-sharing outputs diverge from cold cache"
    assert pref_sched.pool.slab_bytes == nosh_sched.pool.slab_bytes
    assert pref_peak >= 2 * nosh_peak, \
        f"prefix sharing admitted {pref_peak} < 2x non-sharing {nosh_peak}"

    ns, xs = nosh_metrics.summary(), pref_metrics.summary()
    assert xs["prefix_hit_rate"] > 0.5 and xs["prefill_tokens_skipped"] > 0
    assert xs["mean_ttft_hit_s"] < xs["mean_ttft_miss_s"], \
        (xs["mean_ttft_hit_s"], xs["mean_ttft_miss_s"])
    rows.append(row(
        "serve_prefix", (xs['tokens'] / xs['tokens_per_sec']) * 1e6
        if xs['tokens_per_sec'] else 0.0,
        f"{xs['tokens_per_sec']:.1f} tok/s "
        f"admitted={pref_peak} vs {nosh_peak} cold "
        f"hit={xs['prefix_hit_rate']:.0%} "
        f"skipped={xs['prefill_tokens_skipped']}tok "
        f"ttft hit/miss={xs['mean_ttft_hit_s'] * 1e3:.1f}/"
        f"{xs['mean_ttft_miss_s'] * 1e3:.1f}ms 0 retraces"))

    # -- DecodeState family rows: the same scheduler over non-dense state -
    def family_stream(arch, seed):
        fcfg = smoke_config(arch).with_(vocab_size=64, max_cache_len=64)
        fapi = get_model(fcfg)
        fparams = fapi.init(jax.random.PRNGKey(0))
        frng = np.random.default_rng(seed)

        def extra():
            if fcfg.family == "encdec":
                return dict(frames=frng.standard_normal(
                    (fcfg.n_frames, fcfg.d_model)).astype(np.float32))
            if fcfg.family == "vlm":
                return dict(patches=frng.standard_normal(
                    (fcfg.n_patches, fcfg.vision_dim)).astype(np.float32))
            return None

        fsched = ContinuousScheduler(fapi, fparams, SchedulerConfig(
            batch=batch, buckets=(bucket,), max_new_tokens=max_new))
        freqs = _requests(n_req, bucket, seed=seed)
        for r in freqs:                              # warmup stream
            fsched.submit(r, extra=extra())
        fsched.run()
        warm = dict(fsched.trace_counts)
        fmetrics = ServeMetrics()
        fsched.metrics = fmetrics
        for r in freqs:
            fsched.submit(r, extra=extra())
        fsched.run()
        assert dict(fsched.trace_counts) == warm, \
            f"{arch} scheduler recompiled after warmup"
        fs = fmetrics.summary()
        flat = [t.finish - t.submit for t in fmetrics.requests.values()
                if t.finish is not None and t.submit is not None]
        return fs, flat

    families_json = {}
    for name, arch in (("serve_ssm", "mamba2-370m"),
                       ("serve_encdec", "whisper-tiny")):
        fs, flat = family_stream(arch, seed=3)
        rows.append(row(
            name, (fs['tokens'] / fs['tokens_per_sec']) * 1e6
            if fs['tokens_per_sec'] else 0.0,
            f"{fs['tokens_per_sec']:.1f} tok/s "
            f"p50={_pct(flat, 50) * 1e3:.0f}ms "
            f"p99={_pct(flat, 99) * 1e3:.0f}ms "
            f"{fs['requests']} reqs slots={batch} 0 retraces"))
        families_json[name] = dict(
            arch=arch, requests=fs["requests"], tokens=fs["tokens"],
            tokens_per_sec=fs["tokens_per_sec"],
            p50_latency_s=fs["p50_latency_s"],
            p99_latency_s=fs["p99_latency_s"],
            peak_resident_bytes=fs["kv_peak_resident_bytes"])

    # -- SLO under bursty open-loop load: over-commit vs honest reservation
    # Same slab as serve_paged (31 x 8-token blocks == 4 dense stripes),
    # bigger slot table so admission is gated by blocks, not rows. The
    # stream mixes short (2-block worst case) and long (3-4 block) requests
    # in Poisson bursts; ~25% ride the high-priority class.
    slo_slots, n_slo = 24, 40
    arrivals = bursty_arrivals(n_slo, mean_gap=6.0, burst_mean=8.0, seed=17)
    srng = np.random.default_rng(17)
    slo_stream = []
    for t in arrivals:
        # every prompt is one block; budgets split the worst case 2 vs 4
        # blocks — exactly the shape where the honest reservation wastes
        # the most (requests hold 1 block at admission, grow lazily, and
        # often hit EOS before their worst case)
        if srng.random() < 0.4:          # long budget: 4-block worst case
            p, b = srng.integers(4, 64, int(srng.integers(4, 9))), 20
        else:                            # short budget: 2-block worst case
            p, b = srng.integers(4, 64, int(srng.integers(4, 9))), 6
        prio = 1 if srng.random() < 0.25 else 0
        slo_stream.append((float(t), p.astype(np.int32), int(b), prio))

    def open_loop(sched, clock):
        """Open-loop drive: requests appear at their seeded arrival times
        (submit stamped at the true arrival), the clock advances one unit
        per scheduler step, idle gaps fast-forward. Returns (peak
        concurrently admitted, {rid: outputs})."""
        i, peak = 0, 0
        while i < len(slo_stream) or sched.num_active or sched.num_pending:
            if not (sched.num_active or sched.num_pending):
                clock.now = max(clock.now, slo_stream[i][0])
            now = clock.now
            while i < len(slo_stream) and slo_stream[i][0] <= now:
                t, p, b, prio = slo_stream[i]
                clock.now = t
                sched.submit(p, max_new_tokens=b, priority=prio)
                i += 1
            clock.now = now
            sched.step()
            peak = max(peak, sched.num_active)
            clock.advance(1.0)
        return peak, sched.run()

    def slo_run(factor):
        clock = VirtualClock()
        sched = ContinuousScheduler(api, params, SchedulerConfig(
            batch=slo_slots, buckets=(8, 16, 32), max_new_tokens=20,
            paged=True, block_size=block_size, num_blocks=pool_blocks,
            overcommit=factor, debug=True))
        open_loop(sched, clock)                      # warmup (jit traces)
        warm = dict(sched.trace_counts)
        clock.now = 0.0
        sched.metrics = ServeMetrics(clock=clock)
        peak, outs = open_loop(sched, clock)
        assert dict(sched.trace_counts) == warm, \
            f"slo scheduler (overcommit={factor}) recompiled after warmup"
        sched.pool.check_invariants()
        return peak, outs, sched.metrics.summary(), sched

    base_peak, base_outs, bsum, base_sched = slo_run(1.0)
    oc_peak, oc_outs, osum, oc_sched = slo_run(2.0)

    assert oc_sched.pool.slab_bytes == base_sched.pool.slab_bytes
    assert bsum["preemptions"] == 0, "honest reservation preempted"
    slo_bit_equal = all(
        np.array_equal(base_outs[a], oc_outs[b])
        for a, b in zip(sorted(base_outs), sorted(oc_outs)))
    assert slo_bit_equal, \
        "preempted outputs diverge from the never-preempted baseline"
    slo_gain = oc_peak / max(base_peak, 1)
    assert slo_gain >= 1.3, \
        f"over-commit admitted {oc_peak} < 1.3x baseline {base_peak}"
    assert osum["preemptions"] >= 1, \
        "over-commit stream never exercised the preemption path"
    hi_base = bsum["per_priority"][1]["p99_latency_s"]
    hi_oc = osum["per_priority"][1]["p99_latency_s"]
    assert hi_oc <= hi_base, \
        f"hi-pri p99 regressed under over-commit: {hi_oc} > {hi_base}"
    rows.append(row(
        "serve_slo", osum["p99_latency_s"],
        f"admitted={oc_peak} vs {base_peak} honest "
        f"(gain {slo_gain:.2f}x) preempts={osum['preemptions']} "
        f"hi-p99={hi_oc:.0f} vs {hi_base:.0f} steps "
        f"qwait-p99={osum['p99_queue_wait_s']:.0f} steps "
        f"bit_equal={slo_bit_equal} 0 retraces"))

    def _slo_side(peak, s):
        return dict(
            admitted_peak=int(peak), preemptions=int(s["preemptions"]),
            p50_latency_steps=s["p50_latency_s"],
            p99_latency_steps=s["p99_latency_s"],
            p99_queue_wait_steps=s["p99_queue_wait_s"],
            p99_ttft_steps=s["p99_ttft_s"],
            per_priority={
                str(k): dict(requests=v["requests"],
                             preemptions=v["preemptions"],
                             p99_latency_steps=v["p99_latency_s"],
                             p99_queue_wait_steps=v["p99_queue_wait_s"])
                for k, v in s["per_priority"].items()})

    slo_json = dict(
        stream=dict(requests=n_slo, mean_gap=6.0, burst_mean=8.0, seed=17,
                    slots=slo_slots, num_blocks=pool_blocks,
                    block_size=block_size, overcommit=2.0),
        baseline=_slo_side(base_peak, bsum),
        overcommit=_slo_side(oc_peak, osum),
        admission_gain=slo_gain,
        hi_pri_p99_baseline_steps=hi_base,
        hi_pri_p99_overcommit_steps=hi_oc,
        preemptions=int(osum["preemptions"]),
        bit_equal=bool(slo_bit_equal),
    )

    global LAST_JSON
    LAST_JSON = dict(
        slo=slo_json,
        families=families_json,
        stream=dict(requests=n_short, prompt_len="4..8", budget=budget,
                    model="behavior-lm-100m-smoke",
                    max_cache_len=cfg.max_cache_len),
        dense=dict(slab_bytes=int(dense_bytes), slots=dense_slots,
                   admitted_peak=int(dense_peak),
                   tokens_per_sec=ds["tokens_per_sec"],
                   p50_latency_s=ds["p50_latency_s"],
                   p99_latency_s=ds["p99_latency_s"],
                   kv_util_peak=ds["kv_util_peak"],
                   kv_peak_resident_bytes=ds["kv_peak_resident_bytes"]),
        paged=dict(slab_bytes=int(paged_bytes), slots=paged_slots,
                   num_blocks=pool_blocks, block_size=block_size,
                   admitted_peak=int(paged_peak),
                   tokens_per_sec=ps["tokens_per_sec"],
                   p50_latency_s=ps["p50_latency_s"],
                   p99_latency_s=ps["p99_latency_s"],
                   kv_util_peak=ps["kv_util_peak"],
                   kv_peak_resident_bytes=ps["kv_peak_resident_bytes"]),
        admission_gain=paged_peak / max(dense_peak, 1),
        prefix=dict(
            stream=dict(requests=n_short, prompt_len=30, common_prefix=24,
                        budget=budget, num_blocks=pool_blocks,
                        block_size=block_size),
            off=dict(admitted_peak=int(nosh_peak),
                     tokens_per_sec=ns["tokens_per_sec"],
                     p50_ttft_s=ns["p50_ttft_s"],
                     kv_referenced_peak=ns["kv_referenced_peak"]),
            on=dict(admitted_peak=int(pref_peak),
                    tokens_per_sec=xs["tokens_per_sec"],
                    p50_ttft_s=xs["p50_ttft_s"],
                    kv_referenced_peak=xs["kv_referenced_peak"],
                    kv_live_blocks_peak=xs["kv_live_blocks_peak"]),
            admission_gain=pref_peak / max(nosh_peak, 1),
            prefix_hit_rate=xs["prefix_hit_rate"],
            prefill_tokens_skipped=int(xs["prefill_tokens_skipped"]),
            prefix_blocks_reused=int(xs["prefix_blocks_reused"]),
            mean_ttft_hit_s=xs["mean_ttft_hit_s"],
            mean_ttft_miss_s=xs["mean_ttft_miss_s"],
            bit_equal=bool(bit_equal),
        ),
    )
    return rows
