"""Roofline derivation from the dry-run artifacts.

For every supported (arch x shape) cell on the single-pod mesh:

1. read the FULL-mode result (memory proof; compile success);
2. run COST-mode variants — reduced-depth, fully *unrolled* programs whose
   cost_analysis and HLO collective bytes are exact — and extrapolate the
   (bi)linear cost model to production depth/microbatches;
3. emit the three roofline terms:

     compute_s    = FLOPs / (chips * 197e12)          bf16 peak, TPU v5e
     memory_s     = bytes / (chips * 819e9)           HBM bandwidth
     collective_s = coll_bytes_per_chip / 4.5e10      ~link BW (ICI, 1 link
                                                      active per phase,
                                                      conservative)

plus MODEL_FLOPS = 6*N*D (dense; N_active for MoE) and the useful-compute
ratio. Results -> results/roofline.json + a markdown table for
EXPERIMENTS.md.

Cost-model terms per family (train):
  dense/moe/ssm:  f(L, u) = A + B*L + C*u + D*L*u        L in {2,4}, u in {1,2}
  hybrid:         groups g in {1,2} (+ tail point L=6g+1), same u terms
  vlm:            groups g in {1,2}, same u terms
  encdec:         f(enc, dec) = A + B*enc + C*dec        (u = 1)
Serve shapes drop the u terms.
"""
from __future__ import annotations

import itertools
import json
import os
import subprocess
import sys

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
RESULTS = os.path.join(REPO, "results")
DRYRUN = os.path.join(RESULTS, "dryrun")

CHIPS = 256                      # single-pod roofline
PEAK_FLOPS = 197e12              # bf16 / chip
HBM_BW = 819e9                   # B/s / chip
LINK_BW = 45e9                   # B/s effective per chip (ICI)

SHAPE_TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
                "decode_32k": 128, "long_500k": 1}


def _run(arch, shape, overrides, tag, force=False):
    path = os.path.join(DRYRUN, f"{arch}__{shape}__single__cost__{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            r = json.load(f)
        if not r.get("error"):
            return r
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", "single", "--mode", "cost",
           "--overrides", json.dumps(overrides), "--tag", tag]
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    subprocess.run(cmd, cwd=REPO, env=env, check=True,
                   stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    with open(path) as f:
        return json.load(f)


def _metrics(r):
    coll = r.get("collectives", {})
    return np.array([r["flops"] or 0.0, r["bytes_accessed"] or 0.0,
                     float(coll.get("total", 0))])


def _fit_eval(points, targets):
    """points: list of (feature_vec, metrics[3]); solve least squares and
    evaluate at ``targets`` feature vec."""
    X = np.array([p[0] for p in points], float)
    Y = np.array([p[1] for p in points], float)
    coef, *_ = np.linalg.lstsq(X, Y, rcond=None)
    out = np.asarray(targets, float) @ coef
    return np.maximum(out, 0.0)


def extrapolate_cell(arch: str, shape: str, cfg, extra_overrides=None,
                     tag_prefix: str = "") -> dict:
    """Returns dict(flops, bytes, coll_bytes) extrapolated to full config."""
    fam = cfg.family
    train = shape == "train_4k"
    mus = (1, 2) if train and cfg.microbatches > 1 else (1,)
    base_ovr = dict(scan_layers=False, unroll_microbatches=True,
                    **(extra_overrides or {}))

    def feat_train(l, u):
        return [1.0, l, u, l * u] if len(mus) > 1 else [1.0, l]

    if fam in ("dense", "moe", "ssm"):
        ls = (2, 4)
        pts = []
        for l, u in itertools.product(ls, mus):
            r = _run(arch, shape, {**base_ovr, "num_layers": l,
                                   "microbatches": u}, tag_prefix + f"L{l}u{u}")
            pts.append((feat_train(l, u), _metrics(r)))
        tgt = feat_train(cfg.num_layers, cfg.microbatches)
        out = _fit_eval(pts, tgt)

    elif fam == "hybrid":
        ae = cfg.attn_every
        pts, tail_pts = [], {}
        for g, u in itertools.product((1, 2), mus):
            r = _run(arch, shape, {**base_ovr, "num_layers": ae * g,
                                   "microbatches": u}, tag_prefix + f"G{g}u{u}")
            pts.append((feat_train(g, u), _metrics(r)))
        # tail coefficient: one extra mamba layer beyond full groups
        for u in mus:
            r12 = _run(arch, shape, {**base_ovr, "num_layers": 2 * ae,
                                     "microbatches": u}, tag_prefix + f"G2u{u}")
            r13 = _run(arch, shape, {**base_ovr, "num_layers": 2 * ae + 1,
                                     "microbatches": u}, tag_prefix + f"G2t1u{u}")
            tail_pts[u] = _metrics(r13) - _metrics(r12)
        n_groups = cfg.num_layers // ae
        tail_n = cfg.num_layers - n_groups * ae
        out = _fit_eval(pts, feat_train(n_groups, cfg.microbatches))
        if tail_n:
            if len(mus) > 1:
                tA = 2 * tail_pts[1] - tail_pts[2]
                tC = tail_pts[2] - tail_pts[1]
                out = out + tail_n * (tA + cfg.microbatches * tC)
            else:
                out = out + tail_n * tail_pts[1]

    elif fam == "vlm":
        ce = cfg.cross_attn_every
        pts = []
        for g, u in itertools.product((1, 2), mus):
            r = _run(arch, shape, {**base_ovr, "num_layers": ce * g,
                                   "microbatches": u}, tag_prefix + f"G{g}u{u}")
            pts.append((feat_train(g, u), _metrics(r)))
        out = _fit_eval(pts, feat_train(cfg.num_layers // ce,
                                        cfg.microbatches))

    elif fam == "encdec":
        pts = []
        for enc, dec in ((2, 2), (4, 2), (2, 4)):
            r = _run(arch, shape, {**base_ovr, "encoder_layers": enc,
                                   "num_layers": dec}, tag_prefix + f"e{enc}d{dec}")
            pts.append(([1.0, enc, dec], _metrics(r)))
        out = _fit_eval(pts, [1.0, cfg.encoder_layers, cfg.num_layers])
    else:
        raise ValueError(fam)

    return dict(flops=float(out[0]), bytes=float(out[1]),
                coll_bytes=float(out[2]))


def model_flops(cfg, shape: str) -> float:
    """6*N*D (train) / 2*N*D (inference) with N = active params."""
    n = cfg.active_param_count()
    tokens = SHAPE_TOKENS[shape]
    mult = 6.0 if shape == "train_4k" else 2.0
    return mult * n * tokens


def analytic_min_bytes(cfg, shape: str) -> float:
    """Fusion-ideal per-device HBM traffic floor (documented model):

    train:  AdamW state r/w (6 x 4B x P/chips) + bf16 weight reads per
            microbatch pass (3 passes x 2B x P/TP — the FSDP-gathered copy
            is re-read each microbatch) + carry traffic + logits;
    decode: one bf16 read of all (active) weights + the KV cache/state;
    prefill: weight reads + cache write + carry traffic.

    The HLO 'bytes accessed' is the no-fusion UPPER bound; real HBM traffic
    lies between. Dominance below uses this floor (conservative for the
    memory term, so compute/collective dominance is never understated).
    """
    chips = CHIPS
    tp = 16
    p_total = cfg.param_count()
    p_active = cfg.active_param_count()
    d, L, v = cfg.d_model, cfg.num_layers, cfg.vocab_size
    if shape == "train_4k":
        tokens_dev = 4096 * 256 // chips * tp  # per data shard
        opt = 6 * 4 * p_total / chips
        wts = 3 * cfg.microbatches * 2 * (p_total / tp)
        carry = tokens_dev * d * 2 * 6 * L / tp  # seq-replicated over model
        logits = 3 * 2 * tokens_dev * (v / tp)
        return opt + wts + carry + logits
    if shape == "prefill_32k":
        tokens_dev = 32768 * 32 // chips * tp
        wts = 2 * (p_total / tp)
        cache = 2 * 2 * L * cfg.num_kv_heads * cfg.resolved_head_dim * \
            tokens_dev / tp
        carry = tokens_dev * d * 2 * 4 * L / tp
        return wts + cache + carry
    # decode: weights once + cache read once
    batch = SHAPE_TOKENS[shape]
    wts = 2 * p_active / chips
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    ctx = 32768 if shape == "decode_32k" else 524288
    if cfg.family == "ssm":
        cache = 4 * L * batch * cfg.ssm_nheads * cfg.ssm_state * \
            cfg.ssm_headdim / chips * 2  # read+write f32 state
    elif cfg.family == "hybrid":
        n_groups = L // max(cfg.attn_every, 1)
        cache = (4 * L * batch * cfg.ssm_nheads * cfg.ssm_state *
                 cfg.ssm_headdim * 2
                 + 2 * 2 * n_groups * batch * kv * hd * ctx) / chips
    else:
        cache = 2 * 2 * L * batch * kv * hd * ctx / chips
    return wts + cache


def roofline_row(arch: str, shape: str) -> dict | None:
    from repro.launch.shapes import cell_supported, cell_config
    ok, reason = cell_supported(arch, shape)
    if not ok:
        return dict(arch=arch, shape=shape, skipped=True, reason=reason)
    full_path = os.path.join(DRYRUN, f"{arch}__{shape}__single__full.json")
    if not os.path.exists(full_path):
        return None
    with open(full_path) as f:
        full = json.load(f)
    if full.get("error"):
        return dict(arch=arch, shape=shape, error=True)
    cfg = cell_config(arch, shape)
    ext = extrapolate_cell(arch, shape, cfg)

    # cost/bytes from HLO are GLOBAL (whole-program over all devices)?
    # No: with SPMD the compiled module is the per-device program, so
    # cost_analysis flops/bytes are PER DEVICE. Totals = x CHIPS.
    flops_per_dev = ext["flops"]
    bytes_per_dev = ext["bytes"]
    coll_per_dev = ext["coll_bytes"]

    compute_s = flops_per_dev / PEAK_FLOPS
    memory_hlo_s = bytes_per_dev / HBM_BW          # no-fusion UPPER bound
    mem_floor = analytic_min_bytes(cfg, shape)
    memory_s = mem_floor / HBM_BW                  # fusion-ideal floor
    coll_s = coll_per_dev / LINK_BW
    mf = model_flops(cfg, shape)
    hlo_total = flops_per_dev * CHIPS
    terms = dict(compute_s=compute_s, memory_s=memory_s, collective_s=coll_s)
    dominant = max(terms, key=terms.get)
    bound_s = max(compute_s, memory_s, coll_s)
    return dict(
        arch=arch, shape=shape, skipped=False,
        flops_per_dev=flops_per_dev, bytes_per_dev=bytes_per_dev,
        mem_floor_bytes_per_dev=mem_floor,
        coll_bytes_per_dev=coll_per_dev,
        **terms, memory_hlo_s=memory_hlo_s, dominant=dominant,
        model_flops=mf, hlo_flops_total=hlo_total,
        useful_ratio=(mf / hlo_total) if hlo_total else 0.0,
        mfu_bound=(mf / (CHIPS * PEAK_FLOPS)) / bound_s if bound_s else 0.0,
        memory_per_dev=full["memory"],
    )


def main(argv=None):
    import argparse
    from repro.configs import ASSIGNED
    from repro.launch.shapes import SHAPES
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(SHAPES)
    rows = []
    for arch in archs:
        for shape in shapes:
            row = roofline_row(arch, shape)
            if row is None:
                print(f"[missing full dry-run] {arch} {shape}", file=sys.stderr)
                continue
            rows.append(row)
            if not row.get("skipped") and not row.get("error"):
                print(f"{arch:22s} {shape:12s} comp={row['compute_s']*1e3:8.2f}ms "
                      f"mem={row['memory_s']*1e3:8.2f}ms coll={row['collective_s']*1e3:8.2f}ms "
                      f"dom={row['dominant']:12s} useful={row['useful_ratio']:.2f} "
                      f"mfu_bound={row['mfu_bound']*100:5.1f}%", flush=True)
            else:
                print(f"{arch:22s} {shape:12s} SKIP/{row.get('reason','err')[:60]}",
                      flush=True)
    with open(os.path.join(RESULTS, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
