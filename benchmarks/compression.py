"""Paper claim (§4.2): session sequences are ~50x smaller than the raw
client-event logs. We measure the real UTF-8 byte size of the materialized
sequences against (a) a Thrift-sized model of the raw records and (b) the
actual gzip'd JSON the scribe simulation ships."""
from __future__ import annotations

import gzip
import json

import numpy as np

from repro.core import varint
from .common import corpus, timeit, row


def run() -> list[str]:
    c = corpus()
    b, seqs, d = c["batch"], c["seqs"], c["dictionary"]

    mean_name_len = float(np.mean([len(n) for n in b.table.names]))
    raw_model = varint.raw_log_size_bytes(len(b), mean_name_len)

    # actual wire bytes: JSON rows (what the scribe sim ships), gzip'd
    sample = min(len(b), 4000)
    js = "\n".join(b.event_at(i).to_json() for i in range(sample))
    wire = len(gzip.compress(js.encode())) * (len(b) / sample)

    us = timeit(lambda: varint.encoded_size_bytes(seqs))
    seq_bytes = varint.encoded_size_bytes(seqs)
    # metadata of the materialized relation (user, session, ip, duration)
    meta_bytes = len(seqs) * (8 + 8 + 4 + 4)

    r_model = raw_model / (seq_bytes + meta_bytes)
    r_gzip = wire / (seq_bytes + meta_bytes)
    return [
        row("compression_vs_thrift_model", us,
            f"ratio={r_model:.1f}x (paper ~50x); raw={raw_model} "
            f"seq={seq_bytes}+{meta_bytes}meta"),
        row("compression_vs_gzip_json", us, f"ratio={r_gzip:.1f}x"),
        row("varint_bytes_per_event", us,
            f"{seq_bytes / max(int(seqs.length.sum()),1):.2f}B/event "
            f"(freq coding; alphabet={d.alphabet_size})"),
    ]
