"""Paper claim (§4.2): session sequences are ~50x smaller than the raw
client-event logs. Measured end-to-end through the segment store
(repro.data.store): micro-batch writes produce real encoded event segments
(delta+varint timestamps, zigzag-varint ids, varint dictionary codes) — the
*actual stored raw-side bytes*, replacing the old Thrift-sized model — and
compaction folds them into session segments (UTF-8 sequence payloads +
varint metadata columns), the stored sequence-side bytes. The Thrift model
and the gzip'd JSON wire estimate stay as reference points."""
from __future__ import annotations

import gzip
import time

import numpy as np

from repro.core import varint
from repro.data.store import Store, StoreConfig
from repro.data.streampipe import session_multiset, split_ticks
from .common import corpus, timeit, row

# Machine-readable payload for benchmarks/run.py --json; merged into
# BENCH_pipeline.json (the CI + docs-freshness gates parse the "store"
# section: bytes/event and the compaction-vs-oracle equality flag).
LAST_JSON: dict | None = None
JSON_PATH = "BENCH_pipeline.json"

N_WRITES = 16  # micro-batch writes (the log mover's unit)


def build_store(b, codes, n_writes: int = N_WRITES) -> Store:
    """The corpus written as time-ordered micro-batches (no dedup — the
    shared benchmark corpus sequences are sessionized without it)."""
    store = Store(StoreConfig(dedup=False, max_len=2048))
    ip = b.ip.astype(np.int64)
    for ix in split_ticks(b.timestamp, n_writes):
        store.append_events(b.user_id[ix], b.session_id[ix],
                            b.timestamp[ix], codes[ix], ip[ix])
    return store


def run() -> list[str]:
    global LAST_JSON
    c = corpus()
    b, seqs, d, codes = c["batch"], c["seqs"], c["dictionary"], c["codes"]
    n = len(b)

    us_write = timeit(lambda: build_store(b, codes), repeats=3)
    store = build_store(b, codes)
    event_bytes = store.stored_bytes()["events"]

    t0 = time.perf_counter()
    store.compact()
    us_compact = (time.perf_counter() - t0) * 1e6
    session_bytes = store.stored_bytes()["sessions"]
    got = store.sequences()
    equal_oracle = session_multiset(got) == session_multiset(seqs)

    # reference points: the §3.2 Thrift-record model and gzip'd JSON wire
    mean_name_len = float(np.mean([len(nm) for nm in b.table.names]))
    raw_model = varint.raw_log_size_bytes(n, mean_name_len)
    sample = min(n, 4000)
    js = "\n".join(b.event_at(i).to_json() for i in range(sample))
    wire = len(gzip.compress(js.encode())) * (n / sample)

    stored_events = int(got.stored_length().sum())
    r_segments = event_bytes / session_bytes
    r_model = raw_model / session_bytes
    r_gzip = wire / session_bytes
    LAST_JSON = {"store": {
        "n_events": n, "n_sessions": len(got), "n_writes": N_WRITES,
        "event_segment_bytes": int(event_bytes),
        "session_segment_bytes": int(session_bytes),
        "event_bytes_per_event": event_bytes / n,
        "bytes_per_event": session_bytes / max(stored_events, 1),
        "ratio_vs_event_segments": r_segments,
        "ratio_vs_thrift_model": r_model,
        "equal_oracle": bool(equal_oracle),
    }}
    return [
        row("store_event_segments", us_write,
            f"{event_bytes / n:.2f}B/event raw columnar "
            f"({N_WRITES} micro-batch segments)"),
        row("store_session_segments", us_compact,
            f"{session_bytes / max(stored_events, 1):.2f}B/event "
            f"compacted; ratio={r_segments:.1f}x vs event segments, "
            f"{r_model:.1f}x vs Thrift model (paper ~50x); "
            f"oracle_equal={equal_oracle}"),
        row("compression_vs_gzip_json", us_compact,
            f"ratio={r_gzip:.1f}x"),
        row("varint_bytes_per_event", us_compact,
            f"{varint.encoded_size_bytes(seqs) / max(int(seqs.length.sum()), 1):.2f}"
            f"B/event payload only (freq coding; alphabet={d.alphabet_size})"),
    ]
