"""Pipeline-stage throughput: the vectorized JAX group-by vs the Pig-style
Python oracle, dictionary build, the LM batch pipeline feed rate, and the
full 3-stage log pipeline — single-host vs distributed on a host-local
8-shard mesh (repartition -> dedup+sessionize -> ngram/funnel rollups)."""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np

from repro.core import EventDictionary, sessionize
from repro.core.oracle import sessionize_oracle
from repro.data import SessionBatchPipeline, PipelineConfig
from .common import corpus, timeit, row

# The host-local distributed run needs the device-count XLA flag set before
# jax imports, so it lives in a subprocess. It times the SAME corpus and
# funnel through both entry points and asserts the rollups agree before
# reporting.
_DIST_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, time
sys.path.insert(0, {src!r})
import numpy as np, jax
from repro.core import EventDictionary
from repro.data import generate, LogGenConfig
from repro.data.distpipe import (DistPipelineConfig,
                                 make_distributed_pipeline,
                                 single_host_pipeline)

log = generate(LogGenConfig(n_users={n_users}, seed={seed}))
b = log.batch
d = EventDictionary.build(b.table, b.name_id)
codes = np.asarray(d.encode_ids(b.name_id))
stages = [d.codes_matching(p) for p in (
    "*:signup:landing:form:signup_button:click",
    "*:signup:form:form:submit_button:submit",
    "*:signup:follow_suggestions:list:user:follow",
    "*:signup:complete:page::impression")]
n = len(b)
ip = b.ip.astype(np.int64)
cfg = DistPipelineConfig(alphabet_size=d.alphabet_size,
                         max_sessions_per_shard=-(-n // 4), max_len=2048)

def timed(fn, repeats=3):
    out = fn()  # warmup (jit compile); result reused for the equivalence check
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts)), out

us_single, ora = timed(lambda: single_host_pipeline(
    b.user_id, b.session_id, b.timestamp, codes, ip, cfg=cfg, stages=stages))
mesh = jax.make_mesh((8,), ("data",))
pipe = make_distributed_pipeline(mesh, cfg, stages)
us_dist, res = timed(
    lambda: pipe(b.user_id, b.session_id, b.timestamp, codes, ip))

assert res.dropped == 0
assert res.num_sessions() == ora.num_sessions()
assert np.array_equal(res.ngram_counts, ora.ngram_counts)
assert res.funnel_reach == ora.funnel_reach
print(f"DIST,{{n}},{{us_single:.1f}},{{us_dist:.1f}}")
"""


def _distpipe_rows(n_users: int = 2000, seed: int = 42) -> list[str]:
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    script = _DIST_SCRIPT.format(src=src, n_users=n_users, seed=seed)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError("distributed pipeline bench failed:\n"
                           + out.stderr[-3000:])
    line = next(l for l in out.stdout.splitlines() if l.startswith("DIST,"))
    _, n, us_single, us_dist = line.split(",")
    n, us_single, us_dist = int(n), float(us_single), float(us_dist)
    return [
        row("pipeline_single_host", us_single,
            f"{n / (us_single / 1e6) / 1e6:.2f}M events/s "
            "dedup+sessionize+ngram+funnel"),
        row("pipeline_distributed_8shard", us_dist,
            f"{n / (us_dist / 1e6) / 1e6:.2f}M events/s "
            "repartition+dedup+sessionize+rollups, 8 host shards"),
    ]


def run() -> list[str]:
    c = corpus()
    b, codes, seqs = c["batch"], c["codes"], c["seqs"]
    n = len(b)

    us_jax = timeit(lambda: sessionize(
        b.user_id, b.session_id, b.timestamp, codes, b.ip.astype(np.int64),
        max_sessions=n, max_len=2048).symbols.block_until_ready(), repeats=3)
    us_py = timeit(lambda: sessionize_oracle(
        b.user_id, b.session_id, b.timestamp, codes), repeats=1, warmup=0)

    us_dict = timeit(lambda: EventDictionary.build(b.table, b.name_id))

    pipe = SessionBatchPipeline(seqs, PipelineConfig(seq_len=512,
                                                     global_batch=8))
    nb = pipe.batches_per_epoch()

    def one_epoch():
        for _ in pipe.epoch(0):
            pass

    us_pipe = timeit(one_epoch, repeats=2)
    toks = nb * 8 * 512
    return [
        row("sessionize_jax", us_jax,
            f"{n / (us_jax / 1e6) / 1e6:.2f}M events/s"),
        row("sessionize_python_oracle", us_py,
            f"{n / (us_py / 1e6) / 1e6:.2f}M events/s "
            f"(jax speedup={us_py / us_jax:.1f}x)"),
        row("dictionary_build", us_dict, f"alphabet from {n} events"),
        row("lm_batch_pipeline_epoch", us_pipe,
            f"{toks / (us_pipe / 1e6) / 1e6:.2f}M tokens/s prefetch=2"),
        *_distpipe_rows(),
    ]
