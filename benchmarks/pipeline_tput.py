"""Pipeline-stage throughput: the vectorized JAX group-by vs the Pig-style
Python oracle, dictionary build, and the LM batch pipeline feed rate."""
from __future__ import annotations

import numpy as np

from repro.core import EventDictionary, sessionize
from repro.core.oracle import sessionize_oracle
from repro.data import SessionBatchPipeline, PipelineConfig
from .common import corpus, timeit, row


def run() -> list[str]:
    c = corpus()
    b, codes, seqs = c["batch"], c["codes"], c["seqs"]
    n = len(b)

    us_jax = timeit(lambda: sessionize(
        b.user_id, b.session_id, b.timestamp, codes, b.ip.astype(np.int64),
        max_sessions=n, max_len=2048).symbols.block_until_ready(), repeats=3)
    us_py = timeit(lambda: sessionize_oracle(
        b.user_id, b.session_id, b.timestamp, codes), repeats=1, warmup=0)

    us_dict = timeit(lambda: EventDictionary.build(b.table, b.name_id))

    pipe = SessionBatchPipeline(seqs, PipelineConfig(seq_len=512,
                                                     global_batch=8))
    nb = pipe.batches_per_epoch()

    def one_epoch():
        for _ in pipe.epoch(0):
            pass

    us_pipe = timeit(one_epoch, repeats=2)
    toks = nb * 8 * 512
    return [
        row("sessionize_jax", us_jax,
            f"{n / (us_jax / 1e6) / 1e6:.2f}M events/s"),
        row("sessionize_python_oracle", us_py,
            f"{n / (us_py / 1e6) / 1e6:.2f}M events/s "
            f"(jax speedup={us_py / us_jax:.1f}x)"),
        row("dictionary_build", us_dict, f"alphabet from {n} events"),
        row("lm_batch_pipeline_epoch", us_pipe,
            f"{toks / (us_pipe / 1e6) / 1e6:.2f}M tokens/s prefetch=2"),
    ]
