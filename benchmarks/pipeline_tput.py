"""Pipeline-stage throughput: the vectorized JAX group-by vs the Pig-style
Python oracle, dictionary build, the LM batch pipeline feed rate, the
full 3-stage log pipeline — single-host vs distributed on a host-local
8-shard mesh (repartition -> dedup+sessionize -> ngram/funnel rollups) —
and the streaming fast-data tier (micro-batch ticks through
repro.data.streampipe, checked bit-equal against the batch oracle)."""
from __future__ import annotations

import os
import subprocess
import sys
import time

import numpy as np

from repro.core import EventDictionary, sessionize
from repro.core.oracle import sessionize_oracle
from repro.data import SessionBatchPipeline, PipelineConfig
from .common import corpus, timeit, row

# Machine-readable payload for benchmarks/run.py --json (the CI gate parses
# the "stream" section: watermark lag and stream-vs-batch equivalence).
LAST_JSON: dict | None = None
JSON_PATH = "BENCH_pipeline.json"

_FUNNEL = ("*:signup:landing:form:signup_button:click",
           "*:signup:form:form:submit_button:submit",
           "*:signup:follow_suggestions:list:user:follow",
           "*:signup:complete:page::impression")

# The host-local distributed run needs the device-count XLA flag set before
# jax imports, so it lives in a subprocess. It times the SAME corpus and
# funnel through both entry points and asserts the rollups agree before
# reporting.
_DIST_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, time
sys.path.insert(0, {src!r})
import numpy as np, jax
from repro.core import EventDictionary
from repro.data import generate, LogGenConfig
from repro.data.distpipe import (DistPipelineConfig,
                                 make_distributed_pipeline,
                                 single_host_pipeline)

log = generate(LogGenConfig(n_users={n_users}, seed={seed}))
b = log.batch
d = EventDictionary.build(b.table, b.name_id)
codes = np.asarray(d.encode_ids(b.name_id))
stages = [d.codes_matching(p) for p in (
    "*:signup:landing:form:signup_button:click",
    "*:signup:form:form:submit_button:submit",
    "*:signup:follow_suggestions:list:user:follow",
    "*:signup:complete:page::impression")]
n = len(b)
ip = b.ip.astype(np.int64)
cfg = DistPipelineConfig(alphabet_size=d.alphabet_size,
                         max_sessions_per_shard=-(-n // 4), max_len=2048)

def timed(fn, repeats=3):
    out = fn()  # warmup (jit compile); result reused for the equivalence check
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts)), out

us_single, ora = timed(lambda: single_host_pipeline(
    b.user_id, b.session_id, b.timestamp, codes, ip, cfg=cfg, stages=stages))
mesh = jax.make_mesh((8,), ("data",))
pipe = make_distributed_pipeline(mesh, cfg, stages)
us_dist, res = timed(
    lambda: pipe(b.user_id, b.session_id, b.timestamp, codes, ip))

assert res.dropped == 0
assert res.num_sessions() == ora.num_sessions()
assert np.array_equal(res.ngram_counts, ora.ngram_counts)
assert res.funnel_reach == ora.funnel_reach
print(f"DIST,{{n}},{{us_single:.1f}},{{us_dist:.1f}}")
"""


def _distpipe_rows(n_users: int = 2000, seed: int = 42) -> list[str]:
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    script = _DIST_SCRIPT.format(src=src, n_users=n_users, seed=seed)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError("distributed pipeline bench failed:\n"
                           + out.stderr[-3000:])
    line = next(l for l in out.stdout.splitlines() if l.startswith("DIST,"))
    _, n, us_single, us_dist = line.split(",")
    n, us_single, us_dist = int(n), float(us_single), float(us_dist)
    return [
        row("pipeline_single_host", us_single,
            f"{n / (us_single / 1e6) / 1e6:.2f}M events/s "
            "dedup+sessionize+ngram+funnel"),
        row("pipeline_distributed_8shard", us_dist,
            f"{n / (us_dist / 1e6) / 1e6:.2f}M events/s "
            "repartition+dedup+sessionize+rollups, 8 host shards"),
    ]


def _stream_rows(n_users: int = 500, seed: int = 42,
                 n_ticks: int = 16) -> list[str]:
    """One loggen day replayed tick-by-tick through the single-host
    streaming tier: events/sec per tick, watermark lag, ring occupancy,
    and a bit-equality check against the batch pipeline after flush."""
    from repro.data import generate, LogGenConfig
    from repro.data.distpipe import single_host_pipeline
    from repro.data.streampipe import (StreamConfig, session_multiset,
                                       single_host_stream, split_ticks)
    global LAST_JSON
    log = generate(LogGenConfig(n_users=n_users, seed=seed))
    b = log.batch
    d = EventDictionary.build(b.table, b.name_id)
    codes = np.asarray(d.encode_ids(b.name_id), np.int32)
    ip = b.ip.astype(np.int64)
    stages = [d.codes_matching(p) for p in _FUNNEL]
    n = len(b)
    ticks = split_ticks(b.timestamp, n_ticks)
    cap = 1 << int(max(len(ix) for ix in ticks) - 1).bit_length()
    # ring sized ~4x the corpus's peak open sessions / longest session —
    # the per-tick merge cost is O(max_open * max_len + tick_capacity)
    cfg = StreamConfig(alphabet_size=d.alphabet_size, max_open=128,
                       max_len=128, tick_capacity=cap,
                       allowed_lateness_ms=60_000)

    def one_replay(rec=None):
        s = single_host_stream(cfg, stages)
        for ix in ticks:
            t0 = time.perf_counter()
            res = s.tick(b.user_id[ix], b.session_id[ix], b.timestamp[ix],
                         codes[ix], ip[ix])
            if rec is not None:
                rec.append(((time.perf_counter() - t0) * 1e6, len(ix),
                            res.open_sessions, s.watermark_lag_ms))
        s.flush()
        return s

    one_replay()  # warmup: compiles the tick; later replays hit the cache
    rec: list[tuple] = []
    s = one_replay(rec)
    got = s.result()
    oracle = single_host_pipeline(b.user_id, b.session_id, b.timestamp,
                                  codes, ip, cfg=cfg.batch_config(n),
                                  stages=stages)
    bit_equal = bool(
        np.array_equal(got.ngram_counts, oracle.ngram_counts)
        and got.funnel_reach == oracle.funnel_reach
        and session_multiset(got.sequences)
        == session_multiset(oracle.sequences))
    us_tick = float(np.median([r[0] for r in rec]))
    ev_per_s = sum(r[1] for r in rec) / (sum(r[0] for r in rec) / 1e6)
    lag_mean = float(np.mean([r[3] for r in rec]))
    occ_peak = max(r[2] for r in rec)
    occ_mean = float(np.mean([r[2] for r in rec]))
    LAST_JSON = {"stream": {
        "n_events": n, "n_ticks": n_ticks,
        "tick_capacity": cfg.tick_capacity, "max_open": cfg.max_open,
        "us_per_tick": us_tick, "events_per_sec": ev_per_s,
        "watermark_lag_ms_mean": lag_mean,
        "occupancy_mean": occ_mean, "occupancy_peak": occ_peak,
        "late_dropped": s.late_dropped,
        "ring_dropped_events": s.ring_dropped_events,
        "bit_equal": bit_equal,
    }}
    return [row("stream_tput", us_tick,
                f"{ev_per_s / 1e3:.1f}K events/s/tick "
                f"lag={lag_mean:.0f}ms occ={occ_peak}/{cfg.max_open} "
                f"bit_equal={bit_equal}")]


def run() -> list[str]:
    c = corpus()
    b, codes, seqs = c["batch"], c["codes"], c["seqs"]
    n = len(b)

    us_jax = timeit(lambda: sessionize(
        b.user_id, b.session_id, b.timestamp, codes, b.ip.astype(np.int64),
        max_sessions=n, max_len=2048).symbols.block_until_ready(), repeats=3)
    us_py = timeit(lambda: sessionize_oracle(
        b.user_id, b.session_id, b.timestamp, codes), repeats=1, warmup=0)

    us_dict = timeit(lambda: EventDictionary.build(b.table, b.name_id))

    pipe = SessionBatchPipeline(seqs, PipelineConfig(seq_len=512,
                                                     global_batch=8))
    nb = pipe.batches_per_epoch()

    def one_epoch():
        for _ in pipe.epoch(0):
            pass

    us_pipe = timeit(one_epoch, repeats=2)
    toks = nb * 8 * 512
    return [
        row("sessionize_jax", us_jax,
            f"{n / (us_jax / 1e6) / 1e6:.2f}M events/s"),
        row("sessionize_python_oracle", us_py,
            f"{n / (us_py / 1e6) / 1e6:.2f}M events/s "
            f"(jax speedup={us_py / us_jax:.1f}x)"),
        row("dictionary_build", us_dict, f"alphabet from {n} events"),
        row("lm_batch_pipeline_epoch", us_pipe,
            f"{toks / (us_pipe / 1e6) / 1e6:.2f}M tokens/s prefetch=2"),
        *_distpipe_rows(),
        *_stream_rows(),
    ]
