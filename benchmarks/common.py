"""Shared benchmark fixtures: one generated log corpus + derived artifacts."""
from __future__ import annotations

import functools
import time

import numpy as np

from repro.core import EventDictionary, SessionSequences, sessionize
from repro.data import generate, LogGenConfig


@functools.lru_cache(maxsize=1)
def corpus(n_users: int = 2000, seed: int = 42):
    """Generated log + dictionary + sessionized sequences (cached)."""
    log = generate(LogGenConfig(n_users=n_users, seed=seed))
    b = log.batch
    d = EventDictionary.build(b.table, b.name_id)
    codes = np.asarray(d.encode_ids(b.name_id))
    s = sessionize(b.user_id, b.session_id, b.timestamp, codes,
                   b.ip.astype(np.int64), max_sessions=len(b), max_len=2048)
    seqs = SessionSequences.from_sessionized(s)
    return dict(log=log, batch=b, dictionary=d, codes=codes, seqs=seqs)


def timeit(fn, *, repeats: int = 5, warmup: int = 1) -> float:
    """Median microseconds per call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
