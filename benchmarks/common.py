"""Shared benchmark fixtures: one generated log corpus + derived artifacts."""
from __future__ import annotations

import functools
import time

import numpy as np

from repro.core import EventDictionary, SessionSequences, sessionize
from repro.data import generate, LogGenConfig


@functools.lru_cache(maxsize=1)
def corpus(n_users: int = 2000, seed: int = 42):
    """Generated log + dictionary + sessionized sequences (cached)."""
    log = generate(LogGenConfig(n_users=n_users, seed=seed))
    b = log.batch
    d = EventDictionary.build(b.table, b.name_id)
    codes = np.asarray(d.encode_ids(b.name_id))
    s = sessionize(b.user_id, b.session_id, b.timestamp, codes,
                   b.ip.astype(np.int64), max_sessions=len(b), max_len=2048)
    seqs = SessionSequences.from_sessionized(s)
    return dict(log=log, batch=b, dictionary=d, codes=codes, seqs=seqs)


def timeit(fn, *, repeats: int = 5, warmup: int = 1) -> float:
    """Median microseconds per call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


class VirtualClock:
    """Deterministic clock for open-loop load harnesses: injected as
    ``ServeMetrics.clock``, advanced explicitly by the driver (one unit
    per scheduler step), never touching wall time — so every latency the
    SLO gates judge is reproducible run-to-run."""

    def __init__(self, now: float = 0.0):
        self.now = float(now)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float = 1.0) -> None:
        self.now += dt


def bursty_arrivals(n: int, *, mean_gap: float = 8.0,
                    burst_mean: float = 3.0, seed: int = 0) -> np.ndarray:
    """Seeded bursty open-loop arrival times for ``n`` requests, sorted
    ascending (virtual-clock units).

    Burst epochs arrive as a Poisson process (exponential gaps of mean
    ``mean_gap``); each epoch lands ``1 + Poisson(burst_mean - 1)``
    requests at the same instant — the arrival pattern "Fast Data" argues
    real query streams have, and the one worst-case reservation wastes the
    most capacity under. Entirely ``np.random.default_rng(seed)``-driven:
    no wall clock, no OS entropy, identical run-to-run."""
    if n < 1:
        return np.zeros(0, np.float64)
    rng = np.random.default_rng(seed)
    times: list[float] = []
    t = 0.0
    while len(times) < n:
        t += float(rng.exponential(mean_gap))
        size = 1 + int(rng.poisson(max(burst_mean - 1.0, 0.0)))
        times.extend([t] * min(size, n - len(times)))
    return np.asarray(times[:n], np.float64)
