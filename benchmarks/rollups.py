"""§3.2 Oink roll-up aggregations: five progressively-wildcarded count
tables computed daily over all events, no developer intervention."""
from __future__ import annotations

from repro.analytics import rollup_counts
from .common import corpus, timeit, row


def run() -> list[str]:
    c = corpus()
    b, d = c["batch"], c["dictionary"]

    def all_rollups():
        return rollup_counts(b.name_id, d)

    us = timeit(all_rollups)
    tables = all_rollups()
    sizes = "/".join(str(len(t)) for t in tables)
    total = sum(tables[0].values())
    return [
        row("oink_rollups_5_schemas", us,
            f"groups_per_level={sizes} events={total} "
            f"events_per_s={total / (us / 1e6):.0f}"),
    ]
