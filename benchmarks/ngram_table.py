"""§5.4 user modeling: n-gram language models over session sequences.
Cross entropy by order quantifies the 'temporal signal' in user behaviour
(the paper's PubMed-style analysis), plus top activity collocations."""
from __future__ import annotations

from repro.analytics import NGramLM, top_collocations
from .common import corpus, timeit, row


def run() -> list[str]:
    c = corpus()
    d, seqs = c["dictionary"], c["seqs"]
    out = []
    prev = None
    for n in (1, 2, 3):
        lm = NGramLM.fit(seqs, n, d.alphabet_size)
        us = timeit(lambda lm=lm: lm.cross_entropy(seqs), repeats=2)
        h = lm.cross_entropy(seqs)
        gain = f" signal_vs_{n-1}gram={prev - h:+.2f}bits" if prev else ""
        out.append(row(f"ngram_{n}_cross_entropy", us,
                       f"H={h:.3f}bits/event ppl={2**h:.1f}{gain}"))
        prev = h
    us = timeit(lambda: top_collocations(seqs, d, k=5), repeats=2)
    top = top_collocations(seqs, d, k=1)
    first = top[0] if top else {}
    out.append(row("collocations_g2", us,
                   f"top={first.get('first','-')}->{first.get('second','-')}"
                   f" g2={first.get('g2', 0)}"))
    return out
