"""Render EXPERIMENTS.md sections from the dry-run/roofline artifacts."""
from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
RESULTS = os.path.join(REPO, "results")
HBM_LIMIT = 16e9  # v5e


def load_full():
    out = {}
    for p in glob.glob(os.path.join(RESULTS, "dryrun", "*__full.json")):
        r = json.load(open(p))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def dryrun_table() -> str:
    full = load_full()
    lines = ["| arch | shape | mesh | compile | args+out GB/dev | temp GB/dev | fits 16GB |",
             "|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh), r in sorted(full.items()):
        if r.get("skipped"):
            lines.append(f"| {arch} | {shape} | {mesh} | SKIP (sub-quadratic"
                         f" attention required) | — | — | — |")
            continue
        if r.get("error"):
            lines.append(f"| {arch} | {shape} | {mesh} | ERROR | — | — | — |")
            continue
        m = r["memory"]
        args = m["argument_bytes"] / 1e9
        temp = m["temp_bytes"] / 1e9
        tot = args + temp
        lines.append(
            f"| {arch} | {shape} | {mesh} | {r['compile_s']:.1f}s "
            f"| {args:.2f} | {temp:.2f} "
            f"| {'YES' if tot <= HBM_LIMIT/1e9 else f'NO ({tot:.1f}GB)'} |")
    return "\n".join(lines)


def roofline_table() -> str:
    rows = json.load(open(os.path.join(RESULTS, "roofline.json")))
    lines = ["| arch | shape | compute s | memory s (floor) | mem s (HLO ub) "
             "| collective s | dominant | 6ND/HLO | roofline-bound MFU |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP | | | | | | |")
            continue
        if r.get("error"):
            lines.append(f"| {r['arch']} | {r['shape']} | ERR | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f}ms "
            f"| {r['memory_s']*1e3:.2f}ms | {r['memory_hlo_s']*1e3:.2f}ms "
            f"| {r['collective_s']*1e3:.2f}ms | {r['dominant'].replace('_s','')} "
            f"| {r['useful_ratio']:.2f} | {r['mfu_bound']*100:.1f}% |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run (full configs, scanned, both meshes)\n")
        print(dryrun_table())
    if which in ("all", "roofline"):
        print("\n### Roofline (single-pod 16x16, per-device terms)\n")
        print(roofline_table())
