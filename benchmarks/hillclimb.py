"""Perf hillclimbing driver: evaluate a config variant's roofline terms.

Usage:
  PYTHONPATH=src:. python -m benchmarks.hillclimb --arch qwen2-72b \
      --shape train_4k --tag sp_u2 \
      --overrides '{"seq_parallel": true, "microbatches": 2}'

Runs the same reduced/unrolled cost compiles as benchmarks.roofline (with
the overrides merged), extrapolates, prints the three terms next to the
recorded baseline, and (with --full) also compiles the full scanned config
for the memory proof. Results land in results/hillclimb/<arch>__<shape>__
<tag>.json — the EXPERIMENTS.md §Perf log cites these files.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from .roofline import (extrapolate_cell, model_flops, analytic_min_bytes,
                       CHIPS, PEAK_FLOPS, HBM_BW, LINK_BW, RESULTS)

HC_DIR = os.path.join(RESULTS, "hillclimb")


def evaluate(arch: str, shape: str, overrides: dict, tag: str,
             run_full: bool = False) -> dict:
    from repro.launch.shapes import cell_config
    cfg_ovr = {k: v for k, v in overrides.items()
               if not k.startswith("mesh_")}
    cfg = cell_config(arch, shape, cfg_ovr)
    ext = extrapolate_cell(arch, shape, cfg, extra_overrides=overrides,
                           tag_prefix=f"hc_{tag}_")
    compute_s = ext["flops"] / PEAK_FLOPS
    coll_s = ext["coll_bytes"] / LINK_BW
    memory_s = analytic_min_bytes(cfg, shape) / HBM_BW
    mf = model_flops(cfg, shape)
    bound = max(compute_s, memory_s, coll_s)
    out = dict(arch=arch, shape=shape, tag=tag, overrides=overrides,
               compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
               memory_hlo_s=ext["bytes"] / HBM_BW,
               mfu_bound=(mf / (CHIPS * PEAK_FLOPS)) / bound,
               useful_ratio=mf / (ext["flops"] * CHIPS))
    if run_full:
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", "single", "--mode", "full",
               "--overrides", json.dumps(overrides), "--tag", f"hc_{tag}",
               "--force"]
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(os.path.dirname(HC_DIR), "..",
                                           "src"))
        subprocess.run(cmd, check=True, stdout=subprocess.DEVNULL,
                       stderr=subprocess.DEVNULL, env=env)
        p = os.path.join(RESULTS, "dryrun",
                         f"{arch}__{shape}__single__full__hc_{tag}.json")
        full = json.load(open(p))
        out["memory_per_dev"] = full["memory"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--overrides", default="{}")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    os.makedirs(HC_DIR, exist_ok=True)
    res = evaluate(args.arch, args.shape, json.loads(args.overrides),
                   args.tag, run_full=args.full)
    path = os.path.join(HC_DIR,
                        f"{args.arch}__{args.shape}__{args.tag}.json")
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    print(json.dumps(res, indent=2))


if __name__ == "__main__":
    main()
