"""Serving example: batched next-event prediction over live session
prefixes with a KV-cached decode loop — plus the same model served from an
SSM (Mamba2) backbone to show the unified ModelApi.

Run:  PYTHONPATH=src python examples/serve_sessions.py
"""
import numpy as np
import jax

from repro.core import EventDictionary, SessionSequences, sessionize
from repro.data import (generate, LogGenConfig, SessionBatchPipeline,
                        PipelineConfig, lm_vocab_size, NUM_SPECIALS)
from repro.models import ModelConfig, get_model
from repro.serve import Server, ServeConfig


def main():
    log = generate(LogGenConfig(n_users=600, seed=9))
    b = log.batch
    d = EventDictionary.build(b.table, b.name_id)
    codes = np.asarray(d.encode_ids(b.name_id))
    s = sessionize(b.user_id, b.session_id, b.timestamp, codes,
                   b.ip.astype(np.int64), max_sessions=len(b), max_len=1024)
    seqs = SessionSequences.from_sessionized(s)
    vocab = lm_vocab_size(d.alphabet_size)
    pipe = SessionBatchPipeline(seqs, PipelineConfig(seq_len=64,
                                                     global_batch=8))
    prompts = pipe.batch_at(0, 0)["tokens"][:8, :32]

    for family, cfg in [
        ("dense", ModelConfig(name="dense-srv", family="dense", num_layers=2,
                              d_model=128, num_heads=4, num_kv_heads=2,
                              d_ff=256, vocab_size=vocab, dtype="float32",
                              remat="none", max_cache_len=64)),
        ("ssm", ModelConfig(name="ssm-srv", family="ssm", num_layers=2,
                            d_model=128, vocab_size=vocab, d_ff=0,
                            ssm_state=16, ssm_headdim=32, ssm_chunk=16,
                            dtype="float32", remat="none")),
    ]:
        api = get_model(cfg)
        params = api.init(jax.random.PRNGKey(0))
        srv = Server(api, params, ServeConfig(max_new_tokens=8,
                                              temperature=0.8, seed=1))
        gen = srv.generate(prompts)
        print(f"=== {family} backbone ({cfg.name}) ===")
        for i in range(2):
            names = [d.name_of(t - NUM_SPECIALS)
                     if t >= NUM_SPECIALS else "<s>" for t in gen[i]]
            print(f"  req {i}: " + " -> ".join(n.split(":")[-1]
                                               for n in names))
    print("\n(untrained weights — the decode plumbing, batching and KV/SSM "
          "state management are what this example exercises; see "
          "train_behavior_lm.py for a trained model)")


if __name__ == "__main__":
    main()
