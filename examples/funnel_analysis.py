"""Funnel analytics deep-dive (§5.3): session- and user-level reach,
abandonment, A/B-style comparison between client populations, and the
Pallas funnel kernel path.

Run:  PYTHONPATH=src python examples/funnel_analysis.py

``--distributed`` additionally runs the funnel through the distributed
multi-stage pipeline (repro.data.distpipe) on a host-local mesh over every
local device and checks it against the single-host reach. Give the host
more shards with, e.g.:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/funnel_analysis.py --distributed

``--streaming`` replays the same day tick-by-tick through the streaming
fast-data tier (repro.data.streampipe): watermark-closed sessions emit
incremental funnel deltas whose running totals must land bit-equal to the
batch reach after the final flush.
"""
import argparse

import numpy as np

from repro.core import EventDictionary, SessionSequences, sessionize
from repro.data import generate, LogGenConfig
from repro.analytics import (funnel_from_patterns, funnel_reach,
                             funnel_reach_users, abandonment,
                             build_stage_table)
from repro.analytics.summary import client_of_codes
from repro.kernels.funnel_match.ops import reach_counts

FUNNEL = ["*:signup:landing:form:signup_button:click",
          "*:signup:form:form:submit_button:submit",
          "*:signup:follow_suggestions:list:user:follow",
          "*:signup:complete:page::impression"]


def main(distributed: bool = False, streaming: bool = False):
    log = generate(LogGenConfig(n_users=1500, signup_fraction=0.25, seed=5))
    b = log.batch
    d = EventDictionary.build(b.table, b.name_id)
    codes = np.asarray(d.encode_ids(b.name_id))
    s = sessionize(b.user_id, b.session_id, b.timestamp, codes,
                   b.ip.astype(np.int64), max_sessions=len(b), max_len=2048)
    seqs = SessionSequences.from_sessionized(s)
    stages = [d.codes_matching(p) for p in FUNNEL]

    print("=== signup funnel, all clients ===")
    reach = funnel_from_patterns(seqs, d, *FUNNEL)
    for (stage, cnt), pat in zip(reach, FUNNEL):
        print(f"  stage {stage}: {cnt:6d} sessions   {pat}")
    print("abandonment:", [round(x, 3) for x in abandonment(reach)])

    print("\n=== unique users instead of sessions ===")
    print(funnel_reach_users(seqs, stages, d.alphabet_size))

    print("\n=== A/B-style split by client (design-language check) ===")
    client_of, client_names = client_of_codes(d)
    first = np.clip(seqs.symbols[:, 0], 0, d.alphabet_size - 1)
    for cname in ("web", "iphone"):
        cid = client_names.index(cname)
        sel = client_of[first] == cid
        sub = SessionSequences(
            symbols=seqs.symbols[sel], length=seqs.length[sel],
            user_id=seqs.user_id[sel], session_id=seqs.session_id[sel],
            ip=seqs.ip[sel], start_ts=seqs.start_ts[sel],
            duration_s=seqs.duration_s[sel])
        r = funnel_reach(sub, stages, d.alphabet_size)
        done = r[-1][1] / max(r[0][1], 1)
        print(f"  {cname:7s}: reach={[c for _, c in r]} "
              f"completion={done:.2%}")

    print("\n=== Pallas kernel path (TPU-native automaton, interpret) ===")
    table = build_stage_table(stages, d.alphabet_size)
    r = reach_counts(seqs.symbols, seqs.mask(), table, impl="interpret")
    print("  kernel reach:", r)
    assert [c for _, c in r] == [c for _, c in reach]
    print("  matches the jnp reference exactly")

    if distributed:
        import jax
        from repro.data.distpipe import (DistPipelineConfig,
                                         make_distributed_pipeline)
        n_dev = jax.device_count()
        print(f"\n=== distributed pipeline on a host-local (1, {n_dev}) "
              "mesh ===")
        mesh = jax.make_mesh((n_dev,), ("data",))
        cfg = DistPipelineConfig(
            alphabet_size=d.alphabet_size,
            max_sessions_per_shard=-(-len(b) // max(n_dev, 2) * 2),
            max_len=2048)
        pipe = make_distributed_pipeline(mesh, cfg, stages)
        res = pipe(b.user_id, b.session_id, b.timestamp, codes,
                   b.ip.astype(np.int64))
        print(f"  {res.num_sessions()} sessions across {n_dev} shards, "
              f"dropped={res.dropped}")
        print("  pipeline reach:", res.funnel_reach)
        assert [c for _, c in res.funnel_reach] == [c for _, c in reach]
        print("  matches the single-host funnel exactly")

    if streaming:
        from repro.data.streampipe import (StreamConfig, single_host_stream,
                                           split_ticks)
        n_ticks = 8
        print(f"\n=== streaming fast-data tier: {n_ticks} micro-batch "
              "ticks ===")
        ticks = split_ticks(b.timestamp, n_ticks)
        cap = 1 << int(max(len(ix) for ix in ticks) - 1).bit_length()
        scfg = StreamConfig(alphabet_size=d.alphabet_size, max_open=512,
                            max_len=2048, tick_capacity=cap,
                            allowed_lateness_ms=60_000)
        stream = single_host_stream(scfg, stages)
        ip64 = b.ip.astype(np.int64)
        for k, ix in enumerate(ticks):
            r = stream.tick(b.user_id[ix], b.session_id[ix],
                            b.timestamp[ix], codes[ix], ip64[ix])
            print(f"  tick {k}: +{len(ix)} events  closed={r.closed_sessions}"
                  f" open={r.open_sessions} late={r.late_dropped}"
                  f" lag={stream.watermark_lag_ms}ms")
        stream.flush()
        got = stream.result()
        print("  streaming reach:", got.funnel_reach)
        assert [c for _, c in got.funnel_reach] == [c for _, c in reach]
        print("  running totals equal the batch funnel exactly "
              f"({got.num_sessions()} sessions closed over {n_ticks} ticks)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--distributed", action="store_true",
                    help="also run the sharded multi-stage pipeline")
    ap.add_argument("--streaming", action="store_true",
                    help="also replay the day through the streaming tier "
                         "tick-by-tick and check it against the batch reach")
    args = ap.parse_args()
    main(distributed=args.distributed, streaming=args.streaming)
