"""Quickstart: the paper's full pipeline in one script.

Events are generated on simulated production hosts, shipped through the
fault-injected Scribe layer into the warehouse, unified into client events,
dictionary-coded, sessionized, and queried — the §5 analytics suite.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import numpy as np

from repro.core import (EventCatalog, EventDictionary, SessionSequences,
                        sessionize, varint)
from repro.data import generate, LogGenConfig, deliver_batch
from repro.analytics import (count_pattern, funnel_from_patterns,
                             abandonment, summarize, NGramLM,
                             top_collocations)


def main():
    print("=== 1. generate client events on production hosts ===")
    log = generate(LogGenConfig(n_users=800, seed=0))
    batch = log.batch
    print(f"{len(batch)} events, {len(batch.table)} distinct event names")

    print("\n=== 2. scribe delivery (crash-injected) -> warehouse ===")
    with tempfile.TemporaryDirectory() as td:
        stats = deliver_batch(batch, os.path.join(td, "staging"),
                              os.path.join(td, "warehouse"), crash_prob=0.05)
        print(f"delivered {stats['messages']} msgs exactly-once "
              f"({stats['dupes']} retry duplicates absorbed by the mover)")

    print("\n=== 3. daily dictionary job (frequency -> code points) ===")
    d = EventDictionary.build(batch.table, batch.name_id)
    d.verify()
    for code in range(3):
        print(f"  code {code:3d} <- {d.name_of(code)}  "
              f"(count {d.count_of_code(code)})")

    print("\n=== 4. sessionize + materialize session sequences ===")
    codes = np.asarray(d.encode_ids(batch.name_id))
    s = sessionize(batch.user_id, batch.session_id, batch.timestamp, codes,
                   batch.ip.astype(np.int64), max_sessions=len(batch),
                   max_len=2048)
    seqs = SessionSequences.from_sessionized(s)
    raw = varint.raw_log_size_bytes(
        len(batch), float(np.mean([len(n) for n in batch.table.names])))
    enc = varint.encoded_size_bytes(seqs) + len(seqs) * 24
    print(f"{len(seqs)} sessions; sequences are {raw / enc:.1f}x smaller "
          f"than raw logs (paper: ~50x)")
    print("example sequence:", repr(seqs.as_unicode_strings()[0][:40]), "...")

    print("\n=== 5. analytics over the compact sequences (§5) ===")
    total, containing = count_pattern(seqs, d, "*:impression")
    clicks, _ = count_pattern(seqs, d, "*:click")
    print(f"impressions={total} in {containing} sessions; "
          f"CTR proxy={clicks / total:.3f}")

    reach = funnel_from_patterns(
        seqs, d,
        "*:signup:landing:form:signup_button:click",
        "*:signup:form:form:submit_button:submit",
        "*:signup:follow_suggestions:list:user:follow",
        "*:signup:complete:page::impression")
    print("signup funnel reach:", reach)
    print("per-stage abandonment:",
          [round(x, 2) for x in abandonment(reach)])

    rep = summarize(seqs, d)
    print("sessions by client:", rep.sessions_by_client)
    print("duration histogram:", rep.duration_histogram)

    print("\n=== 6. user modeling (§5.4) ===")
    h1 = NGramLM.fit(seqs, 1, d.alphabet_size).cross_entropy(seqs)
    h2 = NGramLM.fit(seqs, 2, d.alphabet_size).cross_entropy(seqs)
    print(f"unigram H={h1:.2f} bits, bigram H={h2:.2f} bits "
          f"-> {h1 - h2:.2f} bits of temporal signal")
    top = top_collocations(seqs, d, k=3)
    for t in top:
        print(f"  collocation g2={t['g2']:9.1f}: {t['first']} -> {t['second']}")

    print("\n=== 7. always-current event catalog (§4.3) ===")
    cat = EventCatalog.build(d, batch)
    print("catalog coverage:", cat.coverage())
    entry = cat.search("*:signup:*")[0]
    print(f"sample entry: {entry.name} code={entry.code} count={entry.count}")


if __name__ == "__main__":
    main()
