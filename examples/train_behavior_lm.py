"""End-to-end training driver: behaviour LM over session sequences (§5.4
extended — the paper's n-gram user models upgraded to a neural LM).

Pipeline: generate logs -> dictionary -> sessionize -> packed LM batches ->
train with checkpoint/restart -> compare perplexity against the paper's
n-gram baselines -> serve next-action predictions.

Presets:
  quick  (default) ~1M params, 120 steps — minutes on this CPU container.
  paper  ~100M params (configs/paper.py FULL), 300 steps — the real run;
         sized for accelerators, works on CPU if you are patient.

Run:  PYTHONPATH=src python examples/train_behavior_lm.py [--preset quick]
"""
import argparse
import os

import numpy as np
import jax

from repro.core import EventDictionary, SessionSequences, sessionize
from repro.data import (generate, LogGenConfig, SessionBatchPipeline,
                        PipelineConfig, lm_vocab_size)
from repro.analytics import NGramLM
from repro.configs import paper
from repro.models import get_model
from repro.train import OptConfig, Trainer, TrainerConfig
from repro.serve import Server, ServeConfig


def build_corpus(n_users: int, seed: int = 0):
    log = generate(LogGenConfig(n_users=n_users, seed=seed))
    b = log.batch
    d = EventDictionary.build(b.table, b.name_id)
    codes = np.asarray(d.encode_ids(b.name_id))
    s = sessionize(b.user_id, b.session_id, b.timestamp, codes,
                   b.ip.astype(np.int64), max_sessions=len(b), max_len=2048)
    return d, SessionSequences.from_sessionized(s)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["quick", "paper"], default="quick")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/behavior_lm_ckpt")
    args = ap.parse_args()

    users = 1200 if args.preset == "quick" else 6000
    d, seqs = build_corpus(users)
    vocab = lm_vocab_size(d.alphabet_size)
    print(f"corpus: {len(seqs)} sessions, alphabet {d.alphabet_size}")

    # paper-faithful baselines (§5.4)
    h1 = NGramLM.fit(seqs, 1, d.alphabet_size).cross_entropy(seqs)
    h2 = NGramLM.fit(seqs, 2, d.alphabet_size).cross_entropy(seqs)
    print(f"n-gram baselines: H1={h1:.3f} H2={h2:.3f} bits/event")

    if args.preset == "paper":
        cfg = paper.FULL.with_(vocab_size=vocab)
        seq_len, batch, steps = 512, 8, args.steps or 300
        lr = 3e-4
    else:
        cfg = paper.SMOKE.with_(vocab_size=vocab, max_cache_len=256)
        seq_len, batch, steps = 128, 8, args.steps or 120
        lr = 1e-3

    pipe = SessionBatchPipeline(seqs, PipelineConfig(seq_len=seq_len,
                                                     global_batch=batch))
    api = get_model(cfg)
    n_params = sum(t.size for t in
                   jax.tree.leaves(api.init(jax.random.PRNGKey(0))))
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params), "
          f"{pipe.batches_per_epoch()} batches/epoch, {steps} steps")

    tr = Trainer(api, OptConfig(lr=lr, warmup_steps=20, total_steps=steps),
                 TrainerConfig(total_steps=steps, checkpoint_every=50,
                               log_every=20, checkpoint_dir=args.ckpt),
                 log_fn=lambda s, m: print(
                     f"  step {s:4d} loss={m['loss']:.3f} "
                     f"gnorm={m['grad_norm']:.2f} {m['steps_per_s']:.2f} st/s"))
    out = tr.run(pipe)

    final_nats = out["history"][-1][1]["loss"]
    final_bits = final_nats / np.log(2)
    print(f"\nneural LM: {final_bits:.3f} bits/token "
          f"(n-gram H2 baseline {h2:.3f}; BOS/EOS tokens included)")

    print("\nnext-action predictions for 4 live sessions:")
    srv = Server(api, out["state"]["params"], ServeConfig(max_new_tokens=6))
    prompts = pipe.batch_at(0, 0)["tokens"][:4, :32]
    gen = srv.generate(prompts)
    from repro.data.pipeline import NUM_SPECIALS
    for i in range(4):
        names = [d.name_of(t - NUM_SPECIALS) if t >= NUM_SPECIALS else "<s>"
                 for t in gen[i]]
        print(f"  session {i}: " + " -> ".join(
            n.split(":")[-1] for n in names))


if __name__ == "__main__":
    main()
