import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (EventDictionary, NameTable, SessionSequences,
                        sessionize)
from repro.core.oracle import (count_events_oracle, funnel_oracle,
                               ngram_counts_oracle)
from repro.analytics import (count_events, count_pattern, rollup_counts,
                             funnel_reach, abandonment, NGramLM,
                             ngram_counts, unpack_key, collocations,
                             top_collocations, summarize)
from repro.core.sessionize import PAD_CODE


def _seqs_from_rows(rows, alphabet):
    s, max_len = len(rows), max(len(r) for r in rows)
    symbols = np.full((s, max_len), PAD_CODE, np.int32)
    for i, r in enumerate(rows):
        symbols[i, :len(r)] = r
    return SessionSequences(
        symbols=symbols, length=np.array([len(r) for r in rows], np.int32),
        user_id=np.arange(s, dtype=np.int64) % 3,
        session_id=np.arange(s, dtype=np.int64),
        ip=np.zeros(s, np.int64), start_ts=np.zeros(s, np.int64),
        duration_s=np.full(s, 100, np.int32))


ROWS = st.lists(st.lists(st.integers(0, 19), min_size=1, max_size=30),
                min_size=1, max_size=20)


@given(ROWS, st.sets(st.integers(0, 19), min_size=1, max_size=5))
@settings(max_examples=40, deadline=None)
def test_count_events_matches_oracle(rows, targets):
    seqs = _seqs_from_rows(rows, 20)
    tot, cont = count_events(seqs, sorted(targets), 20)
    sessions = [dict(symbols=r) for r in rows]
    otot, ocont = count_events_oracle(sessions, sorted(targets))
    assert (tot, cont) == (otot, ocont)


@given(ROWS, st.lists(st.sets(st.integers(0, 19), min_size=1, max_size=3),
                      min_size=1, max_size=4))
@settings(max_examples=40, deadline=None)
def test_funnel_matches_oracle(rows, stages):
    seqs = _seqs_from_rows(rows, 20)
    stages = [sorted(s) for s in stages]
    reach = funnel_reach(seqs, stages, 20)
    want = funnel_oracle([dict(symbols=r) for r in rows], stages)
    assert [c for _, c in reach] == want
    # monotone non-increasing reach
    counts = [c for _, c in reach]
    assert all(a >= b for a, b in zip(counts, counts[1:]))


def test_abandonment():
    assert abandonment([(0, 100), (1, 60), (2, 30)]) == [0.4, 0.5]


@given(ROWS, st.integers(1, 3))
@settings(max_examples=30, deadline=None)
def test_ngram_counts_match_oracle(rows, n):
    seqs = _seqs_from_rows(rows, 20)
    keys, counts = ngram_counts(seqs, n, 20)
    want = ngram_counts_oracle([dict(symbols=r) for r in rows], n)
    got = {unpack_key(int(k), n, 20): int(c) for k, c in zip(keys, counts)}
    assert got == want


def test_perplexity_uniform_data():
    rng = np.random.default_rng(0)
    rows = [rng.integers(0, 16, 50).tolist() for _ in range(40)]
    seqs = _seqs_from_rows(rows, 16)
    lm = NGramLM.fit(seqs, 1, 16)
    # iid uniform over 16 symbols -> ~4 bits/symbol
    assert abs(lm.cross_entropy(seqs) - 4.0) < 0.2


def test_bigram_model_beats_unigram_on_markov_data():
    rng = np.random.default_rng(1)
    rows = []
    for _ in range(60):
        seq = [int(rng.integers(0, 8))]
        for _ in range(40):  # strongly deterministic chain
            seq.append((seq[-1] + (1 if rng.random() < 0.9 else 3)) % 8)
        rows.append(seq)
    seqs = _seqs_from_rows(rows, 8)
    h1 = NGramLM.fit(seqs, 1, 8).cross_entropy(seqs)
    h2 = NGramLM.fit(seqs, 2, 8).cross_entropy(seqs)
    assert h2 < h1 - 1.0  # big temporal signal


def test_planted_collocation_found():
    rng = np.random.default_rng(2)
    rows = []
    for _ in range(50):
        seq = rng.integers(0, 20, 30).tolist()
        for j in range(0, 28, 7):   # plant "5 followed by 17"
            seq[j], seq[j + 1] = 5, 17
        rows.append(seq)
    seqs = _seqs_from_rows(rows, 20)
    top = collocations(seqs, 20, min_count=5)[0]
    assert (top.first, top.second) == (5, 17)
    assert top.pmi > 0


def test_rollup_totals_consistent():
    table = NameTable([f"web:p{i}:s:c:e:act_{i % 3}" for i in range(9)])
    ids = np.arange(9, dtype=np.int32).repeat(3)
    d = EventDictionary.build(table, ids)
    tables = rollup_counts(ids, d)
    for t in tables:
        assert sum(t.values()) == len(ids)   # every level partitions events
    assert len(tables[0]) >= len(tables[-1])  # coarser => fewer groups


def test_summary_buckets():
    rows = [[1, 2], [3]]
    seqs = _seqs_from_rows(rows, 4)
    rep = summarize(seqs)
    assert sum(rep.duration_histogram.values()) == len(rows)
    assert rep.totals["sessions"] == 2
