"""Import-graph smoke test + back-compat shim identity.

Imports every module under ``repro.*`` so a missing-module regression
(like the seed's ``repro.dist`` hole, which killed 9 test modules at
collection) fails one obvious test instead, and asserts the
``repro.core.distributed`` / ``repro.launch.mesh`` shims re-export the
exact objects now living in ``repro.dist``.
"""
import importlib
import os
import pathlib

import pytest

SRC = pathlib.Path(__file__).resolve().parent.parent / "src"


def _all_repro_modules():
    mods = []
    for py in sorted((SRC / "repro").rglob("*.py")):
        rel = py.relative_to(SRC).with_suffix("")
        parts = list(rel.parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        mods.append(".".join(parts))
    return mods


MODULES = _all_repro_modules()


def test_module_list_is_nontrivial():
    assert "repro.dist.sharding" in MODULES
    assert "repro.core.distributed" in MODULES
    assert len(MODULES) > 50


@pytest.mark.parametrize("mod", MODULES)
def test_module_imports(mod):
    # dryrun.py exports XLA_FLAGS for its own subprocesses at import time;
    # keep that out of this process's environment.
    before = os.environ.get("XLA_FLAGS")
    try:
        importlib.import_module(mod)
    finally:
        if os.environ.get("XLA_FLAGS") != before:
            if before is None:
                os.environ.pop("XLA_FLAGS", None)
            else:
                os.environ["XLA_FLAGS"] = before


def test_core_distributed_shim_reexports_identical_objects():
    from repro.core import distributed as shim
    from repro.dist import collectives
    for name in ("mix64", "shard_of_user", "bucket_by_destination",
                 "keyed_all_to_all", "make_distributed_sessionize",
                 "make_distributed_histogram"):
        assert getattr(shim, name) is getattr(collectives, name), name
    # the old private names still resolve; _bucket_by_destination keeps its
    # original 2-tuple (buckets, dropped) contract
    assert shim._mix64 is collectives.mix64
    import jax.numpy as jnp
    cols = dict(v=jnp.arange(4))
    dest = jnp.array([0, 1, 0, 1], jnp.int32)
    buckets, dropped = shim._bucket_by_destination(cols, dest, 2, 2)
    assert buckets["v"].shape == (2, 2) and int(dropped) == 0


def test_launch_mesh_shim_reexports_identical_objects():
    from repro.launch import mesh as shim
    from repro.dist import mesh as dist_mesh
    assert shim.make_host_mesh is dist_mesh.make_host_mesh
    assert shim.make_production_mesh is dist_mesh.make_production_mesh


def test_moe_uses_the_shared_bucketing_primitive():
    from repro.models import moe
    from repro.dist.collectives import bucket_by_destination
    assert moe._bucket is bucket_by_destination
