import os
import sys

# Tests run on the single real CPU device — the 512-device override is
# strictly dryrun.py's (subprocess tests set their own XLA_FLAGS).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
