import os
import sys
from types import SimpleNamespace

import numpy as np
import pytest

# Tests run on the single real CPU device — the 512-device override is
# strictly dryrun.py's (subprocess tests set their own XLA_FLAGS).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The paper's signup funnel (§5.3), as namespace glob patterns over the
# loggen event universe — shared by the batch and streaming equivalence
# tests so both see the identical stage spec.
LOGGEN_FUNNEL = [
    "*:signup:landing:form:signup_button:click",
    "*:signup:form:form:submit_button:submit",
    "*:signup:follow_suggestions:list:user:follow",
    "*:signup:complete:page::impression",
]


@pytest.fixture(scope="session", params=[dict(n_users=250, seed=123)],
                ids=lambda p: f"loggen-u{p['n_users']}-s{p['seed']}")
def loggen_corpus(request):
    """One shared loggen day (events + dictionary codes + funnel stages).

    Session-scoped and parametrized so the batch (test_distpipe) and
    streaming (test_streampipe) equivalence tests consume byte-identical
    inputs without regenerating the corpus per test.
    """
    from repro.core import EventDictionary
    from repro.data import LogGenConfig, generate
    p = request.param
    log = generate(LogGenConfig(n_users=p["n_users"], seed=p["seed"],
                                signup_fraction=0.25))
    b = log.batch
    d = EventDictionary.build(b.table, b.name_id)
    codes = np.asarray(d.encode_ids(b.name_id), np.int32)
    return SimpleNamespace(
        user_id=b.user_id, session_id=b.session_id, timestamp=b.timestamp,
        code=codes, ip=b.ip.astype(np.int64),
        alphabet_size=d.alphabet_size, dictionary=d,
        stages=[d.codes_matching(pat) for pat in LOGGEN_FUNNEL],
        n_events=len(b))

# The container image has no ``hypothesis``; alias in the deterministic
# mini-implementation so the property tests still run (the real package
# wins whenever it is importable, e.g. in CI).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback
    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies
