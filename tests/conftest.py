import os
import sys

# Tests run on the single real CPU device — the 512-device override is
# strictly dryrun.py's (subprocess tests set their own XLA_FLAGS).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The container image has no ``hypothesis``; alias in the deterministic
# mini-implementation so the property tests still run (the real package
# wins whenever it is importable, e.g. in CI).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback
    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies
