"""Serving subsystem tests: decode-loop correctness fixes, ragged
prefill-mask equivalence, DecodeState family matrix, and
continuous-batching scheduler invariants.

Three kinds of model drive these:

* the real smoke behaviour LM (dense) for numerical properties — greedy
  determinism and the padded-vs-trimmed bit-equality the per-row position
  masking guarantees;
* one real smoke model per registry family (the 7-arch matrix) asserting
  the unified DecodeState contract: scheduler output bit-equal to the
  ``Server.generate_batch`` fixed-batch oracle, admit/evict/backfill
  invariants, and zero retraces after warmup on a host-local mesh;
* a deterministic stub ModelApi (an "echo+1, EOS after k steps" machine
  with a real KV-cache-shaped state) for machinery properties — exact
  decode-step counts, EOS freezing, admission accounting.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models.registry import get_model, ModelApi, ServeCaps
from repro.data.pipeline import PAD_ID, EOS_ID
from repro.dist import make_host_mesh, REPLICATED
from repro.serve import (Server, ServeConfig, ContinuousScheduler,
                         ServeMetrics, prompt_lengths,
                         BlockPool, blocks_for)
from repro.serve import SchedulerConfig as _SchedulerConfig

VOCAB = 64


def SchedulerConfig(**kw):
    """Every scheduler test runs with ``debug=True``: the pool re-checks
    its allocator invariants after each evict/preempt, so a refcount or
    free-list corruption fails the test that caused it, not a later one."""
    kw.setdefault("debug", True)
    return _SchedulerConfig(**kw)

# one representative smoke arch per family (+ the paper LM): the 7-arch
# serving matrix every DecodeState implementation is exercised through
MATRIX_ARCHS = ("behavior-lm-100m", "qwen3-0.6b", "olmoe-1b-7b",
                "mamba2-370m", "zamba2-7b", "whisper-tiny",
                "llama-3.2-vision-11b")


@pytest.fixture(scope="module")
def dense():
    cfg = smoke_config("behavior-lm-100m").with_(vocab_size=VOCAB,
                                                 max_cache_len=64)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return api, params


@pytest.fixture(scope="module")
def family_model():
    """Per-arch (api, params) cache shared across the matrix tests."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = smoke_config(arch).with_(vocab_size=VOCAB,
                                           max_cache_len=64)
            api = get_model(cfg)
            cache[arch] = (api, api.init(jax.random.PRNGKey(0)))
        return cache[arch]
    return get


def _family_extra(cfg, rng):
    """One request's stub-frontend encoder inputs, or None."""
    if cfg.family == "encdec":
        return dict(frames=rng.standard_normal(
            (cfg.n_frames, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        return dict(patches=rng.standard_normal(
            (cfg.n_patches, cfg.vision_dim)).astype(np.float32))
    return None


# ---------------------------------------------------------------------------
# Stub model: next token = clip(prev + 1), EOS after `eos_after` decodes.
# State leaves are (X, B, ...) so the scheduler's generic row insert works;
# k/v are KV-cache-shaped so the paged block scatter works too, and
# decode passes unknown state keys (the block table) through.
# ---------------------------------------------------------------------------

def _stub_api(eos_after: int = 3, family: str = "dense",
              caps: ServeCaps | None = None) -> ModelApi:
    cfg = smoke_config("behavior-lm-100m").with_(
        vocab_size=VOCAB, max_cache_len=64, family=family)

    def _next(tok):
        return jnp.clip(tok + 1, 4, VOCAB - 1).astype(jnp.int32)

    def prefill(p, b):
        toks = jnp.asarray(b["tokens"])
        bsz, l = toks.shape
        lengths = b.get("lengths")
        if lengths is None:
            last, idx = toks[:, -1], l
        else:
            li = jnp.asarray(lengths, jnp.int32)
            last, idx = toks[jnp.arange(bsz), li - 1], li
        state = dict(k=jnp.zeros((1, bsz, 1, cfg.max_cache_len, 1)),
                     v=jnp.zeros((1, bsz, 1, cfg.max_cache_len, 1)),
                     gen=jnp.zeros((1, bsz), jnp.int32))
        return 10.0 * jax.nn.one_hot(_next(last), VOCAB), state, idx

    def decode_step(p, tok, state, idx):
        gen = state["gen"] + 1
        nxt = jnp.where(gen[0] >= eos_after, EOS_ID, _next(tok))
        return 10.0 * jax.nn.one_hot(nxt, VOCAB), dict(state, gen=gen)

    api = ModelApi(cfg=cfg, rules=REPLICATED, mesh=None,
                   init=lambda key: {}, axes=lambda: {},
                   loss=None, prefill=prefill, decode_step=decode_step,
                   batch_keys=("tokens",))
    if caps is not None:
        api.caps = caps
    return api


def _stub_expected(prompt, budget, eos_after):
    """The stub's deterministic output for one request."""
    out = [min(int(prompt[-1]) + 1, VOCAB - 1)]
    for k in range(1, budget):
        if k >= eos_after:
            out.append(EOS_ID)
            break
        out.append(min(out[-1] + 1, VOCAB - 1))
    return np.array(out[:budget], np.int32)


def _rand_prompts(rng, n, lo=3, hi=15):
    return [rng.integers(4, VOCAB, int(rng.integers(lo, hi))).astype(np.int32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# prompt length derivation
# ---------------------------------------------------------------------------

def test_prompt_lengths():
    p = np.array([[5, 6, 7, 0, 0],
                  [5, 6, 7, 8, 9],
                  [0, 0, 0, 0, 0]], np.int32)
    assert prompt_lengths(p).tolist() == [3, 5, 1]


# ---------------------------------------------------------------------------
# Server: greedy determinism + padded/trimmed bit-equality (real model)
# ---------------------------------------------------------------------------

def test_greedy_decode_deterministic(dense):
    api, params = dense
    srv = Server(api, params, ServeConfig(max_new_tokens=6))
    rng = np.random.default_rng(0)
    prompts = np.full((3, 12), PAD_ID, np.int32)
    for i, l in enumerate((12, 7, 4)):
        prompts[i, :l] = rng.integers(4, VOCAB, l)
    g1 = srv.generate(prompts)
    g2 = srv.generate(prompts)
    assert g1.shape == (3, 6)
    assert np.array_equal(g1, g2)


def test_padded_prompt_decodes_bit_equal_to_trimmed(dense):
    api, params = dense
    srv = Server(api, params, ServeConfig(max_new_tokens=6))
    rng = np.random.default_rng(1)
    for l in (3, 5, 9):
        prompts = np.full((2, 12), PAD_ID, np.int32)
        prompts[0] = rng.integers(4, VOCAB, 12)
        prompts[1, :l] = rng.integers(4, VOCAB, l)
        padded = srv.generate(prompts)
        trimmed = srv.generate(prompts[1:2, :l])
        assert np.array_equal(padded[1], trimmed[0]), l


@pytest.mark.parametrize("arch", ["mamba2-370m", "zamba2-7b"])
def test_ragged_ssm_prefill_bit_equals_trimmed(arch):
    """The recurrent state must be frozen across right-padding: a padded
    ragged prefill hands decode the exact state of the trimmed prompt
    (dt masked to 0 + ragged-correct conv tails)."""
    cfg = smoke_config(arch).with_(vocab_size=VOCAB, max_cache_len=64)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(12)
    n, S = 5, 8
    row = rng.integers(4, VOCAB, n).astype(np.int32)
    padded = np.zeros((1, S), np.int32)
    padded[0, :n] = row
    lg_p, st_p, idx_p = api.prefill(params, dict(
        tokens=jnp.asarray(padded), lengths=jnp.asarray([n], jnp.int32)))
    lg_t, st_t, idx_t = api.prefill(params, dict(
        tokens=jnp.asarray(row[None])))
    assert np.array_equal(np.asarray(lg_p), np.asarray(lg_t))
    assert int(np.asarray(idx_p)[0]) == idx_t == n
    # recurrent leaves (mamba conv tails + SSM heads) must be bit-equal;
    # attention KV (hybrid) only up to n — pads beyond are masked
    tree = st_p if arch == "mamba2-370m" else st_p["mamba"]
    oracle = st_t if arch == "mamba2-370m" else st_t["mamba"]
    for key in tree:
        np.testing.assert_array_equal(np.asarray(tree[key]),
                                      np.asarray(oracle[key]), err_msg=key)
    l2p, _ = api.decode_step(params, jnp.argmax(lg_p, -1).astype(jnp.int32),
                             st_p, jnp.asarray(idx_p))
    l2t, _ = api.decode_step(params, jnp.argmax(lg_t, -1).astype(jnp.int32),
                             st_t, jnp.int32(n))
    assert np.array_equal(np.asarray(l2p), np.asarray(l2t))


# ---------------------------------------------------------------------------
# RNG regression: the prefill-token draw must come from a split subkey,
# independent of later decode draws; different seeds differ at token 0.
# ---------------------------------------------------------------------------

def test_temperature_seeds_differ_at_token0(dense):
    api, params = dense
    rng = np.random.default_rng(2)
    prompts = rng.integers(4, VOCAB, (4, 8)).astype(np.int32)
    g0 = Server(api, params, ServeConfig(
        max_new_tokens=3, temperature=2.0, seed=0)).generate(prompts)
    g1 = Server(api, params, ServeConfig(
        max_new_tokens=3, temperature=2.0, seed=1)).generate(prompts)
    assert (g0[:, 0] != g1[:, 0]).any()
    # same seed stays reproducible
    g0b = Server(api, params, ServeConfig(
        max_new_tokens=3, temperature=2.0, seed=0)).generate(prompts)
    assert np.array_equal(g0, g0b)


def test_batch_path_first_sample_uses_split_subkey():
    # the ssm smoke model through the explicit fixed-batch oracle path
    cfg = smoke_config("mamba2-370m").with_(vocab_size=VOCAB)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(3).integers(
        4, VOCAB, (2, 8)).astype(np.int32)
    temp, seed = 2.0, 0
    srv = Server(api, params, ServeConfig(
        max_new_tokens=2, temperature=temp, seed=seed))
    got = srv.generate_batch(prompts)[:, 0]
    # same jitted prefill the server used, so logits match bitwise
    logits, _, _ = srv._prefill(params, dict(
        tokens=jnp.asarray(prompts),
        lengths=jnp.asarray(prompt_lengths(prompts))))
    _, sub = jax.random.split(jax.random.PRNGKey(seed))
    expected = jax.random.categorical(sub, logits / temp, axis=-1)
    assert np.array_equal(got, np.asarray(expected))
    # and NOT the pre-fix draw from the raw (reused) parent key
    buggy = jax.random.categorical(
        jax.random.PRNGKey(seed), logits / temp, axis=-1)
    if not np.array_equal(np.asarray(buggy), np.asarray(expected)):
        assert not np.array_equal(got, np.asarray(buggy))


# ---------------------------------------------------------------------------
# off-by-one + EOS short-circuit (exact decode counts via the stub)
# ---------------------------------------------------------------------------

def test_no_discarded_decode_step():
    api = _stub_api(eos_after=99)
    srv = Server(api, {}, ServeConfig(max_new_tokens=4))
    out = srv.generate_batch(np.full((1, 5), 7, np.int32))
    # 4 tokens = 1 prefill sample + exactly 3 decodes (the old loop ran 4)
    assert srv.decode_calls == 3
    assert out.tolist() == [[8, 9, 10, 11]]


def test_eos_short_circuits_batch_loop():
    api = _stub_api(eos_after=2)
    srv = Server(api, {}, ServeConfig(max_new_tokens=8))
    out = srv.generate_batch(np.full((1, 5), 7, np.int32))
    # tokens: 8, 9, EOS then frozen — only 2 decodes ever launched
    assert srv.decode_calls == 2
    assert out.tolist() == [[8, 9, EOS_ID] + [EOS_ID] * 5]


def test_scheduler_decode_step_counts():
    api = _stub_api(eos_after=99)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=6))
    sched.submit(np.full(5, 7, np.int32))
    sched.run()
    assert sched.decode_steps == 5          # 6 tokens, first from prefill
    # budget 1: finished at admission, no decode at all
    before = sched.decode_steps
    sched.submit(np.full(5, 7, np.int32), max_new_tokens=1)
    out = sched.run()
    assert sched.decode_steps == before
    assert out[1].tolist() == [8]


# ---------------------------------------------------------------------------
# scheduler: admit/evict/backfill invariants + no recompilation after warmup
# ---------------------------------------------------------------------------

def test_scheduler_stream_invariants_and_jit_cache_hits():
    eos_after = 4
    api = _stub_api(eos_after=eos_after)
    mesh = make_host_mesh(1, 1)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8, 16), max_new_tokens=6), mesh=mesh)
    rng = np.random.default_rng(4)

    # warmup: one request per bucket
    w1, w2 = np.full(6, 9, np.int32), np.full(12, 9, np.int32)
    sched.submit(w1), sched.submit(w2)
    sched.run()
    warm = dict(sched.trace_counts)
    assert warm["prefill"] == 2             # one trace per bucket
    assert warm["decode"] == 1
    assert warm["insert"] == 1

    # stream of 8 = 4x slot count, variable lengths across both buckets
    prompts = _rand_prompts(rng, 8, lo=3, hi=16)
    rids = [sched.submit(p) for p in prompts]
    max_active = 0
    while sched.num_active or sched.num_pending:
        sched.step()
        max_active = max(max_active, sched.num_active)
    outs = sched.run()

    assert dict(sched.trace_counts) == warm   # jit cache hits only
    assert max_active <= 2                    # never exceeds the slot table
    for rid, p in zip(rids, prompts):
        np.testing.assert_array_equal(
            outs[rid], _stub_expected(p, 6, eos_after), err_msg=str(rid))


def test_scheduler_metrics_lifecycle():
    api = _stub_api(eos_after=3)
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    m = ServeMetrics(clock=clock)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=4), metrics=m)
    for p in _rand_prompts(np.random.default_rng(5), 4, lo=3, hi=8):
        sched.submit(p)
    sched.run()
    s = m.summary()
    assert s["requests"] == 4
    assert s["tokens"] == sum(r.tokens for r in m.requests.values())
    assert s["tokens_per_sec"] > 0
    assert s["p99_latency_s"] >= s["p50_latency_s"] > 0
    for r in m.requests.values():
        assert r.submit < r.admit <= r.first_token < r.finish


def test_scheduler_real_model_matches_single_request(dense):
    """Continuous slots vs one-request-at-a-time: greedy outputs agree."""
    api, params = dense
    sched = ContinuousScheduler(api, params, SchedulerConfig(
        batch=3, buckets=(8, 16), max_new_tokens=5))
    prompts = _rand_prompts(np.random.default_rng(6), 7, lo=3, hi=16)
    rids = [sched.submit(p) for p in prompts]
    outs = sched.run()
    solo = ContinuousScheduler(api, params, SchedulerConfig(
        batch=1, buckets=(8, 16), max_new_tokens=5))
    for rid, p in zip(rids[:3], prompts[:3]):
        srid = solo.submit(p)
        np.testing.assert_array_equal(solo.run()[srid], outs[rid])


def test_bounded_state_requires_positive_cache_len():
    """A position-bounded KV family misconfigured with max_cache_len=0
    must fail loudly at construction, not decode into an empty cache."""
    api = _stub_api()
    api.cfg = api.cfg.with_(max_cache_len=0)
    with pytest.raises(ValueError, match="max_cache_len"):
        ContinuousScheduler(api, {}, SchedulerConfig(batch=2, buckets=(8,)))


def test_scheduler_rejects_unknown_state_kind_loudly():
    """No silent fixed-batch fallback: a family whose registry caps name
    an unknown DecodeState kind fails at construction."""
    api = _stub_api(caps=ServeCaps(state_kind="mystery"))
    with pytest.raises(ValueError, match="unknown serving family"):
        ContinuousScheduler(api, {}, SchedulerConfig(batch=2, buckets=(8,)))


# ---------------------------------------------------------------------------
# DecodeState family matrix: all 7 registry architectures serve through the
# continuous scheduler — bit-equal to the fixed-batch oracle, admit/evict/
# backfill invariants, zero retraces after warmup on a host-local mesh.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", MATRIX_ARCHS)
def test_family_matrix_continuous_serving(arch, family_model):
    api, params = family_model(arch)
    cfg = api.cfg
    mesh = make_host_mesh(1, 1)
    budget = 4
    sched = ContinuousScheduler(api, params, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=budget), mesh=mesh)
    rng = np.random.default_rng(13)

    # warmup stream, then a 3x-slot-count backfill stream
    warm_prompts = _rand_prompts(rng, 2, lo=3, hi=9)
    stream_prompts = _rand_prompts(rng, 6, lo=3, hi=9)
    prompts = warm_prompts + stream_prompts
    extras = [_family_extra(cfg, rng) for _ in prompts]

    rids = [sched.submit(p, extra=e)
            for p, e in zip(warm_prompts, extras[:2])]
    outs = dict(sched.run())
    warm_traces = dict(sched.trace_counts)

    rids += [sched.submit(p, extra=e)
             for p, e in zip(stream_prompts, extras[2:])]
    max_active = 0
    while sched.num_active or sched.num_pending:
        sched.step()
        max_active = max(max_active, sched.num_active)
    outs.update(sched.run())

    # invariants: slot table never overflows, queue fully drained, every
    # request terminated by budget or EOS, zero retraces after warmup
    assert dict(sched.trace_counts) == warm_traces, arch
    assert max_active <= 2
    assert sched.num_active == 0 and sched.num_pending == 0
    for rid in rids:
        toks = outs[rid]
        assert len(toks) == budget or toks[-1] == EOS_ID

    # bit-equality against the fixed-batch oracle over the same rows
    srv = Server(api, params, ServeConfig(max_new_tokens=budget))
    width = max(len(p) for p in prompts)
    rect = np.zeros((len(prompts), width), np.int32)
    for i, p in enumerate(prompts):
        rect[i, :len(p)] = p
    extra = None
    if extras[0] is not None:
        extra = {k: np.stack([e[k] for e in extras])
                 for k in extras[0]}
    oracle = srv.generate_batch(rect, extra)
    for i, rid in enumerate(rids):
        got = outs[rid]
        np.testing.assert_array_equal(
            got, oracle[i][:len(got)], err_msg=f"{arch} row {i}")


@pytest.mark.parametrize("arch", ["whisper-tiny", "llama-3.2-vision-11b"])
def test_cross_families_validate_request_extras(arch, family_model):
    api, params = family_model(arch)
    sched = ContinuousScheduler(api, params, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=2))
    with pytest.raises(ValueError, match="requires extras"):
        sched.submit(np.full(4, 7, np.int32))          # missing frames
    key = "frames" if api.cfg.family == "encdec" else "patches"
    with pytest.raises(ValueError, match="shape"):
        sched.submit(np.full(4, 7, np.int32),
                     extra={key: np.zeros((3, 3), np.float32)})


def test_token_family_rejects_stray_extras(dense):
    api, params = dense
    sched = ContinuousScheduler(api, params, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=2))
    with pytest.raises(ValueError, match="requires extras"):
        sched.submit(np.full(4, 7, np.int32),
                     extra=dict(frames=np.zeros((2, 2), np.float32)))


# ---------------------------------------------------------------------------
# paged KV: block pool allocator
# ---------------------------------------------------------------------------

def _tiny_pool(num_blocks=6, block_size=4):
    return BlockPool(num_blocks=num_blocks, block_size=block_size,
                     num_kv_heads=1, head_dim=2, num_layers=1)


def test_blocks_for():
    assert blocks_for(0, 8) == 0
    assert blocks_for(1, 8) == 1
    assert blocks_for(8, 8) == 1
    assert blocks_for(9, 8) == 2


def test_block_pool_alloc_free_reuse_cycles():
    pool = _tiny_pool(num_blocks=6)
    assert (pool.capacity, pool.available, pool.live_blocks) == (6, 6, 0)
    pool.reserve(4)
    assert pool.available == 2            # reservation sets capacity aside
    ids = [pool.take() for _ in range(4)]
    assert len(set(ids)) == 4 and all(1 <= i <= 6 for i in ids)  # 0 = trash
    assert (pool.available, pool.live_blocks) == (2, 4)
    pool.free(ids[:2])
    assert (pool.available, pool.live_blocks) == (4, 2)
    pool.free(ids[2:])
    # mixed-length alloc/free cycles always reach full capacity again:
    # blocks are interchangeable, so there is no fragmentation to leak
    for k in (6, 1, 5, 2, 6, 3):
        pool.reserve(k)
        got = [pool.take() for _ in range(k)]
        assert len(set(got)) == k
        pool.free(got)
    assert (pool.available, pool.live_blocks) == (6, 0)


def test_block_pool_reservation_guards():
    pool = _tiny_pool(num_blocks=4)
    with pytest.raises(ValueError, match="reserve"):
        pool.reserve(5)
    with pytest.raises(ValueError, match="reservation"):
        pool.take()                        # take without a reservation
    pool.reserve(2)
    a = pool.take()
    pool.cancel(1)                         # evicted before using block 2
    assert pool.available == 3
    pool.free([a])
    assert pool.available == 4
    with pytest.raises(ValueError, match="trash block"):
        pool.free([0])                     # the trash block is never freed
    with pytest.raises(ValueError, match="out of range"):
        pool.free([9])


def test_block_pool_worst_case_accounting():
    pool = _tiny_pool(block_size=8)
    # prefill writes prompt_len, decode writes budget - 1 more positions
    assert pool.blocks_needed(5, 6) == 2       # positions 0..9
    assert pool.blocks_needed(8, 1) == 1       # budget 1: prompt only
    assert pool.blocks_needed(8, 9) == 2       # positions 0..15
    assert pool.blocks_needed(8, 10) == 3      # position 16 opens block 2


# ---------------------------------------------------------------------------
# paged KV: scheduler admission / lazy growth / eviction (stub machinery)
# ---------------------------------------------------------------------------

def test_paged_admission_blocked_at_exhaustion_then_unblocked():
    eos_after = 99                             # run every request to budget
    api = _stub_api(eos_after=eos_after)
    # each request: prompt 5 + budget 6 -> 2 blocks of 8; a 3-block pool
    # holds exactly one in flight even though the slot table has 4 rows
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=4, buckets=(8,), max_new_tokens=6,
        paged=True, block_size=8, num_blocks=3))
    prompts = [np.full(5, 7, np.int32) for _ in range(3)]
    rids = [sched.submit(p) for p in prompts]
    sched.step()
    assert sched.num_active == 1               # admission gated by blocks,
    assert sched.num_pending == 2              # not by the 4 free rows
    max_active = 1
    while sched.num_active or sched.num_pending:
        sched.step()
        max_active = max(max_active, sched.num_active)
    outs = sched.run()
    assert max_active == 1                     # pool exhaustion held
    assert sched.pool.live_blocks == 0 and sched.pool.available == 3
    for rid, p in zip(rids, prompts):
        np.testing.assert_array_equal(outs[rid],
                                      _stub_expected(p, 6, eos_after))


def test_paged_lazy_block_growth():
    api = _stub_api(eos_after=99)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=1, buckets=(8,), max_new_tokens=10,
        paged=True, block_size=4))
    sched.submit(np.full(3, 7, np.int32))      # needs ceil(12/4) = 3 blocks
    sched._admit()
    assert len(sched.state._blocks[0]) == 1    # prompt fits one block
    peak = 1
    while sched.num_active:
        sched.step()
        if sched._active[0]:
            peak = max(peak, len(sched.state._blocks[0]))
    assert peak == 3                           # grew lazily to worst case
    assert sched.pool.live_blocks == 0         # all freed on eviction


def test_paged_dead_row_table_is_cleared():
    api = _stub_api(eos_after=2)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=6,
        paged=True, block_size=8))
    sched.submit(np.full(5, 7, np.int32))
    sched.run()
    assert (sched.state._table == 0).all()     # dead rows write to trash


def test_paged_scheduler_decode_step_counts():
    api = _stub_api(eos_after=99)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=6,
        paged=True, block_size=16))
    sched.submit(np.full(5, 7, np.int32))
    sched.run()
    assert sched.decode_steps == 5             # same contract as dense


def test_paged_scheduler_metrics_report_kv_usage():
    api = _stub_api(eos_after=99)
    m = ServeMetrics()
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=6,
        paged=True, block_size=8, num_blocks=6), metrics=m)
    for p in _rand_prompts(np.random.default_rng(7), 4, lo=3, hi=8):
        sched.submit(p)
    sched.run()
    s = m.summary()
    assert s["kv_total_blocks"] == 6
    assert 0 < s["kv_live_blocks_peak"] <= 6
    assert s["kv_util_peak"] == s["kv_live_blocks_peak"] / 6
    assert s["kv_peak_resident_bytes"] == \
        s["kv_live_blocks_peak"] * sched.pool.block_bytes


def test_paged_rejects_bad_configs():
    api = _stub_api()
    with pytest.raises(ValueError, match="must divide"):
        ContinuousScheduler(api, {}, SchedulerConfig(
            batch=2, buckets=(8,), paged=True, block_size=7))
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=4,
        paged=True, block_size=8, num_blocks=2))
    # capacity error names the bucket and the blocks required
    with pytest.raises(ValueError, match=r"bucket 8.*requires 4 KV blocks"):
        sched.submit(np.full(8, 7, np.int32), max_new_tokens=20)
    api_ssm = _stub_api(family="ssm", caps=ServeCaps(
        state_kind="recurrent", positioned=False))
    with pytest.raises(ValueError, match="paged KV serves"):
        ContinuousScheduler(api_ssm, {}, SchedulerConfig(
            batch=2, buckets=(8,), paged=True))


def test_paged_prefill_writes_bucket_covering_blocks():
    """Paged prefill (ROADMAP item): the admission prefill runs against a
    bucket-covering cache — blocks_for(bucket) * block_size positions —
    not a max_cache_len stripe, and its K/V scatter straight into pool
    blocks."""
    api = _stub_api(eos_after=99)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8, 16), max_new_tokens=4,
        paged=True, block_size=8))
    assert sched.state.prefill_cache_len(8) == 8
    assert sched.state.prefill_cache_len(16) == 16
    # block_size 16 covers a 8-bucket with one 16-token block
    sched16 = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=4,
        paged=True, block_size=16))
    assert sched16.state.prefill_cache_len(8) == 16
    for p in _rand_prompts(np.random.default_rng(14), 4, lo=3, hi=16):
        sched.submit(p)
    sched.run()
    # the compiled admission prefills are keyed by bucket-covering cache
    # lengths, never by max_cache_len (64)
    assert set(sched._prefill_fns) == {8, 16}


def test_paged_rejects_recurrent_state_families():
    """The paged slab replaces dict(k, v) KV stripes only; recurrent rows
    (caps.paged=False) keep their dense layout and say so loudly."""
    cfg = smoke_config("mamba2-370m").with_(vocab_size=VOCAB)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    srv = Server(api, params, ServeConfig(max_new_tokens=2, paged=True))
    with pytest.raises(ValueError, match="paged KV serves"):
        srv.generate(np.full((1, 5), 7, np.int32))


# ---------------------------------------------------------------------------
# paged KV: bit-equality with the dense path (real model, host-local mesh)
# ---------------------------------------------------------------------------

def test_paged_matches_dense_bit_equal_and_no_retrace(dense):
    api, params = dense
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(8)
    prompts = _rand_prompts(rng, 8, lo=3, hi=16)
    dense_s = ContinuousScheduler(api, params, SchedulerConfig(
        batch=3, buckets=(8, 16), max_new_tokens=5), mesh=mesh)
    paged_s = ContinuousScheduler(api, params, SchedulerConfig(
        batch=3, buckets=(8, 16), max_new_tokens=5,
        paged=True, block_size=8), mesh=mesh)
    rd = [dense_s.submit(p) for p in prompts]
    rp = [paged_s.submit(p) for p in prompts]
    outs_d, outs_p = dense_s.run(), paged_s.run()
    for a, b, p in zip(rd, rp, prompts):
        np.testing.assert_array_equal(outs_d[a], outs_p[b],
                                      err_msg=str(p))
    # zero retraces after warmup: a second stream hits the jit cache only
    warm = dict(paged_s.trace_counts)
    for p in _rand_prompts(rng, 6, lo=3, hi=16):
        paged_s.submit(p)
    paged_s.run()
    assert dict(paged_s.trace_counts) == warm


def test_paged_greedy_decode_deterministic(dense):
    api, params = dense
    srv = Server(api, params, ServeConfig(max_new_tokens=6, paged=True,
                                          block_size=8))
    rng = np.random.default_rng(9)
    prompts = np.full((3, 12), PAD_ID, np.int32)
    for i, l in enumerate((12, 7, 4)):
        prompts[i, :l] = rng.integers(4, VOCAB, l)
    g1 = srv.generate(prompts)
    g2 = srv.generate(prompts)
    assert g1.shape == (3, 6)
    assert np.array_equal(g1, g2)


def test_paged_padded_prompt_decodes_bit_equal_to_trimmed(dense):
    api, params = dense
    srv = Server(api, params, ServeConfig(max_new_tokens=6, paged=True,
                                          block_size=8))
    plain = Server(api, params, ServeConfig(max_new_tokens=6))
    rng = np.random.default_rng(10)
    for l in (3, 5, 9):
        prompts = np.full((2, 12), PAD_ID, np.int32)
        prompts[0] = rng.integers(4, VOCAB, 12)
        prompts[1, :l] = rng.integers(4, VOCAB, l)
        padded = srv.generate(prompts)
        trimmed = srv.generate(prompts[1:2, :l])
        assert np.array_equal(padded[1], trimmed[0]), l
        # and the paged Server agrees with the dense one bit-for-bit
        assert np.array_equal(padded, plain.generate(prompts)), l


def test_scheduler_rejects_oversized_prompt_and_cache():
    api = _stub_api()
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,)))
    with pytest.raises(ValueError, match="largest bucket"):
        sched.submit(np.full(9, 7, np.int32))
    with pytest.raises(ValueError, match="overflows"):
        # per-request budget that would decode past the KV cache
        sched.submit(np.full(8, 7, np.int32), max_new_tokens=1000)
    with pytest.raises(ValueError, match="max_cache_len"):
        ContinuousScheduler(api, {}, SchedulerConfig(batch=2, buckets=(64,)))
