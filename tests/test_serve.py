"""Serving subsystem tests: decode-loop correctness fixes, ragged
prefill-mask equivalence, and continuous-batching scheduler invariants.

Two kinds of model drive these:

* the real smoke behaviour LM (dense) for numerical properties — greedy
  determinism and the padded-vs-trimmed bit-equality the per-row position
  masking guarantees;
* a deterministic stub ModelApi (an "echo+1, EOS after k steps" machine
  with a real KV-cache-shaped state) for machinery properties — exact
  decode-step counts, EOS freezing, admit/evict/backfill accounting and
  the no-recompilation-after-warmup contract.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models.registry import get_model, ModelApi
from repro.data.pipeline import PAD_ID, EOS_ID
from repro.dist import make_host_mesh, REPLICATED
from repro.serve import (Server, ServeConfig, ContinuousScheduler,
                         SchedulerConfig, ServeMetrics, prompt_lengths,
                         BlockPool, blocks_for)

VOCAB = 64


@pytest.fixture(scope="module")
def dense():
    cfg = smoke_config("behavior-lm-100m").with_(vocab_size=VOCAB,
                                                 max_cache_len=64)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return api, params


# ---------------------------------------------------------------------------
# Stub model: next token = clip(prev + 1), EOS after `eos_after` decodes.
# State leaves are (X, B, ...) so the scheduler's axis-1 row insert works;
# k/v are KV-cache-shaped so the paged block scatter works too, and
# decode passes unknown state keys (the block table) through.
# ---------------------------------------------------------------------------

def _stub_api(eos_after: int = 3, family: str = "dense") -> ModelApi:
    cfg = smoke_config("behavior-lm-100m").with_(
        vocab_size=VOCAB, max_cache_len=64, family=family)

    def _next(tok):
        return jnp.clip(tok + 1, 4, VOCAB - 1).astype(jnp.int32)

    def prefill(p, b):
        toks = jnp.asarray(b["tokens"])
        bsz, l = toks.shape
        lengths = b.get("lengths")
        if lengths is None:
            last, idx = toks[:, -1], l
        else:
            li = jnp.asarray(lengths, jnp.int32)
            last, idx = toks[jnp.arange(bsz), li - 1], li
        state = dict(k=jnp.zeros((1, bsz, 1, cfg.max_cache_len, 1)),
                     v=jnp.zeros((1, bsz, 1, cfg.max_cache_len, 1)),
                     gen=jnp.zeros((1, bsz), jnp.int32))
        return 10.0 * jax.nn.one_hot(_next(last), VOCAB), state, idx

    def decode_step(p, tok, state, idx):
        gen = state["gen"] + 1
        nxt = jnp.where(gen[0] >= eos_after, EOS_ID, _next(tok))
        return 10.0 * jax.nn.one_hot(nxt, VOCAB), dict(state, gen=gen)

    return ModelApi(cfg=cfg, rules=REPLICATED, mesh=None,
                    init=lambda key: {}, axes=lambda: {},
                    loss=None, prefill=prefill, decode_step=decode_step,
                    batch_keys=("tokens",))


def _stub_expected(prompt, budget, eos_after):
    """The stub's deterministic output for one request."""
    out = [min(int(prompt[-1]) + 1, VOCAB - 1)]
    for k in range(1, budget):
        if k >= eos_after:
            out.append(EOS_ID)
            break
        out.append(min(out[-1] + 1, VOCAB - 1))
    return np.array(out[:budget], np.int32)


def _rand_prompts(rng, n, lo=3, hi=15):
    return [rng.integers(4, VOCAB, int(rng.integers(lo, hi))).astype(np.int32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# prompt length derivation
# ---------------------------------------------------------------------------

def test_prompt_lengths():
    p = np.array([[5, 6, 7, 0, 0],
                  [5, 6, 7, 8, 9],
                  [0, 0, 0, 0, 0]], np.int32)
    assert prompt_lengths(p).tolist() == [3, 5, 1]


# ---------------------------------------------------------------------------
# Server: greedy determinism + padded/trimmed bit-equality (real model)
# ---------------------------------------------------------------------------

def test_greedy_decode_deterministic(dense):
    api, params = dense
    srv = Server(api, params, ServeConfig(max_new_tokens=6))
    rng = np.random.default_rng(0)
    prompts = np.full((3, 12), PAD_ID, np.int32)
    for i, l in enumerate((12, 7, 4)):
        prompts[i, :l] = rng.integers(4, VOCAB, l)
    g1 = srv.generate(prompts)
    g2 = srv.generate(prompts)
    assert g1.shape == (3, 6)
    assert np.array_equal(g1, g2)


def test_padded_prompt_decodes_bit_equal_to_trimmed(dense):
    api, params = dense
    srv = Server(api, params, ServeConfig(max_new_tokens=6))
    rng = np.random.default_rng(1)
    for l in (3, 5, 9):
        prompts = np.full((2, 12), PAD_ID, np.int32)
        prompts[0] = rng.integers(4, VOCAB, 12)
        prompts[1, :l] = rng.integers(4, VOCAB, l)
        padded = srv.generate(prompts)
        trimmed = srv.generate(prompts[1:2, :l])
        assert np.array_equal(padded[1], trimmed[0]), l


def test_ragged_prefill_rejected_for_ssm_state():
    cfg = smoke_config("mamba2-370m").with_(vocab_size=VOCAB)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jnp.ones((2, 8), jnp.int32)
    with pytest.raises(ValueError, match="per-row lengths"):
        api.prefill(params, dict(tokens=toks,
                                 lengths=jnp.array([8, 5], jnp.int32)))


# ---------------------------------------------------------------------------
# RNG regression: the prefill-token draw must come from a split subkey,
# independent of later decode draws; different seeds differ at token 0.
# ---------------------------------------------------------------------------

def test_temperature_seeds_differ_at_token0(dense):
    api, params = dense
    rng = np.random.default_rng(2)
    prompts = rng.integers(4, VOCAB, (4, 8)).astype(np.int32)
    g0 = Server(api, params, ServeConfig(
        max_new_tokens=3, temperature=2.0, seed=0)).generate(prompts)
    g1 = Server(api, params, ServeConfig(
        max_new_tokens=3, temperature=2.0, seed=1)).generate(prompts)
    assert (g0[:, 0] != g1[:, 0]).any()
    # same seed stays reproducible
    g0b = Server(api, params, ServeConfig(
        max_new_tokens=3, temperature=2.0, seed=0)).generate(prompts)
    assert np.array_equal(g0, g0b)


def test_batch_path_first_sample_uses_split_subkey():
    # ssm smoke model exercises the fallback batch loop
    cfg = smoke_config("mamba2-370m").with_(vocab_size=VOCAB)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(3).integers(
        4, VOCAB, (2, 8)).astype(np.int32)
    temp, seed = 2.0, 0
    srv = Server(api, params, ServeConfig(
        max_new_tokens=2, temperature=temp, seed=seed))
    got = srv.generate(prompts)[:, 0]
    # same jitted prefill the server used, so logits match bitwise
    logits, _, _ = srv._prefill(params, dict(tokens=jnp.asarray(prompts)))
    _, sub = jax.random.split(jax.random.PRNGKey(seed))
    expected = jax.random.categorical(sub, logits / temp, axis=-1)
    assert np.array_equal(got, np.asarray(expected))
    # and NOT the pre-fix draw from the raw (reused) parent key
    buggy = jax.random.categorical(
        jax.random.PRNGKey(seed), logits / temp, axis=-1)
    if not np.array_equal(np.asarray(buggy), np.asarray(expected)):
        assert not np.array_equal(got, np.asarray(buggy))


# ---------------------------------------------------------------------------
# off-by-one + EOS short-circuit (exact decode counts via the stub)
# ---------------------------------------------------------------------------

def test_no_discarded_decode_step():
    api = _stub_api(eos_after=99, family="ssm")   # ssm -> batch path
    srv = Server(api, {}, ServeConfig(max_new_tokens=4))
    out = srv.generate(np.full((1, 5), 7, np.int32))
    # 4 tokens = 1 prefill sample + exactly 3 decodes (the old loop ran 4)
    assert srv.decode_calls == 3
    assert out.tolist() == [[8, 9, 10, 11]]


def test_eos_short_circuits_batch_loop():
    api = _stub_api(eos_after=2, family="ssm")
    srv = Server(api, {}, ServeConfig(max_new_tokens=8))
    out = srv.generate(np.full((1, 5), 7, np.int32))
    # tokens: 8, 9, EOS then frozen — only 2 decodes ever launched
    assert srv.decode_calls == 2
    assert out.tolist() == [[8, 9, EOS_ID] + [EOS_ID] * 5]


def test_scheduler_decode_step_counts():
    api = _stub_api(eos_after=99)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=6))
    sched.submit(np.full(5, 7, np.int32))
    sched.run()
    assert sched.decode_steps == 5          # 6 tokens, first from prefill
    # budget 1: finished at admission, no decode at all
    before = sched.decode_steps
    sched.submit(np.full(5, 7, np.int32), max_new_tokens=1)
    out = sched.run()
    assert sched.decode_steps == before
    assert out[1].tolist() == [8]


# ---------------------------------------------------------------------------
# scheduler: admit/evict/backfill invariants + no recompilation after warmup
# ---------------------------------------------------------------------------

def test_scheduler_stream_invariants_and_jit_cache_hits():
    eos_after = 4
    api = _stub_api(eos_after=eos_after)
    mesh = make_host_mesh(1, 1)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8, 16), max_new_tokens=6), mesh=mesh)
    rng = np.random.default_rng(4)

    # warmup: one request per bucket
    w1, w2 = np.full(6, 9, np.int32), np.full(12, 9, np.int32)
    sched.submit(w1), sched.submit(w2)
    sched.run()
    warm = dict(sched.trace_counts)
    assert warm["prefill"] == 2             # one trace per bucket
    assert warm["decode"] == 1
    assert warm["insert"] == 1

    # stream of 8 = 4x slot count, variable lengths across both buckets
    prompts = _rand_prompts(rng, 8, lo=3, hi=16)
    rids = [sched.submit(p) for p in prompts]
    max_active = 0
    while sched.num_active or sched.num_pending:
        sched.step()
        max_active = max(max_active, sched.num_active)
    outs = sched.run()

    assert dict(sched.trace_counts) == warm   # jit cache hits only
    assert max_active <= 2                    # never exceeds the slot table
    for rid, p in zip(rids, prompts):
        np.testing.assert_array_equal(
            outs[rid], _stub_expected(p, 6, eos_after), err_msg=str(rid))


def test_scheduler_metrics_lifecycle():
    api = _stub_api(eos_after=3)
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    m = ServeMetrics(clock=clock)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=4), metrics=m)
    for p in _rand_prompts(np.random.default_rng(5), 4, lo=3, hi=8):
        sched.submit(p)
    sched.run()
    s = m.summary()
    assert s["requests"] == 4
    assert s["tokens"] == sum(r.tokens for r in m.requests.values())
    assert s["tokens_per_sec"] > 0
    assert s["p99_latency_s"] >= s["p50_latency_s"] > 0
    for r in m.requests.values():
        assert r.submit < r.admit <= r.first_token < r.finish


def test_scheduler_real_model_matches_single_request(dense):
    """Continuous slots vs one-request-at-a-time: greedy outputs agree."""
    api, params = dense
    sched = ContinuousScheduler(api, params, SchedulerConfig(
        batch=3, buckets=(8, 16), max_new_tokens=5))
    prompts = _rand_prompts(np.random.default_rng(6), 7, lo=3, hi=16)
    rids = [sched.submit(p) for p in prompts]
    outs = sched.run()
    solo = ContinuousScheduler(api, params, SchedulerConfig(
        batch=1, buckets=(8, 16), max_new_tokens=5))
    for rid, p in zip(rids[:3], prompts[:3]):
        srid = solo.submit(p)
        np.testing.assert_array_equal(solo.run()[srid], outs[rid])


def test_scheduler_rejects_unsupported_family():
    api = _stub_api(family="ssm")
    with pytest.raises(ValueError, match="supports"):
        ContinuousScheduler(api, {}, SchedulerConfig(batch=2, buckets=(8,)))


# ---------------------------------------------------------------------------
# paged KV: block pool allocator
# ---------------------------------------------------------------------------

def _tiny_pool(num_blocks=6, block_size=4):
    return BlockPool(num_blocks=num_blocks, block_size=block_size,
                     num_kv_heads=1, head_dim=2, num_layers=1)


def test_blocks_for():
    assert blocks_for(0, 8) == 0
    assert blocks_for(1, 8) == 1
    assert blocks_for(8, 8) == 1
    assert blocks_for(9, 8) == 2


def test_block_pool_alloc_free_reuse_cycles():
    pool = _tiny_pool(num_blocks=6)
    assert (pool.capacity, pool.available, pool.live_blocks) == (6, 6, 0)
    pool.reserve(4)
    assert pool.available == 2            # reservation sets capacity aside
    ids = [pool.take() for _ in range(4)]
    assert len(set(ids)) == 4 and all(1 <= i <= 6 for i in ids)  # 0 = trash
    assert (pool.available, pool.live_blocks) == (2, 4)
    pool.free(ids[:2])
    assert (pool.available, pool.live_blocks) == (4, 2)
    pool.free(ids[2:])
    # mixed-length alloc/free cycles always reach full capacity again:
    # blocks are interchangeable, so there is no fragmentation to leak
    for k in (6, 1, 5, 2, 6, 3):
        pool.reserve(k)
        got = [pool.take() for _ in range(k)]
        assert len(set(got)) == k
        pool.free(got)
    assert (pool.available, pool.live_blocks) == (6, 0)


def test_block_pool_reservation_guards():
    pool = _tiny_pool(num_blocks=4)
    with pytest.raises(ValueError, match="reserve"):
        pool.reserve(5)
    with pytest.raises(ValueError, match="reservation"):
        pool.take()                        # take without a reservation
    pool.reserve(2)
    a = pool.take()
    pool.cancel(1)                         # evicted before using block 2
    assert pool.available == 3
    pool.free([a])
    assert pool.available == 4
    with pytest.raises(ValueError, match="out of range"):
        pool.free([0])                     # the trash block is never freed


def test_block_pool_worst_case_accounting():
    pool = _tiny_pool(block_size=8)
    # prefill writes prompt_len, decode writes budget - 1 more positions
    assert pool.blocks_needed(5, 6) == 2       # positions 0..9
    assert pool.blocks_needed(8, 1) == 1       # budget 1: prompt only
    assert pool.blocks_needed(8, 9) == 2       # positions 0..15
    assert pool.blocks_needed(8, 10) == 3      # position 16 opens block 2


# ---------------------------------------------------------------------------
# paged KV: scheduler admission / lazy growth / eviction (stub machinery)
# ---------------------------------------------------------------------------

def test_paged_admission_blocked_at_exhaustion_then_unblocked():
    eos_after = 99                             # run every request to budget
    api = _stub_api(eos_after=eos_after)
    # each request: prompt 5 + budget 6 -> 2 blocks of 8; a 3-block pool
    # holds exactly one in flight even though the slot table has 4 rows
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=4, buckets=(8,), max_new_tokens=6,
        paged=True, block_size=8, num_blocks=3))
    prompts = [np.full(5, 7, np.int32) for _ in range(3)]
    rids = [sched.submit(p) for p in prompts]
    sched.step()
    assert sched.num_active == 1               # admission gated by blocks,
    assert sched.num_pending == 2              # not by the 4 free rows
    max_active = 1
    while sched.num_active or sched.num_pending:
        sched.step()
        max_active = max(max_active, sched.num_active)
    outs = sched.run()
    assert max_active == 1                     # pool exhaustion held
    assert sched.pool.live_blocks == 0 and sched.pool.available == 3
    for rid, p in zip(rids, prompts):
        np.testing.assert_array_equal(outs[rid],
                                      _stub_expected(p, 6, eos_after))


def test_paged_lazy_block_growth():
    api = _stub_api(eos_after=99)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=1, buckets=(8,), max_new_tokens=10,
        paged=True, block_size=4))
    sched.submit(np.full(3, 7, np.int32))      # needs ceil(12/4) = 3 blocks
    sched._admit()
    assert len(sched._blocks[0]) == 1          # prompt fits one block
    peak = 1
    while sched.num_active:
        sched.step()
        if sched._active[0]:
            peak = max(peak, len(sched._blocks[0]))
    assert peak == 3                           # grew lazily to worst case
    assert sched.pool.live_blocks == 0         # all freed on eviction


def test_paged_dead_row_table_is_cleared():
    api = _stub_api(eos_after=2)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=6,
        paged=True, block_size=8))
    sched.submit(np.full(5, 7, np.int32))
    sched.run()
    assert (sched._table == 0).all()           # dead rows write to trash


def test_paged_scheduler_decode_step_counts():
    api = _stub_api(eos_after=99)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=6,
        paged=True, block_size=16))
    sched.submit(np.full(5, 7, np.int32))
    sched.run()
    assert sched.decode_steps == 5             # same contract as dense


def test_paged_scheduler_metrics_report_kv_usage():
    api = _stub_api(eos_after=99)
    m = ServeMetrics()
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=6,
        paged=True, block_size=8, num_blocks=6), metrics=m)
    for p in _rand_prompts(np.random.default_rng(7), 4, lo=3, hi=8):
        sched.submit(p)
    sched.run()
    s = m.summary()
    assert s["kv_total_blocks"] == 6
    assert 0 < s["kv_live_blocks_peak"] <= 6
    assert s["kv_util_peak"] == s["kv_live_blocks_peak"] / 6
    assert s["kv_peak_resident_bytes"] == \
        s["kv_live_blocks_peak"] * sched.pool.block_bytes


def test_paged_rejects_bad_configs():
    api = _stub_api()
    with pytest.raises(ValueError, match="must divide"):
        ContinuousScheduler(api, {}, SchedulerConfig(
            batch=2, buckets=(8,), paged=True, block_size=7))
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=4,
        paged=True, block_size=8, num_blocks=2))
    # capacity error names the bucket and the blocks required
    with pytest.raises(ValueError, match=r"bucket 8.*requires 4 KV blocks"):
        sched.submit(np.full(8, 7, np.int32), max_new_tokens=20)
    api_ssm = _stub_api(family="ssm")
    with pytest.raises(ValueError, match="supports"):
        ContinuousScheduler(api_ssm, {}, SchedulerConfig(
            batch=2, buckets=(8,), paged=True))


def test_paged_server_rejects_batch_path_families():
    cfg = smoke_config("mamba2-370m").with_(vocab_size=VOCAB)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    srv = Server(api, params, ServeConfig(max_new_tokens=2, paged=True))
    with pytest.raises(ValueError, match="paged KV serves"):
        srv.generate(np.full((1, 5), 7, np.int32))


# ---------------------------------------------------------------------------
# paged KV: bit-equality with the dense path (real model, host-local mesh)
# ---------------------------------------------------------------------------

def test_paged_matches_dense_bit_equal_and_no_retrace(dense):
    api, params = dense
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(8)
    prompts = _rand_prompts(rng, 8, lo=3, hi=16)
    dense_s = ContinuousScheduler(api, params, SchedulerConfig(
        batch=3, buckets=(8, 16), max_new_tokens=5), mesh=mesh)
    paged_s = ContinuousScheduler(api, params, SchedulerConfig(
        batch=3, buckets=(8, 16), max_new_tokens=5,
        paged=True, block_size=8), mesh=mesh)
    rd = [dense_s.submit(p) for p in prompts]
    rp = [paged_s.submit(p) for p in prompts]
    outs_d, outs_p = dense_s.run(), paged_s.run()
    for a, b, p in zip(rd, rp, prompts):
        np.testing.assert_array_equal(outs_d[a], outs_p[b],
                                      err_msg=str(p))
    # zero retraces after warmup: a second stream hits the jit cache only
    warm = dict(paged_s.trace_counts)
    for p in _rand_prompts(rng, 6, lo=3, hi=16):
        paged_s.submit(p)
    paged_s.run()
    assert dict(paged_s.trace_counts) == warm


def test_paged_greedy_decode_deterministic(dense):
    api, params = dense
    srv = Server(api, params, ServeConfig(max_new_tokens=6, paged=True,
                                          block_size=8))
    rng = np.random.default_rng(9)
    prompts = np.full((3, 12), PAD_ID, np.int32)
    for i, l in enumerate((12, 7, 4)):
        prompts[i, :l] = rng.integers(4, VOCAB, l)
    g1 = srv.generate(prompts)
    g2 = srv.generate(prompts)
    assert g1.shape == (3, 6)
    assert np.array_equal(g1, g2)


def test_paged_padded_prompt_decodes_bit_equal_to_trimmed(dense):
    api, params = dense
    srv = Server(api, params, ServeConfig(max_new_tokens=6, paged=True,
                                          block_size=8))
    plain = Server(api, params, ServeConfig(max_new_tokens=6))
    rng = np.random.default_rng(10)
    for l in (3, 5, 9):
        prompts = np.full((2, 12), PAD_ID, np.int32)
        prompts[0] = rng.integers(4, VOCAB, 12)
        prompts[1, :l] = rng.integers(4, VOCAB, l)
        padded = srv.generate(prompts)
        trimmed = srv.generate(prompts[1:2, :l])
        assert np.array_equal(padded[1], trimmed[0]), l
        # and the paged Server agrees with the dense one bit-for-bit
        assert np.array_equal(padded, plain.generate(prompts)), l


def test_scheduler_rejects_oversized_prompt_and_cache():
    api = _stub_api()
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,)))
    with pytest.raises(ValueError, match="largest bucket"):
        sched.submit(np.full(9, 7, np.int32))
    with pytest.raises(ValueError, match="overflows"):
        # per-request budget that would decode past the KV cache
        sched.submit(np.full(8, 7, np.int32), max_new_tokens=1000)
    with pytest.raises(ValueError, match="max_cache_len"):
        ContinuousScheduler(api, {}, SchedulerConfig(batch=2, buckets=(64,)))
