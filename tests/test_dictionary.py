import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import EventDictionary, NameTable, assign_codes, histogram
from repro.core.oracle import histogram_oracle

NAMES = [f"web:home:s{i}:c:e:action_{i}" for i in range(24)]


def _dict_for(ids):
    table = NameTable(NAMES)
    return EventDictionary.build(table, np.asarray(ids, np.int32))


@given(st.lists(st.integers(0, 23), min_size=1, max_size=400))
@settings(max_examples=50, deadline=None)
def test_histogram_matches_oracle(ids):
    d = _dict_for(ids)
    assert np.array_equal(d.counts, histogram_oracle(ids, 24))


@given(st.lists(st.integers(0, 23), min_size=1, max_size=400))
@settings(max_examples=50, deadline=None)
def test_bijection_and_frequency_order(ids):
    d = _dict_for(ids)
    d.verify()  # asserts bijection + monotone counts
    # paper: more frequent events get smaller code points
    ordered = d.counts[d.name_of_code]
    assert all(ordered[i] >= ordered[i + 1] for i in range(len(ordered) - 1))


@given(st.lists(st.integers(0, 23), min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_encode_decode_roundtrip(ids):
    d = _dict_for(ids)
    codes = np.asarray(d.encode_ids(np.asarray(ids, np.int32)))
    back = np.asarray(d.decode_codes(codes))
    assert np.array_equal(back, np.asarray(ids))


def test_validity_mask_excludes_rows():
    ids = np.array([0, 1, 1, 2], np.int32)
    valid = np.array([True, False, True, True])
    h = np.asarray(histogram(ids, 24, valid=valid))
    assert h[0] == 1 and h[1] == 1 and h[2] == 1


def test_pattern_expansion_codes():
    ids = [0] * 5 + [1] * 3 + [2]
    d = _dict_for(ids)
    codes = d.codes_matching("*:action_1")
    assert len(codes) == 1
    assert d.name_of(int(codes[0])).endswith("action_1")


def test_save_load_stable(tmp_path):
    d = _dict_for([0, 0, 1, 2, 2, 2])
    p = str(tmp_path / "dict.json")
    d.save(p)
    d2 = EventDictionary.load(p)
    assert np.array_equal(d.code_of_name, d2.code_of_name)
    assert d2.code_of("web:home:s2:c:e:action_2") == 0  # most frequent
