"""Deterministic mini-hypothesis, used only when the real package is absent.

The container image does not ship ``hypothesis`` and installing packages is
off the table, so ``conftest.py`` aliases this module into ``sys.modules``
as a fallback. It implements exactly the surface the suite uses —
``given``, ``settings``, ``strategies.integers/lists/sets/from_regex`` —
by running each property test over a fixed number of seeded random
examples (seeded per test name, so runs are reproducible). No shrinking;
a failure reports the falsifying example verbatim.
"""
from __future__ import annotations

import random
import re
import types


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rnd: random.Random):
        return self._draw(rnd)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def _lists(elements: _Strategy, *, min_size: int = 0,
           max_size: int = 10) -> _Strategy:
    def draw(r):
        return [elements.example(r)
                for _ in range(r.randint(min_size, max_size))]
    return _Strategy(draw)


def _sets(elements: _Strategy, *, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(r):
        target = r.randint(min_size, max_size)
        out: set = set()
        for _ in range(1000):
            if len(out) >= target:
                break
            out.add(elements.example(r))
        if len(out) < min_size:
            raise RuntimeError("set strategy: element domain too small for "
                               f"min_size={min_size}")
        return out
    return _Strategy(draw)


def _expand_class(spec: str) -> list[str]:
    out, i = [], 0
    while i < len(spec):
        if i + 2 < len(spec) and spec[i + 1] == "-":
            out.extend(chr(c)
                       for c in range(ord(spec[i]), ord(spec[i + 2]) + 1))
            i += 3
        else:
            out.append(spec[i])
            i += 1
    return out


def _from_regex(pattern: str, *, fullmatch: bool = False) -> _Strategy:
    """Generator for simple patterns: literals, [...] classes (with ranges),
    and {m,n} / * / + / ? quantifiers. Every draw is verified against the
    real ``re`` engine so an unsupported construct fails loudly instead of
    producing wrong data."""
    parts, i = [], 0
    while i < len(pattern):
        c = pattern[i]
        if c == "[":
            j = pattern.index("]", i)
            chars, i = _expand_class(pattern[i + 1:j]), j + 1
        elif c == "\\":
            chars, i = [pattern[i + 1]], i + 2
        else:
            chars, i = [c], i + 1
        lo = hi = 1
        if i < len(pattern):
            q = pattern[i]
            if q == "{":
                j = pattern.index("}", i)
                spec = pattern[i + 1:j].split(",")
                lo = int(spec[0])
                hi = int(spec[-1]) if spec[-1] else lo + 8
                i = j + 1
            elif q in "*+?":
                lo, hi = (0, 8) if q == "*" else (1, 8) if q == "+" else (0, 1)
                i += 1
        parts.append((chars, lo, hi))

    def draw(r):
        s = "".join(r.choice(chars)
                    for chars, lo, hi in parts
                    for _ in range(r.randint(lo, hi)))
        ok = re.fullmatch(pattern, s) if fullmatch else re.match(pattern, s)
        if not ok:
            raise RuntimeError(f"mini from_regex cannot generate for "
                               f"{pattern!r} (produced {s!r})")
        return s
    return _Strategy(draw)


_DEFAULT_MAX_EXAMPLES = 25


def given(*strategies: _Strategy):
    def decorate(fn):
        def wrapper():
            # settings may sit above OR below given in the decorator stack:
            # below attaches to fn, above to the wrapper itself
            conf = getattr(wrapper, "_mini_settings",
                           getattr(fn, "_mini_settings", {}))
            n = conf.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            rnd = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for k in range(n):
                args = [s.example(rnd) for s in strategies]
                try:
                    fn(*args)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (run {k}): {args!r}") from e
        # plain attribute copy, NOT functools.wraps: pytest must see the
        # zero-arg signature, not the property's argument names (it would
        # treat them as fixtures)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return decorate


def settings(**kwargs):
    def decorate(fn):
        fn._mini_settings = kwargs
        return fn
    return decorate


strategies = types.SimpleNamespace(
    integers=_integers, lists=_lists, sets=_sets, from_regex=_from_regex)
