"""Per-kernel validation: shape/dtype sweeps, interpret mode vs pure-jnp
oracle (assert_allclose)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import (flash_attention,
                                               paged_decode_attention)
from repro.kernels.flash_attention.ref import (attention_ref,
                                               attention_blocked,
                                               paged_attention_ref)
from repro.kernels.funnel_match.ops import deepest_stage, reach_counts
from repro.kernels.funnel_match.ref import (pack_match_bits,
                                            deepest_stage_oracle_np)
from repro.kernels.event_count.ops import histogram
from repro.kernels.event_count.ref import histogram_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("b,h,kvh,lq,lk,d", [
    (1, 4, 4, 128, 128, 32),     # MHA square
    (2, 8, 2, 256, 256, 64),     # GQA 4:1
    (1, 8, 1, 128, 256, 64),     # MQA
    (2, 4, 2, 256, 512, 128),    # lk > lq, d=128
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(b, h, kvh, lq, lk, d, causal):
    q = RNG.standard_normal((b, h, lq, d), np.float32)
    k = RNG.standard_normal((b, kvh, lk, d), np.float32)
    v = RNG.standard_normal((b, kvh, lk, d), np.float32)
    ref = attention_ref(q, k, v, causal=causal)
    pal = flash_attention(q, k, v, causal=causal, impl="interpret")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [("float32", 2e-5), ("bfloat16", 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    q = RNG.standard_normal((1, 4, 128, 64)).astype(dtype)
    k = RNG.standard_normal((1, 2, 128, 64)).astype(dtype)
    v = RNG.standard_normal((1, 2, 128, 64)).astype(dtype)
    ref = attention_ref(q, k, v)
    pal = flash_attention(q, k, v, impl="interpret")
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_kv_len_and_offset():
    q = RNG.standard_normal((2, 4, 128, 32), np.float32)
    k = RNG.standard_normal((2, 4, 256, 32), np.float32)
    v = RNG.standard_normal((2, 4, 256, 32), np.float32)
    ref = attention_ref(q, k, v, causal=True, kv_len=200, q_offset=64)
    pal = flash_attention(q, k, v, causal=True, kv_len=200, q_offset=64,
                          impl="interpret")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_blocked_equals_ref_many_blocks():
    q = RNG.standard_normal((1, 4, 96, 32), np.float32)
    k = RNG.standard_normal((1, 4, 320, 32), np.float32)
    v = RNG.standard_normal((1, 4, 320, 32), np.float32)
    ref = attention_ref(q, k, v, causal=False, kv_len=300)
    blk = attention_blocked(q, k, v, causal=False, kv_len=300, block_k=64)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def _paged_case(b, h, kvh, d, bs, nb, n_pool, seed=0):
    """Random pool + per-row tables of distinct live blocks + lengths."""
    rng = np.random.default_rng(seed)
    kp = rng.standard_normal((n_pool, kvh, bs, d)).astype(np.float32)
    vp = rng.standard_normal((n_pool, kvh, bs, d)).astype(np.float32)
    q = rng.standard_normal((b, h, 1, d)).astype(np.float32)
    table = np.zeros((b, nb), np.int32)
    kv_len = np.zeros((b,), np.int32)
    free = list(range(1, n_pool))         # block 0 = trash, stays unmapped
    rng.shuffle(free)
    for r in range(b):
        kv_len[r] = rng.integers(1, nb * bs + 1)
        for j in range((int(kv_len[r]) + bs - 1) // bs):
            table[r, j] = free.pop()
    return q, kp, vp, table, kv_len


def test_paged_ref_bit_equal_to_dense_gather():
    """The oracle over the paged layout is the dense per-row oracle on the
    gathered cache — bitwise, not approximately."""
    q, kp, vp, table, kv_len = _paged_case(3, 4, 2, 32, 8, 4, n_pool=16)
    dk = np.stack([np.concatenate([kp[t] for t in row], axis=1)
                   for row in table])
    dv = np.stack([np.concatenate([vp[t] for t in row], axis=1)
                   for row in table])
    ref = attention_ref(jnp.asarray(q), jnp.asarray(dk), jnp.asarray(dv),
                        causal=True, kv_len=jnp.asarray(kv_len),
                        q_offset=jnp.asarray(kv_len - 1))
    got = paged_attention_ref(jnp.asarray(q), jnp.asarray(kp),
                              jnp.asarray(vp), jnp.asarray(table),
                              jnp.asarray(kv_len))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("b,h,kvh,d,bs,nb", [
    (2, 4, 4, 32, 8, 4),      # MHA
    (3, 8, 2, 64, 16, 4),     # GQA 4:1
    (1, 8, 1, 128, 8, 8),     # MQA, d=128
])
def test_paged_decode_kernel_interpret_matches_ref(b, h, kvh, d, bs, nb):
    q, kp, vp, table, kv_len = _paged_case(b, h, kvh, d, bs, nb,
                                           n_pool=b * nb + 2, seed=b)
    ref = paged_decode_attention(q, kp, vp, table, kv_len, impl="ref")
    pal = paged_decode_attention(q, kp, vp, table, kv_len, impl="interpret")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_trash_block_contents_never_leak():
    """Garbage in unmapped (trash) blocks must contribute exactly zero."""
    q, kp, vp, table, kv_len = _paged_case(2, 4, 2, 32, 8, 4, n_pool=12)
    before = paged_decode_attention(q, kp, vp, table, kv_len, impl="ref")
    kp2, vp2 = kp.copy(), vp.copy()
    kp2[0] = 1e6                          # poison the trash block
    vp2[0] = -1e6
    after = paged_decode_attention(q, kp2, vp2, table, kv_len, impl="ref")
    np.testing.assert_array_equal(np.asarray(before), np.asarray(after))


def test_flash_gradients_match_ref():
    q = RNG.standard_normal((1, 2, 64, 32), np.float32)
    k = RNG.standard_normal((1, 2, 64, 32), np.float32)
    v = RNG.standard_normal((1, 2, 64, 32), np.float32)
    g_ref = jax.grad(lambda q_: attention_ref(q_, k, v).sum())(q)
    g_pal = jax.grad(
        lambda q_: flash_attention(q_, k, v, impl="interpret").sum())(q)
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("s,l,k,a", [(17, 33, 1, 16), (64, 96, 4, 500),
                                     (300, 96, 8, 100), (5, 256, 3, 40)])
def test_funnel_kernel_sweep(s, l, k, a):
    sym = RNG.integers(0, a, (s, l)).astype(np.int32)
    mask = np.arange(l)[None, :] < RNG.integers(1, l + 1, (s, 1))
    table = np.zeros((k, a), bool)
    for kk in range(k):
        table[kk, RNG.choice(a, max(2, a // 10), replace=False)] = True
    bits = np.asarray(pack_match_bits(jnp.asarray(sym), jnp.asarray(mask),
                                      jnp.asarray(table)))
    want = deepest_stage_oracle_np(bits)
    for impl in ("ref", "interpret"):
        got = np.asarray(deepest_stage(sym, mask, table, impl=impl))
        np.testing.assert_array_equal(got, want)


def test_funnel_reach_counts_consistent():
    sym = RNG.integers(0, 30, (50, 40)).astype(np.int32)
    mask = np.ones_like(sym, bool)
    table = np.zeros((3, 30), bool)
    table[0, :10] = True
    table[1, 10:20] = True
    table[2, 20:] = True
    r_ref = reach_counts(sym, mask, table, impl="ref")
    r_pal = reach_counts(sym, mask, table, impl="interpret")
    assert r_ref == r_pal


@pytest.mark.parametrize("s,l,a", [(13, 7, 33), (64, 128, 700), (1, 5, 4096)])
def test_histogram_kernel_sweep(s, l, a):
    sym = RNG.integers(0, a, (s, l)).astype(np.int32)
    mask = RNG.random((s, l)) < 0.8
    ref = np.asarray(histogram_ref(jnp.asarray(sym), jnp.asarray(mask), a))
    pal = np.asarray(histogram(sym, mask, a, impl="interpret"))
    np.testing.assert_array_equal(ref, pal)
    assert ref.sum() == mask.sum()
