"""Deliverable (f): every assigned architecture instantiates a REDUCED
config of the same family and runs one forward/train step on CPU, asserting
output shapes and no NaNs."""
import numpy as np
import jax
import pytest

from repro.configs import REGISTRY, ASSIGNED, smoke_config
from repro.models.registry import get_model
from repro.train import make_train_step, OptConfig, init_opt_state

RNG = np.random.default_rng(0)


def _smoke_batch(cfg, b=2, s=16):
    toks = RNG.integers(4, cfg.vocab_size, (b, s + 1)).astype(np.int32)
    batch = dict(tokens=toks[:, :-1], targets=toks[:, 1:],
                 loss_mask=np.ones((b, s), np.float32))
    if cfg.family == "encdec":
        batch["frames"] = RNG.standard_normal(
            (b, cfg.n_frames, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        batch["patches"] = RNG.standard_normal(
            (b, cfg.n_patches, cfg.vision_dim)).astype(np.float32)
    return batch


@pytest.mark.parametrize("arch", list(REGISTRY))
def test_arch_smoke_train_step(arch):
    cfg = smoke_config(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))

    batch = _smoke_batch(cfg)
    loss, metrics = api.loss(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(metrics["tokens"]) == batch["loss_mask"].sum()

    ocfg = OptConfig(lr=1e-3)
    state = dict(params=params, opt=init_opt_state(params, ocfg))
    state, m = make_train_step(api, ocfg)(state, batch)
    assert np.isfinite(float(m["loss"])), arch
    assert int(m["skipped"]) == 0
    for leaf in jax.tree.leaves(state["params"]):
        assert np.isfinite(np.asarray(leaf)).all(), f"{arch}: NaN params"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_serve(arch):
    cfg = smoke_config(arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    batch = _smoke_batch(cfg, b=2, s=12)
    logits, state, idx = api.prefill(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch
    tok = np.argmax(np.asarray(logits), -1).astype(np.int32)
    logits2, state = api.decode_step(params, tok, state, idx)
    assert logits2.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all(), arch
