import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train import (OptConfig, init_opt_state, apply_updates, schedule,
                         compress_grads, CheckpointManager, Trainer,
                         TrainerConfig, make_train_step)
from repro.models import ModelConfig, get_model

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                  num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=64,
                  dtype="float32", remat="none")


def _params():
    return get_model(CFG).init(jax.random.PRNGKey(0))


def test_adamw_matches_manual_reference():
    ocfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=10, min_lr_frac=1.0,
                     weight_decay=0.0, clip_norm=1e9)
    p = dict(w=jnp.array([[1.0, -2.0]]))
    g = dict(w=jnp.array([[0.5, 0.5]]))
    st = init_opt_state(p, ocfg)
    newp, newst, _ = apply_updates(p, g, st, ocfg)
    # manual AdamW step 1: mu_hat = g, nu_hat = g^2 -> delta = g/|g|
    want = p["w"] - 0.1 * (g["w"] / (jnp.abs(g["w"]) + 1e-8))
    np.testing.assert_allclose(np.asarray(newp["w"]), np.asarray(want),
                               rtol=1e-5)


def test_clip_reduces_large_grads():
    ocfg = OptConfig(lr=1e-2, clip_norm=1.0, warmup_steps=0, total_steps=10)
    p = dict(w=jnp.ones((4, 4)))
    g = dict(w=jnp.full((4, 4), 100.0))
    st = init_opt_state(p, ocfg)
    _, _, stats = apply_updates(p, g, st, ocfg)
    assert float(stats["grad_norm"]) == pytest.approx(400.0)


def test_nonfinite_step_skipped():
    ocfg = OptConfig(lr=1e-2)
    p = dict(w=jnp.ones((2, 2)))
    g = dict(w=jnp.array([[jnp.inf, 0.0], [0.0, 0.0]]))
    st = init_opt_state(p, ocfg)
    newp, newst, stats = apply_updates(p, g, st, ocfg)
    np.testing.assert_array_equal(np.asarray(newp["w"]), np.ones((2, 2)))
    assert int(newst["skipped"]) == 1
    # a following healthy step applies
    g2 = dict(w=jnp.full((2, 2), 0.1))
    newp2, newst2, _ = apply_updates(newp, g2, newst, ocfg)
    assert not np.allclose(np.asarray(newp2["w"]), 1.0)
    assert int(newst2["skipped"]) == 1


def test_schedule_warmup_and_cosine():
    ocfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                     min_lr_frac=0.1)
    assert float(schedule(jnp.asarray(5), ocfg)) == pytest.approx(0.5)
    assert float(schedule(jnp.asarray(10), ocfg)) == pytest.approx(1.0)
    assert float(schedule(jnp.asarray(110), ocfg)) == pytest.approx(0.1)


def test_error_feedback_compression_is_unbiased_over_time():
    rng = np.random.default_rng(0)
    g_true = dict(w=jnp.asarray(rng.standard_normal((32, 32)), jnp.float32))
    err = dict(w=jnp.zeros((32, 32)))
    acc_comp = np.zeros((32, 32))
    steps = 50
    for _ in range(steps):
        comp, err = compress_grads(g_true, err, "ef_int8")
        acc_comp += np.asarray(comp["w"])
    # error feedback: sum of compressed ~= sum of true gradients
    rel = np.linalg.norm(acc_comp - steps * np.asarray(g_true["w"])) / \
        np.linalg.norm(steps * np.asarray(g_true["w"]))
    assert rel < 0.01


def test_sign_compression_direction():
    g = dict(w=jnp.asarray([[3.0, -1.0]]))
    comp, err = compress_grads(g, dict(w=jnp.zeros((1, 2))), "sign")
    c = np.asarray(comp["w"])
    assert c[0, 0] > 0 and c[0, 1] < 0
    np.testing.assert_allclose(np.abs(c), np.mean(np.abs(np.asarray(g["w"]))))


def test_checkpoint_roundtrip_and_checksum(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = dict(a=np.arange(6, dtype=np.float32).reshape(2, 3),
                b=dict(c=np.ones(4, np.int32)))
    mgr.save(3, tree)
    assert mgr.latest_step() == 3
    back = mgr.restore(tree)
    np.testing.assert_array_equal(back["a"], tree["a"])
    # corrupt a leaf -> restore must fail integrity check
    d = tmp_path / "step_00000003"
    target = next(p for p in d.iterdir() if p.name.endswith(".npy"))
    data = bytearray(target.read_bytes())
    data[-1] ^= 0xFF
    target.write_bytes(bytes(data))
    with pytest.raises(IOError):
        mgr.restore(tree)


def test_checkpoint_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = dict(a=np.zeros(2))
    for step in (1, 2, 3, 4):
        mgr.save(step, tree)
    names = sorted(p.name for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert names == ["step_00000003", "step_00000004"]


def test_trainer_nan_watchdog(tmp_path):
    api = get_model(CFG)

    class PoisonPipeline:
        def batches_per_epoch(self):
            return 4

        def batch_at(self, epoch, step):
            b = dict(tokens=np.ones((2, 8), np.int32),
                     targets=np.ones((2, 8), np.int32),
                     loss_mask=np.full((2, 8), np.inf, np.float32))
            return b

    tr = Trainer(api, OptConfig(), TrainerConfig(
        total_steps=50, checkpoint_every=1000, log_every=1000,
        max_consecutive_skips=3, checkpoint_dir=str(tmp_path)))
    with pytest.raises(RuntimeError, match="non-finite"):
        tr.run(PoisonPipeline(), resume=False)
