"""Streaming fast-data tier (repro.data.streampipe): watermark semantics,
ring-buffer overflow accounting, zero-retrace ticks, and — the core
contract — closed-prefix bit-equality against the batch distpipe oracle at
every watermark, on shuffled / late / duplicated streams and on a full
loggen day. Also covers the benchmarks/run.py --only section selector."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(body: str) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, {REPO_SRC!r})
        import numpy as np, jax, jax.numpy as jnp
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _events(n, seed, n_users=12, ts_hi=5 * 10**7):
    rng = np.random.default_rng(seed)
    user = rng.integers(0, n_users, n).astype(np.int64) * 7919
    sess = rng.integers(0, 3, n).astype(np.int64)
    ts = rng.integers(0, ts_hi, n).astype(np.int64)
    code = rng.integers(0, 16, n).astype(np.int32)
    ip = rng.integers(0, 1 << 32, n).astype(np.int64)
    return user, sess, ts, code, ip


def _cfg(**kw):
    from repro.data.streampipe import StreamConfig
    base = dict(alphabet_size=16, max_open=64, max_len=64,
                tick_capacity=64)
    base.update(kw)
    return StreamConfig(**base)


GAP = 30 * 60 * 1000  # DEFAULT_GAP_MS


# ---------------------------------------------------------------------------
# watermark + ring semantics (deterministic)
# ---------------------------------------------------------------------------

def test_empty_tick_is_noop():
    from repro.data.streampipe import single_host_stream
    s = single_host_stream(_cfg())
    u, se, ts, c, ip = _events(20, seed=1)
    s.tick(u, se, ts, c, ip, watermark=0)  # nothing closes
    before = s.open_state()
    wm = s.watermark
    z = np.zeros(0, np.int64)
    res = s.tick(z, z, z, np.zeros(0, np.int32))
    assert res.watermark == wm and s.watermark == wm
    assert res.closed_sessions == 0 and res.late_dropped == 0
    assert res.ring_dropped_events == 0 and res.shuffle_dropped == 0
    after = s.open_state()
    for k in before:
        assert np.array_equal(before[k], after[k]), k


def test_watermark_boundary_session_close():
    """A session closes only when end_ts + gap is *strictly* below the
    watermark — an event at exactly end_ts + gap can still extend it."""
    from repro.data.streampipe import single_host_stream
    one = lambda v, dt=np.int64: np.array([v], dt)

    s = single_host_stream(_cfg())
    s.tick(one(7), one(0), one(1000), one(3, np.int32))
    # watermark = end + gap: an acceptable event at ts == watermark still
    # has ts - end == gap (not > gap), so the session must stay open...
    res = s.tick(np.zeros(0, np.int64), np.zeros(0, np.int64),
                 np.zeros(0, np.int64), np.zeros(0, np.int32),
                 watermark=1000 + GAP)
    assert res.closed_sessions == 0 and res.open_sessions == 1
    # ...and such an event does extend it:
    res = s.tick(one(7), one(0), one(1000 + GAP), one(4, np.int32))
    assert res.late_dropped == 0 and res.open_sessions == 1
    # one past end + gap closes it, with both events merged.
    res = s.tick(np.zeros(0, np.int64), np.zeros(0, np.int64),
                 np.zeros(0, np.int64), np.zeros(0, np.int32),
                 watermark=1000 + 2 * GAP + 1)
    assert res.closed_sessions == 1 and res.open_sessions == 0
    seqs = s.sessions()
    assert len(seqs) == 1 and int(seqs.length[0]) == 2
    assert list(seqs.symbols[0][:2]) == [3, 4]
    assert int(seqs.duration_s[0]) == GAP // 1000


def test_late_events_dropped_and_counted():
    from repro.data.streampipe import single_host_stream
    s = single_host_stream(_cfg())
    u, se, ts, c, ip = _events(30, seed=2)
    s.tick(u, se, ts, c, ip, watermark=10**9)  # everything closes
    before = s.result()
    res = s.tick(u[:5], se[:5], ts[:5] % 100, c[:5], ip[:5])  # all < wm
    assert res.late_dropped == 5
    assert not res.accepted.any()
    # late rows never materialize: closed sessions and totals untouched.
    assert res.closed_sessions == 0 and res.open_sessions == 0
    after = s.result()
    assert np.array_equal(before.ngram_counts, after.ngram_counts)
    assert before.num_sessions() == after.num_sessions()
    assert after.late_dropped == 5


def test_watermark_is_monotone():
    from repro.data.streampipe import single_host_stream
    s = single_host_stream(_cfg())
    u, se, ts, c, ip = _events(10, seed=3)
    s.tick(u, se, ts, c, ip, watermark=500)
    res = s.tick(u, se, np.maximum(ts, 500), c, ip, watermark=100)
    assert res.watermark == 500 and s.watermark == 500


def test_flush_closes_everything_and_matches_full_batch():
    from repro.data.streampipe import (batch_closed_prefix, replay,
                                       assert_stream_equals_batch,
                                       single_host_stream, WATERMARK_MAX)
    cfg = _cfg(allowed_lateness_ms=60_000)
    stages = [np.array([1, 2]), np.array([5])]
    s = single_host_stream(cfg, stages)
    u, se, ts, c, ip = _events(200, seed=4)
    replay(s, u, se, ts, c, ip, n_ticks=4)
    assert s.watermark == WATERMARK_MAX
    last = s.flush()
    assert last.open_sessions == 0 and s.watermark_lag_ms == 0
    oracle = batch_closed_prefix(cfg, stages, u, se, ts, c, ip,
                                 np.ones(len(u), bool), WATERMARK_MAX)
    assert_stream_equals_batch(s, oracle)


def test_ring_overflow_counted_surviving_sessions_unaffected():
    """More open sessions than max_open: overflow sessions are dropped
    whole and counted; survivors' final sessions stay bit-exact."""
    from repro.data.streampipe import single_host_stream
    cfg = _cfg(max_open=2)
    s = single_host_stream(cfg)
    users = np.array([10, 20, 30, 40], np.int64)
    zeros = np.zeros(4, np.int64)
    # tick 1: one event per user, all open -> users 30, 40 overflow out.
    r1 = s.tick(users, zeros, np.arange(1000, 1004, dtype=np.int64),
                np.arange(4, dtype=np.int32), watermark=0)
    assert r1.ring_dropped_sessions == 2 and r1.ring_dropped_events == 2
    assert r1.open_sessions == 2
    # tick 2: a second event per user; 30/40 re-open (first event lost)
    # and overflow out again.
    r2 = s.tick(users, zeros, np.arange(2000, 2004, dtype=np.int64),
                np.arange(4, 8, dtype=np.int32), watermark=0)
    assert r2.ring_dropped_sessions == 2 and r2.ring_dropped_events == 2
    s.flush()
    seqs = s.sessions()
    got = {int(seqs.user_id[j]):
           [int(x) for x in seqs.symbols[j][:int(seqs.length[j])]]
           for j in range(len(seqs))}
    # survivors (lowest-sorting users) carry both events, untouched by the
    # drops; overflowed users lost everything.
    assert got == {10: [0, 4], 20: [1, 5]}
    assert s.ring_dropped_events == 4 and s.ring_dropped_sessions == 4


def test_tick_capacity_exceeded_raises():
    from repro.data.streampipe import single_host_stream
    s = single_host_stream(_cfg(tick_capacity=8))
    u, se, ts, c, ip = _events(9, seed=5)
    with pytest.raises(ValueError, match="tick_capacity"):
        s.tick(u, se, ts, c, ip)


def test_stream_state_structs_shapes():
    from repro.data.streampipe import stream_state_structs
    cfg = _cfg(max_open=32, max_len=16)
    flat = stream_state_structs(cfg)
    assert flat["symbols"].shape == (32, 16)
    assert flat["user_id"].shape == (32,)
    sharded = stream_state_structs(cfg, n_shards=8)
    assert sharded["event_ts"].shape == (8, 32, 16)
    assert sharded["valid"].dtype == bool


# ---------------------------------------------------------------------------
# zero-retrace discipline
# ---------------------------------------------------------------------------

def test_streaming_tick_never_retraces():
    """After the first tick per config, every later tick — mid-stream,
    empty, flush, even from a *second* stream instance with the same
    config — must hit the jit cache (mirrors test_serve trace_counts)."""
    from repro.data.streampipe import replay, single_host_stream
    cfg = _cfg(allowed_lateness_ms=777)  # unique cfg -> fresh jit cache
    s = single_host_stream(cfg)
    u, se, ts, c, ip = _events(150, seed=6)
    replay(s, u, se, ts, c, ip, n_ticks=5)  # 5 ticks + flush
    assert s.trace_counts["tick"] == 1
    s2 = single_host_stream(cfg)
    replay(s2, u, se, ts, c, ip, n_ticks=3)
    assert s2.trace_counts is s.trace_counts
    assert s2.trace_counts["tick"] == 1


# ---------------------------------------------------------------------------
# property tests: closed-prefix bit-equality at every watermark
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6))
def test_property_shuffled_late_streams_match_oracle(seed):
    """Arbitrary arrival order: events land in random ticks, so many are
    late (dropped + counted); the closed prefix of *accepted* events must
    bit-equal the batch oracle at every watermark."""
    from repro.data.streampipe import replay, single_host_stream
    u, se, ts, c, ip = _events(192, seed=seed)
    rng = np.random.default_rng(seed + 1)
    ticks = list(np.array_split(rng.permutation(len(u)), 4))
    s = single_host_stream(_cfg(allowed_lateness_ms=60_000),
                           stages=[np.array([1, 2]), np.array([5])])
    replay(s, u, se, ts, c, ip, tick_index=ticks,
           assert_closed_prefix=True)
    assert not s.truncated and s.ring_dropped_sessions == 0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6))
def test_property_duplicates_within_and_across_ticks(seed):
    """Exact retry duplicates — in the same tick as the original or ticks
    later — never change closed sessions or rollup totals (cross-tick
    dedup runs against the ring's stored per-event keys)."""
    from repro.data.streampipe import replay, single_host_stream
    u, se, ts, c, ip = _events(160, seed=seed)
    rng = np.random.default_rng(seed + 2)
    src = rng.choice(160, 48, replace=False)
    cols = tuple(np.concatenate([a, a[src]]) for a in (u, se, ts, c, ip))
    order = np.argsort(cols[2], kind="stable")
    # originals in time order; dupes of rows from any earlier tick are
    # appended to later ticks (and some share a tick with their original).
    ticks = list(np.array_split(order, 4))
    s = single_host_stream(_cfg(tick_capacity=128, allowed_lateness_ms=0))
    replay(s, *cols, tick_index=ticks, assert_closed_prefix=True)
    assert not s.truncated


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6))
def test_property_time_ordered_ticks_drop_nothing(seed):
    """The log mover's arrival order (time-sorted ticks) with zero allowed
    lateness: no event is ever late, and the post-flush result equals the
    whole-batch oracle exactly."""
    from repro.data.streampipe import replay, single_host_stream
    u, se, ts, c, ip = _events(192, seed=seed)
    s = single_host_stream(_cfg())
    results = replay(s, u, se, ts, c, ip, n_ticks=4,
                     assert_closed_prefix=True)
    assert s.late_dropped == 0 and s.ring_dropped_events == 0
    assert sum(r.closed_sessions for r in results) == s.closed_total


# ---------------------------------------------------------------------------
# a full loggen day vs the batch pipeline (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_loggen_day_replay_bit_equal_at_every_watermark(loggen_corpus):
    from repro.data.distpipe import single_host_pipeline
    from repro.data.streampipe import (StreamConfig, replay,
                                       session_multiset,
                                       single_host_stream)
    lc = loggen_corpus
    cfg = StreamConfig(alphabet_size=lc.alphabet_size, max_open=128,
                       max_len=128, tick_capacity=1024,
                       allowed_lateness_ms=60_000)
    s = single_host_stream(cfg, stages=lc.stages)
    replay(s, lc.user_id, lc.session_id, lc.timestamp, lc.code, lc.ip,
           n_ticks=12, assert_closed_prefix=True)
    assert s.trace_counts["tick"] == 1
    assert s.late_dropped == 0 and s.ring_dropped_sessions == 0
    assert not s.truncated
    got = s.result()
    oracle = single_host_pipeline(
        lc.user_id, lc.session_id, lc.timestamp, lc.code, lc.ip,
        cfg=cfg.batch_config(lc.n_events), stages=lc.stages)
    assert np.array_equal(got.ngram_counts, oracle.ngram_counts)
    assert got.funnel_reach == oracle.funnel_reach
    assert session_multiset(got.sequences) == \
        session_multiset(oracle.sequences)


# ---------------------------------------------------------------------------
# distributed streaming path
# ---------------------------------------------------------------------------

def test_stream_pipeline_single_shard_matches_single_host():
    import jax
    from repro.data.streampipe import (make_stream_pipeline, replay,
                                       session_multiset,
                                       single_host_stream)
    cfg = _cfg(allowed_lateness_ms=30_000)
    stages = [np.array([1, 2]), np.array([5])]
    u, se, ts, c, ip = _events(200, seed=9)
    sp = make_stream_pipeline(jax.make_mesh((1,), ("data",)), cfg, stages)
    sh = single_host_stream(cfg, stages)
    replay(sp, u, se, ts, c, ip, n_ticks=4)
    replay(sh, u, se, ts, c, ip, n_ticks=4)
    assert sp.trace_counts["tick"] == 1
    a, b = sp.result(), sh.result()
    assert a.shuffle_dropped == 0
    assert np.array_equal(a.ngram_counts, b.ngram_counts)
    assert a.funnel_reach == b.funnel_reach
    assert session_multiset(a.sequences) == session_multiset(b.sequences)


def test_repartition_overflow_counted_never_silent():
    import jax
    from repro.data.streampipe import make_stream_pipeline, replay
    cfg = _cfg(capacity_factor=0.25)  # undersized all_to_all buckets
    u, se, ts, c, ip = _events(200, seed=10, n_users=2)
    sp = make_stream_pipeline(jax.make_mesh((1,), ("data",)), cfg)
    replay(sp, u, se, ts, c, ip, n_ticks=4)
    assert sp.result().shuffle_dropped > 0


def test_8shard_stream_matches_single_host():
    _run("""
    from repro.data.streampipe import (StreamConfig, make_stream_pipeline,
                                       replay, session_multiset,
                                       single_host_stream)
    rng = np.random.default_rng(11)
    n = 512
    user = rng.integers(0, 60, n).astype(np.int64) * 7919
    sess = rng.integers(0, 3, n).astype(np.int64)
    ts = rng.integers(0, 2 * 10**7, n).astype(np.int64)
    code = rng.integers(0, 16, n).astype(np.int32)
    ip = rng.integers(0, 1 << 32, n).astype(np.int64)
    stages = [np.array([1, 2]), np.array([5])]
    cfg = StreamConfig(alphabet_size=16, max_open=96, max_len=64,
                       tick_capacity=128, capacity_factor=8.0,
                       allowed_lateness_ms=60_000)
    ticks = list(np.array_split(rng.permutation(n), 4))
    sp = make_stream_pipeline(jax.make_mesh((8,), ("data",)), cfg, stages)
    sh = single_host_stream(cfg, stages)
    replay(sp, user, sess, ts, code, ip, tick_index=ticks)
    replay(sh, user, sess, ts, code, ip, tick_index=ticks)
    a, b = sp.result(), sh.result()
    assert a.shuffle_dropped == 0
    assert a.late_dropped == b.late_dropped > 0
    assert np.array_equal(a.ngram_counts, b.ngram_counts)
    assert a.funnel_reach == b.funnel_reach
    assert session_multiset(a.sequences) == session_multiset(b.sequences)
    assert sp.trace_counts["tick"] == 1
    print("OK")
    """)


# ---------------------------------------------------------------------------
# benchmarks/run.py --only selector (satellite fix)
# ---------------------------------------------------------------------------

def _sections():
    return {n: None for n in ("compression", "pipeline_tput", "serve_tput")}


def test_select_sections_accepts_commas_and_spaces():
    sys.path.insert(0, REPO_ROOT)
    try:
        from benchmarks.run import select_sections
    finally:
        sys.path.pop(0)
    secs = _sections()
    assert select_sections(["pipeline_tput,serve_tput"], secs) == \
        ["pipeline_tput", "serve_tput"]
    assert select_sections(["compression", "pipeline_tput,compression"],
                           secs) == ["compression", "pipeline_tput"]


def test_select_sections_unknown_name_errors_loudly():
    sys.path.insert(0, REPO_ROOT)
    try:
        from benchmarks.run import select_sections
    finally:
        sys.path.pop(0)
    with pytest.raises(ValueError, match="stream_tputt"):
        select_sections(["pipeline_tput,stream_tputt"], _sections())
    with pytest.raises(ValueError, match="available"):
        select_sections(["nope"], _sections())
