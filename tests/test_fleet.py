"""Multi-replica serving fleet (repro.serve.fleet) + the satellites that
ride along with it: occupancy gossip, routing policies (rr / JSQ /
prefix-affinity with spill), fleet-vs-single bit-equality on the real
smoke model, ``merge_summaries`` metrics properties (request-level merge
== one combined stream, ttft decomposition, stable percentile keys),
the segment store's disk aging (``Store.evict_to_disk``), and the
``Server`` scheduler-cache LRU cap.

Machinery tests run on the deterministic stub ModelApi from
``test_serve`` (fast, exact expected outputs); one test drives the real
smoke behaviour LM so routing is proven output-invariant end to end.
"""
import warnings

import jax
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.configs import smoke_config
from repro.data.pipeline import EOS_ID
from repro.data.store import Store, StoreConfig
from repro.dist import gossip_all_gather, make_host_mesh
from repro.models.registry import get_model
from repro.serve import (ContinuousScheduler, FleetConfig, ReplicaRouter,
                         Server, ServeConfig, ServeMetrics, merge_metrics,
                         merge_summaries, prefix_hashes)
from repro.serve.fleet import GOSSIP_ACTIVE, GOSSIP_FREE, GOSSIP_PENDING

from test_serve import SchedulerConfig, VOCAB, _stub_api, _stub_expected
from test_store import _events, _write

EOS_AFTER = 50  # stub never EOSes early: budgets control lifetimes


@pytest.fixture(scope="module")
def dense():
    cfg = smoke_config("behavior-lm-100m").with_(vocab_size=VOCAB,
                                                 max_cache_len=64)
    api = get_model(cfg)
    return api, api.init(jax.random.PRNGKey(0))


def _prompts(rng, n, lo=3, hi=9):
    return [rng.integers(4, VOCAB, int(rng.integers(lo, hi))).astype(np.int32)
            for _ in range(n)]


def _paged_cfg(**kw):
    kw.setdefault("batch", 4)
    kw.setdefault("buckets", (8, 16))
    kw.setdefault("max_new_tokens", 4)
    kw.setdefault("paged", True)
    kw.setdefault("block_size", 4)
    kw.setdefault("num_blocks", 31)
    return SchedulerConfig(**kw)


# ---------------------------------------------------------------------------
# scheduler surface the router builds on
# ---------------------------------------------------------------------------

def test_occupancy_snapshot_tracks_queue_and_pool():
    sched = ContinuousScheduler(_stub_api(EOS_AFTER), {}, _paged_cfg())
    free0 = sched.pool.free_blocks
    assert sched.occupancy_snapshot().tolist() == [free0, 0, 0]
    assert not sched.has_work
    for p in _prompts(np.random.default_rng(0), 6):
        sched.submit(p, max_new_tokens=4)
    snap = sched.occupancy_snapshot()
    assert snap[GOSSIP_PENDING] == 6 and snap[GOSSIP_ACTIVE] == 0
    assert snap.dtype == np.int32 and snap.shape == (3,)
    sched.step()
    snap = sched.occupancy_snapshot()
    assert snap[GOSSIP_ACTIVE] == sched.num_active > 0
    assert snap[GOSSIP_PENDING] == sched.num_pending
    assert snap[GOSSIP_FREE] < free0          # admitted rows hold blocks
    sched.run()
    assert sched.occupancy_snapshot().tolist() == [free0, 0, 0]


def test_step_once_is_noop_when_idle():
    sched = ContinuousScheduler(_stub_api(EOS_AFTER), {}, _paged_cfg())
    before = sched.decode_steps
    assert sched.step_once() == {}
    assert sched.decode_steps == before
    sched.submit(np.arange(4, 9, dtype=np.int32), max_new_tokens=2)
    assert sched.has_work
    emitted = {}
    while sched.has_work:
        emitted.update(sched.step_once())
    assert 0 in emitted and sched.decode_steps > before


def test_chain_hits_is_read_only():
    sched = ContinuousScheduler(
        _stub_api(EOS_AFTER), {},
        _paged_cfg(prefix_cache=True, max_new_tokens=6))
    p = np.arange(4, 16, dtype=np.int32)      # 3 full 4-token blocks
    sched.submit(p, max_new_tokens=6)
    sched.step()                              # admit: registers the chain
    hashes = prefix_hashes(p, 4)
    free = sched.pool.free_blocks
    hits = sched.pool.chain_hits(hashes)
    assert hits == len(hashes) > 0
    assert sched.pool.chain_hits(hashes) == hits      # idempotent
    assert sched.pool.free_blocks == free             # no allocation
    assert sched.pool.chain_hits([b"no-such-hash"]) == 0
    # a chain broken at link 0 scores 0 even if later links were resident
    assert sched.pool.chain_hits([b"missing"] + hashes) == 0
    sched.run()
    assert sched.pool.chain_hits(hashes) == 0         # registry died


# ---------------------------------------------------------------------------
# gossip all-gather
# ---------------------------------------------------------------------------

def test_gossip_all_gather_host_local_identity():
    vecs = np.array([[5, 1, 2], [9, 0, 3]], np.int64)
    out = gossip_all_gather(vecs, mesh=None)
    assert out.dtype == np.int32
    assert np.array_equal(out, vecs)
    with pytest.raises(ValueError):
        gossip_all_gather(np.array([1, 2, 3]))        # not (n, width)


def test_gossip_all_gather_mesh_path():
    mesh = make_host_mesh(data=1, model=1)
    vecs = np.array([[5, 1, 2], [9, 0, 3], [7, 7, 7]], np.int32)
    out = gossip_all_gather(vecs, mesh=mesh, axis="data")
    assert np.array_equal(out, vecs)
    # row count must tile over the gossip axis
    with pytest.raises(ValueError):
        gossip_all_gather(vecs, mesh=make_host_mesh(data=2, model=1),
                          axis="data")


# ---------------------------------------------------------------------------
# routing policies (stub model: outputs exactly predictable)
# ---------------------------------------------------------------------------

def test_fleet_config_validation():
    with pytest.raises(ValueError, match="replicas"):
        FleetConfig(replicas=0)
    with pytest.raises(ValueError, match="route"):
        FleetConfig(route="random")
    with pytest.raises(ValueError, match="affinity"):
        ReplicaRouter(_stub_api(EOS_AFTER), {}, _paged_cfg(),
                      FleetConfig(replicas=2, route="affinity"))


def test_round_robin_cycles_replicas():
    router = ReplicaRouter(_stub_api(EOS_AFTER), {}, _paged_cfg(),
                           FleetConfig(replicas=3, route="rr"))
    prompts = _prompts(np.random.default_rng(1), 7)
    rids = [router.submit(p, max_new_tokens=3) for p in prompts]
    assert rids == list(range(7))             # global rids: submit order
    assert router.routed.tolist() == [3, 2, 2]
    outs = router.run()
    assert not router.has_work
    for rid, p in zip(rids, prompts):
        np.testing.assert_array_equal(
            outs[rid], _stub_expected(p, 3, EOS_AFTER))


def test_jsq_balances_and_respects_since_gossip_delta():
    router = ReplicaRouter(_stub_api(EOS_AFTER), {}, _paged_cfg(),
                           FleetConfig(replicas=4, route="jsq"))
    # all submits land between gossip ticks: only the since-gossip delta
    # can tell the replicas apart, so JSQ must still spread the burst
    for p in _prompts(np.random.default_rng(2), 8):
        router.submit(p, max_new_tokens=3)
    assert sorted(router.routed.tolist()) == [2, 2, 2, 2]
    router.run()
    # a loaded replica is avoided: stuff replica 0's queue out-of-band,
    # refresh gossip, and the next routed request must land on replica 1
    rr = ReplicaRouter(_stub_api(EOS_AFTER), {}, _paged_cfg(),
                       FleetConfig(replicas=2, route="jsq"))
    rr.replicas[0].submit(np.arange(4, 8, dtype=np.int32), max_new_tokens=3)
    rr.replicas[0].submit(np.arange(4, 8, dtype=np.int32), max_new_tokens=3)
    rr._gossip_tick()
    rr.submit(np.arange(4, 8, dtype=np.int32), max_new_tokens=3)
    assert rr.routed.tolist() == [0, 1]
    for rep in rr.replicas:      # out-of-band submits have no global rid:
        rep.run()                # drain replicas directly


def test_affinity_routes_hot_replica_and_spills_when_saturated():
    fleet = FleetConfig(replicas=2, route="affinity", spill_queue=3)
    router = ReplicaRouter(
        _stub_api(EOS_AFTER), {},
        _paged_cfg(prefix_cache=True, max_new_tokens=6, num_blocks=63),
        fleet)
    prefix = np.arange(4, 16, dtype=np.int32)         # 3 full blocks
    tails = [np.array([20 + i], np.int32) for i in range(9)]
    # cold submit falls through to JSQ (replica 0 by tie-break)
    router.submit(np.concatenate([prefix, tails[0]]), max_new_tokens=6)
    assert router.routed.tolist() == [1, 0]
    router.step()                                     # admit -> registry hot
    # warm submits chase the resident chain on replica 0
    for t in tails[1:4]:
        router.submit(np.concatenate([prefix, t]), max_new_tokens=6)
        router.step()
    assert router.routed.tolist() == [4, 0]
    # replica 0's 4 slots are now all in flight; pile hot submits onto its
    # queue without stepping — once the backlog (gossiped pending=1: the
    # last tick snapshotted before that round's admit, plus the
    # since-gossip delta) reaches spill_queue=3, affinity must spill the
    # remainder to replica 1
    for t in tails[4:]:
        router.submit(np.concatenate([prefix, t]), max_new_tokens=6)
    assert router.routed.tolist() == [6, 3], \
        "saturated hot replica never spilled"
    outs = router.run()
    assert not router.has_work
    np.testing.assert_array_equal(
        outs[0], _stub_expected(np.concatenate([prefix, tails[0]]),
                                6, EOS_AFTER))


def test_fleet_summary_merges_replica_metrics():
    router = ReplicaRouter(_stub_api(EOS_AFTER), {}, _paged_cfg(),
                           FleetConfig(replicas=2, route="rr"))
    prompts = _prompts(np.random.default_rng(3), 6)
    for p in prompts:
        router.submit(p, max_new_tokens=3)
    router.run()
    s = router.summary()
    assert s["requests"] == 6
    assert s["tokens"] == 18
    assert s["fleet"]["replicas"] == 2
    assert s["fleet"]["route"] == "rr"
    assert s["fleet"]["routed_per_replica"] == [3, 3]
    assert s["fleet"]["admitted_per_replica"] == [3, 3]
    assert s["fleet"]["load_imbalance"] == 1.0
    assert s["fleet"]["gossip_ticks"] == router.gossip_ticks > 0


def test_fleet_bit_equal_to_single_replica_real_model(dense):
    api, params = dense
    cfg = _paged_cfg(batch=4, buckets=(16,), max_new_tokens=4,
                     block_size=8, num_blocks=31)
    prompts = _prompts(np.random.default_rng(4), 10, lo=3, hi=15)

    single = ContinuousScheduler(api, params, cfg)
    for p in prompts:
        single.submit(p, max_new_tokens=4)
    oracle = single.run()

    for route in ("rr", "jsq"):
        router = ReplicaRouter(api, params, cfg,
                               FleetConfig(replicas=2, route=route))
        rids = [router.submit(p, max_new_tokens=4) for p in prompts]
        outs = router.run()
        for gi, (rid, _) in enumerate(zip(rids, prompts)):
            np.testing.assert_array_equal(outs[rid], oracle[gi],
                                          err_msg=f"route={route} rid={rid}")


# ---------------------------------------------------------------------------
# metrics merge properties
# ---------------------------------------------------------------------------

def _fake_stream(events, k):
    """Replay a list of (submit, queue_wait, prefill, decode_ticks) request
    timelines into one combined ServeMetrics and K split parts
    (round-robin), driving every instance off the same fake clock."""
    clock = lambda: _fake_stream.now                   # noqa: E731
    combined = ServeMetrics(clock=clock)
    parts = [ServeMetrics(clock=clock) for _ in range(k)]
    rid_maps = [dict() for _ in range(k)]
    locals_ = [0] * k
    for rid, (t0, qw, pf, dec) in enumerate(events):
        i = rid % k
        local = locals_[i]
        locals_[i] += 1
        rid_maps[i][local] = rid
        for m, r in ((combined, rid), (parts[i], local)):
            _fake_stream.now = float(t0)
            m.record_submit(r, prompt_len=5, priority=rid % 2)
            _fake_stream.now = float(t0 + qw)
            m.record_admit(r)
            _fake_stream.now = float(t0 + qw + pf)
            m.record_token(r)
            for d in range(dec):
                _fake_stream.now = float(t0 + qw + pf + 1 + d)
                m.record_token(r)
            m.record_finish(r)
    return combined, parts, rid_maps


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=1, max_size=24),
       st.integers(1, 5))
def test_merge_summaries_equals_combined_stream(starts, k):
    rng = np.random.default_rng(len(starts) * 31 + k)
    events = [(t0, int(rng.integers(0, 9)), int(rng.integers(1, 4)),
               int(rng.integers(0, 6))) for t0 in starts]
    combined, parts, rid_maps = _fake_stream(events, k)
    merged = merge_summaries(parts, rid_maps=rid_maps)
    fleet = merged.pop("fleet")
    assert merged == combined.summary()
    assert fleet["replicas"] == k
    assert sum(fleet["admitted_per_replica"]) == len(events)
    m = merge_metrics(parts, rid_maps=rid_maps)
    assert set(m.requests) == set(range(len(events)))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 40), min_size=1, max_size=16))
def test_ttft_decomposes_into_queue_wait_plus_admitted_ttft(starts):
    rng = np.random.default_rng(sum(starts) + len(starts))
    events = [(t0, int(rng.integers(0, 7)), int(rng.integers(1, 5)),
               int(rng.integers(0, 3))) for t0 in starts]
    combined, _, _ = _fake_stream(events, 1)
    for r in combined.requests.values():
        ttft = r.first_token - r.submit
        qwait = r.admit - r.submit
        attft = r.first_token - r.admit
        assert ttft == pytest.approx(qwait + attft)
    s = combined.summary()
    # the decomposition holds for the extreme percentiles too: every
    # component is non-negative, so p99 ttft is bounded by the sum
    assert s["p99_ttft_s"] <= s["p99_queue_wait_s"] + s["p99_ttft_admit_s"]


def test_summary_percentile_keys_stable():
    expected = {f"p{q}_{w}_s" for q in (50, 99)
                for w in ("latency", "ttft", "queue_wait", "ttft_admit")}
    empty = ServeMetrics().summary()
    combined, parts, rid_maps = _fake_stream([(0, 1, 1, 2), (3, 0, 2, 1)], 2)
    merged = merge_summaries(parts, rid_maps=rid_maps)
    for s in (empty, combined.summary(), merged):
        assert expected <= set(s)
        for p, q in (("p50", "p99"),):
            for w in ("latency", "ttft", "queue_wait", "ttft_admit"):
                assert s[f"{p}_{w}_s"] <= s[f"{q}_{w}_s"]


def test_merge_rid_collision_raises():
    combined, parts, _ = _fake_stream([(0, 1, 1, 1), (2, 1, 1, 1)], 2)
    with pytest.raises(ValueError, match="rid 0 appears"):
        merge_metrics(parts)                  # both parts used local rid 0
    assert merge_metrics([]).summary()["requests"] == 0


# ---------------------------------------------------------------------------
# store disk aging
# ---------------------------------------------------------------------------

WIDE = 10 * 30 * 60 * 1000    # 5h of hourly folds -> several segments


def _aged_store(cols, n_writes=6):
    store = _write(Store(StoreConfig(max_len=64)), cols, n_writes)
    for q in (25, 50, 75):
        store.compact(int(np.percentile(cols[2], q)))
    store.compact()
    return store


def test_evict_to_disk_scan_transparent(tmp_path):
    cols = _events(600, seed=33, ts_hi=WIDE)
    store = _aged_store(cols)
    before = store.scan()
    n_sessions = sum(1 for g in store.segments if g.kind == "sessions")
    assert n_sessions >= 3
    n = store.evict_to_disk(1, path=str(tmp_path))
    assert n == n_sessions - 1 == store.segments_evicted
    assert sum(1 for g in store.segments if g.on_disk) == n
    assert all(g.blob == b"" and g.disk_bytes > 0
               for g in store.segments if g.on_disk)
    after = store.scan()
    assert after.stats.segments_on_disk == n
    assert after.stats.segments_reloaded == n          # full scan: all back
    np.testing.assert_array_equal(after.sequences.symbols,
                                  before.sequences.symbols)
    np.testing.assert_array_equal(after.sequences.user_id,
                                  before.sequences.user_id)
    # reloads are transient: the store itself still holds only the cap
    assert sum(1 for g in store.segments if g.on_disk) == n
    assert store.segments_reloaded == n
    s = store.summary()
    assert s["segments_on_disk"] == n and s["segments_evicted"] == n


def test_evict_pruned_scan_skips_disk_reads(tmp_path):
    cols = _events(800, seed=34, ts_hi=WIDE)
    store = _aged_store(cols)
    store.evict_to_disk(0, path=str(tmp_path))         # everything on disk
    lo = int(np.percentile(cols[2], 45))
    hi = int(np.percentile(cols[2], 55))
    narrow = store.scan(time_range=(lo, hi))
    full = store.scan()
    # metadata pruning happens before any disk read: a windowed scan
    # reloads strictly fewer evicted segments than the full scan
    assert narrow.stats.segments_reloaded < full.stats.segments_reloaded
    assert narrow.stats.segments_on_disk == full.stats.segments_on_disk


def test_evict_cap_is_sticky_across_compactions(tmp_path):
    cols = _events(500, seed=35, ts_hi=WIDE)
    t = cols[2]
    mid = t < np.percentile(t, 50)
    early = tuple(a[mid] for a in cols)
    late = tuple(a[~mid] for a in cols)
    store = _write(Store(StoreConfig(max_len=64)), early, 3)
    store.compact()
    store.evict_to_disk(1, path=str(tmp_path))
    u, s_, ts, c, ip = late
    store.append_events(u, s_, ts, c, ip)
    store.compact()                                    # new segments fold in
    resident = [g for g in store.segments
                if g.kind == "sessions" and not g.on_disk]
    assert len(resident) <= 1, "sticky cap ignored by later compaction"


def test_evict_save_load_round_trip(tmp_path):
    cols = _events(400, seed=36, ts_hi=WIDE)
    store = _aged_store(cols)
    want = store.scan().sequences
    store.evict_to_disk(0, path=str(tmp_path / "spill"))
    store.save(str(tmp_path / "saved"))                # materializes blobs
    loaded = Store.load(str(tmp_path / "saved"))
    assert not any(g.on_disk for g in loaded.segments)
    got = loaded.scan().sequences
    np.testing.assert_array_equal(got.symbols, want.symbols)
    np.testing.assert_array_equal(got.user_id, want.user_id)


def test_evict_validation(tmp_path):
    store = Store(StoreConfig(max_len=64))
    with pytest.raises(ValueError, match=">= 0"):
        store.evict_to_disk(-1, path=str(tmp_path))
    with pytest.raises(ValueError):
        store.evict_to_disk(1)                         # no spill dir yet


# ---------------------------------------------------------------------------
# Server scheduler-cache LRU cap
# ---------------------------------------------------------------------------

def test_scheduler_cache_lru_evicts_loudly():
    api = _stub_api(EOS_AFTER)
    srv = Server(api, {}, ServeConfig(max_new_tokens=3, max_schedulers=2))
    rng = np.random.default_rng(5)

    def gen(b, width):
        prompts = rng.integers(4, VOCAB, (b, width)).astype(np.int32)
        return prompts, srv.generate(prompts)

    with warnings.catch_warnings():
        warnings.simplefilter("error")                 # no warning yet
        gen(1, 5)
        gen(2, 5)
    assert len(srv._schedulers) == 2 and srv.scheduler_evictions == 0
    with pytest.warns(RuntimeWarning, match=r"\(batch, bucket\)=\(1, 8\)"):
        gen(3, 5)                                      # evicts the coldest
    assert len(srv._schedulers) == 2 and srv.scheduler_evictions == 1
    assert (1, 8) not in srv._schedulers
    # the evicted shape still serves correctly (recompiled, another evict)
    with pytest.warns(RuntimeWarning):
        p, out = gen(1, 6)
    np.testing.assert_array_equal(
        out[0], np.pad(_stub_expected(p[0], 3, EOS_AFTER), (0, 0)))
    assert srv.scheduler_evictions == 2
    # LRU order, not insertion order: touching a shape protects it
    srv2 = Server(api, {}, ServeConfig(max_new_tokens=3, max_schedulers=2))
    srv2.generate(rng.integers(4, VOCAB, (1, 5)).astype(np.int32))
    srv2.generate(rng.integers(4, VOCAB, (2, 5)).astype(np.int32))
    srv2.generate(rng.integers(4, VOCAB, (1, 5)).astype(np.int32))  # touch
    with pytest.warns(RuntimeWarning, match=r"=\(2, 8\)"):
        srv2.generate(rng.integers(4, VOCAB, (3, 5)).astype(np.int32))
    assert (1, 8) in srv2._schedulers and (2, 8) not in srv2._schedulers
