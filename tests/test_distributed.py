"""Multi-device behaviour on host devices — run in subprocesses so the
8-device XLA flag never leaks into the rest of the suite."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, {REPO_SRC!r})
        import numpy as np, jax, jax.numpy as jnp
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_sessionize_matches_oracle():
    _run("""
    from repro.core.distributed import make_distributed_sessionize
    from repro.core import oracle
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(1)
    N = 4096
    user = rng.integers(0, 150, N).astype(np.int64) * 7919
    sess = rng.integers(0, 2, N).astype(np.int64)
    ts = (1.7e12 + rng.integers(0, 2*3600*1000, N)).astype(np.int64)
    code = rng.integers(0, 64, N).astype(np.int32)
    f = make_distributed_sessionize(mesh, "data",
                                    max_sessions_per_shard=1024, max_len=256)
    out, dropped = f(user, sess, ts, code)
    assert dropped == 0
    ora = oracle.sessionize_oracle(user, sess, ts, code)
    total = int(np.asarray(out["num_sessions"]).sum())
    assert total == len(ora), (total, len(ora))
    got = []
    ns = np.asarray(out["num_sessions"])
    for sh in range(8):
        for i in range(int(ns[sh])):
            got.append((int(np.asarray(out["user_id"])[sh, i]),
                        int(np.asarray(out["length"])[sh, i])))
    assert sorted(got) == sorted((o["user_id"], o["length"]) for o in ora)
    print("OK")
    """)


def test_distributed_histogram():
    _run("""
    from repro.core.distributed import make_distributed_histogram
    from repro.core import oracle
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(2)
    ids = rng.integers(0, 31, 4096).astype(np.int32)
    f = make_distributed_histogram(mesh, "data", num_names=31)
    h = f(ids)
    assert np.array_equal(h, oracle.histogram_oracle(ids, 31))
    print("OK")
    """)


def test_moe_ep_on_real_mesh():
    _run("""
    from repro.models.config import ModelConfig
    from repro.models import moe as M
    from repro.dist import make_mesh, use_mesh
    from repro.dist.sharding import ShardingRules, REPLICATED
    cfg = ModelConfig(num_layers=1, d_model=32, d_ff=64, vocab_size=50,
                      num_experts=8, experts_per_token=2, dtype="float32",
                      moe_capacity_factor=8.0)
    p = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32), jnp.float32)
    y_dense, _ = M.moe_ffn_dense(x, p, cfg, REPLICATED)
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = ShardingRules(batch=("data",), expert="model", embed="data")
    with use_mesh(mesh):
        y_ep, drops = jax.jit(
            lambda xx, pp: M.moe_ffn_ep(xx, pp, cfg, rules, mesh))(x, p)
    assert int(drops) == 0
    np.testing.assert_allclose(y_dense, np.asarray(y_ep), rtol=1e-5,
                               atol=1e-5)
    print("OK")
    """)


def test_sharded_train_step_and_elastic_reshard():
    _run("""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import ModelConfig, get_model
    from repro.dist import use_mesh
    from repro.dist.sharding import ShardingRules, adapt_rules_for_mesh
    from repro.train import (OptConfig, init_opt_state, make_train_step)
    from repro.train.elastic import state_shardings, reshard_state
    from repro.launch.mesh import make_host_mesh

    cfg = ModelConfig(name="d", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128,
                      dtype="float32", remat="none")
    rules = ShardingRules(batch=("data",))
    mesh1 = make_host_mesh(data=2, model=4)
    api = get_model(cfg, mesh1, adapt_rules_for_mesh(rules, mesh1))
    params = api.init(jax.random.PRNGKey(0))
    ocfg = OptConfig(lr=1e-2)
    state = dict(params=params, opt=init_opt_state(params, ocfg))
    rng = np.random.default_rng(0)
    toks = rng.integers(4, 128, (8, 17)).astype(np.int32)
    batch = dict(tokens=toks[:, :-1], targets=toks[:, 1:],
                 loss_mask=np.ones((8, 16), np.float32))

    sh1 = state_shardings(api, mesh1, rules)
    state1 = jax.tree.map(jax.device_put, state, sh1)
    with use_mesh(mesh1):
        step1 = jax.jit(make_train_step(api, ocfg))
        s_after1, m1 = step1(state1, batch)

    # single-device reference
    api0 = get_model(cfg)
    s_ref, m_ref = make_train_step(api0, ocfg)(state, batch)
    for a, b in zip(jax.tree.leaves(s_after1["params"]),
                    jax.tree.leaves(s_ref["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)

    # elastic: reshard the live state onto a different mesh and keep going
    mesh2 = make_host_mesh(data=4, model=2)
    api2 = get_model(cfg, mesh2, adapt_rules_for_mesh(rules, mesh2))
    state2 = reshard_state(s_after1, api2, mesh2, rules)
    with use_mesh(mesh2):
        step2 = jax.jit(make_train_step(api2, ocfg))
        s_after2, m2 = step2(state2, batch)
    s_ref2, _ = make_train_step(api0, ocfg)(s_ref, batch)
    for a, b in zip(jax.tree.leaves(s_after2["params"]),
                    jax.tree.leaves(s_ref2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)
    print("OK")
    """)


def test_restore_checkpoint_on_new_mesh(tmp_path):
    _run(f"""
    from repro.models import ModelConfig, get_model
    from repro.dist.sharding import ShardingRules, adapt_rules_for_mesh
    from repro.train import OptConfig, init_opt_state, CheckpointManager
    from repro.train.elastic import restore_on_mesh
    from repro.launch.mesh import make_host_mesh

    cfg = ModelConfig(name="d", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=128,
                      dtype="float32", remat="none")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(7))
    state = dict(params=params, opt=init_opt_state(params, OptConfig()))
    mgr = CheckpointManager({str(tmp_path)!r})
    mgr.save(5, state)

    mesh = make_host_mesh(data=4, model=2)
    rules = ShardingRules(batch=("data",))
    restored = restore_on_mesh({str(tmp_path)!r}, state,
                               get_model(cfg, mesh), mesh, rules)
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # restored leaves actually live sharded on the new mesh
    leaf = jax.tree.leaves(restored["params"])[1]
    assert len(leaf.sharding.device_set) > 1
    print("OK")
    """)
