"""End-to-end system test: the paper's full pipeline, log generation ->
fault-injected Scribe delivery -> warehouse -> Oink jobs (dictionary,
catalog, sequences, rollups) -> analytics -> behaviour-LM training."""
import os

import numpy as np
import jax
import pytest

from repro.core import (EventBatch, EventCatalog, EventDictionary,
                        SessionSequences, sessionize)
from repro.core.oracle import sessionize_oracle
from repro.data import (generate, LogGenConfig, deliver_batch,
                        read_warehouse_hour, Oink, SessionBatchPipeline,
                        PipelineConfig, lm_vocab_size)
from repro.analytics import (count_pattern, funnel_from_patterns, summarize,
                             NGramLM)
from repro.models import ModelConfig, get_model
from repro.train import OptConfig, Trainer, TrainerConfig


def test_full_pipeline(tmp_path):
    # 1. events are born on production hosts
    log = generate(LogGenConfig(n_users=150, seed=11))

    # 2. scribe delivery with crashes; exactly-once arrival in the warehouse
    stats = deliver_batch(log.batch, str(tmp_path / "staging"),
                          str(tmp_path / "wh"), crash_prob=0.06, seed=2)
    assert stats["undelivered"] == 0
    assert stats["messages"] == len(log.batch)

    # 3. read back from the warehouse into a columnar batch
    from repro.core import ClientEvent
    rows = []
    for hour in stats["hours"]:
        rows.extend(read_warehouse_hour(str(tmp_path / "wh"),
                                        "client_events", hour))
    events = [ClientEvent(
        event_initiator=r["event_initiator"], event_name=r["event_name"],
        user_id=r["user_id"], session_id=r["session_id"], ip=r["ip"],
        timestamp=r["timestamp"], event_details=r["event_details"])
        for r in rows]
    batch = EventBatch.from_events(events)
    assert len(batch) == len(log.batch)

    # 4. Oink schedules the daily jobs with dependencies
    oink = Oink()
    oink.add("dictionary",
             lambda d: EventDictionary.build(batch.table, batch.name_id))
    oink.add("catalog",
             lambda d: EventCatalog.build(d["dictionary"], batch),
             deps=("dictionary",))

    def job_sequences(dep):
        d = dep["dictionary"]
        codes = np.asarray(d.encode_ids(batch.name_id))
        s = sessionize(batch.user_id, batch.session_id, batch.timestamp,
                       codes, batch.ip.astype(np.int64),
                       max_sessions=len(batch), max_len=1024)
        return SessionSequences.from_sessionized(s)

    oink.add("sequences", job_sequences, deps=("dictionary",))
    out = oink.run()
    assert all(t.success for t in oink.traces), oink.report()

    d, seqs, catalog = out["dictionary"], out["sequences"], out["catalog"]
    d.verify()

    # 5. sessionization agrees with the oracle on the delivered data
    codes = np.asarray(d.encode_ids(batch.name_id))
    want = sessionize_oracle(batch.user_id, batch.session_id,
                             batch.timestamp, codes)
    assert len(seqs) == len(want)

    # 6. analytics over the materialized sequences
    total, containing = count_pattern(seqs, d, "*:impression")
    assert total > 0 and containing <= len(seqs)
    reach = funnel_from_patterns(
        seqs, d,
        "*:signup:landing:form:signup_button:click",
        "*:signup:form:form:submit_button:submit",
        "*:signup:follow_suggestions:list:user:follow",
        "*:signup:complete:page::impression")
    counts = [c for _, c in reach]
    assert counts == sorted(counts, reverse=True)
    assert counts[0] > 0

    rep = summarize(seqs, d)
    assert rep.totals["sessions"] == len(seqs)
    assert catalog.coverage()["names"] == len(batch.table)

    # 7. a bigram model finds temporal signal in the behaviour
    h1 = NGramLM.fit(seqs, 1, d.alphabet_size).cross_entropy(seqs)
    h2 = NGramLM.fit(seqs, 2, d.alphabet_size).cross_entropy(seqs)
    assert h2 < h1

    # 8. the sequences train a behaviour LM end to end, loss decreases
    vocab = lm_vocab_size(d.alphabet_size)
    pipe = SessionBatchPipeline(seqs, PipelineConfig(seq_len=64,
                                                     global_batch=8))
    cfg = ModelConfig(name="e2e", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=vocab, dtype="float32", remat="none")
    tr = Trainer(get_model(cfg), OptConfig(lr=1e-3, warmup_steps=5,
                                           total_steps=30),
                 TrainerConfig(total_steps=30, checkpoint_every=15,
                               log_every=10,
                               checkpoint_dir=str(tmp_path / "ckpt")))
    res = tr.run(pipe)
    hist = res["history"]
    assert hist[-1][1]["loss"] < hist[0][1]["loss"]
