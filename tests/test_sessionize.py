import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sessionize, DEFAULT_GAP_MS, PAD_CODE
from repro.core.oracle import sessionize_oracle


def _events(draw_users, draw_sessions, n, rng):
    user = rng.integers(0, draw_users, n).astype(np.int64) * 1_000_003
    sess = rng.integers(0, draw_sessions, n).astype(np.int64)
    ts = (1_700_000_000_000 + rng.integers(0, 4 * 3600 * 1000, n)).astype(np.int64)
    code = rng.integers(0, 50, n).astype(np.int32)
    ip = rng.integers(0, 2**31, n).astype(np.int64)
    return user, sess, ts, code, ip


def _check_against_oracle(user, sess, ts, code, ip, gap_ms=DEFAULT_GAP_MS,
                          max_len=None):
    n = len(user)
    max_len = max_len or n
    got = sessionize(user, sess, ts, code, ip, gap_ms=gap_ms,
                     max_sessions=n, max_len=max_len).trimmed()
    want = sessionize_oracle(user, sess, ts, code, ip, gap_ms=gap_ms)
    assert int(got.num_sessions) == len(want)
    for i, o in enumerate(want):
        assert int(got.user_id[i]) == o["user_id"]
        assert int(got.session_id[i]) == o["session_id"]
        assert int(got.length[i]) == o["length"]
        assert int(got.duration_s[i]) == o["duration_s"]
        assert int(got.ip[i]) == o["ip"]
        assert int(got.start_ts[i]) == o["start_ts"]
        stored = got.symbols[i][got.symbols[i] != PAD_CODE]
        # ties in timestamps permit any order within equal-ts runs
        assert sorted(stored.tolist()) == sorted(o["symbols"][:max_len])
    return got, want


@given(st.integers(0, 2**31 - 1), st.integers(10, 300))
@settings(max_examples=25, deadline=None)
def test_matches_oracle_random(seed, n):
    rng = np.random.default_rng(seed)
    _check_against_oracle(*_events(8, 3, n, rng))


def test_event_conservation():
    rng = np.random.default_rng(0)
    user, sess, ts, code, ip = _events(5, 2, 500, rng)
    got = sessionize(user, sess, ts, code, ip, max_sessions=500, max_len=500)
    assert int(got.length.sum()) == 500          # every event in one session
    assert int(got.num_events) == 500


def test_gap_splits_sessions():
    # one user, one cookie, two bursts separated by > 30 min
    user = np.zeros(6, np.int64)
    sess = np.zeros(6, np.int64)
    ts = np.array([0, 1000, 2000, 2000 + DEFAULT_GAP_MS + 1,
                   2000 + DEFAULT_GAP_MS + 2000,
                   2000 + DEFAULT_GAP_MS + 3000], np.int64)
    code = np.arange(6, dtype=np.int32)
    got, want = _check_against_oracle(user, sess, ts, code,
                                      np.zeros(6, np.int64))
    assert int(got.num_sessions) == 2
    assert got.length.tolist() == [3, 3]


def test_gap_exactly_30min_does_not_split():
    user = np.zeros(2, np.int64)
    sess = np.zeros(2, np.int64)
    ts = np.array([0, DEFAULT_GAP_MS], np.int64)
    got = sessionize(user, sess, ts, np.zeros(2, np.int32),
                     max_sessions=2, max_len=2)
    assert int(got.num_sessions) == 1


def test_invalid_rows_dropped():
    rng = np.random.default_rng(1)
    user, sess, ts, code, ip = _events(4, 2, 100, rng)
    valid = rng.random(100) < 0.7
    got = sessionize(user, sess, ts, code, ip, valid=valid,
                     max_sessions=100, max_len=100)
    assert int(got.num_events) == int(valid.sum())
    want = sessionize_oracle(user, sess, ts, code, ip, valid=valid)
    assert int(got.num_sessions) == len(want)


def test_truncation_flags():
    user = np.zeros(10, np.int64)
    sess = np.zeros(10, np.int64)
    ts = np.arange(10, dtype=np.int64) * 1000
    code = np.arange(10, dtype=np.int32)
    got = sessionize(user, sess, ts, code, max_sessions=10, max_len=4)
    assert bool(got.truncated)        # length 10 > max_len 4
    assert int(got.length[0]) == 10   # true length still reported
    # session-capacity overflow
    user2 = np.arange(10, dtype=np.int64)
    got2 = sessionize(user2, sess, ts, code, max_sessions=3, max_len=10)
    assert bool(got2.truncated)
    assert int(got2.num_sessions) == 3  # clamped


def test_unordered_input_ok():
    # the warehouse guarantees only partial order (§2)
    rng = np.random.default_rng(2)
    user, sess, ts, code, ip = _events(6, 2, 200, rng)
    perm = rng.permutation(200)
    a = sessionize(user, sess, ts, code, ip, max_sessions=200,
                   max_len=200).trimmed()
    b = sessionize(user[perm], sess[perm], ts[perm], code[perm], ip[perm],
                   max_sessions=200, max_len=200).trimmed()
    assert np.array_equal(a.user_id, b.user_id)
    assert np.array_equal(a.length, b.length)
    assert np.array_equal(a.duration_s, b.duration_s)
