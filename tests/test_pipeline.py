import numpy as np
import pytest

from repro.core import SessionSequences
from repro.core.sessionize import PAD_CODE
from repro.data import (SessionBatchPipeline, PipelineConfig, pack_sessions,
                        encode_tokens, PAD_ID, BOS_ID, EOS_ID, NUM_SPECIALS)


def _seqs(rows):
    s, max_len = len(rows), max(len(r) for r in rows)
    symbols = np.full((s, max_len), PAD_CODE, np.int32)
    for i, r in enumerate(rows):
        symbols[i, :len(r)] = r
    return SessionSequences(
        symbols=symbols, length=np.array([len(r) for r in rows], np.int32),
        user_id=np.arange(s, dtype=np.int64),
        session_id=np.arange(s, dtype=np.int64),
        ip=np.zeros(s, np.int64), start_ts=np.zeros(s, np.int64),
        duration_s=np.zeros(s, np.int32))


def test_packing_conserves_all_tokens():
    rows = [[1, 2, 3], [4], [5, 6]]
    seqs = _seqs(rows)
    packed = pack_sessions(seqs, seq_len=6)
    flat = packed.reshape(-1)
    # one BOS+EOS per session, all symbols present (shifted by specials)
    assert (flat == BOS_ID).sum() == 3
    assert (flat == EOS_ID).sum() == 3
    non_special = flat[flat >= NUM_SPECIALS]
    assert sorted(non_special.tolist()) == sorted(
        encode_tokens(np.concatenate([np.asarray(r) for r in rows])).tolist())


def test_shards_are_disjoint_and_cover_batch():
    rows = [[i] * 5 for i in range(40)]
    seqs = _seqs(rows)
    full = SessionBatchPipeline(seqs, PipelineConfig(
        seq_len=8, global_batch=4, num_shards=1, shard_index=0, seed=1))
    sh0 = SessionBatchPipeline(seqs, PipelineConfig(
        seq_len=8, global_batch=4, num_shards=2, shard_index=0, seed=1))
    sh1 = SessionBatchPipeline(seqs, PipelineConfig(
        seq_len=8, global_batch=4, num_shards=2, shard_index=1, seed=1))
    b = full.batch_at(0, 0)
    b0 = sh0.batch_at(0, 0)
    b1 = sh1.batch_at(0, 0)
    np.testing.assert_array_equal(
        np.concatenate([b0["tokens"], b1["tokens"]]), b["tokens"])


def test_deterministic_resume():
    rows = [[i % 7] * 6 for i in range(30)]
    seqs = _seqs(rows)
    pipe = SessionBatchPipeline(seqs, PipelineConfig(seq_len=8,
                                                     global_batch=2, seed=3))
    via_iter = list(pipe.epoch(1))
    via_random_access = [pipe.batch_at(1, s) for s in
                         range(pipe.batches_per_epoch())]
    assert len(via_iter) == len(via_random_access)
    for a, b in zip(via_iter, via_random_access):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_epochs_reshuffle():
    rows = [[i % 7] * 6 for i in range(30)]
    seqs = _seqs(rows)
    pipe = SessionBatchPipeline(seqs, PipelineConfig(seq_len=8,
                                                     global_batch=2, seed=3))
    e0 = pipe.batch_at(0, 0)["tokens"]
    e1 = pipe.batch_at(1, 0)["tokens"]
    assert not np.array_equal(e0, e1)


def test_loss_mask_excludes_pad():
    rows = [[1, 2]]
    seqs = _seqs(rows)
    pipe = SessionBatchPipeline(seqs, PipelineConfig(
        seq_len=8, global_batch=1, drop_remainder=False))
    b = pipe.batch_at(0, 0)
    assert (b["loss_mask"] == (b["targets"] != PAD_ID)).all()
    assert b["loss_mask"].sum() < b["loss_mask"].size  # padding exists
