"""Unified segment store (repro.data.store): varint + segment round trips,
time-based compaction vs the batch-pipeline oracle, metadata pruning
exactness (a filtered scan must equal the unfiltered scan post-filtered,
while decoding strictly fewer segments), and the consumers that read
through the store — streampipe, the LM batch pipeline, and the catalog."""
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.core import sessionize, varint, SessionSequences
from repro.data.distpipe import single_host_pipeline
from repro.data.store import (Store, StoreConfig, concat_sequences,
                              decode_event_segment, decode_session_segment,
                              encode_event_segment, encode_session_segment,
                              scan_matches_sessions, user_shard_mask,
                              _take_rows)
from repro.data.streampipe import session_multiset, split_ticks

GAP = 30 * 60 * 1000  # DEFAULT_GAP_MS
U64 = (1 << 64) - 1
I64_MIN, I64_MAX = -(1 << 63), (1 << 63) - 1


def _events(n, seed, n_users=10, ts_hi=4 * GAP, dup_frac=0.25):
    """Random event columns with exact 5-tuple duplicates mixed in (the
    at-least-once retries the store's dedup must collapse)."""
    rng = np.random.default_rng(seed)
    user = rng.integers(0, n_users, n).astype(np.int64) * 7919
    sess = rng.integers(0, 3, n).astype(np.int64)
    ts = rng.integers(0, ts_hi, n).astype(np.int64)
    code = rng.integers(0, 16, n).astype(np.int32)
    ip = rng.integers(0, 1 << 32, n).astype(np.int64)
    dup = rng.integers(0, n, max(1, int(n * dup_frac)))
    cols = tuple(np.concatenate([a, a[dup]])
                 for a in (user, sess, ts, code, ip))
    perm = rng.permutation(len(cols[0]))
    return tuple(a[perm] for a in cols)


def _write(store, cols, n_writes=4):
    u, s, t, c, i = cols
    for ix in split_ticks(t, n_writes):
        store.append_events(u[ix], s[ix], t[ix], c[ix], i[ix])
    return store


def _oracle(cols, *, max_len=64, dedup=True):
    u, s, t, c, i = cols
    sz = sessionize(u, s, t, c, i, gap_ms=GAP, dedup=dedup,
                    max_sessions=len(u), max_len=max_len)
    return SessionSequences.from_sessionized(sz)


# ---------------------------------------------------------------------------
# varint codecs
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, U64), max_size=40))
def test_uvarint_round_trip(vals):
    a = np.array(vals, np.uint64)
    buf = varint.encode_uvarint(a)
    out, end = varint.decode_uvarint(buf, len(a))
    assert end == len(buf)
    assert np.array_equal(out, a)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(I64_MIN, I64_MAX), max_size=40))
def test_ivarint_round_trip(vals):
    a = np.array(vals, np.int64)
    buf = varint.encode_ivarint(a)
    out, end = varint.decode_ivarint(buf, len(a))
    assert end == len(buf)
    assert np.array_equal(out, a)


def test_varint_extremes_and_truncation():
    a = np.array([0, 1, 127, 128, 255, U64, U64 - 1], np.uint64)
    buf = varint.encode_uvarint(a)
    assert np.array_equal(varint.decode_uvarint(buf, len(a))[0], a)
    b = np.array([I64_MIN, I64_MAX, 0, -1, 1], np.int64)
    assert np.array_equal(
        varint.decode_ivarint(varint.encode_ivarint(b), len(b))[0], b)
    with pytest.raises(ValueError):
        varint.decode_uvarint(buf[:-1], len(a))
    with pytest.raises(ValueError):
        varint.decode_uvarint(b"\x80\x80", 1)  # no terminator byte


# ---------------------------------------------------------------------------
# segment round trips + metadata
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 200), st.integers(0, 10_000))
def test_event_segment_round_trip(n, seed):
    u, s, t, c, i = _events(n, seed)
    seg = encode_event_segment(7, u, s, t, c, i)
    cols = decode_event_segment(seg)
    order = np.argsort(t, kind="stable")  # rows store time-sorted
    assert np.array_equal(cols["timestamp"], t[order])
    assert np.array_equal(cols["user_id"], u[order])
    assert np.array_equal(cols["session_id"], s[order])
    assert np.array_equal(cols["code"], c[order])
    assert np.array_equal(cols["ip"], i[order])
    assert seg.min_ts == int(t.min()) and seg.max_ts == int(t.max())
    assert seg.n == len(t) and seg.n_events == len(t)


def test_event_segment_metadata():
    u, s, t, c, i = _events(300, seed=5)
    seg = encode_event_segment(0, u, s, t, c, i)
    codes, counts = np.unique(c, return_counts=True)
    assert seg.code_counts == {int(k): int(v)
                               for k, v in zip(codes, counts)}
    for uid in np.unique(u):  # every present user sets its shard bit
        assert seg.user_mask & user_shard_mask(np.array([uid]))
    # ip=None stores zeros
    seg0 = encode_event_segment(1, u, s, t, c, None)
    assert not decode_event_segment(seg0)["ip"].any()


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 300), st.integers(0, 10_000))
def test_session_segment_round_trip(n, seed):
    seqs = _oracle(_events(n, seed))
    seg = encode_session_segment(3, seqs)
    got = decode_session_segment(seg)
    # row order is preserved exactly (streampipe's readback contract),
    # only the padded width may shrink to the longest stored row
    assert np.array_equal(got.user_id, seqs.user_id)
    assert np.array_equal(got.start_ts, seqs.start_ts)
    assert session_multiset(got) == session_multiset(seqs)
    assert seg.n == len(seqs)
    assert seg.n_events == int(seqs.stored_length().sum())
    wide = decode_session_segment(seg, min_width=512)
    assert wide.symbols.shape[1] == 512
    assert session_multiset(wide) == session_multiset(seqs)


# ---------------------------------------------------------------------------
# compaction vs the batch oracle
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(8, 400), st.integers(0, 10_000), st.integers(1, 6))
def test_compaction_equals_batch_oracle(n, seed, n_writes):
    cols = _events(n, seed)
    store = _write(Store(StoreConfig(max_len=64)), cols, n_writes)
    assert store.events_appended == len(cols[0])
    # pre-compaction, a full scan returns every raw event bit-equal
    ev = store.scan().events
    got = sorted(zip(*(ev[k].tolist() for k in
                       ("user_id", "session_id", "timestamp", "code", "ip"))))
    assert got == sorted(zip(*(a.tolist() for a in cols)))
    store.compact()
    assert session_multiset(store.sequences()) == \
        session_multiset(_oracle(cols))
    assert all(g.kind == "sessions" for g in store.segments)


def test_incremental_watermarks_equal_full_compact():
    cols = _events(600, seed=11)
    t = cols[2]
    inc = _write(Store(StoreConfig(max_len=64)), cols, 8)
    for q in (20, 40, 60, 80):
        inc.compact(int(np.percentile(t, q)))
    inc.compact()
    full = _write(Store(StoreConfig(max_len=64)), cols, 8)
    full.compact()
    assert session_multiset(inc.sequences()) == \
        session_multiset(full.sequences())
    assert len(inc.segments) > len(full.segments)  # hourly folds, not one
    # compacting again at the same watermark is a no-op
    again = inc.compact()
    assert again.segments_in == 0 and again.sessions_out == 0


def test_watermark_only_folds_closed_prefix():
    cols = _events(400, seed=3)
    t = cols[2]
    store = _write(Store(StoreConfig(max_len=64)), cols, 4)
    st1 = store.compact(int(np.percentile(t, 50)))
    assert st1.residual_events > 0  # open tail survives as events
    kinds = {g.kind for g in store.segments}
    assert kinds == {"sessions", "events"}
    # the open tail is still queryable as raw events, and sequences()
    # refuses to serve while matching events are un-materialized
    with pytest.raises(ValueError):
        store.sequences()
    store.compact()
    assert session_multiset(store.sequences()) == \
        session_multiset(_oracle(cols))


def test_late_append_after_compaction():
    cols = _events(300, seed=9)
    store = _write(Store(StoreConfig(max_len=64)), cols, 4)
    store.compact()
    assert store.late_appended == 0
    u, s, t, c, i = _events(50, seed=10)
    u = u + 13  # disjoint users: late rows cannot extend closed sessions
    store.append_events(u, s, t, c, i)  # all behind the final watermark
    assert store.late_appended == len(t)
    store.compact()  # watermark is clamped monotone; late rows fold now
    assert session_multiset(store.sequences()) == sorted(
        session_multiset(_oracle(cols))
        + session_multiset(_oracle((u, s, t, c, i))))


# ---------------------------------------------------------------------------
# the pruning query path
# ---------------------------------------------------------------------------

def _staged_store(cols, n_writes=8):
    store = _write(Store(StoreConfig(max_len=64)), cols, n_writes)
    for q in (25, 50, 75):
        store.compact(int(np.percentile(cols[2], q)))
    store.compact()
    return store


def test_scan_time_pruning_exact_and_strict():
    cols = _events(800, seed=21)
    store = _staged_store(cols)
    full = store.scan()
    lo = int(np.percentile(cols[2], 40))
    hi = int(np.percentile(cols[2], 60))
    scan = store.scan(time_range=(lo, hi))
    keep = scan_matches_sessions(full.sequences, (lo, hi), None, None)
    assert session_multiset(scan.sequences) == \
        session_multiset(_take_rows(full.sequences, keep))
    # pruning must skip segments, not just rows (the acceptance criterion)
    assert scan.stats.segments_decoded < full.stats.segments_decoded
    assert scan.stats.pruned_time == scan.stats.segments_pruned > 0
    assert scan.stats.segments_total == \
        scan.stats.segments_decoded + scan.stats.segments_pruned


@settings(max_examples=10, deadline=None)
@given(st.integers(50, 500), st.integers(0, 10_000))
def test_scan_filters_equal_post_filtering(n, seed):
    cols = _events(n, seed)
    store = _staged_store(cols, n_writes=4)
    full = store.scan()
    uids = np.unique(cols[0])[::3]
    codes = np.arange(0, 16, 5)
    lo, hi = (int(np.percentile(cols[2], 30)),
              int(np.percentile(cols[2], 70)))
    for tr, users, events in [((lo, hi), None, None),
                              (None, uids, None),
                              (None, None, codes),
                              ((lo, hi), uids, codes)]:
        got = store.scan(time_range=tr,
                         users=None if users is None else list(users),
                         events=None if events is None else list(events))
        keep = scan_matches_sessions(
            full.sequences, tr,
            None if users is None else np.asarray(users, np.int64),
            None if events is None else np.asarray(events, np.int64))
        assert session_multiset(got.sequences) == \
            session_multiset(_take_rows(full.sequences, keep))


def test_analytics_read_through_store():
    from repro.analytics import (count_events, count_events_store,
                                 funnel_reach, funnel_reach_store,
                                 ngram_counts, ngram_counts_store)
    cols = _events(600, seed=31)
    store = _staged_store(cols)
    seqs = store.sequences()
    targets = np.array([2, 7])
    stages = [np.array([1, 2]), np.array([5])]
    assert count_events_store(store, targets, 16) == \
        count_events(seqs, targets, 16)
    assert funnel_reach_store(store, stages, 16) == \
        funnel_reach(seqs, stages, 16)
    got_k, got_c = ngram_counts_store(store, 2, 16)
    want_k, want_c = ngram_counts(seqs, 2, 16)
    assert np.array_equal(got_k, want_k) and np.array_equal(got_c, want_c)


def test_pipeline_from_store():
    from repro.data.pipeline import PipelineConfig, SessionBatchPipeline
    cols = _events(400, seed=41)
    store = _staged_store(cols)
    cfg = PipelineConfig(seq_len=32, global_batch=4, seed=7)
    a = SessionBatchPipeline.from_store(store, cfg)
    b = SessionBatchPipeline(store.sequences(), cfg)
    assert a.batches_per_epoch() == b.batches_per_epoch()
    for x, y in zip(a, b):
        for k in x:
            assert np.array_equal(x[k], y[k])
        break


# ---------------------------------------------------------------------------
# consumers: streaming tier + catalog + persistence
# ---------------------------------------------------------------------------

def test_stream_writes_segments_at_every_watermark():
    from repro.data.streampipe import (StreamConfig, replay,
                                      single_host_stream)
    cols = _events(300, seed=51, dup_frac=0.0)
    u, s, t, c, i = cols
    cfg = StreamConfig(alphabet_size=16, max_open=128, max_len=64,
                       tick_capacity=512)
    stream = single_host_stream(cfg)
    replay(stream, u, s, t, c, i, n_ticks=6, assert_closed_prefix=True)
    # every closed block became an immutable session segment; sessions()
    # reads back through the store's scan, bit-equal to the oracle
    assert all(g.kind == "sessions" for g in stream.store.segments)
    assert len(stream.store.segments) >= 1
    assert session_multiset(stream.sessions()) == \
        session_multiset(_oracle(cols, dedup=cfg.dedup))


def test_catalog_builder_incremental_equals_scratch():
    from repro.core import CatalogBuilder, EventDictionary
    from repro.data import LogGenConfig, generate
    log = generate(LogGenConfig(n_users=40, seed=7))
    b = log.batch
    d = EventDictionary.build(b.table, b.name_id)
    codes = np.asarray(d.encode_ids(b.name_id), np.int32)
    store = Store(StoreConfig(dedup=False))
    builder = CatalogBuilder(d)
    ip = b.ip.astype(np.int64)
    for ix in split_ticks(b.timestamp, 4):
        store.append_events(b.user_id[ix], b.session_id[ix],
                            b.timestamp[ix], codes[ix], ip[ix])
        builder.refresh(store)
    store.compact(int(np.percentile(b.timestamp, 50)))
    store.compact()
    inc = builder.refresh(store)
    assert builder.segments_retracted > 0  # compaction consumed segments
    scratch = CatalogBuilder(d).refresh(store)
    assert {n: e.count for n, e in inc.entries.items()} == \
        {n: e.count for n, e in scratch.entries.items()}
    total = sum(e.count for e in inc.entries.values())
    assert total == int(store.sequences().stored_length().sum())


def test_save_load_round_trip(tmp_path):
    cols = _events(300, seed=61)
    store = _staged_store(cols)
    store.save(str(tmp_path / "store"))
    back = Store.load(str(tmp_path / "store"))
    assert back.cfg == store.cfg
    assert [(g.seg_id, g.kind, g.blob) for g in back.segments] == \
        [(g.seg_id, g.kind, g.blob) for g in store.segments]
    assert session_multiset(back.sequences()) == \
        session_multiset(store.sequences())
    assert back.summary() == store.summary()


def test_user_shard_mask_matches_jax_sharding():
    from jax.experimental import enable_x64
    from repro.dist.collectives import shard_of_user
    uids = np.arange(0, 5000, 37, dtype=np.int64) * 7919
    with enable_x64():
        shards = np.asarray(shard_of_user(uids, 64))
    want = 0
    for sh in np.unique(shards):
        want |= 1 << int(sh)
    assert user_shard_mask(uids, 64) == want


# ---------------------------------------------------------------------------
# the full loggen day (acceptance criterion)
# ---------------------------------------------------------------------------

def test_loggen_day_through_store_equals_batch_pipeline(loggen_corpus):
    lc = loggen_corpus
    from repro.data.distpipe import DistPipelineConfig
    cfg = DistPipelineConfig(alphabet_size=lc.alphabet_size,
                             max_sessions_per_shard=lc.n_events,
                             max_len=2048)
    store = Store(StoreConfig(dedup=cfg.dedup, max_len=cfg.max_len,
                              gap_ms=cfg.gap_ms))
    for ix in split_ticks(lc.timestamp, 16):
        store.append_events(lc.user_id[ix], lc.session_id[ix],
                            lc.timestamp[ix], lc.code[ix], lc.ip[ix])
    for q in (33, 66):
        store.compact(int(np.percentile(lc.timestamp, q)))
    store.compact()
    oracle = single_host_pipeline(lc.user_id, lc.session_id, lc.timestamp,
                                  lc.code, lc.ip, cfg=cfg,
                                  max_sessions=lc.n_events)
    assert session_multiset(store.sequences()) == \
        session_multiset(oracle.sequences)
    assert not store.truncated
