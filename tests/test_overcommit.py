"""Over-commit serving tests: optimistic admission, priority preemption,
and the recompute-requeue path.

Three layers, mirroring the implementation:

* ``BlockPool`` under ``overcommit > 1`` — virtual-capacity reservation
  math, ``PoolExhausted`` from an empty free list (unreachable at 1.0),
  and ``check_invariants`` accepting reservations beyond the free list;
* the scheduler with the deterministic stub — admission past the honest
  worst case, lowest-priority/youngest victim selection, requeue with
  generated tokens as a re-prefill (outputs bit-equal to the
  never-preempted oracle), the loud only-request refusal, prefix-cache
  hits on re-admission, flat ``trace_counts`` across preempt cycles,
  strict priority admission order, and the queue-wait/TTFT split plus
  per-class accounting in ``ServeMetrics``;
* the real smoke LM — a preempting over-commit run decodes bit-equal to
  the honest-reservation oracle, and the seeded bursty arrival generator
  is reproducible run-to-run.
"""
import numpy as np
import pytest

from repro.serve import (ContinuousScheduler, ServeMetrics, BlockPool,
                         blocks_for)
from repro.serve.cache import make_decode_state
from repro.serve.paged import PoolExhausted

from test_serve import _stub_api, _stub_expected, SchedulerConfig


def _pool(num_blocks=4, block_size=4, **kw):
    return BlockPool(num_blocks=num_blocks, block_size=block_size,
                     num_kv_heads=1, head_dim=2, num_layers=1, **kw)


# ---------------------------------------------------------------------------
# BlockPool: virtual capacity + PoolExhausted
# ---------------------------------------------------------------------------

def test_overcommit_scales_virtual_capacity():
    pool = _pool(num_blocks=4, overcommit=2.0)
    assert pool.capacity == 4 and pool.virtual_capacity == 8
    assert pool.available == 8
    pool.reserve(6)                       # beyond real capacity: allowed
    assert pool.available == 2
    pool.check_invariants()               # reserved > free is legal now
    with pytest.raises(ValueError, match="cannot reserve"):
        pool.reserve(3)                   # but never beyond virtual


def test_honest_pool_rejects_reservation_beyond_free():
    pool = _pool(num_blocks=4)            # overcommit 1.0
    with pytest.raises(ValueError, match="cannot reserve"):
        pool.reserve(5)


def test_take_raises_pool_exhausted_when_free_list_empties():
    pool = _pool(num_blocks=2, overcommit=2.0)
    pool.reserve(4)
    a, b = pool.take(), pool.take()
    assert sorted((a, b)) == [1, 2]
    with pytest.raises(PoolExhausted, match="free list empty"):
        pool.take()
    assert pool._reserved == 2            # the failed take consumed nothing
    pool.free([a])
    assert pool.take() == a               # freed capacity serves the retry


def test_take_without_reservation_still_value_error():
    pool = _pool(num_blocks=2, overcommit=2.0)
    with pytest.raises(ValueError, match="without a reservation"):
        pool.take()


def test_overcommit_below_one_rejected():
    with pytest.raises(ValueError, match="overcommit"):
        _pool(overcommit=0.5)
    api = _stub_api()
    with pytest.raises(ValueError, match="overcommit"):
        make_decode_state(api, SchedulerConfig(
            paged=True, block_size=4, overcommit=0.5), {})


def test_overcommit_requires_paged():
    api = _stub_api()
    with pytest.raises(ValueError, match="requires paged"):
        make_decode_state(api, SchedulerConfig(
            paged=False, overcommit=2.0), {})


# ---------------------------------------------------------------------------
# Scheduler: optimistic admission + preemption with the stub
# ---------------------------------------------------------------------------

def _tight_sched(api, *, num_blocks=4, overcommit=2.0, batch=4,
                 budget=9, metrics=None, **kw):
    """Pool where two 3-block requests cannot both hold their worst case:
    exhaustion mid-decode is guaranteed when both run to budget."""
    return ContinuousScheduler(api, {}, SchedulerConfig(
        batch=batch, buckets=(16,), max_new_tokens=budget, paged=True,
        block_size=4, num_blocks=num_blocks, overcommit=overcommit,
        **kw), metrics=metrics)


def test_overcommit_admits_past_honest_worst_case():
    api = _stub_api(eos_after=99)
    prompts = [np.full(4, 7, np.int32), np.full(4, 9, np.int32)]
    honest = _tight_sched(api, overcommit=1.0)
    for p in prompts:
        honest.submit(p)
    honest.step()
    assert honest.num_active == 1         # 2 x 3 blocks > 4: serialized
    oc = _tight_sched(api, overcommit=2.0)
    for p in prompts:
        oc.submit(p)
    oc.step()
    assert oc.num_active == 2             # optimistic: both admitted


def test_preempt_requeue_outputs_bit_equal_to_oracle():
    api = _stub_api(eos_after=99)
    prompts = [np.full(4, 7, np.int32), np.full(4, 9, np.int32)]
    m = ServeMetrics()
    sched = _tight_sched(api, metrics=m)
    rids = [sched.submit(prompts[0], priority=1),
            sched.submit(prompts[1], priority=0)]
    outs = sched.run()
    assert sched.preemptions >= 1
    for rid, p in zip(rids, prompts):
        np.testing.assert_array_equal(outs[rid], _stub_expected(p, 9, 99))
    s = m.summary()
    assert s["preemptions"] == sched.preemptions
    assert s["per_priority"][0]["preemptions"] >= 1
    assert s["per_priority"][1]["preemptions"] == 0   # hi-pri protected
    assert s["per_priority"][0]["requests"] == 1
    timings = {m.requests[r].priority: m.requests[r] for r in rids}
    assert timings[0].preemptions >= 1 and timings[1].preemptions == 0


def test_victim_is_lowest_priority_then_youngest():
    api = _stub_api(eos_after=99)
    # three 3-block requests in a 7-block pool (overcommit 2.0 -> virtual
    # 14): all admitted, growth exhausts, victims ordered lo-pri youngest
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=4, buckets=(16,), max_new_tokens=9, paged=True,
        block_size=4, num_blocks=7, overcommit=2.0))
    r_hi = sched.submit(np.full(4, 7, np.int32), priority=1)
    r_old = sched.submit(np.full(4, 9, np.int32), priority=0)
    r_new = sched.submit(np.full(4, 11, np.int32), priority=0)
    preempted = []
    orig_preempt = sched._preempt_one

    def spy():
        active = np.flatnonzero(sched._active)
        victim = int(max(active, key=lambda s: (
            -sched._slot_prio[s], sched._slot_rid[s])))
        preempted.append(int(sched._slot_rid[victim]))
        orig_preempt()

    sched._preempt_one = spy
    sched.run()
    assert preempted, "pool never exhausted"
    assert preempted[0] == r_new          # lo-pri tie broken by youngest
    assert r_hi not in preempted          # hi-pri never chosen over lo-pri


def test_preempting_the_only_request_errors_loudly():
    api = _stub_api(eos_after=99)
    sched = _tight_sched(api)
    sched.submit(np.full(4, 7, np.int32))
    sched.step()
    # strand the free list under the lone request: its next growth finds
    # nothing to take and nothing legal to preempt
    sched.pool._reserved += len(sched.pool._free)
    stolen = [sched.pool.take() for _ in range(len(sched.pool._free))]
    assert stolen
    with pytest.raises(RuntimeError, match="only"):
        for _ in range(12):
            sched.step()


def test_preempted_request_readmits_via_prefix_cache_hit():
    api = _stub_api(eos_after=99)
    # A (hi-pri) prompts with B's prompt PLUS the tokens the stub will
    # deterministically generate for B, so A's registered hash chain
    # covers B's requeued (prompt + generated) prompt. A's own growth
    # exhausts the 7-block pool and preempts B; B's re-admission then
    # maps 3 resident blocks of A's chain copy-free — a prefix HIT on the
    # requeue, while A is still live to keep the registry entries alive.
    prompt_b = np.arange(7, 16, dtype=np.int32)    # 9 toks, gen: 16,17,...
    prompt_a = np.arange(7, 24, dtype=np.int32)    # covers B's requeue
    m = ServeMetrics()
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=4, buckets=(8, 16, 32), max_new_tokens=8, paged=True,
        block_size=4, num_blocks=7, overcommit=2.0, prefix_cache=True),
        metrics=m)
    ra = sched.submit(prompt_a, priority=1)
    rb = sched.submit(prompt_b, priority=0)
    outs = sched.run()
    assert sched.preemptions >= 1
    tb = m.requests[rb]
    assert tb.preemptions >= 1
    assert tb.prefix_hit and tb.prefix_blocks_reused >= 3, \
        "re-admission should reuse the survivor's resident chain blocks"
    assert tb.prefill_tokens_skipped >= 9, \
        "the whole original prompt should re-prefill from resident K/V"
    np.testing.assert_array_equal(outs[ra], _stub_expected(prompt_a, 8, 99))
    np.testing.assert_array_equal(outs[rb], _stub_expected(prompt_b, 8, 99))
    sched.pool.check_invariants()
    assert sched.pool.live_blocks == 0    # drained clean


def test_trace_counts_flat_across_preempt_requeue_cycles():
    api = _stub_api(eos_after=99)
    prompts = [np.full(4, 7, np.int32), np.full(4, 9, np.int32)]

    def stream(sched):
        for p, prio in zip(prompts, (1, 0)):
            sched.submit(p, priority=prio)
        sched.run()

    sched = _tight_sched(api)
    stream(sched)                          # warmup: includes a preemption
    assert sched.preemptions >= 1
    warm = dict(sched.trace_counts)
    before = sched.preemptions
    stream(sched)                          # same stream -> same cycle
    assert sched.preemptions > before      # preemption happened again
    assert dict(sched.trace_counts) == warm, \
        "preempt/requeue re-prefill retraced after warmup"


def test_priority_classes_admit_strictly_highest_first():
    api = _stub_api(eos_after=99)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=1, buckets=(8,), max_new_tokens=3, paged=True, block_size=4))
    r_lo1 = sched.submit(np.full(4, 7, np.int32), priority=0)
    sched.step()                           # lo1 holds the only slot
    r_lo2 = sched.submit(np.full(4, 9, np.int32), priority=0)
    r_hi = sched.submit(np.full(4, 11, np.int32), priority=2)
    order = [r_lo1]
    while sched.num_active or sched.num_pending:
        sched.step()
        slot_rid = int(sched._slot_rid[0])
        if slot_rid >= 0 and order[-1] != slot_rid:
            order.append(slot_rid)
    # running lo1 is never displaced; the queued hi-pri jumps ahead of the
    # earlier-submitted lo2 the moment the slot frees
    assert order == [r_lo1, r_hi, r_lo2], order


def test_overcommit_guards_requeue_prompt_against_largest_bucket():
    api = _stub_api(eos_after=99)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(16,), max_new_tokens=9, paged=True,
        block_size=4, overcommit=2.0))
    with pytest.raises(ValueError, match="re-prefill"):
        # 10 + 9 - 1 = 18 > 16: a preempted copy could not re-prefill
        sched.submit(np.full(10, 7, np.int32))
    # the same request is legal under honest reservation (never requeued)
    honest = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(16,), max_new_tokens=9, paged=True, block_size=4))
    honest.submit(np.full(10, 7, np.int32))


def test_debug_flag_reaches_pool_and_checks_after_evict():
    api = _stub_api(eos_after=2)
    sched = ContinuousScheduler(api, {}, SchedulerConfig(
        batch=2, buckets=(8,), max_new_tokens=4, paged=True, block_size=4,
        debug=True))
    assert sched.pool.debug is True
    sched.submit(np.full(4, 7, np.int32))
    sched.run()                            # eviction runs check_invariants
    # corrupt state only the invariant checker inspects (take/free never
    # touch the trash block): the next evict-triggered check must trip
    sched.pool._refs[0] = 1
    sched.submit(np.full(4, 9, np.int32))
    with pytest.raises(AssertionError, match="trash"):
        sched.run()


# ---------------------------------------------------------------------------
# Metrics: queue-wait split + per-priority accounting
# ---------------------------------------------------------------------------

def test_queue_wait_splits_out_of_ttft():
    clock = iter(range(100)).__next__
    m = ServeMetrics(clock=lambda: float(clock()))
    m.record_submit(0, prompt_len=4)       # t=0
    m.record_admit(0)                      # t=1
    m.record_token(0)                      # t=2 (first token)
    m.record_finish(0)                     # t=3
    s = m.summary()
    assert s["p50_queue_wait_s"] == 1.0    # submit -> admit
    assert s["p50_ttft_admit_s"] == 1.0    # admit -> first token
    assert s["p50_ttft_s"] == 2.0          # their sum: submit -> first token
    assert s["p50_latency_s"] == 3.0


def test_admit_stamp_survives_preempt_requeue():
    t = {"now": 0.0}
    m = ServeMetrics(clock=lambda: t["now"])
    m.record_submit(0)
    t["now"] = 1.0
    m.record_admit(0)
    m.record_preempt(0)
    t["now"] = 5.0
    m.record_admit(0)                      # re-admission: must not restamp
    assert m.requests[0].admit == 1.0
    assert m.requests[0].preemptions == 1


def test_per_priority_rollup_keys():
    t = {"now": 0.0}
    m = ServeMetrics(clock=lambda: t["now"])
    for rid, prio in ((0, 0), (1, 1)):
        m.record_submit(rid, priority=prio)
        m.record_admit(rid)
        t["now"] += 1.0
        m.record_token(rid)
        m.record_finish(rid)
    s = m.summary()
    assert set(s["per_priority"]) == {0, 1}
    for cls in s["per_priority"].values():
        assert cls["requests"] == 1
        for key in ("p50_latency_s", "p99_latency_s", "p50_ttft_s",
                    "p50_queue_wait_s", "p50_ttft_admit_s", "preemptions"):
            assert key in cls, key


def test_summary_keeps_existing_keys_stable():
    s = ServeMetrics().summary()
    for key in ("requests", "tokens", "tokens_per_sec", "p50_latency_s",
                "p99_latency_s", "p50_ttft_s", "p99_ttft_s",
                "kv_util_peak", "prefix_hit_rate", "mean_ttft_hit_s"):
        assert key in s, key


# ---------------------------------------------------------------------------
# Seeded arrival generator (benchmarks/common.py)
# ---------------------------------------------------------------------------

def _bench_common():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.common import bursty_arrivals, VirtualClock
    return bursty_arrivals, VirtualClock


def test_bursty_arrivals_deterministic_and_bursty():
    bursty_arrivals, _ = _bench_common()
    a = bursty_arrivals(64, mean_gap=5.0, burst_mean=4.0, seed=3)
    b = bursty_arrivals(64, mean_gap=5.0, burst_mean=4.0, seed=3)
    np.testing.assert_array_equal(a, b)    # no wall clock, no OS entropy
    assert len(a) == 64 and (np.diff(a) >= 0).all()
    assert (np.diff(a) == 0).any(), "no bursts: arrivals all distinct"
    c = bursty_arrivals(64, mean_gap=5.0, burst_mean=4.0, seed=4)
    assert not np.array_equal(a, c)
    assert len(bursty_arrivals(0)) == 0


def test_virtual_clock_advances_only_explicitly():
    _, VirtualClock = _bench_common()
    clock = VirtualClock()
    assert clock() == 0.0 and clock() == 0.0
    clock.advance(2.5)
    assert clock() == 2.5


# ---------------------------------------------------------------------------
# Real model: preempting run bit-equal to the honest oracle
# ---------------------------------------------------------------------------

def test_real_model_preempted_outputs_match_honest_oracle(dense_model):
    api, params = dense_model
    prompts = [np.arange(4, 10, dtype=np.int32),
               np.arange(11, 16, dtype=np.int32),
               np.arange(20, 26, dtype=np.int32)]

    def serve(overcommit, num_blocks):
        sched = ContinuousScheduler(api, params, SchedulerConfig(
            batch=4, buckets=(8, 32), max_new_tokens=16, paged=True,
            block_size=8, num_blocks=num_blocks, overcommit=overcommit))
        rids = [sched.submit(p, priority=i % 2)
                for i, p in enumerate(prompts)]
        outs = sched.run()
        return [outs[r] for r in rids], sched.preemptions

    # honest oracle: ample pool, preemption impossible
    oracle, p0 = serve(1.0, 16)
    assert p0 == 0
    # tight over-committed pool: 3 x 3-block worst cases over 5 blocks
    preempted, p1 = serve(2.0, 5)
    assert p1 >= 1, "tight pool never preempted — test lost its teeth"
    for a, b in zip(oracle, preempted):
        np.testing.assert_array_equal(a, b)


@pytest.fixture(scope="module")
def dense_model():
    import jax
    from repro.configs import smoke_config
    from repro.models.registry import get_model
    cfg = smoke_config("behavior-lm-100m").with_(vocab_size=64,
                                                 max_cache_len=64)
    api = get_model(cfg)
    return api, api.init(jax.random.PRNGKey(0))
