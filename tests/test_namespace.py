import pytest
from hypothesis import given, strategies as st

from repro.core import namespace as ns

TOKEN = st.from_regex(r"[a-z][a-z0-9_]{0,8}", fullmatch=True)


def test_parse_canonical_roundtrip():
    name = "web:home:mentions:stream:avatar:profile_click"
    e = ns.parse(name)
    assert e.canonical() == name
    assert e.client == "web" and e.action == "profile_click"


def test_empty_middle_components_allowed():
    e = ns.parse("web:home::scroll_bar:scroll:impression")
    assert e.section == ""


@pytest.mark.parametrize("bad", [
    "Web:home:mentions:stream:avatar:click",      # uppercase
    "web:home:mentions:stream:avatar",            # 5 levels
    "web:home:mentions:stream:avatar:click:x",    # 7 levels
    "web:home:camel_Snake:stream:avatar:cLick",   # the dreaded camel_Snake
    ":home:mentions:stream:avatar:click",         # empty client
    "web:home:mentions:stream:avatar:",           # empty action
])
def test_invalid_names_rejected(bad):
    with pytest.raises(ns.InvalidEventName):
        ns.parse(bad)


@given(st.lists(TOKEN, min_size=6, max_size=6))
def test_roundtrip_property(tokens):
    name = ":".join(tokens)
    assert ns.parse(name).canonical() == name


NAMES = [
    "web:home:mentions:stream:avatar:profile_click",
    "web:home:timeline:stream:tweet:impression",
    "iphone:home:mentions:stream:avatar:profile_click",
    "android:search:results:stream:tweet:click",
]


def test_suffix_glob():
    got = ns.match("web:home:mentions:*", NAMES)
    assert got == [NAMES[0]]


def test_prefix_glob_matches_all_clients():
    got = ns.match("*:profile_click", NAMES)
    assert set(got) == {NAMES[0], NAMES[2]}


def test_mid_level_single_wildcard():
    got = ns.match("web:home:*:stream:tweet:impression", NAMES)
    assert got == [NAMES[1]]


def test_rollup_schemas():
    e = ns.parse(NAMES[0])
    rollups = [e.rollup(s) for s in ns.ROLLUP_SCHEMAS]
    assert rollups[0] == NAMES[0]
    assert rollups[-1] == "web:*:*:*:*:profile_click"
    assert all(r.split(":")[0] == "web" and r.split(":")[-1] == "profile_click"
               for r in rollups)
