import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import SessionSequences, code_to_codepoint, codepoint_to_code
from repro.core import varint
from repro.core.sessionize import PAD_CODE


def _seqs(rows):
    s = len(rows)
    max_len = max(len(r) for r in rows)
    symbols = np.full((s, max_len), PAD_CODE, np.int32)
    for i, r in enumerate(rows):
        symbols[i, :len(r)] = r
    return SessionSequences(
        symbols=symbols, length=np.array([len(r) for r in rows], np.int32),
        user_id=np.arange(s, dtype=np.int64),
        session_id=np.arange(s, dtype=np.int64),
        ip=np.zeros(s, np.int64), start_ts=np.zeros(s, np.int64),
        duration_s=np.zeros(s, np.int32))


@given(st.lists(st.lists(st.integers(0, 70_000), min_size=1, max_size=20),
                min_size=1, max_size=10))
@settings(max_examples=50, deadline=None)
def test_unicode_string_roundtrip(rows):
    seqs = _seqs(rows)
    strs = seqs.as_unicode_strings()
    back = SessionSequences.from_unicode_strings(strs)
    for i, r in enumerate(rows):
        assert back.session_symbols(i).tolist() == r


def test_surrogate_range_is_skipped():
    # codes near the surrogate block must map to VALID code points
    codes = np.array([0xD7FF, 0xD800, 0xDFFF, 0xE000], np.int64)
    cps = code_to_codepoint(codes)
    assert all(not (0xD800 <= int(c) <= 0xDFFF) for c in cps)
    assert np.array_equal(codepoint_to_code(cps), codes)
    # and every produced char is encodable
    "".join(chr(int(c)) for c in cps).encode("utf-8")


@given(st.lists(st.integers(0, 70_000), min_size=1, max_size=50))
@settings(max_examples=50, deadline=None)
def test_varint_roundtrip(codes):
    data = varint.encode_session(np.asarray(codes))
    assert np.array_equal(varint.decode_session(data), np.asarray(codes))


def test_variable_length_coding_property():
    """Paper §4.2: smaller code points need fewer bytes — so frequent
    (small) codes compress better than rare (large) ones."""
    small = varint.encode_session(np.zeros(100, np.int64))       # code 0
    large = varint.encode_session(np.full(100, 60_000, np.int64))
    assert len(small) == 100      # 1 byte each
    assert len(large) == 300      # 3 bytes each
    assert len(small) < len(large)


def test_encoded_size_accounts_masks():
    seqs = _seqs([[0, 1, 2], [5]])
    assert varint.encoded_size_bytes(seqs) == 4  # 4 symbols x 1 byte


def test_save_load_atomic(tmp_path):
    seqs = _seqs([[1, 2, 3], [4, 5]])
    path = str(tmp_path / "seqs.npz")
    seqs.save(path)
    back = SessionSequences.load(path)
    assert np.array_equal(back.symbols, seqs.symbols)
    assert np.array_equal(back.length, seqs.length)
    # no stray temp files (atomic rename)
    assert sorted(p.name for p in tmp_path.iterdir()) == ["seqs.npz"]
