"""Distributed multi-stage log pipeline (repro.data.distpipe): shard-local
pieces against their oracles in-process, and full host-local 1xN mesh
equivalence (distributed sessionize -> dedup -> ngram/funnel rollups ==
single-host oracle path) in an 8-device subprocess, including ragged
(non-divisible) input sizes."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, {REPO_SRC!r})
        import numpy as np, jax, jax.numpy as jnp
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _events(n, seed, n_users=150, n_dupes=0):
    rng = np.random.default_rng(seed)
    user = rng.integers(0, n_users, n).astype(np.int64) * 7919
    sess = rng.integers(0, 3, n).astype(np.int64)
    ts = (1.7e12 + rng.integers(0, 2 * 3600 * 1000, n)).astype(np.int64)
    code = rng.integers(0, 64, n).astype(np.int32)
    ip = rng.integers(0, 1 << 32, n).astype(np.int64)
    if n_dupes:  # overwrite a prefix with copies of random rows (retries)
        src = rng.choice(n, n_dupes, replace=False)
        for col in (user, sess, ts, code, ip):
            col[:n_dupes] = col[src]
    return user, sess, ts, code, ip


# ---------------------------------------------------------------------------
# shard-local pieces vs oracles (in-process, fast)
# ---------------------------------------------------------------------------

def test_mark_duplicates_matches_oracle():
    from jax.experimental import enable_x64
    import jax.numpy as jnp
    from repro.core.sessionize import mark_duplicate_events
    from repro.core.oracle import dedup_events_oracle
    user, sess, ts, code, ip = _events(997, seed=3, n_dupes=200)
    valid = np.random.default_rng(4).random(997) > 0.1
    with enable_x64():
        got = np.asarray(mark_duplicate_events(
            jnp.asarray(user, jnp.int64), jnp.asarray(sess, jnp.int64),
            jnp.asarray(ts, jnp.int64), jnp.asarray(code, jnp.int32),
            jnp.asarray(ip, jnp.int64), jnp.asarray(valid, bool)))
    exp = dedup_events_oracle(user, sess, ts, code, ip, valid)
    # Same surviving multiset of rows; which exact copy survives is
    # irrelevant (duplicates are identical), but the count per row must
    # match and no invalid row may survive.
    assert got.sum() == exp.sum()
    assert not got[~valid].any()
    key = lambda m: sorted(zip(user[m], sess[m], ts[m], code[m], ip[m]))
    assert key(got) == key(exp)


def test_sessionize_dedup_kwarg():
    from repro.core import sessionize
    from repro.core.oracle import sessionize_oracle, dedup_events_oracle
    user, sess, ts, code, ip = _events(800, seed=7, n_dupes=150)
    s = sessionize(user, sess, ts, code, ip, dedup=True)
    keep = dedup_events_oracle(user, sess, ts, code, ip)
    ora = sessionize_oracle(user[keep], sess[keep], ts[keep], code[keep],
                            ip[keep])
    assert int(s.num_sessions) == len(ora)
    assert int(s.num_events) == int(keep.sum())


def test_dense_ngram_matches_sparse():
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from repro.analytics.ngram import dense_ngram_counts, ngram_counts
    from repro.core import SessionSequences, sessionize
    user, sess, ts, code, ip = _events(2048, seed=11)
    seqs = SessionSequences.from_sessionized(
        sessionize(user, sess, ts, code, ip, max_len=64))
    for n in (1, 2, 3):
        keys, counts = ngram_counts(seqs, n, 64)
        with enable_x64():
            dense = np.asarray(dense_ngram_counts(
                jnp.asarray(seqs.symbols), jnp.asarray(seqs.mask()), n, 64))
        expect = np.zeros(64 ** n, np.int64)
        expect[keys] = counts
        assert np.array_equal(dense, expect), f"order {n}"


def test_reach_histogram_matches_funnel_reach():
    import jax.numpy as jnp
    from repro.analytics.funnel import (build_stage_table, funnel_reach,
                                        reach_histogram)
    from repro.core import SessionSequences, sessionize
    user, sess, ts, code, ip = _events(2048, seed=13)
    seqs = SessionSequences.from_sessionized(
        sessionize(user, sess, ts, code, ip, max_len=64))
    stages = [np.array([1, 2]), np.array([5]), np.array([9, 10])]
    table = build_stage_table(stages, 64)
    got = np.asarray(reach_histogram(
        jnp.asarray(seqs.symbols), jnp.asarray(seqs.mask()),
        jnp.asarray(table), len(stages)))
    assert [(j, int(c)) for j, c in enumerate(got)] == \
        funnel_reach(seqs, stages, 64)


def test_bucket_by_destination_pytree_payload():
    """Nested payload trees route identically to flat column dicts."""
    import jax.numpy as jnp
    from repro.dist.collectives import bucket_by_destination
    rng = np.random.default_rng(17)
    n = 257
    dest = jnp.asarray(rng.integers(0, 4, n).astype(np.int32))
    a = jnp.asarray(rng.integers(0, 1000, n).astype(np.int32))
    b = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
    flat, _, _, _, d1 = bucket_by_destination(dict(a=a, b=b), dest, 4, 128)
    nested, _, _, _, d2 = bucket_by_destination(
        dict(cols=dict(a=a), payload=[b]), dest, 4, 128)
    assert int(d1) == int(d2)
    assert np.array_equal(flat["a"], nested["cols"]["a"])
    assert np.array_equal(flat["b"], nested["payload"][0])


# ---------------------------------------------------------------------------
# full pipeline on host-local meshes
# ---------------------------------------------------------------------------

def test_single_shard_pipeline_matches_oracle():
    """(1,) mesh, ragged n: the mesh plumbing with no real repartition."""
    import jax
    from repro.data.distpipe import (DistPipelineConfig,
                                     make_distributed_pipeline,
                                     single_host_pipeline)
    user, sess, ts, code, ip = _events(1023, seed=19, n_dupes=100)
    stages = [np.array([1, 2]), np.array([5])]
    cfg = DistPipelineConfig(alphabet_size=64, max_sessions_per_shard=2048,
                             max_len=64)
    pipe = make_distributed_pipeline(
        jax.make_mesh((1,), ("data",)), cfg, stages)
    res = pipe(user, sess, ts, code, ip)
    ora = single_host_pipeline(user, sess, ts, code, ip, cfg=cfg,
                               stages=stages)
    assert res.dropped == 0 and not res.truncated
    assert res.num_sessions() == ora.num_sessions()
    assert np.array_equal(res.ngram_counts, ora.ngram_counts)
    assert res.funnel_reach == ora.funnel_reach


def test_capacity_overflow_is_counted_never_silent():
    import jax
    from repro.data.distpipe import (DistPipelineConfig,
                                     make_distributed_pipeline)
    user, sess, ts, code, ip = _events(512, seed=23)
    cfg = DistPipelineConfig(alphabet_size=64, max_sessions_per_shard=512,
                             max_len=64, capacity_factor=0.25)
    pipe = make_distributed_pipeline(jax.make_mesh((1,), ("data",)), cfg)
    res = pipe(user, sess, ts, code, ip)
    assert res.dropped > 0
    assert res.funnel_reach is None  # built without stages


def test_loggen_corpus_pipeline_matches_oracle(loggen_corpus):
    """The shared loggen day (same fixture the streaming equivalence tests
    replay in test_streampipe.py) through the batch pipeline: mesh path ==
    single-host oracle on identical inputs, including the signup funnel."""
    import jax
    from repro.data.distpipe import (DistPipelineConfig,
                                     make_distributed_pipeline,
                                     single_host_pipeline)
    lc = loggen_corpus
    cfg = DistPipelineConfig(alphabet_size=lc.alphabet_size,
                             max_sessions_per_shard=lc.n_events,
                             max_len=128)
    pipe = make_distributed_pipeline(jax.make_mesh((1,), ("data",)), cfg,
                                     lc.stages)
    res = pipe(lc.user_id, lc.session_id, lc.timestamp, lc.code, lc.ip)
    ora = single_host_pipeline(lc.user_id, lc.session_id, lc.timestamp,
                               lc.code, lc.ip, cfg=cfg, stages=lc.stages)
    assert res.dropped == 0 and not res.truncated
    assert res.num_sessions() == ora.num_sessions() > 0
    assert np.array_equal(res.ngram_counts, ora.ngram_counts)
    assert res.funnel_reach == ora.funnel_reach
    # the funnel is actually populated in the corpus, not vacuously equal
    assert ora.funnel_reach[0][1] > 0


@pytest.mark.parametrize("n", [4096, 4093])  # divisible and ragged
def test_8shard_pipeline_matches_single_host(n):
    _run(f"""
    from repro.data.distpipe import (DistPipelineConfig,
                                     make_distributed_pipeline,
                                     single_host_pipeline)
    rng = np.random.default_rng(1)
    N = {n}
    user = rng.integers(0, 150, N).astype(np.int64) * 7919
    sess = rng.integers(0, 2, N).astype(np.int64)
    ts = (1.7e12 + rng.integers(0, 2*3600*1000, N)).astype(np.int64)
    code = rng.integers(0, 64, N).astype(np.int32)
    ip = rng.integers(0, 1 << 32, N).astype(np.int64)
    dup = rng.choice(N, 500, replace=False)
    for col in (user, sess, ts, code, ip):
        col[:500] = col[dup]
    stages = [np.array([1, 2]), np.array([5]), np.array([9, 10])]
    cfg = DistPipelineConfig(alphabet_size=64, max_sessions_per_shard=1024,
                             max_len=128, ngram_n=2)
    pipe = make_distributed_pipeline(jax.make_mesh((8,), ("data",)), cfg,
                                     stages)
    res = pipe(user, sess, ts, code, ip)
    ora = single_host_pipeline(user, sess, ts, code, ip, cfg=cfg,
                               stages=stages)
    assert res.dropped == 0
    assert res.num_sessions() == ora.num_sessions()
    assert np.array_equal(res.ngram_counts, ora.ngram_counts)
    assert res.funnel_reach == ora.funnel_reach
    got, exp = res.to_sequences(), ora.sequences
    gm, em = got.mask(), exp.mask()
    gs = sorted((int(got.user_id[i]), int(got.session_id[i]),
                 int(got.start_ts[i]), int(got.ip[i]),
                 int(got.duration_s[i]), tuple(got.symbols[i][gm[i]]))
                for i in range(len(got)))
    es = sorted((int(exp.user_id[i]), int(exp.session_id[i]),
                 int(exp.start_ts[i]), int(exp.ip[i]),
                 int(exp.duration_s[i]), tuple(exp.symbols[i][em[i]]))
                for i in range(len(exp)))
    assert gs == es
    print("OK")
    """)
