"""Unit tests for the unified distribution layer (repro.dist.sharding):
rule resolution, tree_spec round-trips on 1-device host meshes, elastic
degradation, and per-architecture layouts."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import (ShardingRules, REPLICATED, constrain, tree_spec,
                        arch_rules, adapt_rules_for_mesh, abstract_mesh,
                        make_host_mesh, use_mesh)
from repro.dist.sharding import LOGICAL_AXES, tree_shardings


def test_replicated_is_all_none():
    assert all(getattr(REPLICATED, f) is None for f in LOGICAL_AXES)


def test_spec_resolves_logical_names():
    r = ShardingRules(batch=("data",), heads="model")
    assert r.spec("batch", None, "heads", None) == \
        P(("data",), None, "model", None)
    assert r.spec("batch") == P(("data",))


def test_spec_deduplicates_mesh_axes_leftmost_wins():
    r = ShardingRules(kv_heads="model", cache_seq=("data", "model"))
    spec = r.spec("layers", "batch", "kv_heads", "cache_seq", "head_dim")
    assert spec == P(None, None, "model", ("data",), None)


def test_tree_spec_handles_nesting_scalars_and_none_dims():
    axes = dict(w=("embed", "heads", "head_dim"), scalar=(),
                nested=dict(v=(None, "act_embed")))
    specs = tree_spec(axes, ShardingRules(heads="model", act_embed="data"))
    assert specs["w"] == P(None, "model", None)
    assert specs["scalar"] == P()
    assert specs["nested"]["v"] == P(None, "data")


def test_tree_spec_roundtrip_on_host_mesh():
    """device_put through tree_spec shardings on a 1-device mesh is a
    value-preserving round-trip."""
    mesh = make_host_mesh(data=1, model=1)
    rules = adapt_rules_for_mesh(
        ShardingRules(batch=("data",), heads="model", mlp="model"), mesh)
    axes = dict(w=("embed", "mlp"), b=("mlp",), s=())
    tree = dict(w=jnp.arange(12.0).reshape(3, 4), b=jnp.arange(4.0),
                s=jnp.float32(7))
    sh = tree_shardings(axes, rules, mesh)
    assert all(isinstance(s, NamedSharding) for s in jax.tree.leaves(sh))
    out = jax.tree.map(jax.device_put, tree, sh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adapt_drops_model_axes_on_one_device_mesh():
    mesh = make_host_mesh(data=1, model=1)
    rules = ShardingRules(batch=("data",), heads="model", kv_heads="model",
                          mlp="model", expert="model", ssm_heads="model",
                          cache_seq=("data", "model"))
    adapted = adapt_rules_for_mesh(rules, mesh)
    assert adapted == REPLICATED
    # idempotent
    assert adapt_rules_for_mesh(adapted, mesh) == adapted


def test_adapt_drops_unknown_axes_keeps_live_ones():
    mesh = abstract_mesh((2, 4), ("data", "model"))
    rules = ShardingRules(batch=("pod", "data"), heads="model",
                          expert="ep")  # no "pod"/"ep" axis on this mesh
    adapted = adapt_rules_for_mesh(rules, mesh)
    assert adapted.batch == ("data",)
    assert adapted.heads == "model"
    assert adapted.expert is None


def test_constrain_is_noop_without_mesh_or_rules():
    x = jnp.ones((2, 3))
    assert constrain(x, REPLICATED, "batch", None) is x
    r = ShardingRules(batch=("data",))
    assert constrain(x, r, "batch", None) is x  # no active mesh


def test_constrain_applies_under_active_mesh():
    mesh = make_host_mesh(data=1, model=1)
    r = ShardingRules(batch=("data",))
    with use_mesh(mesh):
        y = jax.jit(lambda t: constrain(t, r, "batch", None))(jnp.ones((2, 3)))
    assert isinstance(y.sharding, NamedSharding)


def test_arch_rules_distinct_layouts_per_family():
    mesh = abstract_mesh((4, 4), ("data", "model"))
    dense = arch_rules(ShardingRules(), mesh, family="dense", num_heads=8,
                       num_kv_heads=4, d_ff=512, vocab=1024)
    moe = arch_rules(ShardingRules(), mesh, family="moe", num_heads=8,
                     num_kv_heads=4, d_ff=256, vocab=1024, num_experts=8)
    ssm = arch_rules(ShardingRules(), mesh, family="ssm", vocab=1024,
                     ssm_nheads=8, d_inner=256)
    assert len({dense, moe, ssm}) == 3
    # transformer: megatron-style head/ffn split
    assert dense.heads == "model" and dense.mlp == "model"
    assert dense.expert is None and dense.ssm_heads is None
    # moe: model axis on the expert dim, within-expert ffn unsharded
    assert moe.expert == "model" and moe.mlp is None
    # mamba2: state-space heads + inner width, state dim unsharded
    assert ssm.ssm_heads == "model" and ssm.mlp == "model"
    assert ssm.state is None and ssm.heads is None
    # all share data parallelism over the data axis
    assert dense.batch == moe.batch == ssm.batch == ("data",)


def test_arch_rules_respects_divisibility_and_base_overrides():
    mesh = abstract_mesh((2, 4), ("data", "model"))
    r = arch_rules(ShardingRules(), mesh, family="dense", num_heads=6,
                   num_kv_heads=2, d_ff=512, vocab=1001)
    assert r.heads is None        # 6 % 4 != 0
    assert r.kv_heads is None     # 2 % 4 != 0
    assert r.mlp == "model"
    assert r.vocab is None and r.logits_seq == "model"  # vocab fallback
    base = ShardingRules(mlp="data")  # explicit entries win
    assert arch_rules(base, mesh, family="dense", d_ff=512).mlp == "data"


def test_arch_rules_multi_pod_data_axes():
    mesh = abstract_mesh((2, 4, 4), ("pod", "data", "model"))
    r = arch_rules(ShardingRules(), mesh, family="dense", num_heads=8,
                   num_kv_heads=8, d_ff=512, vocab=1024)
    assert r.batch == ("pod", "data")


def test_arch_rules_on_one_device_mesh_degrades_to_replicated():
    mesh = make_host_mesh(data=1, model=1)
    r = arch_rules(ShardingRules(), mesh, family="moe", num_heads=8,
                   num_kv_heads=8, d_ff=512, vocab=1024, num_experts=8)
    assert r == REPLICATED
