"""Session-prefix caching tests: refcounted block sharing + copy-on-write.

Three layers, mirroring the implementation:

* ``BlockPool`` unit + property tests — refcount lifecycle, loud
  double-free/underflow/trash-block errors, the chained content-hash
  registry (first-wins registration, unregistration at refcount 0, COW
  donor lookup), and randomized take/share/free sequences checked against
  a shadow allocator (refcounts sum to live references, free + live
  partitions capacity).
* scheduler sharing with the deterministic stub — block tables of
  concurrent sharers point at the same ids with matching refcounts,
  registrations survive the first sharer's eviction, the pool drains
  clean, and the refcount-aware reservation admits streams a non-sharing
  pool must serialize.
* the real smoke LM — a prefix-sharing stream decodes bit-equal to the
  cold-cache path (full-block shares AND the copy-on-write boundary
  case), the COW donor's slab content is untouched by its copier, and
  the prefix run stays zero-retrace after warmup.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from hypothesis import given, settings, strategies as st

from repro.configs import smoke_config
from repro.models.registry import get_model
from repro.serve import (ContinuousScheduler, ServeMetrics,
                         BlockPool, PrefixPlan, chain_hash, prefix_hashes)
from repro.serve.cache import make_decode_state
from repro.serve.paged import PREFIX_SEED

# debug-defaulting SchedulerConfig wrapper: invariants checked after
# every evict/preempt in all scheduler tests
from test_serve import _stub_api, _stub_expected, VOCAB, SchedulerConfig


def _pool(num_blocks=8, block_size=4):
    return BlockPool(num_blocks=num_blocks, block_size=block_size,
                     num_kv_heads=1, head_dim=2, num_layers=1)


# ---------------------------------------------------------------------------
# BlockPool refcounts: lifecycle + loud failure modes
# ---------------------------------------------------------------------------

def test_refcount_lifecycle_share_then_free():
    pool = _pool()
    pool.reserve(1)
    blk = pool.take()
    assert pool.refcount(blk) == 1
    pool.share(blk)
    pool.share(blk)
    assert pool.refcount(blk) == 3
    assert pool.live_blocks == 1           # unique residency: still one
    assert pool.referenced_blocks == 3
    pool.free([blk])
    pool.free([blk])
    assert pool.refcount(blk) == 1         # two sharers gone, one holds
    assert pool.live_blocks == 1
    pool.free([blk])
    assert pool.refcount(blk) == 0
    assert pool.live_blocks == 0           # back on the free list
    pool.check_invariants()


def test_double_free_raises_underflow():
    pool = _pool()
    pool.reserve(1)
    blk = pool.take()
    pool.free([blk])
    with pytest.raises(ValueError, match=f"refcount underflow on block {blk}"):
        pool.free([blk])


def test_free_rejects_trash_block_and_out_of_range():
    pool = _pool(num_blocks=4)
    with pytest.raises(ValueError, match="trash block"):
        pool.free([0])
    with pytest.raises(ValueError, match="out of range"):
        pool.free([5])
    with pytest.raises(ValueError, match="out of range"):
        pool.free([-1])


def test_share_rejects_non_resident_and_trash():
    pool = _pool()
    with pytest.raises(ValueError, match="refcount 0"):
        pool.share(1)                      # never allocated
    with pytest.raises(ValueError, match="out of range"):
        pool.share(0)


def test_take_never_returns_trash_block():
    pool = _pool(num_blocks=6)
    pool.reserve(6)
    got = [pool.take() for _ in range(6)]
    assert 0 not in got
    assert sorted(got) == [1, 2, 3, 4, 5, 6]


# ---------------------------------------------------------------------------
# chained content-hash registry
# ---------------------------------------------------------------------------

def test_chain_hash_commits_to_full_prefix():
    toks = np.arange(8, dtype=np.int32)
    h1 = prefix_hashes(toks, 4)
    # identical second block under a DIFFERENT first block: its chained
    # hash must differ (same tokens at the same offset, different prefix)
    other = np.concatenate([toks[:4] + 1, toks[4:]])
    h2 = prefix_hashes(other, 4)
    assert h1[1] != h2[1]
    # and the partial tail never hashes
    assert len(prefix_hashes(np.arange(7, dtype=np.int32), 4)) == 1


def test_register_lookup_first_wins_and_dies_at_refcount_zero():
    pool = _pool(block_size=4)
    toks = np.array([5, 6, 7, 8], np.int32)
    h = chain_hash(PREFIX_SEED, toks)
    pool.reserve(2)
    a, b = pool.take(), pool.take()
    assert pool.register(h, PREFIX_SEED, a, toks) is True
    assert pool.register(h, PREFIX_SEED, b, toks) is False   # first wins
    assert pool.lookup(h) == a
    pool.share(a)
    pool.free([a])
    assert pool.lookup(h) == a             # one reference still holds it
    pool.free([a])
    assert pool.lookup(h) is None          # refcount 0 -> unregistered
    pool.check_invariants()
    pool.free([b])


def test_register_validates_residency_and_block_width():
    pool = _pool(block_size=4)
    toks = np.array([1, 2, 3, 4], np.int32)
    with pytest.raises(ValueError, match="refcount 0"):
        pool.register(b"h", PREFIX_SEED, 1, toks)
    pool.reserve(1)
    blk = pool.take()
    with pytest.raises(ValueError, match="full block"):
        pool.register(b"h", PREFIX_SEED, blk, toks[:3])
    pool.free([blk])


def test_find_extension_matches_leading_tokens_under_parent():
    pool = _pool(block_size=4)
    toks = np.array([9, 8, 7, 6], np.int32)
    h = chain_hash(PREFIX_SEED, toks)
    pool.reserve(1)
    blk = pool.take()
    pool.register(h, PREFIX_SEED, blk, toks)
    assert pool.find_extension(PREFIX_SEED, toks[:2]) == blk
    assert pool.find_extension(PREFIX_SEED, np.array([9, 9], np.int32)) is None
    assert pool.find_extension(b"other-parent", toks[:2]) is None
    assert pool.find_extension(PREFIX_SEED, toks[:0]) is None   # empty
    pool.free([blk])


# ---------------------------------------------------------------------------
# property tests: random take/share/free sequences vs a shadow allocator
# ---------------------------------------------------------------------------

@settings(max_examples=20)
@given(st.lists(st.integers(min_value=0, max_value=2**31 - 1),
                min_size=1, max_size=80))
def test_pool_random_sequences_keep_invariants(ops):
    pool = _pool(num_blocks=8)
    refs: dict[int, int] = {}              # shadow: block -> refcount
    held: list[int] = []                   # one entry per live reference
    for op in ops:
        kind = op % 3
        if kind == 0 and pool.can_reserve(1):          # take
            pool.reserve(1)
            blk = pool.take()
            assert blk != 0 and blk not in refs
            refs[blk] = 1
            held.append(blk)
        elif kind == 1 and held:                       # share a live block
            blk = held[(op // 3) % len(held)]
            pool.share(blk)
            refs[blk] += 1
            held.append(blk)
        elif kind == 2 and held:                       # drop one reference
            blk = held.pop((op // 3) % len(held))
            pool.free([blk])
            refs[blk] -= 1
            if refs[blk] == 0:
                del refs[blk]
        pool.check_invariants()
        assert pool.referenced_blocks == sum(refs.values()) == len(held)
        assert pool.live_blocks == len(refs)
        assert pool.live_blocks + len(pool._free) == pool.capacity
    # every block freed to refcount 0 must reject another free
    for blk in range(1, pool.num_blocks + 1):
        if blk not in refs:
            with pytest.raises(ValueError, match="refcount underflow"):
                pool.free([blk])


@settings(max_examples=10)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_pool_registration_follows_residency(seed):
    rnd = np.random.default_rng(seed)
    pool = _pool(num_blocks=6, block_size=4)
    live: list[int] = []
    registered: dict[int, bytes] = {}
    for _ in range(40):
        if live and rnd.random() < 0.4:
            blk = live.pop(int(rnd.integers(len(live))))
            pool.free([blk])
            if blk in registered:          # registration died with it
                assert pool.lookup(registered.pop(blk)) is None
        elif pool.can_reserve(1):
            pool.reserve(1)
            blk = pool.take()
            live.append(blk)
            toks = rnd.integers(0, 50, 4).astype(np.int32)
            h = chain_hash(PREFIX_SEED, toks)
            if pool.register(h, PREFIX_SEED, blk, toks):
                registered[blk] = h
        pool.check_invariants()
    for blk, h in registered.items():
        assert pool.lookup(h) == blk


# ---------------------------------------------------------------------------
# scheduler sharing with the deterministic stub
# ---------------------------------------------------------------------------

def _prefix_sched(api, *, batch=4, num_blocks=12, eos_after=50,
                  prefix=True, budget=4, metrics=None):
    return ContinuousScheduler(api, {}, SchedulerConfig(
        batch=batch, buckets=(8, 16), max_new_tokens=budget, paged=True,
        block_size=4, num_blocks=num_blocks, prefix_cache=prefix),
        metrics=metrics)


def test_scheduler_shares_resident_prefix_blocks():
    api = _stub_api(eos_after=50)
    sched = _prefix_sched(api)
    common = np.arange(4, 12, dtype=np.int32)      # 8 tokens = 2 full blocks
    a = np.concatenate([common, [20, 21, 22]])     # 11 tokens
    b = np.concatenate([common, [30, 31, 32]])
    sched.submit(a, max_new_tokens=4)
    sched.submit(b, max_new_tokens=4)
    sched._admit()
    st_, pool = sched.state, sched.pool
    # both slots map the same two leading blocks; the boundary is owned
    assert st_._blocks[0][:2] == st_._blocks[1][:2]
    assert st_._blocks[0][2] != st_._blocks[1][2]
    assert int(st_._shared[1]) == 2
    for blk in st_._blocks[0][:2]:
        assert pool.refcount(blk) == 2
    # the device table picks up the shared ids at the next decode view
    view = st_.decode_view(sched._pos, sched._active)
    assert np.array_equal(np.asarray(view["table"])[:2, :3],
                          st_._table[:2, :3])
    outs = sched.run()
    assert np.array_equal(outs[0], _stub_expected(a, 4, 50))
    assert np.array_equal(outs[1], _stub_expected(b, 4, 50))
    pool.check_invariants()
    assert pool.live_blocks == 0 and not pool._hash_to_block


def test_registration_survives_first_evict_and_pool_drains():
    api = _stub_api(eos_after=50)
    sched = _prefix_sched(api, batch=2)
    common = np.arange(4, 12, dtype=np.int32)
    r0 = sched.submit(np.concatenate([common, [20]]), max_new_tokens=2)
    r1 = sched.submit(np.concatenate([common, [30]]), max_new_tokens=6)
    sched._admit()
    pool = sched.state.pool
    shared = list(sched.state._blocks[0][:2])
    h = prefix_hashes(common, 4)
    while r0 in {int(sched._slot_rid[s])
                 for s in np.flatnonzero(sched._active)}:
        sched.step()
    # r0 (the registrant) is gone; r1 still references the shared blocks,
    # so the registrations must survive
    for blk, hh in zip(shared, h):
        assert pool.refcount(blk) == 1
        assert pool.lookup(hh) == blk
    sched.run()
    pool.check_invariants()
    assert pool.live_blocks == 0 and not pool._hash_to_block
    assert pool.available == pool.capacity


def test_refcount_aware_reservation_admits_sharing_stream():
    """At a pool size where cold admission serializes, prefix sharing
    fits everyone at once: the worst-case reservation counts shared
    blocks once."""
    api = _stub_api(eos_after=50)
    common = np.arange(4, 12, dtype=np.int32)      # 2 full blocks
    prompts = [np.concatenate([common, [20 + i]]) for i in range(4)]
    # each request worst-cases ceil((9 + 4 - 1) / 4) = 3 blocks; 4 cold
    # requests need 12, sharing needs 2 + 4 * 1... pool of 7 forces the
    # cold path to stall while the sharing path admits all four
    cold = _prefix_sched(_stub_api(eos_after=50), num_blocks=7, prefix=False)
    warm = _prefix_sched(api, num_blocks=7, prefix=True)
    for p in prompts:
        cold.submit(p, max_new_tokens=4)
        warm.submit(p, max_new_tokens=4)
    cold._admit()
    warm._admit()
    assert cold.num_active == 2            # 7 // 3 cold requests fit
    assert warm.num_active == 4            # sharing fits the whole stream
    co, wo = cold.run(), warm.run()
    for rid in co:
        assert np.array_equal(co[rid], wo[rid])
    warm.pool.check_invariants()


def test_prefix_metrics_rollup():
    api = _stub_api(eos_after=50)
    m = ServeMetrics(clock=iter(range(10000)).__next__)
    sched = _prefix_sched(api, metrics=m)
    common = np.arange(4, 12, dtype=np.int32)
    sched.submit(np.concatenate([common, [20]]), max_new_tokens=3)
    sched.submit(np.concatenate([common, [30]]), max_new_tokens=3)
    sched.run()
    s = m.summary()
    assert s["prefix_hit_rate"] == 0.5             # second request hits
    assert s["prefix_blocks_reused"] == 2
    assert s["prefill_tokens_skipped"] == 8
    assert s["mean_ttft_hit_s"] > 0 and s["mean_ttft_miss_s"] > 0
    # sharing visible in residency accounting: more references than
    # unique resident blocks at the peak
    assert s["kv_referenced_peak"] > s["kv_live_blocks_peak"]
    # existing keys stay stable for the CI gate
    for key in ("requests", "tokens", "tokens_per_sec", "p50_latency_s",
                "p99_latency_s", "p50_ttft_s", "p99_ttft_s", "kv_util_peak",
                "kv_live_blocks_peak", "kv_total_blocks",
                "kv_peak_resident_bytes"):
        assert key in s


def test_prefix_cache_requires_paged():
    api = _stub_api()
    with pytest.raises(ValueError, match="prefix_cache.*requires paged"):
        make_decode_state(api, SchedulerConfig(paged=False,
                                               prefix_cache=True), {})


# ---------------------------------------------------------------------------
# real model: bit-equality, COW donor immutability, zero retraces
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense128():
    cfg = smoke_config("behavior-lm-100m").with_(vocab_size=VOCAB,
                                                 max_cache_len=128)
    api = get_model(cfg)
    return api, api.init(jax.random.PRNGKey(0))


def _real_sched(api, params, *, prefix, metrics=None):
    return ContinuousScheduler(api, params, SchedulerConfig(
        batch=4, buckets=(8, 16, 32), max_new_tokens=6, paged=True,
        block_size=8, num_blocks=40, prefix_cache=prefix), metrics=metrics)


def test_prefix_stream_bit_equal_to_cold_cache(dense128):
    """Full-block shares, the COW boundary case, and a full 4-block share
    must all decode bit-identically to the cold path — the gathered
    prefix K/V is bitwise what a cold prefill would recompute."""
    api, params = dense128
    rng = np.random.default_rng(1)
    base = rng.integers(4, VOCAB, 32).astype(np.int32)   # 4 full blocks
    prompts = [base,                                     # registers 0..3
               base[:30],                                # COW inside block 3
               np.concatenate([base[:24], rng.integers(4, VOCAB, 6)
                               .astype(np.int32)])]      # 3-block share

    def run(prefix):
        sched = _real_sched(api, params, prefix=prefix)
        for p in prompts:
            sched.submit(p, max_new_tokens=6)
        outs = sched.run()
        sched.pool.check_invariants()
        assert sched.pool.live_blocks == 0
        return sched, outs

    _, cold = run(False)
    warm_sched, warm = run(True)
    for rid in cold:
        assert np.array_equal(cold[rid], warm[rid])
    # the stream actually shared: fewer unique blocks at the prefix peak
    # would show in metrics; here assert the plans fired via trace-free
    # re-drain below instead of metrics plumbing
    warm_sched.submit(base[:30], max_new_tokens=6)
    warm_sched.run()


def test_cow_copies_donor_without_mutating_it(dense128):
    api, params = dense128
    rng = np.random.default_rng(2)
    base = rng.integers(4, VOCAB, 32).astype(np.int32)
    sched = _real_sched(api, params, prefix=True)
    sched.submit(base, max_new_tokens=6)          # donor request
    sched._admit()
    st_ = sched.state
    donor_ids = list(st_._blocks[0])              # [b0 b1 b2 b3]
    donor_block = donor_ids[3]
    before = np.asarray(st_.data["k"][:, donor_block])
    sched.submit(base[:30], max_new_tokens=6)     # COW: boundary in block 3
    sched._admit()
    assert int(st_._shared[1]) == 3
    copy_block = st_._blocks[1][3]
    assert copy_block != donor_block              # fresh owned block
    assert st_._blocks[1][:3] == donor_ids[:3]    # leading blocks shared
    after = np.asarray(st_.data["k"][:, donor_block])
    assert np.array_equal(before, after)          # donor never written
    # the copy's prompt positions carry the donor's content (positions
    # 24..28 are before the divergence point 29)
    donor_k = np.asarray(st_.data["k"][:, donor_block])[:, :, :5]
    copy_k = np.asarray(st_.data["k"][:, copy_block])[:, :, :5]
    assert np.array_equal(donor_k, copy_k)
    assert sched.pool.refcount(donor_block) == 1  # COW is not a share
    sched.run()
    sched.pool.check_invariants()


def test_prefix_run_zero_retrace_after_warmup(dense128):
    api, params = dense128
    rng = np.random.default_rng(3)
    base = rng.integers(4, VOCAB, 30).astype(np.int32)

    def stream(sched, seed):
        r = np.random.default_rng(seed)
        for _ in range(6):
            sched.submit(np.concatenate(
                [base[:24], r.integers(4, VOCAB, 6).astype(np.int32)]),
                max_new_tokens=6)
        return sched.run()

    sched = _real_sched(api, params, prefix=True)
    stream(sched, 10)                              # warmup: cold + hit paths
    warm_traces = dict(sched.trace_counts)
    stream(sched, 11)
    assert dict(sched.trace_counts) == warm_traces
