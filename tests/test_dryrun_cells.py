"""Dry-run machinery sanity: lower+compile a reduced cell on a small host
mesh in a subprocess (the production 512-device sweep runs via
``python -m repro.launch.dryrun --all``; these keep the plumbing honest in
the fast suite)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import sys; sys.path.insert(0, {REPO_SRC!r})
        import numpy as np, jax, jax.numpy as jnp
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


CELL_BODY = """
from repro.dist import make_mesh, use_mesh
from repro.launch.shapes import make_cell, Shape
mesh = make_mesh((4, 4), ("data", "model"))
cell = make_cell({arch!r}, {shape!r}, mesh,
                 overrides=dict({overrides}),
                 shape_override=Shape({kind!r}, {seq}, {batch}))
fn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
             donate_argnums=cell.donate_argnums)
with use_mesh(mesh):
    compiled = fn.lower(*cell.args).compile()
mem = compiled.memory_analysis()
assert mem.temp_size_in_bytes > 0
from repro.dist.compat import cost_analysis
cost = cost_analysis(compiled)
assert cost["flops"] > 0
print("OK", int(mem.temp_size_in_bytes), int(cost["flops"]))
"""


@pytest.mark.parametrize("arch,shape,kind,seq,batch,overrides", [
    ("llama3-8b", "train_4k", "train", 256, 16,
     "num_layers=2, d_model=256, d_ff=512, num_heads=8, num_kv_heads=4, "
     "vocab_size=1024, microbatches=2"),
    ("llama3-8b", "decode_32k", "decode", 512, 16,
     "num_layers=2, d_model=256, d_ff=512, num_heads=8, num_kv_heads=4, "
     "vocab_size=1024"),
    ("dbrx-132b", "train_4k", "train", 256, 16,
     "num_layers=2, d_model=256, d_ff=256, num_heads=8, num_kv_heads=4, "
     "vocab_size=1024, num_experts=8, experts_per_token=2, microbatches=1"),
    ("mamba2-370m", "prefill_32k", "prefill", 256, 16,
     "num_layers=2, d_model=256, ssm_state=32, ssm_headdim=32, ssm_chunk=64,"
     " vocab_size=1024"),
])
def test_cell_lowers_and_compiles(arch, shape, kind, seq, batch, overrides):
    out = _run(CELL_BODY.format(arch=arch, shape=shape, kind=kind, seq=seq,
                                batch=batch, overrides=overrides))
    assert out.startswith("OK")


def test_seq_parallel_variant_compiles():
    out = _run(CELL_BODY.format(
        arch="llama3-8b", shape="train_4k", kind="train", seq=256, batch=16,
        overrides="num_layers=2, d_model=256, d_ff=512, num_heads=8, "
                  "num_kv_heads=4, vocab_size=1024, microbatches=1, "
                  "seq_parallel=True"))
    assert out.startswith("OK")
