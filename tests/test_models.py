"""Model-zoo behaviour: param accounting, decode/teacher-forcing agreement,
MoE routing equivalence, SSD chunked-vs-sequential."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import ModelConfig, get_model, analytic_param_count
from repro.models import transformer as T
from repro.models import mamba2 as MB
from repro.models import moe as MOE
from repro.dist.sharding import REPLICATED, ShardingRules

RNG = np.random.default_rng(0)


def _batch(cfg, b=2, s=24, extra=None):
    toks = RNG.integers(4, cfg.vocab_size, (b, s + 1)).astype(np.int32)
    out = dict(tokens=toks[:, :-1], targets=toks[:, 1:],
               loss_mask=np.ones((b, s), np.float32))
    if extra:
        out.update(extra(b))
    return out


DENSE = ModelConfig(name="d", family="dense", num_layers=3, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=211,
                    qk_norm=True, attn_bias=True, dtype="float32",
                    remat="none", max_cache_len=48)


def test_dense_param_count_exact():
    api = get_model(DENSE)
    params = api.init(jax.random.PRNGKey(0))
    assert sum(t.size for t in jax.tree.leaves(params)) == \
        analytic_param_count(DENSE)


def test_dense_decode_matches_teacher_forcing():
    api = get_model(DENSE)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(DENSE)
    hidden, _ = T.forward(params, batch["tokens"], DENSE, REPLICATED)
    full = np.asarray(T.logits_of(params, hidden, DENSE, REPLICATED))
    lg, st, idx = api.prefill(params, {**batch,
                                       "tokens": batch["tokens"][:, :12]})
    np.testing.assert_allclose(np.asarray(lg), full[:, 11], rtol=2e-4,
                               atol=2e-4)
    for t in range(12, 18):
        lg, st = api.decode_step(params, batch["tokens"][:, t], st, t)
        np.testing.assert_allclose(np.asarray(lg), full[:, t], rtol=2e-4,
                                   atol=2e-4)


def test_remat_and_unroll_invariance():
    api = get_model(DENSE)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(DENSE)
    base = float(api.loss(params, batch)[0])
    for variant in (DENSE.with_(remat="full"),
                    DENSE.with_(scan_layers=False),
                    DENSE.with_(remat="dots", scan_layers=False)):
        alt = float(get_model(variant).loss(params, batch)[0])
        assert abs(alt - base) < 1e-5


def test_microbatch_invariance():
    cfg = DENSE.with_(microbatches=1)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, b=4)
    from repro.train import make_train_step, OptConfig, init_opt_state
    ocfg = OptConfig(lr=1e-3)
    s1 = dict(params=params, opt=init_opt_state(params, ocfg))
    s2 = jax.tree.map(jnp.copy, s1)
    st1, m1 = make_train_step(api, ocfg)(s1, batch)
    api4 = get_model(cfg.with_(microbatches=4))
    st4, m4 = make_train_step(api4, ocfg)(s2, batch)
    # same data, same total gradient (up to accumulation-order float noise)
    for a, b in zip(jax.tree.leaves(st1["params"]),
                    jax.tree.leaves(st4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_moe_ep_equals_dense_routing():
    cfg = ModelConfig(num_layers=1, d_model=32, d_ff=64, vocab_size=50,
                      num_experts=8, experts_per_token=2, dtype="float32",
                      moe_capacity_factor=8.0)
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    y_dense, _ = MOE.moe_ffn_dense(x, p, cfg, REPLICATED)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = ShardingRules(batch=("data",), expert="model")
    y_ep, drops = MOE.moe_ffn_ep(x, p, cfg, rules, mesh)
    assert int(drops) == 0
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ep),
                               rtol=1e-5, atol=1e-5)


def test_moe_capacity_drops_counted():
    cfg = ModelConfig(num_layers=1, d_model=32, d_ff=64, vocab_size=50,
                      num_experts=8, experts_per_token=4, dtype="float32",
                      moe_capacity_factor=0.05)   # absurdly tight
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32), jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = ShardingRules(batch=("data",), expert="model")
    _, drops = MOE.moe_ffn_ep(x, p, cfg, rules, mesh)
    assert int(drops) > 0


@pytest.mark.parametrize("s,chunk", [(64, 16), (40, 8), (128, 128)])
def test_ssd_chunked_vs_sequential(s, chunk):
    B, H, P, N = 2, 4, 16, 8
    x = jnp.asarray(RNG.standard_normal((B, s, H, P)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(RNG.standard_normal((B, s, H)), jnp.float32))
    a = -jnp.exp(jnp.asarray(RNG.standard_normal(H), jnp.float32))
    Bm = jnp.asarray(RNG.standard_normal((B, s, H, N)), jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((B, s, H, N)), jnp.float32)
    y1, h1 = MB.ssd_sequential_ref(x, dt, a, Bm, Cm)
    y2, h2 = MB.ssd_chunked(x, dt, a, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4,
                               atol=2e-4)


def test_ssm_decode_matches_teacher_forcing():
    cfg = ModelConfig(name="s", family="ssm", num_layers=2, d_model=64,
                      vocab_size=101, d_ff=0, ssm_state=16, ssm_headdim=16,
                      ssm_chunk=16, dtype="float32", remat="none")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, s=32)
    hidden, _ = MB.forward(params, batch["tokens"], cfg, REPLICATED)
    full = np.asarray(jnp.einsum("bsd,vd->bsv", hidden, params["unembed"]))
    lg, st, idx = api.prefill(params, {**batch,
                                       "tokens": batch["tokens"][:, :16]})
    np.testing.assert_allclose(np.asarray(lg), full[:, 15], rtol=3e-4,
                               atol=3e-4)
    for t in range(16, 22):
        lg, st = api.decode_step(params, batch["tokens"][:, t], st, t)
        np.testing.assert_allclose(np.asarray(lg), full[:, t], rtol=3e-4,
                                   atol=3e-4)


def test_hybrid_decode_matches_teacher_forcing():
    cfg = ModelConfig(name="h", family="hybrid", num_layers=5, attn_every=2,
                      d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
                      vocab_size=101, ssm_state=16, ssm_headdim=16,
                      ssm_chunk=16, dtype="float32", remat="none",
                      max_cache_len=48)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, s=32)
    from repro.models import hybrid as HY
    hidden, _ = HY.forward(params, batch["tokens"], cfg, REPLICATED)
    full = np.asarray(jnp.einsum("bsd,vd->bsv", hidden, params["unembed"]))
    lg, st, idx = api.prefill(params, {**batch,
                                       "tokens": batch["tokens"][:, :16]})
    np.testing.assert_allclose(np.asarray(lg), full[:, 15], rtol=3e-4,
                               atol=3e-4)
    for t in range(16, 20):
        lg, st = api.decode_step(params, batch["tokens"][:, t], st, t)
        np.testing.assert_allclose(np.asarray(lg), full[:, t], rtol=3e-4,
                                   atol=3e-4)


def test_encdec_decode_matches_teacher_forcing():
    cfg = ModelConfig(name="w", family="encdec", num_layers=2,
                      encoder_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=4, d_ff=128, vocab_size=101, n_frames=12,
                      max_target_len=64, use_layernorm=True,
                      tie_embeddings=True, dtype="float32", remat="none",
                      max_cache_len=48)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    frames = RNG.standard_normal((2, 12, 64)).astype(np.float32)
    batch = _batch(cfg, s=24, extra=lambda b: dict(frames=frames))
    from repro.models import encdec as ED
    enc = ED.encode(params, jnp.asarray(frames), cfg, REPLICATED)
    hidden, _ = ED.decode_stack(params, batch["tokens"], enc, cfg, REPLICATED)
    full = np.asarray(jnp.einsum("bsd,vd->bsv", hidden, params["embed"]))
    lg, st, idx = api.prefill(params, {**batch,
                                       "tokens": batch["tokens"][:, :12]})
    np.testing.assert_allclose(np.asarray(lg), full[:, 11], rtol=3e-4,
                               atol=3e-4)
    for t in range(12, 16):
        lg, st = api.decode_step(params, batch["tokens"][:, t], st, t)
        np.testing.assert_allclose(np.asarray(lg), full[:, t], rtol=3e-4,
                                   atol=3e-4)


def test_vlm_decode_matches_teacher_forcing():
    cfg = ModelConfig(name="v", family="vlm", num_layers=6,
                      cross_attn_every=3, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=101, n_patches=8,
                      vision_dim=24, dtype="float32", remat="none",
                      max_cache_len=48)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    patches = RNG.standard_normal((2, 8, 24)).astype(np.float32)
    batch = _batch(cfg, s=24, extra=lambda b: dict(patches=patches))
    from repro.models import vision as VI
    hidden, _ = VI.forward(params, batch["tokens"], jnp.asarray(patches),
                           cfg, REPLICATED)
    full = np.asarray(jnp.einsum("bsd,vd->bsv", hidden, params["unembed"]))
    lg, st, idx = api.prefill(params, {**batch,
                                       "tokens": batch["tokens"][:, :12]})
    np.testing.assert_allclose(np.asarray(lg), full[:, 11], rtol=3e-4,
                               atol=3e-4)
    for t in range(12, 16):
        lg, st = api.decode_step(params, batch["tokens"][:, t], st, t)
        np.testing.assert_allclose(np.asarray(lg), full[:, t], rtol=3e-4,
                                   atol=3e-4)
