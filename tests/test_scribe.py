import os

import numpy as np
import pytest

from repro.data import (generate, LogGenConfig, deliver_batch, LogMover,
                        DeliveryError, read_warehouse_hour, Oink)


def test_exactly_once_under_faults(tmp_path):
    log = generate(LogGenConfig(n_users=60, seed=3))
    stats = deliver_batch(log.batch, str(tmp_path / "staging"),
                          str(tmp_path / "wh"), crash_prob=0.10, seed=7)
    assert stats["undelivered"] == 0
    assert stats["messages"] == len(log.batch)      # no loss
    assert stats["dupes"] > 0                       # faults actually fired
    # and the warehouse parses back
    hours = sorted(stats["hours"])
    rows = read_warehouse_hour(str(tmp_path / "wh"), "client_events", hours[0])
    assert all("event_name" in r for r in rows)


def test_no_faults_no_dupes(tmp_path):
    log = generate(LogGenConfig(n_users=20, seed=1))
    stats = deliver_batch(log.batch, str(tmp_path / "staging"),
                          str(tmp_path / "wh"), crash_prob=0.0, seed=1)
    assert stats["dupes"] == 0
    assert stats["messages"] == len(log.batch)


def test_mover_requires_all_datacenters(tmp_path):
    staging = tmp_path / "staging"
    (staging / "dc0" / "cat" / "1").mkdir(parents=True)
    mover = LogMover(str(staging), str(tmp_path / "wh"), ["dc0", "dc1"])
    with pytest.raises(DeliveryError):
        mover.move_hour("cat", 1)   # dc1 never staged
    assert not (tmp_path / "wh" / "cat" / "1").exists()  # nothing committed


def test_mover_idempotent(tmp_path):
    staging = tmp_path / "staging"
    for dc in ("dc0",):
        (staging / dc / "cat" / "5").mkdir(parents=True)
    mover = LogMover(str(staging), str(tmp_path / "wh"), ["dc0"])
    s1 = mover.move_hour("cat", 5)
    s2 = mover.move_hour("cat", 5)
    assert not s1.get("skipped") and s2.get("skipped")


def test_uncommitted_hour_unreadable(tmp_path):
    os.makedirs(tmp_path / "wh" / "cat" / "9")
    with pytest.raises(DeliveryError):
        read_warehouse_hour(str(tmp_path / "wh"), "cat", 9)


def test_oink_dependency_order_and_retry():
    calls = []
    flaky = {"n": 0}

    def a(_):
        calls.append("a")
        return 1

    def b(dep):
        flaky["n"] += 1
        if flaky["n"] == 1:
            raise RuntimeError("transient")
        calls.append("b")
        return dep["a"] + 1

    o = Oink()
    o.add("b", b, deps=("a",), max_attempts=2)
    o.add("a", a)
    out = o.run()
    assert calls == ["a", "b"]          # dependency order despite add order
    assert out["b"] == 2                # retry succeeded
    assert any(t.attempts == 2 for t in o.traces if t.name == "b")


def test_oink_failure_skips_dependents():
    def bad(_):
        raise RuntimeError("boom")

    o = Oink()
    o.add("x", bad, max_attempts=1)
    o.add("y", lambda d: 1, deps=("x",))
    o.run()
    ty = [t for t in o.traces if t.name == "y"][0]
    assert not ty.success and "dependency" in ty.error
