"""llama-3.2-vision-11b [vlm] 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers every 5th layer; vision tower is a
STUB (input_specs supplies patch embeddings (B, 1600, 1280))
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm", num_layers=40, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=128256,
    cross_attn_every=5, n_patches=1600, vision_dim=1280,
    rope_theta=500_000.0,
    remat="full", microbatches=4,
)

SMOKE = FULL.with_(
    num_layers=6, cross_attn_every=3, d_model=128, num_heads=4,
    num_kv_heads=2, d_ff=256, vocab_size=512, n_patches=16, vision_dim=48,
    dtype="float32", remat="none", microbatches=1, max_cache_len=64)
