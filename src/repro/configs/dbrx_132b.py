"""dbrx-132b [moe] 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16e top-4 — fine-grained  [hf:databricks/dbrx-base;
unverified]"""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="dbrx-132b", family="moe", num_layers=40, d_model=6144,
    num_heads=48, num_kv_heads=8, d_ff=10752, vocab_size=100352,
    num_experts=16, experts_per_token=4, moe_capacity_factor=1.25,
    rope_theta=500_000.0,
    remat="full", microbatches=8,
)

SMOKE = FULL.with_(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=512, num_experts=4, experts_per_token=2,
    dtype="float32", remat="none", microbatches=1, max_cache_len=64)
