"""olmoe-1b-7b [moe] 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64e top-8  [arXiv:2409.02060; hf]"""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="olmoe-1b-7b", family="moe", num_layers=16, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1024, vocab_size=50304,
    num_experts=64, experts_per_token=8, moe_capacity_factor=1.25,
    qk_norm=True,
    remat="full", microbatches=4,
)

SMOKE = FULL.with_(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=64,
    vocab_size=512, num_experts=8, experts_per_token=2,
    dtype="float32", remat="none", microbatches=1, max_cache_len=64)
