"""whisper-tiny [audio] 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 —
enc-dec, conv frontend (STUB: input_specs supplies precomputed frame
embeddings (B, 1500, 384))  [arXiv:2212.04356; unverified]

6 heads / vocab 51865 do not divide the 16-way model axis -> attention
heads and vocab are replicated; only FFN/embed shard (arch_rules)."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-tiny", family="encdec", num_layers=4, encoder_layers=4,
    d_model=384, num_heads=6, num_kv_heads=6, d_ff=1536, vocab_size=51865,
    n_frames=1500, max_target_len=448, use_layernorm=True,
    tie_embeddings=True,
    remat="full", microbatches=1,
)

SMOKE = FULL.with_(
    num_layers=2, encoder_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, n_frames=20, max_target_len=64,
    dtype="float32", remat="none", microbatches=1, max_cache_len=64)
