"""qwen2-72b [dense] 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — GQA, QKV bias  [arXiv:2407.10671; hf]"""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-72b", family="dense", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, d_ff=29568, vocab_size=152064,
    attn_bias=True, rope_theta=1_000_000.0,
    remat="full", microbatches=16,
)

SMOKE = FULL.with_(
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2, d_ff=256,
    vocab_size=512, dtype="float32", remat="none", microbatches=1,
    max_cache_len=64)
