"""qwen3-0.6b [dense] 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 — qk_norm, GQA  [hf:Qwen/Qwen3-8B; hf]

head_dim=128 (decoupled from d_model/num_heads) and tied embeddings, per
the released Qwen3-0.6B."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-0.6b", family="dense", num_layers=28, d_model=1024,
    num_heads=16, num_kv_heads=8, d_ff=3072, vocab_size=151936,
    head_dim=128, qk_norm=True, tie_embeddings=True,
    rope_theta=1_000_000.0,
    remat="full", microbatches=2,
)

SMOKE = FULL.with_(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512, dtype="float32", remat="none", microbatches=1,
    max_cache_len=64)
