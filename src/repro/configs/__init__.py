"""Assigned architecture configs (--arch <id>) + the paper's behaviour LM."""
from . import (stablelm_3b, qwen2_72b, llama3_8b, qwen3_0_6b, mamba2_370m,
               dbrx_132b, olmoe_1b_7b, zamba2_7b, whisper_tiny,
               llama32_vision_11b, paper)

REGISTRY = {
    "stablelm-3b": stablelm_3b,
    "qwen2-72b": qwen2_72b,
    "llama3-8b": llama3_8b,
    "qwen3-0.6b": qwen3_0_6b,
    "mamba2-370m": mamba2_370m,
    "dbrx-132b": dbrx_132b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "zamba2-7b": zamba2_7b,
    "whisper-tiny": whisper_tiny,
    "llama-3.2-vision-11b": llama32_vision_11b,
    "behavior-lm-100m": paper,
}

ASSIGNED = [k for k in REGISTRY if k != "behavior-lm-100m"]


def full_config(arch: str):
    return REGISTRY[arch].FULL


def smoke_config(arch: str):
    return REGISTRY[arch].SMOKE
