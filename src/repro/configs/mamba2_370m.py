"""mamba2-370m [ssm] 48L d_model=1024 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality)  [arXiv:2405.21060; unverified]"""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-370m", family="ssm", num_layers=48, d_model=1024,
    num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    remat="full", microbatches=2,
)

SMOKE = FULL.with_(
    num_layers=2, d_model=128, vocab_size=512, ssm_state=16, ssm_headdim=32,
    ssm_chunk=16, dtype="float32", remat="none", microbatches=1)
