"""zamba2-7b [hybrid] 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attn blocks
[arXiv:2411.15242; unverified]

The shared transformer block runs after every 6th Mamba2 layer (13
invocations + 3-layer tail). Per-invocation LoRA deltas omitted (DESIGN.md
§Arch-applicability)."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-7b", family="hybrid", num_layers=81, d_model=3584,
    num_heads=32, num_kv_heads=32, d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
    attn_every=6,
    remat="full", microbatches=4,
)

SMOKE = FULL.with_(
    num_layers=5, attn_every=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, ssm_state=16, ssm_headdim=32, ssm_chunk=16,
    dtype="float32", remat="none", microbatches=1, max_cache_len=64)
