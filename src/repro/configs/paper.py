"""The paper's own model config: a behaviour LM over the session-sequence
event alphabet (§5.4 extended — 'more advanced sequence models' from §6).

~100M params, trainable end-to-end on this container by
examples/train_behavior_lm.py; vocab = client-event alphabet + specials."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="behavior-lm-100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=2048, vocab_size=2048,
    tie_embeddings=True,
    remat="none", microbatches=1, max_cache_len=1024,
)

SMOKE = FULL.with_(num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
                   d_ff=256, vocab_size=512, dtype="float32",
                   max_cache_len=64)
