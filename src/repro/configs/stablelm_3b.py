"""stablelm-3b [dense] 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304  [hf:stabilityai/stablelm-2-1_6b; unverified]

LayerNorm (not RMSNorm) per the StableLM family. Full rotary is used here
(the released model uses partial rotary_pct=0.25 — noted deviation)."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="stablelm-3b", family="dense", num_layers=32, d_model=2560,
    num_heads=32, num_kv_heads=32, d_ff=6912, vocab_size=50304,
    use_layernorm=True, rope_theta=10_000.0,
    remat="full", microbatches=4,
)

SMOKE = FULL.with_(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
    vocab_size=512, dtype="float32", remat="none", microbatches=1,
    max_cache_len=64)
