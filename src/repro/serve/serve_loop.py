"""Serving loop: batched prefill + incremental decode.

``Server.generate`` is the fixed-batch compatibility surface, and for
EVERY family it is a thin wrapper over the continuous-batching
``ContinuousScheduler`` (scheduler.py): each row is trimmed to its real
length, admitted as one request (per-row encoder extras — frames/patches
— ride along), and decoded with per-row positions — so right-padded
prompts decode bit-identically to their trimmed copies. The family
rejection branches are gone: ssm/hybrid serve through ``RecurrentState``
/ ``HybridState`` (ragged prefill freezes the recurrence across pads) and
encdec/vlm through ``CrossAttnState`` (see ``serve/cache.py``).

``Server.generate_batch`` is the explicit fixed-batch oracle — one
prefill over the whole rectangle, lockstep decode to the longest row —
kept as the independent reference the family-matrix equivalence tests
(and ``launch/serve.py --batch``) compare the scheduler against, with the
decode-loop correctness fixes:

* the RNG key is split *before* the first post-prefill sample, so the
  prefill-token draw and later decode draws are independent streams;
* the loop never launches a decode whose logits would be discarded, and
  short-circuits as soon as every row has emitted EOS;
* rows that hit EOS stay frozen at EOS.
"""
from __future__ import annotations

import collections
import warnings
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..models.registry import ModelApi
from ..data.pipeline import PAD_ID, EOS_ID
from .scheduler import ContinuousScheduler, SchedulerConfig


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0
    # paged KV cache (caps.paged families): fixed-size blocks shared
    # across slots instead of a max_cache_len stripe per row — serve/paged
    paged: bool = False
    block_size: int = 16
    num_blocks: int | None = None
    # session-prefix caching (requires paged): refcounted block sharing +
    # tail-only prefill for prompts with resident prefixes
    prefix_cache: bool = False
    # optimistic admission (requires paged): reserve up to this factor of
    # pool capacity; exhaustion mid-decode preempts the lowest-priority
    # victim (see serve/scheduler.py). 1.0 = honest reservation.
    overcommit: float = 1.0
    # run BlockPool.check_invariants after every evict/preempt
    debug: bool = False
    # cap on cached (batch, bucket) schedulers: each pins its compiled
    # prefill/decode fns AND its decode-state slab on device, so a
    # long-lived server seeing many shapes must not grow without bound —
    # least-recently-used shapes are evicted (loudly, via warnings.warn)
    max_schedulers: int = 8


def prompt_lengths(prompts: np.ndarray) -> np.ndarray:
    """Per-row real lengths of right-PAD-padded prompts: one past the last
    non-PAD token, clamped to >= 1 (an all-PAD row serves a length-1 pad
    prompt rather than an illegal empty one)."""
    prompts = np.asarray(prompts)
    not_pad = prompts != PAD_ID
    lens = prompts.shape[1] - np.argmax(not_pad[:, ::-1], axis=1)
    lens = np.where(not_pad.any(axis=1), lens, 1)
    return lens.astype(np.int32)


class Server:
    def __init__(self, api: ModelApi, params, scfg: ServeConfig, mesh=None):
        self.api = api
        self.params = params
        self.scfg = scfg
        self.mesh = mesh
        self._prefill = jax.jit(lambda p, b: api.prefill(p, b))
        self._decode = jax.jit(
            lambda p, tok, st, i: api.decode_step(p, tok, st, i))
        self.decode_calls = 0        # batch-path decode_step invocations
        # LRU over (batch, bucket) shapes, capped at scfg.max_schedulers
        self._schedulers: collections.OrderedDict[tuple,
                                                  ContinuousScheduler] = \
            collections.OrderedDict()
        self.scheduler_evictions = 0

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    def _bucket_width(self, prompt_len: int) -> int:
        """Round the prefill width up a power-of-two ladder so generate()
        calls with nearby prompt widths share one compiled scheduler (rows
        are trimmed to real length before submit, so the width is only a
        compilation key). Position-bounded families fall back to the exact
        width when the rounded bucket would overflow the KV cache but the
        prompt itself fits; recurrent state has no such bound."""
        b = 8
        while b < prompt_len:
            b *= 2
        if not self.api.caps.positioned:
            return b
        cap = self.api.cfg.max_cache_len - self.scfg.max_new_tokens + 1
        return b if b <= cap else prompt_len

    def scheduler_for(self, batch: int, bucket: int) -> ContinuousScheduler:
        """The cached continuous scheduler for a (slots, bucket) shape —
        cached so repeated generate() calls reuse the compiled fns.

        The cache is a true LRU capped at ``scfg.max_schedulers``: every
        cached scheduler pins compiled executables and a device slab, so
        a long-lived fleet process cycling through many shapes would
        otherwise accrete them forever. Evicting the coldest shape is
        safe — ``generate`` drains its scheduler synchronously, so a
        cached scheduler is never mid-request — but it throws away that
        shape's compilation, so the eviction is *loud* (a
        ``warnings.warn`` naming the shape): seeing it repeatedly means
        ``max_schedulers`` is too small for the workload's shape mix.
        """
        key = (batch, bucket)
        sched = self._schedulers.get(key)
        if sched is not None:
            self._schedulers.move_to_end(key)
            return sched
        while len(self._schedulers) >= max(1, self.scfg.max_schedulers):
            old_key, _ = self._schedulers.popitem(last=False)
            self.scheduler_evictions += 1
            warnings.warn(
                f"Server scheduler cache full ({self.scfg.max_schedulers} "
                f"shapes): evicting least-recently-used shape "
                f"(batch, bucket)={old_key} and its compiled fns — raise "
                "ServeConfig.max_schedulers if this recurs",
                RuntimeWarning, stacklevel=2)
        sched = ContinuousScheduler(
            self.api, self.params,
            SchedulerConfig(batch=batch, buckets=(bucket,),
                            max_new_tokens=self.scfg.max_new_tokens,
                            temperature=self.scfg.temperature,
                            seed=self.scfg.seed,
                            paged=self.scfg.paged,
                            block_size=self.scfg.block_size,
                            num_blocks=self.scfg.num_blocks,
                            prefix_cache=self.scfg.prefix_cache,
                            overcommit=self.scfg.overcommit,
                            debug=self.scfg.debug),
            mesh=self.mesh)
        self._schedulers[key] = sched
        return sched

    def generate(self, prompts: np.ndarray, extra: dict | None = None):
        """prompts: (B, L) int32, PAD-padded on the right. Returns
        (B, max_new_tokens) tokens; rows freeze at EOS once emitted.

        Every family routes through the continuous scheduler: rows are
        trimmed to their real lengths and admitted as one request each
        (``extra`` values are sliced per row — encdec frames, vlm
        patches), so a padded prompt decodes identically to its trimmed
        copy.
        """
        prompts = np.asarray(prompts, np.int32)
        b, l = prompts.shape
        lens = prompt_lengths(prompts)
        sched = self.scheduler_for(b, self._bucket_width(int(lens.max())))
        rids = []
        for i in range(b):
            row_extra = None
            if extra:
                row_extra = {k: np.asarray(v)[i] for k, v in extra.items()}
            rids.append(sched.submit(
                prompts[i, :lens[i]],
                max_new_tokens=self.scfg.max_new_tokens, extra=row_extra))
        outs = sched.run()
        n = self.scfg.max_new_tokens
        rows = []
        for rid in rids:
            toks = outs[rid][:n]
            rows.append(np.concatenate(
                [toks, np.full(n - len(toks), EOS_ID, np.int32)]))
        return np.stack(rows, axis=0)

    def generate_batch(self, prompts: np.ndarray, extra: dict | None = None):
        """Fixed-batch oracle: one ragged prefill over the whole (B, L)
        rectangle, lockstep decode to the longest row. The independent
        reference path the scheduler is asserted bit-equal against."""
        prompts = np.asarray(prompts, np.int32)
        b, l = prompts.shape
        batch = dict(tokens=jnp.asarray(prompts, jnp.int32),
                     lengths=jnp.asarray(prompt_lengths(prompts)))
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        logits, state, index = self._prefill(self.params, batch)
        key, sub = jax.random.split(jax.random.PRNGKey(self.scfg.seed))
        out = []
        tok = self._sample(logits, sub)
        done = jnp.zeros((b,), bool)
        n = self.scfg.max_new_tokens
        for t in range(n):
            out.append(np.asarray(tok))
            done = done | (tok == EOS_ID)
            if t == n - 1 or bool(done.all()):
                break      # never launch a decode whose logits are unused
            key, sub = jax.random.split(key)
            logits, state = self._decode(self.params, tok, state, index + t)
            self.decode_calls += 1
            tok = jnp.where(done, EOS_ID, self._sample(logits, sub))
        while len(out) < n:          # EOS-frozen tail after short-circuit
            out.append(np.full((b,), EOS_ID, np.int32))
        return np.stack(out, axis=1)
