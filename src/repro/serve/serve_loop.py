"""Serving loop: batched prefill + incremental decode.

Requests are padded/batched to the compiled (batch, prompt_len) buckets —
one jitted prefill and one jitted decode_step per bucket, the standard
static-shape TPU serving recipe. Sampling: greedy or temperature.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..models.registry import ModelApi
from ..data.pipeline import PAD_ID, BOS_ID, EOS_ID


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0


class Server:
    def __init__(self, api: ModelApi, params, scfg: ServeConfig):
        self.api = api
        self.params = params
        self.scfg = scfg
        self._prefill = jax.jit(lambda p, b: api.prefill(p, b))
        self._decode = jax.jit(
            lambda p, tok, st, i: api.decode_step(p, tok, st, i))

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, extra: dict | None = None):
        """prompts: (B, L) int32, PAD-padded on the right (all rows share
        the compiled prompt length). Returns (B, max_new_tokens) tokens.

        NOTE: right-padded prompts shorter than L will attend to their own
        padding; serving-quality masking uses per-row lengths — we decode
        from the common prompt length (the bucket contract).
        """
        b, l = prompts.shape
        batch = dict(tokens=jnp.asarray(prompts, jnp.int32))
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        logits, state, index = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(self.scfg.seed)
        out = []
        tok = self._sample(logits, key)
        done = jnp.zeros((b,), bool)
        for t in range(self.scfg.max_new_tokens):
            out.append(np.asarray(tok))
            done = done | (tok == EOS_ID)
            key, sub = jax.random.split(key)
            logits, state = self._decode(self.params, tok, state, index + t)
            tok = jnp.where(done, EOS_ID, self._sample(logits, sub))
        return np.stack(out, axis=1)
