"""Continuous-batching decode scheduler: request queue + slot table.

The serving problem the paper's §5 "answer a large class of common queries
quickly" implies: an open-ended stream of session-prefix requests with
variable prompt lengths, served from fixed-shape device buffers (the TPU
contract — no recompilation per request). The classic continuous-batching
recipe:

* A **slot table** of ``batch`` rows. Each slot owns one row of the decode
  state (KV cache) plus host-side bookkeeping: request id, absolute
  position, tokens emitted, budget.
* **Admission** pulls the next queued request, left-aligns its prompt into
  the smallest compiled ``(1, bucket_len)`` prefill bucket (right-padded
  with PAD), prefills with per-row ``lengths`` so logits come from the last
  *real* token, and inserts the resulting row state into a free slot with
  one ``dynamic_update_slice`` along the batch axis.
* **Decode** runs one jitted step over the *whole* slot table with per-row
  position indices — every active slot sits at a different depth; padding
  K/V is overwritten/masked by the per-row cache write (see
  ``models.registry`` serving contract). Inactive slots decode garbage that
  is ignored and overwritten at the next admission.
* **Eviction** frees a slot the moment its request emits EOS or exhausts
  its token budget; the next ``_admit`` backfills it from the queue.

Everything device-side is jitted once per shape: one prefill per bucket
length, one decode step, one row insert. ``trace_counts`` tracks actual
retraces (a python-level counter bumped only when jit re-traces), which is
what the no-recompilation-after-warmup test asserts.

Sharding: with ``mesh`` given, params and the KV-cache slab are placed via
``repro.dist`` rules (``tree_shardings`` over the models' logical axes) and
every device call runs under ``dist.compat.use_mesh`` — the same rules that
constrain the batch/kv_heads dims on the production mesh degrade to
replicated on the host-local test meshes.
"""
from __future__ import annotations

import collections
from contextlib import nullcontext
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..data.pipeline import PAD_ID, EOS_ID
from ..dist.compat import use_mesh
from ..dist.sharding import tree_shardings
from ..models import layers as L
from ..models.registry import ModelApi
from .metrics import ServeMetrics


@dataclass(frozen=True)
class Request:
    rid: int
    tokens: np.ndarray           # (prompt_len,) int32, no padding
    max_new_tokens: int


@dataclass
class SchedulerConfig:
    batch: int = 4                         # slot-table rows
    buckets: tuple[int, ...] = (16, 32, 64)  # compiled prefill lengths
    max_new_tokens: int = 32               # default per-request budget
    temperature: float = 0.0               # 0 = greedy
    seed: int = 0


class ContinuousScheduler:
    """Serve an open-ended request stream from fixed-shape buffers.

    Supports the attention-cache families whose decode state stacks the
    batch on axis 1 of every leaf (dense/moe) — exactly what the row
    insert relies on. SSM-state families need exact-length prompts and a
    different state layout; they stay on the batch ``Server`` path.
    """

    SUPPORTED_FAMILIES = ("dense", "moe")

    def __init__(self, api: ModelApi, params, cfg: SchedulerConfig,
                 mesh=None, metrics: ServeMetrics | None = None):
        if api.cfg.family not in self.SUPPORTED_FAMILIES:
            raise ValueError(
                f"ContinuousScheduler supports {self.SUPPORTED_FAMILIES}, "
                f"got family {api.cfg.family!r}; use Server.generate's "
                "batch path for SSM/cross-attention families")
        # a request writes its last decode input at prompt_len + budget - 2,
        # so the cache must hold max(buckets) + max_new_tokens - 1 positions
        if api.cfg.max_cache_len < max(cfg.buckets) + cfg.max_new_tokens - 1:
            raise ValueError(
                f"max_cache_len={api.cfg.max_cache_len} cannot hold the "
                f"largest bucket {max(cfg.buckets)} plus "
                f"{cfg.max_new_tokens} generated tokens")
        self.api = api
        self.cfg = cfg
        self.mesh = mesh
        self.metrics = metrics
        self.trace_counts = collections.Counter()
        self.decode_steps = 0
        self.prefills = 0

        if mesh is not None:
            params = jax.device_put(
                params, tree_shardings(api.axes(), api.rules, mesh))
        self.params = params

        temp = cfg.temperature

        def sample(logits, key):
            if temp <= 0.0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.random.categorical(
                key, logits / temp, axis=-1).astype(jnp.int32)

        def prefill_fn(p, toks, lengths, key):
            logits, state, idx = api.prefill(
                p, dict(tokens=toks, lengths=lengths))
            return sample(logits, key), state, idx

        def step_fn(p, cur_tok, state, pos, active, key):
            # inactive slots decode at position 0: their row state is dead
            # (fully overwritten by the next insert) so the garbage write
            # is harmless, and clamping keeps the scatter in bounds.
            safe_pos = jnp.where(active, pos, 0)
            logits, state = api.decode_step(p, cur_tok, state, safe_pos)
            nxt = sample(logits, key)
            return jnp.where(active, nxt, PAD_ID), state

        def insert_fn(state, row_state, slot):
            return jax.tree.map(
                lambda c, r: jax.lax.dynamic_update_slice_in_dim(
                    c, r.astype(c.dtype), slot, axis=1),
                state, row_state)

        self._prefill = jax.jit(self._counted("prefill", prefill_fn))
        self._step = jax.jit(self._counted("decode", step_fn))
        self._insert = jax.jit(self._counted("insert", insert_fn))

        # slot table (host-side bookkeeping)
        B = cfg.batch
        self._active = np.zeros(B, bool)
        self._slot_rid = np.full(B, -1, np.int64)
        self._pos = np.zeros(B, np.int32)
        self._cur_tok = np.zeros(B, np.int32)
        self._emitted = np.zeros(B, np.int32)
        self._budget = np.zeros(B, np.int32)

        self._pending: collections.deque[Request] = collections.deque()
        self._next_rid = 0
        self._step_counter = 0
        self._key = jax.random.PRNGKey(cfg.seed)
        self.outputs: dict[int, list[int]] = {}
        self._state = self._init_state()

    # -- plumbing ----------------------------------------------------------

    def _counted(self, name, fn):
        def wrapped(*args):
            # runs only when jit (re)traces — a cache hit never reaches here
            self.trace_counts[name] += 1
            return fn(*args)
        return wrapped

    def _ctx(self):
        return use_mesh(self.mesh) if self.mesh is not None else nullcontext()

    def _init_state(self):
        """Zero decode state of the full-slot-table shape, via eval_shape
        (no wasted prefill compute, no extra compile)."""
        B, b0 = self.cfg.batch, self.cfg.buckets[0]
        shapes = jax.eval_shape(
            lambda p: self.api.prefill(p, dict(
                tokens=jnp.zeros((B, b0), jnp.int32),
                lengths=jnp.ones((B,), jnp.int32)))[1],
            self.params)
        state = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), shapes)
        if self.mesh is not None:
            try:
                shardings = tree_shardings(L.kv_cache_axes(), self.api.rules,
                                           self.mesh)
                state = jax.device_put(state, shardings)
            except ValueError:
                pass  # state tree doesn't match the plain KV layout
        return state

    # -- public API --------------------------------------------------------

    def submit(self, tokens, max_new_tokens: int | None = None) -> int:
        """Queue one request; returns its rid. ``tokens``: (prompt_len,)."""
        toks = np.asarray(tokens, np.int32).reshape(-1)
        if len(toks) == 0:
            toks = np.array([PAD_ID], np.int32)
        if len(toks) > max(self.cfg.buckets):
            raise ValueError(
                f"prompt length {len(toks)} exceeds the largest bucket "
                f"{max(self.cfg.buckets)}")
        budget = (self.cfg.max_new_tokens if max_new_tokens is None
                  else max_new_tokens)
        if len(toks) + budget - 1 > self.api.cfg.max_cache_len:
            raise ValueError(
                f"prompt length {len(toks)} + budget {budget} overflows "
                f"max_cache_len={self.api.cfg.max_cache_len}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, tokens=toks, max_new_tokens=budget)
        self._pending.append(req)
        if self.metrics is not None:
            self.metrics.record_submit(rid, prompt_len=len(toks))
        return rid

    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    @property
    def num_pending(self) -> int:
        return len(self._pending)

    def _bucket_for(self, n: int) -> int:
        for b in sorted(self.cfg.buckets):
            if n <= b:
                return b
        raise ValueError(n)

    def _finish(self, rid: int) -> None:
        if self.metrics is not None:
            self.metrics.record_finish(rid)

    def _admit(self) -> None:
        """Backfill free slots from the queue (prefill + row insert)."""
        free = np.flatnonzero(~self._active)
        fi = 0
        while self._pending and fi < len(free):
            req = self._pending.popleft()
            slot = int(free[fi])
            n = len(req.tokens)
            bucket = self._bucket_for(n)
            toks = np.full((1, bucket), PAD_ID, np.int32)
            toks[0, :n] = req.tokens
            key = jax.random.fold_in(
                jax.random.fold_in(self._key, 1), req.rid)
            with self._ctx():
                tok0, row_state, idx = self._prefill(
                    self.params, jnp.asarray(toks),
                    jnp.asarray([n], jnp.int32), key)
            self.prefills += 1
            if self.metrics is not None:
                self.metrics.record_admit(req.rid)
            t0 = int(np.asarray(tok0)[0])
            self.outputs[req.rid] = [t0]
            if self.metrics is not None:
                self.metrics.record_token(req.rid)
            if t0 == EOS_ID or req.max_new_tokens <= 1:
                self._finish(req.rid)      # done at admission: slot stays free
                continue
            with self._ctx():
                self._state = self._insert(self._state, row_state,
                                           jnp.int32(slot))
            self._active[slot] = True
            self._slot_rid[slot] = req.rid
            self._pos[slot] = n
            self._cur_tok[slot] = t0
            self._emitted[slot] = 1
            self._budget[slot] = req.max_new_tokens
            fi += 1

    def step(self) -> dict[int, int]:
        """One decode step over the whole slot table; returns this step's
        emissions {rid: token}. Evicts finished rows and backfills."""
        self._admit()
        if not self._active.any():
            return {}
        key = jax.random.fold_in(self._key, 2 * self._step_counter)
        self._step_counter += 1
        with self._ctx():
            nxt, self._state = self._step(
                self.params, jnp.asarray(self._cur_tok), self._state,
                jnp.asarray(self._pos), jnp.asarray(self._active), key)
        self.decode_steps += 1
        nxt = np.asarray(nxt)
        emissions: dict[int, int] = {}
        for slot in np.flatnonzero(self._active):
            rid = int(self._slot_rid[slot])
            tok = int(nxt[slot])
            emissions[rid] = tok
            self.outputs[rid].append(tok)
            self._emitted[slot] += 1
            self._pos[slot] += 1
            if self.metrics is not None:
                self.metrics.record_token(rid)
            if tok == EOS_ID or self._emitted[slot] >= self._budget[slot]:
                self._finish(rid)
                self._active[slot] = False     # evict; backfilled next admit
                self._slot_rid[slot] = -1
        self._cur_tok = nxt.astype(np.int32)
        self._admit()
        return emissions

    def run(self) -> dict[int, np.ndarray]:
        """Drain the queue: admit/decode/evict until every submitted request
        has finished. Returns {rid: (n_tokens,) int32} for the requests
        drained since the last ``run`` and releases them — the open-ended
        stream never accumulates history device- or host-side."""
        self._admit()
        while self._active.any() or self._pending:
            self.step()
        done = {rid: np.asarray(toks, np.int32)
                for rid, toks in self.outputs.items()}
        self.outputs = {}
        return done
