"""Continuous-batching decode scheduler: request queue + slot table.

The serving problem the paper's §5 "answer a large class of common queries
quickly" implies: an open-ended stream of session-prefix requests with
variable prompt lengths, served from fixed-shape device buffers (the TPU
contract — no recompilation per request). The classic continuous-batching
recipe:

* A **slot table** of ``batch`` rows. Each slot owns one row of the decode
  state plus host-side bookkeeping: request id, absolute position, tokens
  emitted, budget.
* **Admission** pulls the next queued request, left-aligns its prompt into
  the smallest compiled ``(1, bucket_len)`` prefill bucket (right-padded
  with PAD), prefills with per-row ``lengths`` so logits come from the last
  *real* token, and inserts the resulting row state into a free slot.
* **Decode** runs one jitted step over the *whole* slot table with per-row
  position indices — every active slot sits at a different depth. Inactive
  slots decode garbage that is ignored and overwritten at the next
  admission (attention families mask/overwrite stale K/V per-row;
  recurrent families fully overwrite the row state at insert).
* **Eviction** frees a slot the moment its request emits EOS or exhausts
  its token budget; the next ``_admit`` backfills it from the queue.

**Every family serves through this scheduler.** The state layouts live
behind the ``DecodeState`` protocol (``serve/cache.py``): dense/moe KV
stripes (``DenseKVState``) or the shared paged block slab
(``PagedKVState``, ``SchedulerConfig.paged``), ssm recurrent rows
(``RecurrentState`` — ragged prefill freezes the recurrence across pads),
hybrid Mamba+shared-attention rows (``HybridState``), and encdec/vlm
self-KV + frozen per-row cross-attention stacks (``CrossAttnState`` —
per-request encoder inputs ride ``submit(..., extra=...)``). The
scheduler itself is a pure protocol consumer: admission is gated by
``state.can_admit``, eviction goes through ``state.evict``, and the
KV-occupancy metrics read ``state.occupancy``.

**Over-commit + priority preemption** (``SchedulerConfig.overcommit``,
paged only). The worst-case block reservation wastes capacity on requests
that finish early, so admission may optimistically reserve up to
``overcommit x pool capacity``. When the bet loses — the pool's free list
is actually empty as a decode crosses a block boundary — the scheduler
preempts the lowest-priority (ties: youngest, i.e. largest rid) victim:
its blocks are freed through the refcount-aware ``evict``, and the
request is requeued at the *front* of its priority class with its
already-generated tokens appended to the prompt as a **re-prefill**
(recompute, not swap — prefill is cheap at these sizes, and with
``prefix_cache`` the original prompt's resident blocks make the re-prefill
nearly free). Greedy outputs are bit-equal to a never-preempted run —
the open-loop SLO benchmark asserts it. Requests carry a ``priority``
class (``submit(..., priority=)``, higher = more important): admission
drains classes strictly highest-first and victims are chosen
lowest-first, so high-priority tail latency is protected while
low-priority work absorbs the over-commit risk.

Everything device-side is jitted once per shape: one prefill per bucket
length, one decode step, one row insert. ``trace_counts`` tracks actual
retraces (a python-level counter bumped only when jit re-traces), which is
what the no-recompilation-after-warmup test asserts — for every family.
Preempt/requeue cycles reuse the same bucketed prefills, so they stay
retrace-free too.

Sharding: with ``mesh`` given, params and the decode state are placed via
``repro.dist`` rules (``tree_shardings`` over the models' logical axes) and
every device call runs under ``dist.compat.use_mesh`` — the same rules that
constrain the batch/kv_heads dims on the production mesh degrade to
replicated on the host-local test meshes.
"""
from __future__ import annotations

import collections
from contextlib import nullcontext
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..data.pipeline import PAD_ID, EOS_ID
from ..dist.compat import use_mesh
from ..dist.sharding import tree_shardings
from ..models.registry import ModelApi
from .cache import make_decode_state
from .metrics import ServeMetrics
from .paged import PoolExhausted


@dataclass(frozen=True)
class Request:
    rid: int
    tokens: np.ndarray           # (prompt_len,) int32, no padding
    max_new_tokens: int
    extra: dict | None = None    # per-request prefill extras (frames/...)
    priority: int = 0            # higher = admitted first, preempted last
    resumed: bool = False        # requeued after preemption: ``tokens``
    #                              already carries the generated prefix and
    #                              ``max_new_tokens`` is the remaining budget


@dataclass
class SchedulerConfig:
    batch: int = 4                         # slot-table rows
    buckets: tuple[int, ...] = (16, 32, 64)  # compiled prefill lengths
    max_new_tokens: int = 32               # default per-request budget
    temperature: float = 0.0               # 0 = greedy
    seed: int = 0
    # paged KV (caps.paged families): share one slab of fixed blocks
    paged: bool = False
    block_size: int = 16                   # tokens per KV block
    num_blocks: int | None = None          # allocatable blocks; default
    #                                        batch * max_cache_len/block_size
    #                                        (dense-equivalent capacity)
    # session-prefix caching (requires paged): refcounted sharing of
    # resident prompt blocks + tail-only prefill (see serve/paged.py)
    prefix_cache: bool = False
    # optimistic admission (requires paged): reserve up to this factor of
    # the pool's real capacity; actual exhaustion mid-decode preempts the
    # lowest-priority (ties: youngest) request, which is requeued with
    # its generated tokens as a re-prefill. 1.0 = honest reservation,
    # preemption impossible.
    overcommit: float = 1.0
    # run BlockPool.check_invariants after every evict/preempt (tests)
    debug: bool = False


class ContinuousScheduler:
    """Serve an open-ended request stream from fixed-shape buffers.

    Hosts every registry family: the family's decode-state layout is
    resolved from its ``ServeCaps`` into a ``DecodeState`` implementation
    (``serve/cache.py``) and the scheduler operates purely on that
    protocol. Unknown families fail loudly at construction.

    With ``cfg.paged`` the per-slot K/V stripes are replaced by a shared
    ``BlockPool`` slab: admission is gated by blocks available, tables
    grow lazily as decode crosses block boundaries, and eviction returns
    blocks to the pool (see ``serve/cache.PagedKVState``).
    """

    def __init__(self, api: ModelApi, params, cfg: SchedulerConfig,
                 mesh=None, metrics: ServeMetrics | None = None):
        self.api = api
        self.cfg = cfg
        self.mesh = mesh
        self.metrics = metrics
        self.trace_counts = collections.Counter()
        self.decode_steps = 0
        self.prefills = 0

        if mesh is not None:
            params = jax.device_put(
                params, tree_shardings(api.axes(), api.rules, mesh))
        self.params = params

        self.state = make_decode_state(api, cfg, params, mesh=mesh,
                                       counted=self._counted)
        cap = self.state.max_positions()
        # a request writes its last decode input at prompt_len + budget - 2,
        # so a bounded cache must hold max(buckets) + max_new_tokens - 1
        if cap is not None and cap < max(cfg.buckets) + cfg.max_new_tokens - 1:
            raise ValueError(
                f"max_cache_len={cap} cannot hold the largest bucket "
                f"{max(cfg.buckets)} plus {cfg.max_new_tokens} generated "
                "tokens")

        temp = cfg.temperature

        def sample(logits, key):
            if temp <= 0.0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.random.categorical(
                key, logits / temp, axis=-1).astype(jnp.int32)

        self._sample = sample

        def step_fn(p, cur_tok, state, pos, active, key):
            # inactive slots decode at position 0: their row state is dead
            # (fully overwritten by the next insert) so the garbage write
            # is harmless, and clamping keeps the scatter in bounds.
            safe_pos = jnp.where(active, pos, 0)
            logits, state = api.decode_step(p, cur_tok, state, safe_pos)
            nxt = sample(logits, key)
            return jnp.where(active, nxt, PAD_ID), state

        self._step = jax.jit(self._counted("decode", step_fn))
        self._prefill_fns: dict[int | None, callable] = {}

        # slot table (host-side bookkeeping)
        B = cfg.batch
        self._active = np.zeros(B, bool)
        self._slot_rid = np.full(B, -1, np.int64)
        self._pos = np.zeros(B, np.int32)
        self._cur_tok = np.zeros(B, np.int32)
        self._emitted = np.zeros(B, np.int32)
        self._budget = np.zeros(B, np.int32)
        self._slot_prio = np.zeros(B, np.int64)
        self._slot_req: list[Request | None] = [None] * B

        # one FIFO per priority class; admission drains the highest class
        # first, a preempted request re-enters at the FRONT of its class
        # (it is the class's most senior in-flight work)
        self._pending: dict[int, collections.deque[Request]] = {}
        self._next_rid = 0
        self._step_counter = 0
        self._key = jax.random.PRNGKey(cfg.seed)
        self.outputs: dict[int, list[int]] = {}
        self.preemptions = 0
        self.state.init(B, cfg.max_new_tokens)

    # -- plumbing ----------------------------------------------------------

    @property
    def pool(self):
        """The paged block pool (None in dense mode) — benchmark surface."""
        return getattr(self.state, "pool", None)

    def _counted(self, name, fn):
        def wrapped(*args):
            # runs only when jit (re)traces — a cache hit never reaches here
            self.trace_counts[name] += 1
            return fn(*args)
        return wrapped

    def _ctx(self):
        return use_mesh(self.mesh) if self.mesh is not None else nullcontext()

    def _prefill_for(self, cache_len: int | None):
        """The jitted admission prefill for a static cache length (paged
        admission prefills into a bucket-covering cache; None keeps the
        family default). One python callable per cache length, all bumping
        the shared 'prefill' trace counter.

        Prefix-hit admissions carry ``prefix_ids``/``pool_k``/``pool_v``
        (the resident blocks to reuse) plus a traced ``start``: the shared
        blocks are gathered out of the slab into the prefill cache, so the
        model computes only the divergent tail — with the COW donor block
        gathered like any other, the boundary block's content rides the
        normal scatter into a freshly owned block (the copy of
        copy-on-write costs one extra block id in the gather)."""
        fn = self._prefill_fns.get(cache_len)
        if fn is None:
            sample = self._sample

            def prefill_fn(p, batch, key):
                b = dict(batch)
                if cache_len is not None:
                    b["cache_len"] = cache_len
                ids = b.pop("prefix_ids", None)
                if ids is not None:
                    pool_k, pool_v = b.pop("pool_k"), b.pop("pool_v")

                    def gather(slab):
                        g = slab[:, ids]          # (L, nb, KVH, bs, Dh)
                        l, nb, kvh, bs, hd = g.shape
                        g = g.transpose(0, 2, 1, 3, 4).reshape(
                            l, kvh, nb * bs, hd)
                        return g[:, None]         # (L, 1, KVH, S, Dh)

                    b["prefix_kv"] = dict(k=gather(pool_k),
                                          v=gather(pool_v))
                logits, state, idx = self.api.prefill(p, b)
                return sample(logits, key), state, idx

            fn = jax.jit(self._counted("prefill", prefill_fn))
            self._prefill_fns[cache_len] = fn
        return fn

    # -- public API --------------------------------------------------------

    def submit(self, tokens, max_new_tokens: int | None = None,
               extra: dict | None = None, priority: int = 0) -> int:
        """Queue one request; returns its rid. ``tokens``: (prompt_len,).
        ``extra`` carries the family's per-request prefill inputs (encdec
        frames, vlm patches) — validated against the registry caps.
        ``priority`` is the request's class (higher = admitted first,
        preempted last; classes drain strictly highest-first)."""
        toks = np.asarray(tokens, np.int32).reshape(-1)
        if len(toks) == 0:
            toks = np.array([PAD_ID], np.int32)
        if len(toks) > max(self.cfg.buckets):
            raise ValueError(
                f"prompt length {len(toks)} exceeds the largest bucket "
                f"{max(self.cfg.buckets)}")
        bucket = self._bucket_for(len(toks))
        budget = (self.cfg.max_new_tokens if max_new_tokens is None
                  else max_new_tokens)
        cap = self.state.max_positions()
        if cap is not None and len(toks) + budget - 1 > cap:
            raise ValueError(
                f"prompt length {len(toks)} (bucket {bucket}) + budget "
                f"{budget} needs {len(toks) + budget - 1} cache positions "
                f"and overflows max_cache_len={cap}")
        if self.cfg.overcommit > 1.0 \
                and len(toks) + budget - 1 > max(self.cfg.buckets):
            # a preempted request re-prefills prompt + generated tokens;
            # its worst-case requeue prompt (one shy of prompt + budget)
            # must still fit a compiled bucket
            raise ValueError(
                f"over-commit serving needs prompt ({len(toks)}) + budget "
                f"({budget}) - 1 <= the largest bucket "
                f"({max(self.cfg.buckets)}) so a preempted request can "
                "always re-prefill its generated tokens")
        self.state.validate_request(len(toks), bucket, budget)
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, tokens=toks, max_new_tokens=budget,
                      extra=self._normalize_extra(extra),
                      priority=int(priority))
        self._push_pending(req)
        if self.metrics is not None:
            self.metrics.record_submit(rid, prompt_len=len(toks),
                                       priority=req.priority)
        return rid

    # -- priority queues ---------------------------------------------------

    def _push_pending(self, req: Request, front: bool = False) -> None:
        dq = self._pending.setdefault(req.priority, collections.deque())
        dq.appendleft(req) if front else dq.append(req)

    def _head_queue(self) -> collections.deque[Request] | None:
        """The nonempty queue of the highest priority class, or None.
        Admission never skips past a blocked head to a lower class — that
        would hand the blocked request's blocks to work it outranks."""
        for prio in sorted(self._pending, reverse=True):
            if self._pending[prio]:
                return self._pending[prio]
        return None

    def _normalize_extra(self, extra: dict | None) -> dict | None:
        spec = self.api.caps.extras
        need = [k for k, _, _ in spec]
        got = sorted(extra or {})
        if sorted(need) != got:
            raise ValueError(
                f"family {self.api.cfg.family!r} requires extras {need} "
                f"per request, got {got}")
        if not spec:
            return None
        norm = {}
        for key, shape_fn, dt in spec:
            want = tuple(shape_fn(self.api.cfg, 1))
            arr = np.asarray(extra[key], dt)
            if arr.shape == want[1:]:
                arr = arr[None]
            if arr.shape != want:
                raise ValueError(
                    f"extra {key!r} must have shape {want[1:]} (one row), "
                    f"got {arr.shape}")
            norm[key] = arr
        return norm

    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    @property
    def num_pending(self) -> int:
        return sum(len(dq) for dq in self._pending.values())

    @property
    def has_work(self) -> bool:
        """True while any request is active or queued — the fleet router's
        drain condition (and a cheap guard before ``step_once``)."""
        return bool(self._active.any()) or self.num_pending > 0

    def occupancy_snapshot(self) -> np.ndarray:
        """The occupancy gossip vector: ``[free, pending, active]`` int32.

        ``free`` is the resource admission is actually gated on — free pool
        blocks in paged mode, free slot rows in dense mode. Host-side
        counters only (no device sync), so a fleet router can refresh it
        every tick for free. Fixed shape/dtype by contract: the fleet's
        gossip all-gather stacks one of these per replica.
        """
        pool = self.pool
        free = (pool.free_blocks if pool is not None
                else self.cfg.batch - self.num_active)
        return np.array([free, self.num_pending, self.num_active], np.int32)

    def step_once(self) -> dict[int, int]:
        """Non-blocking step: one decode step if there is work, else an
        immediate ``{}`` without touching the device — so a fleet router
        can tick every replica each round without idle replicas paying for
        an admission scan or a garbage decode."""
        if not self.has_work:
            return {}
        return self.step()

    def _bucket_for(self, n: int) -> int:
        for b in sorted(self.cfg.buckets):
            if n <= b:
                return b
        raise ValueError(n)

    def _finish(self, rid: int) -> None:
        if self.metrics is not None:
            self.metrics.record_finish(rid)

    def _admit(self) -> None:
        """Backfill free slots from the queue (prefill + row insert).

        Beyond a free row, the head request must pass the state's resource
        gate (``can_admit`` — paged mode reserves its worst case in
        blocks, scaled by ``overcommit``), else admission stalls (FIFO
        within a class, classes strictly highest-first) until an eviction
        frees resources."""
        free = np.flatnonzero(~self._active)
        fi = 0
        while fi < len(free):
            dq = self._head_queue()
            if dq is None:
                break
            req = dq[0]                             # peek: may not fit yet
            n = len(req.tokens)
            # prefix planning is pure (no pool side effects): the plan only
            # shrinks the reservation can_admit gates on, and admit()
            # realizes it after the terminal-at-admission check below
            plan = self.state.prefix_plan(req.tokens, req.max_new_tokens)
            if not self.state.can_admit(n, req.max_new_tokens, plan=plan):
                break                               # wait for an eviction
            dq.popleft()
            slot = int(free[fi])
            # prefix hit: prefill only the divergent tail, bucketed by its
            # own (shorter) length; the cache still covers start + bucket
            start = 0 if plan is None else plan.start
            tail = req.tokens[start:]
            bucket = self._bucket_for(len(tail))
            toks = np.full((1, bucket), PAD_ID, np.int32)
            toks[0, :len(tail)] = tail
            batch = dict(tokens=jnp.asarray(toks),
                         lengths=jnp.asarray([len(tail)], jnp.int32))
            if req.extra:
                batch.update({k: jnp.asarray(v)
                              for k, v in req.extra.items()})
            cache_len = self.state.prefill_cache_len(start + bucket)
            batch.update(self.state.prefill_prefix_inputs(plan, cache_len))
            key = jax.random.fold_in(
                jax.random.fold_in(self._key, 1), req.rid)
            prefill = self._prefill_for(cache_len)
            if self.metrics is not None:
                self.metrics.record_admit(req.rid)
                self.metrics.record_prefix(
                    req.rid,
                    blocks_reused=plan.blocks_reused if plan else 0,
                    tokens_skipped=start)
            with self._ctx():
                tok0, row_state, idx = prefill(self.params, batch, key)
            self.prefills += 1
            t0 = int(np.asarray(tok0)[0])
            if req.resumed:
                # requeued after preemption: the prompt already replayed
                # the generated prefix, t0 continues the same output list
                self.outputs[req.rid].append(t0)
            else:
                self.outputs[req.rid] = [t0]
            if self.metrics is not None:
                self.metrics.record_token(req.rid)
            if t0 == EOS_ID or req.max_new_tokens <= 1:
                self._finish(req.rid)      # done at admission: slot stays free
                continue
            self.state.admit(slot, n, req.max_new_tokens, plan=plan)
            with self._ctx():
                self.state.prefill_insert(row_state, slot, n, bucket)
            self._active[slot] = True
            self._slot_rid[slot] = req.rid
            self._slot_prio[slot] = req.priority
            self._slot_req[slot] = req
            self._pos[slot] = n
            self._cur_tok[slot] = t0
            self._emitted[slot] = 1
            self._budget[slot] = req.max_new_tokens
            fi += 1

    def _preempt_one(self) -> None:
        """Evict the lowest-priority (ties: youngest, i.e. largest rid)
        active request and requeue it at the front of its class with its
        generated tokens appended to the prompt — the re-prefill replays
        them so greedy outputs stay bit-equal to a never-preempted run.

        Preempting the only active request would livelock (its own growth
        exhausted the pool it is about to re-prefill into), and honest
        per-request validation makes that unreachable — so it is a loud
        bug, not a recoverable state."""
        active = np.flatnonzero(self._active)
        if len(active) <= 1:
            raise RuntimeError(
                "BlockPool exhausted with "
                f"{len(active)} active request(s): preempting the only "
                "request cannot free enough blocks for its own re-prefill. "
                "Per-request validation should make this unreachable — "
                f"overcommit={self.cfg.overcommit} is too aggressive for "
                "this pool/budget combination.")
        victim = int(max(
            active,
            key=lambda s: (-self._slot_prio[s], self._slot_rid[s])))
        rid = int(self._slot_rid[victim])
        req = self._slot_req[victim]
        k = int(self._emitted[victim])
        gen = np.asarray(self.outputs[rid][-k:], np.int32)
        # prompt ++ generated re-prefills to the exact point of preemption:
        # len grows by k, budget shrinks by k, so len + budget - 1 is
        # invariant across requeues and always fits the largest bucket
        # (enforced at submit when overcommit > 1)
        requeued = Request(
            rid=rid,
            tokens=np.concatenate([req.tokens, gen]),
            max_new_tokens=int(self._budget[victim]) - k,
            extra=req.extra, priority=req.priority, resumed=True)
        self._active[victim] = False
        self._slot_rid[victim] = -1
        self._slot_req[victim] = None
        self.state.evict(victim)               # refcount-aware block release
        self._push_pending(requeued, front=True)
        self.preemptions += 1
        if self.metrics is not None:
            self.metrics.record_preempt(rid)

    def step(self) -> dict[int, int]:
        """One decode step over the whole slot table; returns this step's
        emissions {rid: token}. Evicts finished rows and backfills."""
        self._admit()
        if not self._active.any():
            return {}
        # lazy table growth may find the free list actually empty under
        # over-commit — preempt until the survivors' growth fits. The growth
        # loop is idempotent for already-grown rows and take() raises before
        # touching pool state, so retrying after an eviction is safe.
        while True:
            try:
                view = self.state.decode_view(self._pos, self._active)
                break
            except PoolExhausted:
                self._preempt_one()
        key = jax.random.fold_in(self._key, 2 * self._step_counter)
        self._step_counter += 1
        with self._ctx():
            nxt, new_state = self._step(
                self.params, jnp.asarray(self._cur_tok), view,
                jnp.asarray(self._pos), jnp.asarray(self._active), key)
        self.state.commit(new_state)
        self.decode_steps += 1
        nxt = np.asarray(nxt)
        # sample occupancy before evictions release resources: the peak
        # must reflect what this decode actually held resident
        if self.metrics is not None:
            live, total, unit = self.state.occupancy(self.num_active)
            self.metrics.record_kv_usage(
                live, total, unit,
                referenced=self.state.referenced(self.num_active))
        emissions: dict[int, int] = {}
        for slot in np.flatnonzero(self._active):
            rid = int(self._slot_rid[slot])
            tok = int(nxt[slot])
            emissions[rid] = tok
            self.outputs[rid].append(tok)
            self._emitted[slot] += 1
            self._pos[slot] += 1
            if self.metrics is not None:
                self.metrics.record_token(rid)
            if tok == EOS_ID or self._emitted[slot] >= self._budget[slot]:
                self._finish(rid)
                self._active[slot] = False     # evict; backfilled next admit
                self._slot_rid[slot] = -1
                self._slot_req[slot] = None
                self.state.evict(slot)
        self._cur_tok = nxt.astype(np.int32)
        self._admit()
        return emissions

    def run(self) -> dict[int, np.ndarray]:
        """Drain the queue: admit/decode/evict until every submitted request
        has finished. Returns {rid: (n_tokens,) int32} for the requests
        drained since the last ``run`` and releases them — the open-ended
        stream never accumulates history device- or host-side."""
        self._admit()
        while self._active.any() or self.num_pending:
            self.step()
        done = {rid: np.asarray(toks, np.int32)
                for rid, toks in self.outputs.items()}
        self.outputs = {}
        return done
