"""Continuous-batching decode scheduler: request queue + slot table.

The serving problem the paper's §5 "answer a large class of common queries
quickly" implies: an open-ended stream of session-prefix requests with
variable prompt lengths, served from fixed-shape device buffers (the TPU
contract — no recompilation per request). The classic continuous-batching
recipe:

* A **slot table** of ``batch`` rows. Each slot owns one row of the decode
  state (KV cache) plus host-side bookkeeping: request id, absolute
  position, tokens emitted, budget.
* **Admission** pulls the next queued request, left-aligns its prompt into
  the smallest compiled ``(1, bucket_len)`` prefill bucket (right-padded
  with PAD), prefills with per-row ``lengths`` so logits come from the last
  *real* token, and inserts the resulting row state into a free slot with
  one ``dynamic_update_slice`` along the batch axis.
* **Decode** runs one jitted step over the *whole* slot table with per-row
  position indices — every active slot sits at a different depth; padding
  K/V is overwritten/masked by the per-row cache write (see
  ``models.registry`` serving contract). Inactive slots decode garbage that
  is ignored and overwritten at the next admission.
* **Eviction** frees a slot the moment its request emits EOS or exhausts
  its token budget; the next ``_admit`` backfills it from the queue.

Everything device-side is jitted once per shape: one prefill per bucket
length, one decode step, one row insert. ``trace_counts`` tracks actual
retraces (a python-level counter bumped only when jit re-traces), which is
what the no-recompilation-after-warmup test asserts.

**Paged KV mode** (``SchedulerConfig.paged``): instead of every slot
owning a dense ``max_cache_len`` K/V stripe, all requests share one slab
of fixed ``block_size`` blocks (``serve/paged.BlockPool``). Admission is
gated by **blocks available**, not just a free slot row: a request
reserves its worst case (ceil((prompt_len + budget - 1) / block_size))
up front — so decode can never strand mid-request — but blocks are
*allocated* lazily: the prompt's blocks at admission, then one per block
boundary as decode proceeds. Eviction returns the request's blocks to the
pool immediately, so a short request no longer pins a long request's
worth of slab and the same bytes admit several times more mixed-length
requests (``benchmarks/serve_tput.py`` measures it). The decode state
carries the ``(batch, max_blocks)`` block table; attention gathers
through it (``kernels.flash_attention.paged_decode_attention``) bit-equal
to the dense path. Dense/moe only — ssm/hybrid/encdec/vlm state layouts
are rejected at construction.

Sharding: with ``mesh`` given, params and the KV-cache slab are placed via
``repro.dist`` rules (``tree_shardings`` over the models' logical axes) and
every device call runs under ``dist.compat.use_mesh`` — the same rules that
constrain the batch/kv_heads dims on the production mesh degrade to
replicated on the host-local test meshes.
"""
from __future__ import annotations

import collections
from contextlib import nullcontext
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..data.pipeline import PAD_ID, EOS_ID
from ..dist.compat import use_mesh
from ..dist.sharding import tree_shardings
from ..models import layers as L
from ..models.registry import ModelApi
from .metrics import ServeMetrics
from .paged import BlockPool, blocks_for


@dataclass(frozen=True)
class Request:
    rid: int
    tokens: np.ndarray           # (prompt_len,) int32, no padding
    max_new_tokens: int


@dataclass
class SchedulerConfig:
    batch: int = 4                         # slot-table rows
    buckets: tuple[int, ...] = (16, 32, 64)  # compiled prefill lengths
    max_new_tokens: int = 32               # default per-request budget
    temperature: float = 0.0               # 0 = greedy
    seed: int = 0
    # paged KV: share one slab of fixed blocks across all slots
    paged: bool = False
    block_size: int = 16                   # tokens per KV block
    num_blocks: int | None = None          # allocatable blocks; default
    #                                        batch * max_cache_len/block_size
    #                                        (dense-equivalent capacity)


class ContinuousScheduler:
    """Serve an open-ended request stream from fixed-shape buffers.

    Supports the attention-cache families whose decode state stacks the
    batch on axis 1 of every leaf (dense/moe) — exactly what the row
    insert relies on. SSM-state families need exact-length prompts and a
    different state layout; they stay on the batch ``Server`` path.

    With ``cfg.paged`` the per-slot K/V stripes are replaced by a shared
    ``BlockPool`` slab: admission is gated by blocks available, tables
    grow lazily as decode crosses block boundaries, and eviction returns
    blocks to the pool (see the module docstring and ``serve/paged.py``).
    """

    SUPPORTED_FAMILIES = ("dense", "moe")

    def __init__(self, api: ModelApi, params, cfg: SchedulerConfig,
                 mesh=None, metrics: ServeMetrics | None = None):
        if api.cfg.family not in self.SUPPORTED_FAMILIES:
            raise ValueError(
                f"ContinuousScheduler supports {self.SUPPORTED_FAMILIES}, "
                f"got family {api.cfg.family!r}; use Server.generate's "
                "batch path for SSM/cross-attention families")
        # a request writes its last decode input at prompt_len + budget - 2,
        # so the cache must hold max(buckets) + max_new_tokens - 1 positions
        if api.cfg.max_cache_len < max(cfg.buckets) + cfg.max_new_tokens - 1:
            raise ValueError(
                f"max_cache_len={api.cfg.max_cache_len} cannot hold the "
                f"largest bucket {max(cfg.buckets)} plus "
                f"{cfg.max_new_tokens} generated tokens")
        self.api = api
        self.cfg = cfg
        self.mesh = mesh
        self.metrics = metrics
        self.trace_counts = collections.Counter()
        self.decode_steps = 0
        self.prefills = 0

        self.pool: BlockPool | None = None
        if cfg.paged:
            if api.cfg.max_cache_len % cfg.block_size != 0:
                raise ValueError(
                    f"block_size={cfg.block_size} must divide "
                    f"max_cache_len={api.cfg.max_cache_len}")
            self._max_blocks = api.cfg.max_cache_len // cfg.block_size
            num_blocks = (cfg.batch * self._max_blocks
                          if cfg.num_blocks is None else cfg.num_blocks)
            self.pool = BlockPool.for_model(
                api.cfg, num_blocks=num_blocks, block_size=cfg.block_size)

        if mesh is not None:
            params = jax.device_put(
                params, tree_shardings(api.axes(), api.rules, mesh))
        self.params = params

        temp = cfg.temperature

        def sample(logits, key):
            if temp <= 0.0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.random.categorical(
                key, logits / temp, axis=-1).astype(jnp.int32)

        def prefill_fn(p, toks, lengths, key):
            logits, state, idx = api.prefill(
                p, dict(tokens=toks, lengths=lengths))
            return sample(logits, key), state, idx

        def step_fn(p, cur_tok, state, pos, active, key):
            # inactive slots decode at position 0: their row state is dead
            # (fully overwritten by the next insert) so the garbage write
            # is harmless, and clamping keeps the scatter in bounds.
            safe_pos = jnp.where(active, pos, 0)
            logits, state = api.decode_step(p, cur_tok, state, safe_pos)
            nxt = sample(logits, key)
            return jnp.where(active, nxt, PAD_ID), state

        def insert_fn(state, row_state, slot):
            return jax.tree.map(
                lambda c, r: jax.lax.dynamic_update_slice_in_dim(
                    c, r.astype(c.dtype), slot, axis=1),
                state, row_state)

        bs_blk = cfg.block_size

        def paged_insert_fn(state, row_state, slot, ids):
            """Scatter a prefilled row into the shared slab: K/V go to the
            blocks in ``ids`` (bucket-covering; trailing ids may be 0 =
            trash for all-pad blocks), any other state leaves (stub
            counters etc.) keep the dense axis-1 row insert."""
            nb = ids.shape[0]
            out = dict(state)
            for key in ("k", "v"):
                slab, row = state[key], row_state[key]
                lyr, _, kvh, _, hd = row.shape
                blocks = row[:, 0, :, :nb * bs_blk, :].reshape(
                    lyr, kvh, nb, bs_blk, hd).transpose(0, 2, 1, 3, 4)
                out[key] = slab.at[:, ids].set(blocks.astype(slab.dtype))
            for key in state:
                if key in ("k", "v", "table"):
                    continue
                out[key] = jax.lax.dynamic_update_slice_in_dim(
                    state[key], row_state[key].astype(state[key].dtype),
                    slot, axis=1)
            return out

        self._prefill = jax.jit(self._counted("prefill", prefill_fn))
        self._step = jax.jit(self._counted("decode", step_fn))
        self._insert = jax.jit(self._counted(
            "insert", paged_insert_fn if cfg.paged else insert_fn))

        # slot table (host-side bookkeeping)
        B = cfg.batch
        self._active = np.zeros(B, bool)
        self._slot_rid = np.full(B, -1, np.int64)
        self._pos = np.zeros(B, np.int32)
        self._cur_tok = np.zeros(B, np.int32)
        self._emitted = np.zeros(B, np.int32)
        self._budget = np.zeros(B, np.int32)

        # paged bookkeeping: per-slot allocated block ids, worst-case
        # reservation, and the host copy of the (B, max_blocks) block table
        # (entry 0 = trash block; rows are zeroed on eviction so dead-row
        # garbage writes can never touch a reallocated block)
        if cfg.paged:
            self._blocks: list[list[int]] = [[] for _ in range(B)]
            self._reserved = np.zeros(B, np.int32)
            self._table = np.zeros((B, self._max_blocks), np.int32)

        self._pending: collections.deque[Request] = collections.deque()
        self._next_rid = 0
        self._step_counter = 0
        self._key = jax.random.PRNGKey(cfg.seed)
        self.outputs: dict[int, list[int]] = {}
        self._state = self._init_state()

    # -- plumbing ----------------------------------------------------------

    def _counted(self, name, fn):
        def wrapped(*args):
            # runs only when jit (re)traces — a cache hit never reaches here
            self.trace_counts[name] += 1
            return fn(*args)
        return wrapped

    def _ctx(self):
        return use_mesh(self.mesh) if self.mesh is not None else nullcontext()

    def _init_state(self):
        """Zero decode state of the full-slot-table shape, via eval_shape
        (no wasted prefill compute, no extra compile)."""
        B, b0 = self.cfg.batch, self.cfg.buckets[0]
        if self.cfg.paged:
            return self._init_paged_state()
        shapes = jax.eval_shape(
            lambda p: self.api.prefill(p, dict(
                tokens=jnp.zeros((B, b0), jnp.int32),
                lengths=jnp.ones((B,), jnp.int32)))[1],
            self.params)
        state = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), shapes)
        if self.mesh is not None:
            try:
                shardings = tree_shardings(L.kv_cache_axes(), self.api.rules,
                                           self.mesh)
                state = jax.device_put(state, shardings)
            except ValueError:
                pass  # state tree doesn't match the plain KV layout
        return state

    def _init_paged_state(self):
        """Shared block slab + per-row block table, plus full-slot-table
        copies of any non-KV state leaves the model's prefill returns
        (shape probed on a single row via eval_shape)."""
        B, b0 = self.cfg.batch, self.cfg.buckets[0]
        shapes = jax.eval_shape(
            lambda p: self.api.prefill(p, dict(
                tokens=jnp.zeros((1, b0), jnp.int32),
                lengths=jnp.ones((1,), jnp.int32)))[1],
            self.params)
        if not isinstance(shapes, dict) or not {"k", "v"} <= set(shapes):
            raise ValueError(
                "paged KV needs a dict(k, v) decode state; got "
                f"{type(shapes).__name__} — this family keeps its dense "
                "layout")
        state = dict(self.pool.init_slab())
        for key, a in shapes.items():
            if key in ("k", "v"):
                continue
            state[key] = jnp.zeros((a.shape[0], B) + a.shape[2:], a.dtype)
        state["table"] = jnp.asarray(self._table)
        if self.mesh is not None:
            try:
                axes = dict(L.paged_kv_cache_axes(),
                            **{k: None for k in state
                               if k not in ("k", "v")})
                state = jax.device_put(
                    state, tree_shardings(axes, self.api.rules, self.mesh))
            except ValueError:
                pass
        return state

    # -- public API --------------------------------------------------------

    def submit(self, tokens, max_new_tokens: int | None = None) -> int:
        """Queue one request; returns its rid. ``tokens``: (prompt_len,)."""
        toks = np.asarray(tokens, np.int32).reshape(-1)
        if len(toks) == 0:
            toks = np.array([PAD_ID], np.int32)
        if len(toks) > max(self.cfg.buckets):
            raise ValueError(
                f"prompt length {len(toks)} exceeds the largest bucket "
                f"{max(self.cfg.buckets)}")
        bucket = self._bucket_for(len(toks))
        budget = (self.cfg.max_new_tokens if max_new_tokens is None
                  else max_new_tokens)
        if len(toks) + budget - 1 > self.api.cfg.max_cache_len:
            raise ValueError(
                f"prompt length {len(toks)} (bucket {bucket}) + budget "
                f"{budget} needs {len(toks) + budget - 1} cache positions "
                f"and overflows max_cache_len={self.api.cfg.max_cache_len}")
        if self.pool is not None:
            need = self.pool.blocks_needed(len(toks), budget)
            if need > self.pool.capacity:
                raise ValueError(
                    f"prompt length {len(toks)} (bucket {bucket}) + budget "
                    f"{budget} requires {need} KV blocks of "
                    f"{self.pool.block_size} tokens, but the pool holds "
                    f"only {self.pool.capacity} blocks total")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, tokens=toks, max_new_tokens=budget)
        self._pending.append(req)
        if self.metrics is not None:
            self.metrics.record_submit(rid, prompt_len=len(toks))
        return rid

    @property
    def num_active(self) -> int:
        return int(self._active.sum())

    @property
    def num_pending(self) -> int:
        return len(self._pending)

    def _bucket_for(self, n: int) -> int:
        for b in sorted(self.cfg.buckets):
            if n <= b:
                return b
        raise ValueError(n)

    def _finish(self, rid: int) -> None:
        if self.metrics is not None:
            self.metrics.record_finish(rid)

    def _admit(self) -> None:
        """Backfill free slots from the queue (prefill + row insert).

        Paged mode admits by **blocks available**, not just free rows: the
        head request's worst case must be reservable, else admission stalls
        (FIFO) until an eviction frees blocks. Reservation happens before
        the insert; allocation is lazy (prompt blocks now, the rest as
        decode crosses block boundaries in ``step``)."""
        free = np.flatnonzero(~self._active)
        fi = 0
        while self._pending and fi < len(free):
            req = self._pending[0]                  # peek: may not fit yet
            n = len(req.tokens)
            bucket = self._bucket_for(n)
            if self.pool is not None:
                need = self.pool.blocks_needed(n, req.max_new_tokens)
                if not self.pool.can_reserve(need):
                    break                           # wait for an eviction
            self._pending.popleft()
            slot = int(free[fi])
            toks = np.full((1, bucket), PAD_ID, np.int32)
            toks[0, :n] = req.tokens
            key = jax.random.fold_in(
                jax.random.fold_in(self._key, 1), req.rid)
            with self._ctx():
                tok0, row_state, idx = self._prefill(
                    self.params, jnp.asarray(toks),
                    jnp.asarray([n], jnp.int32), key)
            self.prefills += 1
            if self.metrics is not None:
                self.metrics.record_admit(req.rid)
            t0 = int(np.asarray(tok0)[0])
            self.outputs[req.rid] = [t0]
            if self.metrics is not None:
                self.metrics.record_token(req.rid)
            if t0 == EOS_ID or req.max_new_tokens <= 1:
                self._finish(req.rid)      # done at admission: slot stays free
                continue
            if self.pool is not None:
                self.pool.reserve(need)
                self._reserved[slot] = need
                ids = [self.pool.take() for _ in range(blocks_for(
                    n, self.cfg.block_size))]
                self._blocks[slot] = ids
                self._table[slot, :] = 0
                self._table[slot, :len(ids)] = ids
                # bucket-covering id vector for the insert: all-pad blocks
                # past the prompt go to the trash block (id 0)
                nb = blocks_for(bucket, self.cfg.block_size)
                bucket_ids = np.zeros(nb, np.int32)
                bucket_ids[:len(ids)] = ids
                with self._ctx():
                    self._state = self._insert(
                        self._state, row_state, jnp.int32(slot),
                        jnp.asarray(bucket_ids))
            else:
                with self._ctx():
                    self._state = self._insert(self._state, row_state,
                                               jnp.int32(slot))
            self._active[slot] = True
            self._slot_rid[slot] = req.rid
            self._pos[slot] = n
            self._cur_tok[slot] = t0
            self._emitted[slot] = 1
            self._budget[slot] = req.max_new_tokens
            fi += 1

    def step(self) -> dict[int, int]:
        """One decode step over the whole slot table; returns this step's
        emissions {rid: token}. Evicts finished rows and backfills."""
        self._admit()
        if not self._active.any():
            return {}
        if self.pool is not None:
            # lazy table growth: map a fresh block the moment a row's write
            # position crosses into it (the admission reservation guarantees
            # take() succeeds), then refresh the device table copy — same
            # shape every step, so the jitted decode never retraces.
            for slot in np.flatnonzero(self._active):
                b_idx = int(self._pos[slot]) // self.cfg.block_size
                if b_idx >= len(self._blocks[slot]):
                    blk = self.pool.take()
                    self._blocks[slot].append(blk)
                    self._table[slot, b_idx] = blk
            self._state["table"] = jnp.asarray(self._table)
        key = jax.random.fold_in(self._key, 2 * self._step_counter)
        self._step_counter += 1
        with self._ctx():
            nxt, self._state = self._step(
                self.params, jnp.asarray(self._cur_tok), self._state,
                jnp.asarray(self._pos), jnp.asarray(self._active), key)
        self.decode_steps += 1
        nxt = np.asarray(nxt)
        # sample KV occupancy before evictions return blocks: the peak
        # must reflect what this decode actually held resident
        if self.metrics is not None:
            if self.pool is not None:
                self.metrics.record_kv_usage(
                    self.pool.live_blocks, self.pool.capacity,
                    self.pool.block_bytes)
            else:
                # dense: every active slot pins one max_cache_len stripe
                row_bytes = 0
                if isinstance(self._state, dict) and \
                        {"k", "v"} <= set(self._state):
                    for leaf in (self._state["k"], self._state["v"]):
                        row_bytes += (int(np.prod(leaf.shape))
                                      // leaf.shape[1]) * leaf.dtype.itemsize
                self.metrics.record_kv_usage(
                    self.num_active, self.cfg.batch, row_bytes)
        emissions: dict[int, int] = {}
        for slot in np.flatnonzero(self._active):
            rid = int(self._slot_rid[slot])
            tok = int(nxt[slot])
            emissions[rid] = tok
            self.outputs[rid].append(tok)
            self._emitted[slot] += 1
            self._pos[slot] += 1
            if self.metrics is not None:
                self.metrics.record_token(rid)
            if tok == EOS_ID or self._emitted[slot] >= self._budget[slot]:
                self._finish(rid)
                self._active[slot] = False     # evict; backfilled next admit
                self._slot_rid[slot] = -1
                if self.pool is not None:
                    self.pool.free(self._blocks[slot])
                    self.pool.cancel(
                        int(self._reserved[slot]) - len(self._blocks[slot]))
                    self._blocks[slot] = []
                    self._reserved[slot] = 0
                    self._table[slot, :] = 0   # dead-row writes -> trash
        self._cur_tok = nxt.astype(np.int32)
        self._admit()
        return emissions

    def run(self) -> dict[int, np.ndarray]:
        """Drain the queue: admit/decode/evict until every submitted request
        has finished. Returns {rid: (n_tokens,) int32} for the requests
        drained since the last ``run`` and releases them — the open-ended
        stream never accumulates history device- or host-side."""
        self._admit()
        while self._active.any() or self._pending:
            self.step()
        done = {rid: np.asarray(toks, np.int32)
                for rid, toks in self.outputs.items()}
        self.outputs = {}
        return done
