"""Serving subsystem: continuous-batching decode over the unified rules.

* ``serve_loop`` — ``Server`` / ``ServeConfig``: the fixed-batch
  compatibility surface (``generate``), a thin wrapper over the scheduler
  for token-only attention families, with an in-place batch fallback.
* ``scheduler`` — ``ContinuousScheduler`` / ``SchedulerConfig`` /
  ``Request``: request queue + slot table; admit into ``(1, bucket)``
  prefill buckets, decode the whole slot table with per-row positions,
  evict on EOS/budget and backfill without recompiling.
* ``metrics`` — ``ServeMetrics``: submit/admit/first-token/finish
  timestamps, tokens/sec and p50/p99 latency + TTFT, plus KV-slab
  utilization (live blocks / total) and peak-resident bytes.
* ``paged`` — ``BlockPool``: the paged-KV block slab + free-list
  allocator (``SchedulerConfig.paged``); long and short requests share
  fixed blocks instead of per-slot ``max_cache_len`` stripes.
"""
from .serve_loop import Server, ServeConfig, prompt_lengths
from .scheduler import ContinuousScheduler, SchedulerConfig, Request
from .metrics import ServeMetrics
from .paged import BlockPool, blocks_for

__all__ = ["Server", "ServeConfig", "prompt_lengths",
           "ContinuousScheduler", "SchedulerConfig", "Request",
           "ServeMetrics", "BlockPool", "blocks_for"]
