"""Serving substrate: batched prefill + decode loop."""
from .serve_loop import Server, ServeConfig
__all__ = ["Server", "ServeConfig"]
