"""Serving subsystem: continuous-batching decode for every model family.

* ``cache`` — the **DecodeState protocol** and its per-family
  implementations (``DenseKVState``, ``PagedKVState``, ``RecurrentState``,
  ``HybridState``, ``CrossAttnState``): one cache abstraction that
  normalizes dense/moe KV stripes, the shared paged block slab, ssm
  recurrent rows, hybrid Mamba+shared-attention state, and encdec/vlm
  cross-attention stacks behind ``init`` / ``can_admit`` / ``admit`` /
  ``prefill_insert`` / ``decode_view`` / ``evict`` / ``occupancy``.
* ``scheduler`` — ``ContinuousScheduler`` / ``SchedulerConfig`` /
  ``Request``: request queue + slot table over a ``DecodeState``; admit
  into ``(1, bucket)`` prefill buckets (per-request frames/patches extras
  ride ``submit``), decode the whole slot table with per-row positions,
  evict on EOS/budget and backfill — zero retraces after warmup, for all
  7 registry architectures.
* ``serve_loop`` — ``Server``: ``generate`` is a thin scheduler wrapper
  for every family; ``generate_batch`` is the explicit fixed-batch oracle
  the scheduler is asserted bit-equal against.
* ``metrics`` — ``ServeMetrics``: submit/admit/first-token/finish
  timestamps, tokens/sec and p50/p99 latency + TTFT, plus state-residency
  (live blocks or rows / total) and peak-resident bytes;
  ``merge_summaries`` rolls K per-replica instances into one fleet
  summary (request-level merge + load-imbalance stat).
* ``fleet`` — ``ReplicaRouter`` / ``FleetConfig``: N independent
  scheduler replicas (each its own slab/prefix registry/over-commit)
  behind the single ``submit``/``step``/``run`` surface; round-robin,
  join-shortest-queue on occupancy gossip (``dist.gossip_all_gather``),
  or prefix-affinity routing with JSQ spill.
* ``paged`` — ``BlockPool``: the paged-KV block slab + refcounted
  free-list allocator behind ``PagedKVState`` (``SchedulerConfig.paged``);
  long and short requests share fixed blocks instead of per-slot
  ``max_cache_len`` stripes. With ``SchedulerConfig.prefix_cache`` the
  pool also runs **session-prefix caching**: prompt blocks resident under
  an identical prefix (chained content hashes) are mapped into new
  requests copy-free, boundary blocks are duplicated copy-on-write, and
  admission prefills only the divergent tail.
"""
from .serve_loop import Server, ServeConfig, prompt_lengths
from .scheduler import ContinuousScheduler, SchedulerConfig, Request
from .cache import (DecodeState, DenseKVState, PagedKVState, RecurrentState,
                    HybridState, CrossAttnState, make_decode_state)
from .metrics import ServeMetrics, merge_metrics, merge_summaries
from .paged import (BlockPool, PrefixPlan, blocks_for, chain_hash,
                    prefix_hashes)
from .fleet import ReplicaRouter, FleetConfig

__all__ = ["Server", "ServeConfig", "prompt_lengths",
           "ContinuousScheduler", "SchedulerConfig", "Request",
           "DecodeState", "DenseKVState", "PagedKVState", "RecurrentState",
           "HybridState", "CrossAttnState", "make_decode_state",
           "ServeMetrics", "merge_metrics", "merge_summaries",
           "ReplicaRouter", "FleetConfig",
           "BlockPool", "PrefixPlan", "blocks_for",
           "chain_hash", "prefix_hashes"]
