"""DecodeState: one cache abstraction so every family serves continuously.

The paper's core move is replacing application-specific log formats with
one unified client-events schema so every downstream consumer speaks the
same language. This module is that normalization applied to decode state:
before it, dense/moe spoke the scheduler's KV-slab dialect while
ssm/hybrid/encdec/vlm each carried bespoke cache layouts and fell back to
a fixed-batch path. Now every family's state lives behind one protocol and
the ``ContinuousScheduler`` is a pure consumer — admit/evict/backfill,
paged admission, and serving metrics work identically for all of them.

The protocol (duck-typed; ``DecodeState`` is the reference base):

* ``init(batch, budget)``      — allocate the zero slot-table state.
* ``can_admit(n, budget)``     — resource gate beyond free rows (paged:
  blocks reservable; others: always true).
* ``admit(slot, n, budget)``   — reserve row resources (paged: worst-case
  block reservation + prompt-block allocation).
* ``prefill_insert(row_state, slot, length, bucket)`` — insert one
  prefilled ``(1, bucket)`` row into the table (jitted once per row
  shape).
* ``decode_view(positions, active)`` — the device state for this decode
  step (paged: grows block tables lazily and refreshes the device copy).
* ``commit(new_state)``        — store ``decode_step``'s returned state.
* ``evict(slot)``              — release row resources (paged: free blocks
  + point the dead row at the trash block).
* ``max_positions()``          — cache-position bound (None = unbounded
  recurrent state).
* ``occupancy(num_active)`` / ``resident_bytes(num_active)`` — live/total
  units + device bytes for ``ServeMetrics.record_kv_usage``.

**Row-layout discovery.** Families stack the slot axis differently (vlm's
grouped self caches batch on axis 2; everything else on axis 1), so the
base class probes ``api.prefill`` via ``jax.eval_shape`` at batch 1 and 2
and records, per state leaf, the one axis that scaled — no family ever
has to register its layout by hand, and a new family that decodes through
``ModelApi`` is continuous-batchable on day one. The per-family
subclasses (``DenseKVState``, ``RecurrentState``, ``HybridState``,
``CrossAttnState``) validate the discovered layout against what the
family contract promises; ``PagedKVState`` swaps the dense K/V leaves for
the shared ``BlockPool`` slab and writes prompt K/V into bucket-covering
blocks directly at insert (paged prefill — no ``max_cache_len``
intermediate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import tree_shardings
from ..models.registry import ModelApi
from .paged import (BlockPool, PoolExhausted, PrefixPlan, PREFIX_SEED,
                    blocks_for, prefix_hashes)


def _uncounted(name, fn):
    return fn


def _leaf_paths(tree, prefix=""):
    """Flatten a nested-dict pytree into (path, leaf) pairs."""
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out += _leaf_paths(tree[k], f"{prefix}{k}.")
        return out
    return [(prefix.rstrip("."), tree)]


class DecodeState:
    """Reference slot-table state: one generic row-insert over discovered
    batch axes. Hosts any family whose decode state is a pytree of arrays
    with exactly one slot axis per leaf."""

    def __init__(self, api: ModelApi, cfg, params, mesh=None,
                 counted=None):
        self.api = api
        self.cfg = cfg                      # SchedulerConfig
        self.params = params
        self.mesh = mesh
        self.data = None
        self.batch = 0
        counted = counted or _uncounted
        self._row_shapes, self._axes = self._probe()
        self._validate()
        self._insert = jax.jit(counted("insert", self._insert_fn))

    # -- layout discovery --------------------------------------------------

    def _probe_batch(self, b: int, bucket: int):
        batch = dict(
            tokens=jax.ShapeDtypeStruct((b, bucket), jnp.int32),
            lengths=jax.ShapeDtypeStruct((b,), jnp.int32))
        for key, shape_fn, dt in self.api.caps.extras:
            batch[key] = jax.ShapeDtypeStruct(
                shape_fn(self.api.cfg, b), jnp.dtype(dt))
        return jax.eval_shape(
            lambda p, bt: self.api.prefill(p, bt)[1], self.params, batch)

    def _probe(self):
        """Row state shapes (batch=1) + per-leaf slot axis, by comparing
        ``eval_shape`` at batch 1 vs 2: the one axis that scales with the
        batch is the slot axis."""
        b0 = self.cfg.buckets[0]
        s1, s2 = self._probe_batch(1, b0), self._probe_batch(2, b0)
        if jax.tree.structure(s1) != jax.tree.structure(s2):
            raise ValueError("prefill state structure depends on batch size")

        def axis_of(a, b):
            diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                     if x != y]
            if len(a.shape) != len(b.shape) or len(diffs) != 1:
                raise ValueError(
                    f"cannot identify the slot axis of a state leaf: "
                    f"batch 1 -> {a.shape}, batch 2 -> {b.shape}")
            return diffs[0]

        axes = jax.tree.map(axis_of, s1, s2)
        return s1, axes

    def _validate(self):
        pass

    # -- allocation --------------------------------------------------------

    def _zero_state(self, batch: int):
        def grow(leaf, ax):
            shape = list(leaf.shape)
            shape[ax] = batch
            return jnp.zeros(shape, leaf.dtype)
        return jax.tree.map(grow, self._row_shapes, self._axes)

    def _place(self, state):
        """Best-effort ``repro.dist`` placement: the family's declared
        state axes when the tree matches, else leave unplaced (host-local
        test meshes degrade to replicated either way)."""
        if self.mesh is None:
            return state
        axes_fn = self.api.caps.state_axes
        if axes_fn is None:
            return state
        try:
            shardings = tree_shardings(axes_fn(self.api.cfg),
                                       self.api.rules, self.mesh)
            return jax.device_put(state, shardings)
        except ValueError:
            return state

    def init(self, batch: int, budget: int) -> None:
        self.batch = batch
        self.data = self._place(self._zero_state(batch))

    # -- admission / insert / decode / eviction ----------------------------

    def max_positions(self) -> int | None:
        cap = self.api.cfg.max_cache_len
        if cap <= 0:
            raise ValueError(
                f"{type(self).__name__} is position-bounded and needs "
                f"max_cache_len > 0, got {cap}")
        return cap

    def validate_request(self, prompt_len: int, bucket: int,
                         budget: int) -> None:
        pass

    def prefix_plan(self, tokens, budget: int):
        """Prefix-cache admission plan for one request, or None when the
        state does not share prefixes (everything but ``PagedKVState``
        with ``cfg.prefix_cache``)."""
        return None

    def can_admit(self, prompt_len: int, budget: int, plan=None) -> bool:
        return True

    def admit(self, slot: int, prompt_len: int, budget: int,
              plan=None) -> None:
        pass

    def prefill_cache_len(self, cover: int) -> int | None:
        """Static cache length for an admission prefill that must hold
        positions ``0..cover-1`` (= prefill start offset + tail bucket;
        start is 0 without prefix sharing, so this is the bucket length).
        None keeps the family default (``max_cache_len``)."""
        return None

    def prefill_prefix_inputs(self, plan, cache_len: int | None) -> dict:
        """Extra prefill-batch inputs realizing ``plan`` (resident-prefix
        gather spec + tail start offset); empty without a prefix hit."""
        return {}

    def referenced(self, num_active: int) -> int:
        """Total state-unit references across requests (== live units
        unless the state shares blocks between requests)."""
        return self.occupancy(num_active)[0]

    def _insert_fn(self, state, row_state, slot):
        return jax.tree.map(
            lambda c, r, ax: jax.lax.dynamic_update_slice_in_dim(
                c, r.astype(c.dtype), slot, axis=ax),
            state, row_state, self._axes)

    def prefill_insert(self, row_state, slot: int, length: int,
                       bucket: int) -> None:
        self.data = self._insert(self.data, row_state, jnp.int32(slot))

    def decode_view(self, positions, active):
        return self.data

    def commit(self, new_state) -> None:
        self.data = new_state

    def evict(self, slot: int) -> None:
        pass

    # -- metrics -----------------------------------------------------------

    def row_bytes(self) -> int:
        """Device bytes one resident row pins (every state leaf)."""
        return sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
                   for _, leaf in _leaf_paths(self._row_shapes))

    def occupancy(self, num_active: int) -> tuple[int, int, int]:
        """(live units, total units, bytes per unit) — one unit = one slot
        row here; ``PagedKVState`` reports pool blocks instead."""
        return num_active, self.batch, self.row_bytes()


class DenseKVState(DecodeState):
    """dense/moe: dict(k, v) caches of ``(L, B, KVH, max_cache_len, Dh)``
    — every row pins a full cache stripe (see ``PagedKVState`` for the
    shared-slab alternative)."""

    def _validate(self):
        if not isinstance(self._row_shapes, dict) or \
                not {"k", "v"} <= set(self._row_shapes):
            raise ValueError(
                f"{type(self).__name__} expects a dict(k, v) decode state, "
                f"got {type(self._row_shapes).__name__} with leaves "
                f"{[p for p, _ in _leaf_paths(self._row_shapes)]}")


class RecurrentState(DecodeState):
    """ssm: O(1) per-row recurrent state (conv tails + SSM heads), no
    position bound — ``max_positions`` is None, so a request's budget is
    limited only by its token budget."""

    def max_positions(self) -> int | None:
        return None


class HybridState(DecodeState):
    """hybrid: Mamba recurrent rows + the shared attention block's
    per-invocation KV stack; the KV part keeps the ``max_cache_len``
    position bound."""

    def _validate(self):
        if not isinstance(self._row_shapes, dict) or \
                "mamba" not in self._row_shapes:
            raise ValueError(
                f"HybridState expects a dict with a 'mamba' sub-state, got "
                f"{[p for p, _ in _leaf_paths(self._row_shapes)]}")


class CrossAttnState(DecodeState):
    """encdec/vlm: self-attention KV plus a frozen per-row cross-attention
    stack (encoder output K/V), resident for the row's whole lifetime —
    the cross stack batches on its own axis per leaf (vlm's grouped self
    caches sit at axis 2), which the probed axes tree absorbs."""

    def _validate(self):
        if not self.api.caps.extras:
            raise ValueError(
                "CrossAttnState expects per-request encoder inputs "
                "(caps.extras); none declared for family "
                f"{self.api.cfg.family!r}")


class PagedKVState(DenseKVState):
    """dense/moe paged mode: the per-row K/V stripes are replaced by one
    shared ``BlockPool`` slab + per-row block tables. Admission reserves a
    request's worst case up front, allocation is lazy per block boundary,
    and **prefill is paged**: the admission prefill runs against a
    bucket-covering cache (``blocks_for(bucket) * block_size`` positions,
    not ``max_cache_len``) and its K/V blocks are scattered straight into
    the pool — the only dense intermediate is the prompt-sized K/V that
    flash attention needs anyway.

    With ``cfg.prefix_cache`` admission first consults the pool's chained
    content-hash registry (``prefix_plan``): prompt blocks already
    resident under an identical prefix are mapped copy-free (refcount
    bump, reservation shrinks by the match), a partially-covered boundary
    block is **copied** out of its donor before anything is written
    (copy-on-write — a shared block is never scattered into), and the
    admission prefill computes only the divergent tail: the matched
    prefix K/V is gathered from the slab into the prefill cache and the
    model runs from ``start`` with RoPE positions offset accordingly. The
    last prompt token is always re-prefilled (its logits sample token 0),
    so a full-prompt match still runs a one-token tail."""

    def __init__(self, api, cfg, params, mesh=None, counted=None):
        if api.cfg.max_cache_len % cfg.block_size != 0:
            raise ValueError(
                f"block_size={cfg.block_size} must divide "
                f"max_cache_len={api.cfg.max_cache_len}")
        self._max_blocks = api.cfg.max_cache_len // cfg.block_size
        num_blocks = (cfg.batch * self._max_blocks
                      if cfg.num_blocks is None else cfg.num_blocks)
        self.pool = BlockPool.for_model(
            api.cfg, num_blocks=num_blocks, block_size=cfg.block_size,
            overcommit=getattr(cfg, "overcommit", 1.0),
            debug=getattr(cfg, "debug", False))
        super().__init__(api, cfg, params, mesh=mesh, counted=counted)

    def _validate(self):
        super()._validate()
        if not self.api.caps.paged:
            raise ValueError(
                f"family {self.api.cfg.family!r} does not support the "
                "paged KV slab (caps.paged); its state keeps the dense "
                "layout")
        nested = [k for k, v in self._row_shapes.items()
                  if isinstance(v, dict)]
        if nested:
            raise ValueError(
                "paged KV expects a flat dict(k, v, ...) decode state; "
                f"nested sub-states {nested} keep the dense layout")
        for key in ("k", "v"):
            leaf, ax = self._row_shapes[key], self._axes[key]
            if len(leaf.shape) != 5 or ax != 1:
                raise ValueError(
                    f"paged KV expects (L, B, KVH, S, Dh) '{key}' leaves "
                    f"with the slot axis at 1, got {leaf.shape} axis {ax}")

    def init(self, batch: int, budget: int) -> None:
        self.batch = batch
        self._blocks: list[list[int]] = [[] for _ in range(batch)]
        self._reserved = np.zeros(batch, np.int32)
        self._shared = np.zeros(batch, np.int32)   # leading shared blocks
        self._table = np.zeros((batch, self._max_blocks), np.int32)
        state = dict(self.pool.init_slab())
        for path, leaf in _leaf_paths(self._row_shapes):
            if path in ("k", "v"):
                continue
            shape = list(leaf.shape)
            shape[self._axes[path]] = batch
            state[path] = jnp.zeros(shape, leaf.dtype)
        state["table"] = jnp.asarray(self._table)
        self.data = self._place_paged(state)

    def _place_paged(self, state):
        if self.mesh is None:
            return state
        from ..models import layers as L
        try:
            axes = dict(L.paged_kv_cache_axes(),
                        **{k: None for k in state if k not in ("k", "v")})
            return jax.device_put(
                state, tree_shardings(axes, self.api.rules, self.mesh))
        except ValueError:
            return state

    # -- admission ---------------------------------------------------------

    def validate_request(self, prompt_len: int, bucket: int,
                         budget: int) -> None:
        need = self.pool.blocks_needed(prompt_len, budget)
        if need > self.pool.capacity:
            raise ValueError(
                f"prompt length {prompt_len} (bucket {bucket}) + budget "
                f"{budget} requires {need} KV blocks of "
                f"{self.pool.block_size} tokens, but the pool holds "
                f"only {self.pool.capacity} blocks total")

    def prefix_plan(self, tokens, budget: int) -> PrefixPlan | None:
        """Match the prompt against the pool's chained-hash registry.

        Pure planning — no pool side effects (the scheduler may still
        drop the request if it terminates at admission); ``admit``
        realizes the plan. Matching walks leading *full* prompt blocks
        through ``lookup`` but never past ``(prompt_len - 1) //
        block_size``: the block holding the last prompt token is always
        owned and re-prefilled (its logits sample token 0, and sharing it
        would mean writing a block another request references). When every
        block before that boundary matched, a resident donor covering the
        boundary tokens (an aligned full block, or a registered block
        extending the matched chain) is recorded for copy-on-write."""
        if not getattr(self.cfg, "prefix_cache", False):
            return None
        toks = np.asarray(tokens, np.int32).reshape(-1)
        n, bs = len(toks), self.cfg.block_size
        hashes = prefix_hashes(toks, bs)
        limit = (n - 1) // bs             # first block the request writes
        shared: list[int] = []
        while len(shared) < min(len(hashes), limit):
            blk = self.pool.lookup(hashes[len(shared)])
            if blk is None:
                break
            shared.append(blk)
        m = len(shared)
        cow = None
        if m == limit and m * bs < n - 1:
            if m < len(hashes):           # boundary is itself a full block
                cow = self.pool.lookup(hashes[m])
            if cow is None:
                parent = hashes[m - 1] if m else PREFIX_SEED
                cow = self.pool.find_extension(parent, toks[m * bs:n - 1])
        start = n - 1 if cow is not None else m * bs
        return PrefixPlan(shared=shared, cow=cow, start=start,
                          hashes=hashes, tokens=toks)

    def can_admit(self, prompt_len: int, budget: int, plan=None) -> bool:
        m = len(plan.shared) if plan is not None else 0
        # shared blocks are already resident: they shrink both the
        # worst-case reservation and the prompt blocks taken at admission
        need = self.pool.blocks_needed(prompt_len, budget) - m
        own_now = blocks_for(prompt_len, self.cfg.block_size) - m
        # under over-commit the reservation gate alone is not enough: the
        # prompt's own blocks are taken *at admission*, so they must exist
        # on the free list right now (admission never preempts — only
        # mid-decode growth does)
        return self.pool.can_reserve(need) and self.pool.free_blocks >= own_now

    def admit(self, slot: int, prompt_len: int, budget: int,
              plan=None) -> None:
        bs = self.cfg.block_size
        shared = list(plan.shared) if plan is not None else []
        m = len(shared)
        # reservation covers only blocks this request will own: the shared
        # prefix is resident already, so its capacity is counted once
        need = self.pool.blocks_needed(prompt_len, budget) - m
        self.pool.reserve(need)
        self._reserved[slot] = need
        for blk in shared:
            self.pool.share(blk)
        ids = shared + [self.pool.take()
                        for _ in range(blocks_for(prompt_len, bs) - m)]
        self._blocks[slot] = ids
        self._shared[slot] = m
        self._table[slot, :] = 0
        self._table[slot, :len(ids)] = ids
        if plan is not None:
            # publish this request's owned full prompt blocks for future
            # sharers (first registration of a hash wins)
            for j in range(m, len(plan.hashes)):
                parent = plan.hashes[j - 1] if j else PREFIX_SEED
                self.pool.register(plan.hashes[j], parent, ids[j],
                                   plan.tokens[j * bs:(j + 1) * bs])

    # -- paged prefill insert ----------------------------------------------

    def prefill_cache_len(self, cover: int) -> int | None:
        """Block-covering cache for the admission prefill: the row K/V
        comes back already block-shaped, so the insert is a pure scatter
        into the pool (the ROADMAP "paged prefill" item). ``cover`` is
        prefill start + tail bucket — just the bucket length without
        prefix sharing."""
        return blocks_for(cover, self.cfg.block_size) * self.cfg.block_size

    def prefill_prefix_inputs(self, plan, cache_len: int | None) -> dict:
        """Prefill-batch inputs that realize a prefix hit: the tail start
        offset plus the block ids whose slab content is gathered into the
        prefill cache before the model runs (shared prefix, then the COW
        donor — gathering the donor and scattering the boundary back into
        an *owned* block is the copy-on-write duplication)."""
        if plan is None or (not plan.shared and plan.cow is None):
            return {}
        nb = cache_len // self.cfg.block_size
        ids = np.zeros(nb, np.int32)
        ids[:len(plan.shared)] = plan.shared
        if plan.cow is not None:
            ids[len(plan.shared)] = plan.cow
        return dict(start=jnp.int32(plan.start),
                    prefix_ids=jnp.asarray(ids),
                    pool_k=self.data["k"], pool_v=self.data["v"])

    def _insert_fn(self, state, row_state, slot, ids):
        """Scatter a prefilled row into the shared slab: K/V go to the
        blocks in ``ids`` (bucket-covering; trailing ids may be 0 = trash
        for all-pad blocks), any other state leaves (stub counters etc.)
        keep the generic row insert."""
        nb = ids.shape[0]
        bs = self.cfg.block_size
        out = dict(state)
        for key in ("k", "v"):
            slab, row = state[key], row_state[key]
            lyr, _, kvh, pos, hd = row.shape          # pos == nb * bs
            blocks = row[:, 0, :, :nb * bs, :].reshape(
                lyr, kvh, nb, bs, hd).transpose(0, 2, 1, 3, 4)
            out[key] = slab.at[:, ids].set(blocks.astype(slab.dtype))
        for path, _ in _leaf_paths(self._row_shapes):
            if path in ("k", "v"):
                continue
            out[path] = jax.lax.dynamic_update_slice_in_dim(
                state[path], row_state[path].astype(state[path].dtype),
                slot, axis=self._axes[path])
        return out

    def prefill_insert(self, row_state, slot: int, length: int,
                       bucket: int) -> None:
        ids = self._blocks[slot]
        # the returned row cache is block-shaped by construction; its own
        # position extent (cache_len, = cover for prefix tails) names the
        # scatter width — shared prefix blocks scatter to the trash block
        # so a block another request references is never written
        nb = row_state["k"].shape[3] // self.cfg.block_size
        m = int(self._shared[slot])
        bucket_ids = np.zeros(nb, np.int32)
        bucket_ids[m:len(ids)] = ids[m:]
        self.data = self._insert(self.data, row_state, jnp.int32(slot),
                                 jnp.asarray(bucket_ids))

    # -- decode / eviction -------------------------------------------------

    def decode_view(self, positions, active):
        """Lazy table growth: map a fresh block the moment a row's write
        position crosses into it, then refresh the device table copy —
        same shape every step, so the jitted decode never retraces. With
        honest reservations (overcommit=1.0) ``take`` always succeeds;
        under over-commit it may raise ``PoolExhausted``, which propagates
        to the scheduler's preempt-and-retry loop — safe because rows
        already grown this call just pass the length check on retry and
        ``take`` raises before touching pool state."""
        for slot in np.flatnonzero(active):
            b_idx = int(positions[slot]) // self.cfg.block_size
            if b_idx >= len(self._blocks[slot]):
                blk = self.pool.take()
                self._blocks[slot].append(blk)
                self._table[slot, b_idx] = blk
        self.data["table"] = jnp.asarray(self._table)
        return self.data

    def evict(self, slot: int) -> None:
        """Drop one reference per mapped block (shared blocks survive for
        their other sharers; blocks reaching refcount 0 return to the free
        list) and cancel the unused tail of the reservation — which only
        ever covered *owned* blocks, so the shared count is excluded."""
        m = int(self._shared[slot])
        owned = len(self._blocks[slot]) - m
        self.pool.free(self._blocks[slot])
        self.pool.cancel(int(self._reserved[slot]) - owned)
        self._blocks[slot] = []
        self._reserved[slot] = 0
        self._shared[slot] = 0
        self._table[slot, :] = 0     # dead-row writes -> trash block
        if self.pool.debug:
            self.pool.check_invariants()

    # -- metrics -----------------------------------------------------------

    def occupancy(self, num_active: int) -> tuple[int, int, int]:
        """live counts *unique* resident blocks: a block shared by five
        requests pins its bytes once — that is the whole point."""
        return (self.pool.live_blocks, self.pool.capacity,
                self.pool.block_bytes)

    def referenced(self, num_active: int) -> int:
        return self.pool.referenced_blocks


_KINDS = {
    "kv": DenseKVState,
    "recurrent": RecurrentState,
    "hybrid": HybridState,
    "cross": CrossAttnState,
}


def make_decode_state(api: ModelApi, cfg, params, mesh=None,
                      counted=None) -> DecodeState:
    """Resolve the family's ``DecodeState`` implementation from its
    registry capability flags. Unknown families fail loudly — there is no
    fixed-batch fallback to hide behind anymore."""
    caps = getattr(api, "caps", None)
    if caps is None or caps.state_kind not in _KINDS:
        kind = None if caps is None else caps.state_kind
        raise ValueError(
            f"unknown serving family {api.cfg.family!r} (state kind "
            f"{kind!r}); known kinds: {sorted(_KINDS)} — declare "
            "ServeCaps in models/registry.py for new families")
    if getattr(cfg, "prefix_cache", False) and not cfg.paged:
        raise ValueError(
            "prefix_cache=True requires paged=True: prefix sharing maps "
            "resident pool blocks into new requests' block tables, which "
            "only exist in paged mode")
    overcommit = getattr(cfg, "overcommit", 1.0)
    if overcommit < 1.0:
        raise ValueError(
            f"overcommit must be >= 1.0, got {overcommit}")
    if overcommit > 1.0 and not cfg.paged:
        raise ValueError(
            "overcommit > 1.0 requires paged=True: only the block pool "
            "can admit past its worst-case reservation and preempt on "
            "exhaustion — dense rows are pinned for a request's lifetime")
    if cfg.paged:
        if not caps.paged:
            raise ValueError(
                f"paged KV serves caps.paged families only; family "
                f"{api.cfg.family!r} ({caps.state_kind}) keeps its own "
                "state layout")
        return PagedKVState(api, cfg, params, mesh=mesh, counted=counted)
    return _KINDS[caps.state_kind](api, cfg, params, mesh=mesh,
                                   counted=counted)
