"""Multi-replica serving fleet: a router over independent schedulers.

The paper's scaling thesis is that one unified substrate absorbs traffic
growth *horizontally* — more identical workers behind a thin routing
tier, not per-application special cases. This module applies that move
to serving: a ``ReplicaRouter`` fronts N completely independent
``ContinuousScheduler`` replicas (each with its own ``DecodeState`` or
``BlockPool`` slab, prefix-cache registry, and over-commit config)
behind the same ``submit`` / ``step`` / ``run`` surface a single
scheduler speaks, so every existing driver — the benchmarks, the CLI,
``Server.generate``-style loops — scales out without changing shape.

**The router tick.** Each ``step()`` is one fleet round:

1. **Gossip refresh** — every replica's ``occupancy_snapshot()``
   (``[free, pending, active]`` int32) is stacked and exchanged through
   ``dist.collectives.gossip_all_gather``. Host-local (``gossip_mesh is
   None``) the exchange is the identity; on a mesh it is a fixed-shape
   all-gather over the gossip axis — same code path either way, which is
   what lets the tests pin the fleet semantics on one host.
2. **Route + submit** happen between ticks: ``submit`` consults the
   *last* gossip plus a router-local since-gossip delta (requests this
   router sent each replica after the snapshot), so routing stays sane
   even though gossip is one tick stale — the staleness the real fleet
   would have.
3. **Step every replica once** (``step_once`` — idle replicas return
   immediately), collect each replica's emissions, and remap local rids
   into the router's global rid namespace.

**Routing policies** (``FleetConfig.route``):

* ``rr`` — round-robin. The baseline: ignores load entirely.
* ``jsq`` — join-shortest-queue on the gossip vector: route to the
  replica with the fewest outstanding requests (gossiped pending +
  active + since-gossip routed delta), breaking ties toward more free
  blocks, then lower index (deterministic).
* ``affinity`` — prefix affinity: hash the prompt's leading *full*
  blocks with the chained content hash from ``serve/paged.py`` and score
  each replica by how many leading links are resident in its registry
  (``BlockPool.chain_hits`` — read-only). Route to the hottest replica
  so PR 6's prefix cache keeps its hit rate instead of being diluted N
  ways; **spill to JSQ** when the preferred replica's backlog (gossiped
  pending + since-gossip delta) has reached ``FleetConfig.spill_queue``
  — a hot replica that is saturated would turn affinity into a convoy.
  Zero resident links anywhere (cold prefix) also falls through to JSQ.

**Bit-equality.** Replicas decode greedily (``temperature=0``) in the
serving benchmarks, and a request's output depends only on its own
prompt — never on which replica served it or who shared its blocks — so
fleet outputs are bit-equal to a single-replica oracle run of the same
stream. ``benchmarks/serve_tput.py`` gates on it.

**Metrics.** Each replica carries its own ``ServeMetrics`` (one shared
injectable clock); ``summary()`` rolls them up through
``metrics.merge_summaries`` — request-level merge, so percentiles are
exactly those of the union stream — and adds routing stats (per-replica
routed/admitted counts, ``load_imbalance`` = max/mean admitted,
gossip tick count).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..dist.collectives import gossip_all_gather
from ..models.registry import ModelApi
from .metrics import ServeMetrics, merge_summaries
from .paged import prefix_hashes
from .scheduler import ContinuousScheduler, SchedulerConfig

# gossip vector layout (must match ContinuousScheduler.occupancy_snapshot)
GOSSIP_FREE, GOSSIP_PENDING, GOSSIP_ACTIVE = 0, 1, 2
GOSSIP_WIDTH = 3

ROUTES = ("rr", "jsq", "affinity")


@dataclass
class FleetConfig:
    replicas: int = 2
    route: str = "jsq"               # "rr" | "jsq" | "affinity"
    # affinity only: spill to JSQ once the preferred replica's backlog
    # (gossiped pending + requests routed there since the last gossip)
    # reaches this depth. None = one full slot table's worth.
    spill_queue: int | None = None
    gossip_axis: str = "data"        # mesh axis the gossip gathers over

    def __post_init__(self):
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        if self.route not in ROUTES:
            raise ValueError(
                f"route must be one of {ROUTES}, got {self.route!r}")


class ReplicaRouter:
    """Front N independent scheduler replicas behind one scheduler API.

    Construction builds ``fleet.replicas`` ``ContinuousScheduler``s from
    the same ``SchedulerConfig`` — equal per-replica slab bytes by
    construction, which is the honest basis for the fleet-vs-single
    scaling claim. ``mesh`` (the model/state mesh) is forwarded to every
    replica; ``gossip_mesh`` drives only the occupancy exchange and is
    None for the host-local fleets the tests and benchmarks run.
    """

    def __init__(self, api: ModelApi, params, cfg: SchedulerConfig,
                 fleet: FleetConfig, mesh=None, gossip_mesh=None,
                 clock=None):
        if fleet.route == "affinity" and not (cfg.paged and
                                              cfg.prefix_cache):
            raise ValueError(
                "route='affinity' scores replicas by resident prefix "
                "chains, which only exist with paged=True + "
                "prefix_cache=True")
        self.cfg = cfg
        self.fleet = fleet
        self.gossip_mesh = gossip_mesh
        self.replicas = [
            ContinuousScheduler(api, params, cfg, mesh=mesh)
            for _ in range(fleet.replicas)]
        self.reset_metrics(clock)
        n = fleet.replicas
        # affinity spill threshold: a replica already holding a full slot
        # table of backlog gains nothing from one more hot request
        self._spill = (cfg.batch if fleet.spill_queue is None
                       else int(fleet.spill_queue))
        # last gossip exchange + per-replica requests routed since it
        self._gossip = np.zeros((n, GOSSIP_WIDTH), np.int32)
        self._gossip[:, GOSSIP_FREE] = [
            r.occupancy_snapshot()[GOSSIP_FREE] for r in self.replicas]
        self._since = np.zeros(n, np.int64)
        self._rr_next = 0
        self._next_rid = 0
        # global rid -> (replica, local rid); per-replica local -> global
        self._placement: dict[int, tuple[int, int]] = {}
        self._grid: list[dict[int, int]] = [{} for _ in range(n)]
        self.routed = np.zeros(n, np.int64)
        self.gossip_ticks = 0

    def reset_metrics(self, clock=None) -> None:
        """Fresh per-replica ``ServeMetrics`` (one shared clock) — the
        benchmarks call this after warmup so compile time never pollutes
        the measured window."""
        kw = {} if clock is None else dict(clock=clock)
        for r in self.replicas:
            r.metrics = ServeMetrics(**kw)

    # -- fleet-wide views --------------------------------------------------

    @property
    def num_active(self) -> int:
        return sum(r.num_active for r in self.replicas)

    @property
    def num_pending(self) -> int:
        return sum(r.num_pending for r in self.replicas)

    @property
    def has_work(self) -> bool:
        return any(r.has_work for r in self.replicas)

    @property
    def preemptions(self) -> int:
        return sum(r.preemptions for r in self.replicas)

    def summary(self) -> dict:
        """Fleet rollup of every replica's metrics (request-level merge,
        local rids remapped to the router's global namespace) plus
        routing stats."""
        out = merge_summaries([r.metrics for r in self.replicas],
                              rid_maps=self._grid)
        out["fleet"].update(
            route=self.fleet.route,
            routed_per_replica=self.routed.tolist(),
            gossip_ticks=self.gossip_ticks,
        )
        return out

    # -- routing -----------------------------------------------------------

    def _outstanding(self, ri: int) -> int:
        """Requests replica ``ri`` is on the hook for, as seen from the
        router: gossiped queue depth + admitted count, plus everything
        this router sent it after that snapshot."""
        g = self._gossip[ri]
        return int(g[GOSSIP_PENDING] + g[GOSSIP_ACTIVE] + self._since[ri])

    def _jsq(self) -> int:
        """Join-shortest-queue: fewest outstanding, ties toward more free
        blocks (the gossip's resource column), then lowest index."""
        return min(
            range(len(self.replicas)),
            key=lambda ri: (self._outstanding(ri),
                            -int(self._gossip[ri][GOSSIP_FREE]), ri))

    def _affinity(self, toks: np.ndarray) -> int:
        """Prefix affinity with JSQ spill: pick the replica whose pool
        registry holds the longest resident chain of the prompt's leading
        full blocks; fall back to JSQ when no replica is warm or the
        preferred one is saturated."""
        hashes = prefix_hashes(toks, self.cfg.block_size)
        # the last block a request shares is never its final block (the
        # boundary block is copied, not shared), but chain_hits is a
        # *score*, not a plan — deeper resident chains mean warmer caches
        hits = [r.pool.chain_hits(hashes) for r in self.replicas]
        best = max(hits)
        if best == 0:
            return self._jsq()                     # cold prefix everywhere
        warm = [ri for ri, h in enumerate(hits) if h == best]
        # ties between equally-warm replicas resolve by JSQ
        ri = min(warm, key=lambda i: (self._outstanding(i), i))
        backlog = int(self._gossip[ri][GOSSIP_PENDING] + self._since[ri])
        if backlog >= self._spill:
            return self._jsq()                     # saturated: spill
        return ri

    def _route(self, toks: np.ndarray) -> int:
        if self.fleet.route == "rr":
            ri = self._rr_next
            self._rr_next = (ri + 1) % len(self.replicas)
            return ri
        if self.fleet.route == "jsq":
            return self._jsq()
        return self._affinity(toks)

    # -- the single-scheduler surface --------------------------------------

    def submit(self, tokens, max_new_tokens: int | None = None,
               extra: dict | None = None, priority: int = 0) -> int:
        """Route one request to a replica; returns its *global* rid."""
        toks = np.asarray(tokens, np.int32).reshape(-1)
        ri = self._route(toks)
        local = self.replicas[ri].submit(
            toks, max_new_tokens=max_new_tokens, extra=extra,
            priority=priority)
        rid = self._next_rid
        self._next_rid += 1
        self._placement[rid] = (ri, local)
        self._grid[ri][local] = rid
        self._since[ri] += 1
        self.routed[ri] += 1
        return rid

    def _gossip_tick(self) -> None:
        vecs = np.stack([r.occupancy_snapshot() for r in self.replicas])
        self._gossip = gossip_all_gather(
            vecs, mesh=self.gossip_mesh, axis=self.fleet.gossip_axis)
        self._since[:] = 0
        self.gossip_ticks += 1

    def step(self) -> dict[int, int]:
        """One fleet round: refresh gossip, step every replica once, and
        return the merged emissions keyed by global rid."""
        self._gossip_tick()
        emissions: dict[int, int] = {}
        for ri, rep in enumerate(self.replicas):
            for local, tok in rep.step_once().items():
                emissions[self._grid[ri][local]] = tok
        return emissions

    def run(self) -> dict[int, np.ndarray]:
        """Drain the fleet; returns {global rid: (n_tokens,) int32} for
        every request finished since the last ``run`` and releases them,
        mirroring ``ContinuousScheduler.run``."""
        while self.has_work:
            self.step()
        out: dict[int, np.ndarray] = {}
        for ri, rep in enumerate(self.replicas):
            for local, toks in rep.run().items():
                out[self._grid[ri][local]] = toks
        # leave a fresh idle-state gossip view: the last in-loop exchange
        # ran while work was still in flight, and routing the next stream
        # off that stale snapshot would be arbitrary (and nondeterministic
        # across warmup/measured replays of the same stream)
        self._gossip_tick()
        return out
