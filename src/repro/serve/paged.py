"""Paged KV-cache block pool: fixed-size blocks shared across requests.

The dense slot table reserves a full ``max_cache_len`` K/V stripe per row,
so a 30-token request pins the same slab bytes as a 2000-token one — the
serving-side analogue of scanning raw logs when a compact session summary
would do. The paged pool is the fix the paper applies to storage and
Loginson applies to collection: **fixed-size buffer management**. One slab
of ``num_blocks`` fixed ``block_size``-token blocks serves every request;
a request holds only the blocks its positions actually reach, so slab
memory converts directly into admission capacity.

Layout and invariants:

* The slab is ``(num_layers, num_blocks + 1, kv_heads, block_size,
  head_dim)`` per K and V. **Block 0 is the trash block**: it is never
  allocated, every cleared block-table entry points at it, and the
  scheduler's garbage writes for inactive rows land there — a freed block
  can be handed to a new request the same step without any risk that a
  dead row still scribbles on it. ``free`` rejects it loudly, so the
  trash block can never leak into the free list.
* Allocation is a LIFO free list — O(1) ``take`` / O(k) ``free`` of k
  blocks, no search, no compaction. Blocks are interchangeable, so there
  is no external fragmentation by construction: any free block serves any
  request (the mixed-length evict/reuse test pins this down).
* Admission **reserves** a request's worst case up front
  (``blocks_needed`` = ceil((prompt_len + budget - 1) / block_size)) but
  **allocates lazily**: the prompt's blocks at admission, then one block
  at a time as decode crosses each block boundary. With the default
  ``overcommit=1.0`` reservations are honest — the free list always
  covers them, so mid-decode exhaustion is impossible. With
  ``overcommit > 1`` admission is **optimistic**: reservations may sum to
  ``overcommit * capacity`` (requests that hit EOS early never claim
  their worst case, so real capacity usually suffices), and the day the
  bet loses — ``take`` finds the free list empty — ``PoolExhausted`` is
  raised for the scheduler to preempt a victim and retry.
* A per-request **block table** is padded to ``max_blocks`` entries
  (``max_cache_len / block_size``); unallocated entries are 0 (trash), so
  gathering through the table always reads in-bounds memory and per-row
  ``kv_len`` masking makes the trash contribution exactly zero.

**Prefix sharing (session-prefix caching).** Every allocated block carries
a refcount: ``take`` starts it at 1, ``share`` bumps it for each request
that maps an already-resident block into its table copy-free, and ``free``
only returns a block to the free list when the count reaches 0 —
double-frees and underflows raise loudly instead of corrupting the free
list. A block whose content is the K/V of a *full* block of prompt tokens
under a known prefix can be **registered** under a chained content hash
(``h_i = blake2b(h_{i-1} || tokens_i)``, rooted at a fixed seed), so a
block is only ever matched when the ENTIRE token prefix before it is
identical — which makes absolute positions (and therefore RoPE phases)
line up by construction. ``lookup`` resolves a chain hash to a resident
block; ``find_extension`` resolves a *partial* boundary block (a resident
block whose leading tokens extend a matched chain) for copy-on-write
duplication. Registration dies with the block: when its refcount reaches
0 the hash entries are dropped before the block re-enters the free list.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import numpy as np
import jax.numpy as jnp

from ..models.config import ModelConfig

# Root of every prefix hash chain. Versioned so a future layout change
# cannot alias stale hashes.
PREFIX_SEED = b"repro-prefix-cache-v1"


class PoolExhausted(RuntimeError):
    """``take`` found the free list empty under over-commit admission.

    Only reachable with ``overcommit > 1``: honest reservations guarantee
    a free block for every reserved unit. The scheduler catches this,
    preempts the lowest-priority (ties: youngest) victim to free its
    blocks, and retries the allocation.
    """


def blocks_for(positions: int, block_size: int) -> int:
    """Blocks needed to hold cache positions ``0..positions-1``."""
    return max(0, -(-int(positions) // int(block_size)))


def chain_hash(parent: bytes, tokens) -> bytes:
    """One link of the prefix hash chain: ``blake2b(parent || tokens)``.

    Chaining means a block's hash commits to every token before it, not
    just its own ``block_size`` tokens — two requests only collide on a
    hash when their prompts are identical up to and including that block.
    """
    h = hashlib.blake2b(parent, digest_size=16)
    h.update(np.ascontiguousarray(np.asarray(tokens, np.int32)).tobytes())
    return h.digest()


def prefix_hashes(tokens, block_size: int) -> list[bytes]:
    """Chained hashes of the *full* blocks covering ``tokens`` (the
    trailing partial block, if any, has no hash — only a block whose
    every position is pinned by prompt tokens is content-addressable)."""
    toks = np.asarray(tokens, np.int32).reshape(-1)
    out: list[bytes] = []
    prev = PREFIX_SEED
    for j in range(len(toks) // block_size):
        prev = chain_hash(prev, toks[j * block_size:(j + 1) * block_size])
        out.append(prev)
    return out


@dataclass
class PrefixPlan:
    """Host-side admission plan for one request against the prefix cache.

    ``shared`` blocks are mapped copy-free (refcount bump, never written);
    ``cow`` names a resident donor block whose content covers the boundary
    block — it is *copied* into a freshly owned block before the request
    scatters anything into it (copy-on-write). ``start`` is the first
    prompt position the tail prefill actually computes; everything before
    it is served from resident K/V.
    """
    shared: list[int]
    cow: int | None
    start: int
    hashes: list[bytes] = field(repr=False)
    tokens: np.ndarray = field(repr=False)

    @property
    def blocks_reused(self) -> int:
        return len(self.shared) + (1 if self.cow is not None else 0)


class BlockPool:
    """Refcounted free-list allocator over a fixed slab of KV blocks.

    ``num_blocks`` counts *allocatable* blocks; the slab carries one extra
    row (block 0, the trash block) that is never handed out. Reservations
    (``reserve``/``cancel``) set aside capacity without choosing blocks;
    ``take`` converts one reserved unit into a concrete block id at
    refcount 1, ``share`` adds a reference to a resident block, and
    ``free`` drops one reference per listed block — a block re-enters the
    free list only at refcount 0.
    """

    def __init__(self, *, num_blocks: int, block_size: int,
                 num_kv_heads: int, head_dim: int, num_layers: int,
                 dtype=jnp.bfloat16, overcommit: float = 1.0,
                 debug: bool = False):
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if overcommit < 1.0:
            raise ValueError(
                f"overcommit must be >= 1.0 (1.0 = honest worst-case "
                f"reservation), got {overcommit}")
        self.overcommit = float(overcommit)
        # when set, ``check_invariants`` runs automatically after every
        # evict/preempt-driven free (see PagedKVState.evict)
        self.debug = bool(debug)
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.num_kv_heads = int(num_kv_heads)
        self.head_dim = int(head_dim)
        self.num_layers = int(num_layers)
        self.dtype = jnp.dtype(dtype)
        # LIFO free list: freshly freed blocks are reused first (warm HBM).
        self._free: list[int] = list(range(self.num_blocks, 0, -1))
        self._reserved = 0
        # per-block reference counts (index 0 = trash, always 0)
        self._refs = np.zeros(self.num_blocks + 1, np.int64)
        # content-hash registry: chain hash -> resident block id, plus the
        # reverse/edge maps needed to unregister and to find COW donors
        self._hash_to_block: dict[bytes, int] = {}
        self._block_hash: dict[int, tuple[bytes, bytes]] = {}
        self._block_tokens: dict[int, np.ndarray] = {}
        self._children: dict[bytes, set[int]] = {}

    @classmethod
    def for_model(cls, cfg: ModelConfig, *, num_blocks: int,
                  block_size: int, overcommit: float = 1.0,
                  debug: bool = False) -> "BlockPool":
        return cls(num_blocks=num_blocks, block_size=block_size,
                   num_kv_heads=cfg.num_kv_heads,
                   head_dim=cfg.resolved_head_dim,
                   num_layers=cfg.num_layers, dtype=jnp.dtype(cfg.dtype),
                   overcommit=overcommit, debug=debug)

    # -- capacity accounting ----------------------------------------------

    @property
    def capacity(self) -> int:
        """Total allocatable blocks (the trash block excluded)."""
        return self.num_blocks

    @property
    def virtual_capacity(self) -> int:
        """Capacity admission reserves against: real blocks scaled by the
        over-commit factor (== ``capacity`` at the default 1.0)."""
        return int(self.num_blocks * self.overcommit)

    @property
    def free_blocks(self) -> int:
        """Blocks ``take`` can hand out *right now* — under over-commit
        this can be far below what reservations promise."""
        return len(self._free)

    @property
    def available(self) -> int:
        """Blocks a new reservation may still claim: virtual capacity
        minus everything resident or already promised."""
        return self.virtual_capacity - self.live_blocks - self._reserved

    @property
    def live_blocks(self) -> int:
        """*Unique* blocks currently resident (shared blocks count once)."""
        return self.num_blocks - len(self._free)

    @property
    def referenced_blocks(self) -> int:
        """Total block references across requests (shared blocks count once
        per sharer) — ``referenced_blocks - live_blocks`` is the capacity
        prefix sharing is saving right now."""
        return int(self._refs.sum())

    def refcount(self, block_id: int) -> int:
        return int(self._refs[int(block_id)])

    @property
    def block_bytes(self) -> int:
        """Device bytes of one block across all layers, K and V."""
        return (2 * self.num_layers * self.num_kv_heads * self.block_size
                * self.head_dim * self.dtype.itemsize)

    @property
    def slab_bytes(self) -> int:
        """Resident bytes of the whole slab (trash block included)."""
        return (self.num_blocks + 1) * self.block_bytes

    def blocks_needed(self, prompt_len: int, budget: int) -> int:
        """Worst-case blocks for a request: prefill writes positions
        ``0..prompt_len-1`` and decode writes ``prompt_len..prompt_len +
        budget - 2`` (the final sampled token is never cached)."""
        return blocks_for(prompt_len + budget - 1, self.block_size)

    def check_invariants(self) -> None:
        """Allocator self-check, used by the property tests: free list +
        live blocks partition capacity, refcounts agree with residency,
        and the trash block is neither free nor referenced."""
        if len(set(self._free)) != len(self._free):
            raise AssertionError(f"duplicate ids in free list: {self._free}")
        if 0 in self._free:
            raise AssertionError("trash block 0 leaked into the free list")
        if len(self._free) + self.live_blocks != self.capacity:
            raise AssertionError(
                f"free ({len(self._free)}) + live ({self.live_blocks}) "
                f"!= capacity ({self.capacity})")
        if self._refs[0] != 0:
            raise AssertionError("trash block 0 has a nonzero refcount")
        free = set(self._free)
        for blk in range(1, self.num_blocks + 1):
            if (blk in free) != (self._refs[blk] == 0):
                raise AssertionError(
                    f"block {blk}: refcount {int(self._refs[blk])} "
                    f"disagrees with free-list membership")
        for h, blk in self._hash_to_block.items():
            if self._refs[blk] == 0:
                raise AssertionError(
                    f"hash registry holds dead block {blk}")
        if self._reserved < 0:
            raise AssertionError(f"negative reservation {self._reserved}")
        if self.live_blocks + self._reserved > self.virtual_capacity:
            raise AssertionError(
                f"live ({self.live_blocks}) + reserved ({self._reserved}) "
                f"exceeds virtual capacity ({self.virtual_capacity} = "
                f"{self.capacity} x {self.overcommit})")
        if self.overcommit == 1.0 and self._reserved > len(self._free):
            raise AssertionError(
                f"{self._reserved} reserved with {len(self._free)} free "
                "under honest (overcommit=1.0) reservation")

    # -- reservation + allocation -----------------------------------------

    def can_reserve(self, n: int) -> bool:
        return self.available >= n

    def reserve(self, n: int) -> None:
        if not self.can_reserve(n):
            raise ValueError(
                f"cannot reserve {n} blocks: {self.available} available "
                f"({self.virtual_capacity} virtual capacity - "
                f"{self.live_blocks} live - {self._reserved} reserved)")
        self._reserved += n

    def cancel(self, n: int) -> None:
        """Return ``n`` unused reservation units (eviction before the
        request's worst case materialized)."""
        if n < 0 or n > self._reserved:
            raise ValueError(f"cancel({n}) with {self._reserved} reserved")
        self._reserved -= n

    def take(self) -> int:
        """Convert one reserved unit into a concrete block id at refcount
        1. O(1). Never returns block 0 (the trash block). Raises
        ``PoolExhausted`` when the free list is empty — reachable only
        under over-commit (honest reservations always have a free block
        behind them); the scheduler preempts a victim and retries."""
        if self._reserved <= 0:
            raise ValueError("take() without a reservation")
        if not self._free:
            raise PoolExhausted(
                f"free list empty with {self._reserved} reserved blocks "
                f"outstanding (over-commit {self.overcommit}x: "
                f"{self.live_blocks}/{self.capacity} blocks live) — "
                "preempt a victim to free capacity")
        self._reserved -= 1
        blk = self._free.pop()
        self._refs[blk] = 1
        return blk

    def share(self, block_id: int) -> None:
        """Add one reference to an already-resident block (prefix hit:
        the block is mapped into another request's table copy-free)."""
        blk = int(block_id)
        if not 1 <= blk <= self.num_blocks:
            raise ValueError(f"block id {blk} out of range")
        if self._refs[blk] < 1:
            raise ValueError(
                f"share() on non-resident block {blk} (refcount 0)")
        self._refs[blk] += 1

    def free(self, block_ids) -> None:
        """Drop one reference per listed block; blocks reaching refcount 0
        are unregistered from the prefix index and returned to the free
        list. Double-frees raise instead of corrupting the free list, and
        block 0 (the trash block) is never accepted."""
        for blk in block_ids:
            blk = int(blk)
            if blk == 0:
                raise ValueError(
                    "free() on block 0: the trash block is never allocated "
                    "and never freed")
            if not 1 <= blk <= self.num_blocks:
                raise ValueError(f"block id {blk} out of range")
            if self._refs[blk] <= 0:
                raise ValueError(
                    f"refcount underflow on block {blk}: double free (block "
                    "is already on the free list)")
            self._refs[blk] -= 1
            if self._refs[blk] == 0:
                self._unregister(blk)
                self._free.append(blk)

    # -- prefix-hash registry ----------------------------------------------

    def register(self, h: bytes, parent: bytes, block_id: int,
                 tokens) -> bool:
        """Publish a resident block as the K/V of one full block of prompt
        tokens under chain hash ``h`` (``parent`` = the chain hash before
        it). First registration wins; returns False if ``h`` is already
        claimed. The block must be resident — its registration is dropped
        automatically when its refcount reaches 0."""
        blk = int(block_id)
        if self._refs[blk] < 1:
            raise ValueError(
                f"register() on non-resident block {blk} (refcount 0)")
        if h in self._hash_to_block:
            return False
        if blk in self._block_hash:      # one hash per block
            return False
        toks = np.asarray(tokens, np.int32).reshape(-1)
        if len(toks) != self.block_size:
            raise ValueError(
                f"register() needs exactly {self.block_size} tokens "
                f"(a full block), got {len(toks)}")
        self._hash_to_block[h] = blk
        self._block_hash[blk] = (h, parent)
        self._block_tokens[blk] = toks.copy()
        self._children.setdefault(parent, set()).add(blk)
        return True

    def lookup(self, h: bytes) -> int | None:
        """Resident block holding the full block of tokens whose chain
        hash is ``h``, or None."""
        return self._hash_to_block.get(h)

    def chain_hits(self, hashes: list[bytes]) -> int:
        """How many *leading* links of a prefix hash chain are resident in
        this pool's registry. Strictly read-only — no refcount bumps, no
        reservations — so a fleet router can probe every replica's pool
        when scoring prefix affinity without perturbing allocator state.

        Counts stop at the first miss: a resident block deeper in the
        chain is unusable without its ancestors (the chained hash pins
        absolute positions), so it must not count as affinity.
        """
        n = 0
        for h in hashes:
            if h not in self._hash_to_block:
                break
            n += 1
        return n

    def find_extension(self, parent: bytes, tokens) -> int | None:
        """A resident registered block that *extends* chain ``parent`` and
        whose leading tokens equal ``tokens`` — the COW donor for a
        request whose prompt ends inside a block some earlier request
        filled completely. Returns None when no such block exists."""
        toks = np.asarray(tokens, np.int32).reshape(-1)
        if not 0 < len(toks) <= self.block_size:
            return None
        for blk in self._children.get(parent, ()):
            if np.array_equal(self._block_tokens[blk][:len(toks)], toks):
                return blk
        return None

    def _unregister(self, blk: int) -> None:
        entry = self._block_hash.pop(blk, None)
        if entry is None:
            return
        h, parent = entry
        self._hash_to_block.pop(h, None)
        self._block_tokens.pop(blk, None)
        kids = self._children.get(parent)
        if kids is not None:
            kids.discard(blk)
            if not kids:
                del self._children[parent]

    # -- device slab -------------------------------------------------------

    def init_slab(self) -> dict:
        """Zeroed K/V slab: ``(L, num_blocks + 1, KVH, block_size, Dh)``.

        Built on demand (the pool itself keeps no reference, so the
        scheduler's functionally-updated copy is the only live one).
        """
        shape = (self.num_layers, self.num_blocks + 1, self.num_kv_heads,
                 self.block_size, self.head_dim)
        return dict(k=jnp.zeros(shape, self.dtype),
                    v=jnp.zeros(shape, self.dtype))
