"""Paged KV-cache block pool: fixed-size blocks shared across requests.

The dense slot table reserves a full ``max_cache_len`` K/V stripe per row,
so a 30-token request pins the same slab bytes as a 2000-token one — the
serving-side analogue of scanning raw logs when a compact session summary
would do. The paged pool is the fix the paper applies to storage and
Loginson applies to collection: **fixed-size buffer management**. One slab
of ``num_blocks`` fixed ``block_size``-token blocks serves every request;
a request holds only the blocks its positions actually reach, so slab
memory converts directly into admission capacity.

Layout and invariants:

* The slab is ``(num_layers, num_blocks + 1, kv_heads, block_size,
  head_dim)`` per K and V. **Block 0 is the trash block**: it is never
  allocated, every cleared block-table entry points at it, and the
  scheduler's garbage writes for inactive rows land there — a freed block
  can be handed to a new request the same step without any risk that a
  dead row still scribbles on it.
* Allocation is a LIFO free list — O(1) ``take`` / O(k) ``free`` of k
  blocks, no search, no compaction. Blocks are interchangeable, so there
  is no external fragmentation by construction: any free block serves any
  request (the mixed-length evict/reuse test pins this down).
* Admission **reserves** a request's worst case up front
  (``blocks_needed`` = ceil((prompt_len + budget - 1) / block_size)) but
  **allocates lazily**: the prompt's blocks at admission, then one block
  at a time as decode crosses each block boundary. Reservation makes
  mid-decode exhaustion impossible (no preemption machinery needed) while
  the lazy table growth keeps ``live_blocks`` — and the utilization
  metric — honest about what is actually written.
* A per-request **block table** is padded to ``max_blocks`` entries
  (``max_cache_len / block_size``); unallocated entries are 0 (trash), so
  gathering through the table always reads in-bounds memory and per-row
  ``kv_len`` masking makes the trash contribution exactly zero.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from ..models.config import ModelConfig


def blocks_for(positions: int, block_size: int) -> int:
    """Blocks needed to hold cache positions ``0..positions-1``."""
    return max(0, -(-int(positions) // int(block_size)))


class BlockPool:
    """Free-list allocator over a fixed slab of KV blocks.

    ``num_blocks`` counts *allocatable* blocks; the slab carries one extra
    row (block 0, the trash block) that is never handed out. Reservations
    (``reserve``/``cancel``) set aside capacity without choosing blocks;
    ``take`` converts one reserved unit into a concrete block id.
    """

    def __init__(self, *, num_blocks: int, block_size: int,
                 num_kv_heads: int, head_dim: int, num_layers: int,
                 dtype=jnp.bfloat16):
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.num_kv_heads = int(num_kv_heads)
        self.head_dim = int(head_dim)
        self.num_layers = int(num_layers)
        self.dtype = jnp.dtype(dtype)
        # LIFO free list: freshly freed blocks are reused first (warm HBM).
        self._free: list[int] = list(range(self.num_blocks, 0, -1))
        self._reserved = 0

    @classmethod
    def for_model(cls, cfg: ModelConfig, *, num_blocks: int,
                  block_size: int) -> "BlockPool":
        return cls(num_blocks=num_blocks, block_size=block_size,
                   num_kv_heads=cfg.num_kv_heads,
                   head_dim=cfg.resolved_head_dim,
                   num_layers=cfg.num_layers, dtype=jnp.dtype(cfg.dtype))

    # -- capacity accounting ----------------------------------------------

    @property
    def capacity(self) -> int:
        """Total allocatable blocks (the trash block excluded)."""
        return self.num_blocks

    @property
    def available(self) -> int:
        """Blocks a new reservation may still claim."""
        return len(self._free) - self._reserved

    @property
    def live_blocks(self) -> int:
        """Blocks currently allocated to requests (written or writable)."""
        return self.num_blocks - len(self._free)

    @property
    def block_bytes(self) -> int:
        """Device bytes of one block across all layers, K and V."""
        return (2 * self.num_layers * self.num_kv_heads * self.block_size
                * self.head_dim * self.dtype.itemsize)

    @property
    def slab_bytes(self) -> int:
        """Resident bytes of the whole slab (trash block included)."""
        return (self.num_blocks + 1) * self.block_bytes

    def blocks_needed(self, prompt_len: int, budget: int) -> int:
        """Worst-case blocks for a request: prefill writes positions
        ``0..prompt_len-1`` and decode writes ``prompt_len..prompt_len +
        budget - 2`` (the final sampled token is never cached)."""
        return blocks_for(prompt_len + budget - 1, self.block_size)

    # -- reservation + allocation -----------------------------------------

    def can_reserve(self, n: int) -> bool:
        return self.available >= n

    def reserve(self, n: int) -> None:
        if not self.can_reserve(n):
            raise ValueError(
                f"cannot reserve {n} blocks: {self.available} available "
                f"({len(self._free)} free - {self._reserved} reserved)")
        self._reserved += n

    def cancel(self, n: int) -> None:
        """Return ``n`` unused reservation units (eviction before the
        request's worst case materialized)."""
        if n < 0 or n > self._reserved:
            raise ValueError(f"cancel({n}) with {self._reserved} reserved")
        self._reserved -= n

    def take(self) -> int:
        """Convert one reserved unit into a concrete block id. O(1)."""
        if self._reserved <= 0:
            raise ValueError("take() without a reservation")
        if not self._free:  # unreachable while reservations are honest
            raise ValueError("free list empty with reservations outstanding")
        self._reserved -= 1
        return self._free.pop()

    def free(self, block_ids) -> None:
        """Return allocated blocks to the pool. O(k)."""
        for blk in block_ids:
            blk = int(blk)
            if not 1 <= blk <= self.num_blocks:
                raise ValueError(f"block id {blk} out of range")
            self._free.append(blk)

    # -- device slab -------------------------------------------------------

    def init_slab(self) -> dict:
        """Zeroed K/V slab: ``(L, num_blocks + 1, KVH, block_size, Dh)``.

        Built on demand (the pool itself keeps no reference, so the
        scheduler's functionally-updated copy is the only live one).
        """
        shape = (self.num_layers, self.num_blocks + 1, self.num_kv_heads,
                 self.block_size, self.head_dim)
        return dict(k=jnp.zeros(shape, self.dtype),
                    v=jnp.zeros(shape, self.dtype))
