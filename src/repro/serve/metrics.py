"""Latency/throughput accounting for the serving path.

One ``ServeMetrics`` instance rides along a scheduler (or a batch
``Server.generate`` call) and timestamps the request lifecycle:
submit -> admit (slot granted) -> first token -> finish. ``summary()``
derives the numbers the serving story is judged on — tokens/sec and the
p50/p99 of per-request latency and time-to-first-token.

The clock is injectable so tests can drive it deterministically.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class RequestTiming:
    submit: float | None = None
    admit: float | None = None
    first_token: float | None = None
    finish: float | None = None
    tokens: int = 0
    prompt_len: int = 0


def _percentile(xs: list[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) — no numpy dependency so the
    struct stays importable anywhere."""
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, int(round(q / 100.0 * (len(ys) - 1)))))
    return ys[i]


@dataclass
class ServeMetrics:
    clock: callable = time.perf_counter
    requests: dict[int, RequestTiming] = field(default_factory=dict)
    # KV-slab occupancy, sampled once per scheduler step. "Blocks" are the
    # paged pool's fixed blocks, or whole slot stripes on the dense path.
    kv_total_blocks: int = 0
    kv_live_blocks: int = 0          # last sample
    kv_live_blocks_peak: int = 0
    kv_block_bytes: int = 0

    def _rec(self, rid: int) -> RequestTiming:
        return self.requests.setdefault(rid, RequestTiming())

    def record_kv_usage(self, live_blocks: int, total_blocks: int,
                        block_bytes: int) -> None:
        """One occupancy sample: ``live_blocks`` of ``total_blocks`` are
        allocated to in-flight requests, each ``block_bytes`` on device."""
        self.kv_live_blocks = int(live_blocks)
        self.kv_total_blocks = int(total_blocks)
        self.kv_block_bytes = int(block_bytes)
        self.kv_live_blocks_peak = max(self.kv_live_blocks_peak,
                                       int(live_blocks))

    def record_submit(self, rid: int, prompt_len: int = 0) -> None:
        r = self._rec(rid)
        r.submit = self.clock()
        r.prompt_len = prompt_len

    def record_admit(self, rid: int) -> None:
        self._rec(rid).admit = self.clock()

    def record_token(self, rid: int) -> None:
        r = self._rec(rid)
        r.tokens += 1
        if r.first_token is None:
            r.first_token = self.clock()

    def record_finish(self, rid: int) -> None:
        self._rec(rid).finish = self.clock()

    def _kv_summary(self) -> dict:
        util = (self.kv_live_blocks_peak / self.kv_total_blocks
                if self.kv_total_blocks else 0.0)
        return dict(
            kv_util_peak=util,
            kv_live_blocks_peak=self.kv_live_blocks_peak,
            kv_total_blocks=self.kv_total_blocks,
            kv_peak_resident_bytes=self.kv_live_blocks_peak
            * self.kv_block_bytes,
        )

    def summary(self) -> dict:
        done = [r for r in self.requests.values() if r.finish is not None]
        total_tokens = sum(r.tokens for r in self.requests.values())
        if not done:
            return dict(requests=0, tokens=total_tokens,
                        tokens_per_sec=0.0, p50_latency_s=0.0,
                        p99_latency_s=0.0, p50_ttft_s=0.0, p99_ttft_s=0.0,
                        **self._kv_summary())
        t0 = min(r.submit for r in done if r.submit is not None)
        t1 = max(r.finish for r in done)
        wall = max(t1 - t0, 1e-9)
        # throughput counts finished requests' tokens only, over their own
        # wall span — in-flight tokens would inflate it against a shorter
        # denominator when summary() is read mid-stream
        done_tokens = sum(r.tokens for r in done)
        lat = [r.finish - r.submit for r in done if r.submit is not None]
        ttft = [r.first_token - r.submit for r in done
                if r.submit is not None and r.first_token is not None]
        return dict(
            requests=len(done),
            tokens=total_tokens,
            tokens_per_sec=done_tokens / wall,
            p50_latency_s=_percentile(lat, 50),
            p99_latency_s=_percentile(lat, 99),
            p50_ttft_s=_percentile(ttft, 50),
            p99_ttft_s=_percentile(ttft, 99),
            **self._kv_summary(),
        )
