"""Latency/throughput accounting for the serving path.

One ``ServeMetrics`` instance rides along a scheduler (or a batch
``Server.generate`` call) and timestamps the request lifecycle:
submit -> admit (slot granted) -> first token -> finish. ``summary()``
derives the numbers the serving story is judged on — tokens/sec, the
p50/p99 of per-request latency and time-to-first-token, and the
**queue-wait split**: TTFT (submit -> first token) decomposes into
queue wait (submit -> first admission) plus admitted TTFT (first
admission -> first token, the prefill the request actually ran), both
exposed separately so a loaded benchmark can tell scheduling delay from
compute delay.

``merge_summaries`` rolls K per-replica ``ServeMetrics`` up into one
fleet-level summary by merging at the *request* level (not by averaging
percentiles — percentiles do not compose), so the merged numbers are
exactly what one combined ``ServeMetrics`` over the union stream would
report. KV capacity/peak fields sum across replicas: each replica owns an
independent slab. The rollup adds a ``fleet`` section with per-replica
admitted counts and the load-imbalance stat ``max/mean admitted``.

Requests carry a **priority class**; ``summary()["per_priority"]``
breaks latency, TTFT, queue wait, and preemption counts out per class —
the numbers the SLO gate in ``benchmarks/serve_tput.py`` judges
over-commit serving on. ``record_preempt`` counts each time a request is
preempted (its ``admit`` stamp keeps the *first* admission, so queue
wait stays submit -> first grant across requeue cycles).

The clock is injectable so tests can drive it deterministically.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class RequestTiming:
    submit: float | None = None
    admit: float | None = None       # FIRST admission (stable under requeue)
    first_token: float | None = None
    finish: float | None = None
    tokens: int = 0
    prompt_len: int = 0
    priority: int = 0
    preemptions: int = 0             # times this request was preempted
    # prefix-cache accounting (paged + prefix_cache only)
    prefix_blocks_reused: int = 0    # resident blocks mapped copy-free
    prefill_tokens_skipped: int = 0  # prompt tokens served from resident K/V
    prefix_hit: bool = False


def _percentile(xs: list[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) — no numpy dependency so the
    struct stays importable anywhere."""
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, int(round(q / 100.0 * (len(ys) - 1)))))
    return ys[i]


@dataclass
class ServeMetrics:
    clock: callable = time.perf_counter
    requests: dict[int, RequestTiming] = field(default_factory=dict)
    # KV-slab occupancy, sampled once per scheduler step. "Blocks" are the
    # paged pool's fixed blocks, or whole slot stripes on the dense path.
    kv_total_blocks: int = 0
    kv_live_blocks: int = 0          # last sample
    kv_live_blocks_peak: int = 0
    kv_block_bytes: int = 0
    kv_referenced_peak: int = 0      # total refs (shared counted per sharer)

    def _rec(self, rid: int) -> RequestTiming:
        return self.requests.setdefault(rid, RequestTiming())

    def record_kv_usage(self, live_blocks: int, total_blocks: int,
                        block_bytes: int, referenced: int | None = None)\
            -> None:
        """One occupancy sample: ``live_blocks`` of ``total_blocks`` are
        allocated to in-flight requests, each ``block_bytes`` on device.

        ``live_blocks`` counts *unique* resident blocks — a block five
        requests share pins its bytes once, so ``kv_peak_resident_bytes``
        stays honest under prefix sharing. ``referenced`` is the total
        reference count across requests (shared blocks counted per
        sharer); ``referenced - live`` is the capacity sharing saved."""
        self.kv_live_blocks = int(live_blocks)
        self.kv_total_blocks = int(total_blocks)
        self.kv_block_bytes = int(block_bytes)
        self.kv_live_blocks_peak = max(self.kv_live_blocks_peak,
                                       int(live_blocks))
        self.kv_referenced_peak = max(
            self.kv_referenced_peak,
            int(live_blocks if referenced is None else referenced))

    def record_prefix(self, rid: int, blocks_reused: int = 0,
                      tokens_skipped: int = 0) -> None:
        """Prefix-cache outcome for one admission: how many resident
        blocks were mapped copy-free and how many prompt tokens the tail
        prefill skipped. Zero/zero = a miss (cold prefill)."""
        r = self._rec(rid)
        r.prefix_blocks_reused = int(blocks_reused)
        r.prefill_tokens_skipped = int(tokens_skipped)
        r.prefix_hit = blocks_reused > 0 or tokens_skipped > 0

    def record_submit(self, rid: int, prompt_len: int = 0,
                      priority: int = 0) -> None:
        r = self._rec(rid)
        r.submit = self.clock()
        r.prompt_len = prompt_len
        r.priority = int(priority)

    def record_admit(self, rid: int) -> None:
        """Stamp the FIRST admission only: a preempted request re-admits,
        but its queue wait is submit -> first slot grant — requeue delay
        shows up in end-to-end latency, not in queue wait."""
        r = self._rec(rid)
        if r.admit is None:
            r.admit = self.clock()

    def record_preempt(self, rid: int) -> None:
        self._rec(rid).preemptions += 1

    def record_token(self, rid: int) -> None:
        r = self._rec(rid)
        r.tokens += 1
        if r.first_token is None:
            r.first_token = self.clock()

    def record_finish(self, rid: int) -> None:
        self._rec(rid).finish = self.clock()

    def _kv_summary(self) -> dict:
        util = (self.kv_live_blocks_peak / self.kv_total_blocks
                if self.kv_total_blocks else 0.0)
        return dict(
            kv_util_peak=util,
            kv_live_blocks_peak=self.kv_live_blocks_peak,
            kv_total_blocks=self.kv_total_blocks,
            kv_peak_resident_bytes=self.kv_live_blocks_peak
            * self.kv_block_bytes,
            kv_referenced_peak=self.kv_referenced_peak,
        )

    def _prefix_summary(self) -> dict:
        """Prefix-cache rollup. The hit/miss TTFT split measures admit ->
        first token (the prefill the request actually ran), not submit ->
        first token: queue wait before admission would otherwise drown the
        prefill saving for requests admitted late in the stream."""
        admitted = [r for r in self.requests.values()
                    if r.admit is not None]
        hits = [r for r in admitted if r.prefix_hit]
        misses = [r for r in admitted if not r.prefix_hit]

        def mean_ttft(rs):
            xs = [r.first_token - r.admit for r in rs
                  if r.first_token is not None]
            return sum(xs) / len(xs) if xs else 0.0

        return dict(
            prefix_hit_rate=len(hits) / len(admitted) if admitted else 0.0,
            prefix_blocks_reused=sum(r.prefix_blocks_reused
                                     for r in admitted),
            prefill_tokens_skipped=sum(r.prefill_tokens_skipped
                                       for r in admitted),
            mean_ttft_hit_s=mean_ttft(hits),
            mean_ttft_miss_s=mean_ttft(misses),
        )

    @staticmethod
    def _latency_stats(rs: list[RequestTiming]) -> dict:
        """p50/p99 latency, TTFT (submit -> first token), queue wait
        (submit -> first admission), and admitted TTFT (first admission ->
        first token) over one set of finished requests. TTFT = queue wait
        + admitted TTFT per request, exposed separately so scheduling
        delay and prefill compute are never conflated again."""
        lat = [r.finish - r.submit for r in rs if r.submit is not None]
        ttft = [r.first_token - r.submit for r in rs
                if r.submit is not None and r.first_token is not None]
        qwait = [r.admit - r.submit for r in rs
                 if r.submit is not None and r.admit is not None]
        attft = [r.first_token - r.admit for r in rs
                 if r.admit is not None and r.first_token is not None]
        return dict(
            p50_latency_s=_percentile(lat, 50),
            p99_latency_s=_percentile(lat, 99),
            p50_ttft_s=_percentile(ttft, 50),
            p99_ttft_s=_percentile(ttft, 99),
            p50_queue_wait_s=_percentile(qwait, 50),
            p99_queue_wait_s=_percentile(qwait, 99),
            p50_ttft_admit_s=_percentile(attft, 50),
            p99_ttft_admit_s=_percentile(attft, 99),
        )

    def _per_priority(self, done: list[RequestTiming]) -> dict[int, dict]:
        """Per-class rollup: latency/TTFT/queue-wait percentiles over the
        class's finished requests, preemption counts over every request
        of the class (a preempted-but-unfinished request still counts)."""
        out: dict[int, dict] = {}
        for p in sorted({r.priority for r in self.requests.values()}):
            rs = [r for r in done if r.priority == p]
            out[p] = dict(
                requests=len(rs),
                preemptions=sum(r.preemptions
                                for r in self.requests.values()
                                if r.priority == p),
                **self._latency_stats(rs))
        return out

    def summary(self) -> dict:
        done = [r for r in self.requests.values() if r.finish is not None]
        total_tokens = sum(r.tokens for r in self.requests.values())
        preemptions = sum(r.preemptions for r in self.requests.values())
        if not done:
            return dict(requests=0, tokens=total_tokens,
                        tokens_per_sec=0.0, preemptions=preemptions,
                        per_priority=self._per_priority([]),
                        **self._latency_stats([]),
                        **self._kv_summary(), **self._prefix_summary())
        t0 = min(r.submit for r in done if r.submit is not None)
        t1 = max(r.finish for r in done)
        wall = max(t1 - t0, 1e-9)
        # throughput counts finished requests' tokens only, over their own
        # wall span — in-flight tokens would inflate it against a shorter
        # denominator when summary() is read mid-stream
        done_tokens = sum(r.tokens for r in done)
        return dict(
            requests=len(done),
            tokens=total_tokens,
            tokens_per_sec=done_tokens / wall,
            preemptions=preemptions,
            per_priority=self._per_priority(done),
            **self._latency_stats(done),
            **self._kv_summary(),
            **self._prefix_summary(),
        )


def merge_metrics(parts: list[ServeMetrics],
                  rid_maps: list[dict[int, int]] | None = None)\
        -> ServeMetrics:
    """Fold K per-replica ``ServeMetrics`` into one combined instance.

    Request records are merged verbatim (every derived stat — percentiles,
    throughput, per-priority splits — then falls out of the ordinary
    ``summary()`` over the union, which is the invariant the property test
    pins: merging K split streams == one combined stream). ``rid_maps[i]``
    remaps replica ``i``'s local rids into the fleet's global namespace;
    without maps the rids must already be globally unique — a collision
    raises instead of silently overwriting a request.

    KV fields sum across parts (independent slabs: fleet capacity and
    fleet peak residency are the sums; the per-replica peaks are
    concurrent by construction since every replica ticks each round).
    """
    out = ServeMetrics(clock=parts[0].clock if parts else time.perf_counter)
    for i, m in enumerate(parts):
        rmap = rid_maps[i] if rid_maps is not None else None
        for rid, rec in m.requests.items():
            key = rid if rmap is None else rmap[rid]
            if key in out.requests:
                raise ValueError(
                    f"rid {key} appears in more than one part — pass "
                    "rid_maps to remap per-replica rids into a global "
                    "namespace")
            out.requests[key] = rec
        out.kv_total_blocks += m.kv_total_blocks
        out.kv_live_blocks += m.kv_live_blocks
        out.kv_live_blocks_peak += m.kv_live_blocks_peak
        out.kv_referenced_peak += m.kv_referenced_peak
        out.kv_block_bytes = max(out.kv_block_bytes, m.kv_block_bytes)
    return out


def merge_summaries(parts: list[ServeMetrics],
                    rid_maps: list[dict[int, int]] | None = None) -> dict:
    """Fleet rollup: ``merge_metrics(parts).summary()`` plus a ``fleet``
    section — per-replica admitted counts and ``load_imbalance`` =
    max/mean admitted (1.0 = perfectly balanced; a router that funnels
    everything to one replica of four scores 4.0)."""
    merged = merge_metrics(parts, rid_maps).summary()
    admitted = [sum(1 for r in m.requests.values() if r.admit is not None)
                for m in parts]
    mean = sum(admitted) / len(admitted) if admitted else 0.0
    merged["fleet"] = dict(
        replicas=len(parts),
        admitted_per_replica=admitted,
        load_imbalance=(max(admitted) / mean) if mean else 0.0,
    )
    return merged
