import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape x
mesh) cell, prove it fits (memory_analysis), and extract roofline inputs
(cost_analysis + HLO collective bytes).

Two modes per cell:

* ``full``  — the REAL config (scanned layers, production microbatching),
  compiled on the production mesh. Proves sharding coherence + per-device
  memory. XLA's HloCostAnalysis counts while-loop bodies ONCE, so this
  compile is NOT used for FLOPs.
* ``cost``  — reduced-depth UNROLLED variants (layers + microbatches as
  python loops) compiled on the single-pod mesh; costs are exactly linear
  (train: bilinear in (L, microbatches)), so two/three points extrapolate
  to the full depth. Collective bytes come from the unrolled optimized HLO
  (no while loops -> every collective instruction is counted once, true).

Results are cached as JSON per (arch, shape, mesh, mode) under
``results/dryrun/``; the sweep driver runs each cell in a subprocess.

Besides the model cells there are pipeline cells: the distributed log
pipeline (data/distpipe.py) lowered at hour-of-events shapes on the
production mesh, for all_to_all/psum collective sizing — and stream cells:
one streaming micro-batch tick (data/streampipe.py) lowered at
events-per-tick shapes (ring merge + repartition + delta psums).

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
      --mesh single --mode full
  python -m repro.launch.dryrun --pipeline hour_1m --mesh single
  python -m repro.launch.dryrun --stream tick_64k --mesh single
  python -m repro.launch.dryrun --store compact_1m
  python -m repro.launch.dryrun --all            # full sweep (both meshes)
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback

import jax
import numpy as np

RESULTS_DIR = os.environ.get("DRYRUN_RESULTS",
                             os.path.join(os.path.dirname(__file__),
                                          "../../../results/dryrun"))

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9]+)\[([0-9,]*)\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device payload bytes of collective ops in optimized HLO.

    Convention: all-reduce counts 2x its output bytes (ring = reduce-scatter
    + all-gather); others count 1x output bytes. Tuple-shaped outputs
    (e.g. fused start ops) sum their parts. '-done' ops are skipped (the
    '-start' carries the shape).
    """
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict(out)
    for line in hlo_text.splitlines():
        if "-done" in line and ("collective" in line or "all-" in line):
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(4)
        if m.group(2):  # plain shape
            nbytes = _shape_bytes(m.group(2), m.group(3))
        else:           # tuple shape: sum the component shapes
            nbytes = sum(_shape_bytes(d, s)
                         for d, s in _TUPLE_RE.findall(m.group(1)))
        mult = 2 if kind == "all-reduce" else 1
        out[kind] += mult * nbytes
        counts[kind] += 1
    out["total"] = sum(v for k, v in out.items())
    out["instruction_counts"] = counts
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, mode: str,
             overrides: dict | None = None, tag: str = "") -> dict:
    from ..dist.compat import cost_analysis, use_mesh
    from ..dist.mesh import make_production_mesh
    from .shapes import make_cell, cell_supported, SHAPES, Shape

    ok, reason = cell_supported(arch, shape_name)
    if not ok:
        return dict(arch=arch, shape=shape_name, mesh=mesh_kind, mode=mode,
                    skipped=True, reason=reason)

    overrides = dict(overrides or {})
    # Mesh refactorization lever (same 256 chips): {"mesh_data": 32,
    # "mesh_model": 8} etc. Consumed here, not by ModelConfig.
    data = overrides.pop("mesh_data", 16)
    model = overrides.pop("mesh_model", 256 // data)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"),
                                data=data, model=model)
    t0 = time.time()
    cell = make_cell(arch, shape_name, mesh, overrides)
    fn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                 donate_argnums=cell.donate_argnums)
    with use_mesh(mesh):  # with_sharding_constraint(P) binds here
        lowered = fn.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)
    result = dict(
        arch=arch, shape=shape_name, mesh=mesh_kind, mode=mode, tag=tag,
        skipped=False, overrides=overrides or {},
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            alias_bytes=getattr(mem, "alias_size_in_bytes", None),
        ),
        flops=cost.get("flops"),
        bytes_accessed=cost.get("bytes accessed"),
        utilization=cost.get("utilization", None),
    )
    if mode == "cost":
        result["collectives"] = collective_bytes(compiled.as_text())
    return result


PIPELINE_SHAPES = {
    "hour_256k": 1 << 18,
    "hour_1m": 1 << 20,
    "hour_16m": 1 << 24,
}


def make_pipeline_cell(n_events: int, mesh, *, alphabet: int = 1024,
                       max_len: int = 256, n_stages: int = 4,
                       capacity_factor: float = 2.0):
    """(fn, args, in_shardings) for the distributed log pipeline.

    Event columns are ShapeDtypeStructs sharded over the mesh ``data`` axis
    (the log mover's arbitrary partitioning); the funnel stage table is
    replicated. Lowering must run under ``jax.experimental.enable_x64`` —
    the columns are int64.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from ..data.distpipe import DistPipelineConfig, build_pipeline_fn

    n_shards = mesh.shape["data"]
    cfg = DistPipelineConfig(
        alphabet_size=alphabet,
        max_sessions_per_shard=-(-n_events // n_shards),
        max_len=max_len, capacity_factor=capacity_factor)
    fn = build_pipeline_fn(mesh, cfg, n_stages)
    sds = jax.ShapeDtypeStruct
    args = (sds((n_events,), np.int64), sds((n_events,), np.int64),
            sds((n_events,), np.int64), sds((n_events,), np.int32),
            sds((n_events,), np.int64), sds((n_events,), bool),
            sds((n_stages, alphabet), bool))
    col = NamedSharding(mesh, P("data"))
    rep = NamedSharding(mesh, P())
    return fn, args, (col,) * 6 + (rep,)


def run_pipeline_cell(shape_name: str, mesh_kind: str,
                      overrides: dict | None = None, tag: str = "") -> dict:
    """Lower + compile the distributed log pipeline on the production mesh
    and extract the same memory/cost/collective-bytes roofline inputs as the
    model cells. The pipeline has no while loops, so collective bytes from
    the optimized HLO are exact (the keyed all_to_all dominates)."""
    from jax.experimental import enable_x64
    from ..dist.compat import cost_analysis, use_mesh
    from ..dist.mesh import make_production_mesh

    overrides = dict(overrides or {})
    data = overrides.pop("mesh_data", 16)
    model = overrides.pop("mesh_model", 256 // data)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"),
                                data=data, model=model)
    n_events = PIPELINE_SHAPES[shape_name]
    t0 = time.time()
    fn, args, in_sh = make_pipeline_cell(n_events, mesh, **overrides)
    jitted = jax.jit(fn, in_shardings=in_sh)
    with enable_x64():
        with use_mesh(mesh):
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)
    return dict(
        arch="pipeline", shape=shape_name, mesh=mesh_kind, mode="cost",
        tag=tag, skipped=False, n_events=n_events,
        overrides=overrides or {},
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            alias_bytes=getattr(mem, "alias_size_in_bytes", None),
        ),
        flops=cost.get("flops"),
        bytes_accessed=cost.get("bytes accessed"),
        utilization=cost.get("utilization", None),
        collectives=collective_bytes(compiled.as_text()),
    )


STREAM_SHAPES = {
    "tick_64k": 1 << 16,
    "tick_256k": 1 << 18,
}


def make_stream_cell(tick_events: int, mesh, *, alphabet: int = 1024,
                     max_len: int = 256, max_open: int = 4096,
                     n_stages: int = 4, capacity_factor: float = 2.0):
    """(fn, args, in_shardings) for one streaming micro-batch tick.

    The ring state and event columns are ShapeDtypeStructs sharded over the
    mesh ``data`` axis; the two watermarks and the stage table are
    replicated. Like the batch pipeline cell, lowering runs under
    ``enable_x64`` (int64 ids/timestamps end-to-end).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from ..data.streampipe import (StreamConfig, build_stream_tick_fn,
                                   stream_state_structs)

    n_shards = mesh.shape["data"]
    cfg = StreamConfig(
        alphabet_size=alphabet, max_open=max_open, max_len=max_len,
        tick_capacity=tick_events, capacity_factor=capacity_factor)
    fn = build_stream_tick_fn(mesh, cfg, n_stages)
    sds = jax.ShapeDtypeStruct
    ring = stream_state_structs(cfg, n_shards)
    args = (ring,
            sds((tick_events,), np.int64), sds((tick_events,), np.int64),
            sds((tick_events,), np.int64), sds((tick_events,), np.int32),
            sds((tick_events,), np.int64), sds((tick_events,), bool),
            sds((), np.int64), sds((), np.int64),
            sds((n_stages, alphabet), bool))
    col = NamedSharding(mesh, P("data"))
    rep = NamedSharding(mesh, P())
    ring_sh = {k: col for k in ring}
    return fn, args, (ring_sh,) + (col,) * 6 + (rep,) * 3


def run_stream_cell(shape_name: str, mesh_kind: str,
                    overrides: dict | None = None, tag: str = "") -> dict:
    """Lower + compile one streaming tick on the production mesh; same
    roofline extraction as the batch pipeline cell. The tick's collectives
    are the keyed all_to_all repartition plus the rollup-delta psums."""
    from jax.experimental import enable_x64
    from ..dist.compat import cost_analysis, use_mesh
    from ..dist.mesh import make_production_mesh

    overrides = dict(overrides or {})
    data = overrides.pop("mesh_data", 16)
    model = overrides.pop("mesh_model", 256 // data)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"),
                                data=data, model=model)
    tick_events = STREAM_SHAPES[shape_name]
    t0 = time.time()
    fn, args, in_sh = make_stream_cell(tick_events, mesh, **overrides)
    jitted = jax.jit(fn, in_shardings=in_sh)
    with enable_x64():
        with use_mesh(mesh):
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)
    return dict(
        arch="stream", shape=shape_name, mesh=mesh_kind, mode="cost",
        tag=tag, skipped=False, tick_events=tick_events,
        overrides=overrides or {},
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            alias_bytes=getattr(mem, "alias_size_in_bytes", None),
        ),
        flops=cost.get("flops"),
        bytes_accessed=cost.get("bytes accessed"),
        utilization=cost.get("utilization", None),
        collectives=collective_bytes(compiled.as_text()),
    )


STORE_SHAPES = {
    "compact_256k": 1 << 18,
    "compact_1m": 1 << 20,
}


def make_store_cell(n_events: int, *, max_len: int = 256,
                    gap_ms: int = 30 * 60 * 1000):
    """(fn, args) for the segment store's compaction kernel
    (data/store.py): the fused sort + segment sessionizer over the closed
    events of the folded segments, at worst-case caps (every event its own
    session). No mesh — compaction runs on the host that owns the store;
    the cell exists for the memory roofline (the (max_sessions, max_len)
    scatter grid dominates) and the sort/segment FLOPs.
    """
    import functools
    from ..core.sessionize import _sessionize

    fn = functools.partial(_sessionize, gap_ms=gap_ms,
                           max_sessions=n_events, max_len=max_len)
    sds = jax.ShapeDtypeStruct
    args = (sds((n_events,), np.int64), sds((n_events,), np.int64),
            sds((n_events,), np.int64), sds((n_events,), np.int32),
            sds((n_events,), np.int64), sds((n_events,), bool))
    return fn, args


def run_store_cell(shape_name: str, mesh_kind: str,
                   overrides: dict | None = None, tag: str = "") -> dict:
    """Lower + compile the store compaction kernel; same roofline
    extraction as the other cells (collective bytes are zero — the pass is
    single-host by design, the segments were already user-sharded)."""
    from jax.experimental import enable_x64
    from ..dist.compat import cost_analysis

    n_events = STORE_SHAPES[shape_name]
    t0 = time.time()
    fn, args = make_store_cell(n_events, **(overrides or {}))
    jitted = jax.jit(fn)
    with enable_x64():
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = cost_analysis(compiled)
    return dict(
        arch="store", shape=shape_name, mesh=mesh_kind, mode="cost",
        tag=tag, skipped=False, n_events=n_events,
        overrides=overrides or {},
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            alias_bytes=getattr(mem, "alias_size_in_bytes", None),
        ),
        flops=cost.get("flops"),
        bytes_accessed=cost.get("bytes accessed"),
        utilization=cost.get("utilization", None),
        collectives=collective_bytes(compiled.as_text()),
    )


def result_path(arch, shape, mesh, mode, tag=""):
    name = f"{arch}__{shape}__{mesh}__{mode}{('__' + tag) if tag else ''}.json"
    return os.path.join(RESULTS_DIR, name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--mode", default="full", choices=["full", "cost"])
    ap.add_argument("--overrides", default="{}")
    ap.add_argument("--tag", default="")
    ap.add_argument("--pipeline", choices=sorted(PIPELINE_SHAPES),
                    help="lower+compile the distributed log pipeline at this "
                         "shape instead of a model cell")
    ap.add_argument("--stream", choices=sorted(STREAM_SHAPES),
                    help="lower+compile one streaming micro-batch tick "
                         "(data/streampipe.py) at this tick shape instead "
                         "of a model cell")
    ap.add_argument("--store", choices=sorted(STORE_SHAPES),
                    help="lower+compile the segment-store compaction "
                         "kernel (data/store.py) at this closed-event "
                         "count instead of a model cell")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)

    if args.pipeline or args.stream or args.store:
        if args.arch or args.shape or args.mode != "full" or args.all \
                or sum(map(bool, (args.pipeline, args.stream,
                                  args.store))) > 1:
            ap.error("--pipeline/--stream/--store are their own cell kinds; "
                     "they cannot be combined with each other or with "
                     "--arch/--shape/--mode/--all (collective bytes are "
                     "always extracted, i.e. cost mode)")
        kind = ("pipeline" if args.pipeline
                else "stream" if args.stream else "store")
        shape = args.pipeline or args.stream or args.store
        runner = {"pipeline": run_pipeline_cell, "stream": run_stream_cell,
                  "store": run_store_cell}[kind]
        try:
            res = runner(shape, args.mesh, json.loads(args.overrides),
                         args.tag)
        except Exception:
            res = dict(arch=kind, shape=shape, mesh=args.mesh,
                       mode="cost", tag=args.tag, error=True,
                       traceback=traceback.format_exc())
        path = result_path(kind, shape, args.mesh, "cost", args.tag)
        with open(path, "w") as f:
            json.dump(res, f, indent=2)
        if res.get("error"):
            print(res["traceback"], file=sys.stderr)
            sys.exit(1)
        print(json.dumps({k: v for k, v in res.items()
                          if k != "overrides"}, indent=2))
        return

    if args.all:
        from ..configs import ASSIGNED
        from .shapes import SHAPES
        cells = [(a, s, m) for a in ASSIGNED for s in SHAPES
                 for m in ("single", "multi")]
        failures = 0
        for arch, shape, mesh in cells:
            path = result_path(arch, shape, mesh, "full")
            if os.path.exists(path) and not args.force:
                print(f"[cached] {arch} {shape} {mesh}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh,
                   "--mode", "full"]
            print(f"[run] {arch} {shape} {mesh}", flush=True)
            r = subprocess.run(cmd, cwd=os.getcwd())
            failures += (r.returncode != 0)
        sys.exit(1 if failures else 0)

    try:
        res = run_cell(args.arch, args.shape, args.mesh, args.mode,
                       json.loads(args.overrides), args.tag)
    except Exception:
        res = dict(arch=args.arch, shape=args.shape, mesh=args.mesh,
                   mode=args.mode, tag=args.tag, error=True,
                   traceback=traceback.format_exc())
    path = result_path(args.arch, args.shape, args.mesh, args.mode, args.tag)
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    if res.get("error"):
        print(res["traceback"], file=sys.stderr)
        sys.exit(1)
    if res.get("skipped"):
        print(f"SKIP {args.arch} {args.shape}: {res['reason']}")
        return
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("overrides",)}, indent=2))


if __name__ == "__main__":
    main()
