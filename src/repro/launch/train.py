"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Builds the corpus (session sequences from the unified logging pipeline),
constructs the model on the requested mesh, and drives the fault-tolerant
Trainer (NaN guards, async checkpoints, deterministic resume). On this CPU
container use --smoke (reduced config); the same flags target a real pod.
"""
from __future__ import annotations

import argparse
import os

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="behavior-lm-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--users", type=int, default=800)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "ef_int8", "sign"])
    args = ap.parse_args()

    if args.data_axis * args.model_axis > 1:
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count="
            f"{args.data_axis * args.model_axis}")

    import jax
    from ..configs import full_config, smoke_config
    from ..core import EventDictionary, SessionSequences, sessionize
    from ..data import (generate, LogGenConfig, SessionBatchPipeline,
                        PipelineConfig, lm_vocab_size)
    from ..dist.compat import use_mesh
    from ..dist.mesh import make_host_mesh
    from ..dist.sharding import ShardingRules, adapt_rules_for_mesh
    from ..models import get_model
    from ..train import OptConfig, Trainer, TrainerConfig

    log = generate(LogGenConfig(n_users=args.users, seed=0))
    b = log.batch
    d = EventDictionary.build(b.table, b.name_id)
    codes = np.asarray(d.encode_ids(b.name_id))
    s = sessionize(b.user_id, b.session_id, b.timestamp, codes,
                   b.ip.astype(np.int64), max_sessions=len(b), max_len=2048)
    seqs = SessionSequences.from_sessionized(s)
    vocab = lm_vocab_size(d.alphabet_size)
    print(f"corpus: {len(seqs)} sessions, lm vocab {vocab}")

    cfg = (smoke_config(args.arch) if args.smoke else full_config(args.arch))
    cfg = cfg.with_(vocab_size=max(vocab, 16))
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit(f"{args.arch}: modality frontends are stubbed — "
                         f"train via tests/benchmarks, not this LM driver")

    mesh = rules = None
    if args.data_axis * args.model_axis > 1:
        mesh = make_host_mesh(data=args.data_axis, model=args.model_axis)
        rules = adapt_rules_for_mesh(ShardingRules(batch=("data",)), mesh)
        api = get_model(cfg, mesh, rules)
    else:
        api = get_model(cfg)

    pipe = SessionBatchPipeline(seqs, PipelineConfig(
        seq_len=args.seq_len, global_batch=args.global_batch))
    tr = Trainer(api,
                 OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                           total_steps=args.steps,
                           compression=args.compression),
                 TrainerConfig(total_steps=args.steps,
                               checkpoint_every=max(args.steps // 4, 1),
                               log_every=10, checkpoint_dir=args.ckpt),
                 log_fn=lambda st, m: print(
                     f"step {st:5d} loss={m['loss']:.4f} "
                     f"gnorm={m['grad_norm']:.2f} lr={m['lr']:.2e} "
                     f"{m['steps_per_s']:.2f} steps/s", flush=True))

    if mesh is not None:
        with use_mesh(mesh):
            out = tr.run(pipe)
    else:
        out = tr.run(pipe)
    print("final:", out["history"][-1])


if __name__ == "__main__":
    main()
