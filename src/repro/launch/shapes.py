"""Cell definitions: (architecture x input shape) -> lowerable function,
ShapeDtypeStruct arguments, and sharding trees.

``input_specs(cfg, shape, rules)`` returns weak-type-correct, shardable
ShapeDtypeStruct stand-ins for every model input — no device allocation.
``make_cell`` assembles the jit-able callable for the dry-run:

  train_4k     -> full train_step (fwd + bwd + AdamW) over packed tokens
  prefill_32k  -> prefill (prompt -> KV cache / SSM state + last logits)
  decode_32k   -> serve_step: ONE new token against a seq_len KV cache
  long_500k    -> serve_step at 524288 context (SSM/hybrid only)
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import full_config
from ..dist.sharding import ShardingRules, arch_rules, tree_spec, \
    adapt_rules_for_mesh
from ..models import layers as L
from ..models import mamba2 as MB
from ..models import hybrid as HY
from ..models import vision as VI
from ..models.config import ModelConfig
from ..models.registry import get_model
from ..train.optimizer import OptConfig
from ..train.train_loop import make_train_step
from ..train.elastic import state_axes


@dataclass(frozen=True)
class Shape:
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train", 4_096, 256),
    "prefill_32k": Shape("prefill", 32_768, 32),
    "decode_32k": Shape("decode", 32_768, 128),
    "long_500k": Shape("decode", 524_288, 1),
}

# long_500k requires sub-quadratic attention state: only the SSM and hybrid
# archs run it (skip documented in DESIGN.md §6).
LONG_CTX_ARCHS = ("mamba2-370m", "zamba2-7b")


def cell_supported(arch: str, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and arch not in LONG_CTX_ARCHS:
        return False, ("pure full-attention arch: a 500k dense KV decode "
                       "cache is memory-infeasible without sub-quadratic "
                       "attention (DESIGN.md §6)")
    return True, ""


def cell_config(arch: str, shape_name: str, overrides: dict | None = None
                ) -> ModelConfig:
    cfg = full_config(arch)
    shape = SHAPES[shape_name]
    kw: dict[str, Any] = dict(attn_impl="blocked")
    if shape.kind in ("prefill", "decode"):
        kw.update(max_cache_len=shape.seq_len, remat="none", microbatches=1)
    if overrides:
        kw.update(overrides)
    return cfg.with_(**kw)


def rules_for_cell(cfg: ModelConfig, shape: Shape, mesh: Mesh,
                   base: ShardingRules = ShardingRules()) -> ShardingRules:
    ssm = cfg.family in ("ssm", "hybrid")
    rules = arch_rules(base, mesh, family=cfg.family,
                       num_heads=cfg.num_heads,
                       num_kv_heads=cfg.num_kv_heads, d_ff=cfg.d_ff,
                       vocab=cfg.vocab_size, num_experts=cfg.num_experts,
                       ssm_nheads=cfg.ssm_nheads if ssm else 0,
                       d_inner=cfg.d_inner if ssm else 0)
    if shape.kind in ("prefill", "decode"):
        if rules.cache_seq is None and rules.kv_heads is None:
            rules = replace(rules, cache_seq="model")
    if shape.global_batch == 1:
        # batch unshardable; put the idle data axis on the cache seq dim
        cache_seq = ("data",) if rules.cache_seq is None else ("data", "model")
        rules = replace(rules, batch=None, cache_seq=cache_seq)
    else:
        # batch must divide the dp axes product
        dp = rules.batch if isinstance(rules.batch, tuple) else (rules.batch,)
        n = int(np.prod([mesh.shape[a] for a in dp if a]))
        if shape.global_batch % max(n, 1):
            rules = replace(rules, batch="data")
    if cfg.seq_parallel:
        rules = replace(rules, seq="model")
    return adapt_rules_for_mesh(rules, mesh)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct builders
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_structs(cfg: ModelConfig, shape: Shape) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = dict(tokens=_sds((b, s), jnp.int32),
               targets=_sds((b, s), jnp.int32),
               loss_mask=_sds((b, s), jnp.float32))
    if cfg.family == "encdec":
        out["frames"] = _sds((b, cfg.n_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        out["patches"] = _sds((b, cfg.n_patches, cfg.vision_dim),
                              jnp.dtype(cfg.dtype))
    return out


def batch_axes(cfg: ModelConfig) -> dict:
    out = dict(tokens=("batch", None), targets=("batch", None),
               loss_mask=("batch", None))
    if cfg.family == "encdec":
        out["frames"] = ("batch", None, None)
    if cfg.family == "vlm":
        out["patches"] = ("batch", None, None)
    return out


def decode_state_structs(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family in ("dense", "moe"):
        return jax.eval_shape(lambda: L.init_kv_cache(cfg, batch, max_len))
    if cfg.family == "ssm":
        return jax.eval_shape(lambda: MB.init_mamba_state(cfg, batch))
    if cfg.family == "hybrid":
        return jax.eval_shape(lambda: HY.init_state(cfg, batch, max_len))
    if cfg.family == "encdec":
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        return dict(
            kv=jax.eval_shape(lambda: L.init_kv_cache(cfg, batch, max_len)),
            cross_kv=dict(
                k=_sds((cfg.num_layers, batch, kv, cfg.n_frames, hd),
                       jnp.dtype(cfg.dtype)),
                v=_sds((cfg.num_layers, batch, kv, cfg.n_frames, hd),
                       jnp.dtype(cfg.dtype))))
    if cfg.family == "vlm":
        base = jax.eval_shape(lambda: VI.init_cache(cfg, batch, max_len))
        ce = cfg.cross_attn_every
        n_groups = cfg.num_layers // ce
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        base["cross"] = dict(
            k=_sds((n_groups, batch, kv, cfg.n_patches, hd),
                   jnp.dtype(cfg.dtype)),
            v=_sds((n_groups, batch, kv, cfg.n_patches, hd),
                   jnp.dtype(cfg.dtype)))
        return base
    raise ValueError(cfg.family)


_KV_AXES = dict(k=("layers", "batch", "kv_heads", "cache_seq", "head_dim"),
                v=("layers", "batch", "kv_heads", "cache_seq", "head_dim"))
_CROSS_AXES = dict(k=("layers", "batch", "kv_heads", "frames", "head_dim"),
                   v=("layers", "batch", "kv_heads", "frames", "head_dim"))


def decode_state_axes(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        return dict(_KV_AXES)
    if cfg.family == "ssm":
        return MB.mamba_state_axes()
    if cfg.family == "hybrid":
        return dict(mamba=MB.mamba_state_axes(), kv=dict(_KV_AXES))
    if cfg.family == "encdec":
        return dict(kv=dict(_KV_AXES), cross_kv=dict(_CROSS_AXES))
    if cfg.family == "vlm":
        six = ("layers", None, "batch", "kv_heads", "cache_seq", "head_dim")
        return dict(self_k=six, self_v=six,
                    tail_k=_KV_AXES["k"], tail_v=_KV_AXES["v"],
                    cross=dict(_CROSS_AXES))
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Cell assembly
# ---------------------------------------------------------------------------

@dataclass
class Cell:
    arch: str
    shape_name: str
    cfg: ModelConfig
    rules: ShardingRules
    fn: Any
    args: tuple
    in_shardings: Any
    donate_argnums: tuple


def _shard(tree_ax, mesh, rules):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_spec(tree_ax, rules))


def make_cell(arch: str, shape_name: str, mesh: Mesh,
              overrides: dict | None = None,
              base_rules: ShardingRules = ShardingRules(),
              shape_override: Shape | None = None) -> Cell:
    shape = shape_override or SHAPES[shape_name]
    cfg = cell_config(arch, shape_name, overrides)
    rules = rules_for_cell(cfg, shape, mesh, base_rules)
    api = get_model(cfg, mesh, rules)

    params_struct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    p_axes = api.axes()
    p_sh = _shard(p_axes, mesh, rules)

    if shape.kind == "train":
        opt_cfg = OptConfig()
        step = make_train_step(api, opt_cfg)
        state_struct = dict(
            params=params_struct,
            opt=dict(mu=params_struct, nu=params_struct,
                     step=_sds((), jnp.int32), skipped=_sds((), jnp.int32)))
        st_axes = state_axes(api)
        st_sh = _shard(st_axes, mesh, rules)
        b_struct = batch_structs(cfg, shape)
        b_sh = _shard(batch_axes(cfg), mesh, rules)
        return Cell(arch, shape_name, cfg, rules, step,
                    (state_struct, b_struct), (st_sh, b_sh), (0,))

    if shape.kind == "prefill":
        b_struct = batch_structs(cfg, shape)
        b_struct.pop("targets"), b_struct.pop("loss_mask")
        bax = batch_axes(cfg)
        bax.pop("targets"), bax.pop("loss_mask")
        b_sh = _shard(bax, mesh, rules)
        fn = lambda p, b: api.prefill(p, b)
        return Cell(arch, shape_name, cfg, rules, fn,
                    (params_struct, b_struct), (p_sh, b_sh), ())

    # decode
    b = shape.global_batch
    state_struct = decode_state_structs(cfg, b, shape.seq_len)
    st_sh = _shard(decode_state_axes(cfg), mesh, rules)
    tok_struct = _sds((b,), jnp.int32)
    tok_sh = NamedSharding(mesh, rules.spec("batch"))
    idx_struct = _sds((), jnp.int32)
    fn = lambda p, tok, st, i: api.decode_step(p, tok, st, i)
    return Cell(arch, shape_name, cfg, rules, fn,
                (params_struct, tok_struct, state_struct, idx_struct),
                (p_sh, tok_sh, st_sh, None), (2,))
