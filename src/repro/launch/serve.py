"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Restores the newest checkpoint (if any) and serves batched next-event
predictions over session prefixes drawn from the live pipeline. The
default (and ``--continuous``) path serves the prefixes as an open-ended
request stream (variable prompt lengths, > 3x the slot count) through the
continuous-batching scheduler — **every registry family**, including
ssm/hybrid (recurrent rows) and encdec/vlm (per-request frames/patches
extras) — and prints the latency/throughput summary afterwards.

``--batch`` opts into the fixed-batch ``Server.generate_batch`` oracle
path explicitly (one lockstep rectangle, no admission/eviction) — the
silent family downgrade it used to hide is gone; unknown families now
fail loudly at scheduler construction.

``--replicas N`` scales the continuous path out to a serving fleet: N
independent scheduler replicas behind a ``ReplicaRouter``
(``serve/fleet.py``), with ``--route {rr,jsq,affinity}`` selecting
round-robin, join-shortest-queue over occupancy gossip, or
prefix-affinity (requires ``--paged --prefix-cache``) routing. The
summary adds the fleet rollup: per-replica routed/admitted counts and
the load-imbalance stat.
"""
from __future__ import annotations

import argparse

import numpy as np


def _decode_names(tokens, d, num_specials: int):
    """Token ids -> event names. vocab may be padded past the dictionary
    alphabet (``max(vocab, 16)``), so clamp instead of raising."""
    names = []
    for t in tokens:
        t = int(t)
        if t < num_specials:
            names.append("<s>")
        elif t - num_specials < d.alphabet_size:
            names.append(d.name_of(t - num_specials))
        else:
            names.append("<unk>")
    return names


def _request_extras(cfg, rng):
    """Per-request encoder inputs for the stubbed frontends (the live
    pipeline carries tokens only): random frame/patch embeddings."""
    if cfg.family == "encdec":
        return dict(frames=rng.standard_normal(
            (cfg.n_frames, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        return dict(patches=rng.standard_normal(
            (cfg.n_patches, cfg.vision_dim)).astype(np.float32))
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="behavior-lm-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--slots", type=int, default=4,
                    help="slot-table rows (continuous) / rectangle rows "
                         "(--batch)")
    ap.add_argument("--continuous", action="store_true",
                    help="serve a request stream through the "
                         "continuous-batching scheduler (the default; "
                         "kept as an explicit flag)")
    ap.add_argument("--batch", action="store_true",
                    help="opt into the fixed-batch Server.generate_batch "
                         "oracle path instead of the scheduler")
    ap.add_argument("--requests", type=int, default=0,
                    help="stream size for the continuous path "
                         "(default 3x slots)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache (fixed-size blocks shared across "
                         "slots; caps.paged families)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block for --paged")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="session-prefix caching on top of --paged: "
                         "prompts whose leading blocks are already "
                         "resident share them copy-free (refcounted) and "
                         "prefill only the divergent tail")
    ap.add_argument("--overcommit", type=float, default=1.0,
                    help="optimistic admission factor on top of --paged: "
                         "reserve up to this multiple of pool capacity; "
                         "actual exhaustion mid-decode preempts the "
                         "lowest-priority request (1.0 = honest "
                         "worst-case reservation, the default)")
    ap.add_argument("--priority", type=int, default=1,
                    help="number of priority classes: requests are "
                         "assigned a seeded random class in [0, N); "
                         "higher classes admit first and are preempted "
                         "last (1 = everything priority 0)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve through a fleet of N independent "
                         "scheduler replicas (each its own slab/prefix "
                         "registry) behind a ReplicaRouter; 1 = the "
                         "single-scheduler path")
    ap.add_argument("--route", choices=("rr", "jsq", "affinity"),
                    default="jsq",
                    help="fleet routing policy for --replicas > 1: "
                         "round-robin, join-shortest-queue on occupancy "
                         "gossip, or prefix-affinity with JSQ spill "
                         "(affinity requires --paged --prefix-cache)")
    args = ap.parse_args()
    if args.prefix_cache and not args.paged:
        ap.error("--prefix-cache requires --paged (it shares blocks of "
                 "the paged KV pool)")
    if args.overcommit > 1.0 and not args.paged:
        ap.error("--overcommit > 1.0 requires --paged (only the block "
                 "pool can preempt on exhaustion)")
    if args.overcommit < 1.0:
        ap.error("--overcommit must be >= 1.0")
    if args.priority < 1:
        ap.error("--priority must be >= 1 class")
    if args.batch and args.continuous:
        ap.error("--batch and --continuous are mutually exclusive")
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if args.replicas > 1 and args.batch:
        ap.error("--replicas needs the continuous path (the fleet routes "
                 "an open request stream, not one rectangle)")
    if args.replicas > 1 and args.route == "affinity" \
            and not (args.paged and args.prefix_cache):
        ap.error("--route affinity requires --paged --prefix-cache (it "
                 "scores replicas by resident prefix chains)")

    import jax
    from ..configs import full_config, smoke_config
    from ..core import EventDictionary, SessionSequences, sessionize
    from ..data import (generate, LogGenConfig, SessionBatchPipeline,
                        PipelineConfig, lm_vocab_size, NUM_SPECIALS)
    from ..models import get_model
    from ..train import CheckpointManager, OptConfig, init_opt_state
    from ..serve import (Server, ServeConfig, ContinuousScheduler,
                         SchedulerConfig, ServeMetrics, prompt_lengths,
                         ReplicaRouter, FleetConfig)

    log = generate(LogGenConfig(n_users=400, seed=0))
    b = log.batch
    d = EventDictionary.build(b.table, b.name_id)
    codes = np.asarray(d.encode_ids(b.name_id))
    s = sessionize(b.user_id, b.session_id, b.timestamp, codes,
                   b.ip.astype(np.int64), max_sessions=len(b), max_len=2048)
    seqs = SessionSequences.from_sessionized(s)
    vocab = lm_vocab_size(d.alphabet_size)

    cfg = (smoke_config(args.arch) if args.smoke else full_config(args.arch))
    cfg = cfg.with_(vocab_size=max(vocab, 16), max_cache_len=256)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    mgr = CheckpointManager(args.ckpt)
    if mgr.latest_step() is not None:
        state = dict(params=params,
                     opt=init_opt_state(params, OptConfig()))
        state = mgr.restore(state)
        params = jax.tree.map(jax.numpy.asarray, state["params"])
        print(f"restored checkpoint step {mgr.latest_step()}")
    else:
        print("no checkpoint found — serving untrained weights")

    slots = max(args.slots, 1)
    pipe = SessionBatchPipeline(seqs, PipelineConfig(
        seq_len=64, global_batch=slots))
    rng = np.random.default_rng(0)

    if args.batch:
        prompts = pipe.batch_at(0, 0)["tokens"][:slots, :32]
        extra = _request_extras(cfg, rng)
        if extra is not None:
            extra = {k: np.stack([v] * prompts.shape[0])
                     for k, v in extra.items()}
        srv = Server(api, params, ServeConfig(
            max_new_tokens=args.max_new_tokens,
            temperature=args.temperature))
        gen = srv.generate_batch(prompts, extra)
        for i in range(prompts.shape[0]):
            names = _decode_names(gen[i], d, NUM_SPECIALS)
            print(f"request {i}: " + " -> ".join(n.split(":")[-1]
                                                 for n in names))
        return

    # continuous (default): every family serves through the scheduler;
    # an unknown family raises at construction instead of downgrading.
    # --replicas > 1 serves the same stream through a fleet of
    # independent replicas behind the ReplicaRouter (same surface).
    n_req = args.requests or 3 * slots * args.replicas
    scfg = SchedulerConfig(
        batch=slots, buckets=(16, 32, 64),
        max_new_tokens=args.max_new_tokens,
        temperature=args.temperature, paged=args.paged,
        block_size=args.block_size,
        prefix_cache=args.prefix_cache,
        overcommit=args.overcommit)
    if args.replicas > 1:
        sched = ReplicaRouter(api, params, scfg, FleetConfig(
            replicas=args.replicas, route=args.route))
    else:
        metrics = ServeMetrics()
        sched = ContinuousScheduler(api, params, scfg, metrics=metrics)
    # over-commit caps the prompt so a preempted request's re-prefill
    # (prompt + generated) always fits the largest compiled bucket
    max_prompt = 33 if args.overcommit <= 1.0 else \
        max(4, 64 - args.max_new_tokens + 1)
    rids = []
    for i in range(n_req):
        row = pipe.batch_at(0, i % slots)["tokens"]
        row = np.asarray(row[i % row.shape[0]])
        n = int(rng.integers(4, min(33, max_prompt + 1)))
        n = min(n, int(prompt_lengths(row[None])[0]))  # stay on real toks
        rids.append(sched.submit(row[:n], extra=_request_extras(cfg, rng),
                                 priority=int(rng.integers(args.priority))))
    outs = sched.run()
    for rid in rids[:slots]:
        names = _decode_names(outs[rid], d, NUM_SPECIALS)
        print(f"request {rid}: "
              + " -> ".join(n.split(":")[-1] for n in names))
    summ = sched.summary() if args.replicas > 1 else metrics.summary()
    print("served {requests} requests, {tokens} tokens, "
          "{tokens_per_sec:.1f} tok/s, p50 latency {p50_latency_s:.3f}s,"
          " p99 {p99_latency_s:.3f}s".format(**summ))
    print("queue wait p50 {p50_queue_wait_s:.4f}s / p99 "
          "{p99_queue_wait_s:.4f}s, admitted TTFT p50 "
          "{p50_ttft_admit_s:.4f}s".format(**summ))
    if args.overcommit > 1.0 or args.priority > 1:
        print(f"over-commit {args.overcommit}x: "
              f"{summ['preemptions']} preemption(s)")
        for prio, ps in sorted(summ["per_priority"].items(), reverse=True):
            print("  class {p}: {requests} requests, {n} preemption(s), "
                  "p99 latency {p99_latency_s:.3f}s, p99 queue wait "
                  "{p99_queue_wait_s:.4f}s".format(
                      p=prio, n=ps["preemptions"], **ps))
    if summ["kv_total_blocks"]:
        print("decode state: peak {kv_live_blocks_peak}/{kv_total_blocks} "
              "{unit} live ({kv_util_peak:.0%}), peak resident "
              "{kv_peak_resident_bytes} bytes".format(
                  unit="blocks" if args.paged else "rows", **summ))
    if args.prefix_cache:
        print("prefix cache: {prefix_hit_rate:.0%} hit rate, "
              "{prefix_blocks_reused} blocks reused, "
              "{prefill_tokens_skipped} prefill tokens skipped, "
              "mean TTFT hit {mean_ttft_hit_s:.4f}s vs miss "
              "{mean_ttft_miss_s:.4f}s".format(**summ))
    if args.replicas > 1:
        f = summ["fleet"]
        print("fleet: {n} replicas, route={route}, routed {routed}, "
              "admitted {adm}, load imbalance {imb:.2f} "
              "(max/mean admitted), {ticks} gossip ticks".format(
                  n=f["replicas"], route=f["route"],
                  routed=f["routed_per_replica"],
                  adm=f["admitted_per_replica"],
                  imb=f["load_imbalance"], ticks=f["gossip_ticks"]))
        for ri, rep in enumerate(sched.replicas):
            print(f"  replica {ri}: jit traces {dict(rep.trace_counts)} "
                  f"(prefills={rep.prefills}, "
                  f"decode_steps={rep.decode_steps})")
    else:
        print(f"jit traces: {dict(sched.trace_counts)} "
              f"(prefills={sched.prefills}, decode_steps="
              f"{sched.decode_steps})")


if __name__ == "__main__":
    main()
