"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Restores the newest checkpoint (if any) and serves batched next-event
predictions over session prefixes drawn from the live pipeline. With
``--continuous`` the prefixes are served as an open-ended request stream
(variable prompt lengths, > 3x the slot count) through the
continuous-batching scheduler, and the latency/throughput summary is
printed afterwards.
"""
from __future__ import annotations

import argparse

import numpy as np


def _decode_names(tokens, d, num_specials: int):
    """Token ids -> event names. vocab may be padded past the dictionary
    alphabet (``max(vocab, 16)``), so clamp instead of raising."""
    names = []
    for t in tokens:
        t = int(t)
        if t < num_specials:
            names.append("<s>")
        elif t - num_specials < d.alphabet_size:
            names.append(d.name_of(t - num_specials))
        else:
            names.append("<unk>")
    return names


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="behavior-lm-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--continuous", action="store_true",
                    help="serve a request stream through the "
                         "continuous-batching scheduler")
    ap.add_argument("--requests", type=int, default=0,
                    help="stream size for --continuous (default 3x batch)")
    ap.add_argument("--paged", action="store_true",
                    help="with --continuous: paged KV cache (fixed-size "
                         "blocks shared across slots)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block for --paged")
    args = ap.parse_args()

    import jax
    from ..configs import full_config, smoke_config
    from ..core import EventDictionary, SessionSequences, sessionize
    from ..data import (generate, LogGenConfig, SessionBatchPipeline,
                        PipelineConfig, lm_vocab_size, NUM_SPECIALS)
    from ..models import get_model
    from ..train import CheckpointManager, OptConfig, init_opt_state
    from ..serve import (Server, ServeConfig, ContinuousScheduler,
                         SchedulerConfig, ServeMetrics, prompt_lengths)

    log = generate(LogGenConfig(n_users=400, seed=0))
    b = log.batch
    d = EventDictionary.build(b.table, b.name_id)
    codes = np.asarray(d.encode_ids(b.name_id))
    s = sessionize(b.user_id, b.session_id, b.timestamp, codes,
                   b.ip.astype(np.int64), max_sessions=len(b), max_len=2048)
    seqs = SessionSequences.from_sessionized(s)
    vocab = lm_vocab_size(d.alphabet_size)

    cfg = (smoke_config(args.arch) if args.smoke else full_config(args.arch))
    cfg = cfg.with_(vocab_size=max(vocab, 16), max_cache_len=256)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    mgr = CheckpointManager(args.ckpt)
    if mgr.latest_step() is not None:
        state = dict(params=params,
                     opt=init_opt_state(params, OptConfig()))
        state = mgr.restore(state)
        params = jax.tree.map(jax.numpy.asarray, state["params"])
        print(f"restored checkpoint step {mgr.latest_step()}")
    else:
        print("no checkpoint found — serving untrained weights")

    pipe = SessionBatchPipeline(seqs, PipelineConfig(
        seq_len=64, global_batch=max(args.batch, 1)))

    if args.continuous and cfg.family in \
            ContinuousScheduler.SUPPORTED_FAMILIES:
        n_req = args.requests or 3 * args.batch
        metrics = ServeMetrics()
        sched = ContinuousScheduler(api, params, SchedulerConfig(
            batch=args.batch, buckets=(16, 32, 64),
            max_new_tokens=args.max_new_tokens,
            temperature=args.temperature, paged=args.paged,
            block_size=args.block_size), metrics=metrics)
        rng = np.random.default_rng(0)
        rids = []
        for i in range(n_req):
            row = pipe.batch_at(0, i % max(args.batch, 1))["tokens"]
            row = np.asarray(row[i % row.shape[0]])
            n = int(rng.integers(4, 33))        # variable prompt lengths
            n = min(n, int(prompt_lengths(row[None])[0]))  # stay on real toks
            rids.append(sched.submit(row[:n]))
        outs = sched.run()
        for rid in rids[: args.batch]:
            names = _decode_names(outs[rid], d, NUM_SPECIALS)
            print(f"request {rid}: "
                  + " -> ".join(n.split(":")[-1] for n in names))
        summ = metrics.summary()
        print("served {requests} requests, {tokens} tokens, "
              "{tokens_per_sec:.1f} tok/s, p50 latency {p50_latency_s:.3f}s,"
              " p99 {p99_latency_s:.3f}s".format(**summ))
        if summ["kv_total_blocks"]:
            print("kv slab: peak {kv_live_blocks_peak}/{kv_total_blocks} "
                  "blocks live ({kv_util_peak:.0%}), peak resident "
                  "{kv_peak_resident_bytes} bytes".format(**summ))
        print(f"jit traces: {dict(sched.trace_counts)} "
              f"(prefills={sched.prefills}, decode_steps="
              f"{sched.decode_steps})")
        return

    if args.continuous:
        print(f"family {cfg.family!r} is not continuous-batchable; "
              "falling back to the fixed-batch server")
    prompts = pipe.batch_at(0, 0)["tokens"][: args.batch, :32]
    srv = Server(api, params, ServeConfig(
        max_new_tokens=args.max_new_tokens, temperature=args.temperature,
        paged=args.paged, block_size=args.block_size))
    gen = srv.generate(prompts)
    for i in range(args.batch):
        names = _decode_names(gen[i], d, NUM_SPECIALS)
        print(f"request {i}: " + " -> ".join(n.split(":")[-1]
                                             for n in names))


if __name__ == "__main__":
    main()
