"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Restores the newest checkpoint (if any) and serves batched next-event
predictions over session prefixes drawn from the live pipeline.
"""
from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="behavior-lm-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    import jax
    from ..configs import full_config, smoke_config
    from ..core import EventDictionary, SessionSequences, sessionize
    from ..data import (generate, LogGenConfig, SessionBatchPipeline,
                        PipelineConfig, lm_vocab_size, NUM_SPECIALS)
    from ..models import get_model
    from ..train import CheckpointManager, OptConfig, init_opt_state
    from ..serve import Server, ServeConfig

    log = generate(LogGenConfig(n_users=400, seed=0))
    b = log.batch
    d = EventDictionary.build(b.table, b.name_id)
    codes = np.asarray(d.encode_ids(b.name_id))
    s = sessionize(b.user_id, b.session_id, b.timestamp, codes,
                   b.ip.astype(np.int64), max_sessions=len(b), max_len=2048)
    seqs = SessionSequences.from_sessionized(s)
    vocab = lm_vocab_size(d.alphabet_size)

    cfg = (smoke_config(args.arch) if args.smoke else full_config(args.arch))
    cfg = cfg.with_(vocab_size=max(vocab, 16), max_cache_len=256)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    mgr = CheckpointManager(args.ckpt)
    if mgr.latest_step() is not None:
        state = dict(params=params,
                     opt=init_opt_state(params, OptConfig()))
        state = mgr.restore(state)
        params = jax.tree.map(jax.numpy.asarray, state["params"])
        print(f"restored checkpoint step {mgr.latest_step()}")
    else:
        print("no checkpoint found — serving untrained weights")

    pipe = SessionBatchPipeline(seqs, PipelineConfig(
        seq_len=64, global_batch=max(args.batch, 1)))
    prompts = pipe.batch_at(0, 0)["tokens"][: args.batch, :32]
    srv = Server(api, params, ServeConfig(
        max_new_tokens=args.max_new_tokens, temperature=args.temperature))
    gen = srv.generate(prompts)
    for i in range(args.batch):
        names = [d.name_of(t - NUM_SPECIALS) if t >= NUM_SPECIALS else "<s>"
                 for t in gen[i]]
        print(f"request {i}: " + " -> ".join(n.split(":")[-1]
                                             for n in names))


if __name__ == "__main__":
    main()
