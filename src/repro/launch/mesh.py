"""Back-compat shim: mesh construction moved to :mod:`repro.dist.mesh`
(the unified distribution layer). Re-exports the old public names; new
code should import from ``repro.dist``.

Per-arch mesh refactorizations (e.g. (32, 8) for qwen2, (64, 4) for narrow
models) remain §Perf levers — see ROADMAP "Open items".
"""
from ..dist.mesh import make_production_mesh, make_host_mesh

__all__ = ["make_production_mesh", "make_host_mesh"]
