"""Dense decoder-only transformer LM (stablelm / qwen2 / llama3 / qwen3).

Layers are a ``lax.scan`` over stacked parameters (HLO size O(1) in depth —
mandatory for 80-layer x 512-device lowering) with configurable remat.
The same forward serves training (full seq), prefill (seq -> cache) and
decode (1 token + cache).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from ..dist.sharding import ShardingRules, constrain


def init_block(key, cfg: ModelConfig):
    from . import moe as MoE
    k1, k2 = jax.random.split(key)
    ffn = (MoE.moe_init(k2, cfg) if cfg.num_experts > 0
           else L.mlp_init(k2, cfg))
    return dict(
        ln1=L.norm_init(cfg), attn=L.attn_init(k1, cfg),
        ln2=L.norm_init(cfg), mlp=ffn,
    )


def block_axes(cfg: ModelConfig):
    from . import moe as MoE
    ffn = MoE.moe_axes(cfg) if cfg.num_experts > 0 else L.mlp_axes()
    return dict(ln1=L.norm_axes(cfg), attn=L.attn_axes(cfg),
                ln2=L.norm_axes(cfg), mlp=ffn)


def _stack_axes(axes_tree, n_layers_axis="layers"):
    return jax.tree.map(
        lambda axes: (n_layers_axis,) + axes,
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, str) or a is None for a in x))


def init_params(key, cfg: ModelConfig):
    kE, kH, kL = jax.random.split(key, 3)
    lkeys = jax.random.split(kL, cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(lkeys)
    p = dict(
        embed=L.embed_init(kE, cfg),
        blocks=blocks,
        ln_f=L.norm_init(cfg),
    )
    if not cfg.tie_embeddings:
        p["unembed"] = L.embed_init(kH, cfg)
    return p


def param_axes(cfg: ModelConfig):
    a = dict(
        embed=L.embed_axes(),
        blocks=_stack_axes(block_axes(cfg)),
        ln_f=L.norm_axes(cfg),
    )
    if not cfg.tie_embeddings:
        a["unembed"] = L.embed_axes()
    return a


def _apply_ffn(x, mp, cfg: ModelConfig, rules: ShardingRules, mesh):
    if cfg.num_experts > 0:
        from . import moe as MoE
        if mesh is not None and rules.expert is not None:
            y, _ = MoE.moe_ffn_ep(x, mp, cfg, rules, mesh)
        else:
            y, _ = MoE.moe_ffn_dense(x, mp, cfg, rules)
        return y
    return L.apply_mlp(x, mp, cfg, rules)


def _apply_block(x, bp, cfg: ModelConfig, rules: ShardingRules, *,
                 positions, cache=None, cache_index=None, mesh=None):
    h, new_cache = L.apply_attention(
        L.apply_norm(x, bp["ln1"], cfg), bp["attn"], cfg, rules,
        positions=positions, causal=True, cache=cache,
        cache_index=cache_index)
    if cfg.parallel_residual:
        m = _apply_ffn(L.apply_norm(x, bp["ln1"], cfg), bp["mlp"], cfg,
                       rules, mesh)
        x = x + h + m
    else:
        x = x + h
        x = x + _apply_ffn(L.apply_norm(x, bp["ln2"], cfg), bp["mlp"], cfg,
                           rules, mesh)
    x = constrain(x, rules, "batch", "seq", "act_embed")
    return x, new_cache


def forward(params, tokens, cfg: ModelConfig, rules: ShardingRules, *,
            positions=None, cache=None, cache_index=None, mesh=None):
    """Returns (hidden (B,S,D), new_cache or None). ``cache`` is the stacked
    (layers-leading) dict from layers.init_kv_cache."""
    x = L.apply_embed(tokens, params["embed"], cfg, rules)
    if positions is None:
        s = tokens.shape[1]
        base = 0 if cache_index is None else cache_index
        positions = L.decode_positions(base, s)

    if cache is None:
        def body(carry, bp):
            y, _ = _apply_block(carry, bp, cfg, rules, positions=positions,
                                mesh=mesh)
            return y, None
        body = L.maybe_remat(body, cfg)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, params["blocks"])
        else:
            for i in range(cfg.num_layers):
                bp = jax.tree.map(lambda t: t[i], params["blocks"])
                x, _ = body(x, bp)
        new_cache = None
    else:
        # paged layout: the (B, max_blocks) block table is shared by every
        # layer, so it rides the scan as a closure capture, not a scanned leaf
        table = cache.get("table")

        def body(carry, inp):
            bp, ck, cv = inp
            layer_cache = dict(k=ck, v=cv)
            if table is not None:
                layer_cache["table"] = table
            y, nc = _apply_block(carry, bp, cfg, rules, positions=positions,
                                 cache=layer_cache,
                                 cache_index=cache_index, mesh=mesh)
            return y, (nc["k"], nc["v"])
        x, (nk, nv) = L.scan_or_unroll(body, x, (params["blocks"],
                                                 cache["k"], cache["v"]),
                                       cfg.scan_layers)
        new_cache = dict(k=nk, v=nv)
        if table is not None:
            new_cache["table"] = table

    x = L.apply_norm(x, params["ln_f"], cfg)
    return x, new_cache


def logits_of(params, hidden, cfg: ModelConfig, rules: ShardingRules):
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.apply_unembed(hidden, table, cfg, rules)


def loss_fn(params, batch, cfg: ModelConfig, rules: ShardingRules, mesh=None):
    hidden, _ = forward(params, batch["tokens"], cfg, rules, mesh=mesh)
    logits = logits_of(params, hidden, cfg, rules)
    return L.softmax_xent(logits, batch["targets"], batch["loss_mask"])


def prefill(params, tokens, cfg: ModelConfig, rules: ShardingRules, *,
            max_cache_len: int, mesh=None, lengths=None, cache=None,
            start=None):
    """Process a prompt, filling the KV cache. Returns (last_logits, cache,
    next_index).

    ``lengths`` (B,) enables ragged (left-aligned, right-PAD-padded)
    prompts: causal masking already keeps real tokens from attending the
    padding to their right, so the fix is to read each row's logits at its
    own last *real* position and return per-row next indices — decode then
    overwrites/masks the stale pad K/V via per-row cache positions. Without
    ``lengths`` all rows share the compiled prompt length (next_index = s).

    ``cache``/``start`` enable tail-only prefill over a pre-populated
    cache (serve-side prefix sharing): positions ``0..start-1`` of
    ``cache`` already hold valid K/V for this prompt, ``tokens`` is only
    the divergent tail, and the forward runs at ``cache_index=start`` —
    RoPE phases, causal masks, and K/V writes all offset to absolute
    positions. ``lengths`` then count *tail* tokens and next_index comes
    back absolute (``start + lengths``). ``start`` may be a traced scalar
    (one compile serves every split point).
    """
    b, s = tokens.shape
    if cache is None:
        cache = L.init_kv_cache(cfg, b, max_cache_len)
    base = 0 if start is None else start
    hidden, cache = forward(params, tokens, cfg, rules, cache=cache,
                            cache_index=base, mesh=mesh)
    if lengths is None:
        logits = logits_of(params, hidden[:, -1:], cfg, rules)
        return logits[:, 0], cache, base + s
    li = jnp.asarray(lengths, jnp.int32)
    last = hidden[jnp.arange(b), li - 1]          # (B, D) per-row last real
    logits = logits_of(params, last[:, None], cfg, rules)
    return logits[:, 0], cache, base + li


def decode_step(params, token, cache, index, cfg: ModelConfig,
                rules: ShardingRules, mesh=None):
    """One decode step. token: (B,) int32; index: current length — a scalar
    (all rows at the same depth) or per-row (B,) positions (continuous
    batching). Returns (logits (B, V), new_cache)."""
    hidden, cache = forward(params, token[:, None], cfg, rules,
                            cache=cache, cache_index=index, mesh=mesh)
    logits = logits_of(params, hidden, cfg, rules)
    return logits[:, 0], cache
