"""Zamba2-style hybrid LM: Mamba2 backbone + a *shared* attention block
(arXiv:2411.15242).

Structure: ``num_layers`` Mamba2 blocks; after every ``attn_every``-th block
the single shared transformer block (full GQA attention + SwiGLU MLP, one
parameter set reused at every invocation) runs on the hidden state. For
num_layers=81, attn_every=6 that is 13 shared-attention invocations plus a
3-layer Mamba tail.

Scan structure: outer ``lax.scan`` over groups, inner ``lax.scan`` over the
``attn_every`` Mamba blocks of each group — the shared block's params ride
in the closure (scan-invariant), so HLO stays O(1) in depth. Each shared
invocation owns its own KV cache slice (stacked on the group axis) because
it sees the same token positions at a different depth.

Deviations from the released Zamba2 noted in DESIGN.md: per-invocation LoRA
deltas on the shared block and the concat-with-embedding input are omitted
(weight sharing and placement are the architecture's load-bearing ideas).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from . import mamba2 as M
from .transformer import init_block as init_attn_block, \
    block_axes as attn_block_axes, _apply_block as apply_attn_block, \
    _stack_axes
from ..dist.sharding import ShardingRules, constrain


def _split(cfg: ModelConfig):
    g = cfg.attn_every
    n_groups = cfg.num_layers // g
    tail = cfg.num_layers - n_groups * g
    return g, n_groups, tail


def init_params(key, cfg: ModelConfig):
    g, n_groups, tail = _split(cfg)
    kE, kH, kS, kL = jax.random.split(key, 4)
    lkeys = jax.random.split(kL, cfg.num_layers)
    mamba = jax.vmap(lambda k: dict(ln=L.norm_init(cfg),
                                    mamba=M.mamba_init(k, cfg)))(lkeys)
    grouped = jax.tree.map(
        lambda t: t[: n_groups * g].reshape((n_groups, g) + t.shape[1:]),
        mamba)
    tail_p = jax.tree.map(lambda t: t[n_groups * g:], mamba)
    p = dict(
        embed=L.embed_init(kE, cfg),
        groups=grouped,
        tail=tail_p,
        shared=init_attn_block(kS, cfg),
        ln_f=L.norm_init(cfg),
    )
    if not cfg.tie_embeddings:
        p["unembed"] = L.embed_init(kH, cfg)
    return p


def param_axes(cfg: ModelConfig):
    mamba_axes = dict(ln=L.norm_axes(cfg), mamba=M.mamba_axes(cfg))
    a = dict(
        embed=L.embed_axes(),
        groups=_stack_axes(_stack_axes(mamba_axes), "layers"),
        tail=_stack_axes(mamba_axes),
        shared=attn_block_axes(cfg),
        ln_f=L.norm_axes(cfg),
    )
    if not cfg.tie_embeddings:
        a["unembed"] = L.embed_axes()
    return a


def init_state(cfg: ModelConfig, batch: int, max_cache_len: int):
    """Decode state: Mamba states for all layers + per-invocation KV caches."""
    g, n_groups, tail = _split(cfg)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return dict(
        mamba=M.init_mamba_state(cfg, batch),
        kv=dict(k=jnp.zeros((n_groups, batch, kv, max_cache_len, hd),
                            jnp.dtype(cfg.dtype)),
                v=jnp.zeros((n_groups, batch, kv, max_cache_len, hd),
                            jnp.dtype(cfg.dtype))),
    )


def state_axes(cfg: ModelConfig):
    """Logical axes of the decode state (``init_state``), for ``repro.dist``
    placement of the serving slot table."""
    kv = ("layers", "batch", "kv_heads", "cache_seq", "head_dim")
    return dict(mamba=M.mamba_state_axes(), kv=dict(k=kv, v=kv))


def _mamba_scan(x, stack, cfg, rules, states=None, lengths=None):
    """Inner scan over stacked mamba blocks; states optional (decode).
    ``lengths`` (B,) masks the recurrence past each row's real length
    (ragged prefill — see ``mamba2.mamba_block``)."""
    if states is None:
        def body(carry, bp):
            y, _ = M.mamba_block(L.apply_norm(carry, bp["ln"], cfg),
                                 bp["mamba"], cfg, rules)
            return constrain(carry + y, rules, "batch", "seq", "act_embed"), None
        body = L.maybe_remat(body, cfg)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, stack)
        else:
            n = jax.tree.leaves(stack)[0].shape[0]
            for i in range(n):
                bp = jax.tree.map(lambda t: t[i], stack)
                x, _ = body(x, bp)
        return x, None

    def body(carry, inp):
        bp, st = inp
        y, ns = M.mamba_block(L.apply_norm(carry, bp["ln"], cfg),
                              bp["mamba"], cfg, rules, state=st,
                              lengths=lengths)
        return carry + y, ns
    x, new_states = L.scan_or_unroll(body, x, (stack, states),
                                     cfg.scan_layers)
    return x, new_states


def forward(params, tokens, cfg: ModelConfig, rules: ShardingRules, *,
            state=None, cache_index=None, mesh=None, lengths=None):
    g, n_groups, tail = _split(cfg)
    x = L.apply_embed(tokens, params["embed"], cfg, rules)
    s = tokens.shape[1]
    base = 0 if cache_index is None else cache_index
    positions = L.decode_positions(base, s)

    def slice_layers(tree, lo, hi):
        return jax.tree.map(lambda t: t[lo:hi], tree)

    if state is None:
        def group_body(carry, gp):
            y, _ = _mamba_scan(carry, gp, cfg, rules)
            y, _ = apply_attn_block(y, params["shared"], cfg, rules,
                                    positions=positions, mesh=mesh)
            return y, None
        group_body = L.maybe_remat(group_body, cfg)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(group_body, x, params["groups"])
        else:
            for i in range(n_groups):
                gp = jax.tree.map(lambda t: t[i], params["groups"])
                x, _ = group_body(x, gp)
        if tail:
            x, _ = _mamba_scan(x, params["tail"], cfg, rules)
        new_state = None
    else:
        mstates = state["mamba"]
        main = jax.tree.map(
            lambda t: t[: n_groups * g].reshape((n_groups, g) + t.shape[1:]),
            mstates)
        tail_st = jax.tree.map(lambda t: t[n_groups * g:], mstates)

        def group_body(carry, inp):
            gp, gst, ck, cv = inp
            y, ns = _mamba_scan(carry, gp, cfg, rules, states=gst,
                                lengths=lengths)
            y, nc = apply_attn_block(y, params["shared"], cfg, rules,
                                     positions=positions,
                                     cache=dict(k=ck, v=cv),
                                     cache_index=cache_index, mesh=mesh)
            return y, (ns, nc["k"], nc["v"])
        x, (new_main, nk, nv) = L.scan_or_unroll(
            group_body, x, (params["groups"], main,
                            state["kv"]["k"], state["kv"]["v"]),
            cfg.scan_layers)
        if tail:
            x, new_tail = _mamba_scan(x, params["tail"], cfg, rules,
                                      states=tail_st, lengths=lengths)
        else:
            new_tail = tail_st
        flat_main = jax.tree.map(
            lambda t: t.reshape((n_groups * g,) + t.shape[2:]), new_main)
        new_mamba = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), flat_main, new_tail)
        new_state = dict(mamba=new_mamba, kv=dict(k=nk, v=nv))

    x = L.apply_norm(x, params["ln_f"], cfg)
    return x, new_state


def _logits(params, hidden, cfg, rules):
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.apply_unembed(hidden, table, cfg, rules)


def loss_fn(params, batch, cfg: ModelConfig, rules: ShardingRules, mesh=None):
    hidden, _ = forward(params, batch["tokens"], cfg, rules, mesh=mesh)
    return L.softmax_xent(_logits(params, hidden, cfg, rules),
                          batch["targets"], batch["loss_mask"])


def prefill(params, tokens, cfg: ModelConfig, rules: ShardingRules, *,
            max_cache_len: int, mesh=None, lengths=None):
    """``lengths`` (B,) serves ragged right-PAD-padded prompts: the Mamba
    recurrence is frozen across pads (``mamba2.mamba_block`` dt masking),
    the shared attention block's causal mask already keeps real tokens off
    the right-padding, logits come from each row's last real token, and the
    next index comes back per-row (stale pad K/V in the shared cache is
    overwritten/masked by per-row decode positions)."""
    b, s = tokens.shape
    state = init_state(cfg, b, max_cache_len)
    li = None if lengths is None else jnp.asarray(lengths, jnp.int32)
    hidden, state = forward(params, tokens, cfg, rules, state=state,
                            cache_index=0, mesh=mesh, lengths=li)
    if li is None:
        return _logits(params, hidden[:, -1:], cfg, rules)[:, 0], state, s
    last = hidden[jnp.arange(b), li - 1]
    return _logits(params, last[:, None], cfg, rules)[:, 0], state, li


def decode_step(params, token, state, index, cfg: ModelConfig,
                rules: ShardingRules, mesh=None):
    """``index``: scalar or per-row (B,) positions (the Mamba state is
    position-free; only the shared attention block consumes it)."""
    hidden, state = forward(params, token[:, None], cfg, rules, state=state,
                            cache_index=index, mesh=mesh)
    return _logits(params, hidden, cfg, rules)[:, 0], state
