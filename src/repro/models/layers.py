"""Common model layers (pure JAX, explicit param pytrees).

Every init function has a sibling ``*_axes`` returning the same tree
structure with logical-axis tuples for the sharding rules (dist/sharding).
Compute follows the mixed-precision convention: params live in
``param_dtype`` (f32 master), are cast to ``dtype`` (bf16) at use, and
reductions (softmax, norms, loss) run in f32.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from ..kernels.flash_attention.ops import flash_attention, \
    paged_decode_attention
from .config import ModelConfig
from ..dist.sharding import ShardingRules, constrain

Params = Any  # nested dict pytree


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, shape, in_axis_size, dtype):
    """Truncated-normal fan-in init (matches common LM practice)."""
    std = in_axis_size ** -0.5
    return (std * jax.random.truncated_normal(key, -3, 3, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, with_bias: bool | None = None):
    with_bias = cfg.use_layernorm if with_bias is None else with_bias
    p = dict(scale=jnp.ones((cfg.d_model,), _pdtype(cfg)))
    if with_bias:
        p["bias"] = jnp.zeros((cfg.d_model,), _pdtype(cfg))
    return p


def norm_axes(cfg: ModelConfig, with_bias: bool | None = None):
    with_bias = cfg.use_layernorm if with_bias is None else with_bias
    a = dict(scale=("act_embed",))
    if with_bias:
        a["bias"] = ("act_embed",)
    return a


def apply_norm(x, p, cfg: ModelConfig, eps: float | None = None):
    eps = cfg.norm_eps if eps is None else eps
    xf = x.astype(jnp.float32)
    if cfg.use_layernorm or "bias" in p:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32)
        if "bias" in p:
            y = y + p["bias"].astype(jnp.float32)
    else:  # RMSNorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(x, scale, eps):
    """qk-norm: RMSNorm over the head_dim of (..., head_dim)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def apply_rope(x, positions, theta: float):
    """x: (B, S, H, Dh); positions: (B, S) or (S,)."""
    b, s, h, d = x.shape
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, :, None].astype(jnp.float32) * freq[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]   # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm / qkv-bias / RoPE / cross / cache)
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    pd = _pdtype(cfg)
    p = dict(
        wq=dense_init(ks[0], (d, h, hd), d, pd),
        wk=dense_init(ks[1], (d, kv, hd), d, pd),
        wv=dense_init(ks[2], (d, kv, hd), d, pd),
        wo=dense_init(ks[3], (h, hd, d), h * hd, pd),
    )
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h, hd), pd)
        p["bk"] = jnp.zeros((kv, hd), pd)
        p["bv"] = jnp.zeros((kv, hd), pd)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), pd)
        p["k_norm"] = jnp.ones((hd,), pd)
    return p


def attn_axes(cfg: ModelConfig):
    a = dict(
        wq=("embed", "heads", "head_dim"),
        wk=("embed", "kv_heads", "head_dim"),
        wv=("embed", "kv_heads", "head_dim"),
        wo=("heads", "head_dim", "embed"),
    )
    if cfg.attn_bias:
        a["bq"] = ("heads", "head_dim")
        a["bk"] = ("kv_heads", "head_dim")
        a["bv"] = ("kv_heads", "head_dim")
    if cfg.qk_norm:
        a["q_norm"] = ("head_dim",)
        a["k_norm"] = ("head_dim",)
    return a


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: int | None = None):
    """Stacked (layers-leading) KV cache for the decode path."""
    n_layers = cfg.num_layers if n_layers is None else n_layers
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (n_layers, batch, kv, max_len, hd)
    return dict(k=jnp.zeros(shape, _dtype(cfg)),
                v=jnp.zeros(shape, _dtype(cfg)))


def kv_cache_axes():
    return dict(k=("layers", "batch", "kv_heads", "cache_seq", "head_dim"),
                v=("layers", "batch", "kv_heads", "cache_seq", "head_dim"))


def paged_kv_cache_axes():
    """Logical axes of the paged block slab (serve/paged.BlockPool): the
    blocks dim replaces (batch, cache_seq) and stays unsharded — any block
    may belong to any request, so only kv_heads carries model parallelism."""
    return dict(k=("layers", None, "kv_heads", None, "head_dim"),
                v=("layers", None, "kv_heads", None, "head_dim"))


def decode_positions(index, s: int):
    """Absolute positions for ``s`` tokens starting at ``index``.

    ``index`` scalar -> (s,) shared positions (the single-stream path);
    ``index`` (B,)   -> (B, s) per-row positions (continuous batching,
    where every slot sits at a different depth).
    """
    idx = jnp.asarray(index, jnp.int32)
    ar = jnp.arange(s, dtype=jnp.int32)
    if idx.ndim == 0:
        return idx + ar
    return idx[:, None] + ar[None, :]


def project_kv(src, p, cfg: ModelConfig, rules: ShardingRules):
    """Precompute (kh, vh) in (B, KVH, S, Dh) layout — cross-attention K/V
    never change during decode, so serving computes them once."""
    sc = src.astype(_dtype(cfg))
    k = jnp.einsum("bsd,dhk->bshk", sc, p["wk"].astype(_dtype(cfg)))
    v = jnp.einsum("bsd,dhk->bshk", sc, p["wv"].astype(_dtype(cfg)))
    if cfg.attn_bias:
        k = k + p["bk"].astype(_dtype(cfg))
        v = v + p["bv"].astype(_dtype(cfg))
    if cfg.qk_norm:
        k = rms_head_norm(k, p["k_norm"], cfg.norm_eps)
    k = constrain(k, rules, "batch", None, "kv_heads", None)
    v = constrain(v, rules, "batch", None, "kv_heads", None)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def apply_attention(x, p, cfg: ModelConfig, rules: ShardingRules, *,
                    positions=None, causal: bool = True,
                    kv_src=None, cache=None, cache_index=None,
                    use_rope: bool = True, kv_precomputed=None):
    """Self- or cross-attention with optional KV cache.

    x: (B, S, D). kv_src: encoder output for cross-attention (no rope, no
    causal). kv_precomputed: (kh, vh) from project_kv (skips projections).
    cache: dict(k, v) of (B, KVH, Lmax, Dh) for *this layer* plus
    cache_index = current length; returns (out, updated_cache).
    ``cache_index`` may be a scalar (all rows at the same depth) or a (B,)
    array of per-row lengths — the continuous-batching decode path, where
    each slot writes its new K/V at its own position and masks keys past
    its own length (S must be 1 in that case).

    **Paged layout**: when ``cache`` carries a ``"table"`` key, k/v are the
    *shared block slab* ``(N, KVH, block_size, Dh)`` and ``table`` is the
    per-row ``(B, max_blocks)`` int32 block table — position ``p`` of row
    ``b`` lives at ``slab[table[b, p // bs], :, p % bs]``. The new token's
    K/V scatters into ``table[row, pos // bs]`` and attention gathers
    block-sparsely through the table. Decode-only: requires S == 1,
    per-row ``cache_index``, and self-attention (only ``caps.paged``
    families reach here — ``serve/cache.PagedKVState`` gates the rest at
    construction).
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    xc = x.astype(_dtype(cfg))

    q = jnp.einsum("bsd,dhk->bshk", xc, p["wq"].astype(_dtype(cfg)))
    if cfg.attn_bias:
        q = q + p["bq"].astype(_dtype(cfg))
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"], cfg.norm_eps)

    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    if use_rope and kv_src is None and kv_precomputed is None:
        q = apply_rope(q, positions, cfg.rope_theta)
    q = constrain(q, rules, "batch", None, "heads", None)
    qh = q.transpose(0, 2, 1, 3)

    if kv_precomputed is not None:
        kh, vh = kv_precomputed
    else:
        src = xc if kv_src is None else kv_src.astype(_dtype(cfg))
        k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(_dtype(cfg)))
        v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(_dtype(cfg)))
        if cfg.attn_bias:
            k = k + p["bk"].astype(_dtype(cfg))
            v = v + p["bv"].astype(_dtype(cfg))
        if cfg.qk_norm:
            k = rms_head_norm(k, p["k_norm"], cfg.norm_eps)
        if use_rope and kv_src is None:
            k = apply_rope(k, positions, cfg.rope_theta)
        k = constrain(k, rules, "batch", None, "kv_heads", None)
        v = constrain(v, rules, "batch", None, "kv_heads", None)
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)

    if cache is not None and "table" in cache:
        if kv_src is not None or kv_precomputed is not None:
            raise ValueError("paged KV cache supports self-attention only; "
                             "cross-attention layouts keep the dense cache")
        if s != 1 or jnp.ndim(cache_index) != 1:
            raise ValueError(
                "paged KV cache is per-row single-token decode only "
                f"(got S={s}, cache_index ndim={jnp.ndim(cache_index)})")
        table = cache["table"]
        bs_blk = cache["k"].shape[2]
        idx = jnp.asarray(cache_index, jnp.int32)
        rows = jnp.arange(b)
        blk = table[rows, idx // bs_blk]
        off = idx % bs_blk
        ck = cache["k"].at[blk, :, off].set(kh[:, :, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[blk, :, off].set(vh[:, :, 0].astype(cache["v"].dtype))
        new_cache = dict(k=ck, v=cv, table=table)
        out = paged_decode_attention(qh, ck, cv, table, idx + 1,
                                     impl=cfg.attn_impl)
        out = out.transpose(0, 2, 1, 3)  # (B, S, H, Dh)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(_dtype(cfg)))
        return y, new_cache

    kv_len = None
    q_offset = 0
    new_cache = None
    if cache is not None:
        if jnp.ndim(cache_index) >= 1:
            # per-row decode: each row writes its single new K/V at its own
            # cache position (scatter; out-of-bounds rows are dropped) and
            # attends only keys below its own length.
            assert s == 1, "per-row cache_index is single-token decode only"
            rows = jnp.arange(b)
            idx = jnp.asarray(cache_index, jnp.int32)
            ck = cache["k"].at[rows, :, idx].set(kh[:, :, 0])
            cv = cache["v"].at[rows, :, idx].set(vh[:, :, 0])
        else:
            # all rows at the same depth: contiguous dynamic-slice write
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], kh, cache_index, axis=2)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], vh, cache_index, axis=2)
        new_cache = dict(k=ck, v=cv)
        kh, vh = ck, cv
        kv_len = cache_index + s
        q_offset = cache_index

    out = flash_attention(qh, kh, vh, causal=causal and kv_src is None,
                          kv_len=kv_len, q_offset=q_offset,
                          impl=cfg.attn_impl, unroll=not cfg.scan_layers)
    out = out.transpose(0, 2, 1, 3)  # (B, S, H, Dh)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(_dtype(cfg)))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU for LM family, GELU for whisper)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, gated: bool = True):
    d, f = cfg.d_model, cfg.d_ff
    pd = _pdtype(cfg)
    ks = jax.random.split(key, 3)
    if gated:
        return dict(w_gate=dense_init(ks[0], (d, f), d, pd),
                    w_up=dense_init(ks[1], (d, f), d, pd),
                    w_down=dense_init(ks[2], (f, d), f, pd))
    return dict(w_in=dense_init(ks[0], (d, f), d, pd),
                b_in=jnp.zeros((f,), pd),
                w_out=dense_init(ks[1], (f, d), f, pd),
                b_out=jnp.zeros((d,), pd))


def mlp_axes(gated: bool = True):
    if gated:
        return dict(w_gate=("embed", "mlp"), w_up=("embed", "mlp"),
                    w_down=("mlp", "embed"))
    return dict(w_in=("embed", "mlp"), b_in=("mlp",),
                w_out=("mlp", "embed"), b_out=("act_embed",))


def apply_mlp(x, p, cfg: ModelConfig, rules: ShardingRules):
    xc = x.astype(_dtype(cfg))
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", xc, p["w_gate"].astype(_dtype(cfg)))
        u = jnp.einsum("bsd,df->bsf", xc, p["w_up"].astype(_dtype(cfg)))
        h = jax.nn.silu(g) * u
        h = constrain(h, rules, "batch", None, "mlp")
        return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(_dtype(cfg)))
    h = jnp.einsum("bsd,df->bsf", xc, p["w_in"].astype(_dtype(cfg)))
    h = jax.nn.gelu(h + p["b_in"].astype(_dtype(cfg)))
    h = constrain(h, rules, "batch", None, "mlp")
    return (jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(_dtype(cfg)))
            + p["b_out"].astype(_dtype(cfg)))


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig, vocab: int | None = None):
    v = vocab if vocab else cfg.vocab_size
    return (jax.random.normal(key, (v, cfg.d_model)) * 0.02).astype(_pdtype(cfg))


def embed_axes():
    return ("vocab", "embed")


def apply_embed(tokens, table, cfg: ModelConfig, rules: ShardingRules):
    x = jnp.take(table.astype(_dtype(cfg)), tokens, axis=0)
    return constrain(x, rules, "batch", "seq", "act_embed")


def apply_unembed(x, table, cfg: ModelConfig, rules: ShardingRules):
    logits = jnp.einsum("bsd,vd->bsv", x.astype(_dtype(cfg)),
                        table.astype(_dtype(cfg)))
    seq_ax = "logits_seq" if (rules.vocab is None and logits.shape[1] > 1) \
        else None
    return constrain(logits, rules, "batch", seq_ax, "vocab")


def softmax_xent(logits, targets, mask):
    """Mean masked cross-entropy (nats), f32 reductions, plus z-loss metric."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    tgt = jnp.take_along_axis(lf, targets[..., None].astype(jnp.int32),
                              axis=-1)[..., 0]
    nll = (lse - tgt) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    z = (jnp.square(lse) * mask).sum() / denom
    return loss, dict(loss=loss, z_loss=z, tokens=mask.sum())


# ---------------------------------------------------------------------------
# Scan-or-unroll (cost-mode compiles unroll so HloCostAnalysis, which counts
# while bodies ONCE, sees every layer)
# ---------------------------------------------------------------------------

def scan_or_unroll(body, carry, xs, scan: bool):
    """lax.scan when ``scan`` else a python loop with stacked outputs."""
    if scan:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda t: t[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if not ys or ys[0] is None:
        return carry, None
    stacked = jax.tree.map(lambda *ts: jnp.stack(ts), *ys)
    return carry, stacked


# ---------------------------------------------------------------------------
# Remat policy
# ---------------------------------------------------------------------------

def maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # full
