"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 256
    vocab_size: int = 256
    head_dim: int | None = None
    # attention flavour
    qk_norm: bool = False        # qwen3
    attn_bias: bool = False      # qwen2 QKV bias
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    use_layernorm: bool = False  # whisper/stablelm use LayerNorm, not RMSNorm
    parallel_residual: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    ssm_groups: int = 1
    # hybrid (zamba2): a shared attention block every attn_every layers
    attn_every: int = 0
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    n_frames: int = 0            # stubbed conv-frontend output length
    max_target_len: int = 448
    # vision-language (llama-3.2-vision)
    cross_attn_every: int = 0
    n_patches: int = 0
    vision_dim: int = 0
    # numerics & execution
    dtype: str = "bfloat16"      # activation/compute dtype
    param_dtype: str = "float32"
    attn_impl: str = "ref"       # ref | pallas | interpret
    remat: str = "full"          # none | full | dots
    scan_layers: bool = True
    # distribution/perf knobs (hillclimb levers)
    seq_parallel: bool = False   # sequence-parallel inter-block carry
    microbatches: int = 1        # gradient-accumulation splits in train_step
    unroll_microbatches: bool = False  # python-loop accumulation (cost runs)
    # serving
    max_cache_len: int = 0

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:     # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def _mamba_layer_params(self) -> int:
        d, di = self.d_model, self.d_inner
        n, h = self.ssm_state, self.ssm_nheads
        g, dc = self.ssm_groups, self.ssm_conv
        return (d * (2 * di + 2 * g * n + h) + 3 * h
                + dc * (di + 2 * g * n) + (di + 2 * g * n)
                + di + di * d)

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline and sanity checks)."""
        d, f, v, hd = self.d_model, self.d_ff, self.vocab_size, self.resolved_head_dim
        h, kv = self.num_heads, self.num_kv_heads
        attn = d * hd * (h + 2 * kv) + h * hd * d
        if self.attn_bias:
            attn += hd * (h + 2 * kv)
        if self.qk_norm:
            attn += 2 * hd
        mlp = 3 * d * f
        per_layer = 0
        n_attn_layers = self.num_layers
        if self.family == "dense":
            per_layer = attn + mlp + 2 * d
        elif self.family == "moe":
            per_layer = attn + self.num_experts * mlp + d * self.num_experts + 2 * d
        elif self.family == "ssm":
            per_layer = self._mamba_layer_params() + d  # + input norm
        elif self.family == "hybrid":
            mamba = self._mamba_layer_params() + d
            shared = attn + mlp + 2 * d
            emb_h = v * d * (1 if self.tie_embeddings else 2)
            return self.num_layers * mamba + shared + emb_h + d
        elif self.family == "encdec":
            enc = self.encoder_layers * (attn + 2 * d * f + 3 * d)
            dec = self.num_layers * (2 * attn + 2 * d * f + 4 * d)
            return enc + dec + v * d + (self.n_frames + self.max_target_len) * d + 2 * d
        elif self.family == "vlm":
            n_cross = self.num_layers // max(self.cross_attn_every, 1)
            cross = attn + 2 * d  # cross-attn + gates
            return (self.num_layers * (attn + mlp + 2 * d) + n_cross * cross
                    + v * d + self.vision_dim * d + d)
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.num_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top-k of experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        full = self.param_count()
        inactive = (self.num_experts - self.experts_per_token) * 3 * d * f
        return full - self.num_layers * inactive
