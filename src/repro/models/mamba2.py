"""Mamba2 / SSD (state-space duality) layer and LM (arXiv:2405.21060).

The SSD chunked algorithm is TPU-native by construction — it replaces the
sequential selective scan with dense matmuls over chunks:

  within chunk (length Q):  Y_intra = ((C B^T) . L_causal-decay) (dt x)
  chunk summary state:      h_c     = sum_t decay_to_end(t) B_t (dt x)_t
  across chunks:            H_k     = exp(sum a)_k H_{k-1} + h_c   (lax.scan)
  inter contribution:       Y_inter = decay_from_start(t) C_t H_{k-1}

All einsums batch over (B, heads); heads shard over the TP axis so the only
cross-shard communication is the in/out projections' embed dim (FSDP).
Decode keeps O(1) state per layer: conv tail + (H, N, P) SSM state.

A naive O(S^2)-free sequential recurrence (``ssd_sequential_ref``) is the
correctness oracle for the chunked path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from ..dist.sharding import ShardingRules, constrain


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def mamba_init(key, cfg: ModelConfig):
    d = cfg.d_model
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    g, dc = cfg.ssm_groups, cfg.ssm_conv
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    return dict(
        w_z=L.dense_init(ks[0], (d, di), d, pd),
        w_x=L.dense_init(ks[1], (d, di), d, pd),
        w_B=L.dense_init(ks[2], (d, g * n), d, pd),
        w_C=L.dense_init(ks[3], (d, g * n), d, pd),
        w_dt=L.dense_init(ks[4], (d, h), d, pd),
        dt_bias=jnp.zeros((h,), pd) + jnp.log(jnp.expm1(0.01)).astype(pd),
        A_log=jnp.log(jnp.linspace(1.0, 16.0, h)).astype(pd),
        D_skip=jnp.ones((h,), pd),
        conv_x=L.dense_init(ks[5], (dc, di), dc, pd),
        conv_B=L.dense_init(ks[6], (dc, g * n), dc, pd),
        conv_C=L.dense_init(ks[7], (dc, g * n), dc, pd),
        conv_bx=jnp.zeros((di,), pd),
        conv_bB=jnp.zeros((g * n,), pd),
        conv_bC=jnp.zeros((g * n,), pd),
        gate_norm=jnp.ones((di,), pd),
        w_out=L.dense_init(ks[0], (di, d), di, pd),
    )


def mamba_axes(cfg: ModelConfig):
    return dict(
        w_z=("embed", "mlp"), w_x=("embed", "mlp"),
        w_B=("embed", "state"), w_C=("embed", "state"),
        w_dt=("embed", "ssm_heads"),
        dt_bias=("ssm_heads",), A_log=("ssm_heads",), D_skip=("ssm_heads",),
        conv_x=(None, "mlp"), conv_B=(None, "state"), conv_C=(None, "state"),
        conv_bx=("mlp",), conv_bB=("state",), conv_bC=("state",),
        gate_norm=("mlp",),
        w_out=("mlp", "embed"),
    )


def mamba_param_count(cfg: ModelConfig) -> int:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    g, dc = cfg.ssm_groups, cfg.ssm_conv
    return (d * (2 * di + 2 * g * n + h)            # projections
            + 3 * h                                  # dt_bias, A_log, D
            + dc * (di + 2 * g * n)                  # conv weights
            + (di + 2 * g * n)                       # conv biases
            + di                                     # gate norm
            + di * d)                                # out proj


# ---------------------------------------------------------------------------
# Causal depthwise conv (width dc), with optional carried tail for decode.
# ---------------------------------------------------------------------------

def _causal_conv(u, w, b, tail=None, lengths=None):
    """u: (B, S, C); w: (dc, C); tail: (B, dc-1, C) state or None.
    Returns (out (B,S,C), new_tail).

    ``lengths`` (B,) makes the *returned tail* ragged-correct: row ``b``'s
    tail is the last ``dc-1`` inputs at positions ``lengths[b]-dc+1 ..
    lengths[b]-1`` (ext coordinates ``lengths[b] .. lengths[b]+dc-2``), not
    the right-padding — so a right-PAD-padded prefill hands decode the same
    conv state as the trimmed prompt would."""
    dc = w.shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], dc - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([tail, u], axis=1)            # (B, S+dc-1, C)
    out = jnp.zeros_like(u)
    for i in range(dc):
        out = out + ext[:, i:i + u.shape[1]] * w[i][None, None, :]
    out = out + b[None, None, :]
    if dc <= 1:
        new_tail = tail
    elif lengths is None:
        new_tail = ext[:, -(dc - 1):]
    else:
        idx = (jnp.asarray(lengths, jnp.int32)[:, None]
               + jnp.arange(dc - 1, dtype=jnp.int32)[None, :])  # (B, dc-1)
        new_tail = jnp.take_along_axis(ext, idx[:, :, None], axis=1)
    return jax.nn.silu(out), new_tail


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, a, Bm, Cm, chunk: int, h_init=None):
    """SSD over chunks.

    x:  (B, S, H, P) f32     dt: (B, S, H) f32 (already softplus'd)
    a:  (H,) f32 negative    Bm, Cm: (B, S, H, N) f32
    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    xdt = x * dt[..., None]                             # (B,S,H,P)
    la = dt * a[None, None, :]                          # log decay per step

    def resh(t):
        return t.reshape(b, nc, q, *t.shape[2:])

    xdt_c, la_c, B_c, C_c = resh(xdt), resh(la), resh(Bm), resh(Cm)
    cum = jnp.cumsum(la_c, axis=2)                      # (B,nc,Q,H)
    seg_sum = cum[:, :, -1]                             # (B,nc,H) total decay

    # Intra-chunk: (C B^T . L) xdt, causal with decay L[i,j]=exp(cum_i-cum_j)
    gmat = jnp.einsum("bcqhn,bckhn->bchqk", C_c, B_c)
    ldiff = cum.transpose(0, 1, 3, 2)[..., :, None] - \
            cum.transpose(0, 1, 3, 2)[..., None, :]     # (B,nc,H,Q,Q)
    causal = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.where(causal[None, None, None], jnp.exp(ldiff), 0.0)
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", gmat * lmat, xdt_c)

    # Chunk summary states: decay from position t to chunk end.
    decay_to_end = jnp.exp(seg_sum[:, :, None, :] - cum)  # (B,nc,Q,H)
    h_c = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp", B_c, decay_to_end, xdt_c)

    # Inter-chunk recurrence.
    if h_init is None:
        h_init = jnp.zeros((b, h, n, p), x.dtype)

    def step(hprev, inp):
        hc, seg = inp                                   # (B,H,N,P), (B,H)
        hnew = hprev * jnp.exp(seg)[:, :, None, None] + hc
        return hnew, hprev

    seg_t = seg_sum.transpose(1, 0, 2)                  # (nc,B,H)
    hc_t = h_c.transpose(1, 0, 2, 3, 4)                 # (nc,B,H,N,P)
    h_last, h_prevs = jax.lax.scan(step, h_init, (hc_t, seg_t))

    decay_from_start = jnp.exp(cum)                     # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqhn,bcqh,cbhnp->bcqhp",
                         C_c, decay_from_start, h_prevs)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, h_last


def ssd_sequential_ref(x, dt, a, Bm, Cm, h_init=None):
    """Position-at-a-time recurrence oracle (slow; tests only)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    if h_init is None:
        h_init = jnp.zeros((b, h, n, p), x.dtype)

    def step(hprev, inp):
        xt, dtt, bt, ct = inp                            # (B,H,P),(B,H),(B,H,N)
        decay = jnp.exp(dtt * a[None, :])                # (B,H)
        hnew = hprev * decay[:, :, None, None] + \
            jnp.einsum("bhn,bhp->bhnp", bt, xt * dtt[..., None])
        yt = jnp.einsum("bhn,bhnp->bhp", ct, hnew)
        return hnew, yt

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2, 3), Cm.transpose(1, 0, 2, 3))
    h_last, ys = jax.lax.scan(step, h_init, xs)
    return ys.transpose(1, 0, 2, 3), h_last


# ---------------------------------------------------------------------------
# Block forward (train/prefill) and single-token decode
# ---------------------------------------------------------------------------

def _gated_norm(y, z, scale, eps):
    yz = y * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(yz), axis=-1, keepdims=True)
    return yz * jax.lax.rsqrt(ms + eps) * scale


def mamba_block(x, p, cfg: ModelConfig, rules: ShardingRules, *,
                state=None, lengths=None):
    """x: (B, S, D). state: decode dict or None. Returns (y, new_state).

    ``lengths`` (B,) serves ragged right-PAD-padded prefills exactly: at
    pad positions ``dt`` is forced to 0, so the SSM recurrence neither
    decays (``exp(0 * a) = 1``) nor absorbs input (``x * dt = 0``) — the
    final state is bit-equal to stopping at each row's real length — and
    the conv tails gather each row's last real inputs. Outputs at pad
    positions are garbage; callers read logits at ``lengths - 1``."""
    b, s, d = x.shape
    h, n, pdim = cfg.ssm_nheads, cfg.ssm_state, cfg.ssm_headdim
    g = cfg.ssm_groups
    dt32 = jnp.float32
    xc = x.astype(jnp.dtype(cfg.dtype))

    z = jnp.einsum("bsd,de->bse", xc, p["w_z"].astype(xc.dtype))
    xi = jnp.einsum("bsd,de->bse", xc, p["w_x"].astype(xc.dtype))
    Br = jnp.einsum("bsd,de->bse", xc, p["w_B"].astype(xc.dtype))
    Cr = jnp.einsum("bsd,de->bse", xc, p["w_C"].astype(xc.dtype))
    dt = jnp.einsum("bsd,dh->bsh", xc, p["w_dt"].astype(xc.dtype))

    tails = state or {}
    xi, t_x = _causal_conv(xi, p["conv_x"].astype(xc.dtype),
                           p["conv_bx"].astype(xc.dtype), tails.get("conv_x"),
                           lengths=lengths)
    Br, t_B = _causal_conv(Br, p["conv_B"].astype(xc.dtype),
                           p["conv_bB"].astype(xc.dtype), tails.get("conv_B"),
                           lengths=lengths)
    Cr, t_C = _causal_conv(Cr, p["conv_C"].astype(xc.dtype),
                           p["conv_bC"].astype(xc.dtype), tails.get("conv_C"),
                           lengths=lengths)

    xi = constrain(xi, rules, "batch", None, "mlp")
    dtf = jax.nn.softplus(dt.astype(dt32) +
                          p["dt_bias"].astype(dt32)[None, None])
    if lengths is not None:
        real = (jnp.arange(s, dtype=jnp.int32)[None, :]
                < jnp.asarray(lengths, jnp.int32)[:, None])   # (B, S)
        dtf = jnp.where(real[..., None], dtf, 0.0)
    a = -jnp.exp(p["A_log"].astype(dt32))

    xh = xi.astype(dt32).reshape(b, s, h, pdim)
    Bh = jnp.repeat(Br.astype(dt32).reshape(b, s, g, n), h // g, axis=2)
    Ch = jnp.repeat(Cr.astype(dt32).reshape(b, s, g, n), h // g, axis=2)

    h0 = tails.get("ssm")
    if state is not None and s == 1:
        # decode: single recurrence step
        y, h_last = ssd_sequential_ref(xh, dtf, a, Bh, Ch, h_init=h0)
    else:
        y, h_last = ssd_chunked(xh, dtf, a, Bh, Ch, cfg.ssm_chunk, h_init=h0)

    y = y + xh * p["D_skip"].astype(dt32)[None, None, :, None]
    y = y.reshape(b, s, cfg.d_inner).astype(xc.dtype)
    y = _gated_norm(y.astype(dt32), z.astype(dt32),
                    p["gate_norm"].astype(dt32), cfg.norm_eps).astype(xc.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(xc.dtype))
    new_state = dict(conv_x=t_x, conv_B=t_B, conv_C=t_C, ssm=h_last) \
        if state is not None else None
    return out, new_state


def init_mamba_state(cfg: ModelConfig, batch: int, n_layers: int | None = None):
    n_layers = cfg.num_layers if n_layers is None else n_layers
    dc, di, gn = cfg.ssm_conv, cfg.d_inner, cfg.ssm_groups * cfg.ssm_state
    dt = jnp.dtype(cfg.dtype)
    return dict(
        conv_x=jnp.zeros((n_layers, batch, dc - 1, di), dt),
        conv_B=jnp.zeros((n_layers, batch, dc - 1, gn), dt),
        conv_C=jnp.zeros((n_layers, batch, dc - 1, gn), dt),
        ssm=jnp.zeros((n_layers, batch, cfg.ssm_nheads, cfg.ssm_state,
                       cfg.ssm_headdim), jnp.float32),
    )


def mamba_state_axes():
    return dict(conv_x=("layers", "batch", None, "mlp"),
                conv_B=("layers", "batch", None, "state"),
                conv_C=("layers", "batch", None, "state"),
                ssm=("layers", "batch", "ssm_heads", "state", None))


# ---------------------------------------------------------------------------
# Full LM: embed -> scanned [norm + mamba + residual] -> norm -> logits
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    kE, kH, kL = jax.random.split(key, 3)
    lkeys = jax.random.split(kL, cfg.num_layers)
    blocks = jax.vmap(lambda k: dict(
        ln=L.norm_init(cfg), mamba=mamba_init(k, cfg)))(lkeys)
    p = dict(embed=L.embed_init(kE, cfg), blocks=blocks, ln_f=L.norm_init(cfg))
    if not cfg.tie_embeddings:
        p["unembed"] = L.embed_init(kH, cfg)
    return p


def param_axes(cfg: ModelConfig):
    from .transformer import _stack_axes
    a = dict(embed=L.embed_axes(),
             blocks=_stack_axes(dict(ln=L.norm_axes(cfg),
                                     mamba=mamba_axes(cfg))),
             ln_f=L.norm_axes(cfg))
    if not cfg.tie_embeddings:
        a["unembed"] = L.embed_axes()
    return a


def forward(params, tokens, cfg: ModelConfig, rules: ShardingRules, *,
            state=None, lengths=None):
    x = L.apply_embed(tokens, params["embed"], cfg, rules)

    if state is None:
        def body(carry, bp):
            y, _ = mamba_block(L.apply_norm(carry, bp["ln"], cfg),
                               bp["mamba"], cfg, rules)
            out = constrain(carry + y, rules, "batch", "seq", "act_embed")
            return out, None
        body = L.maybe_remat(body, cfg)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, params["blocks"])
        else:
            for i in range(cfg.num_layers):
                bp = jax.tree.map(lambda t: t[i], params["blocks"])
                x, _ = body(x, bp)
        new_state = None
    else:
        def body(carry, inp):
            bp, st = inp
            y, ns = mamba_block(L.apply_norm(carry, bp["ln"], cfg),
                                bp["mamba"], cfg, rules, state=st,
                                lengths=lengths)
            return carry + y, ns
        states_in = state
        x, new_state = L.scan_or_unroll(
            body, x, (params["blocks"],
                      dict(conv_x=states_in["conv_x"],
                           conv_B=states_in["conv_B"],
                           conv_C=states_in["conv_C"],
                           ssm=states_in["ssm"])), cfg.scan_layers)
    x = L.apply_norm(x, params["ln_f"], cfg)
    return x, new_state


def loss_fn(params, batch, cfg: ModelConfig, rules: ShardingRules):
    hidden, _ = forward(params, batch["tokens"], cfg, rules)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.apply_unembed(hidden, table, cfg, rules)
    return L.softmax_xent(logits, batch["targets"], batch["loss_mask"])


def prefill(params, tokens, cfg: ModelConfig, rules: ShardingRules, *,
            max_cache_len: int = 0, lengths=None):
    """Run the prompt, returning (last_logits, state, next_index). SSM state
    is O(1); max_cache_len is ignored (kept for API parity).

    ``lengths`` (B,) serves ragged right-PAD-padded prompts: the recurrent
    state is frozen across pad positions (``dt`` masked to 0 — see
    ``mamba_block``), logits are read at each row's last real token, and
    the next index comes back per-row."""
    b, s = tokens.shape
    state = init_mamba_state(cfg, b)
    li = None if lengths is None else jnp.asarray(lengths, jnp.int32)
    hidden, state = forward(params, tokens, cfg, rules, state=state,
                            lengths=li)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    if li is None:
        logits = L.apply_unembed(hidden[:, -1:], table, cfg, rules)
        return logits[:, 0], state, s
    last = hidden[jnp.arange(b), li - 1]          # (B, D) per-row last real
    logits = L.apply_unembed(last[:, None], table, cfg, rules)
    return logits[:, 0], state, li


def decode_step(params, token, state, index, cfg: ModelConfig,
                rules: ShardingRules):
    hidden, state = forward(params, token[:, None], cfg, rules, state=state)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.apply_unembed(hidden, table, cfg, rules)
    return logits[:, 0], state
