"""Llama-3.2-Vision-style VLM backbone: a llama3 text decoder with gated
cross-attention layers into image patch embeddings
(hf:meta-llama/Llama-3.2-11B-Vision).

The vision tower is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (B, n_patches, vision_dim); a learned linear
projects them to d_model. Of the 40 layers, every ``cross_attn_every``-th
is a cross-attention layer (8 for the 11B config), with zero-initialized
tanh gates on both the attention and MLP paths so training starts from the
pure text model — as in the released checkpoints.

Scan structure mirrors hybrid.py: outer scan over groups of
(cross_attn_every - 1) self layers + 1 cross layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from .transformer import init_block as init_self_block, \
    block_axes as self_block_axes, _apply_block as apply_self_block, \
    _stack_axes
from ..dist.sharding import ShardingRules, constrain


def _split(cfg: ModelConfig):
    ce = cfg.cross_attn_every
    n_groups = cfg.num_layers // ce
    n_self = cfg.num_layers - n_groups  # self layers inside groups + tail
    tail = cfg.num_layers - n_groups * ce
    return ce, n_groups, tail


def init_cross_block(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    pd = jnp.dtype(cfg.param_dtype)
    return dict(
        ln1=L.norm_init(cfg), attn=L.attn_init(k1, cfg),
        ln2=L.norm_init(cfg), mlp=L.mlp_init(k2, cfg),
        gate_attn=jnp.zeros((), pd), gate_mlp=jnp.zeros((), pd),
    )


def cross_block_axes(cfg: ModelConfig):
    return dict(ln1=L.norm_axes(cfg), attn=L.attn_axes(cfg),
                ln2=L.norm_axes(cfg), mlp=L.mlp_axes(),
                gate_attn=(), gate_mlp=())


def init_params(key, cfg: ModelConfig):
    ce, n_groups, tail = _split(cfg)
    n_self_main = n_groups * (ce - 1)
    kE, kH, kV, kS, kC, kT = jax.random.split(key, 6)
    skeys = jax.random.split(kS, max(n_self_main, 1))
    ckeys = jax.random.split(kC, n_groups)
    self_stack = jax.vmap(lambda k: init_self_block(k, cfg))(skeys[:n_self_main])
    grouped = jax.tree.map(
        lambda t: t.reshape((n_groups, ce - 1) + t.shape[1:]), self_stack)
    cross = jax.vmap(lambda k: init_cross_block(k, cfg))(ckeys)
    tkeys = jax.random.split(kT, max(tail, 1))
    p = dict(
        embed=L.embed_init(kE, cfg),
        v_proj=L.dense_init(kV, (cfg.vision_dim, cfg.d_model),
                            cfg.vision_dim, jnp.dtype(cfg.param_dtype)),
        groups=dict(self=grouped, cross=cross),
        tail=jax.vmap(lambda k: init_self_block(k, cfg))(tkeys[:tail]),
        ln_f=L.norm_init(cfg),
    )
    if not cfg.tie_embeddings:
        p["unembed"] = L.embed_init(kH, cfg)
    return p


def param_axes(cfg: ModelConfig):
    a = dict(
        embed=L.embed_axes(),
        v_proj=(None, "act_embed"),
        groups=dict(self=_stack_axes(_stack_axes(self_block_axes(cfg)),
                                     "layers"),
                    cross=_stack_axes(cross_block_axes(cfg))),
        tail=_stack_axes(self_block_axes(cfg)),
        ln_f=L.norm_axes(cfg),
    )
    if not cfg.tie_embeddings:
        a["unembed"] = L.embed_axes()
    return a


def vlm_param_count(cfg: ModelConfig) -> int:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd, h, kv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    attn = d * hd * (h + 2 * kv) + h * hd * d
    mlp = 3 * d * f
    ce, n_groups, tail = _split(cfg)
    n_self = n_groups * (ce - 1) + tail
    self_p = n_self * (attn + mlp + 2 * d)
    cross_p = n_groups * (attn + mlp + 2 * d + 2)
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    return self_p + cross_p + emb + cfg.vision_dim * d + d


def _apply_cross_block(x, bp, vis, cfg, rules, *, cross_kv=None):
    h, _ = L.apply_attention(
        L.apply_norm(x, bp["ln1"], cfg), bp["attn"], cfg, rules,
        causal=False, kv_src=vis if cross_kv is None else None,
        kv_precomputed=cross_kv, use_rope=False)
    x = x + jnp.tanh(bp["gate_attn"]).astype(x.dtype) * h
    m = L.apply_mlp(L.apply_norm(x, bp["ln2"], cfg), bp["mlp"], cfg, rules)
    x = x + jnp.tanh(bp["gate_mlp"]).astype(x.dtype) * m
    return constrain(x, rules, "batch", "seq", "act_embed")


def forward(params, tokens, patches, cfg: ModelConfig, rules: ShardingRules,
            *, cache=None, cache_index=None, cross_kv=None, mesh=None):
    """cache: dict(self=stacked self KV over ALL self layers in group order,
    ...) — built by init_cache below. patches: (B, P, vision_dim) or None
    when cross_kv is provided."""
    ce, n_groups, tail = _split(cfg)
    x = L.apply_embed(tokens, params["embed"], cfg, rules)
    s = tokens.shape[1]
    base = 0 if cache_index is None else cache_index
    positions = L.decode_positions(base, s)

    vis = None
    if patches is not None:
        vis = jnp.einsum("bpv,vd->bpd", patches.astype(jnp.dtype(cfg.dtype)),
                         params["v_proj"].astype(jnp.dtype(cfg.dtype)))
        vis = constrain(vis, rules, "batch", "frames", "act_embed")

    if cache is None:
        def self_body(c, bp):
            y, _ = apply_self_block(c, bp, cfg, rules,
                                    positions=positions, mesh=mesh)
            return y, None

        def group_body(carry, gp):
            if cfg.scan_layers:
                y, _ = jax.lax.scan(self_body, carry, gp["self"])
            else:
                y = carry
                for i in range(ce - 1):
                    bp = jax.tree.map(lambda t: t[i], gp["self"])
                    y, _ = self_body(y, bp)
            y = _apply_cross_block(y, gp["cross"], vis, cfg, rules)
            return y, None
        group_body = L.maybe_remat(group_body, cfg)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(group_body, x, params["groups"])
        else:
            for i in range(n_groups):
                gp = jax.tree.map(lambda t: t[i], params["groups"])
                x, _ = group_body(x, gp)
        if tail:
            if cfg.scan_layers:
                x, _ = jax.lax.scan(self_body, x, params["tail"])
            else:
                for i in range(tail):
                    bp = jax.tree.map(lambda t: t[i], params["tail"])
                    x, _ = self_body(x, bp)
        new_cache = None
    else:
        if cross_kv is None:
            cross_kv = precompute_cross_kv(params, vis, cfg, rules)

        def self_body(c, inp2):
            bp, ck, cv = inp2
            y, nc = apply_self_block(c, bp, cfg, rules,
                                     positions=positions,
                                     cache=dict(k=ck, v=cv),
                                     cache_index=cache_index, mesh=mesh)
            return y, (nc["k"], nc["v"])

        def group_body(carry, inp):
            gp, sk, sv, xk, xv = inp
            y, (nk, nv) = L.scan_or_unroll(self_body, carry,
                                           (gp["self"], sk, sv),
                                           cfg.scan_layers)
            y = _apply_cross_block(y, gp["cross"], None, cfg, rules,
                                   cross_kv=(xk, xv))
            return y, (nk, nv)
        x, (gnk, gnv) = L.scan_or_unroll(
            group_body, x, (params["groups"], cache["self_k"],
                            cache["self_v"], cross_kv["k"], cross_kv["v"]),
            cfg.scan_layers)
        if tail:
            x, (tnk, tnv) = L.scan_or_unroll(
                self_body, x, (params["tail"], cache["tail_k"],
                               cache["tail_v"]), cfg.scan_layers)
        else:
            tnk, tnv = cache["tail_k"], cache["tail_v"]
        new_cache = dict(self_k=gnk, self_v=gnv, tail_k=tnk, tail_v=tnv,
                         cross=cross_kv)
    x = L.apply_norm(x, params["ln_f"], cfg)
    return x, new_cache


def precompute_cross_kv(params, vis, cfg: ModelConfig, rules: ShardingRules):
    def body(_, bp):
        kh, vh = L.project_kv(vis, bp["attn"], cfg, rules)
        return 0, (kh, vh)
    _, (ks, vs) = jax.lax.scan(body, 0, params["groups"]["cross"])
    return dict(k=ks, v=vs)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    ce, n_groups, tail = _split(cfg)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return dict(
        self_k=jnp.zeros((n_groups, ce - 1, batch, kv, max_len, hd), dt),
        self_v=jnp.zeros((n_groups, ce - 1, batch, kv, max_len, hd), dt),
        tail_k=jnp.zeros((tail, batch, kv, max_len, hd), dt),
        tail_v=jnp.zeros((tail, batch, kv, max_len, hd), dt),
    )


def state_axes(cfg: ModelConfig):
    """Logical axes of the decode state (``init_cache`` + the frozen cross
    stack): the grouped self caches batch on axis 2 (groups, ce-1 lead),
    the tail and cross stacks on axis 1."""
    self_ax = ("layers", None, "batch", "kv_heads", "cache_seq", "head_dim")
    tail_ax = ("layers", "batch", "kv_heads", "cache_seq", "head_dim")
    cross = ("layers", "batch", "kv_heads", None, "head_dim")
    return dict(self_k=self_ax, self_v=self_ax, tail_k=tail_ax,
                tail_v=tail_ax, cross=dict(k=cross, v=cross))


def _logits(params, hidden, cfg, rules):
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.apply_unembed(hidden, table, cfg, rules)


def loss_fn(params, batch, cfg: ModelConfig, rules: ShardingRules, mesh=None):
    hidden, _ = forward(params, batch["tokens"], batch["patches"], cfg,
                        rules, mesh=mesh)
    return L.softmax_xent(_logits(params, hidden, cfg, rules),
                          batch["targets"], batch["loss_mask"])


def prefill(params, tokens, cfg: ModelConfig, rules: ShardingRules, *,
            patches, max_cache_len: int, mesh=None, lengths=None):
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_cache_len)
    hidden, cache = forward(params, tokens, patches, cfg, rules,
                            cache=cache, cache_index=0, mesh=mesh)
    if lengths is None:
        return _logits(params, hidden[:, -1:], cfg, rules)[:, 0], cache, s
    li = jnp.asarray(lengths, jnp.int32)
    last = hidden[jnp.arange(b), li - 1]
    return _logits(params, last[:, None], cfg, rules)[:, 0], cache, li


def decode_step(params, token, cache, index, cfg: ModelConfig,
                rules: ShardingRules, mesh=None):
    """``index``: scalar or per-row (B,) positions."""
    hidden, cache = forward(params, token[:, None], None, cfg, rules,
                            cache=cache, cache_index=index,
                            cross_kv=cache["cross"], mesh=mesh)
    return _logits(params, hidden, cfg, rules)[:, 0], cache
