"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv audio frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, n_frames, d_model) — the
transformer backbone is what's exercised. Encoder: bidirectional self-attn,
sinusoidal positions, LayerNorm, GELU MLP. Decoder: causal self-attn with
learned positions + cross-attention into the encoder output + GELU MLP.
Token embedding is tied to the output head (as in Whisper).

Serving: prefill encodes frames once, precomputes per-layer cross K/V
(cached — cross keys never change during decode), fills the self-attn cache
with the prompt; decode_step then runs pure incremental decoding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from .transformer import _stack_axes
from ..dist.sharding import ShardingRules, constrain


def _sinusoid(length: int, channels: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(channels // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * dim / (channels // 2))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def init_params(key, cfg: ModelConfig):
    kE, kP, kEnc, kDec = jax.random.split(key, 4)
    ek = jax.random.split(kEnc, cfg.encoder_layers)
    dk = jax.random.split(kDec, cfg.num_layers)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return dict(ln1=L.norm_init(cfg), attn=L.attn_init(k1, cfg),
                    ln2=L.norm_init(cfg), mlp=L.mlp_init(k2, cfg, gated=False))

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return dict(ln1=L.norm_init(cfg), self_attn=L.attn_init(k1, cfg),
                    ln2=L.norm_init(cfg), cross_attn=L.attn_init(k2, cfg),
                    ln3=L.norm_init(cfg), mlp=L.mlp_init(k3, cfg, gated=False))

    return dict(
        embed=L.embed_init(kE, cfg),
        pos_dec=(jax.random.normal(kP, (cfg.max_target_len, cfg.d_model))
                 * 0.01).astype(jnp.dtype(cfg.param_dtype)),
        enc_blocks=jax.vmap(enc_block)(ek),
        dec_blocks=jax.vmap(dec_block)(dk),
        ln_enc=L.norm_init(cfg),
        ln_f=L.norm_init(cfg),
    )


def param_axes(cfg: ModelConfig):
    enc = dict(ln1=L.norm_axes(cfg), attn=L.attn_axes(cfg),
               ln2=L.norm_axes(cfg), mlp=L.mlp_axes(gated=False))
    dec = dict(ln1=L.norm_axes(cfg), self_attn=L.attn_axes(cfg),
               ln2=L.norm_axes(cfg), cross_attn=L.attn_axes(cfg),
               ln3=L.norm_axes(cfg), mlp=L.mlp_axes(gated=False))
    return dict(
        embed=L.embed_axes(),
        pos_dec=(None, "act_embed"),
        enc_blocks=_stack_axes(enc),
        dec_blocks=_stack_axes(dec),
        ln_enc=L.norm_axes(cfg),
        ln_f=L.norm_axes(cfg),
    )


def state_axes(cfg: ModelConfig):
    """Logical axes of the decode state (``prefill``'s dict(kv, cross_kv)):
    the self-attention cache shards like a plain KV cache; the frozen
    per-row cross K/V stack batches on the same axis with a free frames
    dim."""
    kv = ("layers", "batch", "kv_heads", "cache_seq", "head_dim")
    cross = ("layers", "batch", "kv_heads", None, "head_dim")
    return dict(kv=dict(k=kv, v=kv), cross_kv=dict(k=cross, v=cross))


def encdec_param_count(cfg: ModelConfig) -> int:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd, h, kv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    attn = d * hd * (h + 2 * kv) + h * hd * d
    mlp = d * f + f + f * d + d
    norm = 2 * d if cfg.use_layernorm else d  # LayerNorm carries a bias
    enc = cfg.encoder_layers * (attn + mlp + 2 * norm)
    dec = cfg.num_layers * (2 * attn + mlp + 3 * norm)
    return enc + dec + v * d + cfg.max_target_len * d + 2 * norm


def encode(params, frames, cfg: ModelConfig, rules: ShardingRules):
    """frames: (B, F, D) stubbed frontend output -> encoder hidden states."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(frames.shape[1], cfg.d_model).astype(x.dtype)[None]
    x = constrain(x, rules, "batch", "frames", "act_embed")

    def body(carry, bp):
        h, _ = L.apply_attention(L.apply_norm(carry, bp["ln1"], cfg),
                                 bp["attn"], cfg, rules, causal=False,
                                 use_rope=False)
        y = carry + h
        y = y + L.apply_mlp(L.apply_norm(y, bp["ln2"], cfg), bp["mlp"],
                            cfg, rules)
        return constrain(y, rules, "batch", "frames", "act_embed"), None
    body = L.maybe_remat(body, cfg)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    else:
        for i in range(cfg.encoder_layers):
            bp = jax.tree.map(lambda t: t[i], params["enc_blocks"])
            x, _ = body(x, bp)
    return L.apply_norm(x, params["ln_enc"], cfg)


def _dec_block(x, bp, enc_out, cfg, rules, *, positions, cache=None,
               cache_index=None, cross_kv=None):
    h, new_cache = L.apply_attention(
        L.apply_norm(x, bp["ln1"], cfg), bp["self_attn"], cfg, rules,
        positions=positions, causal=True, cache=cache,
        cache_index=cache_index, use_rope=False)
    x = x + h
    c, _ = L.apply_attention(
        L.apply_norm(x, bp["ln2"], cfg), bp["cross_attn"], cfg, rules,
        causal=False, kv_src=enc_out if cross_kv is None else None,
        kv_precomputed=cross_kv, use_rope=False)
    x = x + c
    x = x + L.apply_mlp(L.apply_norm(x, bp["ln3"], cfg), bp["mlp"], cfg, rules)
    return constrain(x, rules, "batch", "seq", "act_embed"), new_cache


def precompute_cross_kv(params, enc_out, cfg: ModelConfig,
                        rules: ShardingRules):
    """Per-layer cross K/V, stacked (L, B, KVH, F, Dh) — computed once at
    prefill, reused every decode step."""
    def body(_, bp):
        kh, vh = L.project_kv(enc_out, bp["cross_attn"], cfg, rules)
        return 0, (kh, vh)
    _, (ks, vs) = L.scan_or_unroll(body, 0, params["dec_blocks"],
                                   cfg.scan_layers)
    return dict(k=ks, v=vs)


def decode_stack(params, tokens, enc_out, cfg: ModelConfig,
                 rules: ShardingRules, *, cache=None, cache_index=None,
                 cross_kv=None):
    b, s = tokens.shape
    base = 0 if cache_index is None else cache_index
    pos = L.decode_positions(base, s)          # (s,) or per-row (B, s)
    x = L.apply_embed(tokens, params["embed"], cfg, rules)
    pe = jnp.take(params["pos_dec"].astype(x.dtype),
                  jnp.minimum(pos, cfg.max_target_len - 1), axis=0)
    x = x + (pe if pos.ndim == 2 else pe[None])

    if cache is None:
        def body(carry, bp):
            y, _ = _dec_block(carry, bp, enc_out, cfg, rules, positions=pos)
            return y, None
        body = L.maybe_remat(body, cfg)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        else:
            for i in range(cfg.num_layers):
                bp = jax.tree.map(lambda t: t[i], params["dec_blocks"])
                x, _ = body(x, bp)
        new_cache = None
    else:
        def body(carry, inp):
            bp, ck, cv, xk, xv = inp
            y, nc = _dec_block(carry, bp, enc_out, cfg, rules, positions=pos,
                               cache=dict(k=ck, v=cv),
                               cache_index=cache_index, cross_kv=(xk, xv))
            return y, (nc["k"], nc["v"])
        if cross_kv is None:
            cross_kv = precompute_cross_kv(params, enc_out, cfg, rules)
        x, (nk, nv) = L.scan_or_unroll(
            body, x, (params["dec_blocks"], cache["k"], cache["v"],
                      cross_kv["k"], cross_kv["v"]), cfg.scan_layers)
        new_cache = dict(k=nk, v=nv)
    x = L.apply_norm(x, params["ln_f"], cfg)
    return x, new_cache


def loss_fn(params, batch, cfg: ModelConfig, rules: ShardingRules, mesh=None):
    enc_out = encode(params, batch["frames"], cfg, rules)
    hidden, _ = decode_stack(params, batch["tokens"], enc_out, cfg, rules)
    logits = L.apply_unembed(hidden, params["embed"], cfg, rules)  # tied
    return L.softmax_xent(logits, batch["targets"], batch["loss_mask"])


def prefill(params, tokens, cfg: ModelConfig, rules: ShardingRules, *,
            frames, max_cache_len: int, mesh=None, lengths=None):
    b, s = tokens.shape
    enc_out = encode(params, frames, cfg, rules)
    cross_kv = precompute_cross_kv(params, enc_out, cfg, rules)
    cache = L.init_kv_cache(cfg, b, max_cache_len)
    hidden, cache = decode_stack(params, tokens, enc_out, cfg, rules,
                                 cache=cache, cache_index=0,
                                 cross_kv=cross_kv)
    state = dict(kv=cache, cross_kv=cross_kv)
    if lengths is None:
        logits = L.apply_unembed(hidden[:, -1:], params["embed"], cfg, rules)
        return logits[:, 0], state, s
    li = jnp.asarray(lengths, jnp.int32)
    last = hidden[jnp.arange(b), li - 1]
    logits = L.apply_unembed(last[:, None], params["embed"], cfg, rules)
    return logits[:, 0], state, li


def decode_step(params, token, state, index, cfg: ModelConfig,
                rules: ShardingRules, mesh=None):
    """``index``: scalar or per-row (B,) decoder positions."""
    hidden, cache = decode_stack(params, token[:, None], None, cfg, rules,
                                 cache=state["kv"], cache_index=index,
                                 cross_kv=state["cross_kv"])
    logits = L.apply_unembed(hidden, params["embed"], cfg, rules)
    return logits[:, 0], dict(kv=cache, cross_kv=state["cross_kv"])
