"""Mixture-of-Experts FFN with expert parallelism (dbrx, olmoe).

Two implementations sharing one routing definition (top-k softmax gating):

* ``dense`` — every token through every expert, combined by gate weights.
  O(E/k) overcompute; used for tiny smoke configs and as the routing oracle.
* ``ep`` — production path: shard_map over (dp_axes x ep_axis) doing the
  GShard/DeepSpeed-MoE dance with explicit collectives:

    1. local top-k routing on each data shard;
    2. capacity-bucketed scatter by destination expert shard (the shared
       dist.collectives.bucket_by_destination primitive — overflow is
       counted token dropping, standard for capacity-factor MoE);
    3. ``all_to_all`` over the expert (model) axis;
    4. second-level local bucketing by expert, one grouped einsum per
       (E_local, C, D) x (E_local, D, F) — zero overcompute, all MXU;
    5. ``all_to_all`` back + weighted combine.

  Expert weights are stored sharded ("expert", "embed", ...) = EP x FSDP;
  the shard_map in_specs keep only the expert split, so XLA materializes
  the FSDP re-gather (ZeRO-3) as an all-gather right before use — visible
  in the HLO for the roofline's collective term.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .config import ModelConfig
from . import layers as L
from ..dist.compat import shard_map
from ..dist.collectives import bucket_by_destination as _bucket
from ..dist.sharding import ShardingRules, constrain


def moe_init(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return dict(
        router=L.dense_init(ks[0], (d, e), d, pd),
        w_gate=L.dense_init(ks[1], (e, d, f), d, pd),
        w_up=L.dense_init(ks[2], (e, d, f), d, pd),
        w_down=L.dense_init(ks[3], (e, f, d), f, pd),
    )


def moe_axes(cfg: ModelConfig):
    # EP consumes the model axis on the expert dim; the within-expert mlp
    # dim must NOT map to the same axis (DuplicateSpec). FSDP shards embed.
    return dict(
        router=("embed", None),
        w_gate=("expert", "embed", None),
        w_up=("expert", "embed", None),
        w_down=("expert", None, "embed"),
    )


def _route(x_flat, router, k):
    """(T, D) -> gate weights (T, k) f32, expert ids (T, k) int32."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        router.astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(gates, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)  # renormalize top-k
    return w, ids.astype(jnp.int32)


def _expert_ffn(x, wg, wu, wd, dtype):
    g = jnp.einsum("ecd,edf->ecf", x, wg.astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", x, wu.astype(dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd.astype(dtype))


def moe_ffn_dense(x, p, cfg: ModelConfig, rules: ShardingRules):
    """All-experts reference path (routing oracle / tiny configs)."""
    b, s, d = x.shape
    dt = jnp.dtype(cfg.dtype)
    xf = x.reshape(-1, d).astype(dt)
    w, ids = _route(xf, p["router"], cfg.experts_per_token)
    # (E, T, D) all-experts compute
    h = _expert_ffn(jnp.broadcast_to(xf[None], (cfg.num_experts,) + xf.shape),
                    p["w_gate"], p["w_up"], p["w_down"], dt)
    onehot = jax.nn.one_hot(ids, cfg.num_experts, dtype=jnp.float32)  # (T,k,E)
    gate = jnp.einsum("tke,tk->et", onehot, w).astype(dt)             # (E,T)
    y = jnp.einsum("etd,et->td", h, gate)
    return y.reshape(b, s, d), jnp.zeros((), jnp.int32)


def moe_ffn_ep(x, p, cfg: ModelConfig, rules: ShardingRules, mesh: Mesh):
    """Expert-parallel MoE FFN. x: (B, S, D) sharded batch over dp axes."""
    dp = rules.batch if isinstance(rules.batch, tuple) else (rules.batch,)
    dp = tuple(a for a in dp if a is not None and a in mesh.axis_names)
    ep = rules.expert
    if ep is None or ep not in mesh.axis_names:
        y, drop = moe_ffn_dense(x, p, cfg, rules)
        return y, drop
    m = mesh.shape[ep]
    e, k = cfg.num_experts, cfg.experts_per_token
    e_loc = e // m
    dt = jnp.dtype(cfg.dtype)

    def local_fn(x_loc, router, wg, wu, wd):
        # x_loc: (B_loc, S, D); weights gathered over FSDP axis already by
        # in_specs (see below) except the expert shard split.
        b_loc, s, d = x_loc.shape
        xf = x_loc.reshape(-1, d).astype(dt)
        t_loc = xf.shape[0]
        w, ids = _route(xf, router, k)                    # (T,k)

        flat_ids = ids.reshape(-1)                        # (T*k,)
        tok = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), k)
        gatew = w.reshape(-1).astype(dt)
        dest = flat_ids // e_loc                          # destination shard
        c_send = int(np.ceil(t_loc * k * cfg.moe_capacity_factor / m))

        cols = dict(x=xf[tok], eid=flat_ids, gw=gatew,
                    tok=tok, valid=jnp.ones((t_loc * k,), jnp.int32))
        buckets, _, _, _, drop1 = _bucket(cols, dest, m, c_send)

        recv = {n: jax.lax.all_to_all(v, ep, split_axis=0, concat_axis=0)
                for n, v in buckets.items()}              # (m, c_send, ...)
        n_recv = m * c_send
        rx = recv["x"].reshape(n_recv, d)
        r_eid = recv["eid"].reshape(n_recv)
        r_valid = recv["valid"].reshape(n_recv)
        shard = jax.lax.axis_index(ep)
        local_e = jnp.where(r_valid > 0, r_eid - shard * e_loc, e_loc)

        # Second-level bucket by local expert (no collective).
        c_e = int(np.ceil(n_recv * cfg.moe_capacity_factor / e_loc))
        c_e = min(c_e, n_recv)
        cols2 = dict(x=rx, slot=jnp.arange(n_recv, dtype=jnp.int32),
                     valid=r_valid)
        b2, _, e_sorted, pos2, _ = _bucket(cols2, local_e, e_loc + 1, c_e)
        # Only valid rows past capacity count as drops (padding rows land in
        # the e_loc dump bucket and are sliced off).
        drop2 = jnp.sum(((pos2 >= c_e) & (e_sorted < e_loc)).astype(jnp.int32))
        xe = b2["x"][:e_loc]                              # (E_loc, C_e, D)
        h = _expert_ffn(xe, wg, wu, wd, dt)               # (E_loc, C_e, D)

        # Scatter back into the (n_recv, D) layout via saved slots.
        out_r = jnp.zeros((n_recv + 1, d), dt)
        slot2 = jnp.where(b2["valid"][:e_loc] > 0, b2["slot"][:e_loc], n_recv)
        out_r = out_r.at[slot2.reshape(-1)].set(h.reshape(-1, d), mode="drop")
        out_r = out_r[:n_recv]

        back = jax.lax.all_to_all(out_r.reshape(m, c_send, d), ep,
                                  split_axis=0, concat_axis=0)
        back = back.reshape(n_recv, d)                    # aligned w/ buckets

        # Combine: bucket slot (dest shard i, pos j) corresponds to sorted
        # row index where d_sorted==i at rank j -> original token tok.
        y = jnp.zeros((t_loc, d), dt)
        bucket_tok = buckets["tok"].reshape(-1)           # (m*c_send,)
        bucket_gw = buckets["gw"].reshape(-1)
        bucket_valid = buckets["valid"].reshape(-1)
        contrib = back * bucket_gw[:, None]
        tok_idx = jnp.where(bucket_valid > 0, bucket_tok, t_loc)
        y = y.at[tok_idx].add(contrib, mode="drop")

        dropped = jax.lax.psum(drop1 + drop2, (ep,) + dp)
        return y.reshape(b_loc, s, d).astype(x_loc.dtype), dropped[None]

    # Shard the sequence over the expert axis too when it divides — tokens
    # are data, so this just multiplies the effective dispatch parallelism
    # and divides the per-shard bucket memory by m (vital at 32k prefill).
    s = x.shape[1]
    seq_ax = ep if (s % m == 0 and s >= m) else None
    wspec = P(ep, None, None)
    out = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp if dp else None, seq_ax, None),
                  P(None, None), wspec, wspec, wspec),
        out_specs=(P(dp if dp else None, seq_ax, None), P(ep)),
        # bf16-cast BEFORE the shard_map: the in_specs reshard is the FSDP
        # re-gather, and it must move 2-byte weights, not the f32 masters
        # (§Perf dbrx iteration: halves the dominant all-gather volume).
    )(x, p["router"].astype(dt), p["w_gate"].astype(dt),
      p["w_up"].astype(dt), p["w_down"].astype(dt))
    y, dropped = out
    return y, dropped.max()
