"""Model zoo: dense GQA transformers, MoE (EP), Mamba2 SSD, Zamba2 hybrid,
Whisper enc-dec, Llama-3.2-Vision — unified behind registry.get_model."""
from .config import ModelConfig
from .registry import get_model, ModelApi, analytic_param_count

__all__ = ["ModelConfig", "get_model", "ModelApi", "analytic_param_count"]
