"""Uniform model API over all architecture families.

``get_model(cfg, mesh=None)`` returns a ModelApi with:
  init(key) -> params                     axes() -> logical-axes pytree
  loss(params, batch) -> (loss, metrics)  # batch dict is family-specific
  prefill(params, batch) -> (logits, state, index)
  decode_step(params, token, state, index) -> (logits, state)
  batch_keys: which inputs the family consumes (tokens/frames/patches...)

Serving contract (the continuous-batching decode path):
  * ``prefill`` honours an optional ``batch["lengths"]`` (B,) for ragged,
    left-aligned right-PAD-padded prompts on EVERY family: attention
    families read logits at each row's last real token; SSM-state families
    (ssm/hybrid) freeze the recurrence across pads (``dt`` masked to 0)
    and gather ragged-correct conv tails. ``index`` comes back per-row.
  * dense/moe ``prefill`` honours an optional static ``batch["cache_len"]``
    (python int) overriding the KV-cache length it allocates — paged
    admission prefills into a bucket-covering cache instead of a full
    ``max_cache_len`` stripe. The other families (never paged) always
    allocate their ``max_cache_len`` layout.
  * dense/moe ``prefill`` also honours ``batch["prefix_kv"]`` (a
    pre-populated dict(k, v) cache) + ``batch["start"]`` (traced scalar
    tail offset) for serve-side prefix sharing: ``tokens`` is then only
    the divergent tail, the forward runs at ``cache_index=start``, and
    ``index`` comes back absolute (``start + lengths``).
  * ``decode_step``'s ``index`` is a scalar (all rows at the same depth)
    or a per-row (B,) array of absolute positions; the per-row form writes
    each row's K/V at its own cache slot and masks keys past its own
    length.
  * **Paged KV** (``caps.paged`` families): when the decode state carries
    a ``"table"`` key, k/v are the shared block slab and attention routes
    through the block-sparse paged path (``serve/paged.py``); the table is
    passed through unchanged.
  * ``caps`` (``ServeCaps``) declares how ``serve/cache.py`` hosts the
    family: which ``DecodeState`` implementation owns its slot table,
    whether the paged slab applies, which extra per-request inputs prefill
    consumes (frames/patches), and whether decode positions are bounded by
    ``max_cache_len``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from .config import ModelConfig
from ..dist.sharding import ShardingRules, REPLICATED, adapt_rules_for_mesh
from . import transformer, mamba2, hybrid, encdec, vision
from . import layers as _L


@dataclass(frozen=True)
class ServeCaps:
    """Serving capability flags: how ``serve/cache.py`` hosts this family.

    * ``state_kind`` selects the ``DecodeState`` implementation:
      ``"kv"`` (dense/moe), ``"recurrent"`` (ssm), ``"hybrid"``, or
      ``"cross"`` (encdec/vlm).
    * ``paged`` — the family's decode state is a plain dict(k, v) KV cache
      that the shared block slab (``serve/paged.BlockPool``) can replace.
    * ``extras`` — per-request prefill inputs beyond tokens/lengths:
      ``(batch_key, shape_fn(cfg, batch) -> tuple, dtype_str)`` triples
      (encdec frames, vlm patches). Frozen per request — the scheduler
      validates them at ``submit`` and threads them through admission.
    * ``positioned`` — decode positions index a bounded cache
      (``max_cache_len``); False for pure recurrent state (O(1), no
      position bound).
    * ``state_axes`` — logical-axes tree of the decode state for
      ``repro.dist`` placement (None = best-effort replicated).
    """
    state_kind: str
    paged: bool = False
    extras: tuple = ()
    positioned: bool = True
    state_axes: Callable | None = None


@dataclass
class ModelApi:
    cfg: ModelConfig
    rules: ShardingRules
    mesh: Any
    init: Callable
    axes: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    batch_keys: tuple[str, ...]
    caps: ServeCaps = field(default_factory=lambda: ServeCaps(
        state_kind="kv", paged=True,
        state_axes=lambda cfg: _L.kv_cache_axes()))


def get_model(cfg: ModelConfig, mesh=None,
              rules: ShardingRules = REPLICATED) -> ModelApi:
    if mesh is not None:
        # Single resolution point: every architecture's rules pass through
        # the unified adapt so a smaller/elastic mesh degrades cleanly
        # (adapt is idempotent — pre-adapted rules are unchanged).
        rules = adapt_rules_for_mesh(rules, mesh)
    fam = cfg.family
    if fam in ("dense", "moe"):
        return ModelApi(
            cfg=cfg, rules=rules, mesh=mesh,
            init=lambda key: transformer.init_params(key, cfg),
            axes=lambda: transformer.param_axes(cfg),
            loss=lambda p, b: transformer.loss_fn(p, b, cfg, rules, mesh),
            prefill=lambda p, b: transformer.prefill(
                p, b["tokens"], cfg, rules,
                max_cache_len=b.get("cache_len") or cfg.max_cache_len,
                mesh=mesh, lengths=b.get("lengths"),
                cache=b.get("prefix_kv"), start=b.get("start")),
            decode_step=lambda p, tok, st, i: transformer.decode_step(
                p, tok, st, i, cfg, rules, mesh),
            batch_keys=("tokens", "targets", "loss_mask"),
            caps=ServeCaps(state_kind="kv", paged=True,
                           state_axes=lambda c: _L.kv_cache_axes()),
        )
    if fam == "ssm":
        return ModelApi(
            cfg=cfg, rules=rules, mesh=mesh,
            init=lambda key: mamba2.init_params(key, cfg),
            axes=lambda: mamba2.param_axes(cfg),
            loss=lambda p, b: mamba2.loss_fn(p, b, cfg, rules),
            prefill=lambda p, b: mamba2.prefill(
                p, b["tokens"], cfg, rules, lengths=b.get("lengths")),
            decode_step=lambda p, tok, st, i: mamba2.decode_step(
                p, tok, st, i, cfg, rules),
            batch_keys=("tokens", "targets", "loss_mask"),
            caps=ServeCaps(state_kind="recurrent", positioned=False,
                           state_axes=lambda c: mamba2.mamba_state_axes()),
        )
    if fam == "hybrid":
        return ModelApi(
            cfg=cfg, rules=rules, mesh=mesh,
            init=lambda key: hybrid.init_params(key, cfg),
            axes=lambda: hybrid.param_axes(cfg),
            loss=lambda p, b: hybrid.loss_fn(p, b, cfg, rules, mesh),
            prefill=lambda p, b: hybrid.prefill(
                p, b["tokens"], cfg, rules,
                max_cache_len=cfg.max_cache_len, mesh=mesh,
                lengths=b.get("lengths")),
            decode_step=lambda p, tok, st, i: hybrid.decode_step(
                p, tok, st, i, cfg, rules, mesh),
            batch_keys=("tokens", "targets", "loss_mask"),
            caps=ServeCaps(state_kind="hybrid", state_axes=hybrid.state_axes),
        )
    if fam == "encdec":
        return ModelApi(
            cfg=cfg, rules=rules, mesh=mesh,
            init=lambda key: encdec.init_params(key, cfg),
            axes=lambda: encdec.param_axes(cfg),
            loss=lambda p, b: encdec.loss_fn(p, b, cfg, rules),
            prefill=lambda p, b: encdec.prefill(
                p, b["tokens"], cfg, rules, frames=b["frames"],
                max_cache_len=cfg.max_cache_len,
                lengths=b.get("lengths")),
            decode_step=lambda p, tok, st, i: encdec.decode_step(
                p, tok, st, i, cfg, rules),
            batch_keys=("tokens", "targets", "loss_mask", "frames"),
            caps=ServeCaps(
                state_kind="cross",
                extras=(("frames",
                         lambda c, b: (b, c.n_frames, c.d_model),
                         "float32"),),
                state_axes=encdec.state_axes),
        )
    if fam == "vlm":
        return ModelApi(
            cfg=cfg, rules=rules, mesh=mesh,
            init=lambda key: vision.init_params(key, cfg),
            axes=lambda: vision.param_axes(cfg),
            loss=lambda p, b: vision.loss_fn(p, b, cfg, rules, mesh),
            prefill=lambda p, b: vision.prefill(
                p, b["tokens"], cfg, rules, patches=b["patches"],
                max_cache_len=cfg.max_cache_len, mesh=mesh,
                lengths=b.get("lengths")),
            decode_step=lambda p, tok, st, i: vision.decode_step(
                p, tok, st, i, cfg, rules, mesh),
            batch_keys=("tokens", "targets", "loss_mask", "patches"),
            caps=ServeCaps(
                state_kind="cross",
                extras=(("patches",
                         lambda c, b: (b, c.n_patches, c.vision_dim),
                         "float32"),),
                state_axes=vision.state_axes),
        )
    raise ValueError(f"unknown family {fam!r}")


def analytic_param_count(cfg: ModelConfig) -> int:
    if cfg.family == "encdec":
        return encdec.encdec_param_count(cfg)
    if cfg.family == "vlm":
        return vision.vlm_param_count(cfg)
    return cfg.param_count()
