"""Version-portable wrappers over the jax distribution APIs.

The distribution surface moved repeatedly between jax 0.4.x and 0.7.x:
``shard_map`` graduated from ``jax.experimental`` to ``jax.shard_map`` (and
its replication check was renamed ``check_rep`` -> ``check_vma``),
``jax.make_mesh`` grew an ``axis_types`` kwarg, and mesh activation went
from the ``Mesh`` context manager through ``jax.sharding.use_mesh`` to
``jax.set_mesh``. Every module in ``repro.dist`` (and everything built on
it) goes through these wrappers so the rest of the tree never has to care
which jax it is running on.
"""
from __future__ import annotations

import inspect

import jax

# --- shard_map -------------------------------------------------------------

if hasattr(jax, "shard_map"):                       # jax >= 0.6
    _shard_map = jax.shard_map
else:                                               # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SM_PARAMS = inspect.signature(_shard_map).parameters
_SM_CHECK_KW = "check_vma" if "check_vma" in _SM_PARAMS else "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` with the replication check under one kwarg name."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_SM_CHECK_KW: check})


# --- mesh construction -----------------------------------------------------

AxisType = getattr(jax.sharding, "AxisType", None)
_MAKE_MESH_HAS_TYPES = "axis_types" in inspect.signature(
    jax.make_mesh).parameters


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _MAKE_MESH_HAS_TYPES and AxisType is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def abstract_mesh(axis_shapes, axis_names):
    """Device-less mesh carrying only (axis_names, shape) — enough for rule
    manipulation (arch_rules / adapt_rules_for_mesh) on meshes larger than
    the local device count. The constructor changed shape across jax
    versions; support both."""
    AbstractMesh = jax.sharding.AbstractMesh
    params = inspect.signature(AbstractMesh).parameters
    if "shape_tuple" in params:                     # jax 0.4.x
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))
    return AbstractMesh(tuple(axis_shapes), tuple(axis_names))


# --- mesh activation -------------------------------------------------------

def use_mesh(mesh):
    """Context manager activating ``mesh`` for jit / with_sharding_constraint.

    ``with use_mesh(m): ...`` works on every supported jax: ``jax.set_mesh``
    (>= 0.6.3), ``jax.sharding.use_mesh`` (0.5.x-0.6.x), or the ``Mesh``
    context manager itself (0.4.x).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions: older
    releases return a per-device list of dicts, newer ones a single dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def active_mesh():
    """The mesh currently activated (by use_mesh / ``with mesh:``), or None.

    Works inside jit tracing — the resource env is thread-local and live
    while the traced function body runs.
    """
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        m = get_abstract()
        if m is not None and not m.empty:
            return m
    try:  # pre-0.5: the thread-local resource env set by ``with mesh:``
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None
