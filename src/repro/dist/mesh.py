"""Mesh construction (moved here from repro.launch.mesh).

FUNCTIONS, not module-level constants — importing this module never touches
jax device state. Single pod: (data=16, model=16) = 256 chips of TPU v5e;
multi-pod: (pod=2, data=16, model=16) = 512 chips, the 'pod' axis crossing
DCI (pure data parallelism there).
"""
from __future__ import annotations

from .compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False,
                         data: int = 16, model: int = 16):
    """(data x model) must stay 256 chips/pod; the (16, 16) default is the
    dry-run baseline, per-arch refactorizations (e.g. (32, 8) for qwen2,
    (64, 4) for narrow models) are §Perf levers."""
    assert data * model == 256, (data, model)
    shape = (2, data, model) if multi_pod else (data, model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Small explicit meshes for tests/examples on host devices."""
    if pod is not None:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
