"""Reusable collectives (moved here from repro.core.distributed).

Distributed sessionization is the paper's Hadoop shuffle on a TPU mesh.
The paper reconstructs sessions with a MapReduce shuffle keyed on
``(user_id, session_id)``. On a TPU pod the identical dataflow is:

1. each ``data``-axis shard holds an arbitrary slice of the hour's events
   (that is exactly how the log mover deposits them: partially ordered,
   arbitrarily partitioned);
2. every shard buckets its rows by ``hash(user_id) % n_shards`` and an
   ``all_to_all`` collective performs the keyed repartition over ICI — all
   events of a user land on one shard;
3. each shard runs the local fused sort + segment pass (sessionize.py).

Bucketing uses fixed per-destination capacity (the MoE dispatch pattern):
overflowed rows are counted and reported, never silently lost — the caller
re-runs with a larger capacity factor, mirroring how the production job
sizes itself from the previous histogram job.

The primitives are deliberately generic: ``bucket_by_destination`` handles
payload rows of any rank (the MoE expert dispatch in models/moe.py routes
(T, D) activations through the same function the sessionizer uses for
scalar event columns), and ``keyed_all_to_all`` is the bucketing +
``all_to_all`` repartition as one reusable stage for future pipeline work.

Also here: the distributed histogram (local segment_sum + psum) used by the
dictionary-building job.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map, use_mesh
from ..core.sessionize import _sessionize, DEFAULT_GAP_MS


def mix64(x: jax.Array) -> jax.Array:
    """splitmix64 finalizer — avalanche so modulo sharding is uniform."""
    x = x.astype(jnp.uint64)
    x = (x ^ (x >> 30)) * jnp.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> 27)) * jnp.uint64(0x94D049BB133111EB)
    x = x ^ (x >> 31)
    return x


def shard_of_user(user_id: jax.Array, n_shards: int) -> jax.Array:
    return (mix64(user_id) % jnp.uint64(n_shards)).astype(jnp.int32)


def bucket_by_destination(cols, dest: jax.Array, n_dest: int, capacity: int):
    """Scatter rows into (n_dest, capacity) buckets.

    ``cols`` is any pytree of arrays sharing leading dim ``len(dest)`` — a
    flat column dict (the sessionizer), activations with trailing dims (the
    MoE dispatch routes (T, D) rows through here), or nested rollup payload
    trees (the distributed pipeline ships column dicts plus per-row rollup
    structs in one call). Rows are stably sorted by destination, positions
    within a destination are contiguous ranks; rows ranked beyond capacity
    are dropped (counted, never silent). Buckets get shape
    (n_dest, capacity, *payload).

    Returns ``(buckets, order, dest_sorted, pos, dropped)``; callers that
    only repartition use ``(buckets, dropped)``, the MoE combine path also
    needs the sort permutation to route results back.
    """
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    d_sorted = dest[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    start = jax.ops.segment_min(idx, d_sorted, num_segments=n_dest)
    pos = idx - start[d_sorted]
    dropped = jnp.sum((pos >= capacity).astype(jnp.int32))

    def scatter(v):
        v_sorted = v[order]
        buf = jnp.zeros((n_dest, capacity) + v.shape[1:], v.dtype)
        return buf.at[d_sorted, pos].set(v_sorted, mode="drop")

    out = jax.tree.map(scatter, cols)
    return out, order, d_sorted, pos, dropped


def keyed_all_to_all(cols, dest: jax.Array, axis: str, n_shards: int,
                     capacity: int):
    """Keyed repartition over mesh axis ``axis`` (call inside shard_map).

    Buckets local rows by destination shard and performs the all_to_all
    shuffle; ``cols`` is any pytree of same-leading-dim arrays (see
    ``bucket_by_destination``). Returns the received pytree with flat
    leading dim ``n_shards * capacity`` (zero-padded — receivers must mask
    on a validity column) plus the local dropped-row count.
    """
    buckets, _, _, _, dropped = bucket_by_destination(
        cols, dest, n_shards, capacity)
    recv = jax.tree.map(
        lambda v: jax.lax.all_to_all(v, axis, split_axis=0, concat_axis=0),
        buckets)
    flat = jax.tree.map(lambda v: v.reshape((-1,) + v.shape[2:]), recv)
    return flat, dropped


def make_distributed_sessionize(mesh: Mesh, axis: str = "data", *,
                                gap_ms: int = DEFAULT_GAP_MS,
                                capacity_factor: float = 2.0,
                                max_sessions_per_shard: int,
                                max_len: int):
    """Build a jitted distributed sessionize over ``mesh[axis]``.

    Inputs are event columns sharded on the leading dim over ``axis``;
    outputs are per-shard Sessionized fields stacked on a leading shard dim
    (still sharded over ``axis``), plus the global dropped-row count.
    """
    n_shards = mesh.shape[axis]

    def local_fn(user_id, session_id, timestamp, code, ip, valid):
        n_local = user_id.shape[0]
        capacity = int(np.ceil(n_local * capacity_factor / n_shards))
        dest = shard_of_user(user_id, n_shards)
        # Invalid rows must not consume capacity: route them to shard of
        # their hash anyway but mark invalid (they're masked later); cheaper
        # than compaction and correct because sessionize drops invalids.
        cols = dict(user_id=user_id, session_id=session_id,
                    timestamp=timestamp, code=code, ip=ip,
                    valid=valid.astype(jnp.int32))
        flat, dropped = keyed_all_to_all(cols, dest, axis, n_shards, capacity)
        # Received padding rows: zero-initialized buckets have valid=0.
        out = _sessionize(
            flat["user_id"], flat["session_id"], flat["timestamp"],
            flat["code"], flat["ip"], flat["valid"].astype(bool),
            gap_ms=gap_ms, max_sessions=max_sessions_per_shard,
            max_len=max_len)
        total_dropped = jax.lax.psum(dropped, axis)
        # Add leading per-shard dim for out_specs concatenation.
        out = {k: v[None] for k, v in out.items()}
        return out, total_dropped[None]

    in_spec = P(axis)
    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(in_spec,) * 6,
                   out_specs=({k: P(axis) for k in
                               ("symbols", "length", "user_id", "session_id",
                                "ip", "start_ts", "duration_s", "num_sessions",
                                "num_events", "truncated")}, P(axis)))

    def wrapper(user_id, session_id, timestamp, code, ip=None, valid=None):
        n = len(user_id)
        if ip is None:
            ip = np.zeros(n, np.int64)
        if valid is None:
            valid = np.ones(n, bool)
        with enable_x64():
            with use_mesh(mesh):
                out, dropped = jax.jit(fn)(
                    jnp.asarray(user_id, jnp.int64),
                    jnp.asarray(session_id, jnp.int64),
                    jnp.asarray(timestamp, jnp.int64),
                    jnp.asarray(code, jnp.int32),
                    jnp.asarray(ip, jnp.int64),
                    jnp.asarray(valid, bool))
        return out, int(np.asarray(dropped)[0])

    return wrapper


# one compiled gossip exchange per (mesh, axis) — the vectors are tiny and
# fixed-shape, so a single jitted all-gather serves every router tick
# without retracing
_GOSSIP_FNS: dict = {}


def gossip_all_gather(vecs, mesh: Mesh | None = None,
                      axis: str = "data") -> np.ndarray:
    """Exchange fixed-shape occupancy vectors between fleet replicas.

    ``vecs`` is ``(n_replicas, k)`` int-like — one small stats vector per
    replica (the serving fleet gossips ``[free, pending, active]``). With
    ``mesh=None`` every replica is host-local and the exchange is the
    identity (the degenerate single-host fleet the tests and benchmarks
    run). With a mesh, each shard holds its replicas' rows and the rows
    are all-gathered over ``mesh[axis]`` so every shard sees the full
    fleet — the same code path host-local tests exercise on 1-device
    meshes. Always returns a host ``np.ndarray`` of shape
    ``(n_replicas_total, k)`` int32: the router consumes it with plain
    python, and a tiny device round-trip per tick would dwarf the gossip.
    """
    arr = np.asarray(vecs, np.int32)
    if arr.ndim != 2:
        raise ValueError(
            f"gossip vectors must be (n_replicas, k), got {arr.shape}")
    if mesh is None:
        return arr
    n_shards = mesh.shape[axis]
    if arr.shape[0] % n_shards:
        raise ValueError(
            f"{arr.shape[0]} gossip rows do not shard evenly over "
            f"mesh axis {axis!r} of size {n_shards}")
    key = (mesh, axis)
    fn = _GOSSIP_FNS.get(key)
    if fn is None:
        def local_fn(x):
            return jax.lax.all_gather(x, axis, axis=0, tiled=True)

        fn = jax.jit(shard_map(local_fn, mesh=mesh,
                               in_specs=(P(axis),), out_specs=P()))
        _GOSSIP_FNS[key] = fn
    with use_mesh(mesh):
        return np.asarray(fn(jnp.asarray(arr)))


def make_distributed_histogram(mesh: Mesh, axis: str = "data", *,
                               num_names: int):
    """Distributed event histogram: local segment_sum + psum (the daily
    dictionary job, §4.2, over the mesh instead of a Pig job)."""

    def local_fn(name_ids, valid):
        ids = jnp.where(valid, name_ids, num_names)
        local = jax.ops.segment_sum(
            jnp.ones_like(ids, jnp.int32), ids,
            num_segments=num_names + 1)[:num_names]
        return jax.lax.psum(local, axis)

    fn = shard_map(local_fn, mesh=mesh, in_specs=(P(axis), P(axis)),
                   out_specs=P())

    def wrapper(name_ids, valid=None):
        if valid is None:
            valid = np.ones(len(name_ids), bool)
        with use_mesh(mesh):
            return np.asarray(jax.jit(fn)(
                jnp.asarray(name_ids, jnp.int32), jnp.asarray(valid, bool)))

    return wrapper
