"""Logical-axis sharding rules: one vocabulary for the whole tree.

Model code never names mesh axes. Every tensor dimension carries a
*logical* axis name ("heads", "mlp", "expert", ...) and a single
``ShardingRules`` instance maps logical names to mesh axes. That keeps the
mapping in exactly one place — the same consolidation the paper performs on
log formats — so changing a parallelism layout (or degrading onto a smaller
elastic mesh) never touches model code.

* ``ShardingRules``          frozen logical->mesh mapping; ``REPLICATED``
                             is the all-None instance (fully replicated).
* ``constrain(x, rules, *ax)``  in-graph ``with_sharding_constraint`` keyed
                             by logical names; a no-op when the resolved
                             spec is fully replicated or no mesh is active.
* ``tree_spec(axes, rules)`` map a pytree of logical-axis tuples (the
                             ``*_axes`` trees next to every init) to
                             ``PartitionSpec``s.
* ``arch_rules(...)``        per-architecture layouts: attention-head
                             (dense/encdec/vlm), expert (moe), state-space
                             (mamba2), and their union (hybrid).
* ``adapt_rules_for_mesh``   degrade rules onto a smaller/elastic mesh by
                             dropping axes the mesh doesn't have (or has at
                             size 1) — the elastic-restart path.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import active_mesh

# One field per logical axis. Params use embed/act_embed/heads/kv_heads/
# head_dim/mlp/vocab/expert/state/ssm_heads/layers; activations and decode
# state add batch/seq/logits_seq/cache_seq/frames.
LOGICAL_AXES = (
    "batch", "seq", "logits_seq", "cache_seq", "frames",
    "embed", "act_embed", "vocab",
    "heads", "kv_heads", "head_dim", "mlp", "expert",
    "state", "ssm_heads", "layers",
)

# A rule value is None (replicated), a mesh-axis name, or a tuple of them.
Rule = None | str | tuple[str | None, ...]


@dataclass(frozen=True)
class ShardingRules:
    """Logical axis name -> mesh axis (or axes, or None = replicated)."""
    batch: Rule = None
    seq: Rule = None
    logits_seq: Rule = None
    cache_seq: Rule = None
    frames: Rule = None
    embed: Rule = None
    act_embed: Rule = None
    vocab: Rule = None
    heads: Rule = None
    kv_heads: Rule = None
    head_dim: Rule = None
    mlp: Rule = None
    expert: Rule = None
    state: Rule = None
    ssm_heads: Rule = None
    layers: Rule = None

    def physical(self, logical: str | None) -> Rule:
        """Mesh axes for one logical axis name (None passes through)."""
        if logical is None:
            return None
        if logical not in LOGICAL_AXES:
            raise ValueError(f"unknown logical axis {logical!r}; "
                             f"known: {LOGICAL_AXES}")
        return getattr(self, logical)

    def spec(self, *logical_axes: str | None) -> P:
        """PartitionSpec for one tensor, one logical name per dimension.

        A mesh axis may appear only once in a PartitionSpec; when two
        dimensions resolve to the same mesh axis the leftmost dimension
        wins and later occurrences degrade to replicated. That makes rule
        composition safe: e.g. ``cache_seq=("data", "model")`` with
        ``kv_heads="model"`` in the same KV-cache spec cannot produce a
        DuplicateSpec error, it just keeps the earlier assignment.
        """
        used: set[str] = set()
        entries = []
        for logical in logical_axes:
            phys = self.physical(logical)
            if phys is None:
                entries.append(None)
                continue
            axes = phys if isinstance(phys, tuple) else (phys,)
            kept = tuple(a for a in axes if a is not None and a not in used)
            used.update(kept)
            if not kept:
                entries.append(None)
            elif isinstance(phys, tuple):
                entries.append(kept)
            else:
                entries.append(kept[0])
        return P(*entries)


REPLICATED = ShardingRules()

_FIELDS = tuple(f.name for f in dataclasses.fields(ShardingRules))


def constrain(x, rules: ShardingRules, *logical_axes: str | None):
    """``with_sharding_constraint`` by logical axis names.

    No-op when the resolved spec is fully replicated (the REPLICATED /
    single-device path) or when no mesh is active — so model code can call
    it unconditionally.
    """
    spec = rules.spec(*logical_axes)
    if all(entry is None for entry in spec):
        return x
    if active_mesh() is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def _is_axes_leaf(node) -> bool:
    return node is None or (
        isinstance(node, tuple)
        and all(a is None or isinstance(a, str) for a in node))


def tree_spec(axes_tree, rules: ShardingRules):
    """Map a pytree of logical-axis tuples to a pytree of PartitionSpecs.

    Leaves are tuples of logical names (None entries = replicated dims,
    ``()`` = scalar) exactly as produced by the ``*_axes`` functions in
    ``repro.models``.
    """
    return jax.tree.map(
        lambda axes: P() if axes is None else rules.spec(*axes),
        axes_tree, is_leaf=_is_axes_leaf)


def tree_shardings(axes_tree, rules: ShardingRules, mesh: Mesh):
    """Like ``tree_spec`` but returns device-placeable ``NamedSharding``s."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_spec(axes_tree, rules))


def adapt_rules_for_mesh(rules: ShardingRules, mesh: Mesh) -> ShardingRules:
    """Degrade ``rules`` onto ``mesh``: drop mesh axes the mesh doesn't
    have, or has at trivial size 1 (a 1-device mesh drops every
    model-parallel axis and yields fully-replicated rules).

    Idempotent, so callers can adapt defensively at every mesh boundary —
    the elastic reshard/restore path relies on that.
    """
    names = set(mesh.axis_names)

    def adapt(value: Rule) -> Rule:
        if value is None:
            return None
        axes = value if isinstance(value, tuple) else (value,)
        kept = tuple(a for a in axes
                     if a is not None and a in names and mesh.shape[a] > 1)
        if not kept:
            return None
        return kept if isinstance(value, tuple) else kept[0]

    return ShardingRules(**{f: adapt(getattr(rules, f)) for f in _FIELDS})


def _divides(dim: int, size: int) -> bool:
    return dim > 0 and size > 0 and dim % size == 0


def arch_rules(base: ShardingRules, mesh: Mesh, *, family: str | None = None,
               num_heads: int = 0, num_kv_heads: int = 0, d_ff: int = 0,
               vocab: int = 0, num_experts: int = 0, ssm_nheads: int = 0,
               d_inner: int = 0) -> ShardingRules:
    """Per-architecture sharding layout for ``mesh``.

    Data parallelism goes over ("pod", "data") — whichever exist — and the
    "model" axis is consumed by the family's natural tensor-parallel dims:

    * dense / encdec / vlm — attention heads + kv heads + mlp + vocab
      (megatron-style head/ffn split);
    * moe   — the expert dim (EP); attention heads still split, but the
      within-expert ffn dim stays unsharded (it shares tensors with the
      expert dim, which already holds the model axis);
    * ssm (mamba2) — state-space heads + inner width; the state dim stays
      unsharded (it shares the SSM-state tensor with ssm_heads);
    * hybrid — union of the attention and state-space layouts.

    A dim is only sharded when its size divides the model-axis size.
    Explicit entries in ``base`` win over the computed layout. The result
    is pre-adapted to ``mesh``.
    """
    if family is None:
        if num_experts > 0:
            family = "moe"
        elif ssm_nheads > 0:
            family = "hybrid" if num_heads > 0 else "ssm"
        else:
            family = "dense"

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    msize = mesh.shape.get("model", 1)
    mp = "model" if "model" in mesh.axis_names else None

    out: dict[str, Rule] = dict(batch=dp or None)
    if mp is not None:
        attn_like = family in ("dense", "moe", "hybrid", "encdec", "vlm")
        if attn_like:
            if _divides(num_heads, msize):
                out["heads"] = mp
            if _divides(num_kv_heads, msize):
                out["kv_heads"] = mp
        if family in ("dense", "encdec", "vlm") and _divides(d_ff, msize):
            out["mlp"] = mp
        if family == "moe" and _divides(num_experts, msize):
            out["expert"] = mp
        if family in ("ssm", "hybrid"):
            if _divides(ssm_nheads, msize):
                out["ssm_heads"] = mp
            # hybrid uses "mlp" for both the attention block's d_ff and the
            # mamba inner width — the split needs both to divide
            if _divides(d_inner, msize) and (
                    family == "ssm" or _divides(d_ff, msize)):
                out["mlp"] = mp
        if _divides(vocab, msize):
            out["vocab"] = mp
        else:
            # fall back to sharding the logits seq dim (layers.apply_unembed
            # uses logits_seq only while vocab is unsharded)
            out["logits_seq"] = mp

    merged = {f: (getattr(base, f) if getattr(base, f) is not None
                  else out.get(f)) for f in _FIELDS}
    return adapt_rules_for_mesh(ShardingRules(**merged), mesh)
