"""Unified distribution layer — the paper's consolidation move applied to
parallelism.

The paper replaces application-specific logging with one "client events"
layer every downstream job consumes; ``repro.dist`` does the same for
distribution machinery. Everything that touches a mesh lives here.

Public API by module:

* ``sharding`` — logical-axis sharding rules: ``ShardingRules`` (named
  logical dims -> mesh axes), ``REPLICATED``, ``LOGICAL_AXES``,
  ``constrain`` (with_sharding_constraint by logical name), ``tree_spec``
  (axes pytree -> PartitionSpec pytree), ``tree_shardings`` (same but
  device-placeable ``NamedSharding``s — how the serving scheduler places
  params and the KV-cache slab), ``arch_rules`` (per-architecture rule
  derivation), ``adapt_rules_for_mesh`` (elastic degradation when an axis
  does not divide).
* ``mesh`` — mesh construction, functions not module constants (importing
  never touches device state): ``make_production_mesh`` (256-chip pods,
  optional multi-pod), ``make_host_mesh`` (small explicit test meshes).
* ``collectives`` — the reusable dataflow primitives: ``mix64`` /
  ``shard_of_user`` (avalanched key hashing), ``bucket_by_destination``
  (fixed-capacity pytree bucketing, shared by MoE dispatch and the log
  pipeline), ``keyed_all_to_all`` (bucketing + all_to_all as one keyed
  repartition stage), ``make_distributed_sessionize`` and
  ``make_distributed_histogram`` (standalone shuffle/psum jobs), and
  ``gossip_all_gather`` (the serving fleet's fixed-shape occupancy
  exchange — identity host-local, all-gather over a mesh axis). The
  multi-stage log pipeline composing these lives in
  ``repro.data.distpipe``.
* ``compat`` — version-portable wrappers over the jax APIs that moved
  between 0.4.x and 0.7.x: ``shard_map`` (check_rep/check_vma under one
  kwarg), ``use_mesh`` (set_mesh / sharding.use_mesh / Mesh ctx),
  ``make_mesh`` (axis_types when supported), ``abstract_mesh``,
  ``active_mesh``, ``cost_analysis``.

Back-compat shims (kept so pre-PR-1 callers keep working; new code imports
from ``repro.dist``): ``repro.core.distributed`` re-exports the collectives
with the old private names and 2-tuple ``_bucket_by_destination`` contract;
``repro.launch.mesh`` re-exports the mesh builders.
"""
from .compat import shard_map, use_mesh, make_mesh, abstract_mesh, \
    active_mesh
from .sharding import (ShardingRules, REPLICATED, LOGICAL_AXES, constrain,
                       tree_spec, tree_shardings, arch_rules,
                       adapt_rules_for_mesh)
from .mesh import make_production_mesh, make_host_mesh
from .collectives import (mix64, shard_of_user, bucket_by_destination,
                          keyed_all_to_all, make_distributed_sessionize,
                          make_distributed_histogram, gossip_all_gather)

__all__ = [
    "shard_map", "use_mesh", "make_mesh", "abstract_mesh", "active_mesh",
    "ShardingRules", "REPLICATED", "LOGICAL_AXES", "constrain",
    "tree_spec", "tree_shardings", "arch_rules", "adapt_rules_for_mesh",
    "make_production_mesh", "make_host_mesh",
    "mix64", "shard_of_user", "bucket_by_destination", "keyed_all_to_all",
    "make_distributed_sessionize", "make_distributed_histogram",
    "gossip_all_gather",
]
