"""Unified distribution layer — the paper's consolidation move applied to
parallelism.

The paper replaces application-specific logging with one "client events"
layer every downstream job consumes; ``repro.dist`` does the same for
distribution machinery. Everything that touches a mesh lives here:

* ``sharding``    — logical-axis sharding rules (``ShardingRules``,
  ``constrain``, ``tree_spec``, ``arch_rules``, ``adapt_rules_for_mesh``)
* ``mesh``        — mesh construction (production pods + host test meshes)
* ``collectives`` — keyed repartition (all_to_all shuffle), fixed-capacity
  bucketing, distributed sessionize / histogram
* ``compat``      — version-portable wrappers over the jax APIs that moved
  between 0.4.x and 0.7.x (``shard_map``, mesh activation, axis types)

``repro.core.distributed`` and ``repro.launch.mesh`` remain as thin
back-compat re-export shims.
"""
from .compat import shard_map, use_mesh, make_mesh, abstract_mesh, \
    active_mesh
from .sharding import (ShardingRules, REPLICATED, LOGICAL_AXES, constrain,
                       tree_spec, arch_rules, adapt_rules_for_mesh)
from .mesh import make_production_mesh, make_host_mesh
from .collectives import (mix64, shard_of_user, bucket_by_destination,
                          keyed_all_to_all, make_distributed_sessionize,
                          make_distributed_histogram)

__all__ = [
    "shard_map", "use_mesh", "make_mesh", "abstract_mesh", "active_mesh",
    "ShardingRules", "REPLICATED", "LOGICAL_AXES", "constrain",
    "tree_spec", "arch_rules", "adapt_rules_for_mesh",
    "make_production_mesh", "make_host_mesh",
    "mix64", "shard_of_user", "bucket_by_destination", "keyed_all_to_all",
    "make_distributed_sessionize", "make_distributed_histogram",
]
