"""Funnel analytics over session sequences (paper §5.3).

``Funnel('signup_page.*', 'signup_submit', ...)``: each stage is a set of
event codes (built by dictionary pattern expansion). A session reaches stage
k when stages 0..k match *in order* (subsequence semantics — the paper
translates the funnel into a regex over the session string; over symbol
tensors the equivalent is a stage-automaton advanced by one ``lax.scan``
pass). Output is the paper's per-stage reach table::

    (0, 490123)   # sessions entering the funnel
    (1, 297071)   # ... completing stage 1
    ...

The Pallas kernel (kernels/funnel_match) accelerates the same automaton with
blocked VMEM tiles; this module is the pure-JAX implementation and oracle
for it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dictionary import EventDictionary
from ..core.sequences import SessionSequences


def build_stage_table(stages, alphabet_size: int) -> np.ndarray:
    """(n_stages, alphabet) bool: stage_table[k, c] = code c satisfies stage k."""
    table = np.zeros((len(stages), alphabet_size), bool)
    for k, codes in enumerate(stages):
        table[k, np.asarray(codes, np.int64)] = True
    return table


@functools.partial(jax.jit, static_argnames=("n_stages",))
def _deepest_stage(symbols, mask, stage_table, n_stages):
    """Per-session deepest stage reached (0 = none, n_stages = completed)."""
    s, l = symbols.shape
    alphabet = stage_table.shape[1]
    # Pad stage table with an always-false row so k == n_stages is absorbing.
    table = jnp.concatenate(
        [stage_table, jnp.zeros((1, alphabet), bool)], axis=0)
    sym = jnp.clip(symbols, 0, alphabet - 1)

    def step(k, t):
        advance = table[k, sym[:, t]] & mask[:, t]
        return k + advance.astype(jnp.int32), None

    k0 = jnp.zeros((s,), jnp.int32)
    k, _ = jax.lax.scan(step, k0, jnp.arange(l))
    return k


@functools.partial(jax.jit, static_argnames=("n_stages",))
def reach_histogram(symbols, mask, stage_table, n_stages):
    """(n_stages,) int32 reach counts — the shard-local half of the
    distributed funnel rollup.

    ``reach[j]`` = sessions whose deepest stage exceeds j (the paper's
    per-stage reach table as a fixed-shape vector, mergeable across shards
    with one ``psum``). Padded session rows have an all-False mask, never
    advance the automaton, and so count toward no stage.
    """
    k = _deepest_stage(symbols, mask, stage_table, n_stages)
    return jnp.sum((k[:, None] > jnp.arange(n_stages)[None, :])
                   .astype(jnp.int32), axis=0)


def funnel_reach(seqs: SessionSequences, stages, alphabet_size: int,
                 deepest_fn=None) -> list[tuple[int, int]]:
    """The paper's funnel output: [(stage, sessions reaching it), ...].

    ``deepest_fn`` lets callers swap in the Pallas kernel implementation.
    """
    table = jnp.asarray(build_stage_table(stages, alphabet_size))
    fn = deepest_fn if deepest_fn is not None else _deepest_stage
    k = np.asarray(fn(jnp.asarray(seqs.symbols), jnp.asarray(seqs.mask()),
                      table, len(stages)))
    return [(j, int((k > j).sum())) for j in range(len(stages))]


def funnel_reach_store(store, stages, alphabet_size: int, *,
                       time_range=None, users=None,
                       deepest_fn=None) -> list[tuple[int, int]]:
    """Funnel reach through the segment store's pruning scan.

    Prunes on the *stage-0* codes: a session that never enters the funnel
    contributes zero to every stage (deepest == 0), so restricting the
    scan to sessions containing a stage-0 event returns reach identical to
    an unpruned scan — segments without any entry event never decode.
    """
    seqs = store.sequences(time_range=time_range, users=users,
                          events=list(np.asarray(stages[0])))
    return funnel_reach(seqs, stages, alphabet_size, deepest_fn=deepest_fn)


def funnel_reach_users(seqs: SessionSequences, stages, alphabet_size: int):
    """Reach counted in unique *users* rather than sessions (§5.3: 'simply a
    matter of applying the unique operator prior to summing')."""
    table = jnp.asarray(build_stage_table(stages, alphabet_size))
    k = np.asarray(_deepest_stage(jnp.asarray(seqs.symbols),
                                  jnp.asarray(seqs.mask()), table, len(stages)))
    users = np.asarray(seqs.user_id)
    out = []
    for j in range(len(stages)):
        out.append((j, int(len(np.unique(users[k > j])))))
    return out


def abandonment(reach: list[tuple[int, int]]) -> list[float]:
    """Per-stage abandonment rate between consecutive stages."""
    out = []
    for (j0, c0), (_, c1) in zip(reach, reach[1:]):
        out.append(1.0 - (c1 / c0) if c0 else 0.0)
    return out


def funnel_from_patterns(seqs: SessionSequences, dictionary: EventDictionary,
                         *patterns: str):
    """The paper's UDF surface: ``Funnel('signup_page.*', ...)`` — stage
    specs as namespace globs, expanded through the dictionary."""
    stages = [dictionary.codes_matching(p) for p in patterns]
    for p, s in zip(patterns, stages):
        if len(s) == 0:
            raise ValueError(f"funnel stage pattern matched no events: {p!r}")
    return funnel_reach(seqs, stages, dictionary.alphabet_size)
