"""Event counting over session sequences (paper §5.2).

``CountClientEvents('$EVENTS')``: the pattern is expanded through the
dictionary to a set of codes, then counting is a masked membership test over
the padded symbol tensor — a single fused gather+reduce instead of a Pig
scan. Both the SUM (total occurrences) and COUNT (sessions containing >= 1)
variants are provided, plus the Oink roll-up aggregations of §3.2.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dictionary import EventDictionary
from ..core.namespace import ROLLUP_SCHEMAS, parse
from ..core.sequences import SessionSequences


@functools.partial(jax.jit, static_argnames=("alphabet_size",))
def _count(symbols, mask, target_codes_onehot, alphabet_size):
    # symbols: (S, L) int32 (PAD allowed where mask False)
    sym = jnp.clip(symbols, 0, alphabet_size - 1)
    hits = target_codes_onehot[sym] & mask
    per_session = jnp.sum(hits, axis=1, dtype=jnp.int32)
    return jnp.sum(per_session), jnp.sum((per_session > 0).astype(jnp.int32))


def make_target_lut(target_codes, alphabet_size: int) -> jax.Array:
    lut = np.zeros(alphabet_size, bool)
    lut[np.asarray(target_codes, np.int64)] = True
    return jnp.asarray(lut)


def count_events(seqs: SessionSequences, target_codes,
                 alphabet_size: int) -> tuple[int, int]:
    """(SUM, COUNT) of the paper's UDF over materialized sequences."""
    lut = make_target_lut(target_codes, alphabet_size)
    total, containing = _count(jnp.asarray(seqs.symbols),
                               jnp.asarray(seqs.mask()), lut,
                               int(alphabet_size))
    return int(total), int(containing)


def count_pattern(seqs: SessionSequences, dictionary: EventDictionary,
                  pattern: str) -> tuple[int, int]:
    """Counting by namespace glob, e.g. ``'*:profile_click'`` — the exact
    §5.2 script: pattern -> dictionary expansion -> count."""
    codes = dictionary.codes_matching(pattern)
    if len(codes) == 0:
        return 0, 0
    return count_events(seqs, codes, dictionary.alphabet_size)


def count_events_store(store, target_codes, alphabet_size: int, *,
                       time_range=None, users=None) -> tuple[int, int]:
    """The same (SUM, COUNT) read through the segment store's pruning
    scan: segments whose code histogram lacks every target (or that miss
    the time/user filters) are skipped before decoding. Filtering to
    sessions *containing* a target changes neither SUM nor COUNT, so the
    pruned answer is identical to scanning everything.
    """
    seqs = store.sequences(time_range=time_range, users=users,
                           events=list(np.asarray(target_codes)))
    return count_events(seqs, target_codes, alphabet_size)


def count_pattern_store(store, dictionary: EventDictionary, pattern: str, *,
                        time_range=None, users=None) -> tuple[int, int]:
    codes = dictionary.codes_matching(pattern)
    if len(codes) == 0:
        return 0, 0
    return count_events_store(store, codes, dictionary.alphabet_size,
                              time_range=time_range, users=users)


# ---------------------------------------------------------------------------
# Oink roll-up aggregations (§3.2): five progressively-wildcarded schemas.
# ---------------------------------------------------------------------------

def build_rollup_keys(dictionary: EventDictionary):
    """Host-side: for each schema, map name id -> dense rollup group id.

    Returns a list (one per schema) of (group_of_name int32 (K,), group
    names list). The JAX aggregation is then a pure segment_sum.
    """
    out = []
    names = dictionary.table.names
    for schema in ROLLUP_SCHEMAS:
        groups: dict[str, int] = {}
        group_of = np.empty(len(names), np.int32)
        for nid, name in enumerate(names):
            key = parse(name).rollup(schema)
            group_of[nid] = groups.setdefault(key, len(groups))
        out.append((group_of, list(groups)))
    return out


@functools.partial(jax.jit, static_argnames=("num_groups",))
def _rollup_counts(name_ids, valid, group_of_name, num_groups):
    gid = jnp.where(valid, group_of_name[name_ids], num_groups)
    return jax.ops.segment_sum(
        jnp.ones_like(gid, jnp.int32), gid, num_segments=num_groups + 1
    )[:num_groups]


def rollup_counts(name_ids, dictionary: EventDictionary, valid=None):
    """All five §3.2 roll-up count tables from one pass over name ids.

    These are the 'top-level metrics presented in our internal dashboard'
    that Oink computes daily without developer intervention.
    """
    name_ids = jnp.asarray(name_ids, jnp.int32)
    if valid is None:
        valid = jnp.ones(name_ids.shape, bool)
    tables = []
    for group_of, group_names in build_rollup_keys(dictionary):
        counts = _rollup_counts(name_ids, jnp.asarray(valid, bool),
                                jnp.asarray(group_of), len(group_names))
        tables.append(dict(zip(group_names, np.asarray(counts).tolist())))
    return tables
