"""Activity collocations (paper §5.4): PMI and Dunning log-likelihood.

"hot dog" for user behaviour: pairs of adjacent events that co-occur far
more than independence predicts — candidate 'interesting patterns of user
activity'. Computed from the sort-based bigram/unigram count tables.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.dictionary import EventDictionary
from ..core.sequences import SessionSequences
from .ngram import ngram_counts, unpack_key


def _xlogx(x: np.ndarray) -> np.ndarray:
    return np.where(x > 0, x * np.log(np.maximum(x, 1e-300)), 0.0)


@dataclass
class Collocation:
    first: int
    second: int
    count: int
    pmi: float
    g2: float


def collocations(seqs: SessionSequences, alphabet_size: int,
                 min_count: int = 5) -> list[Collocation]:
    """All adjacent-pair collocations with PMI and G² scores."""
    bi_keys, bi_counts = ngram_counts(seqs, 2, alphabet_size)
    uni_keys, uni_counts = ngram_counts(seqs, 1, alphabet_size)
    uni = np.zeros(alphabet_size, np.int64)
    uni[uni_keys.astype(np.int64)] = uni_counts
    n = int(bi_counts.sum())  # total bigram windows
    if n == 0:
        return []

    sel = bi_counts >= min_count
    keys, k11 = bi_keys[sel], bi_counts[sel].astype(np.float64)
    first = (keys // alphabet_size).astype(np.int64)
    second = (keys % alphabet_size).astype(np.int64)
    c1 = uni[first].astype(np.float64)   # occurrences of first symbol
    c2 = uni[second].astype(np.float64)

    # PMI (Church & Hanks): log2( P(xy) / (P(x) P(y)) )
    pmi = np.log2(np.maximum(k11 * n / np.maximum(c1 * c2, 1.0), 1e-300))

    # Dunning G² over the 2x2 contingency table of (first?, second?).
    k12 = np.maximum(c1 - k11, 0.0)
    k21 = np.maximum(c2 - k11, 0.0)
    k22 = np.maximum(n - k11 - k12 - k21, 0.0)
    row1, row2 = k11 + k12, k21 + k22
    col1, col2 = k11 + k21, k12 + k22
    g2 = 2.0 * (_xlogx(k11) + _xlogx(k12) + _xlogx(k21) + _xlogx(k22)
                - _xlogx(row1) - _xlogx(row2) - _xlogx(col1) - _xlogx(col2)
                + _xlogx(np.full_like(k11, n)))

    order = np.argsort(-g2)
    return [Collocation(int(first[i]), int(second[i]), int(k11[i]),
                        float(pmi[i]), float(g2[i])) for i in order]


def top_collocations(seqs: SessionSequences, dictionary: EventDictionary,
                     k: int = 20, min_count: int = 5):
    """Human-readable top-k by G² (ranked as Dunning recommends — PMI
    over-weights rare pairs)."""
    out = []
    for c in collocations(seqs, dictionary.alphabet_size, min_count)[:k]:
        out.append(dict(
            first=dictionary.name_of(c.first), second=dictionary.name_of(c.second),
            count=c.count, pmi=round(c.pmi, 3), g2=round(c.g2, 2)))
    return out
