"""BirdBrain-style summary statistics (paper §5.1).

Daily session counts over time, drill-down by client type (first level of
the event namespace) and by bucketed session duration — the dashboard feeds,
computed from the compact session sequences rather than raw logs.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.dictionary import EventDictionary
from ..core.namespace import parse
from ..core.sequences import SessionSequences

# (label, upper bound seconds); paper buckets session durations.
DURATION_BUCKETS = (
    ("<1m", 60), ("1-5m", 300), ("5-15m", 900), ("15-30m", 1800),
    ("30m-1h", 3600), (">1h", np.inf),
)

_MS_PER_DAY = 86_400_000


def client_of_codes(dictionary: EventDictionary) -> tuple[np.ndarray, list[str]]:
    """code -> client id (first namespace level), plus client names."""
    clients: dict[str, int] = {}
    client_of = np.empty(dictionary.alphabet_size, np.int32)
    for code in range(dictionary.alphabet_size):
        c = parse(dictionary.name_of(code)).client
        client_of[code] = clients.setdefault(c, len(clients))
    return client_of, list(clients)


@dataclass
class SummaryReport:
    sessions_per_day: dict[int, int]
    users_per_day: dict[int, int]
    sessions_by_client: dict[str, int]
    duration_histogram: dict[str, int]
    totals: dict = field(default_factory=dict)


def summarize(seqs: SessionSequences,
              dictionary: EventDictionary | None = None) -> SummaryReport:
    days = (np.asarray(seqs.start_ts) // _MS_PER_DAY).astype(np.int64)
    uniq_days, day_counts = np.unique(days, return_counts=True)
    sessions_per_day = {int(d): int(c) for d, c in zip(uniq_days, day_counts)}

    users_per_day = {}
    users = np.asarray(seqs.user_id)
    for d in uniq_days:
        users_per_day[int(d)] = int(len(np.unique(users[days == d])))

    by_client: dict[str, int] = {}
    if dictionary is not None and len(seqs):
        client_of, client_names = client_of_codes(dictionary)
        first_sym = np.clip(seqs.symbols[:, 0], 0, dictionary.alphabet_size - 1)
        cids = client_of[first_sym]
        for cid, cnt in zip(*np.unique(cids, return_counts=True)):
            by_client[client_names[int(cid)]] = int(cnt)

    dur = np.asarray(seqs.duration_s, np.float64)
    hist: dict[str, int] = {}
    lo = -np.inf
    for label, hi in DURATION_BUCKETS:
        hist[label] = int(((dur > lo) & (dur <= hi)).sum())
        lo = hi

    return SummaryReport(
        sessions_per_day=sessions_per_day,
        users_per_day=users_per_day,
        sessions_by_client=by_client,
        duration_histogram=hist,
        totals=seqs.summary(),
    )
