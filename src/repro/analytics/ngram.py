"""n-gram language models over session sequences (paper §5.4).

Sessions are symbol sequences over a finite alphabet, so NLP machinery
applies directly. We reproduce the paper's program: n-gram models with the
Markov assumption, evaluated by cross entropy / perplexity to quantify the
"temporal signal" in user behaviour.

TPU-native counting: windows are packed into integer keys
(``sum code_j * alphabet^(n-1-j)``), sorted, and run-length encoded — the
sort-based group-by again, no host dicts in the hot path. Lookup at eval
time is a vectorized ``searchsorted`` against the sorted key table.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..core.sequences import SessionSequences


@functools.partial(jax.jit, static_argnames=("n", "alphabet_size"))
def _window_keys(symbols, mask, n, alphabet_size):
    """Pack all length-n windows into int64 keys; invalid windows -> -1."""
    s, l = symbols.shape
    sym = jnp.clip(symbols, 0, alphabet_size - 1).astype(jnp.int64)
    key = jnp.zeros((s, l - n + 1), jnp.int64)
    ok = jnp.ones((s, l - n + 1), bool)
    base = jnp.int64(alphabet_size)
    for j in range(n):
        key = key * base + jax.lax.dynamic_slice_in_dim(sym, j, l - n + 1, axis=1)
        ok = ok & jax.lax.dynamic_slice_in_dim(mask, j, l - n + 1, axis=1)
    return jnp.where(ok, key, jnp.int64(-1))


@jax.jit
def _sorted_unique_counts(keys_flat):
    """Sort keys; return (sorted keys, run-start flags, per-key counts at
    run starts). Invalid (-1) keys sort first and are excluded by callers."""
    ks = jnp.sort(keys_flat)
    n = ks.shape[0]
    idx = jnp.arange(n)
    is_start = (idx == 0) | (ks != jnp.roll(ks, 1))
    # run id per element, then counts per run scattered back to run starts
    run_id = jnp.cumsum(is_start.astype(jnp.int32)) - 1
    counts = jax.ops.segment_sum(jnp.ones(n, jnp.int64), run_id, num_segments=n)
    return ks, is_start, counts[run_id]


@functools.partial(jax.jit, static_argnames=("n", "alphabet_size"))
def dense_ngram_counts(symbols, mask, n, alphabet_size):
    """Dense (alphabet_size**n,) count vector of order-n grams — the
    shard-local half of the distributed rollup.

    Unlike ``ngram_counts`` (sparse sort + RLE, host-side), this returns a
    fixed-shape dense histogram so a mesh of shards can merge with one
    ``psum`` — the ``make_distributed_histogram`` pattern applied to packed
    window keys. Intended for the small orders the paper evaluates (n <= 3);
    the table is materialized, so alphabet_size**n must fit in memory.
    ``mask`` is the per-position validity mask (rows past a session's stored
    length, padded session rows, and invalid shard rows are all False).
    """
    size = alphabet_size ** n
    assert size < 2 ** 31, (
        f"dense n-gram table has {size} cells; packed keys are bucketed as "
        "int32, so alphabet_size**n must stay below 2**31 — use the sparse "
        "ngram_counts path for higher orders")
    if symbols.shape[1] < n:
        return jnp.zeros(size, jnp.int32)
    keys = _window_keys(symbols, mask, n, alphabet_size)
    k = jnp.where(keys < 0, size, keys).reshape(-1)  # invalid -> drop bucket
    return jax.ops.segment_sum(
        jnp.ones_like(k, jnp.int32), k.astype(jnp.int32),
        num_segments=size + 1)[:size]


def ngram_counts(seqs: SessionSequences, n: int, alphabet_size: int):
    """(unique_keys int64 (U,), counts int64 (U,)) for all order-n grams."""
    if seqs.max_len < n:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    with enable_x64():
        keys = _window_keys(jnp.asarray(seqs.symbols), jnp.asarray(seqs.mask()),
                            int(n), int(alphabet_size))
        ks, is_start, cnts = _sorted_unique_counts(keys.reshape(-1))
    ks = np.asarray(ks)
    sel = np.asarray(is_start) & (ks >= 0)
    return ks[sel], np.asarray(cnts)[sel]


def ngram_counts_store(store, n: int, alphabet_size: int, *,
                       time_range=None, users=None):
    """N-gram table read through the segment store (no code pruning —
    every session contributes windows; time/user filters still prune)."""
    seqs = store.sequences(time_range=time_range, users=users)
    return ngram_counts(seqs, n, alphabet_size)


def unpack_key(key: int, n: int, alphabet_size: int) -> tuple[int, ...]:
    out = []
    for _ in range(n):
        out.append(int(key % alphabet_size))
        key //= alphabet_size
    return tuple(reversed(out))


@dataclass
class _OrderTable:
    keys: np.ndarray    # sorted unique int64
    counts: np.ndarray  # int64
    total: int

    def lookup(self, query: np.ndarray) -> np.ndarray:
        """Vectorized exact-count lookup (0 for unseen)."""
        pos = np.searchsorted(self.keys, query)
        pos = np.clip(pos, 0, max(len(self.keys) - 1, 0))
        if len(self.keys) == 0:
            return np.zeros(len(query), np.int64)
        hit = self.keys[pos] == query
        return np.where(hit, self.counts[pos], 0)


@dataclass
class NGramLM:
    """Jelinek-Mercer interpolated n-gram model (MLE orders interpolated
    down to uniform): P(w|h) = lam * c(hw)/c(h) + (1-lam) * P_{n-1}(w|h')."""
    n: int
    alphabet_size: int
    tables: list[_OrderTable]   # order 1..n
    lam: float = 0.8

    @staticmethod
    def fit(seqs: SessionSequences, n: int, alphabet_size: int,
            lam: float = 0.8) -> "NGramLM":
        tables = []
        for order in range(1, n + 1):
            keys, counts = ngram_counts(seqs, order, alphabet_size)
            tables.append(_OrderTable(keys, counts, int(counts.sum())))
        return NGramLM(n, alphabet_size, tables, lam)

    def _cond_prob(self, keys_by_order: dict[int, np.ndarray],
                   order: int) -> np.ndarray:
        """P(w|h) for every query position at a given order (vectorized)."""
        uniform = np.full(len(keys_by_order[1]), 1.0 / self.alphabet_size)
        if order == 0:
            return uniform
        gram = self.tables[order - 1].lookup(keys_by_order[order])
        if order == 1:
            hist_count = np.full(len(gram), self.tables[0].total, np.int64)
        else:
            hist = keys_by_order[order] // self.alphabet_size
            hist_count = self.tables[order - 2].lookup(hist)
        mle = np.where(hist_count > 0, gram / np.maximum(hist_count, 1), 0.0)
        lower = self._cond_prob(keys_by_order, order - 1)
        lam = np.where(hist_count > 0, self.lam, 0.0)
        return lam * mle + (1.0 - lam) * lower

    def cross_entropy(self, seqs: SessionSequences) -> float:
        """Bits per symbol under the model (predicting each symbol from its
        n-1 predecessors; the first n-1 symbols of a session use shorter
        histories)."""
        total_bits = 0.0
        total_syms = 0
        # Gather per-position keys for each order in one vectorized pass.
        sym = seqs.symbols
        mask = seqs.mask()
        s, l = sym.shape
        for start_order in range(1, self.n + 1):
            if l < start_order:
                continue
            if start_order < self.n:
                cols = [start_order - 1]  # only the position with short history
            else:
                cols = list(range(self.n - 1, l))
            col_idx = np.asarray(cols)
            keys_by_order = {}
            for order in range(1, start_order + 1):
                key = np.zeros((s, len(cols)), np.int64)
                for j in range(order):
                    key = key * self.alphabet_size + np.clip(
                        sym[:, col_idx - (order - 1) + j], 0,
                        self.alphabet_size - 1)
                keys_by_order[order] = key.reshape(-1)
            valid = mask[:, col_idx].reshape(-1)
            p = self._cond_prob(keys_by_order, start_order)
            p = np.maximum(p, 1e-12)
            total_bits += float(-(np.log2(p) * valid).sum())
            total_syms += int(valid.sum())
        return total_bits / max(total_syms, 1)

    def perplexity(self, seqs: SessionSequences) -> float:
        return float(2.0 ** self.cross_entropy(seqs))
