"""Analytics over session sequences (paper §5): counting, funnels, n-gram
user models, collocations, dashboard summaries."""
from .counting import count_events, count_pattern, count_events_store, \
    count_pattern_store, rollup_counts, make_target_lut, build_rollup_keys
from .funnel import funnel_reach, funnel_reach_store, funnel_reach_users, \
    funnel_from_patterns, build_stage_table, abandonment, reach_histogram
from .ngram import NGramLM, ngram_counts, ngram_counts_store, unpack_key, \
    dense_ngram_counts
from .collocations import collocations, top_collocations, Collocation
from .summary import summarize, SummaryReport, DURATION_BUCKETS

__all__ = [
    "count_events", "count_pattern", "count_events_store",
    "count_pattern_store", "rollup_counts", "make_target_lut",
    "build_rollup_keys", "funnel_reach", "funnel_reach_store",
    "funnel_reach_users", "funnel_from_patterns", "build_stage_table",
    "abandonment", "reach_histogram",
    "NGramLM", "ngram_counts", "ngram_counts_store", "unpack_key",
    "dense_ngram_counts",
    "collocations", "top_collocations", "Collocation",
    "summarize", "SummaryReport", "DURATION_BUCKETS",
]
