"""Data substrate: synthetic log generation, simulated Scribe delivery,
Oink workflow manager, and the LM batch pipeline over session sequences."""
from .loggen import LogGenConfig, GeneratedLog, generate, build_name_table
from .scribe import (ZooKeeperSim, Aggregator, ScribeDaemon, LogMover,
                     DeliveryError, deliver_batch, read_warehouse_hour)
from .oink import Oink, Job, JobTrace, DependencyError
from .pipeline import (SessionBatchPipeline, PipelineConfig, pack_sessions,
                       encode_tokens, lm_vocab_size, synthetic_batch,
                       PAD_ID, BOS_ID, EOS_ID, UNK_ID, NUM_SPECIALS)

__all__ = [
    "LogGenConfig", "GeneratedLog", "generate", "build_name_table",
    "ZooKeeperSim", "Aggregator", "ScribeDaemon", "LogMover",
    "DeliveryError", "deliver_batch", "read_warehouse_hour",
    "Oink", "Job", "JobTrace", "DependencyError",
    "SessionBatchPipeline", "PipelineConfig", "pack_sessions",
    "encode_tokens", "lm_vocab_size", "synthetic_batch",
    "PAD_ID", "BOS_ID", "EOS_ID", "UNK_ID", "NUM_SPECIALS",
]
