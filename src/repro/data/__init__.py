"""Data substrate: log generation, delivery, workflow management, and the
two pipelines that consume the warehouse.

Public API by module:

* ``loggen`` — synthetic client-event corpus with the paper's phenomena
  (Zipf event names, sessions, signup funnels): ``LogGenConfig``,
  ``GeneratedLog``, ``generate``, ``build_name_table``.
* ``scribe`` — simulated at-least-once Scribe delivery into the warehouse
  (§3.1): ``ZooKeeperSim``, ``Aggregator``, ``ScribeDaemon``, ``LogMover``,
  ``DeliveryError``, ``deliver_batch``, ``read_warehouse_hour``.
* ``oink`` — the DAG workflow manager over daily jobs (§3.2): ``Oink``,
  ``Job``, ``JobTrace``, ``DependencyError``.
* ``pipeline`` — single-host LM-batch consumer of *materialized* session
  sequences (deterministic, sharded-by-index, prefetched):
  ``SessionBatchPipeline``, ``PipelineConfig``, ``pack_sessions``,
  ``encode_tokens``, ``lm_vocab_size``, ``synthetic_batch``, and the
  special token ids ``PAD_ID``/``BOS_ID``/``EOS_ID``/``UNK_ID``/
  ``NUM_SPECIALS``.
* ``distpipe`` — the distributed raw-events -> sessions -> rollups pipeline
  over ``repro.dist`` (keyed all_to_all repartition, per-shard
  dedup + sessionize, psum-merged n-gram/funnel rollups):
  ``DistPipelineConfig``, ``DistPipelineResult``,
  ``make_distributed_pipeline``, ``DistributedPipeline``,
  ``single_host_pipeline``, ``SingleHostResult``.
* ``store`` — the unified mega-table segment store (§4.2–4.3): immutable
  columnar segments from micro-batch writes, time-based compaction folding
  closed event segments into session segments, and the metadata-pruning
  ``Store.scan(time_range, users, events)`` query path every consumer
  reads through: ``Store``, ``StoreConfig``, ``Segment``, ``ScanResult``,
  ``ScanStats``, ``CompactionStats``, ``user_shard_mask``,
  ``concat_sequences``, the segment codecs
  ``encode_event_segment``/``decode_event_segment``/
  ``encode_session_segment``/``decode_session_segment``.
* ``streampipe`` — the streaming fast-data tier over the same collectives
  (micro-batch ticks, watermark-closed sessions, incremental psum-merged
  rollup deltas; closed-prefix bit-equal to ``distpipe``):
  ``StreamConfig``, ``StreamResult``, ``TickResult``, ``SingleHostStream``,
  ``StreamPipeline``, ``single_host_stream``, ``make_stream_pipeline``,
  ``build_stream_tick_fn``, ``stream_state_structs``, ``replay``,
  ``split_ticks``, ``closed_prefix_mask``, ``batch_closed_prefix``,
  ``session_multiset``, ``assert_stream_equals_batch``.

``pipeline`` and ``distpipe`` split at the materialization boundary:
``distpipe`` turns the hour's raw event columns into session sequences and
global rollups at mesh scale; ``pipeline`` packs already-materialized
sequences into LM training batches on each host.
"""
from .loggen import LogGenConfig, GeneratedLog, generate, build_name_table
from .scribe import (ZooKeeperSim, Aggregator, ScribeDaemon, LogMover,
                     DeliveryError, deliver_batch, read_warehouse_hour)
from .oink import Oink, Job, JobTrace, DependencyError
from .pipeline import (SessionBatchPipeline, PipelineConfig, pack_sessions,
                       encode_tokens, lm_vocab_size, synthetic_batch,
                       PAD_ID, BOS_ID, EOS_ID, UNK_ID, NUM_SPECIALS)
from .distpipe import (DistPipelineConfig, DistPipelineResult,
                       DistributedPipeline, make_distributed_pipeline,
                       single_host_pipeline, SingleHostResult)
from .store import (Store, StoreConfig, Segment, ScanResult, ScanStats,
                    CompactionStats, user_shard_mask, concat_sequences,
                    encode_event_segment, decode_event_segment,
                    encode_session_segment, decode_session_segment)
from .streampipe import (StreamConfig, StreamResult, TickResult,
                         SingleHostStream, StreamPipeline,
                         single_host_stream, make_stream_pipeline,
                         build_stream_tick_fn, stream_state_structs,
                         replay, split_ticks, closed_prefix_mask,
                         batch_closed_prefix, session_multiset,
                         assert_stream_equals_batch)

__all__ = [
    "LogGenConfig", "GeneratedLog", "generate", "build_name_table",
    "ZooKeeperSim", "Aggregator", "ScribeDaemon", "LogMover",
    "DeliveryError", "deliver_batch", "read_warehouse_hour",
    "Oink", "Job", "JobTrace", "DependencyError",
    "SessionBatchPipeline", "PipelineConfig", "pack_sessions",
    "encode_tokens", "lm_vocab_size", "synthetic_batch",
    "PAD_ID", "BOS_ID", "EOS_ID", "UNK_ID", "NUM_SPECIALS",
    "DistPipelineConfig", "DistPipelineResult", "DistributedPipeline",
    "make_distributed_pipeline", "single_host_pipeline", "SingleHostResult",
    "Store", "StoreConfig", "Segment", "ScanResult", "ScanStats",
    "CompactionStats", "user_shard_mask", "concat_sequences",
    "encode_event_segment", "decode_event_segment",
    "encode_session_segment", "decode_session_segment",
    "StreamConfig", "StreamResult", "TickResult", "SingleHostStream",
    "StreamPipeline", "single_host_stream", "make_stream_pipeline",
    "build_stream_tick_fn", "stream_state_structs", "replay", "split_ticks",
    "closed_prefix_mask", "batch_closed_prefix", "session_multiset",
    "assert_stream_equals_batch",
]
