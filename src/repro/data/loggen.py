"""Synthetic client-event generator.

Produces structured, *behaviourally plausible* client-event streams so the
downstream analytics reproduce the paper's phenomena: Zipf-distributed event
frequencies (the dictionary's variable-length coding needs a skewed
histogram to win), Markov user behaviour (n-gram models find temporal
signal), an embedded signup funnel with per-stage abandonment (§5.3), and
adjacent-event collocations (§5.4).

Generation is vectorized: a (sessions x steps) Markov chain over activity
states, each state emitting events from its own distribution over the
hierarchical namespace.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.events import EventBatch, NameTable

CLIENTS = ("web", "iphone", "android", "ipad")

# Activity states and their Markov transition structure. The signup funnel
# is a chain of states with decreasing continuation probability.
STATES = (
    "home_browse", "mentions", "search_flow", "profile_browse",
    "discover", "who_to_follow",
    "signup_start", "signup_form", "signup_follow", "signup_done",
    "exit",
)
_ST = {s: i for i, s in enumerate(STATES)}

# Per-state event templates: (page, section, component, element, action).
STATE_EVENTS: dict[str, list[tuple[str, float]]] = {
    "home_browse": [
        ("home:timeline:stream:tweet:impression", 8.0),
        ("home:timeline:stream:tweet:click", 1.0),
        ("home:timeline:stream:avatar:profile_click", 0.5),
        ("home:timeline:stream:tweet:expand", 0.7),
        ("home:timeline::scroll_bar:scroll", 2.0),
    ],
    "mentions": [
        ("home:mentions:stream:tweet:impression", 4.0),
        ("home:mentions:stream:avatar:profile_click", 0.8),
        ("home:mentions:stream:tweet:reply", 0.6),
    ],
    "search_flow": [
        ("search:input:search_box:text:search_query", 2.0),
        ("search:results:stream:tweet:impression", 6.0),
        ("search:results:stream:tweet:click", 1.2),
        ("search:results:stream:user:follow", 0.3),
    ],
    "profile_browse": [
        ("profile:tweets:stream:tweet:impression", 5.0),
        ("profile:header:card:follow_button:follow", 0.6),
        ("profile:header:card:avatar:impression", 1.0),
    ],
    "discover": [
        ("discover:trends:list:trend:impression", 3.0),
        ("discover:trends:list:trend:click", 0.8),
        ("discover:stories:stream:story:impression", 2.0),
    ],
    "who_to_follow": [
        ("who_to_follow:suggestions:list:user:impression", 3.0),
        ("who_to_follow:suggestions:list:user:follow", 0.7),
        ("who_to_follow:suggestions:list:user:dismiss", 0.4),
    ],
    "signup_start": [("signup:landing:form:signup_button:click", 1.0)],
    "signup_form":  [("signup:form:form:field:fill", 3.0),
                     ("signup:form:form:submit_button:submit", 1.0)],
    "signup_follow": [("signup:follow_suggestions:list:user:impression", 4.0),
                      ("signup:follow_suggestions:list:user:follow", 1.5)],
    "signup_done": [("signup:complete:page::impression", 1.0)],
    "exit": [("home:timeline::page:unload", 1.0)],
}

# Markov transitions (row-stochastic after normalization).
def _transition_matrix() -> np.ndarray:
    n = len(STATES)
    t = np.zeros((n, n))
    def set_(a, pairs):
        for b, w in pairs:
            t[_ST[a], _ST[b]] = w
    set_("home_browse", [("home_browse", 6.0), ("mentions", 1.0),
                         ("search_flow", 1.0), ("profile_browse", 0.8),
                         ("discover", 0.6), ("who_to_follow", 0.4),
                         ("exit", 1.2)])
    set_("mentions", [("mentions", 3.0), ("home_browse", 1.5),
                      ("profile_browse", 1.0), ("exit", 0.8)])
    set_("search_flow", [("search_flow", 4.0), ("profile_browse", 1.2),
                         ("home_browse", 1.0), ("exit", 0.8)])
    set_("profile_browse", [("profile_browse", 3.0), ("home_browse", 1.5),
                            ("who_to_follow", 0.5), ("exit", 1.0)])
    set_("discover", [("discover", 3.0), ("search_flow", 1.0),
                      ("home_browse", 1.0), ("exit", 0.7)])
    set_("who_to_follow", [("who_to_follow", 2.0), ("profile_browse", 1.2),
                           ("home_browse", 1.0), ("exit", 0.6)])
    # Signup funnel: ~60% continue at each stage (tunable abandonment).
    set_("signup_start", [("signup_form", 1.5), ("exit", 1.0)])
    set_("signup_form", [("signup_form", 1.0), ("signup_follow", 1.5),
                         ("exit", 1.0)])
    set_("signup_follow", [("signup_follow", 1.0), ("signup_done", 1.5),
                           ("exit", 0.8)])
    set_("signup_done", [("home_browse", 3.0), ("exit", 1.0)])
    set_("exit", [("exit", 1.0)])
    return t / t.sum(axis=1, keepdims=True)


@dataclass
class LogGenConfig:
    n_users: int = 500
    sessions_per_user_mean: float = 3.0
    max_steps: int = 48                  # Markov steps per session
    events_per_step_mean: float = 2.0
    signup_fraction: float = 0.15        # sessions entering the funnel
    start_ts_ms: int = 1_700_000_000_000
    horizon_days: int = 2
    mean_gap_s: float = 18.0             # inter-event gap
    long_gap_prob: float = 0.02          # >30 min gap within one cookie
    seed: int = 0


@dataclass
class GeneratedLog:
    batch: EventBatch
    table: NameTable
    # ground truth for test assertions
    n_sessions_true: int = 0
    funnel_entries_true: int = 0


def build_name_table() -> NameTable:
    table = NameTable()
    for client in CLIENTS:
        for events in STATE_EVENTS.values():
            for suffix, _ in events:
                table.intern(f"{client}:{suffix}")
    return table


def generate(cfg: LogGenConfig) -> GeneratedLog:
    rng = np.random.default_rng(cfg.seed)
    table = build_name_table()
    trans = _transition_matrix()
    n_states = len(STATES)

    # Per-state event distributions as (state, client) -> code list + probs.
    state_event_ids = {}
    for s, events in STATE_EVENTS.items():
        for ci, client in enumerate(CLIENTS):
            ids = np.array([table.id_of(f"{client}:{suffix}")
                            for suffix, _ in events])
            w = np.array([w for _, w in events], np.float64)
            state_event_ids[(s, ci)] = (ids, w / w.sum())

    n_sessions = rng.poisson(cfg.sessions_per_user_mean,
                             cfg.n_users).clip(min=0)
    total_sessions = int(n_sessions.sum())
    sess_user = np.repeat(np.arange(cfg.n_users), n_sessions)
    # Stable per-user ids with realistic magnitudes.
    user_ids = (np.arange(cfg.n_users, dtype=np.int64) * 7_919 + 10**12)
    sess_client = rng.choice(len(CLIENTS), total_sessions,
                             p=[0.45, 0.25, 0.22, 0.08])
    # Cookie ids: per (user, device) cookie reused across that user's sessions.
    cookie = (user_ids[sess_user] * 17 + sess_client).astype(np.int64)

    # Markov chain over states, vectorized across sessions.
    start_state = np.where(rng.random(total_sessions) < cfg.signup_fraction,
                           _ST["signup_start"], _ST["home_browse"]).astype(np.int64)
    states = np.empty((total_sessions, cfg.max_steps), np.int64)
    states[:, 0] = start_state
    cum = trans.cumsum(axis=1)
    for t in range(1, cfg.max_steps):
        u = rng.random(total_sessions)
        states[:, t] = (cum[states[:, t - 1]] < u[:, None]).sum(axis=1)

    # Events per step (0 after the chain hits 'exit').
    alive = states != _ST["exit"]
    n_ev = rng.poisson(cfg.events_per_step_mean,
                       (total_sessions, cfg.max_steps)).clip(0, 6) * alive
    # Guarantee at least one event per session at step 0.
    n_ev[:, 0] = np.maximum(n_ev[:, 0], 1)

    # Session start times across the horizon.
    sess_start = (cfg.start_ts_ms
                  + rng.integers(0, cfg.horizon_days * 86_400_000,
                                 total_sessions))

    rows_name, rows_user, rows_sess, rows_ts, rows_ip, rows_init = \
        [], [], [], [], [], []
    ip_of_user = rng.integers(0, 2**31, cfg.n_users, dtype=np.int64)
    funnel_entries = 0
    for si in range(total_sessions):
        ci = int(sess_client[si])
        t_ms = int(sess_start[si])
        if states[si, 0] == _ST["signup_start"]:
            funnel_entries += 1
        for t in range(cfg.max_steps):
            k = int(n_ev[si, t])
            if k == 0:
                if not alive[si, t]:
                    break
                continue
            ids, p = state_event_ids[(STATES[states[si, t]], ci)]
            chosen = rng.choice(ids, size=k, p=p)
            for nid in chosen:
                gap = rng.exponential(cfg.mean_gap_s)
                if rng.random() < cfg.long_gap_prob:
                    gap += 1800 + rng.exponential(600)  # force session split
                t_ms += int(gap * 1000) + 1
                rows_name.append(int(nid))
                rows_user.append(int(user_ids[sess_user[si]]))
                rows_sess.append(int(cookie[si]))
                rows_ts.append(t_ms)
                rows_ip.append(int(ip_of_user[sess_user[si]]))
                rows_init.append(int(rng.random() < 0.9))  # mostly user-initiated

    n = len(rows_name)
    # The warehouse only guarantees *partial* time order (§2): shuffle within
    # coarse chunks to simulate aggregator interleaving.
    perm = np.arange(n)
    chunk = max(1, n // 64)
    for lo in range(0, n, chunk):
        seg = perm[lo:lo + chunk]
        rng.shuffle(seg)

    details = np.array(
        ['{"k":"v"}'] * n, dtype=object)
    batch = EventBatch(
        table=table,
        name_id=np.asarray(rows_name, np.int32)[perm],
        user_id=np.asarray(rows_user, np.int64)[perm],
        session_id=np.asarray(rows_sess, np.int64)[perm],
        ip=np.asarray(rows_ip, np.int64)[perm].astype(np.uint32),
        timestamp=np.asarray(rows_ts, np.int64)[perm],
        initiator=np.asarray(rows_init, np.int8)[perm],
        details=details,
    )
    return GeneratedLog(batch=batch, table=table,
                        n_sessions_true=total_sessions,
                        funnel_entries_true=funnel_entries)
