"""The unified mega-table log store (paper §4.2–4.3).

The paper's endpoint is a single well-formatted log that every analytics
job reads from: raw client events land append-only, session sequences are
materialized once, and common queries never re-scan raw events. This
module is that store as an append-only collection of immutable columnar
**segments**:

* **Event segments** — one per micro-batch write (the log mover's unit).
  Rows are time-sorted; timestamps are delta + varint coded, user/session
  ids zigzag-varint coded, event ids are the dictionary codes
  (``core.dictionary`` frequency order) as unsigned varints.
* **Session segments** — the materialized relation of §4.2. Each session's
  symbol sequence is stored as the paper's UTF-8 string (small code point =
  frequent event, ``core.varint.encode_session``); the metadata columns
  (user, session, ip, start, duration, length) ride along varint-coded.
* **Per-segment metadata** — row/event counts, ``[min_ts, max_ts]`` (for
  session segments a conservative bound covering every event in every
  session), a ``user_shards``-bit presence bitmap over
  ``splitmix64(user) % user_shards`` buckets (the same hash
  ``dist.collectives.shard_of_user`` shards by), and a sparse
  code histogram. Metadata is what ``scan`` prunes on and what the
  catalog (``core.catalog.CatalogBuilder``) folds incrementally.

**Compaction** (`Store.compact(watermark)`) folds closed event segments
into session segments: decode every event segment that can contain a
closed session (``min_ts < watermark``), partition events with
``core.sessionize.closed_prefix_mask`` (re-sessionizing only at segment
boundaries), run the *same* fused sessionizer the batch pipeline runs over
the closed part, and re-encode the open remainder as one residual event
segment. Repeated compactions at monotone watermarks are oracle-equal to
one ``data.distpipe.single_host_pipeline`` pass over the full corpus — the
identical closed-prefix contract the streaming tier proves tick by tick.
Appends are expected to respect the compaction watermark (the log mover /
streaming tier contract); events that arrive below it are counted in
``late_appended`` and still materialize, but as their own late session.

**Scan** (`Store.scan(time_range, users, events)`) is the pruning query
path: segments whose metadata cannot match the filters are skipped before
any decoding (counted per prune reason in ``ScanStats``), surviving
segments decode and apply the exact row filters. Consumers —
``data.pipeline.SessionBatchPipeline.from_store``, the
``analytics.{counting,ngram,funnel}`` store wrappers, and the streaming
tier's closed-session sink — all read through here.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field, replace

import numpy as np

from ..core import varint
from ..core.sequences import SessionSequences
from ..core.sessionize import (DEFAULT_GAP_MS, PAD_CODE, closed_prefix_mask,
                               sessionize)

# Compaction watermark meaning "close everything" (end of day / drain).
# Matches streampipe.WATERMARK_MAX; not full int64 so end+gap can't overflow.
COMPACT_ALL = 1 << 62

EVENT_COLS = ("timestamp", "user_id", "session_id", "code", "ip")
SESSION_COLS = ("start_ts", "user_id", "session_id", "ip", "duration_s",
                "length", "payload_len")


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer — numpy twin of ``dist.collectives.mix64`` so
    segment metadata and the mesh repartition agree on user buckets."""
    x = np.asarray(x).astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def user_shard_mask(user_id, n_shards: int = 64) -> int:
    """Presence bitmap over ``splitmix64(user) % n_shards`` buckets."""
    u = np.asarray(user_id, np.int64)
    if u.size == 0:
        return 0
    shards = np.unique(_mix64(u) % np.uint64(n_shards))
    mask = 0
    for s in shards:
        mask |= 1 << int(s)
    return mask


def _code_counts(codes: np.ndarray) -> dict[int, int]:
    vals, cnts = np.unique(np.asarray(codes, np.int64), return_counts=True)
    return {int(v): int(c) for v, c in zip(vals, cnts)}


@dataclass(frozen=True)
class Segment:
    """One immutable columnar segment + the metadata ``scan`` prunes on.

    An **evicted** segment (``on_disk=True``, via ``Store.evict_to_disk``)
    keeps every metadata field resident — pruning never touches disk — but
    its ``blob`` is empty; ``disk_bytes`` remembers the spilled blob size
    so byte accounting is unchanged. Decoding an evicted segment without
    reloading it first is a loud error, not a silent empty result.
    """
    seg_id: int
    kind: str                 # "events" | "sessions"
    n: int                    # rows (events, or sessions)
    n_events: int             # true events covered (sessions: sum of length)
    min_ts: int               # events: min ts; sessions: min start_ts
    max_ts: int               # conservative upper bound on any event time
    user_mask: int            # user_shards-bit presence bitmap
    code_counts: dict[int, int] = field(repr=False)  # stored symbols only
    col_bytes: dict[str, int] = field(repr=False)
    blob: bytes = field(repr=False)
    on_disk: bool = False     # blob aged out to the spill dir
    disk_bytes: int = 0       # spilled blob size (0 while resident)

    @property
    def nbytes(self) -> int:
        return self.disk_bytes if self.on_disk else len(self.blob)


# ---------------------------------------------------------------------------
# segment codecs
# ---------------------------------------------------------------------------

def _encode_event_blob(t, u, s, c, i) -> tuple[bytes, dict[str, int]]:
    """Time-sorted event columns -> one blob; ts delta-coded."""
    blocks = dict(
        timestamp=varint.encode_ivarint(np.diff(t, prepend=np.int64(0))),
        user_id=varint.encode_ivarint(u),
        session_id=varint.encode_ivarint(s),
        code=varint.encode_uvarint(c),
        ip=varint.encode_ivarint(i),
    )
    return b"".join(blocks[k] for k in EVENT_COLS), \
        {k: len(v) for k, v in blocks.items()}


def encode_event_segment(seg_id: int, user_id, session_id, timestamp, code,
                         ip=None, *, user_shards: int = 64) -> Segment:
    """One micro-batch of raw events -> an immutable time-sorted segment."""
    t = np.asarray(timestamp, np.int64)
    n = len(t)
    order = np.argsort(t, kind="stable")
    t = t[order]
    u = np.asarray(user_id, np.int64)[order]
    s = np.asarray(session_id, np.int64)[order]
    c = np.asarray(code, np.int32)[order]
    i = (np.zeros(n, np.int64) if ip is None
         else np.asarray(ip, np.int64)[order])
    blob, col_bytes = _encode_event_blob(t, u, s, c, i)
    return Segment(
        seg_id=seg_id, kind="events", n=n, n_events=n,
        min_ts=int(t[0]) if n else 0, max_ts=int(t[-1]) if n else 0,
        user_mask=user_shard_mask(u, user_shards),
        code_counts=_code_counts(c), col_bytes=col_bytes, blob=blob)


def decode_event_segment(seg: Segment) -> dict[str, np.ndarray]:
    """Segment -> event columns (time-sorted, as encoded)."""
    assert seg.kind == "events"
    if seg.on_disk:
        raise ValueError(
            f"segment {seg.seg_id} is evicted to disk — reload its blob "
            "before decoding (Store.scan does this transparently)")
    n, off = seg.n, 0
    dt, off = varint.decode_ivarint(seg.blob, n, off)
    u, off = varint.decode_ivarint(seg.blob, n, off)
    s, off = varint.decode_ivarint(seg.blob, n, off)
    c, off = varint.decode_uvarint(seg.blob, n, off)
    i, off = varint.decode_ivarint(seg.blob, n, off)
    return dict(timestamp=np.cumsum(dt, dtype=np.int64),
                user_id=u.astype(np.int64), session_id=s.astype(np.int64),
                code=c.astype(np.int32), ip=i.astype(np.int64))


def encode_session_segment(seg_id: int, seqs: SessionSequences, *,
                           user_shards: int = 64) -> Segment:
    """Materialized sessions -> an immutable segment (row order preserved).

    Payloads are the paper's UTF-8 session strings; ``max_ts`` is the
    conservative bound ``max(start_ts + (duration_s + 1) * 1000)`` — it
    covers every event of every session (duration is floor-seconds), so
    time pruning can never drop a matching segment.
    """
    n = len(seqs)
    payloads = [varint.encode_session(seqs.session_symbols(j))
                for j in range(n)]
    payload_len = np.array([len(p) for p in payloads], np.int64)
    blocks = dict(
        start_ts=varint.encode_ivarint(
            np.diff(np.asarray(seqs.start_ts, np.int64),
                    prepend=np.int64(0))),
        user_id=varint.encode_ivarint(seqs.user_id),
        session_id=varint.encode_ivarint(seqs.session_id),
        ip=varint.encode_ivarint(seqs.ip),
        duration_s=varint.encode_uvarint(seqs.duration_s),
        length=varint.encode_uvarint(seqs.length),
        payload_len=varint.encode_uvarint(payload_len),
    )
    blob = b"".join(blocks[k] for k in SESSION_COLS) + b"".join(payloads)
    col_bytes = {k: len(v) for k, v in blocks.items()}
    col_bytes["payload"] = int(payload_len.sum())
    start = np.asarray(seqs.start_ts, np.int64)
    hi = start + (np.asarray(seqs.duration_s, np.int64) + 1) * 1000
    mask = seqs.mask()
    return Segment(
        seg_id=seg_id, kind="sessions", n=n,
        n_events=int(np.asarray(seqs.length, np.int64).sum()),
        min_ts=int(start.min()) if n else 0,
        max_ts=int(hi.max()) if n else 0,
        user_mask=user_shard_mask(seqs.user_id, user_shards),
        code_counts=_code_counts(np.asarray(seqs.symbols)[mask]),
        col_bytes=col_bytes, blob=blob)


def decode_session_segment(seg: Segment, min_width: int = 0
                           ) -> SessionSequences:
    """Segment -> SessionSequences (row order as encoded; symbol matrix at
    least ``min_width`` wide so callers can concat across segments)."""
    assert seg.kind == "sessions"
    if seg.on_disk:
        raise ValueError(
            f"segment {seg.seg_id} is evicted to disk — reload its blob "
            "before decoding (Store.scan does this transparently)")
    n, off = seg.n, 0
    dstart, off = varint.decode_ivarint(seg.blob, n, off)
    u, off = varint.decode_ivarint(seg.blob, n, off)
    s, off = varint.decode_ivarint(seg.blob, n, off)
    i, off = varint.decode_ivarint(seg.blob, n, off)
    dur, off = varint.decode_uvarint(seg.blob, n, off)
    length, off = varint.decode_uvarint(seg.blob, n, off)
    plen, off = varint.decode_uvarint(seg.blob, n, off)
    plen = plen.astype(np.int64)
    starts = off + np.concatenate([[0], np.cumsum(plen)[:-1]]).astype(np.int64)
    symbol_rows = [varint.decode_session(seg.blob[a: a + l])
                   for a, l in zip(starts, plen)]
    width = max([len(r) for r in symbol_rows], default=0)
    width = max(width, min_width)
    symbols = np.full((n, width), PAD_CODE, np.int32)
    for j, r in enumerate(symbol_rows):
        symbols[j, : len(r)] = r
    return SessionSequences(
        symbols=symbols, length=length.astype(np.int32),
        user_id=u.astype(np.int64), session_id=s.astype(np.int64),
        ip=i.astype(np.int64),
        start_ts=np.cumsum(dstart, dtype=np.int64),
        duration_s=dur.astype(np.int32))


def concat_sequences(parts: list[SessionSequences],
                     min_width: int = 0) -> SessionSequences:
    """Concatenate session relations, padding symbols to a common width."""
    width = max([p.max_len for p in parts] + [min_width])
    if not parts:
        return SessionSequences(
            symbols=np.zeros((0, width), np.int32),
            length=np.zeros(0, np.int32), user_id=np.zeros(0, np.int64),
            session_id=np.zeros(0, np.int64), ip=np.zeros(0, np.int64),
            start_ts=np.zeros(0, np.int64),
            duration_s=np.zeros(0, np.int32))

    def wide(p: SessionSequences) -> np.ndarray:
        if p.max_len == width:
            return p.symbols
        out = np.full((len(p), width), PAD_CODE, np.int32)
        out[:, : p.max_len] = p.symbols
        return out

    return SessionSequences(
        symbols=np.concatenate([wide(p) for p in parts]),
        length=np.concatenate([p.length for p in parts]),
        user_id=np.concatenate([p.user_id for p in parts]),
        session_id=np.concatenate([p.session_id for p in parts]),
        ip=np.concatenate([p.ip for p in parts]),
        start_ts=np.concatenate([p.start_ts for p in parts]),
        duration_s=np.concatenate([p.duration_s for p in parts]))


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StoreConfig:
    """Sessionization semantics + metadata shape of one store.

    ``gap_ms``/``dedup``/``max_len`` must match the pipeline configs for
    the compaction-vs-``single_host_pipeline`` oracle equality to hold;
    ``user_shards`` is the width of the per-segment user presence bitmap.
    """
    gap_ms: int = DEFAULT_GAP_MS
    dedup: bool = True
    max_len: int = 2048
    user_shards: int = 64


@dataclass
class CompactionStats:
    watermark: int
    segments_in: int          # event segments folded
    events_in: int
    sessions_out: int         # closed sessions materialized
    events_closed: int
    residual_events: int      # still-open events re-encoded
    bytes_in: int
    bytes_out: int


@dataclass
class ScanStats:
    segments_total: int
    segments_decoded: int
    pruned_time: int
    pruned_users: int
    pruned_events: int
    rows_decoded: int
    rows_matched: int
    unmaterialized_events: int  # matching events still in event segments
    # RAM-headroom accounting (Store.evict_to_disk): evicted segments this
    # scan *considered* (metadata pruning is free either way) vs. evicted
    # segments it actually had to re-read from disk to decode — the gap is
    # I/O the metadata pruning saved
    segments_on_disk: int = 0
    segments_reloaded: int = 0

    @property
    def segments_pruned(self) -> int:
        return self.pruned_time + self.pruned_users + self.pruned_events


@dataclass
class ScanResult:
    sequences: SessionSequences
    events: dict[str, np.ndarray]
    stats: ScanStats


class Store:
    """Append-only segment store; see module docstring.

    Mutable state is only the segment list and counters — segments
    themselves are immutable, so readers hold no locks and a crashed
    compaction simply leaves the old segments in place (the log-mover
    idempotence story).
    """

    def __init__(self, cfg: StoreConfig = StoreConfig()):
        self.cfg = cfg
        self.segments: list[Segment] = []
        self._next_id = 0
        self.events_appended = 0
        self.late_appended = 0
        self.compaction_watermark = -(1 << 62)
        self.truncated = False
        # RAM-headroom cap (evict_to_disk): None = everything resident
        self.max_resident_segments: int | None = None
        self._spill_dir: str | None = None
        self.segments_evicted = 0     # cumulative blobs aged to disk
        self.segments_reloaded = 0    # cumulative transient re-reads

    def __len__(self) -> int:
        return len(self.segments)

    def _take_id(self) -> int:
        sid, self._next_id = self._next_id, self._next_id + 1
        return sid

    # -- writes ------------------------------------------------------------

    def append_events(self, user_id, session_id, timestamp, code,
                      ip=None) -> Segment:
        """One micro-batch write -> one immutable event segment."""
        t = np.asarray(timestamp, np.int64)
        seg = encode_event_segment(self._take_id(), user_id, session_id,
                                   t, code, ip,
                                   user_shards=self.cfg.user_shards)
        self.segments.append(seg)
        self.events_appended += seg.n
        self.late_appended += int((t < self.compaction_watermark).sum())
        return seg

    def append_sessions(self, seqs: SessionSequences) -> Segment:
        """Already-materialized sessions (the streaming tier's closed
        blocks) -> one immutable session segment."""
        seg = encode_session_segment(self._take_id(), seqs,
                                     user_shards=self.cfg.user_shards)
        self.segments.append(seg)
        self.events_appended += seg.n_events
        self._enforce_residency()
        return seg

    # -- compaction --------------------------------------------------------

    def compact(self, watermark: int | None = None) -> CompactionStats:
        """Fold closed event segments into session segments at
        ``watermark`` (default: close everything).

        Only event segments with ``min_ts < watermark`` decode — a segment
        wholly at or past the watermark can neither contain nor extend a
        closed session (any extender event has ``ts <= end + gap <
        watermark``), so it is skipped untouched.
        """
        wm = COMPACT_ALL if watermark is None else int(watermark)
        wm = max(wm, self.compaction_watermark)
        cand = [g for g in self.segments
                if g.kind == "events" and g.min_ts < wm]
        self.compaction_watermark = wm
        if not cand:
            return CompactionStats(wm, 0, 0, 0, 0, 0, 0, 0)
        cols = [decode_event_segment(g) for g in cand]
        u = np.concatenate([c["user_id"] for c in cols])
        s = np.concatenate([c["session_id"] for c in cols])
        t = np.concatenate([c["timestamp"] for c in cols])
        c_ = np.concatenate([c["code"] for c in cols])
        i = np.concatenate([c["ip"] for c in cols])
        closed = closed_prefix_mask(u, s, t, gap_ms=self.cfg.gap_ms,
                                    watermark=wm)
        # (retry duplicates share all five keys, so a duplicate pair can
        # never straddle the closed/open split — dedup stays exact across
        # compactions)
        n_closed = int(closed.sum())
        sessions_out = 0
        cand_ids = {g.seg_id for g in cand}
        new_segments = [g for g in self.segments
                        if g.seg_id not in cand_ids]
        bytes_out = 0
        if n_closed:
            cap = 1 << max(n_closed - 1, 0).bit_length()
            pad = cap - n_closed

            def col(x, dtype):
                return np.concatenate([np.asarray(x, dtype)[closed],
                                       np.zeros(pad, dtype)])

            sess = sessionize(col(u, np.int64), col(s, np.int64),
                              col(t, np.int64), col(c_, np.int32),
                              col(i, np.int64), np.arange(cap) < n_closed,
                              gap_ms=self.cfg.gap_ms, max_sessions=cap,
                              max_len=self.cfg.max_len,
                              dedup=self.cfg.dedup)
            self.truncated |= bool(np.asarray(sess.truncated))
            seqs = SessionSequences.from_sessionized(sess)
            seg = encode_session_segment(self._take_id(), seqs,
                                         user_shards=self.cfg.user_shards)
            new_segments.append(seg)
            bytes_out += seg.nbytes
            sessions_out = len(seqs)
        n_open = len(u) - n_closed
        if n_open:
            m = ~closed
            seg = encode_event_segment(
                self._take_id(), u[m], s[m], t[m], c_[m], i[m],
                user_shards=self.cfg.user_shards)
            new_segments.append(seg)
            bytes_out += seg.nbytes
        self.segments = new_segments
        self._enforce_residency()
        return CompactionStats(
            watermark=wm, segments_in=len(cand), events_in=len(u),
            sessions_out=sessions_out, events_closed=n_closed,
            residual_events=n_open,
            bytes_in=sum(g.nbytes for g in cand), bytes_out=bytes_out)

    # -- RAM headroom: age cold segments to disk ---------------------------

    def evict_to_disk(self, max_resident_segments: int,
                      path: str | None = None) -> int:
        """Age oldest compacted (session) segments to disk until at most
        ``max_resident_segments`` of them keep their blob in RAM.

        The cap is sticky: future compactions and ``append_sessions``
        keep honoring it, so a long-running store's resident bytes stay
        bounded while its history grows. Only session segments age out —
        event segments are young by construction (compaction folds them
        away) and the next compaction would decode them anyway. Eviction
        writes the blob to ``path`` (the spill dir; required on the first
        call, remembered after) in the exact ``save``-format
        ``seg_<id>.bin`` blob, then drops it from the in-memory segment.
        All pruning metadata stays resident, so ``scan`` still prunes for
        free and only **re-reads the blobs it actually decodes** —
        transiently, the segment stays evicted (counted in
        ``ScanStats.segments_reloaded`` per scan and
        ``Store.segments_reloaded`` cumulatively). Returns the number of
        segments evicted by this call.
        """
        if max_resident_segments < 0:
            raise ValueError(
                f"max_resident_segments must be >= 0, "
                f"got {max_resident_segments}")
        if path is not None:
            self._spill_dir = path
        if self._spill_dir is None:
            raise ValueError(
                "evict_to_disk needs a spill path on the first call")
        self.max_resident_segments = int(max_resident_segments)
        return self._enforce_residency()

    def _enforce_residency(self) -> int:
        """Evict oldest (lowest seg_id) resident session segments beyond
        the cap. No-op until ``evict_to_disk`` sets one."""
        if self.max_resident_segments is None:
            return 0
        resident = [j for j, g in enumerate(self.segments)
                    if g.kind == "sessions" and not g.on_disk]
        resident.sort(key=lambda j: self.segments[j].seg_id)
        n_evict = max(0, len(resident) - self.max_resident_segments)
        os.makedirs(self._spill_dir, exist_ok=True)
        for j in resident[:n_evict]:
            g = self.segments[j]
            fp = os.path.join(self._spill_dir, f"seg_{g.seg_id}.bin")
            with open(fp, "wb") as f:
                f.write(g.blob)
            self.segments[j] = replace(g, blob=b"", on_disk=True,
                                       disk_bytes=len(g.blob))
            self.segments_evicted += 1
        return n_evict

    def _read_spill(self, seg: Segment) -> bytes:
        fp = os.path.join(self._spill_dir, f"seg_{seg.seg_id}.bin")
        with open(fp, "rb") as f:
            blob = f.read()
        if len(blob) != seg.disk_bytes:
            raise IOError(
                f"spill blob for segment {seg.seg_id} is {len(blob)} "
                f"bytes, expected {seg.disk_bytes} — spill dir corrupted?")
        return blob

    def _reload(self, seg: Segment) -> Segment:
        """A transient resident copy of an evicted segment (the stored
        segment stays on disk — reloads never grow resident bytes)."""
        return replace(seg, blob=self._read_spill(seg), on_disk=False,
                       disk_bytes=0)

    # -- the pruning query path --------------------------------------------

    def scan(self, time_range: tuple[int, int] | None = None,
             users=None, events=None, *,
             segment_ids=None, min_width: int = 0) -> ScanResult:
        """Decode only the segments whose metadata can match the filters.

        ``time_range=(lo, hi)`` is inclusive and matches sessions whose
        ``[start_ts, start_ts + duration_s*1000]`` span intersects it (and
        events with ``lo <= ts <= hi``); ``users`` is an id list (segment
        prune via the user-shard bitmap, exact row filter after);
        ``events`` is a code list (segment prune via the code histogram —
        a returned session contains at least one queried code).
        ``segment_ids`` restricts the scan to named segments (the
        streaming tier reads back only its own). Exact filters are in
        ``scan_matches_*`` so tests can assert pruning changes nothing.
        """
        lo, hi = time_range if time_range is not None else (None, None)
        q_user_mask = (user_shard_mask(users, self.cfg.user_shards)
                       if users is not None else None)
        users_arr = (np.asarray(users, np.int64)
                     if users is not None else None)
        events_arr = (np.asarray(events, np.int64)
                      if events is not None else None)
        wanted = set(segment_ids) if segment_ids is not None else None

        stats = ScanStats(0, 0, 0, 0, 0, 0, 0, 0)
        seq_parts: list[SessionSequences] = []
        ev_parts: list[dict[str, np.ndarray]] = []
        for seg in self.segments:
            if wanted is not None and seg.seg_id not in wanted:
                continue
            stats.segments_total += 1
            if seg.on_disk:
                stats.segments_on_disk += 1
            if time_range is not None and (seg.max_ts < lo
                                           or seg.min_ts > hi):
                stats.pruned_time += 1
                continue
            if q_user_mask is not None and not (seg.user_mask & q_user_mask):
                stats.pruned_users += 1
                continue
            if events_arr is not None and not any(
                    int(c) in seg.code_counts for c in events_arr):
                stats.pruned_events += 1
                continue
            if seg.on_disk:
                # survived every metadata prune: pay the disk read, but
                # only transiently — the stored segment stays evicted
                seg = self._reload(seg)
                stats.segments_reloaded += 1
                self.segments_reloaded += 1
            stats.segments_decoded += 1
            stats.rows_decoded += seg.n
            if seg.kind == "sessions":
                seqs = decode_session_segment(seg, min_width=min_width)
                keep = scan_matches_sessions(seqs, time_range, users_arr,
                                             events_arr)
                seq_parts.append(_take_rows(seqs, keep))
                stats.rows_matched += int(keep.sum())
            else:
                cols = decode_event_segment(seg)
                keep = scan_matches_events(cols, time_range, users_arr,
                                           events_arr)
                ev_parts.append({k: v[keep] for k, v in cols.items()})
                n_match = int(keep.sum())
                stats.rows_matched += n_match
                stats.unmaterialized_events += n_match
        ev = ({k: np.concatenate([p[k] for p in ev_parts])
               for k in EVENT_COLS} if ev_parts
              else {k: np.zeros(0, np.int64 if k != "code" else np.int32)
                    for k in EVENT_COLS})
        return ScanResult(
            sequences=concat_sequences(seq_parts, min_width=min_width),
            events=ev, stats=stats)

    def sequences(self, **scan_kwargs) -> SessionSequences:
        """Materialized sequences matching the filters; raises if matching
        events are still un-compacted (the analytics contract)."""
        res = self.scan(**scan_kwargs)
        if res.stats.unmaterialized_events:
            raise ValueError(
                f"{res.stats.unmaterialized_events} matching events are "
                "still in event segments — run Store.compact() before "
                "querying materialized sequences")
        return res.sequences

    # -- bookkeeping -------------------------------------------------------

    def stored_bytes(self) -> dict[str, int]:
        out = {"events": 0, "sessions": 0}
        for seg in self.segments:
            out[seg.kind] += seg.nbytes
        out["total"] = out["events"] + out["sessions"]
        return out

    def summary(self) -> dict:
        by_kind = {"events": 0, "sessions": 0}
        for seg in self.segments:
            by_kind[seg.kind] += 1
        on_disk = sum(1 for seg in self.segments if seg.on_disk)
        return dict(
            segments=len(self.segments),
            event_segments=by_kind["events"],
            session_segments=by_kind["sessions"],
            segments_on_disk=on_disk,
            segments_evicted=self.segments_evicted,
            segments_reloaded=self.segments_reloaded,
            events_appended=self.events_appended,
            late_appended=self.late_appended,
            compaction_watermark=self.compaction_watermark,
            truncated=self.truncated,
            bytes=self.stored_bytes())

    # -- persistence (atomic manifest + one blob per segment) --------------

    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        manifest = dict(
            cfg=dict(gap_ms=self.cfg.gap_ms, dedup=self.cfg.dedup,
                     max_len=self.cfg.max_len,
                     user_shards=self.cfg.user_shards),
            next_id=self._next_id, events_appended=self.events_appended,
            late_appended=self.late_appended,
            compaction_watermark=self.compaction_watermark,
            truncated=self.truncated,
            segments=[dict(
                seg_id=g.seg_id, kind=g.kind, n=g.n, n_events=g.n_events,
                min_ts=g.min_ts, max_ts=g.max_ts, user_mask=g.user_mask,
                code_counts={str(k): v for k, v in g.code_counts.items()},
                col_bytes=g.col_bytes) for g in self.segments])
        for g in self.segments:
            # evicted blobs round-trip through the spill dir, so a saved
            # store is always fully materialized — load() never needs to
            # know the source store was under a residency cap
            blob = self._read_spill(g) if g.on_disk else g.blob
            with open(os.path.join(path, f"seg_{g.seg_id}.bin"), "wb") as f:
                f.write(blob)
        tmp = os.path.join(path, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, os.path.join(path, "manifest.json"))

    @staticmethod
    def load(path: str) -> "Store":
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        store = Store(StoreConfig(**manifest["cfg"]))
        store._next_id = manifest["next_id"]
        store.events_appended = manifest["events_appended"]
        store.late_appended = manifest["late_appended"]
        store.compaction_watermark = manifest["compaction_watermark"]
        store.truncated = manifest["truncated"]
        for m in manifest["segments"]:
            with open(os.path.join(path, f"seg_{m['seg_id']}.bin"),
                      "rb") as f:
                blob = f.read()
            store.segments.append(Segment(
                seg_id=m["seg_id"], kind=m["kind"], n=m["n"],
                n_events=m["n_events"], min_ts=m["min_ts"],
                max_ts=m["max_ts"], user_mask=m["user_mask"],
                code_counts={int(k): v
                             for k, v in m["code_counts"].items()},
                col_bytes=m["col_bytes"], blob=blob))
        return store


# ---------------------------------------------------------------------------
# exact row filters (shared by scan and the pruning-correctness tests)
# ---------------------------------------------------------------------------

def scan_matches_sessions(seqs: SessionSequences,
                          time_range, users_arr, events_arr) -> np.ndarray:
    """Row mask: the exact predicate ``scan``'s session filters implement."""
    keep = np.ones(len(seqs), bool)
    if time_range is not None:
        lo, hi = time_range
        start = np.asarray(seqs.start_ts, np.int64)
        end = start + np.asarray(seqs.duration_s, np.int64) * 1000
        keep &= (start <= hi) & (end >= lo)
    if users_arr is not None:
        keep &= np.isin(seqs.user_id, users_arr)
    if events_arr is not None:
        hit = np.isin(seqs.symbols, events_arr) & seqs.mask()
        keep &= hit.any(axis=1)
    return keep


def scan_matches_events(cols: dict[str, np.ndarray],
                        time_range, users_arr, events_arr) -> np.ndarray:
    keep = np.ones(len(cols["timestamp"]), bool)
    if time_range is not None:
        lo, hi = time_range
        keep &= (cols["timestamp"] >= lo) & (cols["timestamp"] <= hi)
    if users_arr is not None:
        keep &= np.isin(cols["user_id"], users_arr)
    if events_arr is not None:
        keep &= np.isin(cols["code"], events_arr)
    return keep


def _take_rows(seqs: SessionSequences, keep: np.ndarray) -> SessionSequences:
    return SessionSequences(
        symbols=seqs.symbols[keep], length=seqs.length[keep],
        user_id=seqs.user_id[keep], session_id=seqs.session_id[keep],
        ip=seqs.ip[keep], start_ts=seqs.start_ts[keep],
        duration_s=seqs.duration_s[keep])
