"""Simulated Scribe delivery infrastructure (paper §2).

Faithful *protocol* simulation of Figure 1 — in-process, since this
container has no cluster, but every robustness mechanism is real code:

* ``ZooKeeperSim`` — ephemeral-znode registry; aggregators register at a
  fixed location, daemons discover live aggregators and re-discover when
  their aggregator's session dies.
* ``ScribeDaemon`` — per-host; sends (category, message) entries, buffers on
  local disk when no aggregator accepts (HDFS-outage behaviour), retries.
* ``Aggregator`` — merges per-category streams, writes compressed hourly
  files into the per-datacenter *staging* directory; crash-restart capable.
* ``LogMover`` — sanity-checks, dedups (at-least-once delivery upstream ->
  exactly-once warehouse), merges many small files into few big ones, and
  **atomically slides an hour of logs** into the warehouse
  (``/logs/client_events/YYYY/MM/DD/HH``) only after all datacenters that
  produce the category have transferred.

Fault injection: aggregator crash probability per send, staging-outage
windows. The integration test drives thousands of messages through random
failures and asserts exactly-once, loss-free arrival.
"""
from __future__ import annotations

import gzip
import json
import os
import time
from dataclasses import dataclass, field

import numpy as np


class DeliveryError(RuntimeError):
    pass


class ZooKeeperSim:
    """Ephemeral-znode registry: /scribe/aggregators/<name> -> endpoint."""

    def __init__(self):
        self._znodes: dict[str, "Aggregator"] = {}

    def register_ephemeral(self, name: str, agg: "Aggregator") -> None:
        self._znodes[name] = agg

    def session_closed(self, name: str) -> None:
        self._znodes.pop(name, None)

    def live_aggregators(self) -> list["Aggregator"]:
        return [a for a in self._znodes.values() if a.alive]


@dataclass
class Aggregator:
    """Co-located with the staging cluster; merges and stages hourly files."""
    name: str
    datacenter: str
    staging_dir: str
    zk: ZooKeeperSim
    rng: np.random.Generator
    crash_prob: float = 0.0
    alive: bool = True
    _buffers: dict[tuple[str, int], list[str]] = field(default_factory=dict)
    seq: int = 0

    def __post_init__(self):
        self.zk.register_ephemeral(self.name, self)

    def append(self, category: str, hour: int, messages: list[str]) -> None:
        if not self.alive:
            raise DeliveryError(f"{self.name} is down")
        if self.rng.random() < self.crash_prob:
            # Crash mid-send: with 50% probability the entries hit the
            # durable local buffer before the ack was lost — the daemon will
            # retry and the log mover's dedup absorbs the duplicates.
            if self.rng.random() < 0.5:
                self._buffers.setdefault((category, hour), []).extend(messages)
            self.crash()
            raise DeliveryError(f"{self.name} crashed mid-send")
        self._buffers.setdefault((category, hour), []).extend(messages)

    def flush(self) -> None:
        """Write merged per-category hourly files (gzip'd, like the paper's
        on-the-fly compression)."""
        if not self.alive:
            return
        for (category, hour), msgs in list(self._buffers.items()):
            if not msgs:
                continue
            d = os.path.join(self.staging_dir, self.datacenter, category,
                             str(hour))
            os.makedirs(d, exist_ok=True)
            path = os.path.join(d, f"{self.name}-{self.seq:06d}.jsonl.gz")
            self.seq += 1
            with gzip.open(path + ".tmp", "wt") as f:
                f.write("\n".join(msgs) + "\n")
            os.replace(path + ".tmp", path)
            self._buffers[(category, hour)] = []

    def crash(self) -> None:
        """Aggregators buffer on local disk (§2), so acked-but-unflushed
        entries survive the crash; only the ZooKeeper session dies."""
        self.alive = False
        self.zk.session_closed(self.name)

    def restart(self) -> None:
        self.alive = True
        self.zk.register_ephemeral(self.name, self)


@dataclass
class ScribeDaemon:
    """Runs on every production host; writes (category, message) entries."""
    host: str
    zk: ZooKeeperSim
    rng: np.random.Generator
    local_buffer: list[tuple[str, int, str]] = field(default_factory=list)
    max_retries: int = 8
    sent: int = 0

    def log(self, category: str, hour: int, message: str) -> None:
        self.local_buffer.append((category, hour, message))

    def drain(self) -> None:
        """Send buffered entries to a live aggregator; on failure, discover
        another via ZooKeeper (paper: 'simply check ZooKeeper again')."""
        if not self.local_buffer:
            return
        by_bucket: dict[tuple[str, int], list[str]] = {}
        for category, hour, msg in self.local_buffer:
            by_bucket.setdefault((category, hour), []).append(msg)
        remaining = dict(by_bucket)
        for _ in range(self.max_retries):
            if not remaining:
                break
            live = self.zk.live_aggregators()
            if not live:
                break  # keep buffering locally (HDFS-outage behaviour)
            agg = live[int(self.rng.integers(len(live)))]
            done = []
            for bucket, msgs in remaining.items():
                try:
                    agg.append(bucket[0], bucket[1], msgs)
                    self.sent += len(msgs)
                    done.append(bucket)
                except DeliveryError:
                    break  # rediscover on next attempt
            for b in done:
                remaining.pop(b)
        self.local_buffer = [
            (c, h, m) for (c, h), msgs in remaining.items() for m in msgs]


@dataclass
class LogMover:
    """Staging -> warehouse, with dedup, merge, and atomic hourly commit."""
    staging_dir: str
    warehouse_dir: str
    datacenters: list[str]

    def move_hour(self, category: str, hour: int) -> dict:
        """Slide one hour into the warehouse. Returns stats. Idempotent."""
        final_dir = os.path.join(self.warehouse_dir, category, str(hour))
        marker = os.path.join(final_dir, "_COMPLETE")
        if os.path.exists(marker):
            return dict(skipped=True)

        # 1. All producing datacenters must have transferred (paper: "ensures
        #    ... all datacenters ... have transferred their logs").
        staged = []
        for dc in self.datacenters:
            d = os.path.join(self.staging_dir, dc, category, str(hour))
            if not os.path.isdir(d):
                raise DeliveryError(
                    f"datacenter {dc} has not staged {category}/{hour}")
            staged.extend(os.path.join(d, f) for f in sorted(os.listdir(d)))

        # 2. Sanity check + dedup by message id (upstream is at-least-once).
        seen: set[str] = set()
        rows: list[str] = []
        dupes = 0
        for path in staged:
            with gzip.open(path, "rt") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    mid = json.loads(line)["mid"]
                    if mid in seen:
                        dupes += 1
                        continue
                    seen.add(mid)
                    rows.append(line)

        # 3. Merge many small files into a few big ones; atomic rename commit.
        tmp_dir = final_dir + ".tmp"
        os.makedirs(tmp_dir, exist_ok=True)
        per_file = max(1, (len(rows) + 3) // 4)
        for i in range(0, max(len(rows), 1), per_file):
            with gzip.open(os.path.join(tmp_dir, f"part-{i // per_file:05d}.jsonl.gz"),
                           "wt") as f:
                f.write("\n".join(rows[i:i + per_file]) + "\n")
        os.makedirs(os.path.dirname(final_dir), exist_ok=True)
        os.replace(tmp_dir, final_dir)
        with open(marker, "w") as f:
            f.write(str(time.time()))
        return dict(skipped=False, messages=len(rows), dupes=dupes,
                    files_in=len(staged))


def read_warehouse_hour(warehouse_dir: str, category: str, hour: int) -> list[dict]:
    d = os.path.join(warehouse_dir, category, str(hour))
    if not os.path.exists(os.path.join(d, "_COMPLETE")):
        raise DeliveryError(f"{category}/{hour} not committed")
    rows = []
    for fname in sorted(os.listdir(d)):
        if not fname.endswith(".jsonl.gz"):
            continue
        with gzip.open(os.path.join(d, fname), "rt") as f:
            rows.extend(json.loads(l) for l in f if l.strip())
    return rows


def deliver_batch(batch, staging_dir: str, warehouse_dir: str, *,
                  n_daemons: int = 8, n_aggregators: int = 3,
                  n_datacenters: int = 2, crash_prob: float = 0.05,
                  category: str = "client_events", seed: int = 0) -> dict:
    """End-to-end delivery of an EventBatch through the simulated pipeline.

    Returns stats including the warehouse row count; raises if any message
    is lost. Events are assigned to daemons round-robin (they originate on
    many production hosts) and to datacenters by daemon.
    """
    rng = np.random.default_rng(seed)
    zk_by_dc = {f"dc{d}": ZooKeeperSim() for d in range(n_datacenters)}
    aggs = []
    for d in range(n_datacenters):
        for a in range(n_aggregators):
            aggs.append(Aggregator(
                name=f"dc{d}-agg{a}", datacenter=f"dc{d}",
                staging_dir=staging_dir, zk=zk_by_dc[f"dc{d}"],
                rng=np.random.default_rng(seed + 100 + d * 10 + a),
                crash_prob=crash_prob))
    daemons = [ScribeDaemon(host=f"host{i}", zk=zk_by_dc[f"dc{i % n_datacenters}"],
                            rng=np.random.default_rng(seed + i))
               for i in range(n_daemons)]

    hours = np.asarray(batch.timestamp) // 3_600_000
    hour0 = int(hours.min())
    for i in range(len(batch)):
        ev = batch.event_at(i)
        msg = json.dumps(dict(mid=f"m{i}", **json.loads(ev.to_json())))
        daemons[i % n_daemons].log(category, int(hours[i]), msg)

    # Drain with interleaved crash/restart churn. Daemons buffer locally and
    # retry until everything is acked (the paper's local-disk buffering);
    # the round cap only guards against a coding bug, not a policy.
    max_rounds = 200
    for round_ in range(max_rounds):
        for dmn in daemons:
            dmn.drain()
        for agg in aggs:
            if not agg.alive and rng.random() < 0.7:
                agg.restart()
            agg.flush()
        if not any(d.local_buffer for d in daemons):
            break
    # Recovery sweep: restart every aggregator and flush the durable local
    # buffers — a crashed aggregator still holds acked entries on disk, and
    # losing them would break the delivery guarantee.
    for agg in aggs:
        if not agg.alive:
            agg.restart()
        agg.flush()
    undelivered = sum(len(d.local_buffer) for d in daemons)

    mover = LogMover(staging_dir, warehouse_dir,
                     [f"dc{d}" for d in range(n_datacenters)])
    stats = dict(undelivered=undelivered, hours={}, messages=0, dupes=0)
    for hour in sorted(set(int(h) for h in hours)):
        # make sure every dc dir exists even if it produced nothing this hour
        for d in range(n_datacenters):
            os.makedirs(os.path.join(staging_dir, f"dc{d}", category,
                                     str(hour)), exist_ok=True)
        s = mover.move_hour(category, hour)
        stats["hours"][hour] = s
        stats["messages"] += s.get("messages", 0)
        stats["dupes"] += s.get("dupes", 0)
    return stats
