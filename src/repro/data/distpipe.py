"""Distributed multi-stage log pipeline over ``repro.dist`` (§4–§5 at mesh
scale).

The paper's claim is that one unified log format plus pre-materialized
session sequences turns ad-hoc per-query scans into a reusable pipeline:
client events -> sessionize -> session sequences -> rollups. This module is
that pipeline as ONE composable sharded dataflow — three shard_map stages
sharing the ``repro.dist`` primitives, replacing the single-host numpy path
as the scalable entry point (``data/pipeline.py`` stays as the LM-batch
consumer of the materialized sequences):

* **Stage 1 — keyed repartition.** Each ``data``-axis shard holds an
  arbitrary slice of the hour's raw event columns (exactly how the log
  mover deposits them). Rows are bucketed by ``shard_of_user`` and an
  ``all_to_all`` performs the keyed shuffle (``dist.collectives
  .keyed_all_to_all``) — all events of a user land on one shard, so
  sessions never straddle shards. Fixed-capacity bucketing counts (never
  silently drops) overflow.
* **Stage 2 — dedup + sessionize.** Scribe delivery is at-least-once;
  row-level retry duplicates survive into the warehouse. Each shard clears
  them with ``core.sessionize.mark_duplicate_events`` and runs the fused
  sort + segment sessionizer on its now-complete per-user slice.
* **Stage 3 — sharded rollups.** Fixed-shape shard-local aggregates merged
  with one ``psum`` tree each (the ``make_distributed_histogram`` pattern):
  dense n-gram counts over packed window keys
  (``analytics.ngram.dense_ngram_counts``) and the funnel-automaton reach
  table (``analytics.funnel.reach_histogram``). Session tensors stay
  sharded (gathered lazily by ``DistPipelineResult.to_sequences``).

On a host-local (1, N) mesh the outputs are bit-equal to the single-host
oracle path (``single_host_pipeline``); tests/test_distpipe.py holds that
equivalence including ragged (non-divisible) input sizes, which the wrapper
handles by padding with invalid rows spread round-robin across shards.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.sharding import Mesh, PartitionSpec as P

from ..analytics.funnel import build_stage_table, funnel_reach, \
    reach_histogram
from ..analytics.ngram import dense_ngram_counts, ngram_counts
from ..core.sequences import SessionSequences
from ..core.sessionize import DEFAULT_GAP_MS, mark_duplicate_events, \
    sessionize, _sessionize
from ..dist.collectives import keyed_all_to_all, shard_of_user
from ..dist.compat import shard_map, use_mesh

SESSION_FIELDS = ("symbols", "length", "user_id", "session_id", "ip",
                  "start_ts", "duration_s", "num_sessions", "num_events",
                  "truncated")


@dataclass(frozen=True)
class DistPipelineConfig:
    """Static shape/semantics knobs of one pipeline instance.

    ``capacity_factor`` sizes the per-destination repartition buckets
    relative to a perfectly uniform split (production sizes this from the
    previous histogram job); overflow is counted in ``dropped``, and the
    caller re-runs with a larger factor. ``alphabet_size ** ngram_n`` must
    fit in memory — the rollup is a dense mergeable histogram.
    """
    alphabet_size: int
    max_sessions_per_shard: int
    max_len: int
    axis: str = "data"
    gap_ms: int = DEFAULT_GAP_MS
    capacity_factor: float = 2.0
    dedup: bool = True
    ngram_n: int = 2


@dataclass
class DistPipelineResult:
    """Pipeline outputs: sharded session tensors + merged global rollups.

    ``sessions`` fields carry a leading (n_shards,) dim; rows past
    ``sessions["num_sessions"][shard]`` are padding. ``ngram_counts`` is the
    dense (alphabet_size**ngram_n,) global count vector; ``funnel_reach``
    matches ``analytics.funnel.funnel_reach`` output (None when the pipeline
    was built without stages). ``dropped`` counts rows lost to repartition
    capacity overflow (0 unless ``capacity_factor`` was too small).
    """
    sessions: dict[str, np.ndarray]
    ngram_counts: np.ndarray
    funnel_reach: list[tuple[int, int]] | None
    dropped: int
    truncated: bool

    def num_sessions(self) -> int:
        return int(self.sessions["num_sessions"].sum())

    def to_sequences(self) -> SessionSequences:
        """Gather the sharded sessions into one host-side relation (shard
        order, per-shard (user, session, start) order)."""
        ns = self.sessions["num_sessions"]
        parts = {name: [self.sessions[name][sh, : int(ns[sh])]
                        for sh in range(len(ns))]
                 for name in ("symbols", "length", "user_id", "session_id",
                              "ip", "start_ts", "duration_s")}
        return SessionSequences(
            **{k: np.concatenate(v) for k, v in parts.items()})


def build_pipeline_fn(mesh: Mesh, cfg: DistPipelineConfig, n_stages: int):
    """The shard_map-ed three-stage dataflow, un-jitted.

    Exposed separately from ``make_distributed_pipeline`` so the dry-run
    harness can ``jit(...).lower()`` it with ShapeDtypeStructs on the
    production mesh (launch/dryrun.py --pipeline) without allocating the
    hour's event columns.

    Takes ``(user_id, session_id, timestamp, code, ip, valid, stage_table)``
    — all int64/int32/bool columns sharded on the leading dim over
    ``cfg.axis``, stage_table replicated — and returns
    ``(sessions, ngram_counts, reach, dropped)``.
    """
    axis, n_shards = cfg.axis, mesh.shape[cfg.axis]

    def local_fn(user_id, session_id, timestamp, code, ip, valid, stage_tab):
        # ---- stage 1: keyed all_to_all repartition by user ----
        n_local = user_id.shape[0]
        capacity = int(np.ceil(n_local * cfg.capacity_factor / n_shards))
        idx = jnp.arange(n_local, dtype=jnp.int32)
        # Padding/invalid rows are spread round-robin so they never crowd
        # one destination's capacity.
        dest = jnp.where(valid, shard_of_user(user_id, n_shards),
                         idx % n_shards)
        cols = dict(user_id=user_id, session_id=session_id,
                    timestamp=timestamp, code=code, ip=ip,
                    valid=valid.astype(jnp.int32))
        flat, dropped = keyed_all_to_all(cols, dest, axis, n_shards, capacity)
        # Received padding rows: zero-initialized buckets have valid=0.
        valid_r = flat["valid"].astype(bool)

        # ---- stage 2: within-user dedup + sessionize ----
        if cfg.dedup:
            valid_r = mark_duplicate_events(
                flat["user_id"], flat["session_id"], flat["timestamp"],
                flat["code"], flat["ip"], valid_r)
        sess = _sessionize(
            flat["user_id"], flat["session_id"], flat["timestamp"],
            flat["code"], flat["ip"], valid_r,
            gap_ms=cfg.gap_ms, max_sessions=cfg.max_sessions_per_shard,
            max_len=cfg.max_len)

        # ---- stage 3: sharded rollups, one psum tree each ----
        stored = jnp.minimum(sess["length"], cfg.max_len)
        mask = jnp.arange(cfg.max_len)[None, :] < stored[:, None]
        grams = dense_ngram_counts(sess["symbols"], mask, cfg.ngram_n,
                                   cfg.alphabet_size)
        grams = jax.lax.psum(grams, axis)
        if n_stages:
            reach = jax.lax.psum(
                reach_histogram(sess["symbols"], mask, stage_tab, n_stages),
                axis)
        else:
            reach = jnp.zeros((0,), jnp.int32)
        total_dropped = jax.lax.psum(dropped, axis)
        sess = {k: v[None] for k, v in sess.items()}
        return sess, grams, reach, total_dropped[None]

    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(axis),) * 6 + (P(),),
        out_specs=({k: P(axis) for k in SESSION_FIELDS}, P(), P(), P(axis)))


class DistributedPipeline:
    """Callable wrapper: host columns in, ``DistPipelineResult`` out.

    Handles ragged inputs (pads each column to a multiple of the shard
    count with invalid rows), int64 promotion under ``enable_x64``, and
    mesh activation. ``self.fn`` is the raw shard_map-ed dataflow for
    callers that manage jit/lowering themselves (dry-run harness).
    """

    def __init__(self, mesh: Mesh, cfg: DistPipelineConfig, stages=None):
        self.mesh = mesh
        self.cfg = cfg
        self.stage_table = (None if stages is None else
                            build_stage_table(stages, cfg.alphabet_size))
        n_stages = 0 if self.stage_table is None else len(self.stage_table)
        self.fn = build_pipeline_fn(mesh, cfg, n_stages)
        self._jitted = jax.jit(self.fn)

    def __call__(self, user_id, session_id, timestamp, code, ip=None,
                 valid=None) -> DistPipelineResult:
        cfg = self.cfg
        n = len(user_id)
        n_shards = self.mesh.shape[cfg.axis]
        if ip is None:
            ip = np.zeros(n, np.int64)
        if valid is None:
            valid = np.ones(n, bool)
        pad = (-n) % n_shards

        def col(x, dtype):
            x = np.asarray(x, dtype)
            return np.concatenate([x, np.zeros(pad, dtype)]) if pad else x

        table = (np.zeros((0, cfg.alphabet_size), bool)
                 if self.stage_table is None else self.stage_table)
        with enable_x64():
            with use_mesh(self.mesh):
                sess, grams, reach, dropped = self._jitted(
                    jnp.asarray(col(user_id, np.int64)),
                    jnp.asarray(col(session_id, np.int64)),
                    jnp.asarray(col(timestamp, np.int64)),
                    jnp.asarray(col(code, np.int32)),
                    jnp.asarray(col(ip, np.int64)),
                    jnp.asarray(col(valid, bool)),
                    jnp.asarray(table))
        sess = {k: np.asarray(v) for k, v in sess.items()}
        return DistPipelineResult(
            sessions=sess,
            ngram_counts=np.asarray(grams).astype(np.int64),
            funnel_reach=(None if self.stage_table is None else
                          [(j, int(c)) for j, c in enumerate(np.asarray(reach))]),
            dropped=int(np.asarray(dropped)[0]),
            truncated=bool(np.asarray(sess["truncated"]).any()))


def make_distributed_pipeline(mesh: Mesh, cfg: DistPipelineConfig,
                              stages=None) -> DistributedPipeline:
    """Build the distributed pipeline over ``mesh[cfg.axis]``.

    ``stages`` is an optional funnel spec — a list of per-stage code sets
    (as produced by ``EventDictionary.codes_matching``); omit it to skip the
    funnel rollup.
    """
    return DistributedPipeline(mesh, cfg, stages)


@dataclass
class SingleHostResult:
    """Oracle-path outputs, field-compatible with ``DistPipelineResult``."""
    sequences: SessionSequences
    ngram_counts: np.ndarray
    funnel_reach: list[tuple[int, int]] | None
    truncated: bool

    def num_sessions(self) -> int:
        return len(self.sequences)

    def to_sequences(self) -> SessionSequences:
        return self.sequences


def single_host_pipeline(user_id, session_id, timestamp, code, ip=None,
                         valid=None, *, cfg: DistPipelineConfig,
                         stages=None, max_sessions: int | None = None
                         ) -> SingleHostResult:
    """The same dedup -> sessionize -> n-gram/funnel dataflow on one host —
    the equivalence oracle for the distributed pipeline (and the
    single-host baseline in benchmarks/pipeline_tput.py)."""
    s = sessionize(user_id, session_id, timestamp, code, ip, valid,
                   gap_ms=cfg.gap_ms, dedup=cfg.dedup,
                   max_sessions=max_sessions, max_len=cfg.max_len)
    seqs = SessionSequences.from_sessionized(s)
    keys, counts = ngram_counts(seqs, cfg.ngram_n, cfg.alphabet_size)
    dense = np.zeros(cfg.alphabet_size ** cfg.ngram_n, np.int64)
    dense[keys] = counts
    reach = (None if stages is None else
             funnel_reach(seqs, stages, cfg.alphabet_size))
    return SingleHostResult(sequences=seqs, ngram_counts=dense,
                            funnel_reach=reach,
                            truncated=bool(np.asarray(s.truncated)))
