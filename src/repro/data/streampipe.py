"""Streaming "fast data" ingestion: incremental sessionize + online rollups.

``data/distpipe.py`` is batch-oriented — a closed hour of client events in,
session sequences and rollups out. Both Twitter follow-ups push the same
unified-logging infrastructure to seconds-level latency: the real-time
related-query architecture (arxiv 1210.7350) sessionizes in-flight, and
Loginson (arxiv 1703.02602) puts a buffered transform-and-load tier in
front of the store. This module is that tier over the existing
``repro.dist`` collectives:

* **Ring buffer of open sessions.** Each shard owns a fixed-capacity,
  device-resident table of open sessions keyed by user: per-slot
  ``(user_id, session_id, length)`` plus ``(max_open, max_len)`` grids of
  symbols, event timestamps, and event ips (the per-event grids are what
  make exact out-of-order merging possible — a late-but-in-watermark event
  is re-sorted into its session, not appended).
* **Micro-batch ticks.** Each tick repartitions its new events with the
  same keyed ``all_to_all`` the batch pipeline uses
  (``dist.collectives.keyed_all_to_all``), drops-and-counts events older
  than the watermark in force at arrival, then re-runs the fused
  sort + segment sessionizer (``core.sessionize._sessionize``) over
  (flattened ring events ∪ new events). Because it is the *same* kernel
  the batch path runs, closed-prefix bit-equality is by construction, not
  by reimplementation. Per-tick cost is O(open events + tick events) —
  independent of how much history has already been folded away.
* **Watermark semantics.** The watermark is monotone; by default it
  trails the max event time seen by ``allowed_lateness_ms`` (explicit
  ``tick(..., watermark=)`` overrides, clamped monotone). Events with
  ``ts < watermark`` at arrival are late: dropped and counted. A session
  closes when ``last_event_ts + gap_ms < watermark`` — no acceptable
  future event can extend it, so its contribution is final (the paper's
  30-minute gap crossing the watermark).
* **Incremental rollup deltas.** Closed sessions emit dense n-gram and
  funnel-reach deltas (``analytics.ngram.dense_ngram_counts``,
  ``analytics.funnel.reach_histogram``), psum-merged across shards and
  accumulated into running totals host-side. Integer histograms make the
  fold exact: totals after N ticks are bit-equal to one batch rollup over
  the same closed sessions.
* **Overflow accounting.** Repartition capacity overflow and ring
  overflow (more open sessions than ``max_open``) drop whole rows /
  sessions deterministically and are *counted*, never silent — surviving
  sessions are unaffected.

Oracle contract (tests/test_streampipe.py, ``stream_tput`` benchmark row):
replaying any event stream tick-by-tick, the closed sessions and running
rollup totals at every watermark are bit-equal to
``data.distpipe.single_host_pipeline`` run over the *closed prefix* of the
accepted events (``closed_prefix_mask``). Cross-tick exact-retry dedup is
exact too: a duplicate of an open-session event is removed against the
ring (the ring keeps full per-event keys), and a duplicate of an
already-closed event is necessarily late (its timestamp predates the
watermark that closed the session) so it is dropped either way.

Truncation caveat: a session longer than ``max_len`` keeps only its first
``max_len`` events in the ring, so subsequent merges cannot see the tail;
``truncated`` is flagged sticky and closed-prefix equality is only claimed
for untruncated streams (same contract as the batch pipeline's caps).
"""
from __future__ import annotations

import collections
import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.sharding import Mesh, PartitionSpec as P

from ..analytics.funnel import build_stage_table, reach_histogram
from ..analytics.ngram import dense_ngram_counts
from ..core.sequences import SessionSequences
from ..core.sessionize import (DEFAULT_GAP_MS, PAD_CODE, _I64_MAX,
                               _sessionize, closed_prefix_mask,
                               mark_duplicate_events)
from ..dist.collectives import keyed_all_to_all, shard_of_user
from ..dist.compat import shard_map, use_mesh
from .distpipe import DistPipelineConfig, SingleHostResult, \
    single_host_pipeline
from .store import Store, StoreConfig

# Initial watermark / flush watermark. Not the full int64 range so that
# ``end_ts + gap_ms`` can never overflow next to them.
WATERMARK_MIN = -(1 << 62)
WATERMARK_MAX = 1 << 62

RING_FIELDS = ("user_id", "session_id", "length", "symbols", "event_ts",
               "event_ip", "valid")
CLOSED_FIELDS = ("symbols", "length", "user_id", "session_id", "ip",
                 "start_ts", "duration_s")
_PER_ROW_FIELDS = CLOSED_FIELDS + ("event_ts", "event_ip", "end_ts")
COUNTER_FIELDS = ("late_dropped", "shuffle_dropped", "ring_dropped_events",
                  "ring_dropped_sessions", "open_sessions",
                  "closed_sessions", "truncated")


@dataclass(frozen=True)
class StreamConfig:
    """Static shape/semantics knobs of one streaming pipeline instance.

    ``max_open`` is the per-shard ring capacity (open sessions);
    ``tick_capacity`` bounds the events per tick (hosts pad up to it so the
    tick compiles once and never retraces); ``allowed_lateness_ms`` is how
    far the default watermark trails the max event time seen. ``gap_ms``,
    ``dedup``, ``ngram_n`` and ``alphabet_size`` mirror
    ``DistPipelineConfig`` — they must match the batch pipeline's for the
    closed-prefix equivalence to hold.
    """
    alphabet_size: int
    max_open: int
    max_len: int
    tick_capacity: int
    axis: str = "data"
    gap_ms: int = DEFAULT_GAP_MS
    allowed_lateness_ms: int = 0
    capacity_factor: float = 2.0
    dedup: bool = True
    ngram_n: int = 2

    def batch_config(self, max_sessions_per_shard: int = 1
                     ) -> DistPipelineConfig:
        """The batch-pipeline config with matching semantics — the oracle
        side of the closed-prefix equivalence."""
        return DistPipelineConfig(
            alphabet_size=self.alphabet_size,
            max_sessions_per_shard=max_sessions_per_shard,
            max_len=self.max_len, axis=self.axis, gap_ms=self.gap_ms,
            dedup=self.dedup, ngram_n=self.ngram_n)


@dataclass
class TickResult:
    """Host-visible outcome of one tick.

    ``accepted`` masks the tick's *input* rows that passed the late filter
    (the replay harness feeds exactly these to the batch oracle);
    ``open_sessions`` is the post-tick ring occupancy summed over shards.
    Dropped counts are per-tick, not cumulative.
    """
    watermark: int
    accepted: np.ndarray
    closed_sessions: int
    open_sessions: int
    late_dropped: int
    shuffle_dropped: int
    ring_dropped_events: int
    ring_dropped_sessions: int
    truncated: bool


@dataclass
class StreamResult:
    """Closed-so-far sessions + running rollup totals, field-compatible
    with ``distpipe.SingleHostResult`` for oracle comparisons."""
    sequences: SessionSequences
    ngram_counts: np.ndarray
    funnel_reach: list[tuple[int, int]] | None
    truncated: bool
    late_dropped: int
    shuffle_dropped: int
    ring_dropped_events: int

    def num_sessions(self) -> int:
        return len(self.sequences)

    def to_sequences(self) -> SessionSequences:
        return self.sequences


def _init_ring_np(cfg: StreamConfig) -> dict[str, np.ndarray]:
    O, L = cfg.max_open, cfg.max_len
    return dict(
        user_id=np.full(O, -1, np.int64),
        session_id=np.full(O, -1, np.int64),
        length=np.zeros(O, np.int32),
        symbols=np.full((O, L), PAD_CODE, np.int32),
        event_ts=np.zeros((O, L), np.int64),
        event_ip=np.zeros((O, L), np.int64),
        valid=np.zeros(O, bool),
    )


def stream_state_structs(cfg: StreamConfig, n_shards: int = 0
                         ) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs of the ring state (leading shard dim when
    ``n_shards`` > 0) — the dry-run harness lowers the tick with these."""
    lead = (n_shards,) if n_shards else ()
    return {k: jax.ShapeDtypeStruct(lead + v.shape, v.dtype)
            for k, v in _init_ring_np(cfg).items()}


def _tick_core(ring, ev, wm_prev, wm_new, stage_tab, *, cfg: StreamConfig,
               n_stages: int):
    """One shard's tick: late filter -> merge into open sessions -> close
    past the watermark -> rollup deltas. Pure; fixed shapes throughout.

    ``ev`` columns have a fixed per-shard length (``tick_capacity`` on a
    single host, ``n_shards * capacity`` post-``all_to_all``); rows beyond
    the tick are ``valid=False``. Returns
    ``(new_ring, closed_block, n_closed, ngram_delta, reach_delta,
    counters)`` where ``closed_block`` rows ``[:n_closed]`` are the
    sessions closed this tick (sessionizer sort order).
    """
    O, L = cfg.max_open, cfg.max_len
    T = ev["user_id"].shape[0]
    s_cap = O + T  # worst case: every ring segment + one split per event

    late = ev["valid"] & (ev["timestamp"] < wm_prev)
    n_late = jnp.sum(late.astype(jnp.int32))
    ev_valid = ev["valid"] & ~late

    # Flatten the ring back into event rows. Stored events carry their full
    # (user, session, ts, code, ip) key, so dedup and re-sort against the
    # new events are exact.
    stored = jnp.minimum(ring["length"], L)
    col = jnp.arange(L, dtype=jnp.int32)
    r_valid = (ring["valid"][:, None] & (col[None, :] < stored[:, None]))
    r_user = jnp.broadcast_to(ring["user_id"][:, None], (O, L))
    r_sess = jnp.broadcast_to(ring["session_id"][:, None], (O, L))

    u = jnp.concatenate([r_user.reshape(-1), ev["user_id"]])
    s = jnp.concatenate([r_sess.reshape(-1), ev["session_id"]])
    t = jnp.concatenate([ring["event_ts"].reshape(-1), ev["timestamp"]])
    c = jnp.concatenate([ring["symbols"].reshape(-1), ev["code"]])
    i = jnp.concatenate([ring["event_ip"].reshape(-1), ev["ip"]])
    v = jnp.concatenate([r_valid.reshape(-1), ev_valid])
    if cfg.dedup:
        # Ring rows precede tick rows, so a retry duplicate of a stored
        # event is the copy that dies — ring contents stay stable.
        v = mark_duplicate_events(u, s, t, c, i, v)

    sess = _sessionize(u, s, t, c, i, v, gap_ms=cfg.gap_ms,
                       max_sessions=s_cap, max_len=L, with_event_grids=True)

    row = jnp.arange(s_cap, dtype=jnp.int32)
    nonempty = row < sess["num_sessions"]
    # Closed iff no future event can join: any extender has
    # ts <= end_ts + gap, and future arrivals have ts >= watermark.
    closed = nonempty & (sess["end_ts"] + cfg.gap_ms < wm_new)
    open_m = nonempty & ~closed

    perm_c = jnp.argsort(~closed, stable=True)  # closed rows first
    cb = {k: sess[k][perm_c] for k in _PER_ROW_FIELDS}
    n_closed = jnp.sum(closed.astype(jnp.int32))

    c_stored = jnp.minimum(cb["length"], L)
    c_mask = ((row[:, None] < n_closed)
              & (jnp.arange(L)[None, :] < c_stored[:, None]))
    grams = dense_ngram_counts(cb["symbols"], c_mask, cfg.ngram_n,
                               cfg.alphabet_size)
    if n_stages:
        reach = reach_histogram(cb["symbols"], c_mask, stage_tab, n_stages)
    else:
        reach = jnp.zeros((0,), jnp.int32)

    perm_o = jnp.argsort(~open_m, stable=True)  # open rows first
    ob = {k: sess[k][perm_o] for k in _PER_ROW_FIELDS}
    n_open = jnp.sum(open_m.astype(jnp.int32))
    keep = jnp.arange(O, dtype=jnp.int32) < jnp.minimum(n_open, O)
    new_ring = dict(
        user_id=jnp.where(keep, ob["user_id"][:O], -1),
        session_id=jnp.where(keep, ob["session_id"][:O], -1),
        length=jnp.where(keep, ob["length"][:O], 0),
        symbols=jnp.where(keep[:, None], ob["symbols"][:O], PAD_CODE),
        event_ts=jnp.where(keep[:, None], ob["event_ts"][:O], 0),
        event_ip=jnp.where(keep[:, None], ob["event_ip"][:O], 0),
        valid=keep,
    )
    # Ring overflow: open sessions ranked past capacity are dropped whole
    # (deterministic — sessionizer sort order), counted never silent.
    over = (row >= O) & (row < n_open)
    counters = dict(
        late_dropped=n_late.astype(jnp.int64),
        shuffle_dropped=jnp.zeros((), jnp.int64),
        ring_dropped_events=jnp.sum(
            jnp.where(over, ob["length"], 0)).astype(jnp.int64),
        ring_dropped_sessions=jnp.maximum(n_open - O, 0).astype(jnp.int64),
        open_sessions=jnp.minimum(n_open, O).astype(jnp.int64),
        closed_sessions=n_closed.astype(jnp.int64),
        truncated=sess["truncated"].astype(jnp.int64),
    )
    closed_block = {k: cb[k] for k in CLOSED_FIELDS}
    return new_ring, closed_block, n_closed, grams, reach, counters


@functools.lru_cache(maxsize=None)
def _single_host_tick(cfg: StreamConfig, n_stages: int):
    """Jitted single-host tick, cached per (cfg, n_stages) so every
    ``SingleHostStream`` with the same shapes shares one jit cache (the
    property tests build hundreds of instances). The returned counter
    increments only when jit (re)traces — the zero-retrace assertion."""
    counter = collections.Counter()

    def fn(ring, ev, wm_prev, wm_new, stage_tab):
        counter["tick"] += 1  # runs at trace time only
        return _tick_core(ring, ev, wm_prev, wm_new, stage_tab,
                          cfg=cfg, n_stages=n_stages)

    return jax.jit(fn), counter


def build_stream_tick_fn(mesh: Mesh, cfg: StreamConfig, n_stages: int):
    """The shard_map-ed distributed tick, un-jitted (the dry-run harness
    lowers it with ShapeDtypeStructs; ``StreamPipeline`` jits it).

    Takes ``(ring, user_id, session_id, timestamp, code, ip, valid,
    wm_prev, wm_new, stage_table)`` — ring fields stacked on a leading
    shard dim and sharded over ``cfg.axis`` like the event columns;
    watermarks and stage table replicated — and returns ``(new_ring,
    closed_block, n_closed_per_shard, ngram_delta, reach_delta, counters)``
    with the deltas and counters psum-merged.
    """
    axis, n_shards = cfg.axis, mesh.shape[cfg.axis]
    if cfg.tick_capacity % n_shards:
        raise ValueError(
            f"tick_capacity={cfg.tick_capacity} must divide evenly over "
            f"{n_shards} '{axis}' shards")
    local_t = cfg.tick_capacity // n_shards
    capacity = max(int(np.ceil(local_t * cfg.capacity_factor / n_shards)), 1)

    def local_fn(ring, user_id, session_id, timestamp, code, ip, valid,
                 wm_prev, wm_new, stage_tab):
        ring = {k: v[0] for k, v in ring.items()}
        # Stage 1: keyed all_to_all repartition by user (padding rows are
        # spread round-robin so they never crowd one destination).
        idx = jnp.arange(local_t, dtype=jnp.int32)
        dest = jnp.where(valid, shard_of_user(user_id, n_shards),
                         idx % n_shards)
        cols = dict(user_id=user_id, session_id=session_id,
                    timestamp=timestamp, code=code, ip=ip,
                    valid=valid.astype(jnp.int32))
        flat, dropped = keyed_all_to_all(cols, dest, axis, n_shards,
                                         capacity)
        ev = dict(user_id=flat["user_id"], session_id=flat["session_id"],
                  timestamp=flat["timestamp"], code=flat["code"],
                  ip=flat["ip"], valid=flat["valid"].astype(bool))
        new_ring, cb, n_closed, grams, reach, counters = _tick_core(
            ring, ev, wm_prev, wm_new, stage_tab, cfg=cfg,
            n_stages=n_stages)
        counters["shuffle_dropped"] = dropped.astype(jnp.int64)
        grams = jax.lax.psum(grams, axis)
        reach = jax.lax.psum(reach, axis)
        counters = {k: jax.lax.psum(v, axis) for k, v in counters.items()}
        new_ring = {k: v[None] for k, v in new_ring.items()}
        cb = {k: v[None] for k, v in cb.items()}
        return new_ring, cb, n_closed[None], grams, reach, counters

    return shard_map(
        local_fn, mesh=mesh,
        in_specs=({k: P(axis) for k in RING_FIELDS},)
                 + (P(axis),) * 6 + (P(), P(), P()),
        out_specs=({k: P(axis) for k in RING_FIELDS},
                   {k: P(axis) for k in CLOSED_FIELDS},
                   P(axis), P(), P(),
                   {k: P() for k in COUNTER_FIELDS}))


class _StreamBase:
    """Shared host bookkeeping: watermark advance, late masks, the
    segment-store sink for closed sessions, running totals. Subclasses
    implement ``_device_tick``."""

    def __init__(self, cfg: StreamConfig, stages=None,
                 store: Store | None = None):
        self.cfg = cfg
        self.stages = stages
        self.stage_table = (None if stages is None else
                            build_stage_table(stages, cfg.alphabet_size))
        self.n_stages = (0 if self.stage_table is None
                         else len(self.stage_table))
        self._table = (np.zeros((0, cfg.alphabet_size), bool)
                       if self.stage_table is None else self.stage_table)
        self.watermark = WATERMARK_MIN
        self.max_ts_seen = WATERMARK_MIN
        self.ngram_totals = np.zeros(cfg.alphabet_size ** cfg.ngram_n,
                                     np.int64)
        self.reach_totals = np.zeros(self.n_stages, np.int64)
        # Closed sessions land in the unified segment store (one immutable
        # session segment per watermark that closed any), not in host
        # arrays — the same store the batch path compacts into. Pass a
        # shared ``store`` to fan several streams into one mega-table.
        self.store = store if store is not None else Store(StoreConfig(
            gap_ms=cfg.gap_ms, dedup=cfg.dedup, max_len=cfg.max_len))
        self._segment_ids: list[int] = []
        self.closed_total = 0
        self.late_dropped = 0
        self.shuffle_dropped = 0
        self.ring_dropped_events = 0
        self.ring_dropped_sessions = 0
        self.truncated = False

    # -- subclass surface --------------------------------------------------

    def _device_tick(self, ev: dict[str, np.ndarray], wm_prev: int,
                     wm_new: int):
        raise NotImplementedError

    # -- the tick ----------------------------------------------------------

    def tick(self, user_id, session_id, timestamp, code, ip=None, *,
             watermark: int | None = None) -> TickResult:
        """Ingest one micro-batch and advance the watermark.

        ``watermark`` overrides the default (max event ts seen minus
        ``allowed_lateness_ms``); either way it is clamped monotone. Rows
        older than the *previous* watermark are late — dropped and counted
        (they arrived after their session could already have closed);
        rows between the previous and new watermark still merge, then
        sessions whose 30-minute gap crosses the new watermark close.
        """
        cfg = self.cfg
        n = len(user_id)
        if n > cfg.tick_capacity:
            raise ValueError(
                f"tick has {n} events > tick_capacity={cfg.tick_capacity}; "
                "split the tick or build the stream with a larger capacity")
        ts = np.asarray(timestamp, np.int64)
        wm_prev = self.watermark
        if n:
            self.max_ts_seen = max(self.max_ts_seen, int(ts.max()))
        if watermark is not None:
            wm_new = max(wm_prev, int(watermark))
        elif n:
            wm_new = max(wm_prev, int(ts.max()) - cfg.allowed_lateness_ms)
        else:
            wm_new = wm_prev
        accepted = (ts >= wm_prev) if n else np.zeros(0, bool)

        ev = self._pad_events(user_id, session_id, ts, code, ip, n)
        closed, grams, reach, counters = self._device_tick(ev, wm_prev,
                                                           wm_new)
        if len(closed["length"]):
            seg = self.store.append_sessions(SessionSequences(
                **{k: closed[k] for k in CLOSED_FIELDS}))
            self._segment_ids.append(seg.seg_id)
        self.ngram_totals += grams.astype(np.int64)
        if self.n_stages:
            self.reach_totals += reach.astype(np.int64)
        self.watermark = wm_new
        self.closed_total += counters["closed_sessions"]
        self.late_dropped += counters["late_dropped"]
        self.shuffle_dropped += counters["shuffle_dropped"]
        self.ring_dropped_events += counters["ring_dropped_events"]
        self.ring_dropped_sessions += counters["ring_dropped_sessions"]
        self.truncated |= bool(counters["truncated"])
        return TickResult(
            watermark=wm_new, accepted=accepted,
            closed_sessions=counters["closed_sessions"],
            open_sessions=counters["open_sessions"],
            late_dropped=counters["late_dropped"],
            shuffle_dropped=counters["shuffle_dropped"],
            ring_dropped_events=counters["ring_dropped_events"],
            ring_dropped_sessions=counters["ring_dropped_sessions"],
            truncated=bool(counters["truncated"]))

    def flush(self) -> TickResult:
        """Advance the watermark past every possible event: all open
        sessions close (end of day / drain)."""
        z64 = np.zeros(0, np.int64)
        return self.tick(z64, z64, z64, np.zeros(0, np.int32),
                         watermark=WATERMARK_MAX)

    def _pad_events(self, user_id, session_id, ts, code, ip, n):
        cap = self.cfg.tick_capacity
        pad = cap - n
        if ip is None:
            ip = np.zeros(n, np.int64)

        def col(x, dtype):
            x = np.asarray(x, dtype)
            return np.concatenate([x, np.zeros(pad, dtype)]) if pad else x

        return dict(user_id=col(user_id, np.int64),
                    session_id=col(session_id, np.int64),
                    timestamp=col(ts, np.int64),
                    code=col(code, np.int32),
                    ip=col(ip, np.int64),
                    valid=np.arange(cap) < n)

    # -- results -----------------------------------------------------------

    @property
    def watermark_lag_ms(self) -> int:
        """How far the watermark trails the newest event seen."""
        return max(self.max_ts_seen - self.watermark, 0)

    def sessions(self) -> SessionSequences:
        """All sessions closed so far (tick order within shard order),
        decoded back from this stream's own session segments in the
        store — the store is the source of truth, not host arrays."""
        return self.store.scan(segment_ids=self._segment_ids,
                               min_width=self.cfg.max_len).sequences

    def result(self) -> StreamResult:
        reach = (None if self.stage_table is None else
                 [(j, int(c)) for j, c in enumerate(self.reach_totals)])
        return StreamResult(
            sequences=self.sessions(),
            ngram_counts=self.ngram_totals.copy(),
            funnel_reach=reach, truncated=self.truncated,
            late_dropped=self.late_dropped,
            shuffle_dropped=self.shuffle_dropped,
            ring_dropped_events=self.ring_dropped_events)


class SingleHostStream(_StreamBase):
    """The streaming path on one host (no mesh) — the oracle for
    ``StreamPipeline`` and itself oracle-tested against the batch
    ``single_host_pipeline`` on every closed prefix."""

    def __init__(self, cfg: StreamConfig, stages=None,
                 store: Store | None = None):
        super().__init__(cfg, stages, store)
        self._tick_jit, self.trace_counts = _single_host_tick(
            cfg, self.n_stages)
        self._ring = _init_ring_np(cfg)

    def open_state(self) -> dict[str, np.ndarray]:
        """Host copy of the ring (tests/debugging)."""
        return {k: np.asarray(v) for k, v in self._ring.items()}

    def _device_tick(self, ev, wm_prev, wm_new):
        with enable_x64():
            ring, cb, n_closed, grams, reach, counters = self._tick_jit(
                self._ring,
                {k: jnp.asarray(v) for k, v in ev.items()},
                jnp.asarray(wm_prev, jnp.int64),
                jnp.asarray(wm_new, jnp.int64),
                jnp.asarray(self._table))
        self._ring = ring
        nc = int(n_closed)
        closed = {k: np.asarray(v)[:nc] for k, v in cb.items()}
        counters = {k: int(np.asarray(v)) for k, v in counters.items()}
        return closed, np.asarray(grams), np.asarray(reach), counters


class StreamPipeline(_StreamBase):
    """The distributed streaming path: per-shard rings over
    ``mesh[cfg.axis]``, keyed all_to_all repartition each tick, psum-merged
    rollup deltas. Bit-equal to ``SingleHostStream`` fed the same ticks
    (sessions compared as multisets — shard partitioning permutes order)."""

    def __init__(self, mesh: Mesh, cfg: StreamConfig, stages=None,
                 store: Store | None = None):
        super().__init__(cfg, stages, store)
        self.mesh = mesh
        self.n_shards = mesh.shape[cfg.axis]
        self.trace_counts = collections.Counter()
        fn = build_stream_tick_fn(mesh, cfg, self.n_stages)

        def counted(*args):
            self.trace_counts["tick"] += 1  # trace time only
            return fn(*args)

        self._tick_jit = jax.jit(counted)
        base = _init_ring_np(cfg)
        self._ring = {k: np.broadcast_to(v, (self.n_shards,) + v.shape)
                      .copy() for k, v in base.items()}

    def _device_tick(self, ev, wm_prev, wm_new):
        with enable_x64():
            with use_mesh(self.mesh):
                ring, cb, n_closed, grams, reach, counters = self._tick_jit(
                    self._ring,
                    jnp.asarray(ev["user_id"]), jnp.asarray(ev["session_id"]),
                    jnp.asarray(ev["timestamp"]), jnp.asarray(ev["code"]),
                    jnp.asarray(ev["ip"]), jnp.asarray(ev["valid"]),
                    jnp.asarray(wm_prev, jnp.int64),
                    jnp.asarray(wm_new, jnp.int64),
                    jnp.asarray(self._table))
        self._ring = ring
        nc = np.asarray(n_closed)
        closed = {k: np.concatenate([np.asarray(v)[sh, : int(nc[sh])]
                                     for sh in range(self.n_shards)])
                  for k, v in cb.items()}
        counters = {k: int(np.asarray(v)) for k, v in counters.items()}
        return closed, np.asarray(grams), np.asarray(reach), counters


def single_host_stream(cfg: StreamConfig, stages=None,
                       store: Store | None = None) -> SingleHostStream:
    """Build the single-host streaming oracle path. ``store`` is the
    segment store closed sessions sink into (default: a fresh one)."""
    return SingleHostStream(cfg, stages, store)


def make_stream_pipeline(mesh: Mesh, cfg: StreamConfig, stages=None,
                         store: Store | None = None) -> StreamPipeline:
    """Build the distributed streaming pipeline over ``mesh[cfg.axis]``.
    ``stages`` is the optional funnel spec, as in
    ``make_distributed_pipeline``; ``store`` the shared segment store."""
    return StreamPipeline(mesh, cfg, stages, store)


# ---------------------------------------------------------------------------
# replay harness + batch oracle helpers
# ---------------------------------------------------------------------------

def batch_closed_prefix(cfg: StreamConfig, stages, user_id, session_id,
                        timestamp, code, ip, accepted,
                        watermark: int) -> SingleHostResult:
    """The batch oracle over the closed prefix: restrict the accepted
    events to closed sessions at ``watermark`` and run
    ``single_host_pipeline`` with matching semantics.

    Inputs are padded to the next power of two (masked invalid) so the
    replay harness's per-watermark oracle runs hit a small ladder of jit
    shapes instead of retracing at every prefix length.
    """
    acc = np.asarray(accepted, bool)
    u = np.asarray(user_id, np.int64)[acc]
    s = np.asarray(session_id, np.int64)[acc]
    t = np.asarray(timestamp, np.int64)[acc]
    c = np.asarray(code, np.int32)[acc]
    i = np.asarray(ip, np.int64)[acc]
    m = closed_prefix_mask(u, s, t, gap_ms=cfg.gap_ms, watermark=watermark)
    nv = int(m.sum())
    cap = 1 << max(nv - 1, 0).bit_length()
    pad = cap - nv

    def col(x, dtype):
        return np.concatenate([np.asarray(x, dtype)[m],
                               np.zeros(pad, dtype)])

    return single_host_pipeline(
        col(u, np.int64), col(s, np.int64), col(t, np.int64),
        col(c, np.int32), col(i, np.int64), np.arange(cap) < nv,
        cfg=cfg.batch_config(cap), stages=stages, max_sessions=cap)


def session_multiset(seqs: SessionSequences) -> list[tuple]:
    """Canonical sortable view of a session relation — the comparator for
    the bit-equality assertions (shard/tick partitioning permutes rows)."""
    m = seqs.mask()
    return sorted(
        (int(seqs.user_id[j]), int(seqs.session_id[j]),
         int(seqs.start_ts[j]), int(seqs.ip[j]), int(seqs.duration_s[j]),
         tuple(int(x) for x in seqs.symbols[j][m[j]]))
        for j in range(len(seqs)))


def assert_stream_equals_batch(stream: _StreamBase,
                               oracle: SingleHostResult) -> None:
    """Bitwise closed-prefix equality: running rollup totals equal the
    batch rollups, closed sessions equal as a multiset."""
    got = stream.result()
    assert np.array_equal(got.ngram_counts, oracle.ngram_counts), \
        "n-gram totals diverge from the batch oracle"
    if oracle.funnel_reach is not None:
        assert got.funnel_reach == oracle.funnel_reach, \
            (got.funnel_reach, oracle.funnel_reach)
    assert session_multiset(got.sequences) == \
        session_multiset(oracle.sequences), \
        "closed sessions diverge from the batch oracle"


def split_ticks(timestamp, n_ticks: int) -> list[np.ndarray]:
    """Index arrays for ``n_ticks`` contiguous time-ordered micro-batches
    (the log mover's arrival order; shuffle them to simulate lateness)."""
    order = np.argsort(np.asarray(timestamp, np.int64), kind="stable")
    return [ix for ix in np.array_split(order, n_ticks) if True]


def replay(stream: _StreamBase, user_id, session_id, timestamp, code,
           ip=None, *, n_ticks: int = 8,
           tick_index: list[np.ndarray] | None = None,
           assert_closed_prefix: bool = False, stages=None,
           flush: bool = True) -> list[TickResult]:
    """Feed a whole event log through ``stream`` tick-by-tick.

    ``tick_index`` overrides the default time-ordered split. With
    ``assert_closed_prefix`` the accepted prefix is checked against the
    batch oracle *at every watermark* (and after the final flush) —
    the acceptance harness for tests and the ``stream_tput`` benchmark.
    ``stages`` defaults to the stream's own funnel spec.
    """
    if stages is None:
        stages = stream.stages
    u = np.asarray(user_id, np.int64)
    s = np.asarray(session_id, np.int64)
    t = np.asarray(timestamp, np.int64)
    c = np.asarray(code, np.int32)
    i = (np.zeros(len(u), np.int64) if ip is None
         else np.asarray(ip, np.int64))
    ticks = tick_index if tick_index is not None else split_ticks(t, n_ticks)
    fed = {k: [] for k in "ustci"}
    accepted: list[np.ndarray] = []
    results = []

    def check():
        cols = {k: (np.concatenate(v) if v else
                    np.zeros(0, np.int64 if k != "c" else np.int32))
                for k, v in fed.items()}
        acc = (np.concatenate(accepted) if accepted
               else np.zeros(0, bool))
        oracle = batch_closed_prefix(
            stream.cfg, stages, cols["u"], cols["s"], cols["t"], cols["c"],
            cols["i"], acc, stream.watermark)
        assert_stream_equals_batch(stream, oracle)

    for ix in ticks:
        res = stream.tick(u[ix], s[ix], t[ix], c[ix], i[ix])
        results.append(res)
        for k, v in zip("ustci", (u, s, t, c, i)):
            fed[k].append(v[ix])
        accepted.append(res.accepted)
        if assert_closed_prefix:
            check()
    if flush:
        results.append(stream.flush())
        if assert_closed_prefix:
            check()
    return results
