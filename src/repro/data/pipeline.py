"""Deterministic sharded LM batch pipeline over session sequences.

Session sequences are the training corpus for the behaviour LMs (§5.4
extended): each session becomes ``BOS <symbols> EOS`` in a packed token
stream, chunked to fixed-length rows. The pipeline is:

* **deterministic** — (seed, epoch, step) fully determines every batch, so a
  restarted job resumes bit-identically (fault tolerance requirement);
* **sharded** — each data-parallel host reads only its slice (shard_index /
  num_shards), no host reads the full corpus;
* **prefetched** — a background thread keeps a bounded queue of ready
  batches so device steps never wait on host work (straggler mitigation at
  the input layer).

Token space: codes are shifted by NUM_SPECIALS; 0=PAD 1=BOS 2=EOS 3=UNK.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

from ..core.sequences import SessionSequences

PAD_ID, BOS_ID, EOS_ID, UNK_ID = 0, 1, 2, 3
NUM_SPECIALS = 4


def lm_vocab_size(alphabet_size: int) -> int:
    return alphabet_size + NUM_SPECIALS


def encode_tokens(symbols: np.ndarray) -> np.ndarray:
    """Event codes -> LM token ids (shift past specials)."""
    return np.asarray(symbols, np.int64) + NUM_SPECIALS


def pack_sessions(seqs: SessionSequences, seq_len: int,
                  shuffle_seed: int | None = None) -> np.ndarray:
    """Pack sessions into (rows, seq_len+1) token matrix.

    Each row holds seq_len+1 tokens so (inputs, targets) shift by one inside
    the row. Sessions are concatenated as BOS s0..sn EOS; the tail row is
    PAD-padded. Packing (vs one-session-per-row) keeps MXU utilization high
    — sessions are much shorter than seq_len.
    """
    order = np.arange(len(seqs))
    if shuffle_seed is not None:
        np.random.default_rng(shuffle_seed).shuffle(order)
    stored = seqs.stored_length()
    stream_len = int((stored + 2).sum())
    row = seq_len + 1
    n_rows = max(1, -(-stream_len // row))
    flat = np.full(n_rows * row, PAD_ID, np.int32)
    pos = 0
    for i in order:
        l = int(stored[i])
        flat[pos] = BOS_ID
        flat[pos + 1: pos + 1 + l] = encode_tokens(seqs.symbols[i, :l])
        flat[pos + 1 + l] = EOS_ID
        pos += l + 2
    return flat.reshape(n_rows, row)


@dataclass
class PipelineConfig:
    seq_len: int = 512
    global_batch: int = 8
    shard_index: int = 0
    num_shards: int = 1
    seed: int = 0
    prefetch: int = 2
    drop_remainder: bool = True


class SessionBatchPipeline:
    """Iterable over {tokens, targets, loss_mask} batches.

    ``global_batch`` rows per step across all shards; this shard yields
    ``global_batch // num_shards`` rows. Epochs reshuffle rows with
    seed=(seed, epoch); iteration order is identical across restarts.
    """

    def __init__(self, seqs: SessionSequences, cfg: PipelineConfig):
        if cfg.global_batch % cfg.num_shards:
            raise ValueError("global_batch must divide by num_shards")
        self.cfg = cfg
        self.rows = pack_sessions(seqs, cfg.seq_len, shuffle_seed=cfg.seed)
        self.local_batch = cfg.global_batch // cfg.num_shards

    @classmethod
    def from_store(cls, store, cfg: PipelineConfig, *, time_range=None,
                   users=None, events=None) -> "SessionBatchPipeline":
        """Feed the LM pipeline straight from the segment store's pruning
        query path (``repro.data.store``): only segments whose metadata can
        match the filters decode. Raises if matching events are still
        un-compacted — training reads materialized sequences only.
        """
        seqs = store.sequences(time_range=time_range, users=users,
                               events=events)
        return cls(seqs, cfg)

    def batches_per_epoch(self) -> int:
        usable = (len(self.rows) // self.cfg.global_batch) * self.cfg.global_batch
        if usable == 0 and not self.cfg.drop_remainder:
            return 1
        return usable // self.cfg.global_batch

    def _epoch_order(self, epoch: int) -> np.ndarray:
        order = np.arange(len(self.rows))
        np.random.default_rng((self.cfg.seed, epoch)).shuffle(order)
        return order

    def batch_at(self, epoch: int, step: int) -> dict[str, np.ndarray]:
        """Deterministic random access — the restart/resume path."""
        order = self._epoch_order(epoch)
        lo = step * self.cfg.global_batch
        rows = order[lo: lo + self.cfg.global_batch]
        if len(rows) < self.cfg.global_batch:  # wrap (non-drop mode)
            rows = np.concatenate([rows, order[: self.cfg.global_batch - len(rows)]])
        # this shard's slice of the global batch
        sl = rows[self.cfg.shard_index * self.local_batch:
                  (self.cfg.shard_index + 1) * self.local_batch]
        chunk = self.rows[sl]
        tokens = chunk[:, :-1].astype(np.int32)
        targets = chunk[:, 1:].astype(np.int32)
        return dict(tokens=tokens, targets=targets,
                    loss_mask=(targets != PAD_ID).astype(np.float32))

    def epoch(self, epoch: int, start_step: int = 0):
        """Prefetching iterator over one epoch, resumable at start_step."""
        n = self.batches_per_epoch()
        q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        stop = object()

        def producer():
            for step in range(start_step, n):
                q.put(self.batch_at(epoch, step))
            q.put(stop)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        while True:
            item = q.get()
            if item is stop:
                break
            yield item

    def __iter__(self):
        return self.epoch(0)


def synthetic_batch(rng: np.random.Generator, vocab: int, batch: int,
                    seq_len: int) -> dict[str, np.ndarray]:
    """Shape-correct random batch for smoke tests and benches."""
    tokens = rng.integers(NUM_SPECIALS, vocab, (batch, seq_len + 1),
                          dtype=np.int64).astype(np.int32)
    return dict(tokens=tokens[:, :-1], targets=tokens[:, 1:],
                loss_mask=np.ones((batch, seq_len), np.float32))
