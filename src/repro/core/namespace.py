"""Hierarchical six-level client-event namespace (paper §3.2, Table 1).

Event names are ``client:page:section:component:element:action`` — lowercased,
colon-delimited, read right-to-left ("a profile_click on the avatar of a tweet
in the mentions stream of the home page on web"). The namespace supports:

* canonical parse/format + validation (combats the dreaded camel_Snake),
* glob patterns (``web:home:mentions:*``, ``*:profile_click``) compiled to
  regexes for slice-and-dice selection,
* the five Oink roll-up schemas of §3.2 (progressively wildcarded levels).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Iterable, Sequence

LEVELS = ("client", "page", "section", "component", "element", "action")
NUM_LEVELS = len(LEVELS)

# Lowercase snake_case tokens only; empty components are permitted (a page
# without sections logs an empty section — §3.2 discusses this trade-off).
_TOKEN_RE = re.compile(r"^[a-z0-9_]*$")

# The five roll-up schemas from §3.2, expressed as masks of which levels are
# kept (True) vs wildcarded (False):  (c,p,s,comp,elem,action)
ROLLUP_SCHEMAS: tuple[tuple[bool, ...], ...] = (
    (True, True, True, True, True, True),
    (True, True, True, True, False, True),
    (True, True, True, False, False, True),
    (True, True, False, False, False, True),
    (True, False, False, False, False, True),
)


class InvalidEventName(ValueError):
    """Raised for names violating the unified naming specification."""


@dataclass(frozen=True)
class EventName:
    client: str
    page: str
    section: str
    component: str
    element: str
    action: str

    def __post_init__(self):
        for level, token in zip(LEVELS, self.parts()):
            if not _TOKEN_RE.match(token):
                raise InvalidEventName(
                    f"{level}={token!r}: must be lowercase snake_case "
                    f"(got non-conforming token in {':'.join(self.parts())!r})"
                )
        if not self.client or not self.action:
            raise InvalidEventName("client and action levels must be non-empty")

    def parts(self) -> tuple[str, ...]:
        return (self.client, self.page, self.section,
                self.component, self.element, self.action)

    def canonical(self) -> str:
        return ":".join(self.parts())

    def rollup(self, schema: Sequence[bool]) -> str:
        """Project onto one of the five roll-up schemas (wildcard = '*')."""
        return ":".join(p if keep else "*"
                        for p, keep in zip(self.parts(), schema))

    def __str__(self) -> str:  # pragma: no cover - convenience
        return self.canonical()


def parse(name: str) -> EventName:
    """Parse a canonical colon-delimited name, validating each token."""
    parts = name.split(":")
    if len(parts) != NUM_LEVELS:
        raise InvalidEventName(
            f"expected {NUM_LEVELS} colon-delimited levels, got {len(parts)}: {name!r}")
    return EventName(*parts)


def is_valid(name: str) -> bool:
    try:
        parse(name)
        return True
    except InvalidEventName:
        return False


def compile_pattern(pattern: str) -> re.Pattern:
    """Compile a glob pattern over the namespace into a regex.

    A bare ``*`` occupying the *first* or *last* level absorbs any number of
    whole levels — matching the paper's usage ``web:home:mentions:*`` (all
    events under the mentions stream) and ``*:profile_click`` (profile clicks
    across all clients). A bare ``*`` in the middle matches exactly one level;
    a ``*`` embedded in a token matches within that level only.
    """
    parts = pattern.split(":")
    if all(p == "*" for p in parts):
        return re.compile(r"^.*$")

    def token(p: str) -> str:
        return re.escape(p).replace(r"\*", "[a-z0-9_]*")

    head = ""
    tail = ""
    if parts[0] == "*":
        head = r"(?:[a-z0-9_]*:)*"
        parts = parts[1:]
    if parts and parts[-1] == "*":
        tail = r"(?::[a-z0-9_]*)*"
        parts = parts[:-1]
    body = ":".join("[a-z0-9_]*" if p == "*" else token(p) for p in parts)
    return re.compile("^" + head + body + tail + "$")


def match(pattern: str, names: Iterable[str]) -> list[str]:
    """Expand a glob pattern to all matching canonical names."""
    rx = compile_pattern(pattern)
    return [n for n in names if rx.match(n)]
