"""Materialized session sequences (paper §4.2).

The materialized relation is exactly the paper's (plus start_ts, which the
log mover knows anyway)::

    user_id: long, session_id: long, ip: long,
    session_sequence: symbols, duration: int

On TPU the ``session_sequence`` string becomes a padded int32 symbol tensor
(``symbols (S, L)`` + ``length (S,)``); ``as_unicode_strings`` reproduces the
paper's exact string representation (one unicode char per event, small code
point = frequent event) and ``varint.py`` its on-disk byte encoding.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

import numpy as np

from .sessionize import PAD_CODE, Sessionized

# Unicode code-point mapping must skip the surrogate block D800-DFFF to keep
# every sequence a *valid* unicode string (paper: "any session sequence is a
# valid unicode string").
_SURROGATE_START = 0xD800
_SURROGATE_SIZE = 0x800


def code_to_codepoint(code: np.ndarray | int):
    """Frequency code -> unicode code point (bijective, order-preserving)."""
    c = np.asarray(code)
    return np.where(c >= _SURROGATE_START, c + _SURROGATE_SIZE, c)


def codepoint_to_code(cp: np.ndarray | int):
    cp = np.asarray(cp)
    return np.where(cp >= _SURROGATE_START + _SURROGATE_SIZE,
                    cp - _SURROGATE_SIZE, cp)


@dataclass
class SessionSequences:
    """Columnar store of materialized session sequences."""
    symbols: np.ndarray     # (S, L) int32, PAD_CODE padded
    length: np.ndarray      # (S,) int32 (true length; may exceed L if truncated)
    user_id: np.ndarray     # (S,) int64
    session_id: np.ndarray  # (S,) int64
    ip: np.ndarray          # (S,) int64
    start_ts: np.ndarray    # (S,) int64
    duration_s: np.ndarray  # (S,) int32

    @staticmethod
    def from_sessionized(s: Sessionized) -> "SessionSequences":
        t = s.trimmed()
        return SessionSequences(
            symbols=np.asarray(t.symbols), length=np.asarray(t.length),
            user_id=np.asarray(t.user_id), session_id=np.asarray(t.session_id),
            ip=np.asarray(t.ip), start_ts=np.asarray(t.start_ts),
            duration_s=np.asarray(t.duration_s))

    def __len__(self) -> int:
        return len(self.length)

    @property
    def max_len(self) -> int:
        return self.symbols.shape[1]

    def stored_length(self) -> np.ndarray:
        """Length actually materialized (<= max_len)."""
        return np.minimum(self.length, self.max_len)

    def mask(self) -> np.ndarray:
        """(S, L) bool validity mask."""
        return np.arange(self.max_len)[None, :] < self.stored_length()[:, None]

    def session_symbols(self, i: int) -> np.ndarray:
        return self.symbols[i, : int(self.stored_length()[i])]

    def session_string(self, i: int) -> str:
        """One session in the paper's representation: a valid unicode string,
        one char per event, small code point = frequent event."""
        cps = code_to_codepoint(self.session_symbols(i))
        return "".join(chr(int(c)) for c in cps)

    def as_unicode_strings(self) -> list[str]:
        """The paper's representation: one valid unicode string per session."""
        return [self.session_string(i) for i in range(len(self))]

    @staticmethod
    def from_unicode_strings(strings: list[str], **meta) -> "SessionSequences":
        s = len(strings)
        lens = np.array([len(x) for x in strings], np.int32)
        max_len = int(lens.max()) if s else 0
        symbols = np.full((s, max_len), PAD_CODE, np.int32)
        for i, string in enumerate(strings):
            cps = np.array([ord(ch) for ch in string], np.int64)
            symbols[i, : len(string)] = codepoint_to_code(cps)
        def get(name, dtype, fill=0):
            return np.asarray(meta.get(name, np.full(s, fill)), dtype)
        return SessionSequences(
            symbols=symbols, length=lens,
            user_id=get("user_id", np.int64), session_id=get("session_id", np.int64),
            ip=get("ip", np.int64), start_ts=get("start_ts", np.int64),
            duration_s=get("duration_s", np.int32))

    # ---- persistence (atomic, the log-mover way) ----
    def save(self, path: str) -> None:
        tmp = path + ".tmp.npz"  # explicit .npz so numpy doesn't rename it
        np.savez_compressed(
            tmp,
            symbols=self.symbols, length=self.length, user_id=self.user_id,
            session_id=self.session_id, ip=self.ip, start_ts=self.start_ts,
            duration_s=self.duration_s)
        os.replace(tmp, path)

    @staticmethod
    def load(path: str) -> "SessionSequences":
        z = np.load(path)
        return SessionSequences(
            symbols=z["symbols"], length=z["length"], user_id=z["user_id"],
            session_id=z["session_id"], ip=z["ip"], start_ts=z["start_ts"],
            duration_s=z["duration_s"])

    def summary(self) -> dict:
        sl = self.stored_length()
        return dict(
            sessions=int(len(self)),
            events=int(self.length.sum()),
            mean_len=float(self.length.mean()) if len(self) else 0.0,
            mean_duration_s=float(self.duration_s.mean()) if len(self) else 0.0,
            distinct_users=int(len(np.unique(self.user_id))),
            stored_events=int(sl.sum()),
        )

    def to_json_rows(self, limit: int = 10) -> str:
        # Materialize only the strings actually emitted — the previous
        # version rebuilt every session string once per row (O(S^2)).
        rows = []
        for i in range(min(limit, len(self))):
            rows.append(dict(
                user_id=int(self.user_id[i]), session_id=int(self.session_id[i]),
                ip=int(self.ip[i]), duration=int(self.duration_s[i]),
                session_sequence=self.session_string(i)
                if i < 3 else f"<{int(self.length[i])} symbols>"))
        return json.dumps(rows, ensure_ascii=True, indent=2)
