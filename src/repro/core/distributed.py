"""Back-compat shim: the distributed sessionize/histogram collectives moved
to :mod:`repro.dist.collectives` (the unified distribution layer). This
module re-exports the old public names so existing callers keep working;
new code should import from ``repro.dist``.
"""
from ..dist.collectives import (
    mix64, shard_of_user, bucket_by_destination, keyed_all_to_all,
    make_distributed_sessionize, make_distributed_histogram,
)

# Old private names, kept for anyone who reached into the internals.
_mix64 = mix64


def _bucket_by_destination(cols, dest, n_shards, capacity):
    """Old 2-tuple signature: (buckets, dropped). The shared primitive in
    dist.collectives also returns the sort permutation (for the MoE combine
    path); preserve the original contract here."""
    buckets, _, _, _, dropped = bucket_by_destination(
        cols, dest, n_shards, capacity)
    return buckets, dropped

__all__ = [
    "mix64", "shard_of_user", "bucket_by_destination", "keyed_all_to_all",
    "make_distributed_sessionize", "make_distributed_histogram",
]
