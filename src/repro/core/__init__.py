"""Core unified-logging substrate (the paper's contribution).

Pipeline: raw client events (events.py, namespace.py) -> frequency-ordered
dictionary (dictionary.py) -> sessionization + retry dedup (sessionize.py)
-> materialized session sequences (sequences.py, varint.py) -> catalog
(catalog.py). Pure-Python oracles in oracle.py.

Distribution machinery lives in ``repro.dist`` (distributed.py here is only
a back-compat re-export shim over ``repro.dist.collectives``); the
mesh-scale multi-stage pipeline over these pieces is
``repro.data.distpipe``.
"""
from .namespace import EventName, InvalidEventName, parse, is_valid, match, \
    compile_pattern, LEVELS, ROLLUP_SCHEMAS
from .events import ClientEvent, EventBatch, EventInitiator, NameTable
from .dictionary import EventDictionary, histogram, assign_codes
from .sessionize import sessionize, Sessionized, DEFAULT_GAP_MS, PAD_CODE, \
    closed_prefix_mask, mark_duplicate_events
from .sequences import SessionSequences, code_to_codepoint, codepoint_to_code
from .catalog import EventCatalog, CatalogEntry, CatalogBuilder
from . import varint, oracle

__all__ = [
    "EventName", "InvalidEventName", "parse", "is_valid", "match",
    "compile_pattern", "LEVELS", "ROLLUP_SCHEMAS",
    "ClientEvent", "EventBatch", "EventInitiator", "NameTable",
    "EventDictionary", "histogram", "assign_codes",
    "sessionize", "Sessionized", "DEFAULT_GAP_MS", "PAD_CODE",
    "closed_prefix_mask", "mark_duplicate_events",
    "SessionSequences", "code_to_codepoint", "codepoint_to_code",
    "EventCatalog", "CatalogEntry", "CatalogBuilder", "varint", "oracle",
]
