"""Auto-generated client-event catalog (paper §4.3).

Rebuilt from every dictionary/histogram job, so always up to date: per event
name it records the frequency-ordered code, daily count, a few sample
events, and (optionally) developer-supplied descriptions. Browsable
hierarchically, by namespace component, or by regex — the paper's interface,
minus the web frontend.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

from . import namespace
from .dictionary import EventDictionary
from .events import EventBatch


@dataclass
class CatalogEntry:
    name: str
    code: int
    count: int
    samples: list[str] = field(default_factory=list)  # sample event JSON
    description: str = ""

    def levels(self) -> tuple[str, ...]:
        return namespace.parse(self.name).parts()


@dataclass
class EventCatalog:
    entries: dict[str, CatalogEntry]

    @staticmethod
    def build(dictionary: EventDictionary, batch: EventBatch | None = None,
              samples_per_event: int = 3,
              descriptions: dict[str, str] | None = None) -> "EventCatalog":
        entries: dict[str, CatalogEntry] = {}
        sample_map: dict[int, list[str]] = {}
        if batch is not None and batch.details is not None:
            # First-k sampling per name id (the histogram job samples while
            # it scans — §4.2).
            for i in range(len(batch)):
                nid = int(batch.name_id[i])
                bucket = sample_map.setdefault(nid, [])
                if len(bucket) < samples_per_event:
                    bucket.append(batch.event_at(i).to_json())
        for nid, name in enumerate(dictionary.table.names):
            entries[name] = CatalogEntry(
                name=name,
                code=int(dictionary.code_of_name[nid]),
                count=int(dictionary.counts[nid]),
                samples=sample_map.get(nid, []),
                description=(descriptions or {}).get(name, ""),
            )
        return EventCatalog(entries)

    def describe(self, name: str, text: str) -> None:
        """Developers may manually attach descriptions (§4.3)."""
        self.entries[name].description = text

    def search(self, pattern: str) -> list[CatalogEntry]:
        rx = namespace.compile_pattern(pattern)
        return sorted((e for n, e in self.entries.items() if rx.match(n)),
                      key=lambda e: e.code)

    def browse(self, **level_filters: str) -> list[CatalogEntry]:
        """Filter by namespace components, e.g. browse(client='web', page='home')."""
        idx = {lvl: i for i, lvl in enumerate(namespace.LEVELS)}
        out = []
        for e in self.entries.values():
            parts = e.levels()
            if all(parts[idx[k]] == v for k, v in level_filters.items()):
                out.append(e)
        return sorted(out, key=lambda e: e.code)

    def top(self, k: int = 20) -> list[CatalogEntry]:
        return sorted(self.entries.values(), key=lambda e: e.code)[:k]

    def coverage(self) -> dict:
        total = sum(e.count for e in self.entries.values())
        top = self.top(100)
        return dict(
            names=len(self.entries),
            events=total,
            top100_frac=(sum(e.count for e in top) / total) if total else 0.0,
            described=sum(1 for e in self.entries.values() if e.description),
        )

    def save(self, path: str) -> None:
        payload = {n: dict(code=e.code, count=e.count, samples=e.samples,
                           description=e.description)
                   for n, e in self.entries.items()}
        with open(path, "w") as f:
            json.dump(payload, f)

    @staticmethod
    def load(path: str) -> "EventCatalog":
        with open(path) as f:
            payload = json.load(f)
        return EventCatalog({
            n: CatalogEntry(name=n, **v) for n, v in payload.items()})
