"""Auto-generated client-event catalog (paper §4.3).

Rebuilt from every dictionary/histogram job, so always up to date: per event
name it records the frequency-ordered code, daily count, a few sample
events, and (optionally) developer-supplied descriptions. Browsable
hierarchically, by namespace component, or by regex — the paper's interface,
minus the web frontend.

With the segment store (``repro.data.store``) the catalog stops being an
in-memory toy: every segment already carries a sparse code histogram in its
metadata, so ``CatalogBuilder`` maintains the counts *incrementally* — a
refresh folds in only segments added since the last call and retracts the
ones compaction replaced, never re-decoding a single payload byte.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

from . import namespace
from .dictionary import EventDictionary
from .events import EventBatch


@dataclass
class CatalogEntry:
    name: str
    code: int
    count: int
    samples: list[str] = field(default_factory=list)  # sample event JSON
    description: str = ""

    def levels(self) -> tuple[str, ...]:
        return namespace.parse(self.name).parts()


@dataclass
class EventCatalog:
    entries: dict[str, CatalogEntry]

    @staticmethod
    def build(dictionary: EventDictionary, batch: EventBatch | None = None,
              samples_per_event: int = 3,
              descriptions: dict[str, str] | None = None) -> "EventCatalog":
        entries: dict[str, CatalogEntry] = {}
        sample_map: dict[int, list[str]] = {}
        if batch is not None and batch.details is not None:
            # First-k sampling per name id (the histogram job samples while
            # it scans — §4.2).
            for i in range(len(batch)):
                nid = int(batch.name_id[i])
                bucket = sample_map.setdefault(nid, [])
                if len(bucket) < samples_per_event:
                    bucket.append(batch.event_at(i).to_json())
        for nid, name in enumerate(dictionary.table.names):
            entries[name] = CatalogEntry(
                name=name,
                code=int(dictionary.code_of_name[nid]),
                count=int(dictionary.counts[nid]),
                samples=sample_map.get(nid, []),
                description=(descriptions or {}).get(name, ""),
            )
        return EventCatalog(entries)

    def describe(self, name: str, text: str) -> None:
        """Developers may manually attach descriptions (§4.3)."""
        self.entries[name].description = text

    def search(self, pattern: str) -> list[CatalogEntry]:
        rx = namespace.compile_pattern(pattern)
        return sorted((e for n, e in self.entries.items() if rx.match(n)),
                      key=lambda e: e.code)

    def browse(self, **level_filters: str) -> list[CatalogEntry]:
        """Filter by namespace components, e.g. browse(client='web', page='home')."""
        idx = {lvl: i for i, lvl in enumerate(namespace.LEVELS)}
        out = []
        for e in self.entries.values():
            parts = e.levels()
            if all(parts[idx[k]] == v for k, v in level_filters.items()):
                out.append(e)
        return sorted(out, key=lambda e: e.code)

    def top(self, k: int = 20) -> list[CatalogEntry]:
        return sorted(self.entries.values(), key=lambda e: e.code)[:k]

    def coverage(self) -> dict:
        total = sum(e.count for e in self.entries.values())
        top = self.top(100)
        return dict(
            names=len(self.entries),
            events=total,
            top100_frac=(sum(e.count for e in top) / total) if total else 0.0,
            described=sum(1 for e in self.entries.values() if e.description),
        )

    def save(self, path: str) -> None:
        payload = {n: dict(code=e.code, count=e.count, samples=e.samples,
                           description=e.description)
                   for n, e in self.entries.items()}
        with open(path, "w") as f:
            json.dump(payload, f)

    @staticmethod
    def load(path: str) -> "EventCatalog":
        with open(path) as f:
            payload = json.load(f)
        return EventCatalog({
            n: CatalogEntry(name=n, **v) for n, v in payload.items()})

    @staticmethod
    def from_store(dictionary: EventDictionary, store,
                   descriptions: dict[str, str] | None = None
                   ) -> "EventCatalog":
        """One-shot catalog from a segment store's metadata (convenience
        over ``CatalogBuilder`` for callers without an update loop)."""
        return CatalogBuilder(dictionary,
                              descriptions=descriptions).refresh(store)


class CatalogBuilder:
    """Incremental catalog maintenance over a segment store.

    ``store`` is duck-typed: anything with a ``segments`` list of objects
    carrying ``seg_id`` and ``code_counts`` (``repro.data.store.Store``).
    Per-segment histograms are cached by segment id, so ``refresh`` costs
    O(segments changed): new segments (appends, compaction outputs) fold
    in, vanished ids (segments compaction consumed) retract — counts always
    equal a from-scratch rebuild over the live segments, which is the
    invariant tests assert. Counts are over *stored* symbols, so the
    catalog reflects exactly what the store serves (post-dedup,
    post-truncation), the way the paper's daily histogram job reflects the
    materialized log.
    """

    def __init__(self, dictionary: EventDictionary,
                 descriptions: dict[str, str] | None = None):
        self.dictionary = dictionary
        self.descriptions = descriptions or {}
        self._seen: dict[int, dict[int, int]] = {}   # seg_id -> code counts
        self._counts: dict[int, int] = {}            # code -> running count
        self.refreshes = 0
        self.segments_folded = 0
        self.segments_retracted = 0

    def refresh(self, store) -> EventCatalog:
        """Fold segment deltas since the last refresh; return the catalog."""
        live = {seg.seg_id: seg for seg in store.segments}
        for sid in [s for s in self._seen if s not in live]:
            for code, c in self._seen.pop(sid).items():
                self._counts[code] -= c
            self.segments_retracted += 1
        for sid, seg in live.items():
            if sid in self._seen:
                continue
            counts = dict(seg.code_counts)
            self._seen[sid] = counts
            for code, c in counts.items():
                self._counts[code] = self._counts.get(code, 0) + c
            self.segments_folded += 1
        self.refreshes += 1
        return self.catalog()

    def catalog(self) -> EventCatalog:
        d = self.dictionary
        entries: dict[str, CatalogEntry] = {}
        for nid, name in enumerate(d.table.names):
            code = int(d.code_of_name[nid])
            entries[name] = CatalogEntry(
                name=name, code=code,
                count=int(self._counts.get(code, 0)),
                description=self.descriptions.get(name, ""))
        return EventCatalog(entries)
