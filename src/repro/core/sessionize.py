"""Session reconstruction (paper §4.2) as a TPU-native sort + segment pass.

The paper reconstructs sessions with a Hadoop group-by on
``(user_id, session_id)`` followed by a 30-minute-inactivity split. Here the
same dataflow is a single fused lexicographic sort (``jax.lax.sort`` with
``num_keys=3`` over user, session, timestamp) followed by segment-boundary
detection and ``segment_*`` reductions — no shuffle, no reducers, one XLA
program. The distributed variant (dist/collectives.py) prepends the paper's
shuffle as an ``all_to_all`` keyed repartition over the mesh ``data`` axis.

Identifiers and timestamps are int64; JAX defaults to 32-bit, so the jitted
pipeline is traced under ``jax.experimental.enable_x64`` — scoped here only,
never leaking into model code.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

# 30 minutes, following standard practice (paper §4.2).
DEFAULT_GAP_MS = 30 * 60 * 1000
PAD_CODE = -1  # padding symbol in materialized sequence tensors

_I64_MAX = np.iinfo(np.int64).max


@dataclass
class Sessionized:
    """Result of one sessionize pass. All arrays are device/ndarray.

    ``num_sessions`` is the *true* session count; arrays are materialized at
    the static caps (max_sessions, max_len) — rows past num_sessions and
    positions past length are padding. ``truncated`` flags capacity overflow
    so callers can re-run with larger caps (production behaviour: the log
    mover sizes caps from the histogram job's stats).
    """
    symbols: jax.Array      # (max_sessions, max_len) int32, PAD_CODE padded
    length: jax.Array       # (max_sessions,) int32 — true event count (may exceed max_len)
    user_id: jax.Array      # (max_sessions,) int64
    session_id: jax.Array   # (max_sessions,) int64
    ip: jax.Array           # (max_sessions,) int64 (uint32 range)
    start_ts: jax.Array     # (max_sessions,) int64 ms
    duration_s: jax.Array   # (max_sessions,) int32 seconds (paper stores seconds)
    num_sessions: jax.Array # () int32
    num_events: jax.Array   # () int32 — valid events processed
    truncated: jax.Array    # () bool — any session cap overflow

    def trimmed(self) -> "Sessionized":
        n = int(self.num_sessions)
        return Sessionized(
            symbols=np.asarray(self.symbols)[:n],
            length=np.asarray(self.length)[:n],
            user_id=np.asarray(self.user_id)[:n],
            session_id=np.asarray(self.session_id)[:n],
            ip=np.asarray(self.ip)[:n],
            start_ts=np.asarray(self.start_ts)[:n],
            duration_s=np.asarray(self.duration_s)[:n],
            num_sessions=np.int32(n),
            num_events=np.asarray(self.num_events),
            truncated=np.asarray(self.truncated),
        )


@jax.jit
def mark_duplicate_events(user_id, session_id, timestamp, code, ip, valid):
    """Within-user exact-duplicate removal — returns the validity mask with
    retry duplicates cleared.

    Scribe delivery is at-least-once: client retries and daemon resends
    materialize as byte-identical event rows (§3.1; the log mover absorbs
    file-level dupes, row-level ones survive into the warehouse). Two rows
    are duplicates when all of (user_id, session_id, timestamp, code, ip)
    match; the first occurrence (original order) survives. Implemented as
    one stable 5-key ``lax.sort`` + neighbour compare + scatter-back through
    the carried index column — the same sort-based group-by the sessionizer
    uses, so it composes with it inside a single shard_map stage.
    """
    n = user_id.shape[0]
    i64max = jnp.asarray(_I64_MAX, jnp.int64)
    u = jnp.where(valid, user_id, i64max)
    s = jnp.where(valid, session_id, i64max)
    t = jnp.where(valid, timestamp, i64max)
    c = jnp.where(valid, code.astype(jnp.int64), i64max)
    p = jnp.where(valid, ip.astype(jnp.int64), i64max)
    idx = jnp.arange(n, dtype=jnp.int32)
    u, s, t, c, p, idx_s, valid_s = jax.lax.sort(
        (u, s, t, c, p, idx, valid.astype(jnp.int32)),
        num_keys=5, is_stable=True)
    valid_s = valid_s.astype(bool)
    same = ((u == jnp.roll(u, 1)) & (s == jnp.roll(s, 1))
            & (t == jnp.roll(t, 1)) & (c == jnp.roll(c, 1))
            & (p == jnp.roll(p, 1)))
    # Invalid rows sort last (all-max keys), so a valid row's predecessor is
    # always valid; first row can never be a duplicate.
    dup = same & valid_s & (jnp.arange(n) != 0)
    keep_sorted = valid_s & ~dup
    return jnp.zeros(n, bool).at[idx_s].set(keep_sorted)


@functools.partial(jax.jit, static_argnames=("gap_ms", "max_sessions",
                                             "max_len", "with_event_grids"))
def _sessionize(user_id, session_id, timestamp, code, ip, valid,
                *, gap_ms: int, max_sessions: int, max_len: int,
                with_event_grids: bool = False):
    n = user_id.shape[0]
    i64max = jnp.asarray(_I64_MAX, jnp.int64)

    # Invalid rows sort to the end (all-max keys).
    u = jnp.where(valid, user_id, i64max)
    s = jnp.where(valid, session_id, i64max)
    t = jnp.where(valid, timestamp, i64max)

    u, s, t, code_s, ip_s, valid_s = jax.lax.sort(
        (u, s, t, code.astype(jnp.int32), ip.astype(jnp.int64),
         valid.astype(jnp.int32)),
        num_keys=3, is_stable=True)
    valid_s = valid_s.astype(bool)

    idx = jnp.arange(n, dtype=jnp.int32)
    prev_u = jnp.roll(u, 1)
    prev_s = jnp.roll(s, 1)
    prev_t = jnp.roll(t, 1)
    first = idx == 0
    new_seg = valid_s & (first
                         | (u != prev_u)
                         | (s != prev_s)
                         | ((t - prev_t) > gap_ms))

    # Dense segment id per event; invalid rows -> drop bucket (= max_sessions
    # after clamping, also used for capacity overflow).
    seg = jnp.cumsum(new_seg.astype(jnp.int32)) - 1
    seg = jnp.where(valid_s, seg, max_sessions)
    overflow = seg > max_sessions
    seg = jnp.minimum(seg, max_sessions)

    num_sessions_true = jnp.sum(new_seg.astype(jnp.int32))
    num_sessions = jnp.minimum(num_sessions_true, max_sessions)
    num_events = jnp.sum(valid_s.astype(jnp.int32))

    nseg = max_sessions + 1  # + drop bucket
    ones = jnp.ones_like(seg)
    length = jax.ops.segment_sum(ones, seg, num_segments=nseg)
    start_idx = jax.ops.segment_min(idx, seg, num_segments=nseg)
    start_ts = jax.ops.segment_min(t, seg, num_segments=nseg)
    end_ts = jax.ops.segment_max(
        jnp.where(valid_s, t, jnp.asarray(0, jnp.int64)), seg, num_segments=nseg)
    seg_user = jax.ops.segment_max(
        jnp.where(valid_s, u, jnp.asarray(-1, jnp.int64)), seg, num_segments=nseg)
    seg_sess = jax.ops.segment_max(
        jnp.where(valid_s, s, jnp.asarray(-1, jnp.int64)), seg, num_segments=nseg)
    seg_ip = jax.ops.segment_max(
        jnp.where(valid_s, ip_s, jnp.asarray(-1, jnp.int64)), seg, num_segments=nseg)

    pos = idx - start_idx[seg]
    # Scatter codes into the padded (sessions, time) tensor; OOB rows/cols
    # (drop bucket, beyond max_len) are dropped by mode='drop'.
    symbols = jnp.full((max_sessions, max_len), PAD_CODE, jnp.int32)
    symbols = symbols.at[seg, pos].set(code_s, mode="drop")

    duration_s = ((end_ts[:max_sessions] - start_ts[:max_sessions])
                  // 1000).astype(jnp.int32)
    empty = length[:max_sessions] == 0
    extras = {}
    if with_event_grids:
        # Per-event grids aligned with ``symbols`` (streaming ring state:
        # data/streampipe.py re-sorts open sessions with new events each
        # tick, so it must keep every stored event's timestamp and ip).
        ts_grid = jnp.zeros((max_sessions, max_len), jnp.int64)
        ip_grid = jnp.zeros((max_sessions, max_len), jnp.int64)
        extras = dict(
            event_ts=ts_grid.at[seg, pos].set(t, mode="drop"),
            event_ip=ip_grid.at[seg, pos].set(ip_s, mode="drop"),
            end_ts=jnp.where(empty, 0, jnp.asarray(end_ts[:max_sessions])),
        )
    return dict(
        **extras,
        symbols=symbols,
        length=length[:max_sessions],
        user_id=jnp.where(empty, -1, seg_user[:max_sessions]),
        session_id=jnp.where(empty, -1, seg_sess[:max_sessions]),
        ip=jnp.where(empty, -1, seg_ip[:max_sessions]),
        start_ts=jnp.where(empty, 0, start_ts[:max_sessions]),
        duration_s=jnp.where(empty, 0, duration_s),
        num_sessions=num_sessions,
        num_events=num_events,
        truncated=jnp.any(overflow) | (num_sessions_true > max_sessions)
                  | jnp.any(length[:max_sessions] > max_len),
    )


def sessionize(user_id, session_id, timestamp, code, ip=None, valid=None, *,
               gap_ms: int = DEFAULT_GAP_MS,
               max_sessions: int | None = None,
               max_len: int | None = None,
               dedup: bool = False) -> Sessionized:
    """Reconstruct sessions and materialize padded symbol sequences.

    Inputs are parallel event columns in *arbitrary order* (the warehouse
    guarantees only partial time order, §2). Static caps default to
    worst-case (every event its own session / one session holding all).
    ``dedup=True`` drops exact retry duplicates first (the distributed
    pipeline's stage-2 semantics; see ``mark_duplicate_events``).
    """
    n = len(user_id)
    if max_sessions is None:
        max_sessions = n
    if max_len is None:
        max_len = n
    if ip is None:
        ip = np.zeros(n, np.int64)
    if valid is None:
        valid = np.ones(n, bool)
    with enable_x64():
        u = jnp.asarray(user_id, jnp.int64)
        s = jnp.asarray(session_id, jnp.int64)
        t = jnp.asarray(timestamp, jnp.int64)
        c = jnp.asarray(code, jnp.int32)
        i = jnp.asarray(ip, jnp.int64)
        v = jnp.asarray(valid, bool)
        if dedup:
            v = mark_duplicate_events(u, s, t, c, i, v)
        out = _sessionize(u, s, t, c, i, v,
                          gap_ms=int(gap_ms), max_sessions=int(max_sessions),
                          max_len=int(max_len))
    return Sessionized(**out)


def closed_prefix_mask(user_id, session_id, timestamp, *, gap_ms: int,
                       watermark: int) -> np.ndarray:
    """Per-event bool: the event's batch session is closed at
    ``watermark`` (its segment's last event + gap is strictly below it).

    Pure numpy oracle-side helper: segments are the batch sessionizer's
    ((user, session) group split on > ``gap_ms``). Within a group, closed
    segments are a prefix — so batch-sessionizing just the masked events
    reproduces exactly the closed sessions. Shared by the streaming tier's
    oracle harness (``data.streampipe``) and the segment store's compaction
    pass (``data.store``), which partitions event segments into
    closed-session rows vs the open residual with it.
    """
    u = np.asarray(user_id, np.int64)
    s = np.asarray(session_id, np.int64)
    t = np.asarray(timestamp, np.int64)
    n = len(u)
    if n == 0:
        return np.zeros(0, bool)
    order = np.lexsort((t, s, u))
    us, ss, ts = u[order], s[order], t[order]
    new_seg = np.ones(n, bool)
    new_seg[1:] = ((us[1:] != us[:-1]) | (ss[1:] != ss[:-1])
                   | ((ts[1:] - ts[:-1]) > gap_ms))
    seg = np.cumsum(new_seg) - 1
    last = np.full(int(seg[-1]) + 1, np.iinfo(np.int64).min, np.int64)
    np.maximum.at(last, seg, ts)
    out = np.zeros(n, bool)
    out[order] = (last[seg] + gap_ms) < watermark
    return out
