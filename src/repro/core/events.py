"""Unified client-event schema (paper §3.2, Table 2).

Every event in the unified logging format carries exactly the same fields
with exactly the same semantics::

    event_initiator : {client, server} x {user, app}
    event_name      : six-level hierarchical name (namespace.py)
    user_id         : int64
    session_id      : int64 (browser cookie / device identifier, hashed)
    ip              : uint32 (IPv4, anonymizable in one place by construction)
    timestamp       : int64 milliseconds since epoch
    event_details   : event-specific key/value pairs (free-form)

Two representations:

* ``ClientEvent`` — one record (the "Thrift struct"); used at the edges
  (generation, catalog samples, tests).
* ``EventBatch`` — columnar struct-of-arrays over an interned name table;
  this is what the JAX pipeline consumes. Interning event names into a
  ``NameTable`` mirrors Elephant Bird's generated readers: the schema is
  declared once and every downstream consumer shares it.
"""
from __future__ import annotations

import enum
import json
from dataclasses import dataclass, field
from typing import Any, Iterable, Mapping, Sequence

import numpy as np

from . import namespace


class EventInitiator(enum.IntEnum):
    """{client, server} x {user, app} (paper Table 2)."""
    CLIENT_USER = 0
    CLIENT_APP = 1
    SERVER_USER = 2
    SERVER_APP = 3


@dataclass(frozen=True)
class ClientEvent:
    event_initiator: EventInitiator
    event_name: str
    user_id: int
    session_id: int
    ip: int
    timestamp: int
    event_details: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        namespace.parse(self.event_name)  # validates

    def to_json(self) -> str:
        d = dict(
            event_initiator=int(self.event_initiator),
            event_name=self.event_name,
            user_id=int(self.user_id),
            session_id=int(self.session_id),
            ip=int(self.ip),
            timestamp=int(self.timestamp),
            event_details=dict(self.event_details),
        )
        return json.dumps(d, sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "ClientEvent":
        d = json.loads(s)
        return ClientEvent(
            event_initiator=EventInitiator(d["event_initiator"]),
            event_name=d["event_name"],
            user_id=d["user_id"],
            session_id=d["session_id"],
            ip=d["ip"],
            timestamp=d["timestamp"],
            event_details=d.get("event_details", {}),
        )


class NameTable:
    """Bidirectional intern table: canonical event name <-> dense int id.

    Ids are assigned in first-seen order; the frequency-ordered *code*
    assignment is a separate concern (core/dictionary.py), exactly as in the
    paper where the daily histogram job derives the coding dictionary from
    the raw name universe.
    """

    def __init__(self, names: Sequence[str] = ()):
        self._names: list[str] = []
        self._ids: dict[str, int] = {}
        for n in names:
            self.intern(n)

    def intern(self, name: str) -> int:
        got = self._ids.get(name)
        if got is not None:
            return got
        namespace.parse(name)  # validate on first sight
        nid = len(self._names)
        self._names.append(name)
        self._ids[name] = nid
        return nid

    def id_of(self, name: str) -> int:
        return self._ids[name]

    def name_of(self, nid: int) -> str:
        return self._names[nid]

    def __len__(self) -> int:
        return len(self._names)

    def __contains__(self, name: str) -> bool:
        return name in self._ids

    @property
    def names(self) -> list[str]:
        return list(self._names)

    def match_ids(self, pattern: str) -> np.ndarray:
        """Ids of all names matching a namespace glob pattern."""
        rx = namespace.compile_pattern(pattern)
        return np.array([i for i, n in enumerate(self._names) if rx.match(n)],
                        dtype=np.int32)

    def to_json(self) -> str:
        return json.dumps(self._names)

    @staticmethod
    def from_json(s: str) -> "NameTable":
        return NameTable(json.loads(s))


@dataclass
class EventBatch:
    """Columnar batch of client events over a shared NameTable.

    Arrays all share leading dim N. ``details`` is an optional object array
    of JSON strings — analytics over session sequences never touch it, which
    is the paper's point (§4.1: large query classes need names only).
    """
    table: NameTable
    name_id: np.ndarray        # int32 (N,)
    user_id: np.ndarray        # int64 (N,)
    session_id: np.ndarray     # int64 (N,)
    ip: np.ndarray             # uint32 (N,)
    timestamp: np.ndarray      # int64 (N,) ms
    initiator: np.ndarray      # int8  (N,)
    details: np.ndarray | None = None   # object (N,) json strings

    def __post_init__(self):
        n = len(self.name_id)
        for f in ("user_id", "session_id", "ip", "timestamp", "initiator"):
            arr = getattr(self, f)
            if len(arr) != n:
                raise ValueError(f"column {f} length {len(arr)} != {n}")

    def __len__(self) -> int:
        return len(self.name_id)

    @staticmethod
    def from_events(events: Iterable[ClientEvent],
                    table: NameTable | None = None) -> "EventBatch":
        table = table if table is not None else NameTable()
        rows = list(events)
        return EventBatch(
            table=table,
            name_id=np.array([table.intern(e.event_name) for e in rows], np.int32),
            user_id=np.array([e.user_id for e in rows], np.int64),
            session_id=np.array([e.session_id for e in rows], np.int64),
            ip=np.array([e.ip for e in rows], np.uint32),
            timestamp=np.array([e.timestamp for e in rows], np.int64),
            initiator=np.array([int(e.event_initiator) for e in rows], np.int8),
            details=np.array([json.dumps(dict(e.event_details), sort_keys=True)
                              for e in rows], dtype=object) if rows else None,
        )

    def event_at(self, i: int) -> ClientEvent:
        return ClientEvent(
            event_initiator=EventInitiator(int(self.initiator[i])),
            event_name=self.table.name_of(int(self.name_id[i])),
            user_id=int(self.user_id[i]),
            session_id=int(self.session_id[i]),
            ip=int(self.ip[i]),
            timestamp=int(self.timestamp[i]),
            event_details=(json.loads(self.details[i])
                           if self.details is not None else {}),
        )

    @staticmethod
    def concat(batches: Sequence["EventBatch"]) -> "EventBatch":
        """Concatenate batches, re-interning name ids into the first table."""
        if not batches:
            raise ValueError("need at least one batch")
        table = batches[0].table
        name_ids = []
        for b in batches:
            if b.table is table:
                name_ids.append(b.name_id)
            else:
                remap = np.array([table.intern(n) for n in b.table.names],
                                 np.int32)
                name_ids.append(remap[b.name_id])
        cat = lambda f: np.concatenate([getattr(b, f) for b in batches])
        details = None
        if all(b.details is not None for b in batches):
            details = np.concatenate([b.details for b in batches])
        return EventBatch(
            table=table,
            name_id=np.concatenate(name_ids),
            user_id=cat("user_id"),
            session_id=cat("session_id"),
            ip=cat("ip"),
            timestamp=cat("timestamp"),
            initiator=cat("initiator"),
            details=details,
        )

    def take(self, idx: np.ndarray) -> "EventBatch":
        return EventBatch(
            table=self.table,
            name_id=self.name_id[idx],
            user_id=self.user_id[idx],
            session_id=self.session_id[idx],
            ip=self.ip[idx],
            timestamp=self.timestamp[idx],
            initiator=self.initiator[idx],
            details=self.details[idx] if self.details is not None else None,
        )
