"""Byte-level variable-length materialization of session sequences (§4.2).

The paper's coding trick: frequent events get small unicode code points,
which need fewer bytes in UTF-8 — variable-length coding for free. We
reproduce it exactly: codes -> (surrogate-skipping) code points -> UTF-8.
The compression benchmark (benchmarks/compression.py) measures this against
the raw client-event log representation to validate the ~50x claim.

Also here: the vectorized LEB128 codecs the segment store
(``repro.data.store``) builds its columnar blobs from — unsigned varints
for counts/deltas and zigzag varints for signed id columns. Both encoder
and decoder are numpy-vectorized over the whole column (a python loop only
over the <=10 byte positions of the widest value), so encoding a segment
costs a handful of array passes, not a per-value interpreter loop.
"""
from __future__ import annotations

import numpy as np

from .sequences import SessionSequences, code_to_codepoint, codepoint_to_code

_U64_ONE = np.uint64(1)


def encode_uvarint(values) -> bytes:
    """LEB128-encode a non-negative int column (vectorized).

    Each value takes ``ceil(bit_length / 7)`` bytes, low 7 bits first, high
    bit of every byte but the last set (the protobuf/Thrift wire format).
    """
    v = np.ascontiguousarray(np.asarray(values).astype(np.uint64))
    if v.ndim != 1:
        v = v.reshape(-1)
    if v.size == 0:
        return b""
    n_bytes = np.ones(v.shape, np.int64)
    for k in range(1, 10):
        n_bytes += (v >= (_U64_ONE << np.uint64(7 * k))).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(n_bytes)[:-1]])
    out = np.zeros(int(starts[-1] + n_bytes[-1]), np.uint8)
    for k in range(int(n_bytes.max())):
        m = n_bytes > k
        byte = ((v[m] >> np.uint64(7 * k)) & np.uint64(0x7F)).astype(np.uint8)
        cont = (n_bytes[m] > k + 1).astype(np.uint8) << 7
        out[starts[m] + k] = byte | cont
    return out.tobytes()


def decode_uvarint(buf: bytes | np.ndarray, count: int,
                   offset: int = 0) -> tuple[np.ndarray, int]:
    """Decode ``count`` LEB128 values from ``buf[offset:]`` (vectorized).

    Returns ``(values uint64, next_offset)`` so column blocks can be read
    back to back from one segment blob.
    """
    if count == 0:
        return np.zeros(0, np.uint64), offset
    b = np.frombuffer(buf, np.uint8, offset=0)[offset:]
    ends = np.flatnonzero((b & 0x80) == 0)
    if len(ends) < count:
        raise ValueError(f"uvarint blob truncated: {len(ends)} terminators "
                         f"< {count} values")
    ends = ends[:count]
    starts = np.concatenate([[0], ends[:-1] + 1])
    widths = ends - starts + 1
    v = np.zeros(count, np.uint64)
    for k in range(int(widths.max())):
        m = widths > k
        v[m] |= ((b[starts[m] + k].astype(np.uint64)) & np.uint64(0x7F)) \
            << np.uint64(7 * k)
    return v, offset + int(ends[-1]) + 1


def zigzag(values) -> np.ndarray:
    """int64 -> uint64 zigzag map (small magnitudes -> small uvarints)."""
    v = np.asarray(values).astype(np.int64)
    return ((v << 1) ^ (v >> 63)).view(np.uint64)


def unzigzag(values: np.ndarray) -> np.ndarray:
    u = np.asarray(values, np.uint64)
    return ((u >> _U64_ONE).view(np.int64)) ^ -((u & _U64_ONE).view(np.int64))


def encode_ivarint(values) -> bytes:
    """Zigzag + LEB128 for signed columns (user/session ids)."""
    return encode_uvarint(zigzag(values))


def decode_ivarint(buf, count: int, offset: int = 0
                   ) -> tuple[np.ndarray, int]:
    u, offset = decode_uvarint(buf, count, offset)
    return unzigzag(u), offset


def utf8_length(codepoints: np.ndarray) -> np.ndarray:
    """Bytes per code point under UTF-8 (vectorized)."""
    cp = np.asarray(codepoints, np.int64)
    return np.where(cp < 0x80, 1,
                    np.where(cp < 0x800, 2,
                             np.where(cp < 0x10000, 3, 4))).astype(np.int64)


def encoded_size_bytes(seqs: SessionSequences) -> int:
    """Total UTF-8 bytes to store all session_sequence strings."""
    mask = seqs.mask()
    cps = code_to_codepoint(np.where(mask, seqs.symbols, 0))
    return int((utf8_length(cps) * mask).sum())


def encode_session(symbols: np.ndarray) -> bytes:
    """One session's symbols -> UTF-8 bytes (a valid unicode string)."""
    cps = code_to_codepoint(np.asarray(symbols, np.int64))
    return "".join(chr(int(c)) for c in cps).encode("utf-8")


def decode_session(data: bytes) -> np.ndarray:
    cps = np.array([ord(ch) for ch in data.decode("utf-8")], np.int64)
    return codepoint_to_code(cps).astype(np.int32)


def encode_store(seqs: SessionSequences) -> list[bytes]:
    return [encode_session(seqs.session_symbols(i)) for i in range(len(seqs))]


def raw_log_size_bytes(num_events: int, mean_name_len: float,
                       mean_details_len: float = 64.0) -> int:
    """Model of the raw client-event Thrift record footprint, per §3.2
    Table 2: initiator(1) + name(string) + user_id(8) + session_id(8) +
    ip(4) + timestamp(8) + details(string) + Thrift field headers (~3 bytes
    per field x 7 fields).
    """
    per_event = 1 + mean_name_len + 8 + 8 + 4 + 8 + mean_details_len + 21
    return int(num_events * per_event)
