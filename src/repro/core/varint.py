"""Byte-level variable-length materialization of session sequences (§4.2).

The paper's coding trick: frequent events get small unicode code points,
which need fewer bytes in UTF-8 — variable-length coding for free. We
reproduce it exactly: codes -> (surrogate-skipping) code points -> UTF-8.
The compression benchmark (benchmarks/compression.py) measures this against
the raw client-event log representation to validate the ~50x claim.
"""
from __future__ import annotations

import numpy as np

from .sequences import SessionSequences, code_to_codepoint, codepoint_to_code


def utf8_length(codepoints: np.ndarray) -> np.ndarray:
    """Bytes per code point under UTF-8 (vectorized)."""
    cp = np.asarray(codepoints, np.int64)
    return np.where(cp < 0x80, 1,
                    np.where(cp < 0x800, 2,
                             np.where(cp < 0x10000, 3, 4))).astype(np.int64)


def encoded_size_bytes(seqs: SessionSequences) -> int:
    """Total UTF-8 bytes to store all session_sequence strings."""
    mask = seqs.mask()
    cps = code_to_codepoint(np.where(mask, seqs.symbols, 0))
    return int((utf8_length(cps) * mask).sum())


def encode_session(symbols: np.ndarray) -> bytes:
    """One session's symbols -> UTF-8 bytes (a valid unicode string)."""
    cps = code_to_codepoint(np.asarray(symbols, np.int64))
    return "".join(chr(int(c)) for c in cps).encode("utf-8")


def decode_session(data: bytes) -> np.ndarray:
    cps = np.array([ord(ch) for ch in data.decode("utf-8")], np.int64)
    return codepoint_to_code(cps).astype(np.int32)


def encode_store(seqs: SessionSequences) -> list[bytes]:
    return [encode_session(seqs.session_symbols(i)) for i in range(len(seqs))]


def raw_log_size_bytes(num_events: int, mean_name_len: float,
                       mean_details_len: float = 64.0) -> int:
    """Model of the raw client-event Thrift record footprint, per §3.2
    Table 2: initiator(1) + name(string) + user_id(8) + session_id(8) +
    ip(4) + timestamp(8) + details(string) + Thrift field headers (~3 bytes
    per field x 7 fields).
    """
    per_event = 1 + mean_name_len + 8 + 8 + 4 + 8 + mean_details_len + 21
    return int(num_events * per_event)
