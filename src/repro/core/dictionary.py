"""Frequency-ordered client-event dictionary (paper §4.2).

The paper maps each event name to a Unicode code point such that *more
frequent events get smaller code points* — a variable-length code, since
small code points need fewer bytes in UTF-8. We reproduce the bijection
exactly: ``code_of_name[name_id] -> code`` where codes 0..K-1 are assigned by
descending frequency (ties broken by name id for determinism). ``varint.py``
materializes the byte-level representation; in-memory analytics operate on
the int32 codes directly.

The histogram pass is the JAX analogue of the daily Oink job that scans the
client-event logs: a ``segment_sum`` over name ids (and, distributed, a
``psum`` across the data axis — see dist/collectives.py).
"""
from __future__ import annotations

import functools
import json
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .events import NameTable


@functools.partial(jax.jit, static_argnames=("num_names",))
def _histogram(name_ids: jax.Array, valid: jax.Array, num_names: int) -> jax.Array:
    # Invalid rows route to an out-of-range drop segment.
    ids = jnp.where(valid, name_ids, num_names)
    ones = jnp.ones_like(ids, dtype=jnp.int64)
    return jax.ops.segment_sum(ones, ids, num_segments=num_names + 1)[:num_names]


def histogram(name_ids, num_names: int, valid=None) -> jax.Array:
    """Event-count histogram over name ids; invalid rows excluded.

    int64 counts (the daily volume is ~1e11 events at paper scale), so the
    pass runs under the scoped x64 context like the rest of the pipeline.
    """
    name_ids = jnp.asarray(name_ids, jnp.int32)
    if valid is None:
        valid = jnp.ones(name_ids.shape, bool)
    with enable_x64():
        return _histogram(name_ids, jnp.asarray(valid, bool), int(num_names))


def assign_codes(counts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Assign codes by descending count, ties by ascending name id.

    Returns (code_of_name, name_of_code) — inverse permutations of each
    other. Names with zero observed count still receive (large) codes, so
    the mapping is total over the name universe, as in the paper where the
    dictionary covers every event in the daily catalog.
    """
    counts = np.asarray(counts, np.int64)
    k = len(counts)
    # np.lexsort: last key is primary. Primary: -counts; secondary: name id.
    name_of_code = np.lexsort((np.arange(k), -counts)).astype(np.int32)
    code_of_name = np.empty(k, np.int32)
    code_of_name[name_of_code] = np.arange(k, dtype=np.int32)
    return code_of_name, name_of_code


@dataclass
class EventDictionary:
    """Bijection between the event-name universe and frequency-ordered codes."""
    table: NameTable
    counts: np.ndarray          # int64 (K,) — per name id
    code_of_name: np.ndarray    # int32 (K,)
    name_of_code: np.ndarray    # int32 (K,)

    @staticmethod
    def build(table: NameTable, name_ids, valid=None) -> "EventDictionary":
        counts = np.asarray(histogram(name_ids, len(table), valid=valid))
        code_of_name, name_of_code = assign_codes(counts)
        return EventDictionary(table, counts, code_of_name, name_of_code)

    @property
    def alphabet_size(self) -> int:
        return len(self.counts)

    def encode_ids(self, name_ids):
        """name ids -> frequency codes (vectorized gather)."""
        return jnp.asarray(self.code_of_name)[jnp.asarray(name_ids, jnp.int32)]

    def decode_codes(self, codes):
        """frequency codes -> name ids."""
        return jnp.asarray(self.name_of_code)[jnp.asarray(codes, jnp.int32)]

    def code_of(self, name: str) -> int:
        return int(self.code_of_name[self.table.id_of(name)])

    def name_of(self, code: int) -> str:
        return self.table.name_of(int(self.name_of_code[code]))

    def codes_matching(self, pattern: str) -> np.ndarray:
        """Codes of all event names matching a namespace glob pattern.

        This is the dictionary-mediated regex expansion the paper's
        ``CountClientEvents('$EVENTS')`` UDF performs at init.
        """
        return self.code_of_name[self.table.match_ids(pattern)]

    def count_of_code(self, code: int) -> int:
        return int(self.counts[self.name_of_code[code]])

    def save(self, path: str) -> None:
        payload = dict(names=self.table.names, counts=self.counts.tolist())
        with open(path, "w") as f:
            json.dump(payload, f)

    @staticmethod
    def load(path: str) -> "EventDictionary":
        with open(path) as f:
            payload = json.load(f)
        table = NameTable(payload["names"])
        counts = np.asarray(payload["counts"], np.int64)
        code_of_name, name_of_code = assign_codes(counts)
        return EventDictionary(table, counts, code_of_name, name_of_code)

    def verify(self) -> None:
        """Invariants: bijection + monotone frequency ordering."""
        k = self.alphabet_size
        assert sorted(self.code_of_name.tolist()) == list(range(k))
        assert np.array_equal(self.code_of_name[self.name_of_code], np.arange(k))
        ordered = self.counts[self.name_of_code]
        assert np.all(ordered[:-1] >= ordered[1:]), "codes not frequency-ordered"
