"""Pure-Python oracles for the core pipeline (property-test references).

These implement the paper's semantics the "Pig way" — dict-based group-by,
explicit sorting — and are compared against the vectorized JAX pipeline in
tests/ and used by benchmarks/ as the unoptimized baseline.
"""
from __future__ import annotations

from collections import defaultdict

import numpy as np

from .sessionize import DEFAULT_GAP_MS


def sessionize_oracle(user_id, session_id, timestamp, code, ip=None,
                      valid=None, gap_ms: int = DEFAULT_GAP_MS):
    """Group-by (user, session) -> time sort -> 30-min split.

    Returns a list of session dicts sorted by (user_id, session_id,
    start_ts) — the same order the vectorized pipeline emits.
    """
    n = len(user_id)
    ip = np.zeros(n, np.int64) if ip is None else np.asarray(ip)
    valid = np.ones(n, bool) if valid is None else np.asarray(valid)
    groups: dict[tuple[int, int], list[tuple[int, int, int]]] = defaultdict(list)
    for i in range(n):
        if not valid[i]:
            continue
        groups[(int(user_id[i]), int(session_id[i]))].append(
            (int(timestamp[i]), int(code[i]), int(ip[i])))
    sessions = []
    for (u, s), rows in sorted(groups.items()):
        rows.sort()
        cur: list[tuple[int, int, int]] = []
        for row in rows:
            if cur and row[0] - cur[-1][0] > gap_ms:
                sessions.append(_emit(u, s, cur))
                cur = []
            cur.append(row)
        if cur:
            sessions.append(_emit(u, s, cur))
    return sessions


def _emit(u, s, rows):
    ts = [r[0] for r in rows]
    return dict(
        user_id=u,
        session_id=s,
        symbols=[r[1] for r in rows],
        ip=max(r[2] for r in rows),
        start_ts=ts[0],
        duration_s=(ts[-1] - ts[0]) // 1000,
        length=len(rows),
    )


def dedup_events_oracle(user_id, session_id, timestamp, code, ip=None,
                        valid=None) -> np.ndarray:
    """Reference for ``core.sessionize.mark_duplicate_events``: the validity
    mask with exact retry duplicates — identical (user, session, timestamp,
    code, ip) rows after the first — cleared, the "Pig way" (one seen-set)."""
    n = len(user_id)
    ip = np.zeros(n, np.int64) if ip is None else np.asarray(ip)
    valid = np.ones(n, bool) if valid is None else np.asarray(valid)
    seen: set[tuple] = set()
    keep = np.zeros(n, bool)
    for i in range(n):
        if not valid[i]:
            continue
        key = (int(user_id[i]), int(session_id[i]), int(timestamp[i]),
               int(code[i]), int(ip[i]))
        if key not in seen:
            seen.add(key)
            keep[i] = True
    return keep


def histogram_oracle(name_ids, num_names, valid=None):
    valid = np.ones(len(name_ids), bool) if valid is None else np.asarray(valid)
    out = np.zeros(num_names, np.int64)
    for i, nid in enumerate(name_ids):
        if valid[i]:
            out[int(nid)] += 1
    return out


def count_events_oracle(sessions, target_codes) -> tuple[int, int]:
    """(total occurrences, sessions with >=1 occurrence) — the SUM and COUNT
    variants of the paper's CountClientEvents UDF (§5.2)."""
    targets = set(int(c) for c in np.asarray(target_codes).ravel())
    total = 0
    containing = 0
    for sess in sessions:
        c = sum(1 for sym in sess["symbols"] if sym in targets)
        total += c
        containing += 1 if c > 0 else 0
    return total, containing


def funnel_oracle(sessions, stages) -> list[int]:
    """Per-stage reach counts (paper §5.3).

    ``stages`` is a list of stage specs; each spec is a set of codes that
    satisfy the stage. A session reaches stage k if stages 0..k match in
    order (subsequence semantics, the paper's regex over the session
    string). Returns reach[k] = #sessions whose deepest stage >= k.
    """
    stage_sets = [set(int(c) for c in np.asarray(s).ravel()) for s in stages]
    reach = [0] * len(stage_sets)
    for sess in sessions:
        k = 0
        for sym in sess["symbols"]:
            if k < len(stage_sets) and sym in stage_sets[k]:
                k += 1
                if k == len(stage_sets):
                    break
        for j in range(k):
            reach[j] += 1
    return reach


def ngram_counts_oracle(sessions, n: int):
    """n-gram -> count over session symbol streams (no cross-session grams)."""
    out: dict[tuple, int] = defaultdict(int)
    for sess in sessions:
        syms = sess["symbols"]
        for i in range(len(syms) - n + 1):
            out[tuple(syms[i:i + n])] += 1
    return dict(out)
