"""Public histogram / event-count ops over session-sequence tensors."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import histogram_pallas
from .ref import histogram_ref


def histogram(symbols, mask, alphabet_size: int, *, impl: str = "ref"):
    """(alphabet,) counts of each code over valid positions of (S, L)."""
    symbols = jnp.asarray(symbols)
    mask = jnp.asarray(mask)
    if impl == "ref":
        return histogram_ref(symbols, mask, alphabet_size)
    flat = jnp.where(mask, symbols, -1).reshape(-1).astype(jnp.int32)
    return histogram_pallas(flat, alphabet_size=alphabet_size,
                            interpret=(impl == "interpret"))


def count_codes(symbols, mask, target_codes, alphabet_size: int, *,
                impl: str = "ref") -> int:
    """Total occurrences of any target code (the SUM variant of §5.2)."""
    h = histogram(symbols, mask, alphabet_size, impl=impl)
    return int(h[jnp.asarray(target_codes)].sum())
