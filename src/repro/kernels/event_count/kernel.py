"""Blocked alphabet histogram — Pallas TPU kernel.

The daily dictionary/count job (§4.2) reduced to hardware terms: scatter-add
histograms are hostile to the VPU (serialized RMW), so the TPU-native
formulation is compare-and-reduce — for an alphabet tile A and a symbol tile
S, counts[a] += sum_s (S == a), an (|S| x |A|) broadcast compare reduced
over symbols. All tiles live in VMEM; the alphabet axis is the innermost
sequential grid dim so each symbol tile is read once per alphabet tile.

Grid = (alphabet/block_a, N/block_n); out tile (block_a,) accumulates across
the sequential n axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(sym_ref, out_ref, *, block_a: int, num_n_blocks: int):
    ia = pl.program_id(0)
    in_ = pl.program_id(1)

    @pl.when(in_ == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    sym = sym_ref[...]                                   # (block_n,) int32
    base = ia * block_a
    # (block_n, block_a) compare; invalid positions were pre-mapped to -1.
    a = base + jax.lax.broadcasted_iota(jnp.int32, (sym.shape[0], block_a), 1)
    eq = (sym[:, None] == a).astype(jnp.int32)
    out_ref[...] += jnp.sum(eq, axis=0)


def histogram_pallas(symbols_flat, *, alphabet_size: int,
                     block_a: int = 512, block_n: int = 4096,
                     interpret: bool = False):
    """symbols_flat: (N,) int32 with invalid positions = -1."""
    n = symbols_flat.shape[0]
    block_n = min(block_n, n)
    pad_n = (-n) % block_n
    if pad_n:
        symbols_flat = jnp.pad(symbols_flat, (0, pad_n),
                               constant_values=-1)
    block_a = min(block_a, alphabet_size)
    pad_a = (-alphabet_size) % block_a
    a_total = alphabet_size + pad_a
    nn = symbols_flat.shape[0] // block_n

    out = pl.pallas_call(
        functools.partial(_hist_kernel, block_a=block_a, num_n_blocks=nn),
        grid=(a_total // block_a, nn),
        in_specs=[pl.BlockSpec((block_n,), lambda ia, in_: (in_,))],
        out_specs=pl.BlockSpec((block_a,), lambda ia, in_: (ia,)),
        out_shape=jax.ShapeDtypeStruct((a_total,), jnp.int32),
        interpret=interpret,
    )(symbols_flat)
    return out[:alphabet_size]
