"""Pure-jnp oracle for the blocked alphabet histogram."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def histogram_ref(symbols, mask, alphabet_size: int) -> jnp.ndarray:
    """(alphabet,) int32 counts of each code over valid positions."""
    ids = jnp.where(mask, jnp.clip(symbols, 0, alphabet_size - 1),
                    alphabet_size)
    return jax.ops.segment_sum(
        jnp.ones(ids.size, jnp.int32), ids.reshape(-1),
        num_segments=alphabet_size + 1)[:alphabet_size]
