"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd public wrapper with impl dispatch), ref.py (pure-jnp oracle).
Kernels are validated against their oracles in interpret mode on CPU; the
dry-run/compile path uses the oracles (XLA-fused), since Pallas lowers to
TPU only.
"""
from .flash_attention.ops import flash_attention
from .funnel_match.ops import deepest_stage, reach_counts
from .event_count.ops import histogram as event_histogram, count_codes

__all__ = ["flash_attention", "deepest_stage", "reach_counts",
           "event_histogram", "count_codes"]
