"""Jit'd public wrapper for flash attention.

``flash_attention(..., impl=...)``:
* ``"pallas"``    — TPU Pallas kernel (kernel.py);
* ``"interpret"`` — same kernel, Pallas interpret mode (CPU validation);
* ``"ref"``       — pure-jnp oracle (ref.py); the dry-run/compile path.

Gradients flow through a recompute-based custom_vjp: the backward pass
re-derives attention from the oracle formulation (flash backward recomputes
p block-wise on TPU anyway; on this CPU container the oracle *is* the
backward). This keeps the Pallas surface forward-only while training end to
end — documented in DESIGN.md §Hardware-adaptation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd, paged_decode_attention_fwd
from .ref import attention_ref, attention_blocked, paged_attention_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_pallas(q, k, v, causal, scale, kv_len, q_offset, interpret):
    return flash_attention_fwd(q, k, v, causal=causal, scale=scale,
                               kv_len=kv_len, q_offset=q_offset,
                               interpret=interpret)


def _flash_fwd_rule(q, k, v, causal, scale, kv_len, q_offset, interpret):
    out = _flash_pallas(q, k, v, causal, scale, kv_len, q_offset, interpret)
    return out, (q, k, v)


def _flash_bwd_rule(causal, scale, kv_len, q_offset, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(
            q_, k_, v_, causal=causal, scale=scale, kv_len=kv_len,
            q_offset=q_offset), q, k, v)
    return vjp(g)


_flash_pallas.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(q, k, v, *, causal: bool = True,
                    scale: float | None = None, kv_len=None,
                    q_offset=0, impl: str = "ref", unroll: bool = False):
    """GQA attention. q: (B, H, Lq, D); k, v: (B, KVH, Lk, D).

    ``impl="ref"`` accepts traced kv_len/q_offset (the decode path);
    the Pallas impls require them static (training/prefill shapes).
    Per-row (B,)-shaped kv_len/q_offset — the continuous-batching decode
    path, Lq == 1 — always routes to the oracle: single-row scores are
    cheap and the Pallas kernel's masking is scalar-only.
    ``unroll`` unrolls the blocked impl's k-scan (cost-mode compiles).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    per_row = (kv_len is not None and jnp.ndim(kv_len) >= 1) or \
        jnp.ndim(q_offset) >= 1
    if per_row:
        if q.shape[2] != 1:
            raise ValueError(
                "per-row kv_len/q_offset is single-token decode only "
                f"(got Lq={q.shape[2]}); ragged prefill uses scalar "
                "kv_len with per-row logit reads instead")
        return attention_ref(q, k, v, causal=causal, scale=scale,
                             kv_len=kv_len, q_offset=q_offset)
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, scale=scale,
                             kv_len=kv_len, q_offset=q_offset)
    if impl == "blocked":
        if q.shape[2] == 1:   # decode: single-row scores are already cheap
            return attention_ref(q, k, v, causal=causal, scale=scale,
                                 kv_len=kv_len, q_offset=q_offset)
        return attention_blocked(q, k, v, causal=causal, scale=scale,
                                 kv_len=kv_len, q_offset=q_offset,
                                 unroll=unroll)
    if impl not in ("pallas", "interpret"):
        raise ValueError(
            f"unknown flash-attention impl {impl!r}; expected "
            "'ref' | 'blocked' | 'interpret' | 'pallas'")
    return _flash_pallas(q, k, v, causal, float(scale), kv_len, q_offset,
                         impl == "interpret")


def paged_decode_attention(q, k_pool, v_pool, block_table, kv_len, *,
                           scale: float | None = None, impl: str = "ref"):
    """Block-sparse decode attention through a paged KV pool.

    q: (B, H, 1, D); k_pool/v_pool: (N, KVH, bs, D);
    block_table: (B, max_blocks) int32; kv_len: (B,) int32 per-row valid
    length (the query sits at ``kv_len - 1``).

    ``impl="ref"``/``"blocked"`` gather through the table and run the
    per-row oracle — bit-equal to the dense decode path by construction.
    ``"interpret"``/``"pallas"`` run the Pallas kernel, which tiles over
    blocks via scalar-prefetched index maps and never materializes the
    gather. Forward-only (decode never differentiates).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if q.shape[2] != 1 or block_table.ndim != 2 or jnp.ndim(kv_len) != 1:
        raise ValueError(
            "paged decode attention is per-row single-token only: "
            f"got Lq={q.shape[2]}, table ndim={block_table.ndim}, "
            f"kv_len ndim={jnp.ndim(kv_len)}")
    if impl in ("pallas", "interpret"):
        return paged_decode_attention_fwd(
            q, k_pool, v_pool, block_table, kv_len, scale=float(scale),
            interpret=impl == "interpret")
    if impl not in ("ref", "blocked"):
        raise ValueError(
            f"unknown paged-attention impl {impl!r}; expected "
            "'ref' | 'blocked' | 'interpret' | 'pallas'")
    return paged_attention_ref(q, k_pool, v_pool, block_table, kv_len,
                               scale=scale)
