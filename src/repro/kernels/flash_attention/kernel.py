"""Flash attention forward — Pallas TPU kernel.

Online-softmax blocked attention with explicit VMEM tiling:

* grid = (batch, q_heads, Lq/block_q, Lk/block_k); the k axis is the
  innermost (sequential on TPU) so the (m, l, acc) running statistics live
  in VMEM scratch across k steps;
* GQA is native: the k/v BlockSpec index_map divides the q-head index by the
  group size, so kv tiles are fetched once per group — no head replication
  in HBM;
* block shapes default to (block_q, d) x (block_k, d) with d padded to the
  128-lane register width; MXU work is the (block_q, block_k) @ (block_k, d)
  pair per step;
* causal masking prunes *compute* inside fully-masked blocks via pl.when
  (the tile fetch still happens — grid skipping lands with scalar prefetch,
  noted in DESIGN.md as a TPU-side follow-up).

VMEM budget per step (bf16 in, f32 acc), defaults block_q=block_k=256,
d<=256: q 128KB + k/v 256KB + acc/m/l ~260KB + out 128KB << 16MB/core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                scale: float, causal: bool, kv_len: int, q_offset: int,
                block_q: int, block_k: int, num_k_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = q_offset + iq * block_q
    k_start = ik * block_k

    # Skip compute for blocks entirely above the causal diagonal or entirely
    # past kv_len; running stats are unchanged there.
    diag_live = (not causal) or (k_start <= q_start + block_q - 1)
    len_live = k_start < kv_len

    @pl.when(jnp.logical_and(diag_live, len_live))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)         # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)         # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)         # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos >= kv_len
        if causal:
            mask = jnp.logical_or(mask, kpos > qpos)
        s = jnp.where(mask, NEG_INF, s)

        m_prev = m_ref[...]                         # (bq,)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])             # (bq, bk)
        l_cur = jnp.sum(p, axis=1)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + l_cur
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _paged_decode_kernel(table_ref, kvlen_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, scale: float,
                         block_size: int, num_blocks: int):
    ib = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kvl = kvlen_ref[ib]

    # Blocks entirely past the row's length hold trash-block or stale data;
    # skipping them leaves the running statistics untouched — this is the
    # block-sparse part: compute (and, with scalar-prefetched index maps on
    # TPU, the tile fetch) scales with kv_len, not max_cache_len.
    @pl.when(j * block_size < kvl)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)         # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)         # (bs, d)
        v = v_ref[0, 0].astype(jnp.float32)         # (bs, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        # Absolute position of lane t in this block is j*bs + t; the query
        # sits at kv_len - 1, so the kv_len mask subsumes the causal mask.
        kpos = j * block_size + jax.lax.broadcasted_iota(jnp.int32,
                                                         s.shape, 1)
        s = jnp.where(kpos >= kvl, NEG_INF, s)

        m_prev = m_ref[...]                         # (1,)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == num_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention_fwd(q, k_pool, v_pool, block_table, kv_len, *,
                               scale: float | None = None,
                               interpret: bool = False):
    """Paged decode attention — Pallas TPU kernel (interpret mode on CPU).

    q: (B, H, 1, D); k_pool/v_pool: (N, KVH, bs, D);
    block_table: (B, max_blocks) int32; kv_len: (B,) int32.

    The block table and per-row lengths ride in as **scalar prefetch**
    (``PrefetchScalarGridSpec``), so the K/V BlockSpec index maps read the
    table *before* the kernel body runs: grid step (b, h, j) DMAs exactly
    the slab block ``table[b, j]`` — the gather never materializes in HBM,
    which is the whole point of the paged layout. GQA stays native via the
    ``h // g`` index map, as in the prefill kernel. The same grid spec is
    what the TPU dry-run roofline lowers; on CPU it runs in interpret mode
    and is validated against ``paged_attention_ref``.
    """
    b, h, lq, d = q.shape
    n, kvh, bs, _ = k_pool.shape
    assert lq == 1, "paged kernel is single-token decode only"
    assert h % kvh == 0
    g = h // kvh
    nb = block_table.shape[1]
    if scale is None:
        scale = d ** -0.5

    kernel = functools.partial(
        _paged_decode_kernel, scale=float(scale), block_size=bs,
        num_blocks=nb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, nb),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d),
                         lambda b_, h_, j, tbl, kvl: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h_, j, tbl, kvl: (tbl[b_, j],
                                                      h_ // g, 0, 0)),
            pl.BlockSpec((1, 1, bs, d),
                         lambda b_, h_, j, tbl, kvl: (tbl[b_, j],
                                                      h_ // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d),
                               lambda b_, h_, j, tbl, kvl: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, lq, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_table, jnp.int32), jnp.asarray(kv_len, jnp.int32),
      q, k_pool, v_pool)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        scale: float | None = None,
                        kv_len: int | None = None, q_offset: int = 0,
                        block_q: int = 256, block_k: int = 256,
                        interpret: bool = False):
    """q: (B, H, Lq, D); k, v: (B, KVH, Lk, D). Returns (B, H, Lq, D)."""
    b, h, lq, d = q.shape
    _, kvh, lk, _ = k.shape
    assert h % kvh == 0
    g = h // kvh
    if scale is None:
        scale = d ** -0.5
    if kv_len is None:
        kv_len = lk
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    assert lq % block_q == 0 and lk % block_k == 0, (lq, block_q, lk, block_k)
    nq, nk = lq // block_q, lk // block_k

    kernel = functools.partial(
        _fwd_kernel, scale=float(scale), causal=causal, kv_len=int(kv_len),
        q_offset=int(q_offset), block_q=block_q, block_k=block_k,
        num_k_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
