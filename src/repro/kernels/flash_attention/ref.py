"""Pure-jnp oracle for blocked GQA attention.

Also the implementation the models use on non-TPU backends and in the
multi-pod dry-run (XLA fuses it; Pallas lowering targets TPU and is
validated against this oracle in interpret mode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _is_per_row(x) -> bool:
    return x is not None and jnp.ndim(x) >= 1


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None,
                  kv_len=None, q_offset=0):
    """GQA attention oracle.

    q: (B, H, Lq, D); k, v: (B, KVH, Lk, D) with H % KVH == 0.
    ``kv_len`` masks padded key positions; ``q_offset`` is the absolute
    position of q[0] (decode: q_offset = cache length so causal masking is
    correct for a single new token). Both accept a scalar or a per-row
    (B,) array — the per-row form is the continuous-batching decode path,
    where every batch row sits at a different absolute position.
    """
    b, h, lq, d = q.shape
    _, kvh, lk, _ = k.shape
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    if scale is None:
        scale = d ** -0.5

    # GQA formulation depends on lq:
    # * lq > 1 (train/prefill): head-REPEAT. Under TP the q-heads dim (h)
    #   is what divides the model axis; a (kvh, g) reshape leaves no
    #   shardable dim and GSPMD replicates the (.., lq, lk) score tensors
    #   in the backward — 16x traffic. The repeat is a local broadcast.
    # * lq == 1 (decode): grouped einsum. Scores are tiny but the CACHE is
    #   huge; repeating it g-fold materializes/reshards gigabytes.
    kpos = jnp.arange(lk)
    per_row = _is_per_row(kv_len) or _is_per_row(q_offset)
    if per_row:
        # mask: (B, Lq, Lk) — each row masks by its own length/offset
        off = jnp.reshape(jnp.asarray(q_offset), (-1, 1))   # (B|1, 1)
        qpos = off + jnp.arange(lq)[None, :]                # (B|1, Lq)
        mask = jnp.zeros((b, lq, lk), bool)
        if causal:
            mask = mask | (kpos[None, None, :] > qpos[:, :, None])
        if kv_len is not None:
            kvl = jnp.reshape(jnp.asarray(kv_len), (-1, 1, 1))
            mask = mask | (kpos[None, None, :] >= kvl)
    else:
        mask = jnp.zeros((lq, lk), bool)
        if causal:
            qpos = q_offset + jnp.arange(lq)
            mask = mask | (kpos[None, :] > qpos[:, None])
        if kv_len is not None:
            mask = mask | (kpos[None, :] >= kv_len)

    if lq == 1 and g > 1:
        mg = mask[:, None, None] if per_row else mask[None, None, None]
        qf = q.astype(jnp.float32).reshape(b, kvh, g, lq, d)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        s = jnp.einsum("bkgqd,bkld->bkgql", qf, kf) * scale
        s = jnp.where(mg, NEG_INF, s)
        p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
        p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        o = jnp.einsum("bkgql,bkld->bkgqd", p, vf)
        return o.reshape(b, h, lq, d).astype(q.dtype)

    mr = mask[:, None] if per_row else mask[None, None]
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=1) if g > 1 \
        else k.astype(jnp.float32)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=1) if g > 1 \
        else v.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhld->bhql", qf, kf) * scale
    s = jnp.where(mr, NEG_INF, s)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhql,bhld->bhqd", p, vf).astype(q.dtype)


def paged_attention_ref(q, k_pool, v_pool, block_table, kv_len, *,
                        scale: float | None = None):
    """Block-sparse decode-attention oracle over a paged KV pool.

    q: (B, H, 1, D) — single-token decode queries.
    k_pool, v_pool: (N, KVH, bs, D) — the shared block slab (N blocks of
    ``bs`` positions each; block 0 is the trash block).
    block_table: (B, max_blocks) int32 — absolute position ``p`` of row
    ``b`` lives at ``k_pool[block_table[b, p // bs], :, p % bs]``;
    unallocated entries are 0 (trash) and masked by ``kv_len``.
    kv_len: (B,) int32 — valid cache length per row (query position is
    ``kv_len - 1``).

    Bit-equal to the dense per-row path by construction: the gather
    reconstructs a ``(B, KVH, max_blocks * bs, D)`` layout whose live
    positions hold exactly the bytes the dense cache holds, then calls the
    same ``attention_ref`` with the same per-row masks — masked (trash or
    stale) positions contribute an exact 0.0 either way.
    """
    b, h, lq, d = q.shape
    n, kvh, bs, _ = k_pool.shape
    nb = block_table.shape[1]
    gk = k_pool[block_table]                  # (B, nb, KVH, bs, D)
    gv = v_pool[block_table]
    gk = gk.transpose(0, 2, 1, 3, 4).reshape(b, kvh, nb * bs, d)
    gv = gv.transpose(0, 2, 1, 3, 4).reshape(b, kvh, nb * bs, d)
    kvl = jnp.asarray(kv_len, jnp.int32)
    return attention_ref(q, gk, gv, causal=True, scale=scale,
                         kv_len=kvl, q_offset=kvl - lq)


def attention_blocked(q, k, v, *, causal: bool = True,
                      scale: float | None = None, kv_len: int | None = None,
                      q_offset: int = 0, block_k: int = 1024,
                      unroll: bool = False):
    """Online-softmax attention in pure jnp (lax.scan over key blocks).

    Identical math to the Pallas kernel, compiled by XLA: scores are
    materialized only (Lq x block_k) at a time, which is what makes the 32k
    prefill cells fit on chip. Differentiable (scan autodiff); the models'
    remat policy bounds the backward residuals.
    """
    b, h, lq, d = q.shape
    _, kvh, lk, _ = k.shape
    g = h // kvh
    if scale is None:
        scale = d ** -0.5
    if kv_len is None:
        kv_len = lk
    block_k = min(block_k, lk)
    pad = (-lk) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = k.shape[2] // block_k

    # Head-repeat (see attention_ref): keeps the shardable h dim on every
    # blockwise tensor, so the backward residuals shard over TP.
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    qf = q.astype(jnp.float32)
    kb = k.astype(jnp.float32).reshape(b, h, nk, block_k, d
                                       ).transpose(2, 0, 1, 3, 4)
    vb = v.astype(jnp.float32).reshape(b, h, nk, block_k, d
                                       ).transpose(2, 0, 1, 3, 4)
    qpos = q_offset + jnp.arange(lq)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        kc, vc, ik = inp
        s = jnp.einsum("bhqd,bhld->bhql", qf, kc) * scale
        kpos = ik * block_k + jnp.arange(block_k)
        mask = kpos[None, :] >= kv_len
        if causal:
            mask = mask | (kpos[None, :] > qpos[:, None])
        s = jnp.where(mask[None, None], NEG_INF, s)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhql,bhld->bhqd", p, vc)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, lq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, lq), jnp.float32)
    a0 = jnp.zeros((b, h, lq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nk)),
                                  unroll=True if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)
