"""Public funnel-matching op: pattern tables -> per-stage reach counts."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import deepest_stage_pallas
from .ref import pack_match_bits, deepest_stage_ref


def deepest_stage(symbols, mask, stage_table, *, impl: str = "ref",
                  block_s: int = 256):
    """Per-session deepest funnel stage.

    symbols: (S, L) int32; mask: (S, L) bool;
    stage_table: (n_stages, alphabet) bool.
    """
    bits = pack_match_bits(jnp.asarray(symbols), jnp.asarray(mask),
                           jnp.asarray(stage_table))
    if impl == "ref":
        return deepest_stage_ref(bits)
    return deepest_stage_pallas(bits, block_s=block_s,
                                interpret=(impl == "interpret"))


def reach_counts(symbols, mask, stage_table, *, impl: str = "ref"):
    """[(stage, sessions reaching)] — the paper's §5.3 output table."""
    k = deepest_stage(symbols, mask, stage_table, impl=impl)
    n_stages = stage_table.shape[0]
    return [(j, int((k > j).sum())) for j in range(n_stages)]
