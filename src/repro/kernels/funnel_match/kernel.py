"""Funnel stage-automaton — Pallas TPU kernel.

TPU adaptation of the paper's regex-over-strings funnel UDF (§5.3),
decomposed as: (a) an embarrassingly-parallel gather turning each symbol
into a per-stage *match bitmask* (left to XLA — it fuses with upstream
ops), and (b) the inherently sequential automaton advance over positions —
this kernel.

The kernel holds a (block_s, L) tile of bitmasks in VMEM and advances the
per-session stage vector ``k`` with a fori_loop: ``k += (bits[:, t] >> k) & 1``
— one vectorized variable-shift per position, 8 lanes of automaton per
VREG word, zero HBM traffic beyond the single tile read. Grid is 1-D over
session blocks; sessions are independent so blocks parallelize freely.

VMEM: block_s=256, L=2048 -> 2MB int32 tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _funnel_kernel(bits_ref, out_ref, *, seq_len: int):
    bits = bits_ref[...]                       # (block_s, L) int32

    def body(t, k):
        adv = (jax.lax.dynamic_slice_in_dim(bits, t, 1, axis=1)[:, 0] >> k) & 1
        return k + adv

    k0 = jnp.zeros((bits.shape[0],), jnp.int32)
    out_ref[...] = jax.lax.fori_loop(0, seq_len, body, k0)


def deepest_stage_pallas(match_bits, *, block_s: int = 256,
                         interpret: bool = False):
    """(S, L) int32 bitmasks -> (S,) deepest stage reached."""
    s, l = match_bits.shape
    block_s = min(block_s, s)
    pad = (-s) % block_s
    if pad:
        match_bits = jnp.pad(match_bits, ((0, pad), (0, 0)))
    sp = match_bits.shape[0]

    out = pl.pallas_call(
        functools.partial(_funnel_kernel, seq_len=l),
        grid=(sp // block_s,),
        in_specs=[pl.BlockSpec((block_s, l), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_s,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((sp,), jnp.int32),
        interpret=interpret,
    )(match_bits)
    return out[:s]
