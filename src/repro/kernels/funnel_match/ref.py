"""Pure-jnp oracle for the funnel stage-automaton.

Operates on the bit-packed representation shared with the kernel:
``match_bits[s, t]`` has bit k set iff symbol t of session s satisfies
funnel stage k (invalid positions = 0). The automaton state k advances by
``(match_bits >> k) & 1`` per position — stage sets never advance past
n_stages because bit n_stages is never set.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pack_match_bits(symbols, mask, stage_table) -> jnp.ndarray:
    """(S, L) int32 bitmask from symbols + per-stage code lookup table.

    stage_table: (n_stages, alphabet) bool.
    """
    n_stages, alphabet = stage_table.shape
    assert n_stages <= 30
    sym = jnp.clip(symbols, 0, alphabet - 1)
    bits = jnp.zeros(symbols.shape, jnp.int32)
    for k in range(n_stages):
        bits = bits | (stage_table[k][sym].astype(jnp.int32) << k)
    return jnp.where(mask, bits, 0)


def deepest_stage_ref(match_bits: jnp.ndarray) -> jnp.ndarray:
    """(S,) deepest stage reached per session."""
    s, l = match_bits.shape

    def step(k, t):
        adv = (match_bits[:, t] >> k) & 1
        return k + adv, None

    k0 = jnp.zeros((s,), jnp.int32)
    k, _ = jax.lax.scan(step, k0, jnp.arange(l))
    return k


def deepest_stage_oracle_np(match_bits: np.ndarray) -> np.ndarray:
    out = np.zeros(match_bits.shape[0], np.int32)
    for si in range(match_bits.shape[0]):
        k = 0
        for t in range(match_bits.shape[1]):
            if (int(match_bits[si, t]) >> k) & 1:
                k += 1
        out[si] = k
    return out
