"""Sharded, async, topology-independent checkpointing.

Layout (one directory per step)::

    <dir>/step_000123.tmp/...       while writing
    <dir>/step_000123/
        manifest.json               tree structure, shapes, dtypes, sha256
        leaf_00000.npy ...          one file per pytree leaf
    <dir>/LATEST                    atomically-replaced pointer file

Protocol properties:
* **atomic commit** — data is written to ``.tmp`` and renamed only after
  fsync; a crash mid-save can never produce a half checkpoint that restore
  would pick up (the same stage->rename discipline as the paper's log
  mover, §2);
* **integrity** — every leaf carries a sha256 in the manifest, verified on
  restore;
* **async** — ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a background thread, overlapping I/O with the next steps;
* **topology-independent** — leaves are stored unsharded; ``restore`` takes
  the *current* mesh/rules and device_puts each leaf with its sharding, so
  a job checkpointed on one mesh restarts on another (elastic scaling).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _tree_paths(tree):
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ------------------------------------------------------------------ save
    def _write(self, step: int, host_leaves, treedef, paths):
        try:
            name = f"step_{step:08d}"
            final = os.path.join(self.dir, name)
            if os.path.isdir(final):       # idempotent re-save of same step
                return
            tmp = os.path.join(self.dir, name + ".tmp")
            os.makedirs(tmp, exist_ok=True)
            manifest = dict(step=step, treedef=str(treedef), leaves=[])
            for i, (leaf, path) in enumerate(zip(host_leaves, paths)):
                fname = f"leaf_{i:05d}.npy"
                buf = io.BytesIO()
                np.save(buf, leaf, allow_pickle=False)
                data = buf.getvalue()
                with open(os.path.join(tmp, fname), "wb") as f:
                    f.write(data)
                    f.flush()
                    os.fsync(f.fileno())
                manifest["leaves"].append(dict(
                    file=fname, path=path, shape=list(leaf.shape),
                    dtype=str(leaf.dtype),
                    sha256=hashlib.sha256(data).hexdigest()))
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, final)
            # atomic LATEST pointer
            ptr = os.path.join(self.dir, "LATEST.tmp")
            with open(ptr, "w") as f:
                f.write(name)
                f.flush()
                os.fsync(f.fileno())
            os.replace(ptr, os.path.join(self.dir, "LATEST"))
            self._gc()
        except Exception as e:  # surfaced on next wait()/save
            self._error = e

    def save_async(self, step: int, tree) -> None:
        """Snapshot to host memory now; write in the background."""
        self.wait()
        leaves, treedef = _flatten(tree)
        host = [np.asarray(l) for l in leaves]   # device->host sync point
        paths = _tree_paths(tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host, treedef, paths), daemon=True)
        self._thread.start()

    def save(self, step: int, tree) -> None:
        self.save_async(step, tree)
        self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in steps[: -self.keep]:
            full = os.path.join(self.dir, d)
            for f in os.listdir(full):
                os.unlink(os.path.join(full, f))
            os.rmdir(full)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        ptr = os.path.join(self.dir, "LATEST")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.dir, name)):
            return None
        return int(name.split("_")[1])

    def restore(self, template, step: int | None = None, shardings=None):
        """Restore into the structure of ``template``. ``shardings`` is an
        optional matching pytree of jax.sharding.Sharding — pass it to
        resume on a different mesh (elastic restart)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        t_leaves, treedef = _flatten(template)
        if len(manifest["leaves"]) != len(t_leaves):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, template "
                f"has {len(t_leaves)} — structure mismatch")
        s_leaves = (jax.tree.flatten(shardings)[0]
                    if shardings is not None else [None] * len(t_leaves))
        out = []
        for entry, tmpl, shard in zip(manifest["leaves"], t_leaves, s_leaves):
            with open(os.path.join(d, entry["file"]), "rb") as f:
                data = f.read()
            digest = hashlib.sha256(data).hexdigest()
            if digest != entry["sha256"]:
                raise IOError(f"checksum mismatch for {entry['path']}")
            arr = np.load(io.BytesIO(data), allow_pickle=False)
            if list(arr.shape) != list(np.shape(tmpl)):
                raise ValueError(
                    f"shape mismatch for {entry['path']}: "
                    f"{arr.shape} vs {np.shape(tmpl)}")
            out.append(jax.device_put(arr, shard) if shard is not None
                       else arr)
        return jax.tree.unflatten(treedef, out)
