"""Training substrate: optimizer, checkpointing, train loop, elasticity."""
from .optimizer import OptConfig, init_opt_state, apply_updates, schedule, \
    global_norm, compress_grads
from .checkpoint import CheckpointManager
from .train_loop import Trainer, TrainerConfig, make_train_step
from .elastic import reshard_state, restore_on_mesh, state_shardings, state_axes

__all__ = ["OptConfig", "init_opt_state", "apply_updates", "schedule",
           "global_norm", "compress_grads", "CheckpointManager",
           "Trainer", "TrainerConfig", "make_train_step",
           "reshard_state", "restore_on_mesh", "state_shardings", "state_axes"]
