"""Training loop: jitted step with microbatch gradient accumulation, NaN
guards, checkpoint-restart, and failure-injection hooks.

``make_train_step`` builds the jitted (state, batch) -> (state, metrics)
function; microbatching splits the per-step batch into ``cfg.microbatches``
slices and accumulates gradients with a ``lax.scan`` (remat'd model inside),
which is also the activation-memory lever for the biggest configs.

``Trainer`` drives the host loop: deterministic resume from (checkpoint
step -> epoch/step arithmetic on the deterministic pipeline), periodic
async checkpoints, straggler mitigation via the pipeline's prefetch thread,
and a watchdog that aborts if too many consecutive steps were skipped
non-finite.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models.registry import ModelApi
from .optimizer import OptConfig, init_opt_state, apply_updates
from .checkpoint import CheckpointManager


def make_train_step(api: ModelApi, opt_cfg: OptConfig):
    cfg = api.cfg
    n_micro = max(cfg.microbatches, 1)

    def loss_and_grad(params, batch):
        return jax.value_and_grad(lambda p: api.loss(p, batch), has_aux=True)(
            params)

    def step_fn(state, batch):
        params = state["params"]
        if n_micro == 1:
            (loss, aux), grads = loss_and_grad(params, batch)
        else:
            def split(t):
                b = t.shape[0]
                assert b % n_micro == 0, (t.shape, n_micro)
                return t.reshape((n_micro, b // n_micro) + t.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, loss_acc = carry
                (loss, aux), g = loss_and_grad(params, mb)
                g_acc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32),
                                     g_acc, g)
                return (g_acc, loss_acc + loss), aux

            g0 = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32),
                              params)
            carry0 = (g0, jnp.zeros((), jnp.float32))
            if cfg.unroll_microbatches:
                carry = carry0
                for i in range(n_micro):
                    mb = jax.tree.map(lambda t: t[i], micro)
                    carry, aux = acc_body(carry, mb)
                grads, loss_sum = carry
            else:
                (grads, loss_sum), auxs = jax.lax.scan(acc_body, carry0, micro)
                aux = jax.tree.map(lambda t: t[-1], auxs)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro

        new_params, new_opt, opt_stats = apply_updates(
            params, grads, state["opt"], opt_cfg)
        metrics = dict(loss=loss, **opt_stats)
        return dict(params=new_params, opt=new_opt), metrics

    return step_fn


@dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    max_consecutive_skips: int = 10
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3


@dataclass
class Trainer:
    api: ModelApi
    opt_cfg: OptConfig
    tcfg: TrainerConfig
    log_fn: Callable[[int, dict], None] = lambda step, m: None

    def init_state(self, seed: int = 0):
        params = self.api.init(jax.random.PRNGKey(seed))
        return dict(params=params, opt=init_opt_state(params, self.opt_cfg))

    def run(self, pipeline, state=None, resume: bool = True) -> dict:
        """Train over the deterministic pipeline; restart-safe."""
        ckpt = CheckpointManager(self.tcfg.checkpoint_dir,
                                 keep=self.tcfg.keep_checkpoints)
        start_step = 0
        if state is None:
            state = self.init_state()
            if resume and ckpt.latest_step() is not None:
                start_step = ckpt.latest_step()
                state = ckpt.restore(state, step=start_step)
                state = jax.tree.map(jnp.asarray, state)

        step_fn = jax.jit(make_train_step(self.api, self.opt_cfg))
        per_epoch = max(pipeline.batches_per_epoch(), 1)
        history = []
        last_skip = 0
        consecutive_skips = 0
        t0 = time.time()
        for step in range(start_step, self.tcfg.total_steps):
            epoch, estep = divmod(step, per_epoch)
            batch = pipeline.batch_at(epoch, estep)
            state, metrics = step_fn(state, batch)

            skipped = int(metrics["skipped"])
            consecutive_skips = (consecutive_skips + 1
                                 if skipped > last_skip else 0)
            last_skip = skipped
            if consecutive_skips >= self.tcfg.max_consecutive_skips:
                raise RuntimeError(
                    f"{consecutive_skips} consecutive non-finite steps — "
                    f"aborting for operator attention (last checkpoint is "
                    f"intact)")

            if (step + 1) % self.tcfg.log_every == 0 or step == start_step:
                m = {k: float(v) for k, v in metrics.items()}
                m["steps_per_s"] = (step + 1 - start_step) / max(
                    time.time() - t0, 1e-9)
                history.append((step + 1, m))
                self.log_fn(step + 1, m)
            if (step + 1) % self.tcfg.checkpoint_every == 0:
                ckpt.save_async(step + 1, state)
        ckpt.save(self.tcfg.total_steps, state)
        return dict(state=state, history=history)
