"""AdamW with warmup+cosine schedule, global-norm clipping, and optional
error-feedback gradient compression (pure JAX — no optax).

Compression simulates the cross-pod (DCI) all-reduce payload reduction:
``ef_int8`` quantizes each gradient tensor to int8 with a per-tensor scale
and carries the quantization error into the next step (error feedback keeps
the method unbiased in the long run); ``sign`` is 1-bit signSGD-style with
per-tensor L1 scaling. On real hardware the quantize/dequant pair brackets
the pod-axis all-reduce; the numerics here are exactly what ships.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compression: str = "none"    # none | ef_int8 | sign


def schedule(step, cfg: OptConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params, cfg: OptConfig):
    zeros = lambda p: jax.tree.map(lambda t: jnp.zeros_like(t, jnp.float32), p)
    state = dict(mu=zeros(params), nu=zeros(params),
                 step=jnp.zeros((), jnp.int32),
                 skipped=jnp.zeros((), jnp.int32))
    if cfg.compression in ("ef_int8", "sign"):
        state["err"] = zeros(params)
    return state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(t.astype(jnp.float32)))
                        for t in jax.tree.leaves(tree)))


def _quant_int8(t):
    scale = jnp.max(jnp.abs(t)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_grads(grads, err, mode: str):
    """Returns (compressed grads, new error feedback)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        if mode == "ef_int8":
            c = _quant_int8(gf)
        else:  # sign
            scale = jnp.mean(jnp.abs(gf))
            c = jnp.sign(gf) * scale
        return c, gf - c
    pairs = jax.tree.map(one, grads, err)
    comp = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_err


def apply_updates(params, grads, state, cfg: OptConfig):
    """One AdamW step with clip + optional compression + non-finite guard.

    A step whose global grad norm is non-finite is *skipped* (params and
    moments unchanged, 'skipped' counter bumped) — the cheap first line of
    fault tolerance against data poison / numeric blowups.
    """
    step = state["step"] + 1
    gnorm = global_norm(grads)
    finite = jnp.isfinite(gnorm)

    scale = jnp.where(gnorm > cfg.clip_norm, cfg.clip_norm / (gnorm + 1e-12),
                      1.0)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    new_err = state.get("err")
    if cfg.compression in ("ef_int8", "sign"):
        grads, new_err = compress_grads(grads, state["err"], cfg.compression)

    lr = schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, mu, nu):
        mu2 = b1 * mu + (1 - b1) * g
        nu2 = b2 * nu + (1 - b2) * jnp.square(g)
        mu_hat = mu2 / (1 - b1 ** step.astype(jnp.float32))
        nu_hat = nu2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), mu2, nu2

    trip = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3
    newp = jax.tree.map(lambda t: t[0], trip, is_leaf=is3)
    newmu = jax.tree.map(lambda t: t[1], trip, is_leaf=is3)
    newnu = jax.tree.map(lambda t: t[2], trip, is_leaf=is3)

    # Non-finite guard: keep old values wholesale.
    keep = lambda new, old: jax.tree.map(
        lambda n, o: jnp.where(finite, n, o), new, old)
    out_state = dict(mu=keep(newmu, state["mu"]), nu=keep(newnu, state["nu"]),
                     step=step,
                     skipped=state["skipped"] + (1 - finite.astype(jnp.int32)))
    if new_err is not None:
        out_state["err"] = keep(new_err, state["err"])
    return keep(newp, params), out_state, dict(
        grad_norm=gnorm, lr=lr, skipped=out_state["skipped"])
