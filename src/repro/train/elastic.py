"""Elastic scaling: restart a checkpointed job on a *different* mesh.

Checkpoints store unsharded leaves (checkpoint.py), so elasticity reduces
to recomputing the sharding tree for the new mesh and device_put-ing each
leaf. ``reshard_state`` also handles live (in-memory) state for planned
resizes — e.g. shrinking from (16, 16) to (8, 16) after losing a slice, the
scenario tests/test_elastic.py exercises on host devices.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

from ..dist.sharding import ShardingRules, adapt_rules_for_mesh, tree_spec
from ..models.registry import ModelApi
from .checkpoint import CheckpointManager


def state_axes(api: ModelApi):
    """Logical axes for the full train state (opt moments mirror params)."""
    p_axes = api.axes()
    scalar = ()
    axes = dict(params=p_axes,
                opt=dict(mu=p_axes, nu=p_axes, step=scalar, skipped=scalar))
    return axes


def state_shardings(api: ModelApi, mesh: Mesh, rules: ShardingRules,
                    with_err: bool = False):
    axes = state_axes(api)
    if with_err:
        axes["opt"]["err"] = axes["params"]
    rules = adapt_rules_for_mesh(rules, mesh)
    specs = tree_spec(axes, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def reshard_state(state, api: ModelApi, new_mesh: Mesh,
                  rules: ShardingRules):
    """Live reshard onto a new mesh (planned elastic resize)."""
    sh = state_shardings(api, new_mesh, rules,
                         with_err="err" in state.get("opt", {}))
    return jax.tree.map(jax.device_put, state, sh)


def restore_on_mesh(ckpt_dir: str, template_state, api: ModelApi,
                    mesh: Mesh, rules: ShardingRules, step: int | None = None):
    """Restore the latest checkpoint directly onto ``mesh`` — the unplanned
    restart path (node loss -> smaller pod)."""
    mgr = CheckpointManager(ckpt_dir)
    sh = state_shardings(api, mesh, rules,
                         with_err="err" in template_state.get("opt", {}))
    return mgr.restore(template_state, step=step, shardings=sh)
