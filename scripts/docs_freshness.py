"""Docs-freshness gate (run from the repo root with PYTHONPATH=src).

Fails CI when the top-level docs drift from the tree:

* README.md / docs/architecture.md must exist;
* the test-module count README claims ("spans **N test modules**") must
  match what ``pytest --collect-only -q`` actually collects;
* every ``examples/``, ``benchmarks/`` and ``docs/`` path README mentions
  must exist;
* the committed ``BENCH_pipeline.json`` must carry the segment-store
  sections with their equivalence flags true — a perf trajectory entry
  whose store-vs-oracle or store-vs-raw-query check failed must never
  land as if it were a valid measurement.
"""
from __future__ import annotations

import json
import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def fail(msg: str) -> None:
    print(f"docs-freshness: FAIL — {msg}", file=sys.stderr)
    sys.exit(1)


def collected_test_modules() -> set[str]:
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q"],
        capture_output=True, text=True, cwd=ROOT)
    if out.returncode != 0:
        fail(f"pytest --collect-only failed:\n{out.stdout[-2000:]}")
    mods = set()
    for line in out.stdout.splitlines():
        if "::" in line:
            mods.add(line.split("::")[0])
    return mods


def main() -> None:
    readme = ROOT / "README.md"
    if not readme.exists():
        fail("README.md is absent")
    if not (ROOT / "docs" / "architecture.md").exists():
        fail("docs/architecture.md is absent")
    text = readme.read_text()

    m = re.search(r"\*\*(\d+) test modules?\*\*", text)
    if not m:
        fail("README.md does not claim a test-module count "
             "('spans **N test modules**')")
    claimed = int(m.group(1))
    actual = len(collected_test_modules())
    if claimed != actual:
        fail(f"README claims {claimed} test modules, "
             f"pytest --collect-only finds {actual} — update README.md")

    missing = [p for p in re.findall(
        r"`((?:examples|benchmarks|docs)/[\w./-]+\.(?:py|md))`", text)
        if not (ROOT / p).exists()]
    if missing:
        fail(f"README references missing paths: {missing}")

    check_store_bench(ROOT / "BENCH_pipeline.json")

    print(f"docs-freshness: OK ({actual} test modules, README claims match)")


def check_store_bench(path: Path) -> None:
    """The committed benchmark record must include the segment-store rows
    and their correctness flags must be true (benchmarks/compression.py
    and benchmarks/query_speed.py assert these at measurement time; this
    catches a stale or hand-edited committed record)."""
    if not path.exists():
        fail("BENCH_pipeline.json is absent")
    try:
        bench = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        fail(f"BENCH_pipeline.json does not parse: {e}")
    store = bench.get("store")
    if not isinstance(store, dict):
        fail("BENCH_pipeline.json has no 'store' section — run "
             "benchmarks.run --only compression --json")
    if not isinstance(store.get("bytes_per_event"), (int, float)) \
            or store["bytes_per_event"] <= 0:
        fail("store.bytes_per_event missing or non-positive")
    if store.get("equal_oracle") is not True:
        fail("store.equal_oracle is not true — compaction no longer "
             "matches the full-corpus sessionize oracle")
    sq = bench.get("store_query")
    if not isinstance(sq, dict):
        fail("BENCH_pipeline.json has no 'store_query' section — run "
             "benchmarks.run --only query_speed --json")
    if sq.get("equal_raw") is not True:
        fail("store_query.equal_raw is not true — the pruned scan no "
             "longer matches the raw re-sessionize path")


if __name__ == "__main__":
    main()
