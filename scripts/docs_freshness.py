"""Docs-freshness gate (run from the repo root with PYTHONPATH=src).

Fails CI when the top-level docs drift from the tree:

* README.md / docs/architecture.md must exist;
* the test-module count README claims ("spans **N test modules**") must
  match what ``pytest --collect-only -q`` actually collects;
* every ``examples/``, ``benchmarks/`` and ``docs/`` path README mentions
  must exist.
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def fail(msg: str) -> None:
    print(f"docs-freshness: FAIL — {msg}", file=sys.stderr)
    sys.exit(1)


def collected_test_modules() -> set[str]:
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q"],
        capture_output=True, text=True, cwd=ROOT)
    if out.returncode != 0:
        fail(f"pytest --collect-only failed:\n{out.stdout[-2000:]}")
    mods = set()
    for line in out.stdout.splitlines():
        if "::" in line:
            mods.add(line.split("::")[0])
    return mods


def main() -> None:
    readme = ROOT / "README.md"
    if not readme.exists():
        fail("README.md is absent")
    if not (ROOT / "docs" / "architecture.md").exists():
        fail("docs/architecture.md is absent")
    text = readme.read_text()

    m = re.search(r"\*\*(\d+) test modules?\*\*", text)
    if not m:
        fail("README.md does not claim a test-module count "
             "('spans **N test modules**')")
    claimed = int(m.group(1))
    actual = len(collected_test_modules())
    if claimed != actual:
        fail(f"README claims {claimed} test modules, "
             f"pytest --collect-only finds {actual} — update README.md")

    missing = [p for p in re.findall(
        r"`((?:examples|benchmarks|docs)/[\w./-]+\.(?:py|md))`", text)
        if not (ROOT / p).exists()]
    if missing:
        fail(f"README references missing paths: {missing}")

    print(f"docs-freshness: OK ({actual} test modules, README claims match)")


if __name__ == "__main__":
    main()
